//! `cargo bench` target for the parallel inference hot path: threaded
//! packed matvec scaling, batched-vs-sequential prefill, decode
//! tokens/sec on a Llama-2-7B-shaped block, and the continuous-batching
//! serve section - scheduler vs sequential per-request decode at batch
//! 1/4/8 with latency percentiles (custom harness - criterion is
//! unavailable offline; see rust/src/bench/mod.rs).
//!
//! Writes the machine-readable perf snapshot `runs/bench.json` (schema 5:
//! inference sections + native train_step + taped-vs-forward-only
//! eval_forward + serve + the paged-KV kv_fork section; see
//! docs/BENCH_SCHEMA.md) so the throughput trajectory is tracked across
//! PRs. `EQAT_BENCH_FAST=1` shrinks shapes/iterations for CI smoke runs;
//! `EQAT_THREADS=N` caps the worker count.

fn main() {
    efficientqat::util::logging::init();
    let fast = std::env::var("EQAT_BENCH_FAST").is_ok();
    match efficientqat::bench::inference_throughput(fast) {
        Ok((md, payload)) => {
            println!("{md}");
            let _ = std::fs::create_dir_all("runs");
            let _ = std::fs::write("runs/inference.md", &md);
            if let Err(e) = efficientqat::bench::write_bench_json(
                "runs/bench.json", &payload)
            {
                eprintln!("writing runs/bench.json failed: {e:#}");
                std::process::exit(1);
            }
            println!("wrote runs/bench.json");
        }
        Err(e) => {
            eprintln!("inference bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}
