//! `cargo bench` target reproducing paper Table 10: FP-baseline vs packed
//! INT2/3/4 matvec at the exact Llama-2 layer shapes (custom harness -
//! criterion is unavailable offline; see rust/src/bench/mod.rs).
//!
//! Alongside the markdown table it drops machine-readable rows at
//! runs/t10-qlinear.json (the cross-PR throughput snapshot lives in
//! runs/bench.json, written by the `inference` bench).

fn main() {
    efficientqat::util::logging::init();
    let fast = std::env::var("EQAT_BENCH_FAST").is_ok();
    match efficientqat::bench::qlinear_speed_table(fast) {
        Ok((md, rows)) => {
            println!("{md}");
            let _ = std::fs::create_dir_all("runs");
            let _ = std::fs::write("runs/t10-qlinear.md", &md);
            if let Err(e) = efficientqat::bench::write_bench_json(
                "runs/t10-qlinear.json", &rows)
            {
                eprintln!("writing runs/t10-qlinear.json failed: {e:#}");
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("qlinear bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}
