//! `cargo bench` target for paper Tables 8/9: EfficientQAT phase wall-times
//! and memory vs the naive-QAT comparator. Requires artifacts; skips
//! gracefully (exit 0 with a notice) when they are missing so `cargo bench`
//! stays runnable on a fresh checkout.
//!
//! (Inference-side throughput lives in the `inference` bench, which also
//! maintains the cross-PR perf snapshot runs/bench.json.)

use efficientqat::exp::{tables, ExpCtx};

fn main() {
    efficientqat::util::logging::init();
    let ctx = match ExpCtx::new("artifacts", "runs", "auto") {
        Ok(c) => c,
        Err(e) => {
            eprintln!("train_time bench skipped (no backend): {e}");
            return;
        }
    };
    for id in ["t8", "t9"] {
        if let Err(e) = tables::run(&ctx, id, "tiny") {
            eprintln!("{id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}
