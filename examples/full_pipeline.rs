//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer on the largest preset -
//!   pretrain a ~6M-param transformer for several hundred steps (loss curve
//!   logged), run the full EfficientQAT pipeline at w2/w4, evaluate
//!   zero-shot + perplexity vs FP16/RTN, verify the packed model round-trips
//!   and that the pure-Rust engine agrees with the XLA forward, and report
//!   wall-times.
//!
//!     cargo run --release --example full_pipeline [preset] [steps]

use anyhow::Result;
use efficientqat::config::{QuantScheme, TrainHp};
use efficientqat::coordinator::block_ap::rtn_quantize_model;
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::{domain_redpajama, World};
use efficientqat::data::loader::LmLoader;
use efficientqat::eval::fwd::ModelRef;
use efficientqat::eval::zeroshot::eval_zeroshot;
use efficientqat::eval::ppl::perplexity;
use efficientqat::infer::engine::Engine;
use efficientqat::model::quantized::QuantizedModel;
use efficientqat::runtime::make_backend;

fn main() -> Result<()> {
    efficientqat::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("small");
    let steps: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = make_backend("auto", "artifacts")?;
    let cfg = rt.manifest().preset(preset)?.config.clone();
    let fpl = rt.manifest().layout(preset, "fp")?;
    let world = World::new(cfg.vocab, 7);
    let dom = domain_redpajama();
    println!("== end-to-end driver: preset {preset} ({:.1}M params), \
              {steps} pretrain steps ==",
             fpl.size as f64 / 1e6);

    // Phase 0: pretrain with logged loss curve
    let mut loader = LmLoader::new(&world, &dom, 11, cfg.e2e_batch,
                                   cfg.e2e_ctx);
    let opts = PretrainOpts { steps, lr: 3e-3, seed: 5, log_every: 25 };
    let t0 = std::time::Instant::now();
    let (params, rep) = pretrain(rt.as_ref(), preset, &mut loader, &opts)?;
    println!("[pretrain] {:.3} -> {:.3} in {:.1}s ({:.1} tok/s)",
             rep.losses[0], rep.losses.last().unwrap(), rep.seconds,
             (steps * cfg.e2e_batch * cfg.e2e_ctx) as f64 / rep.seconds);
    std::fs::create_dir_all("runs")?;
    std::fs::write(
        format!("runs/full-pipeline-{preset}-loss.csv"),
        rep.losses.iter().map(|l| format!("{l:.4}"))
            .collect::<Vec<_>>().join("\n"),
    )?;

    // Phase 1+2: EfficientQAT at w4 and w2
    let mut summary = Vec::new();
    let fp_ref = ModelRef::Fp { preset, params: &params };
    let (fp_suites, fp_acc) = eval_zeroshot(rt.as_ref(), &fp_ref, &world, 60, 1234)?;
    let fp_ppl = perplexity(rt.as_ref(), &fp_ref, &world, &dom, 4, 99)?;
    summary.push(format!(
        "FP16: acc {:.1}% ppl {fp_ppl:.2}", 100.0 * fp_acc));
    for (s, a) in &fp_suites {
        println!("  fp16 {s}: {:.1}%", 100.0 * a);
    }

    for bits in [4u32, 2] {
        let sch = QuantScheme::new(bits, cfg.default_group);
        let hp = TrainHp::default();
        let (mut qm, prep) = efficient_qat(rt.as_ref(), preset, &params, sch, &hp,
                                           &world, &dom,
                                           PhaseToggle::default())?;
        qm.round_scales_f16();
        let rtn = rtn_quantize_model(rt.as_ref(), preset, &params, sch)?;
        let (_, acc_rtn) =
            eval_zeroshot(rt.as_ref(), &ModelRef::Quant(&rtn), &world, 60, 1234)?;
        let (_, acc_eq) =
            eval_zeroshot(rt.as_ref(), &ModelRef::Quant(&qm), &world, 60, 1234)?;
        let ppl_rtn = perplexity(rt.as_ref(), &ModelRef::Quant(&rtn), &world, &dom,
                                 4, 99)?;
        let ppl_eq = perplexity(rt.as_ref(), &ModelRef::Quant(&qm), &world, &dom,
                                4, 99)?;
        summary.push(format!(
            "{}: RTN acc {:.1}% ppl {ppl_rtn:.2} | EfficientQAT acc \
             {:.1}% ppl {ppl_eq:.2} ({:.1}s pipeline)",
            sch.tag(), 100.0 * acc_rtn, 100.0 * acc_eq, prep.total_seconds
        ));

        // round-trip + engine parity check at w2
        if bits == 2 {
            let path = format!("runs/full-pipeline-{preset}-{}.eqt",
                               sch.tag());
            qm.save(&path)?;
            let back = QuantizedModel::load(&path)?;
            assert_eq!(back.wq, qm.wq, "packed roundtrip mismatch");
            let info = rt.manifest().preset(preset)?;
            let mut eng = Engine::new(&back, info, cfg.eval_ctx)?;
            let mut l = LmLoader::new(&world, &dom, 3, cfg.eval_batch,
                                      cfg.eval_ctx);
            let b = l.next_batch();
            let xla = ModelRef::Quant(&back).logits(rt.as_ref(), &b.x)?;
            let mut max_err = 0f32;
            for (t, &tok) in b.x[..cfg.eval_ctx].iter().enumerate() {
                let lg = eng.step(tok)?;
                for (a, c) in
                    lg.iter().zip(&xla[t * cfg.vocab..(t + 1) * cfg.vocab])
                {
                    max_err = max_err.max((a - c).abs());
                }
            }
            println!("[deploy] engine-vs-XLA max logit err: {max_err:.2e}");
            assert!(max_err < 5e-3);
        }
    }

    println!("\n== SUMMARY (total {:.1}s) ==", t0.elapsed().as_secs_f64());
    for s in &summary {
        println!("  {s}");
    }
    std::fs::write(format!("runs/full-pipeline-{preset}-summary.txt"),
                   summary.join("\n"))?;
    Ok(())
}
