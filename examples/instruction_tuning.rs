//! Instruction-tuning scenario (paper §4.2): take a pretrained model,
//! quantize, then adapt to the instruction-following format by training
//! only the step sizes (E2E-QP) on an Alpaca-like synthetic set; compare
//! against PEQA and QLoRA on the MMLU-like few-shot exam.
//!
//!     cargo run --release --example instruction_tuning

use anyhow::Result;
use efficientqat::baselines::qlora::{run_peqa, run_qlora};
use efficientqat::config::{QuantScheme, TrainHp};
use efficientqat::coordinator::block_ap::rtn_quantize_model;
use efficientqat::coordinator::e2e_qp::{instr_batches, run_e2e_qp};
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::{domain_redpajama, World};
use efficientqat::data::loader::{InstrLoader, LmLoader};
use efficientqat::eval::fwd::ModelRef;
use efficientqat::eval::zeroshot::eval_mmlu;
use efficientqat::runtime::make_backend;

fn main() -> Result<()> {
    efficientqat::util::logging::init();
    let rt = make_backend("auto", "artifacts")?;
    let preset = "tiny";
    let cfg = rt.manifest().preset(preset)?.config.clone();
    let world = World::new(cfg.vocab, 7);
    let dom = domain_redpajama();

    let mut loader = LmLoader::new(&world, &dom, 11, cfg.e2e_batch,
                                   cfg.e2e_ctx);
    let opts = PretrainOpts { steps: 250, lr: 3e-3, seed: 5, log_every: 50 };
    let (params, _) = pretrain(rt.as_ref(), preset, &mut loader, &opts)?;

    let sch = QuantScheme::new(2, cfg.default_group);
    let hp = TrainHp::default();
    let mk_batches = || {
        let mut il = InstrLoader::new(&world, 91, 256, cfg.e2e_batch,
                                      cfg.e2e_ctx);
        instr_batches(&mut il, 48)
    };

    let base_acc = eval_mmlu(
        rt.as_ref(), &ModelRef::Fp { preset, params: &params }, &world, 555)?;
    println!("base fp16 (no tuning): MMLU-like {:.1}%", 100.0 * base_acc);

    // PEQA: RTN + step-size tuning
    let (peqa, _) = run_peqa(rt.as_ref(), preset, &params, sch, &mk_batches(), &hp)?;
    println!(
        "PEQA {}: {:.1}%",
        sch.tag(),
        100.0 * eval_mmlu(rt.as_ref(), &ModelRef::Quant(&peqa), &world, 555)?
    );

    // QLoRA at 4-bit base (its standard regime)
    let qbase = rtn_quantize_model(rt.as_ref(), preset, &params,
                                   QuantScheme::new(4, cfg.default_group))?;
    let (lora, _) = run_qlora(rt.as_ref(), &qbase, &mk_batches(), 1, 2e-3, 33)?;
    println!(
        "QLoRA w4+16: {:.1}%",
        100.0 * eval_mmlu(rt.as_ref(), &ModelRef::Lora { qm: &qbase, lora: &lora },
                          &world, 555)?
    );

    // EfficientQAT: Block-AP init then instruction E2E-QP
    let (mut eq, _) = efficient_qat(rt.as_ref(), preset, &params, sch, &hp, &world,
                                    &dom,
                                    PhaseToggle { block_ap: true,
                                                  e2e_qp: false })?;
    let before = eval_mmlu(rt.as_ref(), &ModelRef::Quant(&eq), &world, 555)?;
    run_e2e_qp(rt.as_ref(), &mut eq, &mk_batches(), &hp)?;
    let after = eval_mmlu(rt.as_ref(), &ModelRef::Quant(&eq), &world, 555)?;
    println!(
        "EfficientQAT {}: {:.1}% -> {:.1}% after instruction E2E-QP",
        sch.tag(), 100.0 * before, 100.0 * after
    );
    Ok(())
}
