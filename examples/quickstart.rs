//! Quickstart: pretrain a tiny LM on the synthetic corpus, quantize it to
//! 2-bit with EfficientQAT (Block-AP + E2E-QP), compare against RTN, save
//! the packed model, and generate text with the pure-Rust engine.
//!
//!     cargo run --release --example quickstart
//!
//! Runs on the native pure-Rust backend out of the box; `make artifacts`
//! switches it to the PJRT AOT path automatically (backend "auto").

use anyhow::Result;
use efficientqat::config::{QuantScheme, TrainHp};
use efficientqat::coordinator::block_ap::rtn_quantize_model;
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::{domain_redpajama, World};
use efficientqat::data::loader::LmLoader;
use efficientqat::eval::fwd::ModelRef;
use efficientqat::eval::ppl::perplexity;
use efficientqat::infer::engine::Engine;
use efficientqat::infer::generate::{generate, Sampler};
use efficientqat::model::quantized::QuantizedModel;
use efficientqat::runtime::make_backend;

fn main() -> Result<()> {
    efficientqat::util::logging::init();
    let rt = make_backend("auto", "artifacts")?;
    let preset = "tiny";
    let cfg = rt.manifest().preset(preset)?.config.clone();
    let world = World::new(cfg.vocab, 7);
    let dom = domain_redpajama();

    // 1. pretrain the fp model (the asset the paper downloads, we build)
    println!("== pretraining {preset} ==");
    let mut loader = LmLoader::new(&world, &dom, 11, cfg.e2e_batch,
                                   cfg.e2e_ctx);
    let opts = PretrainOpts { steps: 200, lr: 3e-3, seed: 5, log_every: 40 };
    let (params, rep) = pretrain(rt.as_ref(), preset, &mut loader, &opts)?;
    println!("loss {:.3} -> {:.3} in {:.1}s",
             rep.losses[0], rep.losses.last().unwrap(), rep.seconds);

    // 2. EfficientQAT to 2-bit
    let sch = QuantScheme::new(2, cfg.default_group);
    println!("== EfficientQAT {} ==", sch.tag());
    let hp = TrainHp::default();
    let (mut qm, prep) = efficient_qat(rt.as_ref(), preset, &params, sch, &hp,
                                       &world, &dom,
                                       PhaseToggle::default())?;
    qm.round_scales_f16();
    println!("pipeline done in {:.1}s", prep.total_seconds);

    // 3. compare: FP16, RTN, EfficientQAT perplexity
    let rtn = rtn_quantize_model(rt.as_ref(), preset, &params, sch)?;
    for (name, m) in [
        ("FP16", ModelRef::Fp { preset, params: &params }),
        ("RTN w2", ModelRef::Quant(&rtn)),
        ("EfficientQAT w2", ModelRef::Quant(&qm)),
    ] {
        let ppl = perplexity(rt.as_ref(), &m, &world, &dom, 4, 99)?;
        println!("{name:>16}: ppl {ppl:.2}");
    }

    // 4. save packed + generate with the pure-Rust engine
    std::fs::create_dir_all("runs")?;
    let path = format!("runs/quickstart-{}.eqt", sch.tag());
    qm.save(&path)?;
    println!("packed model: {path} ({:.2} MB)",
             qm.packed_bytes() as f64 / 1e6);
    let qm2 = QuantizedModel::load(&path)?;
    let info = rt.manifest().preset(preset)?;
    let mut eng = Engine::new(&qm2, info, cfg.eval_ctx)?;
    let prompt = vec![0, world.topic_tokens(3)[0], world.topic_tokens(3)[1]];
    let g = generate(&mut eng, &prompt, 32, Sampler::Temperature(0.8), 7)?;
    println!("generated {:?}", g.tokens);
    println!("decode speed: {:.0} tok/s (pure rust, packed 2-bit)",
             g.decode_tok_per_sec);
    Ok(())
}
