//! Deployment scenario: load a packed low-bit model from disk and serve
//! generations with the pure-Rust engine (no Python, no XLA on the request
//! path), reporting latency/throughput per request - plus the INT2-vs-f32
//! decode-speed comparison that motivates uniform quantization (Table 10).
//!
//! The request path is the parallel one: prompts go through the batched
//! prefill (one packed matmul per linear, KV cache filled in one pass),
//! decode reuses the engine's persistent scratch (zero allocation per
//! token), and the kernels row/token-chunk across `EQAT_THREADS` workers.
//!
//!     cargo run --release --example serve_quantized [model.eqt]

use anyhow::Result;
use efficientqat::config::{QuantScheme, TrainHp};
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::{domain_redpajama, World};
use efficientqat::data::loader::LmLoader;
use efficientqat::infer::engine::Engine;
use efficientqat::infer::generate::{generate, Sampler};
use efficientqat::model::quantized::QuantizedModel;
use efficientqat::runtime::make_backend;

fn main() -> Result<()> {
    efficientqat::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rt = make_backend("auto", "artifacts")?;

    // load packed model, or build one on the spot
    let qm = match args.first() {
        Some(p) => QuantizedModel::load(p)?,
        None => {
            let preset = "tiny";
            let cfg = rt.manifest().preset(preset)?.config.clone();
            let world = World::new(cfg.vocab, 7);
            let dom = domain_redpajama();
            let mut loader = LmLoader::new(&world, &dom, 11, cfg.e2e_batch,
                                           cfg.e2e_ctx);
            let opts = PretrainOpts { steps: 150, lr: 3e-3, seed: 5,
                                      log_every: 0 };
            let (params, _) = pretrain(rt.as_ref(), preset, &mut loader, &opts)?;
            let sch = QuantScheme::new(2, cfg.default_group);
            let (mut qm, _) = efficient_qat(
                rt.as_ref(), preset, &params, sch, &TrainHp::default(), &world,
                &dom, PhaseToggle::default())?;
            qm.round_scales_f16();
            qm
        }
    };
    let info = rt.manifest().preset(&qm.preset)?;
    let cfg = info.config.clone();
    let world = World::new(cfg.vocab, 7);
    println!(
        "serving {} {} ({:.2} MB packed, ctx {}, {} worker thread(s))",
        qm.preset, qm.scheme.tag(),
        qm.packed_bytes() as f64 / 1e6, cfg.eval_ctx,
        efficientqat::util::threads::num_threads()
    );

    // serve a batch of "requests" (prompts from different topics); each
    // prompt takes the batched prefill path, decode is zero-alloc
    let mut eng = Engine::new(&qm, info, cfg.eval_ctx)?;
    let mut total_tokens = 0usize;
    let mut total_secs = 0f64;
    let mut total_prefill_secs = 0f64;
    let mut total_prompt_tokens = 0usize;
    for req in 0..6 {
        let topic = world.topic_tokens(req * 2 + 1);
        let prompt = vec![0, topic[0], topic[1], topic[2]];
        let rep = generate(&mut eng, &prompt, 40,
                           Sampler::Temperature(0.8), 100 + req as u64)?;
        println!(
            "req {req}: prefill {:.1}ms ({} tok), {} tokens @ {:.0} tok/s",
            rep.prefill_secs * 1e3,
            prompt.len(),
            rep.tokens.len(),
            rep.decode_tok_per_sec
        );
        total_tokens += rep.tokens.len();
        total_secs += rep.decode_secs;
        total_prefill_secs += rep.prefill_secs;
        total_prompt_tokens += prompt.len();
    }
    println!(
        "aggregate: prefill {:.0} tok/s (batched), decode {:.0} tok/s",
        total_prompt_tokens as f64 / total_prefill_secs.max(1e-9),
        total_tokens as f64 / total_secs.max(1e-9)
    );
    Ok(())
}
