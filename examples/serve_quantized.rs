//! Deployment scenario: load a packed low-bit model from disk and serve
//! a stream of concurrent requests with the pure-Rust serving core (no
//! Python, no XLA on the request path): one shared immutable `ModelCore`,
//! per-request sessions leasing page tables from the paged KV pool, and
//! continuous-batching `Scheduler` running one rows-parallel matmul per
//! linear per tick across all live sequences.
//!
//! The demo serves the same request set twice - sequentially on a solo
//! engine, then batched through the scheduler - prints both aggregate
//! throughputs, and checks the serving determinism contract: batching
//! changes the speed, never the tokens.
//!
//!     cargo run --release --example serve_quantized [model.eqt]

use std::sync::Arc;

use anyhow::Result;
use efficientqat::config::{QuantScheme, TrainHp};
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::{domain_redpajama, World};
use efficientqat::data::loader::LmLoader;
use efficientqat::infer::core::ModelCore;
use efficientqat::infer::engine::Engine;
use efficientqat::infer::generate::{generate, Sampler};
use efficientqat::infer::sched::{SchedConfig, Scheduler};
use efficientqat::infer::session::Request;
use efficientqat::model::quantized::QuantizedModel;
use efficientqat::runtime::make_backend;

fn main() -> Result<()> {
    efficientqat::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rt = make_backend("auto", "artifacts")?;

    // load packed model, or build one on the spot
    let qm = match args.first() {
        Some(p) => QuantizedModel::load(p)?,
        None => {
            let preset = "tiny";
            let cfg = rt.manifest().preset(preset)?.config.clone();
            let world = World::new(cfg.vocab, 7);
            let dom = domain_redpajama();
            let mut loader = LmLoader::new(&world, &dom, 11, cfg.e2e_batch,
                                           cfg.e2e_ctx);
            let opts = PretrainOpts { steps: 150, lr: 3e-3, seed: 5,
                                      log_every: 0 };
            let (params, _) = pretrain(rt.as_ref(), preset, &mut loader, &opts)?;
            let sch = QuantScheme::new(2, cfg.default_group);
            let (mut qm, _) = efficient_qat(
                rt.as_ref(), preset, &params, sch, &TrainHp::default(), &world,
                &dom, PhaseToggle::default())?;
            qm.round_scales_f16();
            qm
        }
    };
    let info = rt.manifest().preset(&qm.preset)?;
    let cfg = info.config.clone();
    let world = World::new(cfg.vocab, 7);
    println!(
        "serving {} {} ({:.2} MB packed, ctx {}, {} worker thread(s))",
        qm.preset, qm.scheme.tag(),
        qm.packed_bytes() as f64 / 1e6, cfg.eval_ctx,
        efficientqat::util::threads::num_threads()
    );

    // one shared immutable core serves every request
    let core = Arc::new(ModelCore::from_quantized(&qm, info,
                                                  cfg.eval_ctx)?);
    let requests: Vec<(Vec<i32>, u64)> = (0..6)
        .map(|req| {
            let topic = world.topic_tokens(req * 2 + 1);
            (vec![0, topic[0], topic[1], topic[2]], 100 + req as u64)
        })
        .collect();
    let max_new = 40;

    // baseline: the same requests one after another on a solo engine
    let mut eng = Engine::from_core(core.clone());
    let t0 = std::time::Instant::now();
    let mut seq_outs = Vec::new();
    let mut total_tokens = 0usize;
    for (prompt, seed) in &requests {
        eng.reset();
        let rep = generate(&mut eng, prompt, max_new,
                           Sampler::Temperature(0.8), *seed)?;
        total_tokens += rep.tokens.len();
        seq_outs.push(rep.tokens);
    }
    let seq_secs = t0.elapsed().as_secs_f64();

    // batched: all requests live at once over 4 sequences' worth of KV
    // pages (late requests queue until pages free up as sequences retire)
    let mut sched = Scheduler::new(core, 4, SchedConfig {
        max_batch: 4,
        prefill_chunk: 8,
        ..SchedConfig::default()
    });
    for (prompt, seed) in &requests {
        sched.submit(Request::new(prompt.clone(), max_new,
                                  Sampler::Temperature(0.8), *seed))?;
    }
    let t1 = std::time::Instant::now();
    let comps = sched.run_all()?;
    let sched_secs = t1.elapsed().as_secs_f64();
    for c in &comps {
        println!(
            "req {}: {} prompt tok -> {} tokens, first token {:.1}ms, \
             done {:.1}ms",
            c.id, c.prompt_len, c.tokens.len(),
            c.first_token_secs * 1e3, c.finish_secs * 1e3
        );
        // determinism contract: batching never changes the tokens
        assert_eq!(c.tokens, seq_outs[c.id as usize],
                   "batched output diverged from solo");
    }
    println!(
        "aggregate: sequential {:.0} tok/s vs batched {:.0} tok/s \
         ({:.2}x), outputs identical",
        total_tokens as f64 / seq_secs.max(1e-9),
        total_tokens as f64 / sched_secs.max(1e-9),
        seq_secs / sched_secs.max(1e-9)
    );
    Ok(())
}
