"""AOT lowering: JAX graphs -> HLO TEXT artifacts + manifest.json.

HLO *text* (not `.serialize()`): the image's xla_extension 0.5.1 rejects
jax>=0.5 protos (64-bit instruction ids); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--presets tiny,small]
The Makefile invokes this once; artifacts are never rebuilt on the request
path. Rust consumes manifest.json (rust/src/io/manifest.rs).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import train
from .configs import PRESETS


def to_hlo_text(fn, args) -> str:
    """Lower a jitted fn to HLO text via stablehlo -> XlaComputation.

    print_large_constants=True is CRITICAL: the default printer elides any
    array constant as `{...}`, which HloModuleProto::from_text_file silently
    parses as ZEROS - e.g. the RoPE frequency table became all-zero
    exponents (freq 1.0) and every position-dependent computation was wrong
    while position 0 stayed exact. Found via the engine-vs-XLA parity test.
    """
    lowered = jax.jit(fn).lower(*[a for (_, a) in args])
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "constant({...})" in text:
        raise RuntimeError("elided constant survived in HLO text")
    return text


def arg_desc(args):
    out = []
    for name, sds in args:
        out.append({
            "name": name,
            "shape": list(sds.shape),
            "dtype": {"int32": "s32", "float32": "f32"}[str(sds.dtype)],
        })
    return out


def lower_preset(p, out_dir, manifest, only=None):
    pdir = os.path.join(out_dir, p.name)
    os.makedirs(pdir, exist_ok=True)

    jobs = []
    for entry, builder in train.BASE_ENTRIES.items():
        jobs.append((entry, builder(p), None))
    for g in p.group_sizes:
        for entry, builder in train.GROUP_ENTRIES.items():
            if entry in train.DEFAULT_GROUP_ONLY and g != p.default_group:
                continue
            jobs.append((f"{entry}_g{g}", builder(p, g), g))

    for name, (fn, args, outs), group in jobs:
        if only and name not in only:
            continue
        t0 = time.time()
        text = to_hlo_text(fn, args)
        rel = f"{p.name}/{name}.hlo.txt"
        path = os.path.join(out_dir, rel)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "preset": p.name,
            "entry": name,
            "group": group,
            "file": rel,
            "args": arg_desc(args),
            "outputs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  [{p.name}] {name}: {len(text)/1e6:.2f} MB "
              f"({time.time()-t0:.1f}s)", flush=True)


def layouts_json(p):
    out = {
        "fp": M.fp_layout(p).to_json(),
        "block": M.block_layout(p).to_json(),
        "wq_block": M.wq_block_layout(p).to_json(),
        "wq": M.wq_layout(p).to_json(),
        "fpr": M.fpr_layout(p).to_json(),
        "lora": M.lora_layout(p).to_json(),
    }
    for g in p.group_sizes:
        out[f"qp_g{g}"] = M.qp_layout(p, g).to_json()
        out[f"qp_block_g{g}"] = M.qp_block_layout(p, g).to_json()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small,base")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry names to (re)lower")
    ns = ap.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    manifest = {"version": 1, "presets": {}, "artifacts": []}
    only = set(ns.only.split(",")) if ns.only else None

    t0 = time.time()
    for pname in ns.presets.split(","):
        p = PRESETS[pname]
        manifest["presets"][pname] = {
            "config": p.to_json_dict(),
            "layouts": layouts_json(p),
        }
        print(f"lowering preset {pname} ...", flush=True)
        lower_preset(p, ns.out_dir, manifest, only=only)

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written; total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    sys.exit(main())
