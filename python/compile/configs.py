"""Model / training presets shared by model.py, train.py, aot.py.

The same presets are mirrored on the Rust side (rust/src/config/presets.rs);
`aot.py` embeds each preset into artifacts/manifest.json so the Rust
coordinator never hardcodes shapes.

Design constraints:
  * `dim` and `inter` must be divisible by every group size we lower for the
    preset (Table 12 group-size sweep runs on `small`).
  * Heads divide dim; head_dim even (RoPE pairs).
  * Sizes are deliberately laptop-scale: the paper's quantization dynamics
    (group-wise ranges, the 2-bit cliff, Block-AP recovery) are architecture
    phenomena, not scale phenomena. See DESIGN.md §4.
"""

from dataclasses import dataclass, field, asdict
from typing import List


@dataclass(frozen=True)
class Preset:
    name: str
    dim: int
    n_layers: int
    n_heads: int
    inter: int
    vocab: int
    # static batch geometry for the lowered artifacts
    block_batch: int      # Block-AP reconstruction batch
    block_ctx: int        # Block-AP context length
    e2e_batch: int        # E2E-QP / pretrain batch
    e2e_ctx: int          # E2E-QP / pretrain context length
    eval_batch: int       # evaluation forward batch
    eval_ctx: int         # evaluation context length
    default_group: int    # default quantization group size
    group_sizes: List[int] = field(default_factory=list)  # lowered variants
    lora_rank: int = 8
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    def to_json_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


# NOTE: keep in sync with rust/src/config/presets.rs
PRESETS = {
    # Fast preset for unit/ablation experiments (T5, T6, T7, T13, fig3, fig4).
    "tiny": Preset(
        name="tiny", dim=128, n_layers=4, n_heads=4, inter=256, vocab=512,
        block_batch=8, block_ctx=64, e2e_batch=8, e2e_ctx=64,
        eval_batch=8, eval_ctx=64,
        default_group=32, group_sizes=[32, 64, 128],
    ),
    # Group-size sweep preset (T12) - dims divisible by 256.
    "small": Preset(
        name="small", dim=256, n_layers=6, n_heads=4, inter=768, vocab=2048,
        block_batch=8, block_ctx=64, e2e_batch=8, e2e_ctx=128,
        eval_batch=8, eval_ctx=128,
        default_group=64, group_sizes=[32, 64, 128, 256],
    ),
    # Headline preset for the end-to-end driver (~18.5M params).
    "base": Preset(
        name="base", dim=384, n_layers=8, n_heads=6, inter=1152, vocab=4096,
        block_batch=4, block_ctx=128, e2e_batch=4, e2e_ctx=256,
        eval_batch=4, eval_ctx=256,
        default_group=64, group_sizes=[64, 128],
    ),
}

# Linear layers inside one transformer block, in flat-layout order.
# (name, out_expr, in_expr) with d=dim, i=inter.
BLOCK_LINEARS = [
    ("attn.q", "d", "d"),
    ("attn.k", "d", "d"),
    ("attn.v", "d", "d"),
    ("attn.o", "d", "d"),
    ("mlp.gate", "i", "d"),
    ("mlp.up", "i", "d"),
    ("mlp.down", "d", "i"),
]


def linear_shapes(p: Preset):
    """[(name, (out, in))] for the 7 quantized linears of one block."""
    dims = {"d": p.dim, "i": p.inter}
    return [(n, (dims[o], dims[i])) for (n, o, i) in BLOCK_LINEARS]
