"""Pallas dequantize-then-matmul kernel (L1): y = x @ dequant(W_int,s,z)^T.

This is the E2E-QP / evaluation hot path: integer weights stay frozen, only
dequantization happens in the forward pass (paper §3.3), and the custom VJP
provides the analytic gradients for the quantization parameters
(d w_hat / d s = w_q - z).

TPU mapping (DESIGN.md §3): the GPU/BitBLAS version unpacks INT2 in registers
feeding tensor cores; here BlockSpec streams (TILE_N, K) weight tiles
HBM->VMEM, the VPU dequantizes, and the MXU consumes x @ W_tile^T. The x
operand is resident across grid steps (index_map pins it to block 0) so each
weight byte is touched exactly once - the schedule that makes low-bit
inference memory-bandwidth-, not compute-, bound.

Lowered with interpret=True on this CPU testbed; the packed-integer speedup
claim (paper Table 10) is reproduced natively in Rust (infer/qlinear.rs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True


def _col_tile(n: int, max_grid: int = 8) -> int:
    target = -(-n // max_grid)
    for t in range(target, n + 1):
        if n % t == 0:
            return t
    return n


def _dqmm_kernel(x_ref, w_ref, s_ref, z_ref, o_ref):
    x = x_ref[...]                        # (M, K) resident
    w = w_ref[...]                        # (TN, K) streamed tile
    s = s_ref[...]                        # (TN, G)
    z = z_ref[...]                        # (TN, G)
    tn, k = w.shape
    G = s.shape[1]
    g = k // G
    wg = (w.reshape(tn, G, g) - z[:, :, None]) * s[:, :, None]
    w_hat = wg.reshape(tn, k)
    o_ref[...] = jnp.dot(x, w_hat.T, preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp)
def dequant_matmul(x, w_int, s, z):
    """x: (M, K) f32; w_int: (N, K) f32 integer values; s, z: (N, G).

    Returns (M, N). Differentiable in x, s, z; w_int is treated as frozen
    (its cotangent is zero), matching E2E-QP.
    """
    return _dqmm_impl(x, w_int, s, z)


def _dqmm_impl(x, w_int, s, z):
    m, k = x.shape
    n = w_int.shape[0]
    G = s.shape[1]
    tn = _col_tile(n)
    return pl.pallas_call(
        _dqmm_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),     # x resident
            pl.BlockSpec((tn, k), lambda i: (i, 0)),    # W tile streamed
            pl.BlockSpec((tn, G), lambda i: (i, 0)),
            pl.BlockSpec((tn, G), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w_int, s, z)


def _dqmm_vjp_fwd(x, w_int, s, z):
    return _dqmm_impl(x, w_int, s, z), (x, w_int, s, z)


def _dqmm_vjp_bwd(res, gout):
    x, w_int, s, z = res
    gx, gs, gz = ref.dequant_matmul_grads_ref(x, w_int, s, z, gout)
    return gx, jnp.zeros_like(w_int), gs, gz


dequant_matmul.defvjp(_dqmm_vjp_fwd, _dqmm_vjp_bwd)
