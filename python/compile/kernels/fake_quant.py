"""Pallas fake-quantization kernel (L1) with a fused STE backward kernel.

Forward : W_hat = (clamp(round(W/s) + z, 0, qmax) - z) * s, group-wise.
Backward: paper Eqs. 3-5 (see ref.py docstring for the z-gradient fix),
          fused into ONE kernel emitting (gW, gs, gz) per row tile, with the
          group reduction done inside the tile.

TPU mapping (DESIGN.md §3): this is a pure VPU kernel. BlockSpec tiles rows
into (TILE_R, in) VMEM blocks; the per-row group params (TILE_R, G) ride in
the same grid step, so one HBM->VMEM stream per operand, no revisits.
On this testbed we lower with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the grid becomes a small HLO while-loop.

`qmax` is a runtime (1,1) f32 operand so a single compiled artifact serves
2/3/4-bit quantization (DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls cannot run; see DESIGN.md


def _row_tile(out_dim: int, max_grid: int = 8) -> int:
    """Smallest row-tile that divides out_dim with a grid of <= max_grid.

    Keeps the interpret-mode while-loop short on CPU while still exercising
    a real multi-step grid; on TPU the same tile bounds VMEM residency.
    """
    target = -(-out_dim // max_grid)  # ceil
    for t in range(target, out_dim + 1):
        if out_dim % t == 0:
            return t
    return out_dim


def _fq_fwd_kernel(w_ref, s_ref, z_ref, qmax_ref, o_ref):
    w = w_ref[...]                       # (TR, IN)
    s = s_ref[...]                       # (TR, G)
    z = z_ref[...]                       # (TR, G)
    qmax = qmax_ref[0, 0]
    tr, in_dim = w.shape
    G = s.shape[1]
    g = in_dim // G
    wg = w.reshape(tr, G, g)
    se = s[:, :, None]
    ze = z[:, :, None]
    q = jnp.clip(jnp.round(wg / se) + ze, 0.0, qmax)
    o_ref[...] = ((q - ze) * se).reshape(tr, in_dim)


def _fq_bwd_kernel(w_ref, s_ref, z_ref, qmax_ref, g_ref,
                   gw_ref, gs_ref, gz_ref):
    w = w_ref[...]
    s = s_ref[...]
    z = z_ref[...]
    qmax = qmax_ref[0, 0]
    gout = g_ref[...]
    tr, in_dim = w.shape
    G = s.shape[1]
    g = in_dim // G
    wg = w.reshape(tr, G, g)
    gg = gout.reshape(tr, G, g)
    se = s[:, :, None]
    ze = z[:, :, None]
    t = jnp.round(wg / se)
    qu = t + ze
    below = qu < 0.0
    above = qu > qmax
    in_range = jnp.logical_not(jnp.logical_or(below, above))

    gw = jnp.where(in_range, gg, 0.0)
    ds = jnp.where(in_range, t - wg / se, jnp.where(below, -ze, qmax - ze))
    gz_el = jnp.where(in_range, 0.0, -se) * gg

    gw_ref[...] = gw.reshape(tr, in_dim)
    gs_ref[...] = (gg * ds).sum(axis=2)
    gz_ref[...] = gz_el.sum(axis=2)


def _specs(out_dim, in_dim, G, tile_r):
    row_block = lambda i: (i, 0)
    return dict(
        w=pl.BlockSpec((tile_r, in_dim), row_block),
        q=pl.BlockSpec((tile_r, G), row_block),
        scalar=pl.BlockSpec((1, 1), lambda i: (0, 0)),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant(w, s, z, qmax):
    """Group-wise fake quantization via the Pallas kernel.

    w: (out, in) f32; s, z: (out, G) f32; qmax: (1,1) f32.
    Differentiable in (w, s, z) with STE semantics.
    """
    return _fake_quant_fwd_impl(w, s, z, qmax)


def _fake_quant_fwd_impl(w, s, z, qmax):
    out_dim, in_dim = w.shape
    G = s.shape[1]
    tile_r = _row_tile(out_dim)
    sp = _specs(out_dim, in_dim, G, tile_r)
    return pl.pallas_call(
        _fq_fwd_kernel,
        grid=(out_dim // tile_r,),
        in_specs=[sp["w"], sp["q"], sp["q"], sp["scalar"]],
        out_specs=sp["w"],
        out_shape=jax.ShapeDtypeStruct((out_dim, in_dim), w.dtype),
        interpret=INTERPRET,
    )(w, s, z, qmax)


def _fake_quant_vjp_fwd(w, s, z, qmax):
    return _fake_quant_fwd_impl(w, s, z, qmax), (w, s, z, qmax)


def _fake_quant_vjp_bwd(res, gout):
    w, s, z, qmax = res
    out_dim, in_dim = w.shape
    G = s.shape[1]
    tile_r = _row_tile(out_dim)
    sp = _specs(out_dim, in_dim, G, tile_r)
    gw, gs, gz = pl.pallas_call(
        _fq_bwd_kernel,
        grid=(out_dim // tile_r,),
        in_specs=[sp["w"], sp["q"], sp["q"], sp["scalar"], sp["w"]],
        out_specs=[sp["w"], sp["q"], sp["q"]],
        out_shape=[
            jax.ShapeDtypeStruct((out_dim, in_dim), w.dtype),
            jax.ShapeDtypeStruct((out_dim, G), s.dtype),
            jax.ShapeDtypeStruct((out_dim, G), z.dtype),
        ],
        interpret=INTERPRET,
    )(w, s, z, qmax, gout)
    return gw, gs, gz, jnp.zeros_like(res[3])


fake_quant.defvjp(_fake_quant_vjp_fwd, _fake_quant_vjp_bwd)


def quantize(w, s, z, qmax):
    """Eq. (1) as a (non-differentiable) kernel-free op for graph tails."""
    from . import ref
    return ref.quantize_ref(w, s, z, qmax)
