"""Pure-jnp oracle for the Pallas kernels.

Everything here is the *specification*: the Pallas kernels in fake_quant.py /
dequant_matmul.py must match these functions bit-for-bit (f32, CPU) and their
custom VJPs must match `jax.grad` of the STE formulation below (paper
Eqs. 3-5, corrected: d(w_hat)/dz = -s outside the clamp range, because
w_hat = (clamp(round(w/s)+z) - z) * s; the paper's Eq. 4 writes -1, folding
the s factor into its parameterization).

Group convention: weights are (out, in); quantization groups tile the `in`
axis; s, z have shape (out, in // g).
"""

import jax
import jax.numpy as jnp


def expand_groups(p, out_dim, in_dim):
    """(out, G) group params -> (out, in) elementwise broadcast."""
    g = in_dim // p.shape[1]
    return jnp.repeat(p, g, axis=1)


def quantize_ref(w, s, z, qmax):
    """Eq. (1): W_int = clamp(round(W/s) + z, 0, qmax). Returns f32 ints."""
    out_dim, in_dim = w.shape
    se = expand_groups(s, out_dim, in_dim)
    ze = expand_groups(z, out_dim, in_dim)
    return jnp.clip(jnp.round(w / se) + ze, 0.0, qmax)


def dequantize_ref(w_int, s, z):
    """Eq. (2): W_hat = (W_int - z) * s."""
    out_dim, in_dim = w_int.shape
    se = expand_groups(s, out_dim, in_dim)
    ze = expand_groups(z, out_dim, in_dim)
    return (w_int - ze) * se


def fake_quant_ref(w, s, z, qmax):
    """Quant->dequant with straight-through rounding (differentiable spec).

    The STE treats round() as identity for gradient purposes; clamping
    saturation IS differentiated (this yields exactly paper Eqs. 3-5).
    """
    out_dim, in_dim = w.shape
    se = expand_groups(s, out_dim, in_dim)
    ze = expand_groups(z, out_dim, in_dim)
    t = w / se
    r = t + jax.lax.stop_gradient(jnp.round(t) - t)  # STE round
    # Saturation masks on the *integer* pre-clamp value, with strict
    # inequalities: boundary hits (q == 0 or q == qmax) count as in-range.
    # This pins the clamp's tie-breaking so autodiff of this spec equals the
    # analytic Eqs. 3-5 exactly (jnp.clip's min/max tie convention differs).
    qu = jax.lax.stop_gradient(jnp.round(t) + ze)
    below = qu < 0.0
    above = qu > qmax
    q = jnp.where(below, 0.0, jnp.where(above, qmax, r + ze))
    return (q - ze) * se


def fake_quant_grads_ref(w, s, z, qmax, gout):
    """Analytic STE gradients (paper Eqs. 3-5, with correct -s factor on z).

    Returns (gw, gs, gz) with gs, gz reduced to (out, G).
    """
    out_dim, in_dim = w.shape
    G = s.shape[1]
    g = in_dim // G
    se = expand_groups(s, out_dim, in_dim)
    ze = expand_groups(z, out_dim, in_dim)
    t = jnp.round(w / se)
    q_unclamped = t + ze
    below = q_unclamped < 0.0
    above = q_unclamped > qmax
    in_range = jnp.logical_not(jnp.logical_or(below, above))

    gw = jnp.where(in_range, gout, 0.0)
    # d w_hat / d s (per element, before group reduction):
    ds = jnp.where(in_range, t - w / se, jnp.where(below, -ze, qmax - ze))
    gs_el = gout * ds
    # d w_hat / d z: 0 in range, -s when clamped (either side)
    gz_el = jnp.where(in_range, 0.0, -se) * gout

    gs = gs_el.reshape(out_dim, G, g).sum(axis=2)
    gz = gz_el.reshape(out_dim, G, g).sum(axis=2)
    return gw, gs, gz


def dequant_matmul_ref(x, w_int, s, z):
    """y = x @ dequantize(w_int, s, z)^T ; x: (M, K), w_int: (N, K)."""
    return x @ dequantize_ref(w_int, s, z).T


def dequant_matmul_grads_ref(x, w_int, s, z, gout):
    """Analytic grads of dequant_matmul wrt (x, s, z). w_int is frozen.

    gx  = gout @ W_hat            (M,N)@(N,K)
    gs[n,g] = sum_m gout[m,n] * sum_{k in g} x[m,k] * (w_int[n,k]-z[n,g])
    gz[n,g] = -s[n,g] * sum_m gout[m,n] * sum_{k in g} x[m,k]
    """
    N, K = w_int.shape
    G = s.shape[1]
    g = K // G
    w_hat = dequantize_ref(w_int, s, z)
    gx = gout @ w_hat

    # u[m,n,g] = sum_{k in group} x[m,k] * (w_int[n,k] - z[n,g])
    ze = expand_groups(z, N, K)
    wz = (w_int - ze).reshape(N, G, g)               # (N,G,g)
    xg = x.reshape(x.shape[0], G, g)                  # (M,G,g)
    u = jnp.einsum("mgk,ngk->mng", xg, wz)            # (M,N,G)
    gs = jnp.einsum("mn,mng->ng", gout, u)
    xsum = xg.sum(axis=2)                             # (M,G)
    gz = -s * jnp.einsum("mn,mg->ng", gout, xsum)
    return gx, gs, gz


def minmax_init_ref(w, group, qmax):
    """RTN min/max initialization of (s, z) for group size `group`.

    s = (max - min) / qmax ; z = clamp(round(-min/s), 0, qmax)
    min is clamped <= 0 and max >= 0 so that zero is representable.
    Degenerate all-constant groups get s clamped to a small epsilon.
    """
    out_dim, in_dim = w.shape
    G = in_dim // group
    wg = w.reshape(out_dim, G, group)
    wmax = jnp.maximum(wg.max(axis=2), 0.0)
    wmin = jnp.minimum(wg.min(axis=2), 0.0)
    s = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    z = jnp.clip(jnp.round(-wmin / s), 0.0, qmax)
    return s, z


def dynamic_fake_quant_ref(w, group, qmax):
    """Min/max fake quant with scales recomputed from w each call (the naive
    QAT baseline, LLM-QAT style): scales follow w but gradients flow through
    the STE rounding path only (scales are stop-gradiented, as in LLM-QAT).
    """
    out_dim, in_dim = w.shape
    G = in_dim // group
    wg = w.reshape(out_dim, G, group)
    wmax = jnp.maximum(wg.max(axis=2, keepdims=True), 0.0)
    wmin = jnp.minimum(wg.min(axis=2, keepdims=True), 0.0)
    s = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    s = jax.lax.stop_gradient(s)
    z = jax.lax.stop_gradient(jnp.clip(jnp.round(-wmin / s), 0.0, qmax))
    t = wg / s
    r = t + jax.lax.stop_gradient(jnp.round(t) - t)
    q = jnp.clip(r + z, 0.0, qmax)
    return ((q - z) * s).reshape(out_dim, in_dim)
