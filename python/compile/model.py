"""L2: Llama-style decoder-only transformer in JAX, calling the L1 kernels.

Everything operates on FLAT f32 parameter vectors (one buffer per logical
parameter group) so the Rust coordinator moves a handful of buffers per step
instead of dozens of tensors; layouts are static and exported in
artifacts/manifest.json (DESIGN.md §2).

Forward modes (all share `block_core`, differing only in the linear
application function):
  * fp        : y = x @ W^T                      (pretraining / teacher)
  * fake-quant: y = x @ fake_quant(W, s, z)^T    (Block-AP training)
  * dequant   : y = dequant_matmul(x, W_int,s,z) (E2E-QP / evaluation)
  * dynamic   : y = x @ dyn_fq(W)^T              (naive-QAT baseline)
  * lora      : dequant + x @ A^T @ B^T          (QLoRA baseline)
"""

import jax
import jax.numpy as jnp

from .configs import Preset, linear_shapes
from .kernels.fake_quant import fake_quant
from .kernels.dequant_matmul import dequant_matmul
from .kernels import ref

# ---------------------------------------------------------------------------
# Flat-buffer layouts
# ---------------------------------------------------------------------------


class Layout:
    """Ordered (name -> offset, shape) map over one flat f32 vector."""

    def __init__(self, entries):
        self.entries = []  # (name, offset, shape)
        off = 0
        for name, shape in entries:
            n = 1
            for d in shape:
                n *= d
            self.entries.append((name, off, tuple(shape)))
            off += n
        self.size = off
        self.by_name = {n: (o, s) for (n, o, s) in self.entries}

    def slice(self, flat, name):
        off, shape = self.by_name[name]
        n = 1
        for d in shape:
            n *= d
        return flat[off:off + n].reshape(shape)

    def unflatten(self, flat):
        return {n: self.slice(flat, n) for (n, _, _) in self.entries}

    def to_json(self):
        return [
            {"name": n, "offset": o, "shape": list(s)}
            for (n, o, s) in self.entries
        ]


def block_param_entries(p: Preset):
    """One transformer block's fp parameters, in flat order."""
    ents = [("attn_norm", (p.dim,))]
    lins = dict(linear_shapes(p))
    for name in ("attn.q", "attn.k", "attn.v", "attn.o"):
        ents.append((name, lins[name]))
    ents.append(("mlp_norm", (p.dim,)))
    for name in ("mlp.gate", "mlp.up", "mlp.down"):
        ents.append((name, lins[name]))
    return ents


LINEAR_NAMES = ["attn.q", "attn.k", "attn.v", "attn.o",
                "mlp.gate", "mlp.up", "mlp.down"]


def fp_layout(p: Preset) -> Layout:
    ents = [("embed", (p.vocab, p.dim))]
    for b in range(p.n_layers):
        for name, shape in block_param_entries(p):
            ents.append((f"blocks.{b}.{name}", shape))
    ents.append(("final_norm", (p.dim,)))
    ents.append(("head", (p.vocab, p.dim)))
    return Layout(ents)


def block_layout(p: Preset) -> Layout:
    return Layout(block_param_entries(p))


def wq_block_layout(p: Preset) -> Layout:
    """Integer weights of ONE block's 7 linears (values stored as f32)."""
    return Layout([(n, s) for n, s in linear_shapes(p)])


def wq_layout(p: Preset) -> Layout:
    ents = []
    for b in range(p.n_layers):
        for n, s in linear_shapes(p):
            ents.append((f"blocks.{b}.{n}", s))
    return Layout(ents)


def _qp_entries(p: Preset, group: int, prefix: str, blocks):
    ents = []
    for which in ("s", "z"):
        for b in blocks:
            for n, (out_d, in_d) in linear_shapes(p):
                nm = f"{which}.{prefix}{b}{'.' if prefix else ''}{n}" if prefix \
                    else f"{which}.{n}"
                ents.append((nm, (out_d, in_d // group)))
    return ents


def qp_block_layout(p: Preset, group: int) -> Layout:
    """[s_all || z_all] for one block (enables scalar-masked updates)."""
    ents = []
    for which in ("s", "z"):
        for n, (out_d, in_d) in linear_shapes(p):
            ents.append((f"{which}.{n}", (out_d, in_d // group)))
    return Layout(ents)


def qp_layout(p: Preset, group: int) -> Layout:
    """[s_all || z_all] over the whole model."""
    ents = []
    for which in ("s", "z"):
        for b in range(p.n_layers):
            for n, (out_d, in_d) in linear_shapes(p):
                ents.append((f"{which}.blocks.{b}.{n}", (out_d, in_d // group)))
    return Layout(ents)


def fpr_layout(p: Preset) -> Layout:
    """Parameters that stay fp in the quantized model."""
    ents = [("embed", (p.vocab, p.dim))]
    for b in range(p.n_layers):
        ents.append((f"blocks.{b}.attn_norm", (p.dim,)))
        ents.append((f"blocks.{b}.mlp_norm", (p.dim,)))
    ents.append(("final_norm", (p.dim,)))
    ents.append(("head", (p.vocab, p.dim)))
    return Layout(ents)


def lora_layout(p: Preset) -> Layout:
    r = p.lora_rank
    ents = []
    for b in range(p.n_layers):
        for n, (out_d, in_d) in linear_shapes(p):
            ents.append((f"blocks.{b}.{n}.A", (r, in_d)))
            ents.append((f"blocks.{b}.{n}.B", (out_d, r)))
    return Layout(ents)


# ---------------------------------------------------------------------------
# Core forward
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(p: Preset, t: int):
    hd = p.head_dim
    inv = 1.0 / (p.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    pos = jnp.arange(t, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]          # (T, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(q, cos, sin):
    """q: (B, H, T, hd); split-half convention (mirrored in rust infer)."""
    hd = q.shape[-1]
    q1, q2 = q[..., : hd // 2], q[..., hd // 2:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([q1 * c - q2 * s, q2 * c + q1 * s], axis=-1)


def block_core(x, norms, lin, p: Preset, capture=False):
    """One transformer block. `lin(name, x3d) -> y3d` applies a linear.

    Returns h_out, or (h_out, captures) with the four intra-block linear
    inputs when capture=True (GPTQ/AWQ calibration, DESIGN.md §2).
    """
    bsz, t, d = x.shape
    h = rms_norm(x, norms["attn_norm"], p.norm_eps)
    q = lin("attn.q", h)
    k = lin("attn.k", h)
    v = lin("attn.v", h)
    hd, nh = p.head_dim, p.n_heads
    q = q.reshape(bsz, t, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(bsz, t, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(bsz, t, nh, hd).transpose(0, 2, 1, 3)
    cos, sin = rope_tables(p, t)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz, t, d)
    attn_out = lin("attn.o", ctx)
    x = x + attn_out

    h2 = rms_norm(x, norms["mlp_norm"], p.norm_eps)
    gate = lin("mlp.gate", h2)
    up = lin("mlp.up", h2)
    mid = jax.nn.silu(gate) * up
    down = lin("mlp.down", mid)
    out = x + down
    if capture:
        return out, {"x_attn": h, "attn_ctx": ctx, "x_mlp": h2, "mlp_mid": mid}
    return out


def make_lin_fp(weights):
    """weights: dict name -> (out, in) array."""
    def lin(name, x):
        w = weights[name]
        shp = x.shape[:-1] + (w.shape[0],)
        return (x.reshape(-1, w.shape[1]) @ w.T).reshape(shp)
    return lin


def make_lin_fake_quant(weights, s, z, qmax):
    """fake_quant Pallas kernel on the weight, then matmul (Block-AP)."""
    def lin(name, x):
        w = fake_quant(weights[name], s[name], z[name], qmax)
        shp = x.shape[:-1] + (w.shape[0],)
        return (x.reshape(-1, w.shape[1]) @ w.T).reshape(shp)
    return lin


def make_lin_dequant(w_int, s, z):
    """dequant_matmul Pallas kernel (E2E-QP / eval)."""
    def lin(name, x):
        wi = w_int[name]
        shp = x.shape[:-1] + (wi.shape[0],)
        y = dequant_matmul(x.reshape(-1, wi.shape[1]), wi, s[name], z[name])
        return y.reshape(shp)
    return lin


def make_lin_dynamic(weights, group, qmax):
    """Min/max-recomputed fake quant (naive QAT baseline, LLM-QAT style)."""
    def lin(name, x):
        w = ref.dynamic_fake_quant_ref(weights[name], group, qmax)
        shp = x.shape[:-1] + (w.shape[0],)
        return (x.reshape(-1, w.shape[1]) @ w.T).reshape(shp)
    return lin


def make_lin_lora(w_int, s, z, lora, scale):
    """Frozen dequant path + trainable low-rank update (QLoRA baseline)."""
    base = make_lin_dequant(w_int, s, z)

    def lin(name, x):
        y = base(name, x)
        a = lora[name + ".A"]
        b = lora[name + ".B"]
        x2 = x.reshape(-1, a.shape[1])
        delta = (x2 @ a.T) @ b.T * scale
        return y + delta.reshape(y.shape)
    return lin


# ---------------------------------------------------------------------------
# Whole-model forwards over flat buffers
# ---------------------------------------------------------------------------


def _block_weight_dicts(params, b):
    names = LINEAR_NAMES
    w = {n: params[f"blocks.{b}.{n}"] for n in names}
    norms = {
        "attn_norm": params[f"blocks.{b}.attn_norm"],
        "mlp_norm": params[f"blocks.{b}.mlp_norm"],
    }
    return w, norms


def model_fwd_fp(flat, x_ids, p: Preset, layout: Layout):
    params = layout.unflatten(flat)
    h = params["embed"][x_ids]
    for b in range(p.n_layers):
        w, norms = _block_weight_dicts(params, b)
        h = block_core(h, norms, make_lin_fp(w), p)
    h = rms_norm(h, params["final_norm"], p.norm_eps)
    return h @ params["head"].T


def model_fwd_quant(wq_flat, qp_flat, fpr_flat, x_ids, p: Preset,
                    wql: Layout, qpl: Layout, fprl: Layout):
    """Dequant-only forward over a quantized model (eval / E2E-QP)."""
    wq = wql.unflatten(wq_flat)
    qp = qpl.unflatten(qp_flat)
    fpr = fprl.unflatten(fpr_flat)
    h = fpr["embed"][x_ids]
    for b in range(p.n_layers):
        w_int = {n: wq[f"blocks.{b}.{n}"] for n in LINEAR_NAMES}
        s = {n: qp[f"s.blocks.{b}.{n}"] for n in LINEAR_NAMES}
        z = {n: qp[f"z.blocks.{b}.{n}"] for n in LINEAR_NAMES}
        norms = {
            "attn_norm": fpr[f"blocks.{b}.attn_norm"],
            "mlp_norm": fpr[f"blocks.{b}.mlp_norm"],
        }
        h = block_core(h, norms, make_lin_dequant(w_int, s, z), p)
    h = rms_norm(h, fpr["final_norm"], p.norm_eps)
    return h @ fpr["head"].T


def model_fwd_dynamic(flat, x_ids, p: Preset, layout: Layout, group, qmax):
    """Naive-QAT forward: every linear weight dynamically fake-quantized."""
    params = layout.unflatten(flat)
    h = params["embed"][x_ids]
    for b in range(p.n_layers):
        w, norms = _block_weight_dicts(params, b)
        h = block_core(h, norms, make_lin_dynamic(w, group, qmax), p)
    h = rms_norm(h, params["final_norm"], p.norm_eps)
    return h @ params["head"].T


def model_fwd_lora(wq_flat, qp_flat, fpr_flat, lora_flat, x_ids, p: Preset,
                   wql, qpl, fprl, loral, scale=1.0):
    wq = wql.unflatten(wq_flat)
    qp = qpl.unflatten(qp_flat)
    fpr = fprl.unflatten(fpr_flat)
    lora = loral.unflatten(lora_flat)
    h = fpr["embed"][x_ids]
    for b in range(p.n_layers):
        w_int = {n: wq[f"blocks.{b}.{n}"] for n in LINEAR_NAMES}
        s = {n: qp[f"s.blocks.{b}.{n}"] for n in LINEAR_NAMES}
        z = {n: qp[f"z.blocks.{b}.{n}"] for n in LINEAR_NAMES}
        lora_b = {}
        for n in LINEAR_NAMES:
            lora_b[n + ".A"] = lora[f"blocks.{b}.{n}.A"]
            lora_b[n + ".B"] = lora[f"blocks.{b}.{n}.B"]
        norms = {
            "attn_norm": fpr[f"blocks.{b}.attn_norm"],
            "mlp_norm": fpr[f"blocks.{b}.mlp_norm"],
        }
        h = block_core(h, norms, make_lin_lora(w_int, s, z, lora_b, scale), p)
    h = rms_norm(h, fpr["final_norm"], p.norm_eps)
    return h @ fpr["head"].T


# ---------------------------------------------------------------------------
# Single-block forwards over flat buffers
# ---------------------------------------------------------------------------


def _split_block(bl: Layout, flat):
    params = bl.unflatten(flat)
    w = {n: params[n] for n in LINEAR_NAMES}
    norms = {"attn_norm": params["attn_norm"], "mlp_norm": params["mlp_norm"]}
    return w, norms


def block_fwd_fp(bp_flat, h, p: Preset, bl: Layout, capture=False):
    w, norms = _split_block(bl, bp_flat)
    return block_core(h, norms, make_lin_fp(w), p, capture=capture)


def block_fwd_fake_quant(bp_flat, qp_flat, h, qmax, p: Preset,
                         bl: Layout, qbl: Layout):
    w, norms = _split_block(bl, bp_flat)
    qp = qbl.unflatten(qp_flat)
    s = {n: qp[f"s.{n}"] for n in LINEAR_NAMES}
    z = {n: qp[f"z.{n}"] for n in LINEAR_NAMES}
    return block_core(h, norms, make_lin_fake_quant(w, s, z, qmax), p)


def block_fwd_dequant(wq_flat, qp_flat, norms_flat, h, p: Preset,
                      wqbl: Layout, qbl: Layout):
    """Quantized-block forward (propagation through finished blocks)."""
    wq = wqbl.unflatten(wq_flat)
    qp = qbl.unflatten(qp_flat)
    s = {n: qp[f"s.{n}"] for n in LINEAR_NAMES}
    z = {n: qp[f"z.{n}"] for n in LINEAR_NAMES}
    norms = {"attn_norm": norms_flat[:p.dim], "mlp_norm": norms_flat[p.dim:]}
    return block_core(h, norms, make_lin_dequant(wq, s, z), p)


# ---------------------------------------------------------------------------
# Losses / optimizer
# ---------------------------------------------------------------------------


def cross_entropy(logits, y_ids):
    """Mean token cross-entropy; logits (B,T,V), y (B,T) int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y_ids[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def masked_cross_entropy(logits, y_ids, mask):
    """CE over positions where mask == 1 (instruction tuning targets)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y_ids[..., None], axis=-1)[..., 0]
    per = (logz - gold) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def adam_update(param, grad, m, v, step, lr,
                b1=0.9, b2=0.999, eps=1e-8):
    """Adam on flat vectors; `step` is a 1-based f32 scalar.

    Mirrored bit-for-bit by rust tests (coordinator/opt.rs golden test).
    """
    m = b1 * m + (1.0 - b1) * grad
    v = b2 * v + (1.0 - b2) * grad * grad
    mhat = m / (1.0 - b1 ** step)
    vhat = v / (1.0 - b2 ** step)
    return param - lr * mhat / (jnp.sqrt(vhat) + eps), m, v
