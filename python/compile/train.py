"""L2 training-step graph builders: one fused HLO per phase.

Each builder returns (fn, ordered example args) for aot.py to lower. All
hyper-knobs that do not change tensor shapes are RUNTIME SCALARS so a single
compiled artifact serves every bit-width (qmax) and every Table-6/7 ablation
(gradient masks m_w/m_s/m_z + rounding-projection flag) - DESIGN.md §2.

Scalar convention: scalars are f32[] positional args appearing AFTER the
array args; (1,1)-shaped qmax feeds the Pallas kernels directly.
"""

import jax
import jax.numpy as jnp

from . import model as M
from .configs import Preset


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _qp_halves_mask(qpl_size, mask_s, mask_z):
    half = qpl_size // 2
    return jnp.concatenate([
        jnp.full((half,), 1.0) * mask_s,
        jnp.full((qpl_size - half,), 1.0) * mask_z,
    ])


# ---------------------------------------------------------------------------
# Full-precision pretraining (substrate: creates the model we quantize)
# ---------------------------------------------------------------------------


def build_pretrain_step(p: Preset):
    fl = M.fp_layout(p)
    bsz, t = p.e2e_batch, p.e2e_ctx

    def step_fn(params, m, v, x, y, step, lr):
        def loss_fn(f):
            return M.cross_entropy(M.model_fwd_fp(f, x, p, fl), y)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, m, v = M.adam_update(params, g, m, v, step, lr)
        return params, m, v, loss

    args = [
        ("params", _sds((fl.size,))), ("m", _sds((fl.size,))),
        ("v", _sds((fl.size,))),
        ("x", _sds((bsz, t), jnp.int32)), ("y", _sds((bsz, t), jnp.int32)),
        ("step", _sds(())), ("lr", _sds(())),
    ]
    outs = ["params", "m", "v", "loss"]
    return step_fn, args, outs


def build_model_fwd_fp(p: Preset):
    fl = M.fp_layout(p)
    bsz, t = p.eval_batch, p.eval_ctx

    def fn(params, x):
        return (M.model_fwd_fp(params, x, p, fl),)

    args = [("params", _sds((fl.size,))), ("x", _sds((bsz, t), jnp.int32))]
    return fn, args, ["logits"]


def build_embed_fwd(p: Preset):
    fl = M.fp_layout(p)
    bsz, t = p.block_batch, p.block_ctx

    def fn(params, x):
        emb = fl.slice(params, "embed")
        return (emb[x],)

    args = [("params", _sds((fl.size,))), ("x", _sds((bsz, t), jnp.int32))]
    return fn, args, ["h0"]


# ---------------------------------------------------------------------------
# Block-level forwards (teacher capture / propagation)
# ---------------------------------------------------------------------------


def build_block_fwd_fp(p: Preset):
    bl = M.block_layout(p)
    bsz, t = p.block_batch, p.block_ctx

    def fn(bp, h):
        return (M.block_fwd_fp(bp, h, p, bl),)

    args = [("bp", _sds((bl.size,))), ("h", _sds((bsz, t, p.dim)))]
    return fn, args, ["h_out"]


def build_block_capture_fp(p: Preset):
    bl = M.block_layout(p)
    bsz, t = p.block_batch, p.block_ctx

    def fn(bp, h):
        out, cap = M.block_fwd_fp(bp, h, p, bl, capture=True)
        return (out, cap["x_attn"], cap["attn_ctx"], cap["x_mlp"],
                cap["mlp_mid"])

    args = [("bp", _sds((bl.size,))), ("h", _sds((bsz, t, p.dim)))]
    return fn, args, ["h_out", "x_attn", "attn_ctx", "x_mlp", "mlp_mid"]


def build_block_fwd_q(p: Preset, group: int):
    wqbl = M.wq_block_layout(p)
    qbl = M.qp_block_layout(p, group)
    bsz, t = p.block_batch, p.block_ctx

    def fn(wq, qp, norms, h):
        return (M.block_fwd_dequant(wq, qp, norms, h, p, wqbl, qbl),)

    args = [
        ("wq", _sds((wqbl.size,))), ("qp", _sds((qbl.size,))),
        ("norms", _sds((2 * p.dim,))), ("h", _sds((bsz, t, p.dim))),
    ]
    return fn, args, ["h_out"]


# ---------------------------------------------------------------------------
# Block-AP: the paper's phase-1 train step
# ---------------------------------------------------------------------------


def build_block_ap_step(p: Preset, group: int):
    """Masked, projected Block-AP step (paper §3.2 + Table 6 ablations).

    Trainables: the whole block fp vector `bp` (7 linears + 2 norms, Adam
    with lr_w, gated by m_w) and qp = [s||z] (Adam with lr_q, gated by
    m_s/m_z). `proj` = 1 clips updated weights to [w_lo, w_hi] - the
    AutoRound-style (-0.5, +0.5)*s rounding-window regularizer, computed
    host-side by the Rust coordinator.
    """
    bl = M.block_layout(p)
    qbl = M.qp_block_layout(p, group)
    bsz, t = p.block_batch, p.block_ctx

    def step_fn(bp, qp, m_w, v_w, m_q, v_q, w_lo, w_hi, h, target,
                qmax, step, lr_w, lr_q, m_wf, m_sf, m_zf, proj):
        def loss_fn(bp_, qp_):
            out = M.block_fwd_fake_quant(bp_, qp_, h, qmax, p, bl, qbl)
            d = out - target
            return jnp.mean(d * d)

        loss, (g_w, g_q) = jax.value_and_grad(loss_fn, argnums=(0, 1))(bp, qp)
        g_w = g_w * m_wf
        g_q = g_q * _qp_halves_mask(qbl.size, m_sf, m_zf)
        bp2, m_w, v_w = M.adam_update(bp, g_w, m_w, v_w, step, lr_w)
        qp2, m_q, v_q = M.adam_update(qp, g_q, m_q, v_q, step, lr_q)
        bp2 = proj * jnp.clip(bp2, w_lo, w_hi) + (1.0 - proj) * bp2
        # keep zero points on the integer grid drift-free? No: z trains
        # continuously during Block-AP (rounded once at final quantization).
        return bp2, qp2, m_w, v_w, m_q, v_q, loss

    n, q = bl.size, qbl.size
    args = [
        ("bp", _sds((n,))), ("qp", _sds((q,))),
        ("m_w", _sds((n,))), ("v_w", _sds((n,))),
        ("m_q", _sds((q,))), ("v_q", _sds((q,))),
        ("w_lo", _sds((n,))), ("w_hi", _sds((n,))),
        ("h", _sds((bsz, t, p.dim))), ("target", _sds((bsz, t, p.dim))),
        ("qmax", _sds((1, 1))),
        ("step", _sds(())), ("lr_w", _sds(())), ("lr_q", _sds(())),
        ("m_wf", _sds(())), ("m_sf", _sds(())), ("m_zf", _sds(())),
        ("proj", _sds(())),
    ]
    outs = ["bp", "qp", "m_w", "v_w", "m_q", "v_q", "loss"]
    return step_fn, args, outs


def build_block_loss(p: Preset, group: int):
    """Reconstruction loss only (validation batches, fig3 overfitting gap)."""
    bl = M.block_layout(p)
    qbl = M.qp_block_layout(p, group)
    bsz, t = p.block_batch, p.block_ctx

    def fn(bp, qp, h, target, qmax):
        out = M.block_fwd_fake_quant(bp, qp, h, qmax, p, bl, qbl)
        d = out - target
        return (jnp.mean(d * d),)

    args = [
        ("bp", _sds((bl.size,))), ("qp", _sds((qbl.size,))),
        ("h", _sds((bsz, t, p.dim))), ("target", _sds((bsz, t, p.dim))),
        ("qmax", _sds((1, 1))),
    ]
    return fn, args, ["loss"]


# ---------------------------------------------------------------------------
# E2E-QP: the paper's phase-2 train step
# ---------------------------------------------------------------------------


def build_e2e_qp_step(p: Preset, group: int):
    """Frozen W_int; trains qp = [s||z] with masks (Table 7).

    `loss_mask` (f32 B,T) selects supervised positions: all-ones for
    continual pretraining, response-span-only for instruction tuning -
    one artifact serves both (paper §3.3 'simply changing datasets').
    """
    wql = M.wq_layout(p)
    qpl = M.qp_layout(p, group)
    fprl = M.fpr_layout(p)
    bsz, t = p.e2e_batch, p.e2e_ctx

    def step_fn(wq, qp, fpr, m_q, v_q, x, y, loss_mask, step, lr,
                m_sf, m_zf):
        def loss_fn(qp_):
            logits = M.model_fwd_quant(wq, qp_, fpr, x, p, wql, qpl, fprl)
            return M.masked_cross_entropy(logits, y, loss_mask)

        loss, g = jax.value_and_grad(loss_fn)(qp)
        g = g * _qp_halves_mask(qpl.size, m_sf, m_zf)
        qp2, m_q, v_q = M.adam_update(qp, g, m_q, v_q, step, lr)
        return qp2, m_q, v_q, loss

    args = [
        ("wq", _sds((wql.size,))), ("qp", _sds((qpl.size,))),
        ("fpr", _sds((fprl.size,))),
        ("m_q", _sds((qpl.size,))), ("v_q", _sds((qpl.size,))),
        ("x", _sds((bsz, t), jnp.int32)), ("y", _sds((bsz, t), jnp.int32)),
        ("loss_mask", _sds((bsz, t))),
        ("step", _sds(())), ("lr", _sds(())),
        ("m_sf", _sds(())), ("m_zf", _sds(())),
    ]
    outs = ["qp", "m_q", "v_q", "loss"]
    return step_fn, args, outs


def build_model_fwd_q(p: Preset, group: int):
    wql = M.wq_layout(p)
    qpl = M.qp_layout(p, group)
    fprl = M.fpr_layout(p)
    bsz, t = p.eval_batch, p.eval_ctx

    def fn(wq, qp, fpr, x):
        return (M.model_fwd_quant(wq, qp, fpr, x, p, wql, qpl, fprl),)

    args = [
        ("wq", _sds((wql.size,))), ("qp", _sds((qpl.size,))),
        ("fpr", _sds((fprl.size,))), ("x", _sds((bsz, t), jnp.int32)),
    ]
    return fn, args, ["logits"]


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def build_e2e_full_step(p: Preset, group: int):
    """Naive end-to-end QAT (LLM-QAT style): every weight trainable, scales
    recomputed from min/max each step. The Table 2/9 comparator."""
    fl = M.fp_layout(p)
    bsz, t = p.e2e_batch, p.e2e_ctx

    def step_fn(params, m, v, x, y, step, lr, qmax):
        def loss_fn(f):
            logits = M.model_fwd_dynamic(f, x, p, fl, group, qmax)
            return M.cross_entropy(logits, y)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, m, v = M.adam_update(params, g, m, v, step, lr)
        return params, m, v, loss

    args = [
        ("params", _sds((fl.size,))), ("m", _sds((fl.size,))),
        ("v", _sds((fl.size,))),
        ("x", _sds((bsz, t), jnp.int32)), ("y", _sds((bsz, t), jnp.int32)),
        ("step", _sds(())), ("lr", _sds(())), ("qmax", _sds(())),
    ]
    return step_fn, args, ["params", "m", "v", "loss"]


def build_e2e_lora_step(p: Preset, group: int):
    """QLoRA-style baseline: frozen quantized base, trainable LoRA."""
    wql = M.wq_layout(p)
    qpl = M.qp_layout(p, group)
    fprl = M.fpr_layout(p)
    ll = M.lora_layout(p)
    bsz, t = p.e2e_batch, p.e2e_ctx

    def step_fn(wq, qp, fpr, lora, m, v, x, y, loss_mask, step, lr):
        def loss_fn(lo):
            logits = M.model_fwd_lora(wq, qp, fpr, lo, x, p,
                                      wql, qpl, fprl, ll)
            return M.masked_cross_entropy(logits, y, loss_mask)
        loss, g = jax.value_and_grad(loss_fn)(lora)
        lora2, m, v = M.adam_update(lora, g, m, v, step, lr)
        return lora2, m, v, loss

    args = [
        ("wq", _sds((wql.size,))), ("qp", _sds((qpl.size,))),
        ("fpr", _sds((fprl.size,))), ("lora", _sds((ll.size,))),
        ("m", _sds((ll.size,))), ("v", _sds((ll.size,))),
        ("x", _sds((bsz, t), jnp.int32)), ("y", _sds((bsz, t), jnp.int32)),
        ("loss_mask", _sds((bsz, t))),
        ("step", _sds(())), ("lr", _sds(())),
    ]
    return step_fn, args, ["lora", "m", "v", "loss"]


def build_model_fwd_lora(p: Preset, group: int):
    wql = M.wq_layout(p)
    qpl = M.qp_layout(p, group)
    fprl = M.fpr_layout(p)
    ll = M.lora_layout(p)
    bsz, t = p.eval_batch, p.eval_ctx

    def fn(wq, qp, fpr, lora, x):
        return (M.model_fwd_lora(wq, qp, fpr, lora, x, p,
                                 wql, qpl, fprl, ll),)

    args = [
        ("wq", _sds((wql.size,))), ("qp", _sds((qpl.size,))),
        ("fpr", _sds((fprl.size,))), ("lora", _sds((ll.size,))),
        ("x", _sds((bsz, t), jnp.int32)),
    ]
    return fn, args, ["logits"]


# ---------------------------------------------------------------------------
# Registry used by aot.py
# ---------------------------------------------------------------------------

# entries lowered once per preset (group-independent)
BASE_ENTRIES = {
    "pretrain_step": build_pretrain_step,
    "model_fwd_fp": build_model_fwd_fp,
    "embed_fwd": build_embed_fwd,
    "block_fwd_fp": build_block_fwd_fp,
    "block_capture_fp": build_block_capture_fp,
}

# entries lowered per (preset, group size)
GROUP_ENTRIES = {
    "block_ap_step": build_block_ap_step,
    "block_loss": build_block_loss,
    "block_fwd_q": build_block_fwd_q,
    "e2e_qp_step": build_e2e_qp_step,
    "model_fwd_q": build_model_fwd_q,
    "e2e_full_step": build_e2e_full_step,
    "e2e_lora_step": build_e2e_lora_step,
    "model_fwd_lora": build_model_fwd_lora,
}

# heavier baselines: only lowered at the DEFAULT group size of each preset
DEFAULT_GROUP_ONLY = {"e2e_full_step", "e2e_lora_step", "model_fwd_lora"}
