"""AOT pipeline invariants: manifest consistency with the live layouts, and
the large-constant regression (elided `{...}` constants parse as ZEROS in
xla_extension 0.5.1 - the RoPE table bug; see aot.to_hlo_text)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M, train
from compile.configs import PRESETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_never_elides_constants():
    def f(x):
        c = jnp.asarray(np.arange(64, dtype=np.float32))
        return (x * c + jnp.cos(c),)

    text = aot.to_hlo_text(
        f, [("x", jax.ShapeDtypeStruct((64,), jnp.float32))])
    assert "constant({...})" not in text
    # the arange constant must appear with real digits
    assert any("constant({0, 1, 2" in l for l in text.splitlines())


def test_rope_tables_survive_lowering():
    """The exact regression: lowered rope must contain a non-trivial
    exponent constant (the arange(0,hd,2)/hd table)."""
    p = PRESETS["tiny"]

    def f(q):
        cos, sin = M.rope_tables(p, 8)
        return (M.apply_rope(q, cos, sin),)

    text = aot.to_hlo_text(
        f, [("q", jax.ShapeDtypeStruct((1, p.n_heads, 8, p.head_dim),
                                       jnp.float32))])
    assert "constant({...})" not in text
    assert "0.0625" in text  # 2/32: second entry of the exponent table


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_live_layouts():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for pname, pinfo in man["presets"].items():
        p = PRESETS[pname]
        live = {
            "fp": M.fp_layout(p),
            "block": M.block_layout(p),
            "wq": M.wq_layout(p),
            "fpr": M.fpr_layout(p),
            "lora": M.lora_layout(p),
        }
        for g in p.group_sizes:
            live[f"qp_g{g}"] = M.qp_layout(p, g)
            live[f"qp_block_g{g}"] = M.qp_block_layout(p, g)
        for lname, lay in live.items():
            ents = pinfo["layouts"][lname]
            assert len(ents) == len(lay.entries), f"{pname}/{lname}"
            for e, (name, off, shape) in zip(ents, lay.entries):
                assert e["name"] == name
                assert e["offset"] == off
                assert tuple(e["shape"]) == shape


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_artifact_args_match_builders():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    by_key = {(a["preset"], a["entry"]): a for a in man["artifacts"]}
    p = PRESETS["tiny"]
    for entry, builder in train.BASE_ENTRIES.items():
        _, args, outs = builder(p)
        spec = by_key[("tiny", entry)]
        assert [a["name"] for a in spec["args"]] == [n for n, _ in args]
        assert spec["outputs"] == outs
    g = p.default_group
    for entry, builder in train.GROUP_ENTRIES.items():
        _, args, outs = builder(p, g)
        spec = by_key[("tiny", f"{entry}_g{g}")]
        assert [a["name"] for a in spec["args"]] == [n for n, _ in args]
        for a, (_, sds) in zip(spec["args"], args):
            assert tuple(a["shape"]) == tuple(sds.shape)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_artifact_files_exist_and_have_no_elisions():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    checked = 0
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        if a["preset"] == "tiny":
            with open(path) as fh:
                assert "constant({...})" not in fh.read(), a["file"]
            checked += 1
    assert checked > 10
