"""Kernel vs ref-oracle correctness - the CORE L1 correctness signal.

Hypothesis sweeps shapes / bits / group sizes; asserts the Pallas kernels
(interpret mode) match the pure-jnp oracle, and that the fused STE backward
kernel matches BOTH the analytic gradients (paper Eqs. 3-5) and jax.grad of
the oracle's differentiable formulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant
from compile.kernels.dequant_matmul import dequant_matmul

jax.config.update("jax_enable_x64", False)


@st.composite
def qshapes(draw):
    """(out, in, group) with group | in."""
    g = draw(st.sampled_from([8, 16, 32, 64]))
    n_groups = draw(st.integers(1, 4))
    in_dim = g * n_groups
    out_dim = draw(st.sampled_from([1, 3, 8, 24, 64]))
    return out_dim, in_dim, g


def make_wsz(seed, out_dim, in_dim, g, bits):
    rng = np.random.default_rng(seed)
    qmax = float(2 ** bits - 1)
    w = rng.normal(0, 1.0, (out_dim, in_dim)).astype(np.float32)
    s, z = ref.minmax_init_ref(jnp.asarray(w), g, qmax)
    # perturb s, z away from the exact minmax init so clamping branches fire
    s = s * (1.0 + 0.3 * rng.normal(0, 1, s.shape).astype(np.float32) ** 2)
    z = jnp.clip(jnp.round(z + rng.integers(-1, 2, z.shape)), 0, qmax)
    return jnp.asarray(w), s.astype(jnp.float32), z.astype(jnp.float32), qmax


@settings(max_examples=6, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 2 ** 16))
def test_fake_quant_fwd_matches_ref(shape, bits, seed):
    out_dim, in_dim, g = shape
    w, s, z, qmax = make_wsz(seed, out_dim, in_dim, g, bits)
    qm = jnp.full((1, 1), qmax, jnp.float32)
    got = fake_quant(w, s, z, qm)
    want = ref.fake_quant_ref(w, s, z, qmax)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=8, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 2 ** 16))
def test_fake_quant_bwd_matches_analytic(shape, bits, seed):
    out_dim, in_dim, g = shape
    w, s, z, qmax = make_wsz(seed, out_dim, in_dim, g, bits)
    qm = jnp.full((1, 1), qmax, jnp.float32)
    rng = np.random.default_rng(seed + 1)
    gout = jnp.asarray(rng.normal(0, 1, (out_dim, in_dim)).astype(np.float32))

    def loss(w_, s_, z_):
        return jnp.vdot(fake_quant(w_, s_, z_, qm), gout)

    gw, gs, gz = jax.grad(loss, argnums=(0, 1, 2))(w, s, z)
    egw, egs, egz = ref.fake_quant_grads_ref(w, s, z, qmax, gout)
    np.testing.assert_allclose(gw, egw, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gs, egs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gz, egz, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 4]),
       seed=st.integers(0, 2 ** 16))
def test_fake_quant_bwd_matches_jax_grad_of_ref(shape, bits, seed):
    """The kernel VJP == autodiff of the STE spec (independent derivation)."""
    out_dim, in_dim, g = shape
    w, s, z, qmax = make_wsz(seed, out_dim, in_dim, g, bits)
    qm = jnp.full((1, 1), qmax, jnp.float32)
    rng = np.random.default_rng(seed + 2)
    gout = jnp.asarray(rng.normal(0, 1, (out_dim, in_dim)).astype(np.float32))

    def loss_kernel(w_, s_, z_):
        return jnp.vdot(fake_quant(w_, s_, z_, qm), gout)

    def loss_ref(w_, s_, z_):
        return jnp.vdot(ref.fake_quant_ref(w_, s_, z_, qmax), gout)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(w, s, z)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(w, s, z)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 3, 4]),
       m=st.sampled_from([1, 2, 7, 16]), seed=st.integers(0, 2 ** 16))
def test_dequant_matmul_fwd_matches_ref(shape, bits, m, seed):
    n, k, g = shape
    w, s, z, qmax = make_wsz(seed, n, k, g, bits)
    w_int = ref.quantize_ref(w, s, z, qmax)
    rng = np.random.default_rng(seed + 3)
    x = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    got = dequant_matmul(x, w_int, s, z)
    want = ref.dequant_matmul_ref(x, w_int, s, z)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 4]),
       m=st.sampled_from([1, 5, 8]), seed=st.integers(0, 2 ** 16))
def test_dequant_matmul_bwd_matches_analytic(shape, bits, m, seed):
    n, k, g = shape
    w, s, z, qmax = make_wsz(seed, n, k, g, bits)
    w_int = ref.quantize_ref(w, s, z, qmax)
    rng = np.random.default_rng(seed + 4)
    x = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    gout = jnp.asarray(rng.normal(0, 1, (m, n)).astype(np.float32))

    def loss(x_, s_, z_):
        return jnp.vdot(dequant_matmul(x_, w_int, s_, z_), gout)

    gx, gs, gz = jax.grad(loss, argnums=(0, 1, 2))(x, s, z)
    egx, egs, egz = ref.dequant_matmul_grads_ref(x, w_int, s, z, gout)
    np.testing.assert_allclose(gx, egx, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gs, egs, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gz, egz, rtol=1e-4, atol=1e-3)


def test_dequant_matmul_grad_s_is_wq_minus_z_times_x():
    """Paper §3.3: with a single output row and unit upstream gradient,
    d y / d s reduces to sum_k x_k (w_q - z) - spot-check the formula."""
    w_int = jnp.asarray([[0., 1., 2., 3., 1., 1., 2., 2.]])
    s = jnp.asarray([[0.5, 0.25]])
    z = jnp.asarray([[1.0, 2.0]])
    x = jnp.asarray([[1., 2., 3., 4., 5., 6., 7., 8.]])

    def y(s_):
        return dequant_matmul(x, w_int, s_, z)[0, 0]

    gs = jax.grad(y)(s)
    want0 = ((w_int[0, :4] - 1.0) * x[0, :4]).sum()
    want1 = ((w_int[0, 4:] - 2.0) * x[0, 4:]).sum()
    np.testing.assert_allclose(gs, jnp.asarray([[want0, want1]]), rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(shape=qshapes(), bits=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 2 ** 16))
def test_rtn_error_bound(shape, bits, seed):
    """RTN dequant error <= s/2 + eps elementwise at min/max init."""
    out_dim, in_dim, g = shape
    qmax = float(2 ** bits - 1)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (out_dim, in_dim)).astype(np.float32))
    s, z = ref.minmax_init_ref(w, g, qmax)
    w_hat = ref.fake_quant_ref(w, s, z, qmax)
    se = ref.expand_groups(s, out_dim, in_dim)
    err = jnp.abs(w_hat - w)
    assert bool(jnp.all(err <= se * 0.5 + 1e-5))


def test_quantize_values_are_integers_in_range():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 1, (16, 64)).astype(np.float32))
    for bits in (2, 3, 4):
        qmax = float(2 ** bits - 1)
        s, z = ref.minmax_init_ref(w, 16, qmax)
        wi = ref.quantize_ref(w, s, z, qmax)
        assert bool(jnp.all(wi == jnp.round(wi)))
        assert bool(jnp.all((wi >= 0) & (wi <= qmax)))


def test_dynamic_fake_quant_matches_static_at_minmax_init():
    """Naive-QAT dynamic quant == fake_quant with freshly-computed s,z."""
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(0, 1, (8, 32)).astype(np.float32))
    g, bits = 8, 3
    qmax = float(2 ** bits - 1)
    s, z = ref.minmax_init_ref(w, g, qmax)
    a = ref.dynamic_fake_quant_ref(w, g, qmax)
    b = ref.fake_quant_ref(w, s, z, qmax)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
