"""L2 model/layout semantics: shapes, causality, layout partition,
fake-quant <-> dequant consistency (the invariant linking Block-AP output to
the E2E-QP input), and Adam golden vectors (mirrored in rust)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import PRESETS, Preset
from compile.kernels import ref

P = PRESETS["tiny"]


def rand_flat(layout, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, (layout.size,)).astype(np.float32))


def init_fp_params(p: Preset, seed=0):
    """Sane init: norms at 1.0, weights small."""
    fl = M.fp_layout(p)
    rng = np.random.default_rng(seed)
    flat = np.zeros(fl.size, np.float32)
    for name, off, shape in fl.entries:
        n = int(np.prod(shape))
        if name.endswith("norm"):
            flat[off:off + n] = 1.0
        else:
            std = 0.02 if "embed" in name or "head" in name else \
                (2.0 / (shape[0] + shape[1])) ** 0.5
            flat[off:off + n] = rng.normal(0, std, n)
    return jnp.asarray(flat), fl


def test_layout_partitions_exactly():
    for mk in (M.fp_layout, M.block_layout, M.wq_layout, M.fpr_layout,
               M.lora_layout):
        lay = mk(P)
        covered = 0
        prev_end = 0
        for name, off, shape in lay.entries:
            assert off == prev_end, f"gap before {name}"
            n = int(np.prod(shape))
            covered += n
            prev_end = off + n
        assert covered == lay.size


def test_qp_layout_s_z_halves():
    for g in P.group_sizes:
        lay = M.qp_layout(P, g)
        half = lay.size // 2
        # all s entries fit exactly in the first half, z in the second
        for name, off, shape in lay.entries:
            n = int(np.prod(shape))
            if name.startswith("s."):
                assert off + n <= half
            else:
                assert off >= half
        assert lay.size == 2 * half


def test_model_fwd_shapes_and_causality():
    params, fl = init_fp_params(P)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, P.vocab, (2, 16)).astype(np.int32))
    logits = M.model_fwd_fp(params, x, P, fl)
    assert logits.shape == (2, 16, P.vocab)
    # causality: perturbing token t must not change logits at positions < t
    x2 = x.at[:, 10].set((x[:, 10] + 1) % P.vocab)
    logits2 = M.model_fwd_fp(params, x2, P, fl)
    np.testing.assert_allclose(logits[:, :10], logits2[:, :10],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(logits[:, 10:], logits2[:, 10:])


def test_block_fake_quant_equals_dequant_after_quantize():
    """block_fwd_fake_quant(W,s,z) == block_fwd_dequant(quantize(W,s,z),s,z):
    the handoff invariant between Block-AP and E2E-QP."""
    bl = M.block_layout(P)
    g = 32
    qbl = M.qp_block_layout(P, g)
    wqbl = M.wq_block_layout(P)
    rng = np.random.default_rng(3)

    bp = np.zeros(bl.size, np.float32)
    for name, off, shape in bl.entries:
        n = int(np.prod(shape))
        if name.endswith("norm"):
            bp[off:off + n] = 1.0
        else:
            bp[off:off + n] = rng.normal(0, 0.1, n)
    bp = jnp.asarray(bp)

    qmax = 3.0  # 2-bit
    # minmax init of qp from the weights
    qp = np.zeros(qbl.size, np.float32)
    for name, off, shape in qbl.entries:
        which, lin = name.split(".", 1)
        w = bl.slice(bp, lin)
        s, z = ref.minmax_init_ref(w, g, qmax)
        n = int(np.prod(shape))
        qp[off:off + n] = np.asarray(s if which == "s" else z).ravel()
    qp = jnp.asarray(qp)

    h = jnp.asarray(rng.normal(0, 1, (2, 8, P.dim)).astype(np.float32))
    qm = jnp.full((1, 1), qmax, jnp.float32)
    out_fq = M.block_fwd_fake_quant(bp, qp, h, qm, P, bl, qbl)

    # quantize weights -> wq flat
    wq = np.zeros(wqbl.size, np.float32)
    for name, off, shape in wqbl.entries:
        w = bl.slice(bp, name)
        s = qbl.slice(qp, f"s.{name}")
        z = qbl.slice(qp, f"z.{name}")
        wi = ref.quantize_ref(w, s, z, qmax)
        n = int(np.prod(shape))
        wq[off:off + n] = np.asarray(wi).ravel()
    wq = jnp.asarray(wq)
    norms = jnp.concatenate([bl.slice(bp, "attn_norm"),
                             bl.slice(bp, "mlp_norm")])
    out_dq = M.block_fwd_dequant(wq, qp, norms, h, P, wqbl, qbl)
    np.testing.assert_allclose(out_fq, out_dq, rtol=2e-4, atol=2e-4)


def test_adam_golden_vector():
    """Golden values mirrored by rust/src/coordinator/opt.rs tests."""
    p = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
    g = jnp.asarray([0.1, -0.2, 0.3], jnp.float32)
    m = jnp.asarray([0.01, 0.0, -0.05], jnp.float32)
    v = jnp.asarray([0.001, 0.0002, 0.0], jnp.float32)
    p2, m2, v2 = M.adam_update(p, g, m, v, jnp.float32(3.0), jnp.float32(0.01))
    # reference computed independently (numpy, float64 then cast)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m_ref = b1 * np.array([0.01, 0.0, -0.05]) + 0.1 * np.array([0.1, -0.2, 0.3])
    v_ref = b2 * np.array([0.001, 0.0002, 0.0]) + 0.001 * np.array([0.1, -0.2, 0.3]) ** 2
    mhat = m_ref / (1 - b1 ** 3)
    vhat = v_ref / (1 - b2 ** 3)
    p_ref = np.array([1.0, -2.0, 0.5]) - 0.01 * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(p2, p_ref.astype(np.float32), rtol=1e-5)
    np.testing.assert_allclose(m2, m_ref.astype(np.float32), rtol=1e-6)
    np.testing.assert_allclose(v2, v_ref.astype(np.float32), rtol=1e-6)


def test_cross_entropy_uniform_logits():
    logits = jnp.zeros((2, 4, P.vocab))
    y = jnp.zeros((2, 4), jnp.int32)
    ce = M.cross_entropy(logits, y)
    np.testing.assert_allclose(ce, np.log(P.vocab), rtol=1e-5)


def test_masked_cross_entropy_ignores_masked_positions():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 1, (1, 4, P.vocab)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, P.vocab, (1, 4)).astype(np.int32))
    mask = jnp.asarray([[0.0, 1.0, 1.0, 0.0]])
    full = M.masked_cross_entropy(logits, y, mask)
    # manually over the two unmasked positions
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    per = logz - gold
    np.testing.assert_allclose(full, (per[0, 1] + per[0, 2]) / 2, rtol=1e-6)


def test_rope_preserves_norm_and_relative_phase():
    cos, sin = M.rope_tables(P, 8)
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 8, P.head_dim)).astype(np.float32))
    qr = M.apply_rope(q, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(qr, axis=-1), jnp.linalg.norm(q, axis=-1),
        rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(qr[:, :, 0], q[:, :, 0], rtol=1e-6)
