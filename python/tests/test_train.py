"""Train-step graph semantics: losses decrease, masks freeze what they
should, projections clamp, frozen buffers stay frozen. These run the SAME
functions that aot.py lowers, so green here == green artifacts (modulo the
HLO text round-trip, covered by rust integration tests)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import train
from compile.configs import PRESETS
from compile.kernels import ref
from tests.test_model import init_fp_params

P = PRESETS["tiny"]
G = 32
QMAX2 = 3.0


def _toy_batch(seed, bsz, t):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, P.vocab, (bsz, t)).astype(np.int32)
    # learnable structure: y is a cyclic shift of x's token ids
    y = (x + 1) % P.vocab
    return jnp.asarray(x), jnp.asarray(y)


def _block_setup(seed=0):
    bl = M.block_layout(P)
    qbl = M.qp_block_layout(P, G)
    rng = np.random.default_rng(seed)
    bp = np.zeros(bl.size, np.float32)
    for name, off, shape in bl.entries:
        n = int(np.prod(shape))
        bp[off:off + n] = 1.0 if name.endswith("norm") else \
            rng.normal(0, 0.1, n)
    bp = jnp.asarray(bp)
    qp = np.zeros(qbl.size, np.float32)
    for name, off, shape in qbl.entries:
        which, lin = name.split(".", 1)
        s, z = ref.minmax_init_ref(bl.slice(bp, lin), G, QMAX2)
        n = int(np.prod(shape))
        qp[off:off + n] = np.asarray(s if which == "s" else z).ravel()
    return bp, jnp.asarray(qp), bl, qbl


def test_pretrain_step_decreases_loss():
    fn, args, outs = train.build_pretrain_step(P)
    params, fl = init_fp_params(P)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    x, y = _toy_batch(0, P.e2e_batch, P.e2e_ctx)
    jfn = jax.jit(fn)
    losses = []
    for i in range(8):
        params, m, v, loss = jfn(params, m, v, x, y,
                                 jnp.float32(i + 1), jnp.float32(1e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_block_ap_step_decreases_reconstruction_loss():
    bp, qp, bl, qbl = _block_setup()
    fn, args, outs = train.build_block_ap_step(P, G)
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(0, 1, (P.block_batch, P.block_ctx, P.dim))
                    .astype(np.float32))
    target = M.block_fwd_fp(bp, h, P, bl)  # fp teacher output
    mw = jnp.zeros_like(bp)
    vw = jnp.zeros_like(bp)
    mq = jnp.zeros_like(qp)
    vq = jnp.zeros_like(qp)
    lo = jnp.full_like(bp, -1e30)
    hi = jnp.full_like(bp, 1e30)
    qm = jnp.full((1, 1), QMAX2, jnp.float32)
    jfn = jax.jit(fn)
    losses = []
    for i in range(10):
        bp, qp, mw, vw, mq, vq, loss = jfn(
            bp, qp, mw, vw, mq, vq, lo, hi, h, target, qm,
            jnp.float32(i + 1), jnp.float32(1e-3), jnp.float32(1e-3),
            jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.0),
            jnp.float32(0.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_block_ap_masks_freeze_param_groups():
    bp0, qp0, bl, qbl = _block_setup()
    fn, *_ = train.build_block_ap_step(P, G)
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(0, 1, (P.block_batch, P.block_ctx, P.dim))
                    .astype(np.float32))
    target = M.block_fwd_fp(bp0, h, P, bl) + 0.1
    z0 = jnp.zeros_like
    qm = jnp.full((1, 1), QMAX2, jnp.float32)
    half = qp0.shape[0] // 2

    # m_w = 0: weights frozen, qp moves
    bp, qp, *_ = jax.jit(fn)(
        bp0, qp0, z0(bp0), z0(bp0), z0(qp0), z0(qp0),
        jnp.full_like(bp0, -1e30), jnp.full_like(bp0, 1e30), h, target, qm,
        jnp.float32(1), jnp.float32(1e-3), jnp.float32(1e-3),
        jnp.float32(0.0), jnp.float32(1.0), jnp.float32(1.0), jnp.float32(0.0))
    assert np.allclose(bp, bp0)
    assert not np.allclose(qp, qp0)

    # m_s = 0, m_z = 1: s half frozen, z half moves.
    # At exact minmax init no element saturates, so the z-gradient (paper
    # Eq. 4) is identically zero - shrink s by 2x to activate clamping.
    qp0 = qp0.at[:half].multiply(0.5)
    bp, qp, *_ = jax.jit(fn)(
        bp0, qp0, z0(bp0), z0(bp0), z0(qp0), z0(qp0),
        jnp.full_like(bp0, -1e30), jnp.full_like(bp0, 1e30), h, target, qm,
        jnp.float32(1), jnp.float32(1e-3), jnp.float32(1e-3),
        jnp.float32(1.0), jnp.float32(0.0), jnp.float32(1.0), jnp.float32(0.0))
    assert np.allclose(qp[:half], qp0[:half])
    assert not np.allclose(qp[half:], qp0[half:])


def test_block_ap_round_projection_clamps_weights():
    bp0, qp0, bl, qbl = _block_setup()
    fn, *_ = train.build_block_ap_step(P, G)
    rng = np.random.default_rng(4)
    h = jnp.asarray(rng.normal(0, 1, (P.block_batch, P.block_ctx, P.dim))
                    .astype(np.float32))
    target = M.block_fwd_fp(bp0, h, P, bl) + 0.5
    z0 = jnp.zeros_like
    qm = jnp.full((1, 1), QMAX2, jnp.float32)
    eps = 1e-6
    lo = bp0 - eps
    hi = bp0 + eps
    bp, *_ = jax.jit(fn)(
        bp0, qp0, z0(bp0), z0(bp0), z0(qp0), z0(qp0), lo, hi, h, target, qm,
        jnp.float32(1), jnp.float32(1e-2), jnp.float32(0.0),
        jnp.float32(1.0), jnp.float32(0.0), jnp.float32(0.0), jnp.float32(1.0))
    assert np.all(np.asarray(bp) <= np.asarray(hi) + 1e-7)
    assert np.all(np.asarray(bp) >= np.asarray(lo) - 1e-7)


def _quantized_model_setup(seed=0):
    params, fl = init_fp_params(P, seed)
    wql = M.wq_layout(P)
    qpl = M.qp_layout(P, G)
    fprl = M.fpr_layout(P)
    wq = np.zeros(wql.size, np.float32)
    qp = np.zeros(qpl.size, np.float32)
    fpr = np.zeros(fprl.size, np.float32)
    for name, off, shape in fprl.entries:
        src = fl.slice(params, name)
        n = int(np.prod(shape))
        fpr[off:off + n] = np.asarray(src).ravel()
    for name, off, shape in wql.entries:
        w = fl.slice(params, name)
        s, z = ref.minmax_init_ref(w, G, QMAX2)
        wi = ref.quantize_ref(w, s, z, QMAX2)
        n = int(np.prod(shape))
        wq[off:off + n] = np.asarray(wi).ravel()
        so, ss = qpl.by_name[f"s.{name}"]
        zo, zs = qpl.by_name[f"z.{name}"]
        qp[so:so + s.size] = np.asarray(s).ravel()
        qp[zo:zo + z.size] = np.asarray(z).ravel()
    return (jnp.asarray(wq), jnp.asarray(qp), jnp.asarray(fpr),
            wql, qpl, fprl)


def test_e2e_qp_step_trains_only_qp_and_decreases_loss():
    wq, qp, fpr, *_ = _quantized_model_setup()
    fn, *_ = train.build_e2e_qp_step(P, G)
    x, y = _toy_batch(1, P.e2e_batch, P.e2e_ctx)
    mask = jnp.ones(x.shape, jnp.float32)
    mq = jnp.zeros_like(qp)
    vq = jnp.zeros_like(qp)
    jfn = jax.jit(fn)
    losses = []
    qp0 = qp
    for i in range(8):
        qp, mq, vq, loss = jfn(wq, qp, fpr, mq, vq, x, y, mask,
                               jnp.float32(i + 1), jnp.float32(5e-3),
                               jnp.float32(1.0), jnp.float32(0.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    half = qp.shape[0] // 2
    # z half stayed frozen (m_zf = 0)
    assert np.allclose(qp[half:], qp0[half:])
    assert not np.allclose(qp[:half], qp0[:half])


def test_e2e_full_step_runs_and_decreases_loss():
    params, fl = init_fp_params(P)
    fn, *_ = train.build_e2e_full_step(P, G)
    x, y = _toy_batch(2, P.e2e_batch, P.e2e_ctx)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    jfn = jax.jit(fn)
    losses = []
    for i in range(6):
        params, m, v, loss = jfn(params, m, v, x, y,
                                 jnp.float32(i + 1), jnp.float32(1e-3),
                                 jnp.float32(QMAX2))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_e2e_lora_step_trains_lora_only():
    wq, qp, fpr, *_ = _quantized_model_setup()
    ll = M.lora_layout(P)
    rng = np.random.default_rng(9)
    # A ~ N(0, 0.02), B = 0 (standard LoRA init: delta starts at zero)
    lora = np.zeros(ll.size, np.float32)
    for name, off, shape in ll.entries:
        if name.endswith(".A"):
            n = int(np.prod(shape))
            lora[off:off + n] = rng.normal(0, 0.02, n)
    lora = jnp.asarray(lora)
    fn, *_ = train.build_e2e_lora_step(P, G)
    x, y = _toy_batch(3, P.e2e_batch, P.e2e_ctx)
    mask = jnp.ones(x.shape, jnp.float32)
    m = jnp.zeros_like(lora)
    v = jnp.zeros_like(lora)
    jfn = jax.jit(fn)
    losses = []
    for i in range(6):
        lora, m, v, loss = jfn(wq, qp, fpr, lora, m, v, x, y, mask,
                               jnp.float32(i + 1), jnp.float32(5e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_model_fwd_q_matches_fwd_lora_with_zero_lora():
    wq, qp, fpr, *_ = _quantized_model_setup()
    ll = M.lora_layout(P)
    lora = jnp.zeros((ll.size,), jnp.float32)
    fnq, *_ = train.build_model_fwd_q(P, G)
    fnl, *_ = train.build_model_fwd_lora(P, G)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, P.vocab, (P.eval_batch, P.eval_ctx))
                    .astype(np.int32))
    (lq,) = jax.jit(fnq)(wq, qp, fpr, x)
    (ll_,) = jax.jit(fnl)(wq, qp, fpr, lora, x)
    np.testing.assert_allclose(lq, ll_, rtol=1e-5, atol=1e-5)
