//! Comparison methods reimplemented from scratch: PTQ (GPTQ/AWQ), naive
//! end-to-end QAT, and the Q-PEFT family (PEQA, QLoRA).
pub mod naive_qat;
pub mod ptq;
pub mod qlora;
