//! Naive end-to-end QAT baseline (LLM-QAT style, Table 2/9 comparator):
//! trains ALL parameters with dynamically re-quantized weights, end to end.
//! Memory = full params + full Adam state; time per step >> Block-AP.

use anyhow::Result;

use crate::config::QuantScheme;
use crate::coordinator::block_ap::rtn_quantize_model;
use crate::coordinator::opt::{AdamState, LrSchedule};
use crate::data::loader::LmBatch;
use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Backend};

pub struct NaiveQatReport {
    pub losses: Vec<f32>,
    pub seconds: f64,
    /// full params + 2x Adam moments (the memory cost Block-AP avoids)
    pub mem_bytes: usize,
}

/// Train from the pretrained fp params; returns the final RTN-quantized
/// model (dynamic scales frozen into the standard format at the end).
pub fn run_naive_qat(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    pool: &[LmBatch],
    epochs: usize,
    lr: f64,
) -> Result<(QuantizedModel, NaiveQatReport)> {
    let t0 = std::time::Instant::now();
    let exec = rt.exec_g(preset, "e2e_full_step", sch.group)?;
    let mut p = params.to_vec();
    let mut adam = AdamState::new(p.len());
    let total = pool.len() * epochs;
    let sched = LrSchedule::cosine(lr, total / 20 + 1, total);
    let mut losses = Vec::with_capacity(total);
    let mut it = 0usize;
    for _ in 0..epochs {
        for b in pool {
            let step = adam.next_step();
            let outs = exec.run(&[
                Arg::F32(&p),
                Arg::F32(&adam.m),
                Arg::F32(&adam.v),
                Arg::I32(&b.x),
                Arg::I32(&b.y),
                Arg::Scalar(step),
                Arg::Scalar(sched.at(it)),
                Arg::Scalar(sch.qmax()),
            ])?;
            let mut o = outs.into_iter();
            p = o.next().unwrap().data;
            adam.m = o.next().unwrap().data;
            adam.v = o.next().unwrap().data;
            losses.push(o.next().unwrap().data[0]);
            it += 1;
        }
    }
    let mem = p.len() * 4 * 3;
    let qm = rtn_quantize_model(rt, preset, &p, sch)?;
    Ok((
        qm,
        NaiveQatReport {
            losses,
            seconds: t0.elapsed().as_secs_f64(),
            mem_bytes: mem,
        },
    ))
}
