//! Model-level PTQ baselines: GPTQ and AWQ applied block-by-block with
//! calibration activations captured by the `block_capture_fp` executable.
//!
//! Convention (matches the reference GPTQ pipeline): block inputs come from
//! the quantized-propagated stream; intra-block activations are computed
//! with the block's original weights; after quantization the stream is
//! propagated through the quantized block.

use anyhow::{anyhow, Result};

use crate::config::QuantScheme;
use crate::coordinator::block_ap::extract_block;
use crate::data::loader::LmBatch;
use crate::model::quantized::QuantizedModel;
use crate::quant::awq::{awq_quantize, x2_mean};
use crate::quant::gptq::gptq_quantize;
use crate::quant::rtn::GroupParams;
use crate::runtime::{Arg, Backend};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PtqMethod {
    Gptq,
    Awq,
}

/// Which capture output feeds each linear.
/// capture outputs: [h_out, x_attn, attn_ctx, x_mlp, mlp_mid]
const LIN_SRC: [(&str, usize); 7] = [
    ("attn.q", 1),
    ("attn.k", 1),
    ("attn.v", 1),
    ("attn.o", 2),
    ("mlp.gate", 3),
    ("mlp.up", 3),
    ("mlp.down", 4),
];

/// Quantize a pretrained fp model with GPTQ or AWQ.
pub fn ptq_quantize_model(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    pool: &[LmBatch],
    method: PtqMethod,
    max_rows: usize,
) -> Result<QuantizedModel> {
    let cfg = rt.manifest().preset(preset)?.config.clone();
    let g = sch.group;
    let fpl = rt.manifest().layout(preset, "fp")?.clone();
    let bl = rt.manifest().layout(preset, "block")?.clone();
    let qbl = rt.manifest().layout(preset, &format!("qp_block_g{g}"))?.clone();
    let wql = rt.manifest().layout(preset, "wq")?.clone();
    let qpl = rt.manifest().layout(preset, &format!("qp_g{g}"))?.clone();
    let fprl = rt.manifest().layout(preset, "fpr")?.clone();

    let embed = rt.exec(preset, "embed_fwd")?;
    let capture = rt.exec(preset, "block_capture_fp")?;
    let block_q = rt.exec_g(preset, "block_fwd_q", g)?;

    let mut h: Vec<Vec<f32>> = Vec::with_capacity(pool.len());
    for b in pool {
        h.push(embed.run1(&[Arg::F32(params), Arg::I32(&b.x)])?);
    }

    let mut wq_full = vec![0f32; wql.size];
    let mut qp_full = vec![0f32; qpl.size];
    let mut fpr = vec![0f32; fprl.size];
    let tokens_per_batch = cfg.block_batch * cfg.block_ctx;

    for b in 0..cfg.n_layers {
        let bp = extract_block(params, &fpl, &bl, b)?;
        // capture intra-block activations over the pool
        // acts[src] has rows of width depending on src (d or inter)
        let mut acts: Vec<Vec<f32>> = vec![Vec::new(); 5];
        for hb in &h {
            let outs = capture.run(&[Arg::F32(&bp), Arg::F32(hb)])?;
            for (si, o) in outs.iter().enumerate() {
                if si == 0 {
                    continue; // h_out not needed here
                }
                acts[si].extend_from_slice(&o.data);
            }
        }
        // subsample rows deterministically (stride) to bound Hessian cost
        let total_rows = pool.len() * tokens_per_batch;
        let stride = (total_rows + max_rows - 1) / max_rows.max(1);
        let sub = |src: usize, width: usize| -> Vec<f32> {
            let a = &acts[src];
            let mut out = Vec::new();
            let mut r = 0;
            while r < total_rows {
                out.extend_from_slice(&a[r * width..(r + 1) * width]);
                r += stride.max(1);
            }
            out
        };

        // quantize each linear
        let mut qp_b = vec![0f32; qbl.size];
        let mut wq_b: Vec<(String, Vec<f32>)> = Vec::new();
        for (lin, src) in LIN_SRC {
            let we = bl.entry(lin)?;
            let (out_d, in_d) = (we.shape[0], we.shape[1]);
            let w = bl.slice(&bp, lin)?;
            let x = sub(src, in_d);
            let (w_int, gp): (Vec<f32>, GroupParams) = match method {
                PtqMethod::Gptq => {
                    let r = gptq_quantize(w, out_d, in_d, &x, sch)?;
                    (r.w_int, r.gp)
                }
                PtqMethod::Awq => {
                    let m = x2_mean(&x, in_d);
                    let r = awq_quantize(w, out_d, in_d, &m, sch);
                    (r.w_int, r.gp)
                }
            };
            let se = qbl.entry(&format!("s.{lin}"))?;
            let ze = qbl.entry(&format!("z.{lin}"))?;
            qp_b[se.offset..se.offset + se.numel()].copy_from_slice(&gp.s);
            qp_b[ze.offset..ze.offset + ze.numel()].copy_from_slice(&gp.z);
            wq_b.push((lin.to_string(), w_int));
        }

        // assemble into full buffers
        let mut wq_block_flat =
            vec![
                0f32;
                bl.entries
                    .iter()
                    .filter(|e| !e.name.ends_with("norm"))
                    .map(|e| e.numel())
                    .sum()
            ];
        let mut woff = 0usize;
        for e in bl.entries.iter().filter(|e| !e.name.ends_with("norm")) {
            let w_int = &wq_b
                .iter()
                .find(|(n, _)| n == &e.name)
                .ok_or_else(|| anyhow!("missing {}", e.name))?
                .1;
            wql.slice_mut(&mut wq_full, &format!("blocks.{b}.{}", e.name))?
                .copy_from_slice(w_int);
            wq_block_flat[woff..woff + e.numel()].copy_from_slice(w_int);
            woff += e.numel();
        }
        for e in &qbl.entries {
            let (which, lin) = e.name.split_once('.').unwrap();
            qpl.slice_mut(&mut qp_full,
                          &format!("{which}.blocks.{b}.{lin}"))?
                .copy_from_slice(&qp_b[e.offset..e.offset + e.numel()]);
        }
        let mut norms = vec![0f32; 2 * cfg.dim];
        norms[..cfg.dim].copy_from_slice(bl.slice(&bp, "attn_norm")?);
        norms[cfg.dim..].copy_from_slice(bl.slice(&bp, "mlp_norm")?);
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.attn_norm"))?
            .copy_from_slice(&norms[..cfg.dim]);
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.mlp_norm"))?
            .copy_from_slice(&norms[cfg.dim..]);

        // propagate through the quantized block
        for hb in h.iter_mut() {
            *hb = block_q.run1(&[
                Arg::F32(&wq_block_flat),
                Arg::F32(&qp_b),
                Arg::F32(&norms),
                Arg::F32(hb),
            ])?;
        }
        crate::info!("ptq[{method:?} {preset} {}] block {b} done",
                     sch.tag());
    }
    for name in ["embed", "final_norm", "head"] {
        fprl.slice_mut(&mut fpr, name)?
            .copy_from_slice(fpl.slice(params, name)?);
    }
    Ok(QuantizedModel {
        preset: preset.to_string(),
        scheme: sch,
        wq: wq_full,
        qp: qp_full,
        fpr,
    })
}
