//! Q-PEFT baselines (Table 4):
//!  * PEQA  = RTN init + E2E-QP on step sizes only (the paper notes PEQA is
//!    the closest prior method; it differs from EfficientQAT exactly by the
//!    missing Block-AP phase).
//!  * QLoRA = frozen quantized base + trainable LoRA (bits "4+16"); the
//!    "QLoRA w/ GPTQ" row merges LoRA into fp weights and re-quantizes.

use anyhow::Result;

use crate::config::{QuantScheme, TrainHp};
use crate::coordinator::block_ap::rtn_quantize_model;
use crate::coordinator::e2e_qp::{run_e2e_qp, E2eBatch, E2eReport};
use crate::coordinator::opt::{AdamState, LrSchedule};
use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Backend};
use crate::util::rng::Rng;

/// PEQA: RTN quantization + s-only end-to-end tuning.
pub fn run_peqa(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    batches: &[E2eBatch],
    hp: &TrainHp,
) -> Result<(QuantizedModel, E2eReport)> {
    let mut qm = rtn_quantize_model(rt, preset, params, sch)?;
    let mut hp = hp.clone();
    hp.train_z_e2e = false;
    let report = run_e2e_qp(rt, &mut qm, batches, &hp)?;
    Ok((qm, report))
}

pub struct QloraReport {
    pub losses: Vec<f32>,
    pub seconds: f64,
}

/// LoRA init matching the convention: A ~ N(0, 0.02), B = 0.
pub fn init_lora(rt: &dyn Backend, preset: &str, seed: u64) -> Result<Vec<f32>> {
    let ll = rt.manifest().layout(preset, "lora")?;
    let mut lora = vec![0f32; ll.size];
    let mut rng = Rng::new(seed).fork("lora");
    for e in &ll.entries {
        if e.name.ends_with(".A") {
            rng.fill_normal(&mut lora[e.offset..e.offset + e.numel()],
                            0.0, 0.02);
        }
    }
    Ok(lora)
}

/// QLoRA: train LoRA over a frozen quantized base.
pub fn run_qlora(
    rt: &dyn Backend,
    qm: &QuantizedModel,
    batches: &[E2eBatch],
    epochs: usize,
    lr: f64,
    seed: u64,
) -> Result<(Vec<f32>, QloraReport)> {
    let t0 = std::time::Instant::now();
    let preset = qm.preset.clone();
    let exec = rt.exec_g(&preset, "e2e_lora_step", qm.scheme.group)?;
    let mut lora = init_lora(rt, &preset, seed)?;
    let mut adam = AdamState::new(lora.len());
    let total = batches.len() * epochs;
    let sched = LrSchedule::cosine(lr, total / 20 + 1, total);
    let mut losses = Vec::with_capacity(total);
    let mut it = 0usize;
    for _ in 0..epochs {
        for b in batches {
            let step = adam.next_step();
            let outs = exec.run(&[
                Arg::F32(&qm.wq),
                Arg::F32(&qm.qp),
                Arg::F32(&qm.fpr),
                Arg::F32(&lora),
                Arg::F32(&adam.m),
                Arg::F32(&adam.v),
                Arg::I32(&b.x),
                Arg::I32(&b.y),
                Arg::F32(&b.mask),
                Arg::Scalar(step),
                Arg::Scalar(sched.at(it)),
            ])?;
            let mut o = outs.into_iter();
            lora = o.next().unwrap().data;
            adam.m = o.next().unwrap().data;
            adam.v = o.next().unwrap().data;
            losses.push(o.next().unwrap().data[0]);
            it += 1;
        }
    }
    Ok((
        lora,
        QloraReport { losses, seconds: t0.elapsed().as_secs_f64() },
    ))
}

/// Merge LoRA into the dequantized base -> full-precision flat params
/// (the step that reverts QLoRA models to FP16, paper §2).
pub fn merge_lora(
    rt: &dyn Backend,
    qm: &QuantizedModel,
    lora: &[f32],
) -> Result<Vec<f32>> {
    let preset = &qm.preset;
    let g = qm.scheme.group;
    let fpl = rt.manifest().layout(preset, "fp")?;
    let wql = rt.manifest().layout(preset, "wq")?;
    let qpl = rt.manifest().layout(preset, &format!("qp_g{g}"))?;
    let fprl = rt.manifest().layout(preset, "fpr")?;
    let ll = rt.manifest().layout(preset, "lora")?;

    let mut fp = vec![0f32; fpl.size];
    // fp remainder
    for e in fprl.entries.iter() {
        fpl.slice_mut(&mut fp, &e.name)?
            .copy_from_slice(fprl.slice(&qm.fpr, &e.name)?);
    }
    // linears: dequant + B @ A
    for e in wql.entries.iter() {
        let (out_d, in_d) = (e.shape[0], e.shape[1]);
        let gpr = in_d / g;
        let w_int = wql.slice(&qm.wq, &e.name)?;
        let s = qpl.slice(&qm.qp, &format!("s.{}", e.name))?;
        let z = qpl.slice(&qm.qp, &format!("z.{}", e.name))?;
        let a = ll.slice(lora, &format!("{}.A", e.name))?;
        let b = ll.slice(lora, &format!("{}.B", e.name))?;
        let r = ll.entry(&format!("{}.A", e.name))?.shape[0];
        let dst = fpl.slice_mut(&mut fp, &e.name)?;
        for o in 0..out_d {
            for k in 0..in_d {
                let gi = o * gpr + k / g;
                let mut v = (w_int[o * in_d + k] - z[gi]) * s[gi];
                for rr in 0..r {
                    v += b[o * r + rr] * a[rr * in_d + k];
                }
                dst[o * in_d + k] = v;
            }
        }
    }
    Ok(fp)
}
