//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p95, markdown output. Used by the Table
//! 10 qlinear bench and the runtime-overhead bench.

use std::time::Instant;

use crate::config::{llama_by_name, QuantScheme};
use crate::infer::qlinear::{dense_matvec, PackedLinear};
use crate::quant::rtn::{minmax_init, quantize};
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub iters: usize,
}

/// Time `f` for `iters` iterations after `warmup` calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
                         -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        mean_us: mean(&times),
        p50_us: percentile(&times, 50.0),
        p95_us: percentile(&times, 95.0),
        iters,
    }
}

/// Table 10 analog: f32 vs packed INT{2,3,4} matvec at the exact Llama-2
/// layer shapes the paper benches. Returns markdown.
pub fn qlinear_speed_table(fast: bool) -> anyhow::Result<String> {
    // the paper's six (out x in) shapes
    let shapes: Vec<(&str, usize, usize)> = vec![
        ("2-7B attn", 4096, 4096),
        ("2-7B mlp", 11008, 4096),
        ("2-13B attn", 5120, 5120),
        ("2-13B mlp", 13824, 5120),
        ("2-70B attn", 8192, 8192),
        ("2-70B mlp", 28672, 8192),
    ];
    let shapes = if fast { shapes[..2].to_vec() } else { shapes };
    let mut rows = Vec::new();
    let mut rng = Rng::new(101);
    for (name, out_d, in_d) in shapes {
        let mut w = vec![0f32; out_d * in_d];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; in_d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; out_d];

        let iters = if out_d * in_d > 64_000_000 { 3 } else { 10 };
        let dense = bench("f32", 2, iters, || {
            dense_matvec(&w, out_d, in_d, &x, &mut y);
            std::hint::black_box(&y);
        });

        let mut row = vec![
            name.to_string(),
            format!("{out_d}x{in_d}"),
            format!("{:.0}", dense.mean_us),
        ];
        for bits in [2u32, 3, 4] {
            let sch = QuantScheme::new(bits, 128);
            let gp = minmax_init(&w, out_d, in_d, sch);
            let wi = quantize(&w, &gp, sch);
            let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z,
                                        sch)?;
            let r = bench(&format!("int{bits}"), 2, iters, || {
                pl.matvec(&x, &mut y);
                std::hint::black_box(&y);
            });
            row.push(format!("{:.0} ({:.1}x)", r.mean_us,
                             dense.mean_us / r.mean_us));
        }
        crate::info!("qlinear bench {name} done");
        rows.push(row);
    }
    Ok(format!(
        "## Table 10 analog - matvec latency us (CPU; f32 baseline vs \
         packed, speedup in parens; paper: INT2 2.9-4.4x vs fp16 on \
         A100)\n\n{}",
        crate::exp::md_table(
            &["Layer", "Shape", "f32 us", "INT2", "INT3", "INT4"], &rows)
    ))
}

/// Sanity check used by the size table: llama shapes resolve.
pub fn llama_shapes_ok() -> bool {
    ["llama2-7b", "llama2-13b", "llama2-70b"]
        .iter()
        .all(|n| llama_by_name(n).is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 1, 20, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_us >= 0.0);
        assert!(r.p95_us >= r.p50_us * 0.5);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn packed_matvec_faster_than_dense_at_scale() {
        // the Table 10 mechanism: memory-bound matvec, 16x fewer weight
        // bytes at 2-bit. Use a mid-size layer to keep test time low.
        let (out_d, in_d) = (1024, 1024);
        let mut rng = Rng::new(7);
        let mut w = vec![0f32; out_d * in_d];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; in_d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; out_d];
        let sch = QuantScheme::new(2, 128);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)
            .unwrap();
        let dense = bench("f32", 3, 30, || {
            dense_matvec(&w, out_d, in_d, &x, &mut y);
            std::hint::black_box(&y);
        });
        let packed = bench("int2", 3, 30, || {
            pl.matvec(&x, &mut y);
            std::hint::black_box(&y);
        });
        // conservatively just require parity-or-better in test builds
        assert!(
            packed.mean_us < dense.mean_us * 1.5,
            "packed {:.0}us vs dense {:.0}us",
            packed.mean_us,
            dense.mean_us
        );
    }
}
