//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p95, markdown output. Used by the Table
//! 10 qlinear bench, the runtime-overhead bench, and the inference
//! throughput bench behind `runs/bench.json`.
//!
//! `runs/bench.json` convention: every run of `eqat bench inference` (or
//! the `inference` bench binary) rewrites this machine-readable snapshot
//! (schema 10 = inference sections + native train_step + eval_forward +
//! the continuous-batching `serve` section + the paged-KV `kv_fork`
//! section + the open-loop `serve_robust` section + the SIMD `kernels`
//! section + the cross-request `prefix_cache` section + the low-bit KV
//! `kv_lowbit` section + the SLO scheduling `serve_slo` section:
//! EDF-vs-FIFO goodput under p95 first-token and per-token latency
//! targets at batch 8/32/128 on the work-proportional open-loop clock,
//! plus the 200-schedule randomized property-fuzzer sweep, all behind
//! in-bench gates) so the perf trajectory is trackable across PRs;
//! [`check_bench_json`] validates it (used by scripts/tier1.sh).
//! Schemas 1-9 from older PRs stay accepted. Every section and field is
//! documented in docs/BENCH_SCHEMA.md - keep that file in sync when
//! bumping the schema.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{llama_by_name, QuantScheme};
use crate::infer::core::ModelCore;
use crate::infer::engine::Engine;
use crate::infer::generate::{generate, Sampler};
use crate::infer::kv::{KvFormat, KvLease, KvPool};
use crate::infer::qlinear::{dense_matvec, PackedLinear};
use crate::infer::sched::{SchedConfig, Scheduler};
use crate::infer::session::Request;
use crate::quant::rtn::{minmax_init, quantize};
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::simd::{self, Isa};
use crate::util::stats::{mean, percentile};
use crate::util::threads::{self, with_threads};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub iters: usize,
}

/// Time `f` for `iters` iterations after `warmup` calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
                         -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        mean_us: mean(&times),
        p50_us: percentile(&times, 50.0),
        p95_us: percentile(&times, 95.0),
        iters,
    }
}

/// Table 10 analog: f32 vs packed INT{2,3,4} matvec at the exact Llama-2
/// layer shapes the paper benches. Returns (markdown, json rows).
pub fn qlinear_speed_table(fast: bool) -> Result<(String, Json)> {
    // the paper's six (out x in) shapes
    let shapes: Vec<(&str, usize, usize)> = vec![
        ("2-7B attn", 4096, 4096),
        ("2-7B mlp", 11008, 4096),
        ("2-13B attn", 5120, 5120),
        ("2-13B mlp", 13824, 5120),
        ("2-70B attn", 8192, 8192),
        ("2-70B mlp", 28672, 8192),
    ];
    let shapes = if fast { shapes[..2].to_vec() } else { shapes };
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut rng = Rng::new(101);
    for (name, out_d, in_d) in shapes {
        let mut w = vec![0f32; out_d * in_d];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; in_d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; out_d];

        let iters = if out_d * in_d > 64_000_000 { 3 } else { 10 };
        let dense = bench("f32", 2, iters, || {
            dense_matvec(&w, out_d, in_d, &x, &mut y);
            std::hint::black_box(&y);
        });

        let mut row = vec![
            name.to_string(),
            format!("{out_d}x{in_d}"),
            format!("{:.0}", dense.mean_us),
        ];
        let mut jrow = vec![
            ("layer", Json::str(name)),
            ("shape", Json::str(format!("{out_d}x{in_d}"))),
            ("f32_us", Json::num(dense.mean_us)),
        ];
        for bits in [2u32, 3, 4] {
            let sch = QuantScheme::new(bits, 128);
            let gp = minmax_init(&w, out_d, in_d, sch);
            let wi = quantize(&w, &gp, sch);
            let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z,
                                        sch)?;
            let r = bench(&format!("int{bits}"), 2, iters, || {
                pl.matvec(&x, &mut y);
                std::hint::black_box(&y);
            });
            row.push(format!("{:.0} ({:.1}x)", r.mean_us,
                             dense.mean_us / r.mean_us));
            jrow.push((
                match bits {
                    2 => "int2_us",
                    3 => "int3_us",
                    _ => "int4_us",
                },
                Json::num(r.mean_us),
            ));
        }
        crate::info!("qlinear bench {name} done");
        rows.push(row);
        jrows.push(Json::obj(jrow));
    }
    let md = format!(
        "## Table 10 analog - matvec latency us (CPU; f32 baseline vs \
         packed, speedup in parens; paper: INT2 2.9-4.4x vs fp16 on \
         A100)\n\n{}",
        crate::exp::md_table(
            &["Layer", "Shape", "f32 us", "INT2", "INT3", "INT4"], &rows)
    );
    Ok((md, Json::arr(jrows)))
}

/// Thread counts reported in the throughput tables (per the perf issue:
/// single-thread, typical-laptop, typical-server).
const THREAD_COUNTS: [usize; 3] = [1, 4, 16];

/// End-to-end inference throughput: threaded matvec scaling (packed vs
/// dense) plus engine decode tokens/sec and batched-vs-sequential prefill
/// on a Llama-2-7B-shaped block. Returns (markdown, bench.json payload).
///
/// Fast mode shrinks shapes/iterations for CI smoke runs
/// (`EQAT_BENCH_FAST=1`); the acceptance numbers come from the full run.
pub fn inference_throughput(fast: bool) -> Result<(String, Json)> {
    let mut md = String::new();
    let (mv_md, mv_json) = matvec_thread_table(fast)?;
    md.push_str(&mv_md);
    md.push('\n');
    let (eng_md, eng_json) = engine_throughput_table(fast)?;
    md.push_str(&eng_md);
    md.push('\n');
    let (ts_md, ts_json) = train_step_throughput(fast)?;
    md.push_str(&ts_md);
    md.push('\n');
    let (ef_md, ef_json) = eval_forward_throughput(fast)?;
    md.push_str(&ef_md);
    md.push('\n');
    let (sv_md, sv_json) = serve_throughput(fast)?;
    md.push_str(&sv_md);
    md.push('\n');
    let (kf_md, kf_json) = kv_fork_throughput(fast)?;
    md.push_str(&kf_md);
    md.push('\n');
    let (sr_md, sr_json) = serve_robust_throughput(fast)?;
    md.push_str(&sr_md);
    md.push('\n');
    let (kn_md, kn_json) = kernels_throughput(fast)?;
    md.push_str(&kn_md);
    md.push('\n');
    let (pc_md, pc_json) = prefix_cache_throughput(fast)?;
    md.push_str(&pc_md);
    md.push('\n');
    let (kl_md, kl_json) = kv_lowbit_throughput(fast)?;
    md.push_str(&kl_md);
    md.push('\n');
    let (ss_md, ss_json) = serve_slo_throughput(fast)?;
    md.push_str(&ss_md);

    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let payload = Json::obj(vec![
        // schema 10 = schema 9 + the SLO scheduling serve_slo section
        ("schema", Json::num(10.0)),
        ("kind", Json::str("inference_throughput")),
        ("fast", Json::Bool(fast)),
        ("generated_unix", Json::num(now)),
        ("threads_available", Json::num(threads::num_threads() as f64)),
        ("simd", Json::str(simd::isa_name())),
        ("matvec", mv_json),
        ("engine", eng_json),
        ("train_step", ts_json),
        ("eval_forward", ef_json),
        ("serve", sv_json),
        ("kv_fork", kf_json),
        ("serve_robust", sr_json),
        ("kernels", kn_json),
        ("prefix_cache", pc_json),
        ("kv_lowbit", kl_json),
        ("serve_slo", ss_json),
    ]);
    Ok((md, payload))
}

/// Time one kernel under forced-scalar and the detected SIMD path,
/// asserting first that the two outputs are bit-identical. `bytes` /
/// `flops` are the nominal traffic and work per call, for GB/s and
/// GFLOP/s columns.
fn kernel_row<F: FnMut() -> Vec<f32>>(
    name: &str, isa: Isa, iters: usize, bytes: f64, flops: f64,
    mut run: F) -> Result<(Vec<String>, Json)> {
    let y_s = simd::with_isa(Isa::Scalar, &mut run);
    let y_v = simd::with_isa(isa, &mut run);
    if y_s.len() != y_v.len()
        || y_s.iter().zip(&y_v).any(|(a, b)| a.to_bits() != b.to_bits())
    {
        bail!("kernels bench: {name} output diverges between scalar and \
               {}", isa.name());
    }
    let r_s = simd::with_isa(Isa::Scalar, || {
        bench(name, 1, iters, || {
            std::hint::black_box(run());
        })
    });
    let r_v = simd::with_isa(isa, || {
        bench(name, 1, iters, || {
            std::hint::black_box(run());
        })
    });
    let gb = |us: f64| bytes / (us * 1e-6) / 1e9;
    let gf = |us: f64| flops / (us * 1e-6) / 1e9;
    let row = vec![
        name.to_string(),
        format!("{:.0}", r_s.mean_us),
        format!("{:.0}", r_v.mean_us),
        format!("{:.1}", gb(r_s.mean_us)),
        format!("{:.1}", gb(r_v.mean_us)),
        format!("{:.1}", gf(r_s.mean_us)),
        format!("{:.1}", gf(r_v.mean_us)),
        format!("{:.2}x", r_s.mean_us / r_v.mean_us),
    ];
    let jrow = Json::obj(vec![
        ("kernel", Json::str(name)),
        ("scalar_us", Json::num(r_s.mean_us)),
        ("simd_us", Json::num(r_v.mean_us)),
        ("scalar_gb_s", Json::num(gb(r_s.mean_us))),
        ("simd_gb_s", Json::num(gb(r_v.mean_us))),
        ("scalar_gflop_s", Json::num(gf(r_s.mean_us))),
        ("simd_gflop_s", Json::num(gf(r_v.mean_us))),
        ("speedup", Json::num(r_s.mean_us / r_v.mean_us)),
        ("bitexact", Json::Bool(true)),
    ]);
    Ok((row, jrow))
}

/// Kernel-layer throughput: forced-scalar vs the detected SIMD path for
/// the packed 2/3/4-bit matvec and matmul kernels, the dense microkernel,
/// and the fake-quant gradient kernel. Every row first *asserts* the
/// bit-identity contract (`EQAT_SIMD=scalar` output == vector output,
/// compared via `to_bits`), so a published `kernels` section doubles as
/// a determinism witness for the detected ISA (recorded in `isa`).
/// Schema-7 `kernels` section of runs/bench.json.
pub fn kernels_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::runtime::native::ops;

    let (out_d, in_d) =
        if fast { (256usize, 512usize) } else { (2048, 2048) };
    let group = 64usize;
    let n_tok = 8usize;
    let iters = if fast { 5 } else { 20 };
    let isa = simd::detected();

    let mut rng = Rng::new(4242);
    let mut w = vec![0f32; out_d * in_d];
    rng.fill_normal(&mut w, 0.0, 0.05);
    let mut x = vec![0f32; in_d];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut xs = vec![0f32; n_tok * in_d];
    rng.fill_normal(&mut xs, 0.0, 1.0);

    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mv_flops = 2.0 * (out_d * in_d) as f64;
    let act_bytes = 4.0 * (out_d + in_d) as f64;

    for bits in [2u32, 3, 4] {
        let sch = QuantScheme::new(bits, group as u32);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)?;
        let w_bytes = (out_d * in_d) as f64 * bits as f64 / 8.0;

        let (row, jrow) = kernel_row(
            &format!("matvec_b{bits}"), isa, iters, w_bytes + act_bytes,
            mv_flops, || {
                let mut y = vec![0f32; out_d];
                pl.matvec(&x, &mut y);
                y
            })?;
        rows.push(row);
        jrows.push(jrow);

        let (row, jrow) = kernel_row(
            &format!("matmul_b{bits}"), isa, iters,
            w_bytes + n_tok as f64 * act_bytes, n_tok as f64 * mv_flops,
            || {
                let mut ys = vec![0f32; n_tok * out_d];
                pl.matmul(&xs, n_tok, &mut ys);
                ys
            })?;
        rows.push(row);
        jrows.push(jrow);
    }

    let (row, jrow) = kernel_row(
        "dense_matvec", isa, iters,
        4.0 * (out_d * in_d) as f64 + act_bytes, mv_flops, || {
            let mut y = vec![0f32; out_d];
            dense_matvec(&w, out_d, in_d, &x, &mut y);
            y
        })?;
    rows.push(row);
    jrows.push(jrow);

    let gpr = out_d * (in_d / group);
    let mut gout = vec![0f32; out_d * in_d];
    rng.fill_normal(&mut gout, 0.0, 1.0);
    let mut s = vec![0f32; gpr];
    let mut z = vec![0f32; gpr];
    for v in s.iter_mut() {
        *v = 0.05 + 0.2 * rng.f32();
    }
    for v in z.iter_mut() {
        *v = rng.below(4) as f32;
    }
    let (row, jrow) = kernel_row(
        "fq_grads", isa, iters, 3.0 * 4.0 * (out_d * in_d) as f64,
        4.0 * (out_d * in_d) as f64, || {
            let mut gw = vec![0f32; out_d * in_d];
            let mut gs = vec![0f32; gpr];
            let mut gz = vec![0f32; gpr];
            ops::fake_quant_grads(&w, out_d, in_d, &s, &z, group, 3.0,
                                  &gout, &mut gw, &mut gs, &mut gz);
            gw.extend_from_slice(&gs);
            gw.extend_from_slice(&gz);
            gw
        })?;
    rows.push(row);
    jrows.push(jrow);

    crate::info!("kernels bench done (isa {})", isa.name());
    let md = format!(
        "## Kernel layer - scalar vs SIMD ({}; bit-identical outputs \
         asserted per row)\n\n{}",
        isa.name(),
        crate::exp::md_table(
            &["Kernel", "scalar us", "SIMD us", "scalar GB/s",
              "SIMD GB/s", "scalar GF/s", "SIMD GF/s", "speedup"],
            &rows)
    );
    let j = Json::obj(vec![
        ("isa", Json::str(isa.name())),
        ("rows", Json::arr(jrows)),
    ]);
    Ok((md, j))
}

/// Cross-request prefix cache: N personas x M users sharing system
/// prompts through the radix cache over the paged KV pool. Three gates
/// run before any number is published: (1) cache-hit resumed prefill
/// produces bit-identical last-token logits to a cold full prefill of
/// the same prompt; (2) at the scheduler level every user request hits,
/// copies zero bytes (page sharing is pure refcounting), and emits the
/// same greedy tokens as a cache-off scheduler; (3) an eviction-churn
/// run over distinct prompts on a tiny pool evicts (> 0) and still
/// drains to zero pages after the flush. The published numbers are the
/// hit rate, prefill tokens avoided, and first-token latency
/// percentiles hit-vs-cold. Schema-8 `prefix_cache` section of
/// runs/bench.json.
pub fn prefix_cache_throughput(fast: bool) -> Result<(String, Json)> {
    let (dim, nh, hd, inter, vocab) = if fast {
        (256usize, 4usize, 64usize, 512usize, 1024usize)
    } else {
        (1024, 8, 128, 2816, 4096)
    };
    let n_layers = 1usize;
    let sys_len = if fast { 24usize } else { 48 };
    let users = if fast { 3usize } else { 5 };
    let personas = 3usize;
    let suffix_len = 2usize;
    let max_new = 6usize;
    let page_rows = 8usize;
    let max_ctx = sys_len + 16;
    let per_seq = (max_ctx + page_rows - 1) / page_rows;
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, QuantScheme::new(2, 128),
        max_ctx, 4444)?);
    let prompt = |p: usize, u: usize| -> Vec<i32> {
        let mut t: Vec<i32> = (0..sys_len)
            .map(|k| ((k * 11 + p * 29 + 5) % vocab) as i32)
            .collect();
        t.extend((0..suffix_len)
            .map(|k| ((u * 7 + k * 13 + 3) % vocab) as i32));
        t
    };

    // gate 1: hit-resumed prefill logits are bit-identical to a cold
    // full prefill of the same prompt (KV rows are a pure function of
    // the token prefix at absolute positions)
    {
        let mut pool =
            KvPool::for_core_paged(&core, 2 * per_seq, page_rows);
        pool.enable_prefix_cache();
        let mut sc = core.scratch();
        let p = prompt(0, 0);
        let plen = p.len();
        let cold = pool.lease_rows(plen).expect("2-seq pool");
        let mut cold_logits = Vec::new();
        core.forward_logits(&mut pool, &cold, 0, &p, &mut sc,
                            &mut cold_logits)?;
        let inserted = pool.cache_insert(&p, &cold)?;
        ensure!(inserted > 0, "prefix_cache bench: nothing cached");
        pool.release(cold);
        let (hit, matched) = pool
            .lease_rows_cached(&p[..plen - 1], plen)
            .expect("hit lease");
        ensure!(matched > 0 && matched % page_rows == 0,
                "prefix_cache bench: match not page-aligned ({matched})");
        let mut hit_logits = Vec::new();
        core.forward_logits(&mut pool, &hit, matched, &p[matched..],
                            &mut sc, &mut hit_logits)?;
        pool.release(hit);
        let a = &cold_logits[(plen - 1) * vocab..];
        let b = &hit_logits[(plen - matched - 1) * vocab..];
        ensure!(
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "prefix_cache bench: hit logits diverge from cold prefill");
        let flushed = pool.cache_flush();
        ensure!(flushed == inserted && pool.pages_in_use() == 0,
                "prefix_cache bench: gate-1 pool did not drain");
    }

    // gate 2 + timing: warm the cache with one request per persona,
    // then serve M user requests per persona and compare first-token
    // latency against a cache-off scheduler running the same requests
    let mk_sched = |cache: bool| -> Scheduler {
        let pool = KvPool::for_core_paged(&core, 4 * per_seq, page_rows);
        Scheduler::with_clock(
            core.clone(), pool,
            SchedConfig {
                max_batch: 4,
                prefill_chunk: sys_len + suffix_len,
                prefix_cache: cache,
                ..SchedConfig::default()
            },
            Clock::wall())
    };
    let m = personas * users;
    let reqs: Vec<Request> = (0..m)
        .map(|i| Request::new(prompt(i % personas, i), max_new,
                              Sampler::Greedy, 7000 + i as u64))
        .collect();

    let mut hot = mk_sched(true);
    for p in 0..personas {
        hot.submit(Request::new(prompt(p, 900 + p), max_new,
                                Sampler::Greedy, 6000 + p as u64))?;
    }
    let warm = hot.run_all()?;
    ensure!(warm.iter().all(|c| c.finish.is_ok()),
            "prefix_cache bench: warm-up request failed");
    let cached_after_warm = hot.pool().cached_pages();
    ensure!(cached_after_warm > 0,
            "prefix_cache bench: warm-up cached nothing");

    let b0 = hot.pool().bytes_copied();
    for r in &reqs {
        hot.submit(r.clone())?;
    }
    let t0 = Instant::now();
    let mut hit_comps = hot.run_all()?;
    let hit_secs = t0.elapsed().as_secs_f64();
    let st = hot.stats();
    ensure!(st.cache_hits == m as u64,
            "prefix_cache bench: {} hits, wanted {m}", st.cache_hits);
    ensure!(st.cache_misses == personas as u64,
            "prefix_cache bench: {} misses, wanted {personas}",
            st.cache_misses);
    ensure!(st.tokens_prefill_avoided >= (m * sys_len) as u64,
            "prefix_cache bench: only {} prefill tokens avoided",
            st.tokens_prefill_avoided);
    ensure!(hot.pool().bytes_copied() == b0,
            "prefix_cache bench: cache hits copied bytes (COW on a \
             shared page)");

    let mut cold_s = mk_sched(false);
    for r in &reqs {
        cold_s.submit(r.clone())?;
    }
    let t1 = Instant::now();
    let mut cold_comps = cold_s.run_all()?;
    let cold_secs = t1.elapsed().as_secs_f64();
    ensure!(cold_s.stats().cache_hits == 0);
    hit_comps.sort_by_key(|c| c.id);
    cold_comps.sort_by_key(|c| c.id);
    ensure!(hit_comps.len() == m && cold_comps.len() == m);
    for (h, c) in hit_comps.iter().zip(&cold_comps) {
        ensure!(h.tokens == c.tokens,
                "prefix_cache bench: hit-path greedy tokens diverge \
                 from the cache-off scheduler");
    }
    let hit_firsts: Vec<f64> =
        hit_comps.iter().map(|c| c.first_token_secs * 1e3).collect();
    let cold_firsts: Vec<f64> =
        cold_comps.iter().map(|c| c.first_token_secs * 1e3).collect();
    let p50_hit = percentile(&hit_firsts, 50.0);
    let p95_hit = percentile(&hit_firsts, 95.0);
    let p50_cold = percentile(&cold_firsts, 50.0);
    let p95_cold = percentile(&cold_firsts, 95.0);
    ensure!(p50_hit < p50_cold,
            "prefix_cache bench: hit first-token p50 {p50_hit:.3}ms not \
             below cold {p50_cold:.3}ms");
    let flushed = hot.flush_prefix_cache();
    ensure!(flushed > 0 && hot.pool().pages_in_use() == 0,
            "prefix_cache bench: hot scheduler leaked pages");
    ensure!(cold_s.pool().pages_in_use() == 0);

    // gate 3: eviction churn - distinct prompts on a tiny pool must
    // evict cold cache pages and still drain to zero
    let churn_reqs = 10usize;
    let mut churn = Scheduler::with_clock(
        core.clone(),
        KvPool::for_core_paged(&core, 2 * per_seq, page_rows),
        SchedConfig {
            max_batch: 2,
            prefill_chunk: sys_len,
            prefix_cache: true,
            ..SchedConfig::default()
        },
        Clock::wall());
    for i in 0..churn_reqs {
        let p: Vec<i32> = (0..sys_len)
            .map(|k| ((i * 17 + k * 5 + 1) % vocab) as i32)
            .collect();
        churn.submit(Request::new(p, 4, Sampler::Greedy,
                                  8000 + i as u64))?;
    }
    let churn_comps = churn.run_all()?;
    ensure!(churn_comps.len() == churn_reqs
                && churn_comps.iter().all(|c| c.finish.is_ok()),
            "prefix_cache bench: churn request failed");
    let evictions = churn.stats().cache_evictions;
    ensure!(evictions > 0,
            "prefix_cache bench: churn run never evicted");
    churn.flush_prefix_cache();
    ensure!(churn.pool().pages_in_use() == 0,
            "prefix_cache bench: churn run leaked pages");

    let hit_rate = st.cache_hits as f64
        / (st.cache_hits + st.cache_misses).max(1) as f64;
    let avoided = st.tokens_prefill_avoided;
    let speedup = p50_cold / p50_hit.max(1e-9);
    crate::info!("prefix_cache bench: {m} hits at {:.0}% hit rate, \
                  {avoided} prefill tokens avoided, first token \
                  {p50_hit:.2}ms hit vs {p50_cold:.2}ms cold \
                  ({speedup:.2}x)", hit_rate * 100.0);

    let rows = vec![
        vec!["config".into(),
             format!("dim {dim}, {n_layers} block(s), {personas} \
                      personas x {users} users, {sys_len}-token system \
                      prompts over {page_rows}-row pages")],
        vec!["hit rate (after warm-up)".into(),
             format!("{}/{} ({:.0}%)", st.cache_hits,
                     st.cache_hits + st.cache_misses, hit_rate * 100.0)],
        vec!["prefill tokens avoided".into(), format!("{avoided}")],
        vec!["bytes copied on hits".into(), "0 B (asserted)".into()],
        vec!["first token, cache hit".into(),
             format!("p50 {p50_hit:.2}ms  p95 {p95_hit:.2}ms")],
        vec!["first token, cold".into(),
             format!("p50 {p50_cold:.2}ms  p95 {p95_cold:.2}ms")],
        vec!["first-token speedup (p50)".into(),
             format!("{speedup:.2}x")],
        vec!["batch walltime hit vs cold".into(),
             format!("{:.1}ms vs {:.1}ms", hit_secs * 1e3,
                     cold_secs * 1e3)],
        vec!["eviction churn".into(),
             format!("{evictions} evictions, 0 pages leaked")],
    ];
    let md = format!(
        "## Cross-request prefix cache - shared system prompts served \
         by refcount (hit logits bit-identical to cold prefill, \
         asserted)\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );
    let j = Json::obj(vec![
        ("page_rows", Json::num(page_rows as f64)),
        ("personas", Json::num(personas as f64)),
        ("users", Json::num(m as f64)),
        ("sys_tokens", Json::num(sys_len as f64)),
        ("hits", Json::num(st.cache_hits as f64)),
        ("misses", Json::num(st.cache_misses as f64)),
        ("hit_rate", Json::num(hit_rate)),
        ("tokens_prefill_avoided", Json::num(avoided as f64)),
        ("evictions", Json::num(evictions as f64)),
        ("first_token_p50_hit_ms", Json::num(p50_hit)),
        ("first_token_p95_hit_ms", Json::num(p95_hit)),
        ("first_token_p50_cold_ms", Json::num(p50_cold)),
        ("first_token_p95_cold_ms", Json::num(p95_cold)),
        ("prefill_speedup", Json::num(speedup)),
        ("hit_fork_bytes", Json::num(0.0)),
        ("bitexact", Json::Bool(true)),
        ("leaked_pages", Json::num(0.0)),
    ]);
    Ok((md, j))
}

/// Low-bit KV serving: capacity, bandwidth, goodput, and accuracy of
/// the packed int8/int4 page formats against the f32 pool. Four gates
/// run before any number is published: (1) at an identical pool byte
/// budget the int4 pool leases >= 3.5x the concurrent sequences of the
/// f32 pool (lease-until-full on both); (2) every fused dequant+dot /
/// dequant+axpy kernel row asserts scalar-vs-SIMD bit identity before
/// its GB/s is recorded; (3) the int4 open-loop run reproduces its
/// lifecycle digest bit-for-bit across forced-scalar and the detected
/// ISA, and the f32 run is equally ISA-invariant and run-to-run
/// deterministic (pinning the fp serve path the low-bit mode must not
/// perturb); (4) the synthetic teacher-forced ppl delta vs the f32
/// pool stays under the same gates the unit tests enforce (int8 5%,
/// int4 25% relative). Schema-9 `kv_lowbit` section of runs/bench.json.
pub fn kv_lowbit_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::infer::openloop::{run_open_loop, OpenLoopCfg};

    let (dim, nh, hd, inter, vocab, n_layers) = if fast {
        (64usize, 4usize, 16usize, 128usize, 256usize, 1usize)
    } else {
        (256, 4, 64, 512, 1024, 2)
    };
    let page_rows = 8usize;
    let prompt_len = 8usize;
    let max_new = 8usize;
    let max_ctx = prompt_len + max_new + 4;
    let per_seq = (max_ctx + page_rows - 1) / page_rows;
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, QuantScheme::new(2, 128),
        max_ctx, 4545)?);

    // gate 1: capacity at an identical pool byte budget. Size each
    // packed pool to at most the f32 pool's bytes, then lease whole
    // sequences until each pool refuses.
    let page_bytes_of = |fmt: KvFormat| -> u64 {
        KvPool::for_core_paged_fmt(&core, 1, page_rows, fmt).page_bytes()
    };
    let fp_pb = page_bytes_of(KvFormat::F32);
    let i8_pb = page_bytes_of(KvFormat::Int8);
    let i4_pb = page_bytes_of(KvFormat::Int4);
    let fp_pages = 8 * per_seq;
    let budget = fp_pb * fp_pages as u64;
    let i8_pages = (budget / i8_pb) as usize;
    let i4_pages = (budget / i4_pb) as usize;
    ensure!(i4_pb * i4_pages as u64 <= budget
                && i8_pb * i8_pages as u64 <= budget,
            "kv_lowbit bench: packed pool sized over the byte budget");
    let seqs_at_budget = |fmt: KvFormat, n_pages: usize|
                         -> Result<usize> {
        let mut pool =
            KvPool::for_core_paged_fmt(&core, n_pages, page_rows, fmt);
        let mut held = Vec::new();
        while let Some(l) = pool.lease_rows(max_ctx) {
            held.push(l);
        }
        let n = held.len();
        for l in held {
            pool.release(l);
        }
        ensure!(pool.pages_in_use() == 0,
                "kv_lowbit bench: {fmt:?} capacity probe leaked pages");
        Ok(n)
    };
    let fp_seqs = seqs_at_budget(KvFormat::F32, fp_pages)?;
    let i8_seqs = seqs_at_budget(KvFormat::Int8, i8_pages)?;
    let i4_seqs = seqs_at_budget(KvFormat::Int4, i4_pages)?;
    ensure!(fp_seqs > 0, "kv_lowbit bench: f32 pool admitted nothing");
    let mult8 = i8_seqs as f64 / fp_seqs as f64;
    let mult4 = i4_seqs as f64 / fp_seqs as f64;
    ensure!(mult4 >= 3.5,
            "kv_lowbit bench: int4 capacity multiplier {mult4:.2}x \
             below the 3.5x gate ({i4_seqs} vs {fp_seqs} sequences at \
             {budget} B)");

    // gate 2: fused dequant kernel rows, scalar-vs-SIMD bit identity
    // asserted per row by kernel_row before GB/s is recorded
    let n = if fast { 2048usize } else { 8192 };
    let iters = if fast { 5 } else { 20 };
    let isa = simd::detected();
    let mut rng = Rng::new(4646);
    let mut qh = vec![0f32; n];
    rng.fill_normal(&mut qh, 0.0, 1.0);
    let w4: Vec<u32> =
        (0..n / 8).map(|_| rng.next_u64() as u32).collect();
    let w8: Vec<u32> =
        (0..n / 4).map(|_| rng.next_u64() as u32).collect();
    let mut krows = Vec::new();
    let mut kjson = Vec::new();
    let flops = 2.0 * n as f64;
    let act_bytes = 4.0 * n as f64;
    let (row, jrow) = kernel_row(
        "kv_dot_q4", isa, iters, n as f64 / 2.0 + act_bytes, flops,
        || vec![simd::kv_dot_q4(&qh, &w4)])?;
    krows.push(row);
    kjson.push(jrow);
    let (row, jrow) = kernel_row(
        "kv_dot_q8", isa, iters, n as f64 + act_bytes, flops,
        || vec![simd::kv_dot_q8(&qh, &w8)])?;
    krows.push(row);
    kjson.push(jrow);
    let (row, jrow) = kernel_row(
        "kv_axpy_q4", isa, iters, n as f64 / 2.0 + act_bytes, flops,
        || {
            let mut y = vec![0f32; n];
            simd::kv_axpy_q4(&mut y, 1.25, -0.5, &w4);
            y
        })?;
    krows.push(row);
    kjson.push(jrow);
    let (row, jrow) = kernel_row(
        "kv_axpy_q8", isa, iters, n as f64 + act_bytes, flops,
        || {
            let mut y = vec![0f32; n];
            simd::kv_axpy_q8(&mut y, 1.25, -0.5, &w8);
            y
        })?;
    krows.push(row);
    kjson.push(jrow);

    // gate 3: open-loop goodput at a fixed pool byte budget. The int4
    // run gets the slot count that fits the f32 run's bytes; it must
    // reproduce its digest across forced-scalar and the detected ISA,
    // and the fp run must be equally deterministic and ISA-invariant.
    let requests = if fast { 24 } else { 48 };
    let fp_slots = 2usize;
    let ol_budget = fp_pb * (fp_slots * per_seq) as u64;
    let i4_slots = (ol_budget / i4_pb) as usize / per_seq;
    ensure!(i4_slots > fp_slots,
            "kv_lowbit bench: int4 slot budget {i4_slots} not above fp \
             {fp_slots}");
    let fp_cfg = OpenLoopCfg {
        requests,
        rate: 120.0,
        tick_secs: 0.005,
        prompt_len,
        max_new,
        deadline_secs: 0.4,
        seed: 17,
        slots: fp_slots,
        max_batch: fp_slots,
        prefill_chunk: prompt_len,
        max_queue: requests,
        fault_rate: 0.0,
        personas: 0,
        page_rows,
        prefix_cache: false,
        kv_bits: 16,
        ..OpenLoopCfg::default()
    };
    let i4_cfg = OpenLoopCfg {
        slots: i4_slots,
        max_batch: i4_slots,
        kv_bits: 4,
        ..fp_cfg
    };
    let fp_a = run_open_loop(core.clone(), &fp_cfg)?;
    let fp_b = run_open_loop(core.clone(), &fp_cfg)?;
    ensure!(fp_a == fp_b,
            "kv_lowbit bench: fp open-loop run not deterministic");
    let fp_s =
        simd::with_isa(Isa::Scalar, || run_open_loop(core.clone(),
                                                     &fp_cfg))?;
    ensure!(fp_a == fp_s,
            "kv_lowbit bench: fp digest diverges between scalar and {}",
            isa.name());
    let i4_a =
        simd::with_isa(Isa::Scalar, || run_open_loop(core.clone(),
                                                     &i4_cfg))?;
    let i4_b =
        simd::with_isa(isa, || run_open_loop(core.clone(), &i4_cfg))?;
    ensure!(i4_a == i4_b,
            "kv_lowbit bench: int4 digest diverges between scalar and \
             {}", isa.name());
    ensure!(fp_a.kv_bits == 32 && i4_a.kv_bits == 4,
            "kv_lowbit bench: effective kv_bits wrong");
    ensure!(fp_a.leaked_pages == 0 && i4_a.leaked_pages == 0,
            "kv_lowbit bench: open-loop run leaked pages");
    ensure!(i4_a.pool_bytes <= fp_a.pool_bytes,
            "kv_lowbit bench: int4 pool {} B over the fp budget {} B",
            i4_a.pool_bytes, fp_a.pool_bytes);
    ensure!(i4_a.goodput >= fp_a.goodput && fp_a.goodput > 0,
            "kv_lowbit bench: int4 goodput {} below fp {} at the same \
             byte budget", i4_a.goodput, fp_a.goodput);
    let goodput_mult = i4_a.goodput as f64 / fp_a.goodput as f64;

    // gate 4: synthetic teacher-forced ppl delta vs the f32 pool on
    // the same tiny core and gates the core unit tests pin (the bench
    // records the deltas the tests only bound)
    let pvocab = 96usize;
    let pc = Arc::new(ModelCore::synthetic(
        32, 4, 8, 64, pvocab, 2, QuantScheme::new(2, 32), 24, 35)?);
    let tf_ppl = |pool: &mut KvPool| -> Result<f64> {
        let seq: Vec<i32> =
            (0..20).map(|i| ((i * 3 + 5) % pvocab) as i32).collect();
        let mut sc = pc.scratch();
        let Some(l) = pool.lease() else {
            bail!("kv_lowbit bench: ppl pool too small");
        };
        let mut out = Vec::new();
        pc.forward_logits(pool, &l, 0, &seq, &mut sc, &mut out)?;
        let mut nll = 0f64;
        for t in 0..seq.len() - 1 {
            let row = &out[t * pvocab..(t + 1) * pvocab];
            let mx =
                row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f64 =
                row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
            nll += z.ln() - (row[seq[t + 1] as usize] - mx) as f64;
        }
        pool.release(l);
        Ok((nll / (seq.len() - 1) as f64).exp())
    };
    let mut ppl_pool = KvPool::for_core_fmt(&pc, 1, KvFormat::F32);
    let ppl_fp = tf_ppl(&mut ppl_pool)?;
    let mut ppl_pool = KvPool::for_core_fmt(&pc, 1, KvFormat::Int8);
    let ppl_i8 = tf_ppl(&mut ppl_pool)?;
    let mut ppl_pool = KvPool::for_core_fmt(&pc, 1, KvFormat::Int4);
    let ppl_i4 = tf_ppl(&mut ppl_pool)?;
    ensure!(ppl_fp.is_finite() && ppl_i8.is_finite()
                && ppl_i4.is_finite(),
            "kv_lowbit bench: non-finite ppl");
    let d8 = (ppl_i8 - ppl_fp).abs() / ppl_fp;
    let d4 = (ppl_i4 - ppl_fp).abs() / ppl_fp;
    let (gate8, gate4) = (0.05f64, 0.25f64);
    ensure!(d8 < gate8,
            "kv_lowbit bench: int8 ppl delta {d8:.4} over the {gate8} \
             gate (ppl {ppl_i8:.4} vs fp {ppl_fp:.4})");
    ensure!(d4 < gate4,
            "kv_lowbit bench: int4 ppl delta {d4:.4} over the {gate4} \
             gate (ppl {ppl_i4:.4} vs fp {ppl_fp:.4})");

    crate::info!("kv_lowbit bench: int4 {mult4:.2}x / int8 {mult8:.2}x \
                  capacity at {budget} B; goodput {} vs {} at fixed \
                  bytes ({goodput_mult:.2}x); ppl delta int4 {d4:.4} \
                  int8 {d8:.4}", i4_a.goodput, fp_a.goodput);

    let rows = vec![
        vec!["config".into(),
             format!("dim {dim}, {n_layers} block(s), {page_rows}-row \
                      pages, {max_ctx}-row sequences, budget {budget} \
                      B")],
        vec!["sequences at budget (f32)".into(), format!("{fp_seqs}")],
        vec!["sequences at budget (int8)".into(),
             format!("{i8_seqs} ({mult8:.2}x)")],
        vec!["sequences at budget (int4)".into(),
             format!("{i4_seqs} ({mult4:.2}x, gate >= 3.5x)")],
        vec!["open-loop goodput at fixed bytes".into(),
             format!("int4 {}/{} vs f32 {}/{} ({goodput_mult:.2}x; \
                      {} vs {} B pool)",
                     i4_a.goodput, i4_a.arrivals, fp_a.goodput,
                     fp_a.arrivals, i4_a.pool_bytes, fp_a.pool_bytes)],
        vec![format!("int4 digest (scalar == {})", isa.name()),
             format!("{:016x}", i4_a.digest)],
        vec!["ppl delta vs f32".into(),
             format!("int8 {d8:.4} (gate {gate8}), int4 {d4:.4} (gate \
                      {gate4})")],
    ];
    let md = format!(
        "## Low-bit KV pages - packed int8/int4 capacity, fused-dequant \
         bandwidth, goodput at fixed pool bytes, ppl delta (3.5x \
         capacity, ISA bit-identity, and ppl gates asserted)\n\n{}\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows),
        crate::exp::md_table(
            &["Kernel", "scalar us", "SIMD us", "scalar GB/s",
              "SIMD GB/s", "scalar GF/s", "SIMD GF/s", "speedup"],
            &krows)
    );
    let j = Json::obj(vec![
        ("page_rows", Json::num(page_rows as f64)),
        ("fp_page_bytes", Json::num(fp_pb as f64)),
        ("int8_page_bytes", Json::num(i8_pb as f64)),
        ("int4_page_bytes", Json::num(i4_pb as f64)),
        ("pool_budget_bytes", Json::num(budget as f64)),
        ("fp_seqs", Json::num(fp_seqs as f64)),
        ("int8_seqs", Json::num(i8_seqs as f64)),
        ("int4_seqs", Json::num(i4_seqs as f64)),
        ("capacity_multiplier_int8", Json::num(mult8)),
        ("capacity_multiplier_int4", Json::num(mult4)),
        ("kernels", Json::arr(kjson)),
        ("goodput_fp", Json::num(fp_a.goodput as f64)),
        ("goodput_int4", Json::num(i4_a.goodput as f64)),
        ("goodput_multiplier", Json::num(goodput_mult)),
        ("tokens_fp", Json::num(fp_a.total_tokens as f64)),
        ("tokens_int4", Json::num(i4_a.total_tokens as f64)),
        ("openloop_pool_bytes_fp", Json::num(fp_a.pool_bytes as f64)),
        ("openloop_pool_bytes_int4", Json::num(i4_a.pool_bytes as f64)),
        ("digest_int4", Json::str(format!("{:016x}", i4_a.digest))),
        ("ppl_fp", Json::num(ppl_fp)),
        ("ppl_int8", Json::num(ppl_i8)),
        ("ppl_int4", Json::num(ppl_i4)),
        ("ppl_rel_delta_int8", Json::num(d8)),
        ("ppl_rel_delta_int4", Json::num(d4)),
        ("ppl_gate_int8", Json::num(gate8)),
        ("ppl_gate_int4", Json::num(gate4)),
        ("lowbit_deterministic", Json::Bool(true)),
        ("fp_bitexact", Json::Bool(true)),
        ("leaked_pages", Json::num(0.0)),
    ]);
    Ok((md, j))
}

/// Paged-KV fork cost: zero-copy prefix-shared forks vs the deep-copy
/// fork the slab pool used to do, plus zeroshot-style candidate scoring
/// throughput over both paths (N options scored off one prefilled
/// prefix). Before timing, the bench *asserts* the paging contracts:
/// a plain fork copies zero bytes, continuing from it COWs at most one
/// page, and shared-prefix scoring logits are bit-identical to
/// copy-fork scoring. Schema-5 `kv_fork` section of runs/bench.json.
pub fn kv_fork_throughput(fast: bool) -> Result<(String, Json)> {
    let (dim, nh, hd, inter, vocab, n_layers) = if fast {
        (64usize, 4usize, 16usize, 128usize, 256usize, 2usize)
    } else {
        (256, 4, 64, 512, 1024, 2)
    };
    let prefix_len = if fast { 96 } else { 192 };
    let opt_len = 4usize;
    let n_opts = if fast { 4 } else { 8 };
    let scoring_reps = if fast { 3 } else { 10 };
    let max_ctx = prefix_len + opt_len + 4;
    let sch = QuantScheme::new(2, 32);
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, sch, max_ctx, 77)?);
    let mut pool = KvPool::for_core(&core, 2);
    let page_rows = pool.page_rows();
    ensure!(prefix_len > page_rows,
            "kv_fork bench prefix must span multiple pages");
    let mut sc = core.scratch();
    let prefix: Vec<i32> =
        (0..prefix_len).map(|i| ((i * 13 + 7) % vocab) as i32).collect();
    let parent = pool.lease().expect("2-sequence pool");
    core.prefill(&mut pool, &parent, 0, &prefix, &mut sc)?;
    let prefix_pages = pool.seq_pages(&parent);

    // fork/release latency: zero-copy share vs deep copy of the prefix
    let fork_iters = if fast { 200 } else { 500 };
    let b0 = pool.bytes_copied();
    let r_fork = bench("kv-fork", 5, fork_iters, || {
        let f = pool.fork(&parent, prefix_len).unwrap();
        pool.release(f);
    });
    ensure!(pool.bytes_copied() == b0,
            "kv_fork bench: plain fork copied bytes");
    let copy_iters = if fast { 50 } else { 100 };
    let b1 = pool.bytes_copied();
    let r_copy = bench("kv-fork-copy", 2, copy_iters, || {
        let f = pool.fork_copy(&parent, prefix_len).unwrap();
        pool.release(f);
    });
    let copy_bytes_per_fork = (pool.bytes_copied() - b1)
        / (copy_iters + 2) as u64;

    // zeroshot-style scoring: N candidate continuations off the shared
    // prefix, prefix-shared forks vs deep-copy forks, bit-equal logits
    let opts: Vec<Vec<i32>> = (0..n_opts)
        .map(|o| {
            (0..opt_len)
                .map(|t| ((3 + o * 7 + t * 11) % vocab) as i32)
                .collect()
        })
        .collect();
    let mut buf = Vec::new();
    let mut shared_logits: Vec<Vec<f32>> = Vec::new();
    let b2 = pool.bytes_copied();
    let t0 = Instant::now();
    for rep in 0..scoring_reps {
        for opt in &opts {
            let f = pool.fork(&parent, prefix_len).unwrap();
            let r = core.forward_logits(&mut pool, &f, prefix_len, opt,
                                        &mut sc, &mut buf);
            pool.release(f);
            r?;
            if rep == 0 {
                shared_logits.push(buf.clone());
            }
        }
    }
    let shared_secs = t0.elapsed().as_secs_f64();
    let cow_bytes_per_fork = (pool.bytes_copied() - b2)
        / (scoring_reps * n_opts) as u64;
    ensure!(cow_bytes_per_fork <= pool.page_bytes(),
            "kv_fork bench: COW copied more than one page per fork");

    let mut copy_logits: Vec<Vec<f32>> = Vec::new();
    let t1 = Instant::now();
    for rep in 0..scoring_reps {
        for opt in &opts {
            let f = pool.fork_copy(&parent, prefix_len).unwrap();
            let r = core.forward_logits(&mut pool, &f, prefix_len, opt,
                                        &mut sc, &mut buf);
            pool.release(f);
            r?;
            if rep == 0 {
                copy_logits.push(buf.clone());
            }
        }
    }
    let copy_secs = t1.elapsed().as_secs_f64();
    for (oi, (a, b)) in
        shared_logits.iter().zip(&copy_logits).enumerate()
    {
        ensure!(
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "kv_fork bench: shared-prefix scoring logits diverge from \
             copy-fork scoring (option {oi})"
        );
    }
    pool.release(parent);

    let n_tok = (scoring_reps * n_opts * opt_len) as f64;
    let shared_tps = n_tok / shared_secs.max(1e-9);
    let copy_tps = n_tok / copy_secs.max(1e-9);
    let speedup = shared_tps / copy_tps.max(1e-9);
    crate::info!("kv_fork bench: fork {:.2}us vs copy-fork {:.2}us; \
                  scoring {shared_tps:.0} vs {copy_tps:.0} tok/s \
                  ({speedup:.2}x)",
                 r_fork.mean_us, r_copy.mean_us);

    let rows = vec![
        vec!["config".into(),
             format!("dim {dim}, {n_layers} blocks, {prefix_len}-token \
                      prefix over {prefix_pages} pages of {page_rows} \
                      rows, {n_opts} options x {opt_len} tok")],
        vec!["fork (zero-copy share)".into(),
             format!("{:.2} us, 0 B copied", r_fork.mean_us)],
        vec!["fork (deep copy)".into(),
             format!("{:.2} us, {copy_bytes_per_fork} B copied",
                     r_copy.mean_us)],
        vec!["scoring, prefix-shared".into(),
             format!("{shared_tps:.0} tok/s ({cow_bytes_per_fork} B \
                      COW/fork)")],
        vec!["scoring, copy-fork".into(),
             format!("{copy_tps:.0} tok/s")],
        vec!["scoring speedup".into(), format!("{speedup:.2}x")],
    ];
    let md = format!(
        "## Paged KV - zero-copy fork vs deep copy (scoring logits \
         bit-identical across both paths, asserted)\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );
    let j = Json::obj(vec![
        ("dim", Json::num(dim as f64)),
        ("n_layers", Json::num(n_layers as f64)),
        ("page_rows", Json::num(page_rows as f64)),
        ("page_bytes", Json::num(pool.page_bytes() as f64)),
        ("prefix_rows", Json::num(prefix_len as f64)),
        ("prefix_pages", Json::num(prefix_pages as f64)),
        ("n_options", Json::num(n_opts as f64)),
        ("option_tokens", Json::num(opt_len as f64)),
        ("fork_us", Json::num(r_fork.mean_us)),
        ("fork_bytes_copied", Json::num(0.0)),
        ("fork_copy_us", Json::num(r_copy.mean_us)),
        ("fork_copy_bytes_copied", Json::num(copy_bytes_per_fork as f64)),
        ("cow_bytes_per_fork", Json::num(cow_bytes_per_fork as f64)),
        ("shared_tok_per_sec", Json::num(shared_tps)),
        ("copy_tok_per_sec", Json::num(copy_tps)),
        ("speedup", Json::num(speedup)),
    ]);
    Ok((md, j))
}

/// Multi-sequence serving throughput: the continuous-batching scheduler
/// (one rows-parallel matmul per linear per tick across the batch) vs
/// sequential per-request decode on a solo engine, at batch 1/4/8, with
/// per-token and first-token latency percentiles. Before timing, the
/// bench *asserts* the serving determinism contract: scheduler logits
/// (and greedy outputs) are bit-identical to solo-engine runs of the
/// same prompts. `serve` section of runs/bench.json (schema >= 4).
pub fn serve_throughput(fast: bool) -> Result<(String, Json)> {
    let (dim, nh, hd, inter, vocab, n_layers) = if fast {
        (256usize, 4usize, 64usize, 512usize, 1024usize, 1usize)
    } else {
        (1024, 8, 128, 2816, 4096, 1)
    };
    let prompt_len = if fast { 8 } else { 16 };
    let max_new = if fast { 12 } else { 24 };
    let max_ctx = prompt_len + max_new + 4;
    let sch = QuantScheme::new(2, 128);
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, sch, max_ctx, 4242)?);
    let mk_prompt = |i: usize| -> Vec<i32> {
        (0..prompt_len)
            .map(|t| ((t * 37 + 11 * (i + 1)) % vocab) as i32)
            .collect()
    };

    // determinism gate 1: one batched decode step over sequences at
    // staggered positions is bit-identical to solo engine steps
    {
        let mut pool = KvPool::for_core(&core, 3);
        let mut sc = core.scratch();
        let mut leases = Vec::new();
        let mut poss = Vec::new();
        for i in 0..3usize {
            let p = mk_prompt(i);
            let p = &p[..p.len() - i]; // staggered lengths
            let l = pool.lease().unwrap();
            core.prefill(&mut pool, &l, 0, p, &mut sc)?;
            leases.push(l);
            poss.push(p.len());
        }
        let batch: Vec<(&KvLease, usize)> =
            leases.iter().zip(&poss).map(|(l, &p)| (l, p)).collect();
        core.decode_batch(&mut pool, &batch, &[5, 6, 7], &mut sc)?;
        drop(batch);
        for i in 0..3usize {
            let mut solo = Engine::from_core(core.clone());
            let p = mk_prompt(i);
            solo.prefill(&p[..p.len() - i])?;
            let want = solo.step(5 + i as i32)?;
            ensure!(
                sc.batch_logits(i)
                    .iter()
                    .zip(&want)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "serve bench: decode_batch logits diverge from solo \
                 engine at row {i}"
            );
        }
    }

    let mut rows = vec![vec![
        "config".into(),
        format!("dim {dim}, inter {inter}, vocab {vocab}, {n_layers} \
                 block(s), w2g128, {prompt_len}+{max_new} tok/req"),
    ]];
    let mut jbatches = Vec::new();
    let mut speedup8 = 0f64;
    for &bsz in &[1usize, 4, 8] {
        // batched: one scheduler, bsz slots, all requests up front
        let mut sched = Scheduler::new(core.clone(), bsz, SchedConfig {
            max_batch: bsz,
            prefill_chunk: prompt_len,
            ..SchedConfig::default()
        });
        for i in 0..bsz {
            sched.submit(Request::new(mk_prompt(i), max_new,
                                      Sampler::Greedy,
                                      1000 + i as u64))?;
        }
        let t0 = Instant::now();
        let comps = sched.run_all()?;
        let sched_secs = t0.elapsed().as_secs_f64();
        let total_tokens: usize =
            comps.iter().map(|c| c.tokens.len()).sum();
        let gaps: Vec<f64> = comps
            .iter()
            .flat_map(|c| c.token_gaps.iter().map(|g| g * 1e3))
            .collect();
        let firsts: Vec<f64> =
            comps.iter().map(|c| c.first_token_secs * 1e3).collect();

        // sequential: the same requests one after another on one engine
        let mut eng = Engine::from_core(core.clone());
        let t1 = Instant::now();
        let mut seq_tokens = 0usize;
        let mut seq_outs = Vec::new();
        for i in 0..bsz {
            eng.reset();
            let rep = generate(&mut eng, &mk_prompt(i), max_new,
                               Sampler::Greedy, 1000 + i as u64)?;
            seq_tokens += rep.tokens.len();
            seq_outs.push(rep.tokens);
        }
        let seq_secs = t1.elapsed().as_secs_f64();

        // determinism gate 2: scheduler greedy outputs == solo outputs
        for (c, want) in comps.iter().zip(&seq_outs) {
            ensure!(&c.tokens == want,
                    "serve bench: scheduler output diverged from solo \
                     generate (req {})", c.id);
        }
        ensure!(total_tokens == seq_tokens && total_tokens > 0,
                "serve bench: token accounting mismatch");

        let sched_tps = total_tokens as f64 / sched_secs.max(1e-9);
        let seq_tps = seq_tokens as f64 / seq_secs.max(1e-9);
        let speedup = sched_tps / seq_tps.max(1e-9);
        if bsz == 8 {
            speedup8 = speedup;
        }
        let p50 = percentile(&gaps, 50.0);
        let p95 = percentile(&gaps, 95.0);
        rows.push(vec![
            format!("batch {bsz}"),
            format!("batched {sched_tps:.0} tok/s vs sequential \
                     {seq_tps:.0} tok/s ({speedup:.2}x); token lat \
                     p50 {p50:.2}ms p95 {p95:.2}ms"),
        ]);
        crate::info!("serve bench batch {bsz}: {sched_tps:.0} tok/s \
                      batched vs {seq_tps:.0} sequential \
                      ({speedup:.2}x)");
        jbatches.push(Json::obj(vec![
            ("batch", Json::num(bsz as f64)),
            ("requests", Json::num(bsz as f64)),
            ("tokens", Json::num(total_tokens as f64)),
            ("sched_tok_per_sec", Json::num(sched_tps)),
            ("seq_tok_per_sec", Json::num(seq_tps)),
            ("speedup", Json::num(speedup)),
            ("p50_token_ms", Json::num(p50)),
            ("p95_token_ms", Json::num(p95)),
            ("p50_first_token_ms", Json::num(percentile(&firsts, 50.0))),
            ("p95_first_token_ms", Json::num(percentile(&firsts, 95.0))),
        ]));
    }
    rows.push(vec!["speedup @ batch 8 (target >= 3x)".into(),
                   format!("{speedup8:.2}x")]);
    let md = format!(
        "## Serve - continuous batching vs sequential per-request decode \
         (scheduler logits bit-identical to solo engine, asserted)\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );
    let j = Json::obj(vec![
        ("dim", Json::num(dim as f64)),
        ("inter", Json::num(inter as f64)),
        ("vocab", Json::num(vocab as f64)),
        ("n_layers", Json::num(n_layers as f64)),
        ("prompt_tokens", Json::num(prompt_len as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("batches", Json::arr(jbatches)),
    ]);
    Ok((md, j))
}

/// Open-loop serving robustness: seeded Poisson arrivals with a deadline
/// mix driven through the scheduler on the virtual clock at a light, a
/// near-capacity, and an overload rate, reporting goodput / shed /
/// timeout / reject counters per rate. Before reporting, the bench
/// *asserts* the robustness contracts: every run is run-to-run
/// deterministic (identical lifecycle digests), survivors of a clean
/// run are bit-identical to solo `generate`, a seeded fault-injection
/// run is just as deterministic, and no run leaks a single KV page.
/// `serve_robust` section of runs/bench.json (schema >= 6).
pub fn serve_robust_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::infer::openloop::{planned_requests, run_open_loop,
                                 run_open_loop_with_completions,
                                 OpenLoopCfg};

    let (dim, nh, hd, inter, vocab, n_layers) = if fast {
        (256usize, 4usize, 64usize, 512usize, 1024usize, 1usize)
    } else {
        (1024, 8, 128, 2816, 4096, 1)
    };
    let prompt_len = 8usize;
    let max_new = if fast { 12 } else { 16 };
    let max_ctx = prompt_len + max_new + 4;
    let requests = if fast { 24 } else { 48 };
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, QuantScheme::new(2, 128),
        max_ctx, 4343)?);
    let base = OpenLoopCfg {
        requests,
        rate: 20.0,
        tick_secs: 0.005,
        prompt_len,
        max_new,
        deadline_secs: 0.5,
        seed: 21,
        slots: 4,
        max_batch: 4,
        prefill_chunk: prompt_len,
        max_queue: 8,
        fault_rate: 0.0,
        personas: 0,
        page_rows: 0,
        prefix_cache: false,
        kv_bits: 16,
        ..OpenLoopCfg::default()
    };

    // robustness gate 1: survivors of a clean, uncontended run are
    // bit-identical to solo generate runs of the same requests
    let gentle = OpenLoopCfg {
        rate: 10.0,
        deadline_secs: 0.0, // no deadlines: every arrival must finish
        max_queue: requests.max(1),
        ..base
    };
    let (grep, comps) =
        run_open_loop_with_completions(core.clone(), &gentle)?;
    ensure!(grep.rejected == 0 && grep.goodput == grep.arrivals,
            "serve_robust bench: uncontended run did not finish \
             everything: {grep:?}");
    let reqs = planned_requests(&gentle, core.max_ctx);
    ensure!(comps.len() == reqs.len());
    for (c, req) in comps.iter().zip(&reqs) {
        let mut eng = Engine::from_core(core.clone());
        let want = generate(&mut eng, &req.prompt, req.max_new,
                            req.sampler, req.seed)?;
        ensure!(c.tokens == want.tokens,
                "serve_robust bench: open-loop request {} diverged from \
                 its solo generate run", c.id);
    }

    // robustness gate 2 + the rate sweep: every rate is run twice and
    // must reproduce its lifecycle digest bit-for-bit
    let mut rows = vec![vec![
        "config".into(),
        format!("dim {dim}, inter {inter}, vocab {vocab}, {n_layers} \
                 block(s), w2g128; {requests} arrivals, deadline \
                 {:.0}ms, queue cap {}", base.deadline_secs * 1e3,
                base.max_queue),
    ]];
    let mut jrates = Vec::new();
    for &rate in &[20.0f64, 60.0, 300.0] {
        let cfg = OpenLoopCfg { rate, ..base };
        let a = run_open_loop(core.clone(), &cfg)?;
        let b = run_open_loop(core.clone(), &cfg)?;
        ensure!(a == b,
                "serve_robust bench: rate {rate} not deterministic");
        ensure!(a.goodput > 0,
                "serve_robust bench: zero goodput at rate {rate}");
        ensure!(a.leaked_pages == 0);
        let goodput_rate = a.goodput as f64 / a.arrivals.max(1) as f64;
        let shed_rate = (a.shed_queued + a.rejected) as f64
            / a.arrivals.max(1) as f64;
        rows.push(vec![
            format!("offered {rate:.0} req/s"),
            format!("goodput {}/{} ({:.0}%), shed {}, timed out {}, \
                     rejected {}, queue max {}",
                    a.goodput, a.arrivals, goodput_rate * 100.0,
                    a.shed_queued, a.timed_out_live, a.rejected,
                    a.queue_depth_max),
        ]);
        crate::info!("serve_robust bench rate {rate:.0}: goodput \
                      {}/{}, shed {}, rejected {}",
                     a.goodput, a.arrivals, a.shed_queued, a.rejected);
        jrates.push(Json::obj(vec![
            ("rate", Json::num(rate)),
            ("offered", Json::num(a.arrivals as f64)),
            ("goodput", Json::num(a.goodput as f64)),
            ("shed", Json::num(a.shed_queued as f64)),
            ("timed_out", Json::num(a.timed_out_live as f64)),
            ("failed", Json::num(a.failed as f64)),
            ("rejected", Json::num(a.rejected as f64)),
            ("goodput_rate", Json::num(goodput_rate)),
            ("shed_rate", Json::num(shed_rate)),
            ("queue_depth_max", Json::num(a.queue_depth_max as f64)),
        ]));
    }

    // robustness gate 3: a seeded fault-injection run reproduces
    // bit-for-bit and leaks nothing either
    let fcfg = OpenLoopCfg { rate: 60.0, fault_rate: 0.05, ..base };
    let fa = run_open_loop(core.clone(), &fcfg)?;
    let fb = run_open_loop(core, &fcfg)?;
    ensure!(fa == fb, "serve_robust bench: fault run not deterministic");
    ensure!(fa.leaked_pages == 0);
    rows.push(vec![
        format!("faults armed (p = {})", fcfg.fault_rate),
        format!("goodput {}/{}, failed {}, digest {:016x}",
                fa.goodput, fa.arrivals, fa.failed, fa.digest),
    ]);

    let md = format!(
        "## Serve robustness - open-loop arrivals with deadlines, \
         backpressure, and fault injection (determinism + zero-leak \
         contracts asserted)\n\n{}",
        crate::exp::md_table(&["Scenario", "Outcome"], &rows)
    );
    let j = Json::obj(vec![
        ("dim", Json::num(dim as f64)),
        ("prompt_tokens", Json::num(prompt_len as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("requests", Json::num(requests as f64)),
        ("deadline_secs", Json::num(base.deadline_secs)),
        ("max_queue", Json::num(base.max_queue as f64)),
        ("rates", Json::arr(jrates)),
        ("fault_rate", Json::num(fcfg.fault_rate)),
        ("fault_goodput", Json::num(fa.goodput as f64)),
        ("fault_failed", Json::num(fa.failed as f64)),
        ("survivors_bitexact", Json::Bool(true)),
        ("deterministic", Json::Bool(true)),
        ("leaked_pages", Json::num(0.0)),
    ]);
    Ok((md, j))
}

/// SLO-aware scheduling bench: goodput under a p95 first-token +
/// per-token latency target, EDF-with-prefill-budget vs FIFO, at batch
/// 8/32/128 on the work-proportional open-loop clock (each processed
/// token costs virtual time, so admission order and prefill
/// interleaving genuinely move the latency metrics). In-bench gates:
/// every run reproduces its report (digest included) bit-for-bit, EDF +
/// budget achieves >= FIFO SLO goodput at every batch size (summed over
/// seeds), streamed tokens reconcile with retired outputs, and the
/// 200-schedule property fuzzer passes with zero leaked pages and zero
/// determinism violations. `serve_slo` section of runs/bench.json
/// (schema >= 10).
pub fn serve_slo_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::infer::fuzz::run_fuzz;
    use crate::infer::openloop::{run_open_loop, OpenLoopCfg};
    use crate::infer::sched::SchedPolicy;

    let (dim, nh, hd, inter, vocab, n_layers) = if fast {
        (256usize, 4usize, 64usize, 512usize, 1024usize, 1usize)
    } else {
        (1024, 8, 128, 2816, 4096, 1)
    };
    let prompt_len = 12usize;
    let max_new = 12usize;
    let max_ctx = prompt_len + max_new + 4;
    let requests = if fast { 32 } else { 64 };
    let core = Arc::new(ModelCore::synthetic(
        dim, nh, hd, inter, vocab, n_layers, QuantScheme::new(2, 128),
        max_ctx, 5151)?);
    // an arrival burst well above capacity at batch 8, with the
    // standard 1-tight : 3-standard : 1-relaxed : 1-none deadline mix,
    // so admission order decides which deadlines survive
    let base = OpenLoopCfg {
        requests,
        rate: 300.0,
        tick_secs: 0.002,
        prompt_len,
        max_new,
        deadline_secs: 0.4,
        prefill_chunk: 8,
        max_queue: requests,
        token_cost_secs: 0.001,
        slo_first_token_secs: 0.6,
        slo_token_secs: 0.1,
        stream: true,
        ..OpenLoopCfg::default()
    };

    let mut rows = vec![vec![
        "config".into(),
        format!("dim {dim}, vocab {vocab}, {n_layers} block(s); \
                 {requests} arrivals at {:.0} req/s, deadline base \
                 {:.0}ms, SLO first-token {:.0}ms / p95 gap {:.0}ms, \
                 token cost {:.1}ms",
                base.rate, base.deadline_secs * 1e3,
                base.slo_first_token_secs * 1e3,
                base.slo_token_secs * 1e3,
                base.token_cost_secs * 1e3),
    ]];
    let mut jbatches = Vec::new();
    for &batch in &[8usize, 32, 128] {
        let mut fifo_slo = 0usize;
        let mut edf_slo = 0usize;
        let mut fifo_good = 0usize;
        let mut edf_good = 0usize;
        let mut fifo_p95ft = 0.0f64;
        let mut edf_p95ft = 0.0f64;
        let mut fifo_p95tok = 0.0f64;
        let mut edf_p95tok = 0.0f64;
        for seed in [11u64, 12] {
            let fifo_cfg = OpenLoopCfg {
                seed,
                slots: batch,
                max_batch: batch,
                policy: SchedPolicy::Fifo,
                prefill_budget: 0,
                ..base
            };
            let edf_cfg = OpenLoopCfg {
                policy: SchedPolicy::Edf,
                prefill_budget: 16,
                ..fifo_cfg
            };
            let fa = run_open_loop(core.clone(), &fifo_cfg)?;
            let fb = run_open_loop(core.clone(), &fifo_cfg)?;
            ensure!(fa == fb,
                    "serve_slo bench: FIFO batch {batch} seed {seed} \
                     not deterministic");
            let ea = run_open_loop(core.clone(), &edf_cfg)?;
            let eb = run_open_loop(core.clone(), &edf_cfg)?;
            ensure!(ea == eb,
                    "serve_slo bench: EDF batch {batch} seed {seed} \
                     not deterministic");
            for r in [&fa, &ea] {
                ensure!(r.leaked_pages == 0);
                ensure!(r.streamed_tokens == r.total_tokens,
                        "serve_slo bench: streamed tokens diverge from \
                         retired outputs");
            }
            ensure!(ea.goodput > 0,
                    "serve_slo bench: EDF batch {batch} seed {seed} \
                     produced no goodput");
            fifo_slo += fa.slo_goodput;
            edf_slo += ea.slo_goodput;
            fifo_good += fa.goodput;
            edf_good += ea.goodput;
            fifo_p95ft = fifo_p95ft.max(fa.p95_first_token_secs);
            edf_p95ft = edf_p95ft.max(ea.p95_first_token_secs);
            fifo_p95tok = fifo_p95tok.max(fa.p95_token_gap_secs);
            edf_p95tok = edf_p95tok.max(ea.p95_token_gap_secs);
        }
        // the headline gate: EDF admission + a bounded prefill quantum
        // must never lose SLO goodput to FIFO (ties allowed - at large
        // batch everything admits immediately and the policies agree)
        ensure!(edf_slo >= fifo_slo,
                "serve_slo bench: EDF SLO goodput {edf_slo} below FIFO \
                 {fifo_slo} at batch {batch}");
        rows.push(vec![
            format!("batch {batch}"),
            format!("SLO goodput EDF {edf_slo} vs FIFO {fifo_slo} (of \
                     {} offered); goodput {edf_good} vs {fifo_good}; \
                     p95 first-token {:.0}ms vs {:.0}ms",
                    2 * requests, edf_p95ft * 1e3, fifo_p95ft * 1e3),
        ]);
        crate::info!("serve_slo bench batch {batch}: EDF {edf_slo} vs \
                      FIFO {fifo_slo} SLO goodput (goodput {edf_good} \
                      vs {fifo_good})");
        jbatches.push(Json::obj(vec![
            ("batch", Json::num(batch as f64)),
            ("fifo_slo_goodput", Json::num(fifo_slo as f64)),
            ("edf_slo_goodput", Json::num(edf_slo as f64)),
            ("fifo_goodput", Json::num(fifo_good as f64)),
            ("edf_goodput", Json::num(edf_good as f64)),
            ("fifo_p95_first_token_ms", Json::num(fifo_p95ft * 1e3)),
            ("edf_p95_first_token_ms", Json::num(edf_p95ft * 1e3)),
            ("fifo_p95_token_ms", Json::num(fifo_p95tok * 1e3)),
            ("edf_p95_token_ms", Json::num(edf_p95tok * 1e3)),
            ("deterministic", Json::Bool(true)),
        ]));
    }

    // acceptance sweep: 200 randomized schedules through the property
    // harness - zero leaked pages, zero determinism violations
    let fuzz = run_fuzz(200, 0xF0AA)?;
    ensure!(fuzz.schedules == 200 && fuzz.violations == 0
            && fuzz.leaked_pages == 0,
            "serve_slo bench: property fuzzer failed: {fuzz:?}");
    rows.push(vec![
        "property fuzzer".into(),
        format!("{} schedules ({} EDF), {} completions, {} cancels, \
                 {} timeouts, {} faults fired, 0 leaks, 0 violations",
                fuzz.schedules, fuzz.edf_schedules, fuzz.completions,
                fuzz.cancels, fuzz.timeouts, fuzz.faults_fired),
    ]);

    let md = format!(
        "## Serve SLO - EDF + prefill budget vs FIFO under latency \
         targets, pinned by the randomized scheduler property harness\n\
         \n{}",
        crate::exp::md_table(&["Scenario", "Outcome"], &rows)
    );
    let j = Json::obj(vec![
        ("dim", Json::num(dim as f64)),
        ("requests", Json::num(requests as f64)),
        ("rate", Json::num(base.rate)),
        ("deadline_secs", Json::num(base.deadline_secs)),
        ("slo_first_token_ms",
         Json::num(base.slo_first_token_secs * 1e3)),
        ("slo_token_ms", Json::num(base.slo_token_secs * 1e3)),
        ("token_cost_ms", Json::num(base.token_cost_secs * 1e3)),
        ("prefill_budget", Json::num(16.0)),
        ("batches", Json::arr(jbatches)),
        ("fuzz_schedules", Json::num(fuzz.schedules as f64)),
        ("fuzz_violations", Json::num(fuzz.violations as f64)),
        ("fuzz_leaked_pages", Json::num(fuzz.leaked_pages as f64)),
        ("streamed_prefix_ok", Json::Bool(true)),
    ]);
    Ok((md, j))
}

/// Eval-forward throughput on the native backend's `synthetic` preset:
/// tokens/s through the taped training forward (what eval entries paid
/// before the forward-only rework) vs the no-tape path they run now.
/// Both paths produce bit-identical logits (asserted), so the delta is
/// pure tape/allocation overhead. Schema-3 section of runs/bench.json.
pub fn eval_forward_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::model::init::init_fp_params;
    use crate::runtime::native::model::{self, FwdScratch, Geom};
    use crate::runtime::native::{model_refs_fp, NativeBackend};
    use crate::runtime::Backend;

    let be = NativeBackend::new();
    let preset = "synthetic";
    let cfg = be.manifest().preset(preset)?.config.clone();
    let fpl = be.manifest().layout(preset, "fp")?.clone();
    let params = init_fp_params(&fpl, 3);
    let geom = Geom::new(cfg.eval_batch, cfg.eval_ctx, cfg.dim,
                         cfg.n_heads, cfg.head_dim, cfg.inter,
                         cfg.norm_eps as f32, cfg.rope_theta);
    let n_tok = cfg.eval_batch * cfg.eval_ctx;
    let x: Vec<i32> =
        (0..n_tok).map(|i| ((i * 7 + 1) % cfg.vocab) as i32).collect();
    let mp = model_refs_fp(&cfg, &fpl, &params, None)?;

    let iters = if fast { 5 } else { 30 };
    let r_taped = bench("eval-fwd-taped", 1, iters, || {
        let (logits, tape) = model::model_fwd(&geom, &mp, &x, cfg.vocab);
        std::hint::black_box((logits.len(), tape.tapes.len()));
    });
    let mut sc = FwdScratch::new();
    let r_notape = bench("eval-fwd-notape", 1, iters, || {
        let logits =
            model::model_fwd_notape(&geom, &mp, &x, cfg.vocab, &mut sc);
        std::hint::black_box(logits.len());
    });
    // sanity: the two paths agree bit-for-bit (also pinned by tests)
    let (lg_t, _) = model::model_fwd(&geom, &mp, &x, cfg.vocab);
    let lg_n = model::model_fwd_notape(&geom, &mp, &x, cfg.vocab, &mut sc);
    if lg_t.iter().zip(&lg_n).any(|(a, b)| a.to_bits() != b.to_bits()) {
        bail!("eval_forward bench: taped and notape logits diverge");
    }

    let taped_tps = n_tok as f64 * 1e6 / r_taped.mean_us;
    let notape_tps = n_tok as f64 * 1e6 / r_notape.mean_us;
    let speedup = r_taped.mean_us / r_notape.mean_us;
    let rows = vec![
        vec!["preset".into(),
             format!("{preset} ({} tok/batch)", n_tok)],
        vec!["taped forward".into(),
             format!("{:.0} us ({taped_tps:.0} tok/s)", r_taped.mean_us)],
        vec!["forward-only".into(),
             format!("{:.0} us ({notape_tps:.0} tok/s)",
                     r_notape.mean_us)],
        vec!["speedup (notape vs taped)".into(),
             format!("{speedup:.2}x")],
    ];
    let md = format!(
        "## Eval forward - taped vs forward-only (native backend, \
         bit-identical logits)\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );
    let j = Json::obj(vec![
        ("preset", Json::str(preset)),
        ("tokens_per_batch", Json::num(n_tok as f64)),
        ("taped_us", Json::num(r_taped.mean_us)),
        ("taped_tok_per_sec", Json::num(taped_tps)),
        ("notape_us", Json::num(r_notape.mean_us)),
        ("notape_tok_per_sec", Json::num(notape_tps)),
        ("speedup", Json::num(speedup)),
    ]);
    Ok((md, j))
}

/// Native-backend training-step throughput on the `synthetic` preset:
/// one Block-AP step (block fwd+bwd with STE fake-quant + Adam) and one
/// E2E-QP step (full-model dequant fwd+bwd over the step sizes). Tracked
/// in runs/bench.json so train-path perf regressions show up across PRs
/// alongside the inference numbers.
pub fn train_step_throughput(fast: bool) -> Result<(String, Json)> {
    use crate::coordinator::block_ap::{extract_block, init_block_qp,
                                       rtn_quantize_model};
    use crate::model::init::init_fp_params;
    use crate::runtime::{native::NativeBackend, Arg, Backend};

    let be = NativeBackend::new();
    let preset = "synthetic";
    let cfg = be.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let fpl = be.manifest().layout(preset, "fp")?.clone();
    let bl = be.manifest().layout(preset, "block")?.clone();
    let qbl = be.manifest()
        .layout(preset, &format!("qp_block_g{g}"))?
        .clone();
    let qpl = be.manifest().layout(preset, &format!("qp_g{g}"))?.clone();

    let params = init_fp_params(&fpl, 7);
    let bp = extract_block(&params, &fpl, &bl, 0)?;
    let qp = init_block_qp(&bp, &bl, &qbl, sch)?;
    let m_w = vec![0f32; bl.size];
    let v_w = vec![0f32; bl.size];
    let m_q = vec![0f32; qbl.size];
    let v_q = vec![0f32; qbl.size];
    let lo = vec![-1e30f32; bl.size];
    let hi = vec![1e30f32; bl.size];
    let mrows = cfg.block_batch * cfg.block_ctx;
    let mut rng = Rng::new(55);
    let mut h = vec![0f32; mrows * cfg.dim];
    rng.fill_normal(&mut h, 0.0, 1.0);
    let mut target = vec![0f32; mrows * cfg.dim];
    rng.fill_normal(&mut target, 0.0, 1.0);
    let qmax = [sch.qmax()];

    let iters = if fast { 3 } else { 10 };
    let step_exec = be.exec_g(preset, "block_ap_step", g)?;
    let r_block = bench("block_ap_step", 1, iters, || {
        let outs = step_exec
            .run(&[
                Arg::F32(&bp), Arg::F32(&qp), Arg::F32(&m_w),
                Arg::F32(&v_w), Arg::F32(&m_q), Arg::F32(&v_q),
                Arg::F32(&lo), Arg::F32(&hi), Arg::F32(&h),
                Arg::F32(&target), Arg::F32(&qmax), Arg::Scalar(1.0),
                Arg::Scalar(1e-3), Arg::Scalar(1e-3), Arg::Scalar(1.0),
                Arg::Scalar(1.0), Arg::Scalar(1.0), Arg::Scalar(0.0),
            ])
            .unwrap();
        std::hint::black_box(outs.len());
    });

    let qm = rtn_quantize_model(&be, preset, &params, sch)?;
    let e2e_exec = be.exec_g(preset, "e2e_qp_step", g)?;
    let n = cfg.e2e_batch * cfg.e2e_ctx;
    let x: Vec<i32> =
        (0..n).map(|i| ((i * 13 + 2) % cfg.vocab) as i32).collect();
    let y: Vec<i32> =
        (0..n).map(|i| ((i * 13 + 3) % cfg.vocab) as i32).collect();
    let mask = vec![1.0f32; n];
    let m_e = vec![0f32; qpl.size];
    let v_e = vec![0f32; qpl.size];
    let r_e2e = bench("e2e_qp_step", 1, iters, || {
        let outs = e2e_exec
            .run(&[
                Arg::F32(&qm.wq), Arg::F32(&qm.qp), Arg::F32(&qm.fpr),
                Arg::F32(&m_e), Arg::F32(&v_e), Arg::I32(&x),
                Arg::I32(&y), Arg::F32(&mask), Arg::Scalar(1.0),
                Arg::Scalar(1e-3), Arg::Scalar(1.0), Arg::Scalar(0.0),
            ])
            .unwrap();
        std::hint::black_box(outs.len());
    });

    let rows = vec![
        vec!["preset".into(), preset.to_string()],
        vec!["block_ap_step".into(),
             format!("{:.0} us ({:.1}/s)", r_block.mean_us,
                     1e6 / r_block.mean_us)],
        vec!["e2e_qp_step".into(),
             format!("{:.0} us ({:.1}/s)", r_e2e.mean_us,
                     1e6 / r_e2e.mean_us)],
    ];
    let md = format!(
        "## Native train-step throughput ({} w2g{g})\n\n{}",
        preset,
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );
    let j = Json::obj(vec![
        ("preset", Json::str(preset)),
        ("bits", Json::num(2.0)),
        ("group", Json::num(g as f64)),
        ("block_ap_step_us", Json::num(r_block.mean_us)),
        ("block_ap_steps_per_sec", Json::num(1e6 / r_block.mean_us)),
        ("e2e_qp_step_us", Json::num(r_e2e.mean_us)),
        ("e2e_qp_steps_per_sec", Json::num(1e6 / r_e2e.mean_us)),
    ]);
    Ok((md, j))
}

fn matvec_thread_table(fast: bool) -> Result<(String, Json)> {
    let shapes: Vec<(&str, usize, usize)> = if fast {
        vec![("2-7B attn", 4096, 4096)]
    } else {
        vec![("2-7B attn", 4096, 4096), ("2-7B mlp", 11008, 4096)]
    };
    let iters = if fast { 5 } else { 10 };
    let mut rng = Rng::new(202);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for (name, out_d, in_d) in shapes {
        let mut w = vec![0f32; out_d * in_d];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; in_d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; out_d];
        let sch = QuantScheme::new(2, 128);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)?;

        for kind in ["f32", "int2"] {
            let mut per_t = Vec::new();
            for &t in &THREAD_COUNTS {
                let r = with_threads(t, || {
                    bench(kind, 2, iters, || {
                        if kind == "f32" {
                            dense_matvec(&w, out_d, in_d, &x, &mut y);
                        } else {
                            pl.matvec(&x, &mut y);
                        }
                        std::hint::black_box(&y);
                    })
                });
                jrows.push(Json::obj(vec![
                    ("shape", Json::str(format!("{out_d}x{in_d}"))),
                    ("kind", Json::str(kind)),
                    ("threads", Json::num(t as f64)),
                    ("mean_us", Json::num(r.mean_us)),
                    ("p50_us", Json::num(r.p50_us)),
                    ("p95_us", Json::num(r.p95_us)),
                ]));
                per_t.push(r.mean_us);
            }
            rows.push(vec![
                name.to_string(),
                format!("{out_d}x{in_d}"),
                kind.to_string(),
                format!("{:.0}", per_t[0]),
                format!("{:.0}", per_t[1]),
                format!("{:.0}", per_t[2]),
                format!("{:.2}x", per_t[0] / per_t[1]),
            ]);
            crate::info!("matvec thread bench {name} {kind} done");
        }
    }
    let md = format!(
        "## Threaded matvec - latency us by worker count (row-chunked; \
         EQAT_THREADS override)\n\n{}",
        crate::exp::md_table(
            &["Layer", "Shape", "Kind", "1T us", "4T us", "16T us",
              "4T speedup"],
            &rows)
    );
    Ok((md, Json::arr(jrows)))
}

fn engine_throughput_table(fast: bool) -> Result<(String, Json)> {
    // Llama-2-7B-shaped single block (full) / scaled-down twin (fast)
    let (dim, nh, hd, inter, vocab) = if fast {
        (512usize, 8usize, 64usize, 1408usize, 2048usize)
    } else {
        (4096, 32, 128, 11008, 8192)
    };
    let n_layers = 1;
    let n_prefill = if fast { 16 } else { 64 };
    let decode_iters = if fast { 6 } else { 12 };
    let max_ctx = n_prefill + decode_iters + 20;
    let sch = QuantScheme::new(2, 128);

    crate::info!("building synthetic engine dim={dim} inter={inter} \
                  vocab={vocab}");
    let mut eng = Engine::synthetic(dim, nh, hd, inter, vocab, n_layers,
                                    sch, max_ctx, 42)?;
    let toks: Vec<i32> =
        (0..n_prefill).map(|i| ((i * 37 + 11) % vocab) as i32).collect();

    // prefill: batched vs the old sequential step loop, single-threaded
    // (isolates the batching win); plus batched at 4T for the table
    let seq_iters = 2;
    let batched_1t = with_threads(1, || {
        bench("prefill-batched", 1, seq_iters + 1, || {
            eng.reset();
            eng.prefill(&toks).unwrap();
            std::hint::black_box(eng.pos());
        })
    });
    let sequential_1t = with_threads(1, || {
        bench("prefill-sequential", 0, seq_iters, || {
            eng.reset();
            for &t in &toks {
                eng.step_ref(t).unwrap();
            }
            std::hint::black_box(eng.pos());
        })
    });
    let batched_4t = with_threads(4, || {
        bench("prefill-batched-4t", 1, seq_iters + 1, || {
            eng.reset();
            eng.prefill(&toks).unwrap();
            std::hint::black_box(eng.pos());
        })
    });
    let prefill_speedup = sequential_1t.mean_us / batched_1t.mean_us;
    crate::info!("prefill {n_prefill} tok: batched {:.1}ms vs sequential \
                  {:.1}ms ({prefill_speedup:.1}x)",
                 batched_1t.mean_us / 1e3, sequential_1t.mean_us / 1e3);

    // decode tokens/sec by thread count; pos is pinned back to the prompt
    // end so the KV window stays bounded while benching
    let mut decode_rows = Vec::new();
    let mut step_1t_us = 0f64;
    for &t in &THREAD_COUNTS {
        let r = with_threads(t, || {
            eng.reset();
            eng.prefill(&toks).unwrap();
            bench("decode", 2, decode_iters, || {
                if eng.pos() >= max_ctx {
                    eng.set_pos(n_prefill);
                }
                eng.step_ref(1).unwrap();
            })
        });
        if t == 1 {
            step_1t_us = r.mean_us;
        }
        decode_rows.push((t, 1e6 / r.mean_us, r.mean_us));
        crate::info!("decode @{t}T: {:.1} tok/s", 1e6 / r.mean_us);
    }

    // dense decode estimate: swap measured packed linear latencies for
    // dense ones at the same shapes (attention/head/norm cost unchanged)
    let lin_shapes =
        [(dim, dim, 4usize), (inter, dim, 2usize), (dim, inter, 1usize)];
    let mut packed_lin_us = 0f64;
    let mut dense_lin_us = 0f64;
    let mut rng = Rng::new(77);
    for &(o, i, count) in &lin_shapes {
        let mut w = vec![0f32; o * i];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; i];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; o];
        let gp = minmax_init(&w, o, i, sch);
        let wi = quantize(&w, &gp, sch);
        let pl = PackedLinear::pack(&wi, o, i, &gp.s, &gp.z, sch)?;
        let (rp, rd) = with_threads(1, || {
            let rp = bench("lin-packed", 1, 5, || {
                pl.matvec(&x, &mut y);
                std::hint::black_box(&y);
            });
            let rd = bench("lin-dense", 1, 5, || {
                dense_matvec(&w, o, i, &x, &mut y);
                std::hint::black_box(&y);
            });
            (rp, rd)
        });
        packed_lin_us += rp.mean_us * count as f64 * n_layers as f64;
        dense_lin_us += rd.mean_us * count as f64 * n_layers as f64;
    }
    let dense_step_est_us =
        (step_1t_us - packed_lin_us + dense_lin_us).max(1e-3);
    let dense_est_tps = 1e6 / dense_step_est_us;

    let rows = vec![
        vec!["config".into(),
             format!("dim {dim}, inter {inter}, vocab {vocab}, \
                      {n_layers} block(s), w2g128")],
        vec![format!("prefill batched ({n_prefill} tok, 1T)"),
             format!("{:.1} ms", batched_1t.mean_us / 1e3)],
        vec![format!("prefill batched ({n_prefill} tok, 4T)"),
             format!("{:.1} ms", batched_4t.mean_us / 1e3)],
        vec![format!("prefill sequential step loop ({n_prefill} tok, 1T)"),
             format!("{:.1} ms", sequential_1t.mean_us / 1e3)],
        vec!["prefill speedup (batched vs sequential, 1T)".into(),
             format!("{prefill_speedup:.1}x")],
        vec!["decode tok/s @1T".into(),
             format!("{:.1}", decode_rows[0].1)],
        vec!["decode tok/s @4T".into(),
             format!("{:.1}", decode_rows[1].1)],
        vec!["decode tok/s @16T".into(),
             format!("{:.1}", decode_rows[2].1)],
        vec!["decode tok/s dense f32 (estimated, 1T)".into(),
             format!("{dense_est_tps:.1}")],
    ];
    let md = format!(
        "## Engine throughput - batched prefill + threaded decode \
         (packed w2g128; dense row estimated by swapping measured linear \
         latencies)\n\n{}",
        crate::exp::md_table(&["Metric", "Value"], &rows)
    );

    let j = Json::obj(vec![
        ("dim", Json::num(dim as f64)),
        ("inter", Json::num(inter as f64)),
        ("vocab", Json::num(vocab as f64)),
        ("n_layers", Json::num(n_layers as f64)),
        ("bits", Json::num(2.0)),
        ("group", Json::num(128.0)),
        ("prefill_tokens", Json::num(n_prefill as f64)),
        ("prefill_batched_ms", Json::num(batched_1t.mean_us / 1e3)),
        ("prefill_batched_4t_ms", Json::num(batched_4t.mean_us / 1e3)),
        ("prefill_sequential_ms", Json::num(sequential_1t.mean_us / 1e3)),
        ("prefill_speedup", Json::num(prefill_speedup)),
        (
            "decode",
            Json::arr(
                decode_rows
                    .iter()
                    .map(|&(t, tps, us)| {
                        Json::obj(vec![
                            ("threads", Json::num(t as f64)),
                            ("tok_per_sec", Json::num(tps)),
                            ("step_us", Json::num(us)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("decode_dense_est_tok_per_sec", Json::num(dense_est_tps)),
    ]);
    Ok((md, j))
}

/// Write a bench payload to `path` (creating parent dirs).
pub fn write_bench_json(path: &str, payload: &Json) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, payload.dump())
        .with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// Validate a `runs/bench.json` produced by [`inference_throughput`]:
/// parses, checks the schema (1 legacy, 2 adds train_step, 3 adds
/// eval_forward, 4 adds the continuous-batching serve section, 5 adds
/// the paged-KV kv_fork section, 6 adds the open-loop serve_robust
/// section, 7 adds the SIMD kernels section, 8 adds the cross-request
/// prefix_cache section, 9 adds the low-bit KV kv_lowbit section, 10
/// adds the SLO scheduling serve_slo section - see
/// docs/BENCH_SCHEMA.md), and requires non-empty matvec/decode sections
/// with numeric fields. scripts/tier1.sh fails the build on error.
pub fn check_bench_json(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("missing bench output {path}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let schema = j.get("schema")?.as_usize()?;
    if !(1..=10).contains(&schema) {
        bail!("{path}: unsupported schema {schema}");
    }
    let mv = j.get("matvec")?.as_arr()?;
    if mv.is_empty() {
        bail!("{path}: empty matvec section");
    }
    for e in mv {
        e.get("mean_us")?.as_f64()?;
        e.get("threads")?.as_usize()?;
        e.get("kind")?.as_str()?;
    }
    let eng = j.get("engine")?;
    let speedup = eng.get("prefill_speedup")?.as_f64()?;
    if !speedup.is_finite() || speedup <= 0.0 {
        bail!("{path}: bad prefill_speedup {speedup}");
    }
    let dec = eng.get("decode")?.as_arr()?;
    if dec.is_empty() {
        bail!("{path}: empty decode section");
    }
    for d in dec {
        d.get("tok_per_sec")?.as_f64()?;
        d.get("threads")?.as_usize()?;
    }
    // schema 2 adds the native train-step section; schema-1 snapshots
    // from older PRs stay valid
    if schema >= 2 {
        let ts = j.get("train_step")?;
        for key in ["block_ap_step_us", "e2e_qp_step_us"] {
            let v = ts.get(key)?.as_f64()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: bad train_step.{key} {v}");
            }
        }
    }
    // schema 3 adds the taped-vs-forward-only eval_forward section
    if schema >= 3 {
        let ef = j.get("eval_forward")?;
        for key in ["taped_tok_per_sec", "notape_tok_per_sec",
                    "speedup"] {
            let v = ef.get(key)?.as_f64()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: bad eval_forward.{key} {v}");
            }
        }
    }
    // schema 4 adds the continuous-batching serve section
    if schema >= 4 {
        let sv = j.get("serve")?.get("batches")?.as_arr()?;
        if sv.is_empty() {
            bail!("{path}: empty serve.batches section");
        }
        for b in sv {
            b.get("batch")?.as_usize()?;
            for key in ["sched_tok_per_sec", "seq_tok_per_sec",
                        "speedup"] {
                let v = b.get(key)?.as_f64()?;
                if !v.is_finite() || v <= 0.0 {
                    bail!("{path}: bad serve.{key} {v}");
                }
            }
            // latency percentiles can round to ~0 on coarse timers;
            // require presence and non-negative finite values
            for key in ["p50_token_ms", "p95_token_ms"] {
                let v = b.get(key)?.as_f64()?;
                if !v.is_finite() || v < 0.0 {
                    bail!("{path}: bad serve.{key} {v}");
                }
            }
        }
    }
    // schema 5 adds the paged-KV kv_fork section; beyond presence, the
    // checker re-asserts the paging contract the numbers encode: a plain
    // fork copies nothing and COW stays within one page
    if schema >= 5 {
        let kf = j.get("kv_fork")?;
        for key in ["shared_tok_per_sec", "copy_tok_per_sec", "speedup",
                    "page_bytes"] {
            let v = kf.get(key)?.as_f64()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: bad kv_fork.{key} {v}");
            }
        }
        for key in ["fork_us", "fork_copy_us"] {
            let v = kf.get(key)?.as_f64()?;
            if !v.is_finite() || v < 0.0 {
                bail!("{path}: bad kv_fork.{key} {v}");
            }
        }
        let fork_bytes = kf.get("fork_bytes_copied")?.as_f64()?;
        if fork_bytes != 0.0 {
            bail!("{path}: kv_fork.fork_bytes_copied {fork_bytes} != 0 \
                   (fork must be zero-copy)");
        }
        let cow = kf.get("cow_bytes_per_fork")?.as_f64()?;
        let page = kf.get("page_bytes")?.as_f64()?;
        if !cow.is_finite() || cow < 0.0 || cow > page {
            bail!("{path}: kv_fork.cow_bytes_per_fork {cow} exceeds one \
                   page ({page} B)");
        }
    }
    // schema 6 adds the open-loop serve_robust section; the checker
    // re-asserts the robustness contract the numbers encode: the runs
    // were deterministic, survivors matched solo generate, and no KV
    // page leaked
    if schema >= 6 {
        let sr = j.get("serve_robust")?;
        let rates = sr.get("rates")?.as_arr()?;
        if rates.is_empty() {
            bail!("{path}: empty serve_robust.rates section");
        }
        for r in rates {
            for key in ["rate", "offered", "goodput", "shed",
                        "timed_out", "failed", "rejected",
                        "queue_depth_max"] {
                let v = r.get(key)?.as_f64()?;
                if !v.is_finite() || v < 0.0 {
                    bail!("{path}: bad serve_robust.rates.{key} {v}");
                }
            }
            let g = r.get("goodput")?.as_f64()?;
            if g <= 0.0 {
                bail!("{path}: serve_robust rate with zero goodput");
            }
            for key in ["goodput_rate", "shed_rate"] {
                let v = r.get(key)?.as_f64()?;
                if !v.is_finite() || !(0.0..=1.0).contains(&v) {
                    bail!("{path}: serve_robust.rates.{key} {v} outside \
                           [0, 1]");
                }
            }
        }
        for key in ["survivors_bitexact", "deterministic"] {
            if !sr.get(key)?.as_bool()? {
                bail!("{path}: serve_robust.{key} is false");
            }
        }
        let leaked = sr.get("leaked_pages")?.as_f64()?;
        if leaked != 0.0 {
            bail!("{path}: serve_robust.leaked_pages {leaked} != 0");
        }
    }
    // schema 7 adds the SIMD kernel-layer section; the checker re-asserts
    // the determinism contract the numbers encode: every published row
    // passed the in-bench scalar-vs-SIMD bit-equality assertion
    if schema >= 7 {
        j.get("simd")?.as_str()?;
        let kn = j.get("kernels")?;
        kn.get("isa")?.as_str()?;
        let rows = kn.get("rows")?.as_arr()?;
        if rows.is_empty() {
            bail!("{path}: empty kernels.rows section");
        }
        for r in rows {
            let name = r.get("kernel")?.as_str()?.to_string();
            for key in ["scalar_us", "simd_us", "scalar_gb_s",
                        "simd_gb_s", "scalar_gflop_s", "simd_gflop_s",
                        "speedup"] {
                let v = r.get(key)?.as_f64()?;
                if !v.is_finite() || v <= 0.0 {
                    bail!("{path}: bad kernels.{name}.{key} {v}");
                }
            }
            if !r.get("bitexact")?.as_bool()? {
                bail!("{path}: kernels.{name}.bitexact is false (SIMD \
                       path diverged from scalar)");
            }
        }
    }
    // schema 8 adds the cross-request prefix_cache section; the checker
    // re-asserts the caching contract the numbers encode: hits happened,
    // hits avoided real prefill work and beat cold first-token latency,
    // page sharing copied nothing, hit logits matched cold prefill
    // bit-for-bit, and nothing leaked
    if schema >= 8 {
        let pc = j.get("prefix_cache")?;
        let hits = pc.get("hits")?.as_f64()?;
        if !hits.is_finite() || hits < 1.0 {
            bail!("{path}: prefix_cache.hits {hits} < 1");
        }
        let hr = pc.get("hit_rate")?.as_f64()?;
        if !hr.is_finite() || !(hr > 0.0 && hr <= 1.0) {
            bail!("{path}: prefix_cache.hit_rate {hr} outside (0, 1]");
        }
        let avoided = pc.get("tokens_prefill_avoided")?.as_f64()?;
        if !avoided.is_finite() || avoided <= 0.0 {
            bail!("{path}: prefix_cache.tokens_prefill_avoided \
                   {avoided} <= 0");
        }
        for key in ["page_rows", "personas", "users", "sys_tokens",
                    "misses", "evictions", "first_token_p50_hit_ms",
                    "first_token_p95_hit_ms", "first_token_p50_cold_ms",
                    "first_token_p95_cold_ms", "prefill_speedup"] {
            let v = pc.get(key)?.as_f64()?;
            if !v.is_finite() || v < 0.0 {
                bail!("{path}: bad prefix_cache.{key} {v}");
            }
        }
        let p50_hit = pc.get("first_token_p50_hit_ms")?.as_f64()?;
        let p50_cold = pc.get("first_token_p50_cold_ms")?.as_f64()?;
        if p50_hit >= p50_cold {
            bail!("{path}: prefix_cache first-token p50 hit {p50_hit} \
                   not below cold {p50_cold}");
        }
        let fb = pc.get("hit_fork_bytes")?.as_f64()?;
        if fb != 0.0 {
            bail!("{path}: prefix_cache.hit_fork_bytes {fb} != 0 (hits \
                   must share pages by refcount, never copy)");
        }
        if !pc.get("bitexact")?.as_bool()? {
            bail!("{path}: prefix_cache.bitexact is false (hit logits \
                   diverged from cold prefill)");
        }
        let leaked = pc.get("leaked_pages")?.as_f64()?;
        if leaked != 0.0 {
            bail!("{path}: prefix_cache.leaked_pages {leaked} != 0");
        }
    }
    // schema 9 adds the low-bit KV kv_lowbit section; the checker
    // re-asserts the low-bit contract the numbers encode: int4 admits
    // >= 3.5x the sequences of f32 at an identical byte budget, the
    // open-loop comparison never let the packed pool out-spend fp (and
    // goodput did not regress), every fused dequant kernel row passed
    // the scalar-vs-SIMD bit-equality assertion, the run digests were
    // ISA-invariant, the fp path stayed byte-identical, the ppl deltas
    // sit under their gates, and nothing leaked
    if schema >= 9 {
        let kl = j.get("kv_lowbit")?;
        let cm4 = kl.get("capacity_multiplier_int4")?.as_f64()?;
        if !cm4.is_finite() || cm4 < 3.5 {
            bail!("{path}: kv_lowbit.capacity_multiplier_int4 {cm4} \
                   below the 3.5x gate");
        }
        for key in ["capacity_multiplier_int8", "fp_page_bytes",
                    "int8_page_bytes", "int4_page_bytes",
                    "pool_budget_bytes", "fp_seqs", "int4_seqs",
                    "goodput_fp", "goodput_int4", "ppl_fp", "ppl_int8",
                    "ppl_int4"] {
            let v = kl.get(key)?.as_f64()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: bad kv_lowbit.{key} {v}");
            }
        }
        let bf = kl.get("openloop_pool_bytes_fp")?.as_f64()?;
        let b4 = kl.get("openloop_pool_bytes_int4")?.as_f64()?;
        if !(b4 > 0.0 && b4 <= bf) {
            bail!("{path}: kv_lowbit int4 open-loop pool {b4} B over \
                   the fp budget {bf} B");
        }
        let g_fp = kl.get("goodput_fp")?.as_f64()?;
        let g_i4 = kl.get("goodput_int4")?.as_f64()?;
        if g_i4 < g_fp {
            bail!("{path}: kv_lowbit.goodput_int4 {g_i4} below fp \
                   {g_fp} at the same byte budget");
        }
        for (dk, gk) in [("ppl_rel_delta_int8", "ppl_gate_int8"),
                         ("ppl_rel_delta_int4", "ppl_gate_int4")] {
            let d = kl.get(dk)?.as_f64()?;
            let g = kl.get(gk)?.as_f64()?;
            if !d.is_finite() || d < 0.0 || d >= g {
                bail!("{path}: kv_lowbit.{dk} {d} over its gate {g}");
            }
        }
        let rows = kl.get("kernels")?.as_arr()?;
        if rows.is_empty() {
            bail!("{path}: empty kv_lowbit.kernels section");
        }
        for r in rows {
            let name = r.get("kernel")?.as_str()?.to_string();
            for key in ["scalar_us", "simd_us", "scalar_gb_s",
                        "simd_gb_s", "speedup"] {
                let v = r.get(key)?.as_f64()?;
                if !v.is_finite() || v <= 0.0 {
                    bail!("{path}: bad kv_lowbit.{name}.{key} {v}");
                }
            }
            if !r.get("bitexact")?.as_bool()? {
                bail!("{path}: kv_lowbit.{name}.bitexact is false \
                       (SIMD dequant path diverged from scalar)");
            }
        }
        for key in ["lowbit_deterministic", "fp_bitexact"] {
            if !kl.get(key)?.as_bool()? {
                bail!("{path}: kv_lowbit.{key} is false");
            }
        }
        kl.get("digest_int4")?.as_str()?;
        let leaked = kl.get("leaked_pages")?.as_f64()?;
        if leaked != 0.0 {
            bail!("{path}: kv_lowbit.leaked_pages {leaked} != 0");
        }
    }
    // schema 10 adds the SLO scheduling serve_slo section; the checker
    // re-asserts the scheduling contract the numbers encode: EDF with a
    // prefill budget never lost SLO goodput to FIFO at any batch size,
    // every run reproduced its digest, the streamed tokens reconciled
    // with retired outputs, and the 200-schedule property fuzzer passed
    // with zero leaks and zero determinism violations
    if schema >= 10 {
        let ss = j.get("serve_slo")?;
        for key in ["slo_first_token_ms", "slo_token_ms",
                    "token_cost_ms", "prefill_budget", "rate",
                    "requests"] {
            let v = ss.get(key)?.as_f64()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: bad serve_slo.{key} {v}");
            }
        }
        let batches = ss.get("batches")?.as_arr()?;
        if batches.is_empty() {
            bail!("{path}: empty serve_slo.batches section");
        }
        for b in batches {
            let bs = b.get("batch")?.as_usize()?;
            for key in ["fifo_slo_goodput", "edf_slo_goodput",
                        "fifo_goodput", "edf_goodput",
                        "fifo_p95_first_token_ms",
                        "edf_p95_first_token_ms", "fifo_p95_token_ms",
                        "edf_p95_token_ms"] {
                let v = b.get(key)?.as_f64()?;
                if !v.is_finite() || v < 0.0 {
                    bail!("{path}: bad serve_slo.batches.{key} {v}");
                }
            }
            let f = b.get("fifo_slo_goodput")?.as_f64()?;
            let e = b.get("edf_slo_goodput")?.as_f64()?;
            if e < f {
                bail!("{path}: serve_slo batch {bs}: EDF SLO goodput \
                       {e} below FIFO {f}");
            }
            if !b.get("deterministic")?.as_bool()? {
                bail!("{path}: serve_slo batch {bs}: deterministic is \
                       false");
            }
        }
        let fs = ss.get("fuzz_schedules")?.as_f64()?;
        if !fs.is_finite() || fs < 200.0 {
            bail!("{path}: serve_slo.fuzz_schedules {fs} below the \
                   200-schedule acceptance sweep");
        }
        for key in ["fuzz_violations", "fuzz_leaked_pages"] {
            let v = ss.get(key)?.as_f64()?;
            if v != 0.0 {
                bail!("{path}: serve_slo.{key} {v} != 0");
            }
        }
        if !ss.get("streamed_prefix_ok")?.as_bool()? {
            bail!("{path}: serve_slo.streamed_prefix_ok is false");
        }
    }
    Ok(())
}

/// Sanity check used by the size table: llama shapes resolve.
pub fn llama_shapes_ok() -> bool {
    ["llama2-7b", "llama2-13b", "llama2-70b"]
        .iter()
        .all(|n| llama_by_name(n).is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 1, 20, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_us >= 0.0);
        assert!(r.p95_us >= r.p50_us * 0.5);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn packed_matvec_faster_than_dense_at_scale() {
        // the Table 10 mechanism: memory-bound matvec, 16x fewer weight
        // bytes at 2-bit. Use a mid-size layer to keep test time low.
        let (out_d, in_d) = (1024, 1024);
        let mut rng = Rng::new(7);
        let mut w = vec![0f32; out_d * in_d];
        rng.fill_normal(&mut w, 0.0, 0.05);
        let mut x = vec![0f32; in_d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0f32; out_d];
        let sch = QuantScheme::new(2, 128);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)
            .unwrap();
        let dense = bench("f32", 3, 30, || {
            dense_matvec(&w, out_d, in_d, &x, &mut y);
            std::hint::black_box(&y);
        });
        let packed = bench("int2", 3, 30, || {
            pl.matvec(&x, &mut y);
            std::hint::black_box(&y);
        });
        // conservatively just require parity-or-better in test builds
        assert!(
            packed.mean_us < dense.mean_us * 1.5,
            "packed {:.0}us vs dense {:.0}us",
            packed.mean_us,
            dense.mean_us
        );
    }

    #[test]
    fn bench_json_roundtrip_and_validation() {
        let good = Json::obj(vec![
            ("schema", Json::num(10.0)),
            ("kind", Json::str("inference_throughput")),
            ("simd", Json::str("avx2")),
            (
                "matvec",
                Json::arr(vec![Json::obj(vec![
                    ("shape", Json::str("8x8")),
                    ("kind", Json::str("int2")),
                    ("threads", Json::num(1.0)),
                    ("mean_us", Json::num(3.5)),
                ])]),
            ),
            (
                "engine",
                Json::obj(vec![
                    ("prefill_speedup", Json::num(4.2)),
                    (
                        "decode",
                        Json::arr(vec![Json::obj(vec![
                            ("threads", Json::num(1.0)),
                            ("tok_per_sec", Json::num(10.0)),
                        ])]),
                    ),
                ]),
            ),
            (
                "train_step",
                Json::obj(vec![
                    ("block_ap_step_us", Json::num(1500.0)),
                    ("e2e_qp_step_us", Json::num(4000.0)),
                ]),
            ),
            (
                "eval_forward",
                Json::obj(vec![
                    ("taped_tok_per_sec", Json::num(9000.0)),
                    ("notape_tok_per_sec", Json::num(15000.0)),
                    ("speedup", Json::num(1.6)),
                ]),
            ),
            (
                "serve",
                Json::obj(vec![(
                    "batches",
                    Json::arr(vec![Json::obj(vec![
                        ("batch", Json::num(8.0)),
                        ("sched_tok_per_sec", Json::num(400.0)),
                        ("seq_tok_per_sec", Json::num(100.0)),
                        ("speedup", Json::num(4.0)),
                        ("p50_token_ms", Json::num(2.5)),
                        ("p95_token_ms", Json::num(4.0)),
                    ])]),
                )]),
            ),
            (
                "kv_fork",
                Json::obj(vec![
                    ("page_bytes", Json::num(65536.0)),
                    ("fork_us", Json::num(0.4)),
                    ("fork_bytes_copied", Json::num(0.0)),
                    ("fork_copy_us", Json::num(90.0)),
                    ("cow_bytes_per_fork", Json::num(32768.0)),
                    ("shared_tok_per_sec", Json::num(5000.0)),
                    ("copy_tok_per_sec", Json::num(3000.0)),
                    ("speedup", Json::num(1.67)),
                ]),
            ),
            (
                "serve_robust",
                Json::obj(vec![
                    (
                        "rates",
                        Json::arr(vec![Json::obj(vec![
                            ("rate", Json::num(60.0)),
                            ("offered", Json::num(24.0)),
                            ("goodput", Json::num(20.0)),
                            ("shed", Json::num(2.0)),
                            ("timed_out", Json::num(1.0)),
                            ("failed", Json::num(0.0)),
                            ("rejected", Json::num(1.0)),
                            ("goodput_rate", Json::num(20.0 / 24.0)),
                            ("shed_rate", Json::num(3.0 / 24.0)),
                            ("queue_depth_max", Json::num(5.0)),
                        ])]),
                    ),
                    ("survivors_bitexact", Json::Bool(true)),
                    ("deterministic", Json::Bool(true)),
                    ("leaked_pages", Json::num(0.0)),
                ]),
            ),
            (
                "kernels",
                Json::obj(vec![
                    ("isa", Json::str("avx2")),
                    (
                        "rows",
                        Json::arr(vec![Json::obj(vec![
                            ("kernel", Json::str("matvec_b2")),
                            ("scalar_us", Json::num(120.0)),
                            ("simd_us", Json::num(30.0)),
                            ("scalar_gb_s", Json::num(8.0)),
                            ("simd_gb_s", Json::num(32.0)),
                            ("scalar_gflop_s", Json::num(4.0)),
                            ("simd_gflop_s", Json::num(16.0)),
                            ("speedup", Json::num(4.0)),
                            ("bitexact", Json::Bool(true)),
                        ])]),
                    ),
                ]),
            ),
            (
                "prefix_cache",
                Json::obj(vec![
                    ("page_rows", Json::num(8.0)),
                    ("personas", Json::num(3.0)),
                    ("users", Json::num(9.0)),
                    ("sys_tokens", Json::num(24.0)),
                    ("hits", Json::num(9.0)),
                    ("misses", Json::num(3.0)),
                    ("hit_rate", Json::num(0.75)),
                    ("tokens_prefill_avoided", Json::num(216.0)),
                    ("evictions", Json::num(4.0)),
                    ("first_token_p50_hit_ms", Json::num(0.4)),
                    ("first_token_p95_hit_ms", Json::num(0.9)),
                    ("first_token_p50_cold_ms", Json::num(2.1)),
                    ("first_token_p95_cold_ms", Json::num(3.5)),
                    ("prefill_speedup", Json::num(5.25)),
                    ("hit_fork_bytes", Json::num(0.0)),
                    ("bitexact", Json::Bool(true)),
                    ("leaked_pages", Json::num(0.0)),
                ]),
            ),
            (
                "kv_lowbit",
                Json::obj(vec![
                    ("page_rows", Json::num(8.0)),
                    ("fp_page_bytes", Json::num(4096.0)),
                    ("int8_page_bytes", Json::num(1152.0)),
                    ("int4_page_bytes", Json::num(640.0)),
                    ("pool_budget_bytes", Json::num(98304.0)),
                    ("fp_seqs", Json::num(8.0)),
                    ("int8_seqs", Json::num(28.0)),
                    ("int4_seqs", Json::num(51.0)),
                    ("capacity_multiplier_int8", Json::num(3.5)),
                    ("capacity_multiplier_int4", Json::num(6.375)),
                    (
                        "kernels",
                        Json::arr(vec![Json::obj(vec![
                            ("kernel", Json::str("kv_dot_q4")),
                            ("scalar_us", Json::num(12.0)),
                            ("simd_us", Json::num(3.0)),
                            ("scalar_gb_s", Json::num(4.0)),
                            ("simd_gb_s", Json::num(16.0)),
                            ("scalar_gflop_s", Json::num(2.0)),
                            ("simd_gflop_s", Json::num(8.0)),
                            ("speedup", Json::num(4.0)),
                            ("bitexact", Json::Bool(true)),
                        ])]),
                    ),
                    ("goodput_fp", Json::num(9.0)),
                    ("goodput_int4", Json::num(21.0)),
                    ("goodput_multiplier", Json::num(21.0 / 9.0)),
                    ("tokens_fp", Json::num(70.0)),
                    ("tokens_int4", Json::num(160.0)),
                    ("openloop_pool_bytes_fp", Json::num(24576.0)),
                    ("openloop_pool_bytes_int4", Json::num(24320.0)),
                    ("digest_int4", Json::str("00c0ffee00c0ffee")),
                    ("ppl_fp", Json::num(94.8)),
                    ("ppl_int8", Json::num(94.9)),
                    ("ppl_int4", Json::num(96.1)),
                    ("ppl_rel_delta_int8", Json::num(0.002)),
                    ("ppl_rel_delta_int4", Json::num(0.014)),
                    ("ppl_gate_int8", Json::num(0.05)),
                    ("ppl_gate_int4", Json::num(0.25)),
                    ("lowbit_deterministic", Json::Bool(true)),
                    ("fp_bitexact", Json::Bool(true)),
                    ("leaked_pages", Json::num(0.0)),
                ]),
            ),
            (
                "serve_slo",
                Json::obj(vec![
                    ("dim", Json::num(256.0)),
                    ("requests", Json::num(32.0)),
                    ("rate", Json::num(300.0)),
                    ("deadline_secs", Json::num(0.4)),
                    ("slo_first_token_ms", Json::num(600.0)),
                    ("slo_token_ms", Json::num(100.0)),
                    ("token_cost_ms", Json::num(1.0)),
                    ("prefill_budget", Json::num(16.0)),
                    (
                        "batches",
                        Json::arr(vec![Json::obj(vec![
                            ("batch", Json::num(8.0)),
                            ("fifo_slo_goodput", Json::num(18.0)),
                            ("edf_slo_goodput", Json::num(27.0)),
                            ("fifo_goodput", Json::num(24.0)),
                            ("edf_goodput", Json::num(29.0)),
                            ("fifo_p95_first_token_ms", Json::num(220.0)),
                            ("edf_p95_first_token_ms", Json::num(160.0)),
                            ("fifo_p95_token_ms", Json::num(40.0)),
                            ("edf_p95_token_ms", Json::num(35.0)),
                            ("deterministic", Json::Bool(true)),
                        ])]),
                    ),
                    ("fuzz_schedules", Json::num(200.0)),
                    ("fuzz_violations", Json::num(0.0)),
                    ("fuzz_leaked_pages", Json::num(0.0)),
                    ("streamed_prefix_ok", Json::Bool(true)),
                ]),
            ),
        ]);
        let dir = std::env::temp_dir().join("eqat-bench-test");
        let path = dir.join("bench.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, &good).unwrap();
        check_bench_json(&path).unwrap();

        // schema-10 file without its required sections is rejected...
        for missing in ["train_step", "eval_forward", "serve", "kv_fork",
                        "serve_robust", "kernels", "simd",
                        "prefix_cache", "kv_lowbit", "serve_slo"] {
            let mut pruned = Vec::new();
            if let Json::Obj(fields) = &good {
                for (k, v) in fields {
                    if k != missing {
                        pruned.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(pruned)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "missing {missing} accepted");
        }
        // ...and a kv_fork section violating the paging contract
        // (non-zero fork copy, COW above one page) is rejected
        for (key, val) in [("fork_bytes_copied", 8.0),
                           ("cow_bytes_per_fork", 1e9)] {
            let mut fields = Vec::new();
            if let Json::Obj(outer) = &good {
                for (k, v) in outer {
                    if k == "kv_fork" {
                        let mut kf = Vec::new();
                        if let Json::Obj(inner) = v {
                            for (ik, iv) in inner {
                                kf.push((
                                    ik.as_str(),
                                    if ik == key {
                                        Json::num(val)
                                    } else {
                                        iv.clone()
                                    },
                                ));
                            }
                        }
                        fields.push((k.as_str(), Json::obj(kf)));
                    } else {
                        fields.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(fields)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "bad kv_fork.{key} accepted");
        }
        // ...and a serve_robust section violating the robustness
        // contract (false determinism flags, leaked pages) is rejected
        for (key, val) in [("survivors_bitexact", Json::Bool(false)),
                           ("deterministic", Json::Bool(false)),
                           ("leaked_pages", Json::num(3.0))] {
            let mut fields = Vec::new();
            if let Json::Obj(outer) = &good {
                for (k, v) in outer {
                    if k == "serve_robust" {
                        let mut sr = Vec::new();
                        if let Json::Obj(inner) = v {
                            for (ik, iv) in inner {
                                sr.push((
                                    ik.as_str(),
                                    if ik == key {
                                        val.clone()
                                    } else {
                                        iv.clone()
                                    },
                                ));
                            }
                        }
                        fields.push((k.as_str(), Json::obj(sr)));
                    } else {
                        fields.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(fields)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "bad serve_robust.{key} accepted");
        }
        // ...and a kv_lowbit section violating the low-bit contract
        // (capacity under the 3.5x gate, ppl delta over its gate,
        // broken determinism flags, an out-of-budget pool, leaks) is
        // rejected
        for (key, val) in [
            ("capacity_multiplier_int4", Json::num(3.0)),
            ("ppl_rel_delta_int4", Json::num(0.5)),
            ("ppl_rel_delta_int8", Json::num(0.09)),
            ("goodput_int4", Json::num(5.0)),
            ("openloop_pool_bytes_int4", Json::num(1e9)),
            ("lowbit_deterministic", Json::Bool(false)),
            ("fp_bitexact", Json::Bool(false)),
            ("leaked_pages", Json::num(2.0)),
        ] {
            let mut fields = Vec::new();
            if let Json::Obj(outer) = &good {
                for (k, v) in outer {
                    if k == "kv_lowbit" {
                        let mut kl = Vec::new();
                        if let Json::Obj(inner) = v {
                            for (ik, iv) in inner {
                                kl.push((
                                    ik.as_str(),
                                    if ik == key {
                                        val.clone()
                                    } else {
                                        iv.clone()
                                    },
                                ));
                            }
                        }
                        fields.push((k.as_str(), Json::obj(kl)));
                    } else {
                        fields.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(fields)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "bad kv_lowbit.{key} accepted");
        }
        // ...and a serve_slo section violating the SLO scheduling
        // contract (fuzz violations or leaks, an undersized fuzz
        // sweep, a broken streamed-prefix flag) is rejected
        for (key, val) in [("fuzz_violations", Json::num(1.0)),
                           ("fuzz_leaked_pages", Json::num(4.0)),
                           ("fuzz_schedules", Json::num(50.0)),
                           ("streamed_prefix_ok", Json::Bool(false))] {
            let mut fields = Vec::new();
            if let Json::Obj(outer) = &good {
                for (k, v) in outer {
                    if k == "serve_slo" {
                        let mut ss = Vec::new();
                        if let Json::Obj(inner) = v {
                            for (ik, iv) in inner {
                                ss.push((
                                    ik.as_str(),
                                    if ik == key {
                                        val.clone()
                                    } else {
                                        iv.clone()
                                    },
                                ));
                            }
                        }
                        fields.push((k.as_str(), Json::obj(ss)));
                    } else {
                        fields.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(fields)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "bad serve_slo.{key} accepted");
        }
        // ...as is a batch row where EDF loses the SLO-goodput gate
        // to FIFO or the per-batch determinism flag drops
        for (key, val) in [("edf_slo_goodput", Json::num(3.0)),
                           ("deterministic", Json::Bool(false))] {
            let mut fields = Vec::new();
            if let Json::Obj(outer) = &good {
                for (k, v) in outer {
                    if k == "serve_slo" {
                        let mut ss = Vec::new();
                        if let Json::Obj(inner) = v {
                            for (ik, iv) in inner {
                                if ik == "batches" {
                                    let mut rows = Vec::new();
                                    if let Json::Arr(bs) = iv {
                                        for b in bs {
                                            let mut row = Vec::new();
                                            if let Json::Obj(bf) = b {
                                                for (bk, bv) in bf {
                                                    row.push((
                                                        bk.as_str(),
                                                        if bk == key {
                                                            val.clone()
                                                        } else {
                                                            bv.clone()
                                                        },
                                                    ));
                                                }
                                            }
                                            rows.push(Json::obj(row));
                                        }
                                    }
                                    ss.push((ik.as_str(),
                                             Json::Arr(rows)));
                                } else {
                                    ss.push((ik.as_str(), iv.clone()));
                                }
                            }
                        }
                        fields.push((k.as_str(), Json::obj(ss)));
                    } else {
                        fields.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(fields)).unwrap();
            assert!(check_bench_json(&path).is_err(),
                    "bad serve_slo batch {key} accepted");
        }
        // ...but the core sections under legacy schemas 1-9 stay valid
        // (9 keeps kv_lowbit, 8 keeps prefix_cache, 7 keeps kernels,
        // 6 keeps serve_robust, 5 keeps kv_fork, 4 keeps serve, 3
        // keeps eval_forward, 1/2 drop those too)
        for (legacy_schema, drop_keys) in [
            (1.0f64, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                          "kernels", "simd", "serve_robust", "kv_fork",
                          "serve", "eval_forward", "schema"]),
            (2.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "kernels", "simd", "serve_robust", "kv_fork",
                       "serve", "eval_forward", "schema"]),
            (3.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "kernels", "simd", "serve_robust", "kv_fork",
                       "serve", "schema"]),
            (4.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "kernels", "simd", "serve_robust", "kv_fork",
                       "schema"]),
            (5.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "kernels", "simd", "serve_robust", "schema"]),
            (6.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "kernels", "simd", "schema"]),
            (7.0, vec!["serve_slo", "kv_lowbit", "prefix_cache",
                       "schema"]),
            (8.0, vec!["serve_slo", "kv_lowbit", "schema"]),
            (9.0, vec!["serve_slo", "schema"]),
        ] {
            let mut legacy = vec![("schema", Json::num(legacy_schema))];
            if let Json::Obj(fields) = &good {
                for (k, v) in fields {
                    if !drop_keys.contains(&k.as_str()) {
                        legacy.push((k.as_str(), v.clone()));
                    }
                }
            }
            write_bench_json(&path, &Json::obj(legacy)).unwrap();
            check_bench_json(&path).unwrap();
        }

        // malformed: missing decode section
        let bad = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("matvec", Json::arr(vec![])),
            ("engine", Json::obj(vec![])),
        ]);
        write_bench_json(&path, &bad).unwrap();
        assert!(check_bench_json(&path).is_err());
        assert!(check_bench_json("/nonexistent/bench.json").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fast_engine_throughput_smoke_shapes() {
        // tiny engine exercising the same code path the bench drives;
        // keeps the bench harness itself under test without the cost
        let mut eng = Engine::synthetic(64, 4, 16, 128, 256, 1,
                                        QuantScheme::new(2, 32), 12, 9)
            .unwrap();
        let toks: Vec<i32> = (0..6).map(|i| (i * 5 % 256) as i32).collect();
        let lg = eng.prefill(&toks).unwrap();
        assert_eq!(lg.len(), 256);
        let lg2 = eng.step_ref(3).unwrap();
        assert_eq!(lg2.len(), 256);
    }
}
