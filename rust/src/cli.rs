//! Hand-rolled CLI (clap is unavailable offline): subcommands + --key value
//! flags. `eqat help` prints usage.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug)]
pub struct Cli {
    pub cmd: String,
    pub pos: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const USAGE: &str = "\
eqat - EfficientQAT reproduction (pure-Rust native backend + optional
       JAX/Pallas AOT artifacts via PJRT)

USAGE: eqat <command> [args] [--flag value]...

COMMANDS
  train                 full pipeline: pretrain (cached) -> Block-AP ->
                        E2E-QP -> ppl vs RTN baseline. Runs offline on the
                        native backend with no artifacts.
                        [--preset P --bits N --group G --backend B
                         --pretrain-steps N --block-samples N
                         --block-epochs N --e2e-samples N
                         --ppl-batches N --trainable SET --out FILE
                         --require-beat-rtn]
  pretrain              train the fp model  [--preset P --steps N --lr X
                        --out runs/P-fp.eqt]
  quantize              EfficientQAT pipeline -> packed model
                        [--preset P --bits N --group G --out FILE
                         --no-block-ap --no-e2e --trainable SET]
  eval                  evaluate a model [--model FILE | --preset P (fp)]
                        (ppl wiki/c4 + 5 zero-shot suites); --ppl-only
                        [--ppl-batches N] runs just wiki ppl through the
                        forward-only eval path (the tier-1 smoke)
  generate              pure-Rust generation from a packed model
                        [--model FILE --tokens N --temp T]
  serve-sim             multi-request serving demo: synthetic request
                        stream through the continuous-batching scheduler
                        (shared ModelCore + paged-KV sessions), with
                        aggregate tok/s, latency percentiles, and
                        page-pool occupancy (peak pages, COW bytes)
                        [--requests N --slots N --tokens N --prompt-len L
                         --prefill-chunk N --seed S --model FILE];
                        --kv-bits {4,8,16} selects the KV page storage
                        width (16 = f32 default; 4/8 = packed low-bit
                        pages with SIMD dequant attention: 4-8x the
                        sequences at fixed pool bytes, bit-deterministic
                        per seed but not vs f32);
                        --shared-prefix switches to an N-personas x
                        M-users mix (fixed system prompts + short user
                        suffixes) with the cross-request prefix cache on,
                        reporting hits/misses/prefill-tokens-avoided/
                        evictions [--personas N --page-rows R --no-cache];
                        --open-loop switches to deterministic Poisson
                        arrivals on the virtual clock with deadlines,
                        bounded-queue backpressure, and seeded fault
                        injection [--rate R --tick-ms MS --deadline-ms MS
                         --max-queue N --fail-rate P] (composes with
                        --shared-prefix);
                        --policy {fifo,edf} picks the admission policy
                        (edf = earliest absolute deadline first, with
                        priority-class fallback for deadline-free
                        requests; bit-identical per-request output either
                        way), --prefill-budget N caps prefill tokens per
                        tick (0 = unbounded), --stream surfaces tokens
                        incrementally through the scheduler's stream
                        events; open-loop SLO accounting via
                        [--token-cost-ms MS --slo-ft-ms MS
                         --slo-tok-ms MS]
  size                  Table-11 size arithmetic [--model llama2-7b ...]
  exp <id>              reproduce a paper table/figure: t1..t9, t11..t14,
                        fig1, fig3, fig4  [--preset P]
  bench <which>         qlinear (Table 10) | inference (threaded decode +
                        batched prefill + native train_step + eval_forward
                        + serve + paged-KV kv_fork + open-loop
                        serve_robust + SIMD kernels + prefix_cache +
                        low-bit KV kv_lowbit + SLO scheduling serve_slo
                        sections -> runs/bench.json, schema 10; see
                        docs/BENCH_SCHEMA.md) | check (validate
                        runs/bench.json) | train-time (Tables 8/9)
                        [--fast]
  help                  this text

BACKENDS (--backend, default auto)
  native    pure-Rust CPU implementation of every train/eval executable;
            built-in presets (synthetic, tiny, small, base), no artifacts
  pjrt      AOT HLO artifacts via the PJRT CPU client (`make artifacts`
            first; needs real xla-rs bindings)
  auto      pjrt when artifacts/manifest.json exists and loads, else
            native

FLAG DEFAULTS: --preset tiny --bits 2 --group <preset default>
  --artifacts artifacts --runs runs --backend auto
";

pub fn parse(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        bail!("no command; try `eqat help`");
    }
    let cmd = args[0].clone();
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            // boolean flags have no value or next token is another flag
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    Ok(Cli { cmd, pos, flags })
}

impl Cli {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} wants an integer, got {v}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} wants a number, got {v}")),
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_positional_and_flags() {
        let c = parse(&s(&["exp", "t5", "--preset", "tiny", "--fast"]))
            .unwrap();
        assert_eq!(c.cmd, "exp");
        assert_eq!(c.pos, vec!["t5"]);
        assert_eq!(c.flag("preset"), Some("tiny"));
        assert!(c.flag_bool("fast"));
        assert!(!c.flag_bool("slow"));
    }

    #[test]
    fn typed_flags() {
        let c = parse(&s(&["pretrain", "--steps", "100", "--lr", "3e-3"]))
            .unwrap();
        assert_eq!(c.flag_usize("steps", 1).unwrap(), 100);
        assert_eq!(c.flag_f64("lr", 0.0).unwrap(), 3e-3);
        assert_eq!(c.flag_usize("missing", 7).unwrap(), 7);
        assert!(parse(&s(&["x", "--steps", "abc"]))
            .unwrap()
            .flag_usize("steps", 1)
            .is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse(&[]).is_err());
    }
}
