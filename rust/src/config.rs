//! Configuration: run-time knobs for the coordinator plus exact shape
//! definitions of the real Llama-2/3 models (used by the Table 10 qlinear
//! speed bench and the Table 11 size calculator - arithmetic only, no
//! weights are needed for those reproductions).

use anyhow::{bail, Result};

/// Which parameters Block-AP trains (paper Table 6 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainableSet {
    /// step sizes only (~ OmniQuant's learned clipping)
    Clipping,
    /// step sizes + zero points
    SZ,
    /// weights restricted to the +-s/2 rounding window (~ AutoRound/BRECQ)
    Round,
    /// s, z, and rounding-window-restricted weights (~ CBQ-like)
    SZRound,
    /// full Block-AP: s, z, W unrestricted (the paper's contribution)
    SZW,
}

impl TrainableSet {
    /// (m_w, m_s, m_z, proj) scalar mask values fed to block_ap_step.
    pub fn masks(self) -> (f32, f32, f32, f32) {
        match self {
            TrainableSet::Clipping => (0.0, 1.0, 0.0, 0.0),
            TrainableSet::SZ => (0.0, 1.0, 1.0, 0.0),
            TrainableSet::Round => (1.0, 0.0, 0.0, 1.0),
            TrainableSet::SZRound => (1.0, 1.0, 1.0, 1.0),
            TrainableSet::SZW => (1.0, 1.0, 1.0, 0.0),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrainableSet::Clipping => "clipping",
            TrainableSet::SZ => "s,z",
            TrainableSet::Round => "round",
            TrainableSet::SZRound => "s,z,round",
            TrainableSet::SZW => "s,z,W",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "clipping" => TrainableSet::Clipping,
            "sz" | "s,z" => TrainableSet::SZ,
            "round" => TrainableSet::Round,
            "szround" | "s,z,round" => TrainableSet::SZRound,
            "szw" | "s,z,W" | "s,z,w" => TrainableSet::SZW,
            _ => bail!("unknown trainable set '{s}'"),
        })
    }
}

/// Quantization scheme: bit-width + group size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantScheme {
    pub bits: u32,
    pub group: usize,
}

impl QuantScheme {
    pub fn new(bits: u32, group: usize) -> QuantScheme {
        QuantScheme { bits, group }
    }

    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Average bits/param including group metadata: N + (N+16)/g
    /// (paper Appendix E; f16 scale + N-bit zero point per group).
    pub fn avg_bits(&self) -> f64 {
        self.bits as f64 + (self.bits as f64 + 16.0) / self.group as f64
    }

    pub fn tag(&self) -> String {
        format!("w{}g{}", self.bits, self.group)
    }
}

/// How finished blocks feed inputs to the next block during Block-AP.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Propagation {
    /// through the quantized block (default, matches OmniQuant/EfficientQAT)
    Quant,
    /// through the original fp block (BRECQ-style ablation)
    Fp,
}

/// Hyper-parameters of the two training phases (paper §4.1 defaults,
/// re-scaled for the synthetic testbed).
#[derive(Clone, Debug)]
pub struct TrainHp {
    pub block_samples: usize,
    pub block_epochs: usize,
    pub block_lr_w: f64,
    pub block_lr_q: f64,
    pub e2e_samples: usize,
    pub e2e_epochs: usize,
    pub e2e_lr: f64,
    pub seed: u64,
    pub propagation: Propagation,
    pub trainable: TrainableSet,
    pub train_s_e2e: bool,
    pub train_z_e2e: bool,
}

impl Default for TrainHp {
    fn default() -> Self {
        TrainHp {
            block_samples: 128,
            block_epochs: 2,
            // paper: lr 1e-4 (qp) / 2e-5 (w) at 2-bit; our models are tiny
            // and synthetic, trained for few steps -> proportionally larger
            block_lr_w: 1e-3,
            block_lr_q: 1e-3,
            e2e_samples: 128,
            e2e_epochs: 1,
            e2e_lr: 1e-3,
            seed: 0xEFC1,
            propagation: Propagation::Quant,
            trainable: TrainableSet::SZW,
            train_s_e2e: true,
            train_z_e2e: false,
        }
    }
}

/// Exact shape definition of a real Llama-family model (GQA-aware).
#[derive(Clone, Debug)]
pub struct LlamaShape {
    pub name: &'static str,
    pub dim: usize,
    pub n_layers: usize,
    pub inter: usize,
    pub vocab: usize,
    /// k/v projection output dim (= dim unless grouped-query attention)
    pub kv_dim: usize,
}

impl LlamaShape {
    /// The quantized linears of one block: (name, out, in).
    pub fn linears(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("attn.q", self.dim, self.dim),
            ("attn.k", self.kv_dim, self.dim),
            ("attn.v", self.kv_dim, self.dim),
            ("attn.o", self.dim, self.dim),
            ("mlp.gate", self.inter, self.dim),
            ("mlp.up", self.inter, self.dim),
            ("mlp.down", self.dim, self.inter),
        ]
    }

    /// Parameters in quantized (linear) layers.
    pub fn linear_params(&self) -> u64 {
        let per_block: u64 = self
            .linears()
            .iter()
            .map(|&(_, o, i)| (o * i) as u64)
            .sum();
        per_block * self.n_layers as u64
    }

    /// Parameters kept in fp16: embeddings, head, norms.
    pub fn fp_params(&self) -> u64 {
        let embed = (self.vocab * self.dim) as u64 * 2; // embed + untied head
        let norms = (self.n_layers * 2 * self.dim + self.dim) as u64;
        embed + norms
    }

    pub fn total_params(&self) -> u64 {
        self.linear_params() + self.fp_params()
    }
}

pub fn llama2_7b() -> LlamaShape {
    LlamaShape { name: "LLaMA-2-7B", dim: 4096, n_layers: 32, inter: 11008,
                 vocab: 32000, kv_dim: 4096 }
}

pub fn llama2_13b() -> LlamaShape {
    LlamaShape { name: "LLaMA-2-13B", dim: 5120, n_layers: 40, inter: 13824,
                 vocab: 32000, kv_dim: 5120 }
}

pub fn llama2_70b() -> LlamaShape {
    LlamaShape { name: "LLaMA-2-70B", dim: 8192, n_layers: 80, inter: 28672,
                 vocab: 32000, kv_dim: 1024 } // GQA: 8 kv heads x 128
}

pub fn llama3_8b() -> LlamaShape {
    LlamaShape { name: "LLaMA-3-8B", dim: 4096, n_layers: 32, inter: 14336,
                 vocab: 128256, kv_dim: 1024 }
}

pub fn llama3_70b() -> LlamaShape {
    LlamaShape { name: "LLaMA-3-70B", dim: 8192, n_layers: 80, inter: 28672,
                 vocab: 128256, kv_dim: 1024 }
}

pub fn llama_by_name(name: &str) -> Result<LlamaShape> {
    Ok(match name {
        "llama2-7b" | "2-7" => llama2_7b(),
        "llama2-13b" | "2-13" => llama2_13b(),
        "llama2-70b" | "2-70" => llama2_70b(),
        "llama3-8b" | "3-8" => llama3_8b(),
        "llama3-70b" | "3-70" => llama3_70b(),
        _ => bail!("unknown llama shape '{name}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_param_counts_match_published() {
        // Known totals (within 1%): 6.74B, 13.0B, 69.0B, 8.0B, 70.6B
        let checks = [
            (llama2_7b(), 6.74e9),
            (llama2_13b(), 13.0e9),
            (llama2_70b(), 69.0e9),
            (llama3_8b(), 8.03e9),
            (llama3_70b(), 70.6e9),
        ];
        for (shape, want) in checks {
            let got = shape.total_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.01, "{}: got {got:.3e} want {want:.3e}",
                    shape.name);
        }
    }

    #[test]
    fn block_param_count_matches_paper_table6() {
        // Table 6: trainable "# Param." for one Llama-2-7B block = 202.4M
        let s = llama2_7b();
        let per_block = s.linear_params() / s.n_layers as u64;
        assert!((per_block as f64 - 202.4e6).abs() / 202.4e6 < 0.01,
                "per_block={per_block}");
    }

    #[test]
    fn avg_bits_formula() {
        // paper Appendix E: N + (N+16)/g
        assert!((QuantScheme::new(2, 64).avg_bits() - 2.28).abs() < 0.005);
        assert!((QuantScheme::new(2, 128).avg_bits() - 2.14).abs() < 0.005);
        assert!((QuantScheme::new(4, 32).avg_bits() - 4.63).abs() < 0.005);
        assert!((QuantScheme::new(3, 64).avg_bits() - 3.30).abs() < 0.005);
    }

    #[test]
    fn qmax_by_bits() {
        assert_eq!(QuantScheme::new(2, 64).qmax(), 3.0);
        assert_eq!(QuantScheme::new(3, 64).qmax(), 7.0);
        assert_eq!(QuantScheme::new(4, 64).qmax(), 15.0);
    }

    #[test]
    fn trainable_set_masks() {
        assert_eq!(TrainableSet::SZW.masks(), (1.0, 1.0, 1.0, 0.0));
        assert_eq!(TrainableSet::Clipping.masks(), (0.0, 1.0, 0.0, 0.0));
        assert_eq!(TrainableSet::Round.masks(), (1.0, 0.0, 0.0, 1.0));
        assert_eq!(TrainableSet::parse("s,z,W").unwrap(), TrainableSet::SZW);
        assert!(TrainableSet::parse("bogus").is_err());
    }
}
