//! Block-AP scheduler - the paper's phase 1 (§3.2) and the heart of the L3
//! coordinator.
//!
//! Memory-efficiency mechanism (why a 70B fits on one GPU): only ONE
//! transformer block's weights + optimizer state is live at a time; the
//! rest of the model exists as cached activations. The coordinator:
//!
//!   1. runs `embed_fwd` once over the calibration pool -> activation cache
//!      (two copies: fp-propagated teacher inputs, quantized-propagated
//!      student inputs - `Propagation::Quant`, OmniQuant convention);
//!   2. per block: captures teacher targets with the ORIGINAL block weights,
//!      initializes (s, z) by min/max RTN, then trains (W, s, z) with the
//!      masked `block_ap_step` executable (Table 6 ablations = masks, the
//!      AutoRound-style rounding window = host-computed [w_lo, w_hi]);
//!   3. quantizes the trained block onto the integer grid (z rounded to
//!      N-bit storage) and propagates both caches through it;
//!   4. assembles the full quantized model (wq, qp, fpr flat buffers).

use anyhow::{anyhow, Result};

use crate::config::{Propagation, QuantScheme, TrainHp};
use crate::data::loader::LmBatch;
use crate::io::manifest::Layout;
use crate::model::quantized::QuantizedModel;
use crate::quant::rtn;
use crate::runtime::{Arg, Backend};
use crate::util::rng::Rng;

pub struct BlockApReport {
    /// per block: training loss at each step
    pub loss_curves: Vec<Vec<f32>>,
    /// per block: mean validation reconstruction loss after training
    pub val_losses: Vec<f32>,
    /// per block: mean train reconstruction loss after training
    pub train_losses: Vec<f32>,
    pub seconds: f64,
    /// analytic training-memory estimate in bytes (Table 6/8)
    pub mem_bytes: usize,
}

pub struct BlockApOutput {
    pub model: QuantizedModel,
    pub report: BlockApReport,
}

/// Extract block `b`'s params from the full fp flat vector into
/// block-layout order.
pub fn extract_block(
    fp: &[f32],
    fpl: &Layout,
    bl: &Layout,
    b: usize,
) -> Result<Vec<f32>> {
    let mut bp = vec![0f32; bl.size];
    for e in &bl.entries {
        let src = fpl.slice(fp, &format!("blocks.{b}.{}", e.name))?;
        bp[e.offset..e.offset + e.numel()].copy_from_slice(src);
    }
    Ok(bp)
}

/// Min/max-initialize the block's qp = [s||z] vector from its weights.
pub fn init_block_qp(
    bp: &[f32],
    bl: &Layout,
    qbl: &Layout,
    sch: QuantScheme,
) -> Result<Vec<f32>> {
    let mut qp = vec![0f32; qbl.size];
    for e in &qbl.entries {
        let (which, lin) = e
            .name
            .split_once('.')
            .ok_or_else(|| anyhow!("bad qp entry {}", e.name))?;
        if which == "z" {
            continue; // handled together with s below
        }
        let we = bl.entry(lin)?;
        let (rows, cols) = (we.shape[0], we.shape[1]);
        let w = bl.slice(bp, lin)?;
        let gp = rtn::minmax_init(w, rows, cols, sch);
        qp[e.offset..e.offset + e.numel()].copy_from_slice(&gp.s);
        let ze = qbl.entry(&format!("z.{lin}"))?;
        qp[ze.offset..ze.offset + ze.numel()].copy_from_slice(&gp.z);
    }
    Ok(qp)
}

/// AutoRound-style rounding window [w - s/2, w + s/2] per linear weight;
/// norms unconstrained.
fn rounding_window(
    bp: &[f32],
    qp: &[f32],
    bl: &Layout,
    qbl: &Layout,
    sch: QuantScheme,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut lo = vec![-1e30f32; bl.size];
    let mut hi = vec![1e30f32; bl.size];
    for e in &bl.entries {
        if e.name.ends_with("norm") {
            continue;
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        let g = sch.group;
        let s = qbl.slice(qp, &format!("s.{}", e.name))?;
        for r in 0..rows {
            for c in 0..cols {
                let idx = e.offset + r * cols + c;
                let step = s[r * (cols / g) + c / g];
                lo[idx] = bp[idx] - 0.5 * step;
                hi[idx] = bp[idx] + 0.5 * step;
            }
        }
    }
    Ok((lo, hi))
}

/// Quantize a trained block onto the integer grid: rounds z to storage
/// precision, emits (wq_block, qp_block) in block layouts.
pub fn quantize_block(
    bp: &[f32],
    qp: &[f32],
    bl: &Layout,
    qbl: &Layout,
    sch: QuantScheme,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let wq_size: usize = bl
        .entries
        .iter()
        .filter(|e| !e.name.ends_with("norm"))
        .map(|e| e.numel())
        .sum();
    let mut wq = vec![0f32; wq_size];
    let mut qp_out = qp.to_vec();
    let mut woff = 0usize;
    for e in &bl.entries {
        if e.name.ends_with("norm") {
            continue;
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        let se = qbl.entry(&format!("s.{}", e.name))?;
        let ze = qbl.entry(&format!("z.{}", e.name))?;
        let mut gp = rtn::GroupParams {
            s: qp[se.offset..se.offset + se.numel()].to_vec(),
            z: qp[ze.offset..ze.offset + ze.numel()].to_vec(),
            rows,
            groups_per_row: cols / sch.group,
        };
        rtn::round_zeros(&mut gp, sch);
        // guard against non-positive trained step sizes
        for s in gp.s.iter_mut() {
            if !s.is_finite() || s.abs() < 1e-8 {
                *s = 1e-8;
            }
        }
        let w = bl.slice(bp, &e.name)?;
        let ints = rtn::quantize(w, &gp, sch);
        wq[woff..woff + e.numel()].copy_from_slice(&ints);
        woff += e.numel();
        qp_out[se.offset..se.offset + se.numel()].copy_from_slice(&gp.s);
        qp_out[ze.offset..ze.offset + ze.numel()].copy_from_slice(&gp.z);
    }
    Ok((wq, qp_out))
}

/// Analytic training-memory estimate for one block (bytes): parameters,
/// qp, Adam moments, rounding window, plus one batch of activations x4
/// (input, target, output, grad).
pub fn block_train_mem_bytes(
    bl: &Layout,
    qbl: &Layout,
    batch: usize,
    ctx: usize,
    dim: usize,
) -> usize {
    let params = bl.size * 4 * 3; // bp + m + v
    let window = bl.size * 4 * 2; // lo + hi
    let qp = qbl.size * 4 * 3;
    let acts = batch * ctx * dim * 4 * 4;
    params + window + qp + acts
}

/// Run Block-AP over a calibration pool. `params` is the pretrained fp
/// model (teacher); returns the quantized model + stats.
pub fn run_block_ap(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    hp: &TrainHp,
    pool: &[LmBatch],
    val_pool: &[LmBatch],
) -> Result<BlockApOutput> {
    let t0 = std::time::Instant::now();
    let info = rt.manifest().preset(preset)?;
    let cfg = info.config.clone();
    let g = sch.group;
    let fpl = rt.manifest().layout(preset, "fp")?.clone();
    let bl = rt.manifest().layout(preset, "block")?.clone();
    let qbl = rt.manifest().layout(preset, &format!("qp_block_g{g}"))?.clone();
    let wql = rt.manifest().layout(preset, "wq")?.clone();
    let qpl = rt.manifest().layout(preset, &format!("qp_g{g}"))?.clone();
    let fprl = rt.manifest().layout(preset, "fpr")?.clone();

    let embed = rt.exec(preset, "embed_fwd")?;
    let block_fp = rt.exec(preset, "block_fwd_fp")?;
    let block_q = rt.exec_g(preset, "block_fwd_q", g)?;
    let step_exec = rt.exec_g(preset, "block_ap_step", g)?;
    let loss_exec = rt.exec_g(preset, "block_loss", g)?;

    // 1. activation caches
    let mut h_fp: Vec<Vec<f32>> = Vec::with_capacity(pool.len());
    for b in pool {
        h_fp.push(embed.run1(&[Arg::F32(params), Arg::I32(&b.x)])?);
    }
    let mut h_q = h_fp.clone();
    let mut hv_fp: Vec<Vec<f32>> = Vec::with_capacity(val_pool.len());
    for b in val_pool {
        hv_fp.push(embed.run1(&[Arg::F32(params), Arg::I32(&b.x)])?);
    }
    let mut hv_q = hv_fp.clone();

    // output buffers
    let mut wq_full = vec![0f32; wql.size];
    let mut qp_full = vec![0f32; qpl.size];
    let mut fpr = vec![0f32; fprl.size];

    let (m_wf, m_sf, m_zf, proj) = hp.trainable.masks();
    let qmax = sch.qmax();
    let mut rng = Rng::new(hp.seed).fork("block_ap");

    let mut loss_curves = Vec::new();
    let mut val_losses = Vec::new();
    let mut train_losses = Vec::new();

    for b in 0..cfg.n_layers {
        let bp0 = extract_block(params, &fpl, &bl, b)?;
        let mut bp = bp0.clone();
        let mut qp = init_block_qp(&bp0, &bl, &qbl, sch)?;
        let (lo, hi) = if proj > 0.0 {
            rounding_window(&bp0, &qp, &bl, &qbl, sch)?
        } else {
            (vec![-1e30; bl.size], vec![1e30; bl.size])
        };

        // teacher targets from the ORIGINAL block on fp-propagated inputs
        let mut targets = Vec::with_capacity(pool.len());
        for h in &h_fp {
            targets.push(block_fp.run1(&[Arg::F32(&bp0), Arg::F32(h)])?);
        }

        let mut m_w = vec![0f32; bl.size];
        let mut v_w = vec![0f32; bl.size];
        let mut m_q = vec![0f32; qbl.size];
        let mut v_q = vec![0f32; qbl.size];
        let mut step = 0f32;
        let mut curve = Vec::new();
        // persistent output buffers (run_into): the step writes in
        // place, then swaps with the live state - the epoch loop
        // allocates no fresh output Vecs
        let mut obuf: Vec<Vec<f32>> = Vec::new();

        for _epoch in 0..hp.block_epochs {
            let mut order: Vec<usize> = (0..pool.len()).collect();
            rng.shuffle(&mut order);
            for &i in &order {
                let h_in = match hp.propagation {
                    Propagation::Quant => &h_q[i],
                    Propagation::Fp => &h_fp[i],
                };
                step += 1.0;
                step_exec.run_into(&[
                    Arg::F32(&bp),
                    Arg::F32(&qp),
                    Arg::F32(&m_w),
                    Arg::F32(&v_w),
                    Arg::F32(&m_q),
                    Arg::F32(&v_q),
                    Arg::F32(&lo),
                    Arg::F32(&hi),
                    Arg::F32(h_in),
                    Arg::F32(&targets[i]),
                    Arg::F32(&[qmax]),
                    Arg::Scalar(step),
                    Arg::Scalar(hp.block_lr_w as f32),
                    Arg::Scalar(hp.block_lr_q as f32),
                    Arg::Scalar(m_wf),
                    Arg::Scalar(m_sf),
                    Arg::Scalar(m_zf),
                    Arg::Scalar(proj),
                ], &mut obuf)?;
                std::mem::swap(&mut bp, &mut obuf[0]);
                std::mem::swap(&mut qp, &mut obuf[1]);
                std::mem::swap(&mut m_w, &mut obuf[2]);
                std::mem::swap(&mut v_w, &mut obuf[3]);
                std::mem::swap(&mut m_q, &mut obuf[4]);
                std::mem::swap(&mut v_q, &mut obuf[5]);
                curve.push(obuf[6][0]);
            }
        }

        // post-training reconstruction losses (fig3 overfitting gap)
        let mut tloss = 0f64;
        for (i, h) in h_q.iter().enumerate() {
            let h_in = match hp.propagation {
                Propagation::Quant => h,
                Propagation::Fp => &h_fp[i],
            };
            let l = loss_exec.run1(&[
                Arg::F32(&bp),
                Arg::F32(&qp),
                Arg::F32(h_in),
                Arg::F32(&targets[i]),
                Arg::F32(&[qmax]),
            ])?;
            tloss += l[0] as f64;
        }
        train_losses.push((tloss / pool.len().max(1) as f64) as f32);

        let mut vloss = 0f64;
        for (i, hv) in hv_q.iter().enumerate() {
            let vt = block_fp.run1(&[Arg::F32(&bp0), Arg::F32(&hv_fp[i])])?;
            let h_in = match hp.propagation {
                Propagation::Quant => hv,
                Propagation::Fp => &hv_fp[i],
            };
            let l = loss_exec.run1(&[
                Arg::F32(&bp),
                Arg::F32(&qp),
                Arg::F32(h_in),
                Arg::F32(&vt),
                Arg::F32(&[qmax]),
            ])?;
            vloss += l[0] as f64;
        }
        val_losses.push((vloss / val_pool.len().max(1) as f64) as f32);

        // 3. quantize + assemble + propagate
        let (wq_b, qp_b) = quantize_block(&bp, &qp, &bl, &qbl, sch)?;
        let mut norms = vec![0f32; 2 * cfg.dim];
        norms[..cfg.dim].copy_from_slice(bl.slice(&bp, "attn_norm")?);
        norms[cfg.dim..].copy_from_slice(bl.slice(&bp, "mlp_norm")?);

        // write into the full-model buffers
        let mut woff = 0usize;
        for e in bl.entries.iter().filter(|e| !e.name.ends_with("norm")) {
            let dst = wql.slice_mut(
                &mut wq_full,
                &format!("blocks.{b}.{}", e.name),
            )?;
            dst.copy_from_slice(&wq_b[woff..woff + e.numel()]);
            woff += e.numel();
        }
        for e in &qbl.entries {
            let (which, lin) = e.name.split_once('.').unwrap();
            let dst = qpl.slice_mut(
                &mut qp_full,
                &format!("{which}.blocks.{b}.{lin}"),
            )?;
            dst.copy_from_slice(&qp_b[e.offset..e.offset + e.numel()]);
        }
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.attn_norm"))?
            .copy_from_slice(&norms[..cfg.dim]);
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.mlp_norm"))?
            .copy_from_slice(&norms[cfg.dim..]);

        // propagate caches through the finished block
        for h in h_fp.iter_mut() {
            *h = block_fp.run1(&[Arg::F32(&bp0), Arg::F32(h)])?;
        }
        for h in hv_fp.iter_mut() {
            *h = block_fp.run1(&[Arg::F32(&bp0), Arg::F32(h)])?;
        }
        match hp.propagation {
            Propagation::Quant => {
                for h in h_q.iter_mut() {
                    *h = block_q.run1(&[
                        Arg::F32(&wq_b),
                        Arg::F32(&qp_b),
                        Arg::F32(&norms),
                        Arg::F32(h),
                    ])?;
                }
                for h in hv_q.iter_mut() {
                    *h = block_q.run1(&[
                        Arg::F32(&wq_b),
                        Arg::F32(&qp_b),
                        Arg::F32(&norms),
                        Arg::F32(h),
                    ])?;
                }
            }
            Propagation::Fp => {
                h_q.clone_from(&h_fp);
                hv_q.clone_from(&hv_fp);
            }
        }

        loss_curves.push(curve);
        crate::info!(
            "block_ap[{preset} {}] block {b}/{} train {:.5} val {:.5}",
            sch.tag(),
            cfg.n_layers,
            train_losses[b],
            val_losses[b]
        );
    }

    // 4. fp remainder from the original model
    for name in ["embed", "final_norm", "head"] {
        fprl.slice_mut(&mut fpr, name)?
            .copy_from_slice(fpl.slice(params, name)?);
    }

    let mem = block_train_mem_bytes(
        &bl, &qbl, cfg.block_batch, cfg.block_ctx, cfg.dim,
    );
    Ok(BlockApOutput {
        model: QuantizedModel {
            preset: preset.to_string(),
            scheme: sch,
            wq: wq_full,
            qp: qp_full,
            fpr,
        },
        report: BlockApReport {
            loss_curves,
            val_losses,
            train_losses,
            seconds: t0.elapsed().as_secs_f64(),
            mem_bytes: mem,
        },
    })
}

/// RTN-only quantization of a full fp model (the no-Block-AP baseline and
/// the QLoRA/PEQA starting point) - same assembly path, no training.
pub fn rtn_quantize_model(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
) -> Result<QuantizedModel> {
    let info = rt.manifest().preset(preset)?;
    let cfg = info.config.clone();
    let g = sch.group;
    let fpl = rt.manifest().layout(preset, "fp")?.clone();
    let bl = rt.manifest().layout(preset, "block")?.clone();
    let qbl = rt.manifest().layout(preset, &format!("qp_block_g{g}"))?.clone();
    let wql = rt.manifest().layout(preset, "wq")?.clone();
    let qpl = rt.manifest().layout(preset, &format!("qp_g{g}"))?.clone();
    let fprl = rt.manifest().layout(preset, "fpr")?.clone();

    let mut wq_full = vec![0f32; wql.size];
    let mut qp_full = vec![0f32; qpl.size];
    let mut fpr = vec![0f32; fprl.size];

    for b in 0..cfg.n_layers {
        let bp = extract_block(params, &fpl, &bl, b)?;
        let qp = init_block_qp(&bp, &bl, &qbl, sch)?;
        let (wq_b, qp_b) = quantize_block(&bp, &qp, &bl, &qbl, sch)?;
        let mut woff = 0usize;
        for e in bl.entries.iter().filter(|e| !e.name.ends_with("norm")) {
            wql.slice_mut(&mut wq_full, &format!("blocks.{b}.{}", e.name))?
                .copy_from_slice(&wq_b[woff..woff + e.numel()]);
            woff += e.numel();
        }
        for e in &qbl.entries {
            let (which, lin) = e.name.split_once('.').unwrap();
            qpl.slice_mut(&mut qp_full,
                          &format!("{which}.blocks.{b}.{lin}"))?
                .copy_from_slice(&qp_b[e.offset..e.offset + e.numel()]);
        }
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.attn_norm"))?
            .copy_from_slice(bl.slice(&bp, "attn_norm")?);
        fprl.slice_mut(&mut fpr, &format!("blocks.{b}.mlp_norm"))?
            .copy_from_slice(bl.slice(&bp, "mlp_norm")?);
    }
    for name in ["embed", "final_norm", "head"] {
        fprl.slice_mut(&mut fpr, name)?
            .copy_from_slice(fpl.slice(params, name)?);
    }
    Ok(QuantizedModel {
        preset: preset.to_string(),
        scheme: sch,
        wq: wq_full,
        qp: qp_full,
        fpr,
    })
}
