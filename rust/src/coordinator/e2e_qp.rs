//! E2E-QP trainer - the paper's phase 2 (§3.3).
//!
//! Integer weights stay frozen (no quantization op exists in the graph at
//! all - only dequantization); the coordinator trains qp = [s||z] end-to-end
//! with Adam, a loss mask selecting supervised positions (all-ones for
//! continual pretraining, response spans for instruction tuning), and the
//! Table-7 s/z trainability masks.

use anyhow::Result;

use crate::config::TrainHp;
use crate::coordinator::opt::{AdamState, LrSchedule};
use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Backend};

/// One supervised batch: x, y (B*T each) and a loss mask over y positions.
pub struct E2eBatch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
}

pub struct E2eReport {
    pub losses: Vec<f32>,
    pub seconds: f64,
    /// analytic training-memory estimate (Table 8): model + qp train state
    pub mem_bytes: usize,
}

/// Train the quantized model's step sizes (and optionally zero points)
/// end-to-end over the given batches. Mutates `qm.qp` in place.
pub fn run_e2e_qp(
    rt: &dyn Backend,
    qm: &mut QuantizedModel,
    batches: &[E2eBatch],
    hp: &TrainHp,
) -> Result<E2eReport> {
    let t0 = std::time::Instant::now();
    let preset = qm.preset.clone();
    let exec = rt.exec_g(&preset, "e2e_qp_step", qm.scheme.group)?;
    let mut adam = AdamState::new(qm.qp.len());
    let total = batches.len() * hp.e2e_epochs;
    let sched = LrSchedule::cosine(hp.e2e_lr, total / 20 + 1, total);
    let m_sf = if hp.train_s_e2e { 1.0 } else { 0.0 };
    let m_zf = if hp.train_z_e2e { 1.0 } else { 0.0 };

    let mut losses = Vec::with_capacity(total);
    let mut it = 0usize;
    // persistent output buffers (run_into): swap with the live state
    // instead of allocating fresh outputs every step
    let mut obuf: Vec<Vec<f32>> = Vec::new();
    for _epoch in 0..hp.e2e_epochs {
        for b in batches {
            let step = adam.next_step();
            exec.run_into(&[
                Arg::F32(&qm.wq),
                Arg::F32(&qm.qp),
                Arg::F32(&qm.fpr),
                Arg::F32(&adam.m),
                Arg::F32(&adam.v),
                Arg::I32(&b.x),
                Arg::I32(&b.y),
                Arg::F32(&b.mask),
                Arg::Scalar(step),
                Arg::Scalar(sched.at(it)),
                Arg::Scalar(m_sf), // paper default: s trainable, z frozen
                Arg::Scalar(m_zf),
            ], &mut obuf)?;
            std::mem::swap(&mut qm.qp, &mut obuf[0]);
            std::mem::swap(&mut adam.m, &mut obuf[1]);
            std::mem::swap(&mut adam.v, &mut obuf[2]);
            losses.push(obuf[3][0]);
            it += 1;
        }
        crate::info!(
            "e2e_qp[{preset} {}] epoch done, loss {:.4}",
            qm.scheme.tag(),
            losses.last().copied().unwrap_or(f32::NAN)
        );
    }

    // Memory estimate: frozen model buffers + 3x qp (params, m, v).
    let mem = (qm.wq.len() + qm.fpr.len()) * 4
        + qm.qp.len() * 4 * 3
        + batches.first().map(|b| b.x.len() * 8).unwrap_or(0);
    Ok(E2eReport {
        losses,
        seconds: t0.elapsed().as_secs_f64(),
        mem_bytes: mem,
    })
}

/// Adapt LM batches (continual pretraining: mask = all ones).
pub fn lm_batches(pool: &[crate::data::loader::LmBatch]) -> Vec<E2eBatch> {
    pool.iter()
        .map(|b| E2eBatch {
            x: b.x.clone(),
            y: b.y.clone(),
            mask: vec![1.0; b.y.len()],
        })
        .collect()
}

/// Adapt instruction batches (Alpaca-style: response-span masks).
pub fn instr_batches(
    loader: &mut crate::data::loader::InstrLoader,
    n: usize,
) -> Vec<E2eBatch> {
    (0..n)
        .map(|_| {
            let b = loader.next_batch();
            E2eBatch { x: b.x, y: b.y, mask: b.mask }
        })
        .collect()
}
