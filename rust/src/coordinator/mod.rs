//! The L3 coordination contribution: pretraining substrate, the Block-AP
//! scheduler (activation caching + block-by-block masked training), the
//! E2E-QP trainer, and the two-phase pipeline.
pub mod block_ap;
pub mod e2e_qp;
pub mod opt;
pub mod pipeline;
pub mod pretrain;
