//! Host-side optimizer state & schedules. The Adam *math* runs inside the
//! lowered train-step graphs (python/compile/model.py::adam_update); the
//! coordinator owns the buffers and the learning-rate schedule, and this
//! module keeps a bit-parity reference implementation used in golden tests
//! against the python/artifact side.

/// Adam moment buffers for one flat parameter group.
#[derive(Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
}

impl AdamState {
    pub fn new(n: usize) -> AdamState {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// 1-based step count fed to the graph as f32 (bias correction).
    pub fn next_step(&mut self) -> f32 {
        self.step += 1;
        self.step as f32
    }
}

/// Reference Adam matching model.py::adam_update exactly (b1=0.9, b2=0.999,
/// eps=1e-8) - used by tests to validate artifact numerics.
pub fn adam_ref(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    lr: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let bc1 = 1.0 - B1.powf(step);
    let bc2 = 1.0 - B2.powf(step);
    for i in 0..p.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

/// Cosine decay with linear warmup (the schedule used by both phases).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f64,
    pub warmup: usize,
    pub total: usize,
    pub min_frac: f64,
}

impl LrSchedule {
    pub fn constant(base: f64) -> LrSchedule {
        LrSchedule { base, warmup: 0, total: 0, min_frac: 1.0 }
    }

    pub fn cosine(base: f64, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule { base, warmup, total, min_frac: 0.1 }
    }

    pub fn at(&self, step: usize) -> f32 {
        if self.total == 0 {
            return self.base as f32;
        }
        if step < self.warmup {
            return (self.base * (step + 1) as f64 / self.warmup.max(1) as f64)
                as f32;
        }
        let t = (step - self.warmup) as f64
            / (self.total.saturating_sub(self.warmup)).max(1) as f64;
        let t = t.min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        (self.base * (self.min_frac + (1.0 - self.min_frac) * cos)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_golden_vector_matches_python() {
        // Same golden vector as python/tests/test_model.py::
        // test_adam_golden_vector (independent implementations agree).
        let mut p = vec![1.0f32, -2.0, 0.5];
        let g = vec![0.1f32, -0.2, 0.3];
        let mut m = vec![0.01f32, 0.0, -0.05];
        let mut v = vec![0.001f32, 0.0002, 0.0];
        adam_ref(&mut p, &g, &mut m, &mut v, 3.0, 0.01);
        let want_m = [0.019, -0.02, -0.015];
        let want_v = [
            0.001 * 0.999 + 0.001 * 0.01,
            0.0002 * 0.999 + 0.001 * 0.04,
            0.001 * 0.09,
        ];
        for i in 0..3 {
            assert!((m[i] - want_m[i]).abs() < 1e-6, "m[{i}]={}", m[i]);
            assert!((v[i] - want_v[i]).abs() < 1e-7, "v[{i}]={}", v[i]);
        }
        // p moves opposite to the sign of the updated momentum
        // (m = [0.019, -0.02, -0.015])
        assert!(p[0] < 1.0 && p[1] > -2.0 && p[2] > 0.5);
    }

    #[test]
    fn schedule_constant() {
        let s = LrSchedule::constant(1e-3);
        assert_eq!(s.at(0), 1e-3);
        assert_eq!(s.at(1000), 1e-3);
    }

    #[test]
    fn schedule_warmup_and_decay() {
        let s = LrSchedule::cosine(1.0, 10, 100);
        assert!(s.at(0) < 0.2);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!(s.at(50) < 1.0);
        // floors at min_frac * base = 0.1 (the old form compared s.at(99)
        // to itself and was vacuously true)
        assert!(s.at(99) >= 0.1 - 1e-6, "late lr {} below floor", s.at(99));
        assert!((s.at(200) - 0.1).abs() < 1e-5);
        // monotone decreasing after warmup
        assert!(s.at(20) > s.at(60));
    }

    #[test]
    fn adam_state_steps() {
        let mut st = AdamState::new(4);
        assert_eq!(st.next_step(), 1.0);
        assert_eq!(st.next_step(), 2.0);
        assert_eq!(st.m.len(), 4);
    }
}
