//! The EfficientQAT pipeline: Block-AP then E2E-QP (paper Fig. 2), plus the
//! ablation switches that turn either phase off (Table 5).

use anyhow::Result;

use crate::config::{QuantScheme, TrainHp};
use crate::coordinator::block_ap::{run_block_ap, rtn_quantize_model,
                                   BlockApReport};
use crate::coordinator::e2e_qp::{lm_batches, run_e2e_qp, E2eReport};
use crate::data::corpus::{Domain, World};
use crate::data::loader::LmLoader;
use crate::model::quantized::QuantizedModel;
use crate::runtime::Backend;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PhaseToggle {
    pub block_ap: bool,
    pub e2e_qp: bool,
}

impl Default for PhaseToggle {
    fn default() -> Self {
        PhaseToggle { block_ap: true, e2e_qp: true }
    }
}

pub struct PipelineReport {
    pub block_ap: Option<BlockApReport>,
    pub e2e: Option<E2eReport>,
    pub total_seconds: f64,
}

/// Full EfficientQAT: pretrained fp params -> quantized model.
///
/// Calibration (Block-AP) and training (E2E-QP) pools are drawn from
/// `domain` with disjoint seeds; validation uses a third seed (fig3).
pub fn efficient_qat(
    rt: &dyn Backend,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    hp: &TrainHp,
    world: &World,
    domain: &Domain,
    phases: PhaseToggle,
) -> Result<(QuantizedModel, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let cfg = rt.manifest().preset(preset)?.config.clone();

    // Block-AP calibration pool ("4096 samples from RedPajama" analog)
    let n_cal = (hp.block_samples + cfg.block_batch - 1) / cfg.block_batch;
    let mut cal_loader = LmLoader::new(
        world, domain, hp.seed ^ 0xB10C, cfg.block_batch, cfg.block_ctx,
    );
    let cal_pool = cal_loader.sample_pool(n_cal);
    let mut val_loader = LmLoader::new(
        world, domain, hp.seed ^ 0x7A11, cfg.block_batch, cfg.block_ctx,
    );
    let val_pool = val_loader.sample_pool(8.min(n_cal.max(1)));

    let (mut qm, block_report) = if phases.block_ap {
        let out = run_block_ap(rt, preset, params, sch, hp, &cal_pool,
                               &val_pool)?;
        (out.model, Some(out.report))
    } else {
        (rtn_quantize_model(rt, preset, params, sch)?, None)
    };

    let e2e_report = if phases.e2e_qp {
        let n_e2e = (hp.e2e_samples + cfg.e2e_batch - 1) / cfg.e2e_batch;
        let mut e2e_loader = LmLoader::new(
            world, domain, hp.seed ^ 0xE2E0, cfg.e2e_batch, cfg.e2e_ctx,
        );
        let pool = e2e_loader.sample_pool(n_e2e);
        let batches = lm_batches(&pool);
        Some(run_e2e_qp(rt, &mut qm, &batches, hp)?)
    } else {
        None
    };

    Ok((
        qm,
        PipelineReport {
            block_ap: block_report,
            e2e: e2e_report,
            total_seconds: t0.elapsed().as_secs_f64(),
        },
    ))
}
