//! Full-precision pretraining loop (substrate): creates the model that the
//! EfficientQAT pipeline quantizes. One fused PJRT executable per step; the
//! coordinator owns parameters, Adam buffers, the data pipeline and the lr
//! schedule.

use anyhow::Result;

use crate::coordinator::opt::{AdamState, LrSchedule};
use crate::data::loader::LmLoader;
use crate::model::init::init_fp_params;
use crate::runtime::{Arg, Backend};

pub struct PretrainReport {
    pub losses: Vec<f32>,
    pub seconds: f64,
}

pub struct PretrainOpts {
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainOpts {
    fn default() -> Self {
        PretrainOpts { steps: 300, lr: 3e-3, seed: 1, log_every: 20 }
    }
}

/// Train from scratch; returns (flat params, report).
pub fn pretrain(
    rt: &dyn Backend,
    preset: &str,
    loader: &mut LmLoader,
    opts: &PretrainOpts,
) -> Result<(Vec<f32>, PretrainReport)> {
    let fpl = rt.manifest().layout(preset, "fp")?;
    let params = init_fp_params(fpl, opts.seed);
    pretrain_from(rt, preset, params, loader, opts)
}

/// Continue training from existing params (used by naive-QAT comparisons).
pub fn pretrain_from(
    rt: &dyn Backend,
    preset: &str,
    mut params: Vec<f32>,
    loader: &mut LmLoader,
    opts: &PretrainOpts,
) -> Result<(Vec<f32>, PretrainReport)> {
    let t0 = std::time::Instant::now();
    let exec = rt.exec(preset, "pretrain_step")?;
    let mut adam = AdamState::new(params.len());
    let sched = LrSchedule::cosine(opts.lr, opts.steps / 20 + 1, opts.steps);
    let mut losses = Vec::with_capacity(opts.steps);

    // persistent output buffers: the step writes in place, then swaps
    // with the live state - no per-step output allocation (run_into)
    let mut obuf: Vec<Vec<f32>> = Vec::new();
    for it in 0..opts.steps {
        let batch = loader.next_batch();
        let step = adam.next_step();
        let lr = sched.at(it);
        exec.run_into(&[
            Arg::F32(&params),
            Arg::F32(&adam.m),
            Arg::F32(&adam.v),
            Arg::I32(&batch.x),
            Arg::I32(&batch.y),
            Arg::Scalar(step),
            Arg::Scalar(lr),
        ], &mut obuf)?;
        std::mem::swap(&mut params, &mut obuf[0]);
        std::mem::swap(&mut adam.m, &mut obuf[1]);
        std::mem::swap(&mut adam.v, &mut obuf[2]);
        let loss = obuf[3][0];
        losses.push(loss);
        if opts.log_every > 0 && (it % opts.log_every == 0
            || it + 1 == opts.steps)
        {
            crate::info!(
                "pretrain[{preset}] step {it:4}/{} loss {loss:.4} lr {lr:.2e}",
                opts.steps
            );
        }
    }
    Ok((
        params,
        PretrainReport { losses, seconds: t0.elapsed().as_secs_f64() },
    ))
}
