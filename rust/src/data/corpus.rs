//! Synthetic corpus substrate (replaces RedPajama / C4 / WikiText2).
//!
//! A seeded "world" fixes the latent structure every domain shares:
//!   * topics - disjoint token ranges under a hidden permutation
//!   * facts  - deterministic token bigrams a->b ("knowledge")
//! Domains differ in *diversity* (topic mixing, Zipf skew, structure
//! density), which is exactly the axis the paper's Table 13 calibration
//! ablation probes (WikiText2 narrow vs C4/RedPajama diverse).
//!
//! The structure makes the five zero-shot suites in tasks.rs learnable:
//! facts -> fact-recall, copy windows -> copy, ascending runs -> successor,
//! repeated bigrams -> induction, topic coherence -> topic agreement.

use crate::util::rng::{Rng, Zipf};

/// Reserved special token ids (kept below any topic token).
pub const TOK_SEP: i32 = 0; // document separator
pub const TOK_INS: i32 = 1; // instruction marker
pub const TOK_ANS: i32 = 2; // answer marker
pub const TOK_EOS: i32 = 3; // end of answer
pub const TOK_Q: i32 = 4; // question marker (MMLU-like)
pub const N_SPECIAL: usize = 8;

/// Shared latent structure across all domains of one experiment.
#[derive(Clone)]
pub struct World {
    pub vocab: usize,
    pub n_topics: usize,
    pub topic_size: usize,
    /// hidden permutation of the non-special token space
    perm: Vec<i32>,
    /// deterministic fact bigrams: fact_b[i] follows fact_a[i]
    pub facts: Vec<(i32, i32)>,
}

impl World {
    pub fn new(vocab: usize, seed: u64) -> World {
        assert!(vocab > N_SPECIAL + 64, "vocab too small: {vocab}");
        let usable = vocab - N_SPECIAL;
        let topic_size = 48.min(usable / 4);
        let n_topics = usable / topic_size;
        let mut rng = Rng::new(seed).fork("world");
        let mut perm: Vec<i32> =
            (N_SPECIAL as i32..vocab as i32).collect();
        rng.shuffle(&mut perm);

        // facts: distinct heads a (one per fact), arbitrary tails b != a
        let n_facts = (usable / 8).max(8);
        let heads = rng.sample_distinct(usable, n_facts);
        let mut facts = Vec::with_capacity(n_facts);
        for h in heads {
            let a = perm[h];
            let mut b = perm[rng.below(usable)];
            while b == a {
                b = perm[rng.below(usable)];
            }
            facts.push((a, b));
        }
        World { vocab, n_topics, topic_size, perm, facts }
    }

    /// t-th topic's token pool.
    pub fn topic_tokens(&self, t: usize) -> &[i32] {
        let t = t % self.n_topics;
        &self.perm[t * self.topic_size..(t + 1) * self.topic_size]
    }

    /// Which topic owns this token (None for specials / leftover tokens).
    pub fn topic_of(&self, tok: i32) -> Option<usize> {
        let idx = self.perm.iter().position(|&p| p == tok)?;
        let t = idx / self.topic_size;
        (t < self.n_topics).then_some(t)
    }

    pub fn fact_tail(&self, a: i32) -> Option<i32> {
        self.facts.iter().find(|(fa, _)| *fa == a).map(|(_, b)| *b)
    }

    /// A non-special token chosen uniformly (for distractors).
    pub fn random_token(&self, rng: &mut Rng) -> i32 {
        self.perm[rng.below(self.perm.len())]
    }
}

/// Generation knobs of one corpus domain.
#[derive(Clone, Debug)]
pub struct Domain {
    pub name: &'static str,
    /// Zipf exponent within a topic (higher = more peaked = lower entropy)
    pub zipf_a: f64,
    /// topics mixed inside one document
    pub topics_per_doc: usize,
    /// probability a step emits a fact pair (a then b)
    pub fact_density: f64,
    /// probability a step copies the token from `copy_lag` back
    pub copy_prob: f64,
    /// probability a step starts a 4-token ascending run
    pub run_prob: f64,
    pub copy_lag: usize,
    pub doc_len: (usize, usize),
}

/// Narrow, low-entropy domain (WikiText2 analog).
pub fn domain_wiki() -> Domain {
    Domain { name: "wiki", zipf_a: 1.4, topics_per_doc: 1,
             fact_density: 0.10, copy_prob: 0.15, run_prob: 0.05,
             copy_lag: 6, doc_len: (96, 192) }
}

/// Diverse web-crawl analog (C4).
pub fn domain_c4() -> Domain {
    Domain { name: "c4", zipf_a: 1.05, topics_per_doc: 3,
             fact_density: 0.08, copy_prob: 0.10, run_prob: 0.05,
             copy_lag: 5, doc_len: (48, 160) }
}

/// Diverse mixed-source analog (RedPajama) - the paper's default
/// calibration set.
pub fn domain_redpajama() -> Domain {
    Domain { name: "redpajama", zipf_a: 1.15, topics_per_doc: 2,
             fact_density: 0.09, copy_prob: 0.12, run_prob: 0.05,
             copy_lag: 5, doc_len: (64, 176) }
}

pub fn domain_by_name(name: &str) -> anyhow::Result<Domain> {
    Ok(match name {
        "wiki" | "wikitext2" => domain_wiki(),
        "c4" => domain_c4(),
        "redpajama" | "rp" => domain_redpajama(),
        _ => anyhow::bail!("unknown domain '{name}'"),
    })
}

/// Infinite deterministic token stream for (world, domain, seed).
pub struct CorpusGen {
    world: World,
    domain: Domain,
    rng: Rng,
    zipf: Zipf,
    buf: Vec<i32>,
    pos: usize,
}

impl CorpusGen {
    pub fn new(world: &World, domain: &Domain, seed: u64) -> CorpusGen {
        let rng = Rng::new(seed).fork(domain.name);
        let zipf = Zipf::new(world.topic_size, domain.zipf_a);
        CorpusGen {
            world: world.clone(),
            domain: domain.clone(),
            rng,
            zipf,
            buf: Vec::new(),
            pos: 0,
        }
    }

    fn gen_doc(&mut self) {
        let d = &self.domain;
        let len = self.rng.range(d.doc_len.0, d.doc_len.1);
        let mut topics = Vec::with_capacity(d.topics_per_doc);
        for _ in 0..d.topics_per_doc {
            topics.push(self.rng.below(self.world.n_topics));
        }
        self.buf.push(TOK_SEP);
        let start = self.buf.len();
        while self.buf.len() - start < len {
            let r = self.rng.f64();
            if r < d.fact_density && !self.world.facts.is_empty() {
                let (a, b) =
                    self.world.facts[self.rng.below(self.world.facts.len())];
                self.buf.push(a);
                self.buf.push(b);
            } else if r < d.fact_density + d.copy_prob
                && self.buf.len() - start > d.copy_lag
            {
                let t = self.buf[self.buf.len() - d.copy_lag];
                self.buf.push(t);
            } else if r < d.fact_density + d.copy_prob + d.run_prob {
                // ascending run inside the permuted topic pool
                let t = topics[self.rng.below(topics.len())];
                let pool = self.world.topic_tokens(t);
                let i0 = self.rng.below(pool.len().saturating_sub(4).max(1));
                for k in 0..4.min(pool.len()) {
                    self.buf.push(pool[(i0 + k) % pool.len()]);
                }
            } else {
                let t = topics[self.rng.below(topics.len())];
                let pool = self.world.topic_tokens(t);
                self.buf.push(pool[self.zipf.sample(&mut self.rng)]);
            }
        }
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> i32 {
        while self.pos >= self.buf.len() {
            // keep memory bounded: drop consumed prefix occasionally
            if self.pos > 1 << 16 {
                self.buf.drain(..self.pos);
                self.pos = 0;
            }
            self.gen_doc();
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        t
    }

    pub fn fill(&mut self, out: &mut [i32]) {
        for o in out.iter_mut() {
            *o = self.next_token();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(512, 7)
    }

    #[test]
    fn world_topics_are_disjoint() {
        let w = world();
        let mut seen = std::collections::HashSet::new();
        for t in 0..w.n_topics {
            for &tok in w.topic_tokens(t) {
                assert!(seen.insert(tok), "token {tok} in two topics");
                assert!(tok >= N_SPECIAL as i32 && (tok as usize) < w.vocab);
            }
        }
    }

    #[test]
    fn topic_of_inverts_topic_tokens() {
        let w = world();
        for t in 0..w.n_topics {
            for &tok in w.topic_tokens(t) {
                assert_eq!(w.topic_of(tok), Some(t));
            }
        }
        assert_eq!(w.topic_of(TOK_SEP), None);
    }

    #[test]
    fn facts_unique_heads_and_in_range() {
        let w = world();
        let mut heads = std::collections::HashSet::new();
        for &(a, b) in &w.facts {
            assert!(heads.insert(a));
            assert_ne!(a, b);
            assert_eq!(w.fact_tail(a), Some(b));
        }
    }

    #[test]
    fn stream_is_deterministic() {
        let w = world();
        let mut g1 = CorpusGen::new(&w, &domain_redpajama(), 11);
        let mut g2 = CorpusGen::new(&w, &domain_redpajama(), 11);
        let mut a = vec![0; 2000];
        let mut b = vec![0; 2000];
        g1.fill(&mut a);
        g2.fill(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_and_domains_differ() {
        let w = world();
        let mut a = vec![0; 500];
        let mut b = vec![0; 500];
        CorpusGen::new(&w, &domain_redpajama(), 1).fill(&mut a);
        CorpusGen::new(&w, &domain_redpajama(), 2).fill(&mut b);
        assert_ne!(a, b);
        CorpusGen::new(&w, &domain_wiki(), 1).fill(&mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn facts_appear_as_adjacent_bigrams() {
        let w = world();
        let mut g = CorpusGen::new(&w, &domain_redpajama(), 3);
        let mut s = vec![0; 50_000];
        g.fill(&mut s);
        // count occurrences of fact heads followed by the right tail
        let mut hits = 0usize;
        let mut total = 0usize;
        for win in s.windows(2) {
            if let Some(b) = w.fact_tail(win[0]) {
                total += 1;
                if win[1] == b {
                    hits += 1;
                }
            }
        }
        assert!(total > 100);
        // heads also occur as plain topic tokens, so the tail doesn't always
        // follow - but P(tail|head) must be far above chance (~1/vocab)
        assert!(
            hits as f64 / total as f64 > 0.2,
            "fact bigram rate {hits}/{total}"
        );
    }

    #[test]
    fn wiki_is_lower_entropy_than_c4() {
        let w = world();
        let entropy = |dom: &Domain| {
            let mut g = CorpusGen::new(&w, dom, 5);
            let mut s = vec![0; 30_000];
            g.fill(&mut s);
            let mut counts = vec![0f64; w.vocab];
            for &t in &s {
                counts[t as usize] += 1.0;
            }
            let n: f64 = counts.iter().sum();
            counts
                .iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| {
                    let p = c / n;
                    -p * p.ln()
                })
                .sum::<f64>()
        };
        let h_wiki = entropy(&domain_wiki());
        let h_c4 = entropy(&domain_c4());
        assert!(h_wiki < h_c4, "wiki={h_wiki:.3} c4={h_c4:.3}");
    }

    #[test]
    fn tokens_in_vocab_range() {
        let w = world();
        let mut g = CorpusGen::new(&w, &domain_c4(), 9);
        let mut s = vec![0; 10_000];
        g.fill(&mut s);
        for &t in &s {
            assert!(t >= 0 && (t as usize) < w.vocab);
        }
    }
}
