//! Deterministic batchers: LM next-token batches from a corpus stream, and
//! instruction batches with loss masks. Train/val splits use disjoint
//! stream seeds (fig3 measures exactly this train/val gap).

use crate::data::corpus::{CorpusGen, Domain, World};
use crate::data::tasks::{gen_instruction, InstrExample};

/// (x, y) next-token LM batches of fixed geometry.
pub struct LmLoader {
    generator: CorpusGen,
    pub batch: usize,
    pub ctx: usize,
}

#[derive(Clone, Debug)]
pub struct LmBatch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
}

impl LmLoader {
    pub fn new(world: &World, domain: &Domain, seed: u64, batch: usize,
               ctx: usize) -> LmLoader {
        LmLoader { generator: CorpusGen::new(world, domain, seed), batch, ctx }
    }

    /// Next batch: x[b] = tokens[t..t+ctx], y[b] = tokens[t+1..t+ctx+1].
    pub fn next_batch(&mut self) -> LmBatch {
        let n = self.batch * self.ctx;
        let mut raw = vec![0i32; self.batch * (self.ctx + 1)];
        self.generator.fill(&mut raw);
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for b in 0..self.batch {
            let row = &raw[b * (self.ctx + 1)..(b + 1) * (self.ctx + 1)];
            x.extend_from_slice(&row[..self.ctx]);
            y.extend_from_slice(&row[1..]);
        }
        LmBatch { x, y }
    }

    /// A fixed sample pool of `n` batches (the paper's "4096 samples from
    /// RedPajama"); epochs re-iterate the same pool.
    pub fn sample_pool(&mut self, n_batches: usize) -> Vec<LmBatch> {
        (0..n_batches).map(|_| self.next_batch()).collect()
    }
}

/// Instruction batches with response-span loss masks.
pub struct InstrLoader {
    examples: Vec<InstrExample>,
    pub batch: usize,
    pub ctx: usize,
    cursor: usize,
}

#[derive(Clone, Debug)]
pub struct InstrBatch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
}

impl InstrLoader {
    pub fn new(world: &World, seed: u64, n_examples: usize, batch: usize,
               ctx: usize) -> InstrLoader {
        let examples: Vec<_> =
            gen_instruction(world, ctx + 1, seed).take(n_examples).collect();
        InstrLoader { examples, batch, ctx, cursor: 0 }
    }

    pub fn next_batch(&mut self) -> InstrBatch {
        let n = self.batch * self.ctx;
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        let mut mask = Vec::with_capacity(n);
        for _ in 0..self.batch {
            let ex = &self.examples[self.cursor % self.examples.len()];
            self.cursor += 1;
            x.extend_from_slice(&ex.tokens[..self.ctx]);
            y.extend_from_slice(&ex.tokens[1..]);
            // mask aligns with y (predict token i+1 at position i)
            mask.extend_from_slice(&ex.mask[1..]);
        }
        InstrBatch { x, y, mask }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::domain_redpajama;

    fn world() -> World {
        World::new(512, 7)
    }

    #[test]
    fn lm_batch_shapes_and_shift() {
        let w = world();
        let mut l = LmLoader::new(&w, &domain_redpajama(), 1, 2, 16);
        let b = l.next_batch();
        assert_eq!(b.x.len(), 32);
        assert_eq!(b.y.len(), 32);
        // y is x shifted by one within each row
        for row in 0..2 {
            for t in 0..15 {
                assert_eq!(b.y[row * 16 + t], b.x[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn lm_loader_deterministic_and_seed_sensitive() {
        let w = world();
        let b1 = LmLoader::new(&w, &domain_redpajama(), 5, 2, 8).next_batch();
        let b2 = LmLoader::new(&w, &domain_redpajama(), 5, 2, 8).next_batch();
        let b3 = LmLoader::new(&w, &domain_redpajama(), 6, 2, 8).next_batch();
        assert_eq!(b1.x, b2.x);
        assert_ne!(b1.x, b3.x);
    }

    #[test]
    fn sample_pool_is_stable_across_epochs() {
        let w = world();
        let mut l = LmLoader::new(&w, &domain_redpajama(), 5, 2, 8);
        let pool = l.sample_pool(4);
        assert_eq!(pool.len(), 4);
        // batches differ from each other (stream advances)
        assert_ne!(pool[0].x, pool[1].x);
    }

    #[test]
    fn instr_batches_align_masks() {
        let w = world();
        let mut l = InstrLoader::new(&w, 3, 16, 2, 32);
        let b = l.next_batch();
        assert_eq!(b.x.len(), 64);
        assert_eq!(b.mask.len(), 64);
        // some supervision present
        assert!(b.mask.iter().sum::<f32>() > 0.0);
        // supervised positions: predicted token y is response content
        for i in 0..64 {
            if b.mask[i] == 1.0 {
                let y = b.y[i];
                assert!(
                    y == crate::data::corpus::TOK_EOS
                        || w.facts.iter().any(|&(_, t)| t == y)
                );
            }
        }
    }

    #[test]
    fn instr_loader_cycles_pool() {
        let w = world();
        let mut l = InstrLoader::new(&w, 3, 2, 1, 16);
        let b1 = l.next_batch();
        let _ = l.next_batch();
        let b3 = l.next_batch(); // wraps back to example 0
        assert_eq!(b1.x, b3.x);
    }
}
