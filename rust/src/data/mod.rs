//! Synthetic data substrates: corpora, evaluation suites, batchers.
pub mod corpus;
pub mod loader;
pub mod tasks;
