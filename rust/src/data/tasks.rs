//! Synthetic evaluation suites (replace lm-eval zero-shot tasks, MMLU, and
//! Alpaca - DESIGN.md §4).
//!
//! Five zero-shot multiple-choice suites mirror the paper's WinoGrande /
//! PIQA / HellaSwag / ARC-e / ARC-c set mechanically: each item is a context
//! plus K options; the model scores each option by total log-likelihood and
//! must rank the gold option first. Each suite probes one structure the
//! pretraining corpus actually contains (corpus.rs).
//!
//! The MMLU analog groups fact families into "subjects" and is evaluated
//! few-shot; the Alpaca analog is an instruction-format dataset whose loss
//! is masked to the response span.

use crate::data::corpus::{World, TOK_ANS, TOK_EOS, TOK_INS, TOK_Q, TOK_SEP};
use crate::util::rng::Rng;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub ctx: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub correct: usize,
}

pub const ZEROSHOT_SUITES: [&str; 5] =
    ["fact_recall", "copy", "successor", "induction", "topic"];

/// Generate `n` items of the given suite.
pub fn gen_suite(world: &World, suite: &str, n: usize, seed: u64)
                 -> Vec<McItem> {
    let mut rng = Rng::new(seed).fork(suite);
    (0..n)
        .map(|_| match suite {
            "fact_recall" => fact_recall(world, &mut rng),
            "copy" => copy_task(world, &mut rng),
            "successor" => successor(world, &mut rng),
            "induction" => induction(world, &mut rng),
            "topic" => topic_task(world, &mut rng),
            _ => panic!("unknown suite {suite}"),
        })
        .collect()
}

fn distractors(world: &World, rng: &mut Rng, gold: i32, k: usize)
               -> Vec<i32> {
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        let t = world.random_token(rng);
        if t != gold && !out.contains(&t) {
            out.push(t);
        }
    }
    out
}

fn mc_single_token(world: &World, rng: &mut Rng, ctx: Vec<i32>, gold: i32)
                   -> McItem {
    let mut options: Vec<Vec<i32>> =
        distractors(world, rng, gold, 3).into_iter().map(|t| vec![t])
            .collect();
    let correct = rng.below(4);
    options.insert(correct, vec![gold]);
    McItem { ctx, options, correct }
}

/// ARC-style knowledge probe: context primes topic then ends with a fact
/// head; gold continuation is the fact tail.
fn fact_recall(world: &World, rng: &mut Rng) -> McItem {
    let (a, b) = world.facts[rng.below(world.facts.len())];
    let topic = world.topic_of(a).unwrap_or(0);
    let pool = world.topic_tokens(topic);
    let mut ctx = vec![TOK_SEP];
    for _ in 0..6 {
        ctx.push(pool[rng.below(pool.len())]);
    }
    ctx.push(a);
    mc_single_token(world, rng, ctx, b)
}

/// HellaSwag-style surface continuation: the context repeats a window with
/// lag L; gold option continues the copy.
fn copy_task(world: &World, rng: &mut Rng) -> McItem {
    let lag = 5usize;
    let pool = world.topic_tokens(rng.below(world.n_topics));
    let seq: Vec<i32> =
        (0..lag).map(|_| pool[rng.below(pool.len())]).collect();
    let mut ctx = vec![TOK_SEP];
    ctx.extend_from_slice(&seq);
    ctx.extend_from_slice(&seq[..lag - 1]); // replay all but last
    mc_single_token(world, rng, ctx, seq[lag - 1])
}

/// PIQA-style pattern completion: an ascending run in the hidden topic
/// order; gold option is the next element.
fn successor(world: &World, rng: &mut Rng) -> McItem {
    let t = rng.below(world.n_topics);
    let pool = world.topic_tokens(t);
    let i0 = rng.below(pool.len() - 4);
    let ctx = vec![
        TOK_SEP, pool[i0], pool[i0 + 1], pool[i0 + 2],
    ];
    mc_single_token(world, rng, ctx, pool[i0 + 3])
}

/// WinoGrande-style binding: [x y ... x ?] -> y (classic induction).
fn induction(world: &World, rng: &mut Rng) -> McItem {
    let pool = world.topic_tokens(rng.below(world.n_topics));
    let x = pool[rng.below(pool.len())];
    let mut y = pool[rng.below(pool.len())];
    while y == x {
        y = pool[rng.below(pool.len())];
    }
    let mut ctx = vec![TOK_SEP, x, y];
    for _ in 0..4 {
        ctx.push(pool[rng.below(pool.len())]);
    }
    ctx.push(x);
    mc_single_token(world, rng, ctx, y)
}

/// Topic-coherence probe: context from one topic; gold option is another
/// token of the same topic vs tokens of foreign topics.
fn topic_task(world: &World, rng: &mut Rng) -> McItem {
    let t = rng.below(world.n_topics);
    let pool = world.topic_tokens(t);
    let mut ctx = vec![TOK_SEP];
    for _ in 0..8 {
        ctx.push(pool[rng.below(pool.len())]);
    }
    let gold = pool[rng.below(pool.len())];
    let mut options = Vec::new();
    while options.len() < 3 {
        let ft = rng.below(world.n_topics);
        if ft == t {
            continue;
        }
        let fp = world.topic_tokens(ft);
        options.push(vec![fp[rng.below(fp.len())]]);
    }
    let correct = rng.below(4);
    options.insert(correct, vec![gold]);
    McItem { ctx, options, correct }
}

// ---------------------------------------------------------------------------
// MMLU analog (few-shot, subject-grouped fact QA)
// ---------------------------------------------------------------------------

/// Few-shot MC exam: subjects partition the fact list; each question shows
/// `shots` solved (Q a ANS b EOS) examples then asks a new head.
pub fn gen_mmlu(world: &World, n_subjects: usize, per_subject: usize,
                shots: usize, seed: u64) -> Vec<McItem> {
    let mut rng = Rng::new(seed).fork("mmlu");
    let nf = world.facts.len();
    let per = (nf / n_subjects).max(2);
    let mut items = Vec::new();
    for s in 0..n_subjects {
        let subject = &world.facts[s * per..((s + 1) * per).min(nf)];
        if subject.len() < shots + 1 {
            continue;
        }
        for _ in 0..per_subject {
            let qi = rng.below(subject.len());
            let mut ctx = vec![TOK_SEP];
            let mut used = vec![qi];
            for _ in 0..shots {
                let mut ei = rng.below(subject.len());
                while used.contains(&ei) {
                    ei = rng.below(subject.len());
                }
                used.push(ei);
                let (a, b) = subject[ei];
                ctx.extend_from_slice(&[TOK_Q, a, TOK_ANS, b, TOK_EOS]);
            }
            let (a, b) = subject[qi];
            ctx.extend_from_slice(&[TOK_Q, a, TOK_ANS]);
            items.push(mc_single_token(world, &mut rng, ctx, b));
        }
    }
    items
}

// ---------------------------------------------------------------------------
// Alpaca analog (instruction corpus with response loss mask)
// ---------------------------------------------------------------------------

/// One instruction example rendered into a fixed-length window.
#[derive(Clone, Debug)]
pub struct InstrExample {
    pub tokens: Vec<i32>,
    /// 1.0 where loss applies (the response span), else 0.0
    pub mask: Vec<f32>,
}

/// Instruction item: [INS a_topic_ctx a ANS] b [EOS]; response = b EOS.
/// Teaching the INS/ANS format transfers fact knowledge into the QA format
/// used by the MMLU analog - same mechanism as Alpaca -> MMLU in the paper.
pub fn gen_instruction(world: &World, len: usize, seed: u64)
                       -> impl Iterator<Item = InstrExample> + '_ {
    let mut rng = Rng::new(seed).fork("alpaca");
    std::iter::from_fn(move || {
        let mut toks = Vec::with_capacity(len);
        let mut mask = Vec::with_capacity(len);
        while toks.len() < len {
            let (a, b) = world.facts[rng.below(world.facts.len())];
            let topic = world.topic_of(a).unwrap_or(0);
            let pool = world.topic_tokens(topic);
            let push = |t: i32, m: f32, toks: &mut Vec<i32>,
                            mask: &mut Vec<f32>| {
                if toks.len() < len {
                    toks.push(t);
                    mask.push(m);
                }
            };
            push(TOK_INS, 0.0, &mut toks, &mut mask);
            for _ in 0..3 {
                push(pool[rng.below(pool.len())], 0.0, &mut toks, &mut mask);
            }
            push(a, 0.0, &mut toks, &mut mask);
            push(TOK_ANS, 0.0, &mut toks, &mut mask);
            push(b, 1.0, &mut toks, &mut mask);
            push(TOK_EOS, 1.0, &mut toks, &mut mask);
        }
        Some(InstrExample { tokens: toks, mask })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(512, 7)
    }

    #[test]
    fn suites_generate_valid_items() {
        let w = world();
        for suite in ZEROSHOT_SUITES {
            let items = gen_suite(&w, suite, 50, 3);
            assert_eq!(items.len(), 50);
            for it in &items {
                assert_eq!(it.options.len(), 4);
                assert!(it.correct < 4);
                assert!(!it.ctx.is_empty());
                // gold option differs from every distractor
                let gold = &it.options[it.correct];
                for (i, o) in it.options.iter().enumerate() {
                    if i != it.correct {
                        assert_ne!(o, gold);
                    }
                }
            }
        }
    }

    #[test]
    fn suites_are_deterministic() {
        let w = world();
        let a = gen_suite(&w, "fact_recall", 10, 5);
        let b = gen_suite(&w, "fact_recall", 10, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ctx, y.ctx);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn fact_recall_gold_is_fact_tail() {
        let w = world();
        for it in gen_suite(&w, "fact_recall", 30, 1) {
            let head = *it.ctx.last().unwrap();
            assert_eq!(w.fact_tail(head), Some(it.options[it.correct][0]));
        }
    }

    #[test]
    fn topic_distractors_are_foreign() {
        let w = world();
        for it in gen_suite(&w, "topic", 30, 2) {
            let ctx_topic = w.topic_of(it.ctx[1]).unwrap();
            for (i, o) in it.options.iter().enumerate() {
                let ot = w.topic_of(o[0]).unwrap();
                if i == it.correct {
                    assert_eq!(ot, ctx_topic);
                } else {
                    assert_ne!(ot, ctx_topic);
                }
            }
        }
    }

    #[test]
    fn mmlu_items_have_shot_structure() {
        let w = world();
        let items = gen_mmlu(&w, 4, 5, 2, 9);
        assert!(!items.is_empty());
        for it in &items {
            let qs = it.ctx.iter().filter(|&&t| t == TOK_Q).count();
            assert_eq!(qs, 3); // 2 shots + 1 question
            assert_eq!(*it.ctx.last().unwrap(), TOK_ANS);
        }
    }

    #[test]
    fn instruction_masks_cover_responses_only() {
        let w = world();
        let ex = gen_instruction(&w, 64, 4).next().unwrap();
        assert_eq!(ex.tokens.len(), 64);
        assert_eq!(ex.mask.len(), 64);
        let masked: f32 = ex.mask.iter().sum();
        assert!(masked > 0.0 && masked < 64.0);
        // every masked position is a fact tail or EOS
        for (i, &m) in ex.mask.iter().enumerate() {
            if m == 1.0 {
                let t = ex.tokens[i];
                assert!(
                    t == TOK_EOS
                        || w.facts.iter().any(|&(_, b)| b == t),
                    "masked token {t} at {i} is not a response token"
                );
            }
        }
    }
}
