//! Uniform forward interface over the three evaluated model kinds:
//! full-precision, quantized (dequant path), and quantized+LoRA.
//! All run the `eval_batch x eval_ctx` logits executables. On the native
//! backend these dispatch to the **forward-only** model core
//! (`runtime::native::model::model_fwd_notape`): no training tape, no
//! per-head attention-probability allocation, bit-identical logits - so
//! every perplexity/zero-shot/MMLU pass below runs at inference cost.
//!
//! [`engine_logits`] is the pure-Rust sibling: the same
//! `(batch*ctx) -> (batch*ctx*vocab)` contract evaluated on the packed
//! inference engine's batched forward, with no PJRT runtime or artifacts
//! required. This is what makes CPU-only eval (and `eval::ppl::
//! perplexity_engine`) possible on a deployment box.

use anyhow::{anyhow, bail, Result};

use crate::infer::core::{Linear, ModelCore};
use crate::infer::engine::Engine;
use crate::io::manifest::PresetInfo;
use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Backend};

pub enum ModelRef<'a> {
    Fp { preset: &'a str, params: &'a [f32] },
    Quant(&'a QuantizedModel),
    Lora { qm: &'a QuantizedModel, lora: &'a [f32] },
}

impl<'a> ModelRef<'a> {
    pub fn preset(&self) -> &str {
        match self {
            ModelRef::Fp { preset, .. } => preset,
            ModelRef::Quant(qm) => &qm.preset,
            ModelRef::Lora { qm, .. } => &qm.preset,
        }
    }

    /// Logits for one eval-geometry batch; x is (eval_batch * eval_ctx)
    /// i32, returns (eval_batch * eval_ctx * vocab) f32.
    pub fn logits(&self, rt: &dyn Backend, x: &[i32]) -> Result<Vec<f32>> {
        let mut outs = Vec::new();
        self.logits_into(rt, x, &mut outs)?;
        Ok(outs.pop().unwrap())
    }

    /// [`ModelRef::logits`] through `Executor::run_into`: `outs[0]` holds
    /// the logits, and its allocation (like the native backend's own
    /// output writes) is reused across calls - the eval loops' per-batch
    /// allocation-free path.
    pub fn logits_into(&self, rt: &dyn Backend, x: &[i32],
                       outs: &mut Vec<Vec<f32>>) -> Result<()> {
        match self {
            ModelRef::Fp { preset, params } => {
                let exec = rt.exec(preset, "model_fwd_fp")?;
                exec.run_into(&[Arg::F32(params), Arg::I32(x)], outs)
            }
            ModelRef::Quant(qm) => {
                let exec =
                    rt.exec_g(&qm.preset, "model_fwd_q", qm.scheme.group)?;
                exec.run_into(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::I32(x),
                ], outs)
            }
            ModelRef::Lora { qm, lora } => {
                let exec = rt.exec_g(&qm.preset, "model_fwd_lora",
                                     qm.scheme.group)?;
                exec.run_into(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::F32(lora),
                    Arg::I32(x),
                ], outs)
            }
        }
    }
}

/// Build a serving-core view of any evaluated model kind, so session
/// forking / prefix reuse (KV-cache mechanics) work for evaluation too:
/// a `Quant` model keeps its packed low-bit linears (exactly the
/// deployment artifact), while `Fp` and `Lora` materialize dense
/// effective weights (for LoRA, `dequant(wq) + B@A`, matching
/// model.py's merged forward). `max_ctx` bounds the per-session KV.
pub fn model_core_of(info: &PresetInfo, model: &ModelRef,
                     max_ctx: usize) -> Result<ModelCore> {
    let cfg = &info.config;
    if let ModelRef::Quant(qm) = model {
        return ModelCore::from_quantized(qm, info, max_ctx);
    }
    let layout = |name: &str| {
        info.layouts
            .get(name)
            .ok_or_else(|| anyhow!("missing {name} layout"))
    };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let (embed, final_norm, head);
    match model {
        ModelRef::Fp { params, .. } => {
            let fpl = layout("fp")?;
            for b in 0..cfg.n_layers {
                let mut lins = Vec::with_capacity(7);
                for (name, o, i) in cfg.linears() {
                    let w = fpl
                        .slice(params, &format!("blocks.{b}.{name}"))?
                        .to_vec();
                    lins.push(Linear::Dense { w, out_dim: o, in_dim: i });
                }
                blocks.push(crate::infer::core::BlockW {
                    attn_norm: fpl
                        .slice(params, &format!("blocks.{b}.attn_norm"))?
                        .to_vec(),
                    mlp_norm: fpl
                        .slice(params, &format!("blocks.{b}.mlp_norm"))?
                        .to_vec(),
                    lins,
                });
            }
            embed = fpl.slice(params, "embed")?.to_vec();
            final_norm = fpl.slice(params, "final_norm")?.to_vec();
            head = fpl.slice(params, "head")?.to_vec();
        }
        ModelRef::Lora { qm, lora } => {
            let g = qm.scheme.group;
            let wql = layout("wq")?;
            let qpl = layout(&format!("qp_g{g}"))?;
            let fprl = layout("fpr")?;
            let ll = layout("lora")?;
            let rank = cfg.lora_rank;
            for b in 0..cfg.n_layers {
                let mut lins = Vec::with_capacity(7);
                for (name, o, i) in cfg.linears() {
                    let wi =
                        wql.slice(&qm.wq, &format!("blocks.{b}.{name}"))?;
                    let s = qpl
                        .slice(&qm.qp, &format!("s.blocks.{b}.{name}"))?;
                    let z = qpl
                        .slice(&qm.qp, &format!("z.blocks.{b}.{name}"))?;
                    let mut w = vec![0f32; o * i];
                    crate::runtime::native::ops::dequantize(
                        wi, o, i, s, z, g, &mut w);
                    // + B@A at scale 1.0, matching model_refs_q's LoRA
                    let a =
                        ll.slice(lora, &format!("blocks.{b}.{name}.A"))?;
                    let bm =
                        ll.slice(lora, &format!("blocks.{b}.{name}.B"))?;
                    for r in 0..o {
                        for j in 0..rank {
                            let bv = bm[r * rank + j];
                            if bv == 0.0 {
                                continue;
                            }
                            let ar = &a[j * i..(j + 1) * i];
                            let wr = &mut w[r * i..(r + 1) * i];
                            for c in 0..i {
                                wr[c] += bv * ar[c];
                            }
                        }
                    }
                    lins.push(Linear::Dense { w, out_dim: o, in_dim: i });
                }
                blocks.push(crate::infer::core::BlockW {
                    attn_norm: fprl
                        .slice(&qm.fpr, &format!("blocks.{b}.attn_norm"))?
                        .to_vec(),
                    mlp_norm: fprl
                        .slice(&qm.fpr, &format!("blocks.{b}.mlp_norm"))?
                        .to_vec(),
                    lins,
                });
            }
            embed = fprl.slice(&qm.fpr, "embed")?.to_vec();
            final_norm = fprl.slice(&qm.fpr, "final_norm")?.to_vec();
            head = fprl.slice(&qm.fpr, "head")?.to_vec();
        }
        ModelRef::Quant(_) => unreachable!(),
    }
    Ok(ModelCore::assemble(
        cfg.dim,
        cfg.n_heads,
        cfg.head_dim,
        cfg.inter,
        cfg.vocab,
        max_ctx,
        cfg.rope_theta,
        cfg.norm_eps as f32,
        embed,
        final_norm,
        head,
        blocks,
    ))
}

/// Batched eval forward on the pure-Rust engine: logits for every position
/// of every row. `x` is (batch * ctx) i32, the result is
/// (batch * ctx * vocab) f32 - the same contract as [`ModelRef::logits`],
/// but no PJRT runtime needed. Each row runs through the engine's batched
/// prefill (`Engine::forward_logits`); the KV cache is reset per row.
pub fn engine_logits(eng: &mut Engine, x: &[i32], batch: usize, ctx: usize)
                     -> Result<Vec<f32>> {
    let mut out = Vec::new();
    engine_logits_into(eng, x, batch, ctx, &mut out)?;
    Ok(out)
}

/// [`engine_logits`] into a reusable buffer - the allocation-free form
/// `eval::ppl::perplexity_engine` loops over: each row's logits are
/// written straight into their place in `out` (no per-row staging
/// buffer or copy).
pub fn engine_logits_into(eng: &mut Engine, x: &[i32], batch: usize,
                          ctx: usize, out: &mut Vec<f32>) -> Result<()> {
    if x.len() != batch * ctx {
        bail!("engine_logits: x has {} tokens, want {batch}x{ctx}",
              x.len());
    }
    if ctx > eng.max_ctx() {
        bail!("engine_logits: ctx {ctx} exceeds engine max_ctx {}",
              eng.max_ctx());
    }
    let v = eng.vocab();
    out.resize(batch * ctx * v, 0.0);
    for b in 0..batch {
        eng.reset();
        eng.forward_logits_slice(&x[b * ctx..(b + 1) * ctx],
                                 &mut out[b * ctx * v..(b + 1) * ctx * v])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;

    #[test]
    fn engine_logits_matches_per_step_rows() {
        let (vocab, ctx, batch) = (96usize, 6usize, 2usize);
        let mut eng = Engine::synthetic(32, 4, 8, 64, vocab, 2,
                                        QuantScheme::new(2, 32), ctx, 21)
            .unwrap();
        let x: Vec<i32> =
            (0..batch * ctx).map(|i| ((i * 11 + 3) % vocab) as i32).collect();
        let all = engine_logits(&mut eng, &x, batch, ctx).unwrap();
        assert_eq!(all.len(), batch * ctx * vocab);

        let mut step_eng = Engine::synthetic(32, 4, 8, 64, vocab, 2,
                                             QuantScheme::new(2, 32), ctx,
                                             21)
            .unwrap();
        for b in 0..batch {
            step_eng.reset();
            for (t, &tk) in x[b * ctx..(b + 1) * ctx].iter().enumerate() {
                let lg = step_eng.step(tk).unwrap();
                let row = &all[(b * ctx + t) * vocab
                    ..(b * ctx + t + 1) * vocab];
                for (i, (p, s)) in row.iter().zip(&lg).enumerate() {
                    assert!((p - s).abs() <= 1e-4,
                            "b={b} t={t} i={i}: {p} vs {s}");
                }
            }
        }
    }

    #[test]
    fn engine_logits_validates_shapes() {
        let mut eng = Engine::synthetic(32, 4, 8, 64, 96, 1,
                                        QuantScheme::new(2, 32), 4, 22)
            .unwrap();
        assert!(engine_logits(&mut eng, &[0, 1, 2], 2, 2).is_err());
        assert!(engine_logits(&mut eng, &[0; 10], 2, 5).is_err());
    }
}
