//! Uniform forward interface over the three evaluated model kinds:
//! full-precision, quantized (dequant path), and quantized+LoRA.
//! All run the `eval_batch x eval_ctx` logits executables.

use anyhow::Result;

use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Runtime};

pub enum ModelRef<'a> {
    Fp { preset: &'a str, params: &'a [f32] },
    Quant(&'a QuantizedModel),
    Lora { qm: &'a QuantizedModel, lora: &'a [f32] },
}

impl<'a> ModelRef<'a> {
    pub fn preset(&self) -> &str {
        match self {
            ModelRef::Fp { preset, .. } => preset,
            ModelRef::Quant(qm) => &qm.preset,
            ModelRef::Lora { qm, .. } => &qm.preset,
        }
    }

    /// Logits for one eval-geometry batch; x is (eval_batch * eval_ctx)
    /// i32, returns (eval_batch * eval_ctx * vocab) f32.
    pub fn logits(&self, rt: &Runtime, x: &[i32]) -> Result<Vec<f32>> {
        match self {
            ModelRef::Fp { preset, params } => {
                let exec = rt.exec(preset, "model_fwd_fp")?;
                exec.run1(&[Arg::F32(params), Arg::I32(x)])
            }
            ModelRef::Quant(qm) => {
                let exec =
                    rt.exec_g(&qm.preset, "model_fwd_q", qm.scheme.group)?;
                exec.run1(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::I32(x),
                ])
            }
            ModelRef::Lora { qm, lora } => {
                let exec = rt.exec_g(&qm.preset, "model_fwd_lora",
                                     qm.scheme.group)?;
                exec.run1(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::F32(lora),
                    Arg::I32(x),
                ])
            }
        }
    }
}
