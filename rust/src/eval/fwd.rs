//! Uniform forward interface over the three evaluated model kinds:
//! full-precision, quantized (dequant path), and quantized+LoRA.
//! All run the `eval_batch x eval_ctx` logits executables. On the native
//! backend these dispatch to the **forward-only** model core
//! (`runtime::native::model::model_fwd_notape`): no training tape, no
//! per-head attention-probability allocation, bit-identical logits - so
//! every perplexity/zero-shot/MMLU pass below runs at inference cost.
//!
//! [`engine_logits`] is the pure-Rust sibling: the same
//! `(batch*ctx) -> (batch*ctx*vocab)` contract evaluated on the packed
//! inference engine's batched forward, with no PJRT runtime or artifacts
//! required. This is what makes CPU-only eval (and `eval::ppl::
//! perplexity_engine`) possible on a deployment box.

use anyhow::{bail, Result};

use crate::infer::engine::Engine;
use crate::model::quantized::QuantizedModel;
use crate::runtime::{Arg, Backend};

pub enum ModelRef<'a> {
    Fp { preset: &'a str, params: &'a [f32] },
    Quant(&'a QuantizedModel),
    Lora { qm: &'a QuantizedModel, lora: &'a [f32] },
}

impl<'a> ModelRef<'a> {
    pub fn preset(&self) -> &str {
        match self {
            ModelRef::Fp { preset, .. } => preset,
            ModelRef::Quant(qm) => &qm.preset,
            ModelRef::Lora { qm, .. } => &qm.preset,
        }
    }

    /// Logits for one eval-geometry batch; x is (eval_batch * eval_ctx)
    /// i32, returns (eval_batch * eval_ctx * vocab) f32.
    pub fn logits(&self, rt: &dyn Backend, x: &[i32]) -> Result<Vec<f32>> {
        match self {
            ModelRef::Fp { preset, params } => {
                let exec = rt.exec(preset, "model_fwd_fp")?;
                exec.run1(&[Arg::F32(params), Arg::I32(x)])
            }
            ModelRef::Quant(qm) => {
                let exec =
                    rt.exec_g(&qm.preset, "model_fwd_q", qm.scheme.group)?;
                exec.run1(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::I32(x),
                ])
            }
            ModelRef::Lora { qm, lora } => {
                let exec = rt.exec_g(&qm.preset, "model_fwd_lora",
                                     qm.scheme.group)?;
                exec.run1(&[
                    Arg::F32(&qm.wq),
                    Arg::F32(&qm.qp),
                    Arg::F32(&qm.fpr),
                    Arg::F32(lora),
                    Arg::I32(x),
                ])
            }
        }
    }
}

/// Batched eval forward on the pure-Rust engine: logits for every position
/// of every row. `x` is (batch * ctx) i32, the result is
/// (batch * ctx * vocab) f32 - the same contract as [`ModelRef::logits`],
/// but no PJRT runtime needed. Each row runs through the engine's batched
/// prefill (`Engine::forward_logits`); the KV cache is reset per row.
pub fn engine_logits(eng: &mut Engine, x: &[i32], batch: usize, ctx: usize)
                     -> Result<Vec<f32>> {
    if x.len() != batch * ctx {
        bail!("engine_logits: x has {} tokens, want {batch}x{ctx}",
              x.len());
    }
    if ctx > eng.max_ctx {
        bail!("engine_logits: ctx {ctx} exceeds engine max_ctx {}",
              eng.max_ctx);
    }
    let v = eng.vocab;
    let mut out = vec![0f32; batch * ctx * v];
    for b in 0..batch {
        eng.reset();
        let row = &x[b * ctx..(b + 1) * ctx];
        let lg = eng.forward_logits(row)?;
        out[b * ctx * v..(b + 1) * ctx * v].copy_from_slice(&lg);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;

    #[test]
    fn engine_logits_matches_per_step_rows() {
        let (vocab, ctx, batch) = (96usize, 6usize, 2usize);
        let mut eng = Engine::synthetic(32, 4, 8, 64, vocab, 2,
                                        QuantScheme::new(2, 32), ctx, 21)
            .unwrap();
        let x: Vec<i32> =
            (0..batch * ctx).map(|i| ((i * 11 + 3) % vocab) as i32).collect();
        let all = engine_logits(&mut eng, &x, batch, ctx).unwrap();
        assert_eq!(all.len(), batch * ctx * vocab);

        let mut step_eng = Engine::synthetic(32, 4, 8, 64, vocab, 2,
                                             QuantScheme::new(2, 32), ctx,
                                             21)
            .unwrap();
        for b in 0..batch {
            step_eng.reset();
            for (t, &tk) in x[b * ctx..(b + 1) * ctx].iter().enumerate() {
                let lg = step_eng.step(tk).unwrap();
                let row = &all[(b * ctx + t) * vocab
                    ..(b * ctx + t + 1) * vocab];
                for (i, (p, s)) in row.iter().zip(&lg).enumerate() {
                    assert!((p - s).abs() <= 1e-4,
                            "b={b} t={t} i={i}: {p} vs {s}");
                }
            }
        }
    }

    #[test]
    fn engine_logits_validates_shapes() {
        let mut eng = Engine::synthetic(32, 4, 8, 64, 96, 1,
                                        QuantScheme::new(2, 32), 4, 22)
            .unwrap();
        assert!(engine_logits(&mut eng, &[0, 1, 2], 2, 2).is_err());
        assert!(engine_logits(&mut eng, &[0; 10], 2, 5).is_err());
    }
}
