//! Evaluation harnesses: perplexity (Table 3) and multiple-choice scoring
//! (Tables 1/4 via the synthetic suites).
pub mod fwd;
pub mod ppl;
pub mod zeroshot;
