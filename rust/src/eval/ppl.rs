//! Perplexity evaluation (paper Table 3): mean token cross-entropy over
//! held-out windows of a domain corpus, exp'd.
//!
//! Two backends share the NLL accounting: [`perplexity`] runs the
//! backend's eval executables (on the native backend that is the
//! forward-only, tape-free model core), [`perplexity_engine`] runs the
//! pure-Rust packed engine's batched forward (no artifacts needed) -
//! useful for validating a deployed .eqt model on the serving box
//! itself. Both paths are backed by the persistent worker pool, so
//! multi-batch eval pays no per-call thread-spawn latency, and both
//! stream their logits through reusable buffers (`Executor::run_into` /
//! `Engine::forward_logits_into`): steady-state perplexity eval
//! allocates no fresh logits Vec per batch.

use anyhow::Result;

use crate::data::corpus::{Domain, World};
use crate::data::loader::LmLoader;
use crate::eval::fwd::{engine_logits_into, ModelRef};
use crate::infer::engine::Engine;
use crate::runtime::Backend;
use crate::util::stats::logsumexp;

/// Accumulate mean NLL over (x, y) batches given a logits provider that
/// writes into a reusable buffer.
fn ppl_over_batches<F>(
    loader: &mut LmLoader,
    vocab: usize,
    n_batches: usize,
    mut logits_of: F,
) -> Result<f64>
where
    F: FnMut(&[i32], &mut Vec<f32>) -> Result<()>,
{
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    let mut logits = Vec::new();
    for _ in 0..n_batches {
        let b = loader.next_batch();
        logits_of(&b.x, &mut logits)?;
        for (i, &y) in b.y.iter().enumerate() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let nll = logsumexp(row) - row[y as usize] as f64;
            total_nll += nll;
            total_tok += 1;
        }
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Perplexity over `n_batches` eval-geometry batches from `domain`
/// (seeded disjoint from all training pools).
pub fn perplexity(
    rt: &dyn Backend,
    model: &ModelRef,
    world: &World,
    domain: &Domain,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = rt.manifest().preset(model.preset())?.config.clone();
    let mut loader =
        LmLoader::new(world, domain, seed, cfg.eval_batch, cfg.eval_ctx);
    let mut outs: Vec<Vec<f32>> = Vec::new();
    ppl_over_batches(&mut loader, cfg.vocab, n_batches, |x, logits| {
        model.logits_into(rt, x, &mut outs)?;
        // hand the freshly-written buffer out, keep the old one for the
        // backend to refill next batch - no allocation either way
        std::mem::swap(logits, &mut outs[0]);
        Ok(())
    })
}

/// Perplexity of a packed model on the pure-Rust engine (batched eval
/// forward, `eval::fwd::engine_logits`): same accounting as
/// [`perplexity`], no PJRT runtime or artifacts required.
#[allow(clippy::too_many_arguments)]
pub fn perplexity_engine(
    eng: &mut Engine,
    world: &World,
    domain: &Domain,
    batch: usize,
    ctx: usize,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let vocab = eng.vocab();
    let mut loader = LmLoader::new(world, domain, seed, batch, ctx);
    ppl_over_batches(&mut loader, vocab, n_batches, |x, logits| {
        engine_logits_into(eng, x, batch, ctx, logits)
    })
}

#[cfg(test)]
mod tests {
    use crate::util::stats::logsumexp;

    /// End-to-end through the native backend's forward-only eval entry
    /// (`model_fwd_fp` -> `model_fwd_notape`): the ppl accounting must
    /// stay finite and near-uniform for an untrained model. This is the
    /// same path `eqat eval --ppl-only` (tier-1 smoke) drives.
    #[test]
    fn native_backend_perplexity_runs_forward_only() {
        use crate::data::corpus::{domain_wiki, World};
        use crate::eval::fwd::ModelRef;
        use crate::model::init::init_fp_params;
        use crate::runtime::{native::NativeBackend, Backend};
        let be = NativeBackend::new();
        let cfg = be.manifest().preset("synthetic").unwrap().config
            .clone();
        let fpl = be.manifest().layout("synthetic", "fp").unwrap().clone();
        let params = init_fp_params(&fpl, 19);
        let world = World::new(cfg.vocab, 5);
        let ppl = super::perplexity(
            &be,
            &ModelRef::Fp { preset: "synthetic", params: &params },
            &world, &domain_wiki(), 2, 77)
            .unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "ppl={ppl}");
        assert!(ppl < cfg.vocab as f64 * 4.0, "ppl={ppl}");
    }

    #[test]
    fn engine_perplexity_is_finite_and_near_uniform_for_random_model() {
        use crate::config::QuantScheme;
        use crate::data::corpus::{domain_wiki, World};
        use crate::infer::engine::Engine;
        let vocab = 96usize;
        let mut eng = Engine::synthetic(32, 4, 8, 64, vocab, 1,
                                        QuantScheme::new(2, 32), 8, 31)
            .unwrap();
        let world = World::new(vocab, 5);
        let ppl = super::perplexity_engine(&mut eng, &world, &domain_wiki(),
                                           2, 8, 2, 77)
            .unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "ppl={ppl}");
        // an untrained model is near-uniform over the vocab
        assert!(ppl < vocab as f64 * 4.0, "ppl={ppl}");
    }

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        // nll of uniform over V = ln V -> ppl = V (sanity of the formula)
        let v = 512;
        let row = vec![0f32; v];
        let nll = logsumexp(&row) - row[3] as f64;
        assert!(((nll.exp()) - v as f64).abs() < 1e-6);
    }
}
