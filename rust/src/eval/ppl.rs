//! Perplexity evaluation (paper Table 3): mean token cross-entropy over
//! held-out windows of a domain corpus, exp'd.

use anyhow::Result;

use crate::data::corpus::{Domain, World};
use crate::data::loader::LmLoader;
use crate::eval::fwd::ModelRef;
use crate::runtime::Runtime;
use crate::util::stats::logsumexp;

/// Perplexity over `n_batches` eval-geometry batches from `domain`
/// (seeded disjoint from all training pools).
pub fn perplexity(
    rt: &Runtime,
    model: &ModelRef,
    world: &World,
    domain: &Domain,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = rt.manifest.preset(model.preset())?.config.clone();
    let mut loader =
        LmLoader::new(world, domain, seed, cfg.eval_batch, cfg.eval_ctx);
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    for _ in 0..n_batches {
        let b = loader.next_batch();
        let logits = model.logits(rt, &b.x)?;
        let v = cfg.vocab;
        for (i, &y) in b.y.iter().enumerate() {
            let row = &logits[i * v..(i + 1) * v];
            let nll = logsumexp(row) - row[y as usize] as f64;
            total_nll += nll;
            total_tok += 1;
        }
    }
    Ok((total_nll / total_tok as f64).exp())
}

#[cfg(test)]
mod tests {
    use crate::util::stats::logsumexp;

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        // nll of uniform over V = ln V -> ppl = V (sanity of the formula)
        let v = 512;
        let row = vec![0f32; v];
        let nll = logsumexp(&row) - row[3] as f64;
        assert!(((nll.exp()) - v as f64).abs() < 1e-6);
    }
}
