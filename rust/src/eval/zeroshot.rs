//! Multiple-choice scoring harness (lm-eval mechanics): each option is
//! scored by the total log-likelihood of its tokens given the context; the
//! model is correct when the gold option ranks first. Drives both the five
//! zero-shot suites (Table 1) and the MMLU analog (Table 4).
//!
//! # Scoring path: sessions forked off one prefilled prompt state
//!
//! An item's options all share the same context, so scoring used to pay
//! `options x` full forwards over `ctx + option` rows (each padded to the
//! eval geometry) - the shared question prefix was re-prefilled for every
//! candidate continuation. [`eval_items`] runs on the serving core
//! instead: the context is prefilled **once** into a paged KV-pool
//! session, and each option is scored from a session *forked* off that
//! state with **zero KV copying** - [`KvPool::fork`] shares the prefix
//! pages by refcount, and only the option's own rows touch fresh pages
//! (a copy-on-write of at most one partial tail page; see `infer::kv`).
//! Single-token options need no forward at all - their log-likelihood is
//! already in the context's last-position logits. Chunked continuation
//! is bit-exact with a monolithic forward (see `infer::core`), so
//! forking changes the cost, not the scores (tested, incl. bitwise vs
//! the naive full-re-prefill path).
//!
//! Model kinds map onto the core via [`fwd::model_core_of`]: packed
//! linears for `Quant` (the deployment artifact), dense effective weights
//! for `Fp` and `Lora`. Numerics therefore follow the packed-engine
//! forward (backend-vs-engine parity is covered by the integration
//! suite).

use anyhow::{bail, Result};

use crate::data::corpus::World;
use crate::data::tasks::{gen_mmlu, gen_suite, McItem, ZEROSHOT_SUITES};
use crate::eval::fwd::{model_core_of, ModelRef};
use crate::infer::core::{ModelCore, Scratch};
use crate::infer::kv::KvPool;
use crate::runtime::Backend;
use crate::util::stats::logsumexp;

/// Per-option log-likelihoods of one item, computed from sessions forked
/// off the item's prefilled context. `opt_logits` is a reusable buffer
/// for the option-continuation forwards.
pub(crate) fn score_item(core: &ModelCore, pool: &mut KvPool,
                         sc: &mut Scratch, opt_logits: &mut Vec<f32>,
                         item: &McItem) -> Result<Vec<f64>> {
    let v = core.vocab;
    if item.ctx.is_empty() {
        bail!("multiple-choice item with empty context");
    }
    for opt in &item.options {
        if opt.is_empty() {
            bail!("multiple-choice option with no tokens");
        }
        if item.ctx.len() + opt.len() > core.max_ctx {
            bail!("item length {} exceeds eval ctx {}",
                  item.ctx.len() + opt.len(), core.max_ctx);
        }
        for &t in opt {
            if t < 0 || t as usize >= v {
                bail!("option token {t} out of range (vocab {v})");
            }
        }
    }
    // prefill the shared context once; its last-position logits score
    // every option's first token
    let parent = pool.lease().expect("score pool sized for parent+fork");
    let r = (|| -> Result<Vec<f64>> {
        core.prefill(pool, &parent, 0, &item.ctx, sc)?;
        let lse0 = logsumexp(sc.logits());
        let first_lp: Vec<f64> = item
            .options
            .iter()
            .map(|o| sc.logits()[o[0] as usize] as f64 - lse0)
            .collect();
        let mut scores = Vec::with_capacity(item.options.len());
        for (oi, opt) in item.options.iter().enumerate() {
            let mut ll = first_lp[oi];
            if opt.len() > 1 {
                // zero-copy: the fork shares the prefilled context's
                // pages; only the option rows COW/extend
                let fork = pool
                    .fork(&parent, item.ctx.len())
                    .expect("score pool sized for parent+fork");
                let fr = core.forward_logits(pool, &fork,
                                             item.ctx.len(), opt, sc,
                                             opt_logits);
                pool.release(fork);
                fr?;
                // position p of the continuation predicts opt[p+1]
                for p in 0..opt.len() - 1 {
                    let row = &opt_logits[p * v..(p + 1) * v];
                    ll += row[opt[p + 1] as usize] as f64 - logsumexp(row);
                }
            }
            scores.push(ll);
        }
        Ok(scores)
    })();
    pool.release(parent);
    r
}

/// Option log-likelihood scoring over a prebuilt serving core; returns
/// per-item accuracy. See the module docs for the fork-based mechanics.
/// Callers scoring several suites against one model build the core once
/// (see [`eval_zeroshot`]) instead of re-materializing the weights per
/// call.
pub fn eval_items_core(core: &ModelCore, items: &[McItem]) -> Result<f64> {
    // two slots: the prefilled context + one fork at a time
    let mut pool = KvPool::for_core(core, 2);
    let mut sc = core.scratch();
    let mut opt_logits = Vec::new();

    let mut correct = 0usize;
    for it in items {
        let scores =
            score_item(core, &mut pool, &mut sc, &mut opt_logits, it)?;
        let mut best = 0usize;
        for (i, &x) in scores.iter().enumerate() {
            if x > scores[best] {
                best = i;
            }
        }
        if best == it.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// [`eval_items_core`] over a model reference (core built per call).
pub fn eval_items(
    rt: &dyn Backend,
    model: &ModelRef,
    items: &[McItem],
) -> Result<f64> {
    let info = rt.manifest().preset(model.preset())?;
    let core = model_core_of(info, model, info.config.eval_ctx)?;
    eval_items_core(&core, items)
}

/// Accuracy per zero-shot suite + the average (paper Table 1 columns).
/// The serving core is built once and reused across all five suites.
pub fn eval_zeroshot(
    rt: &dyn Backend,
    model: &ModelRef,
    world: &World,
    per_suite: usize,
    seed: u64,
) -> Result<(Vec<(String, f64)>, f64)> {
    let info = rt.manifest().preset(model.preset())?;
    let core = model_core_of(info, model, info.config.eval_ctx)?;
    let mut rows = Vec::new();
    let mut total = 0f64;
    for suite in ZEROSHOT_SUITES {
        let items = gen_suite(world, suite, per_suite, seed);
        let acc = eval_items_core(&core, &items)?;
        total += acc;
        rows.push((suite.to_string(), acc));
    }
    let avg = total / ZEROSHOT_SUITES.len() as f64;
    Ok((rows, avg))
}

/// MMLU-analog accuracy (few-shot).
pub fn eval_mmlu(
    rt: &dyn Backend,
    model: &ModelRef,
    world: &World,
    seed: u64,
) -> Result<f64> {
    let items = gen_mmlu(world, 4, 24, 2, seed);
    eval_items(rt, model, &items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp_params;
    use crate::runtime::native::NativeBackend;

    /// Forked-session scoring must equal the naive path that re-runs the
    /// full `ctx + option` sequence per candidate - bit-for-bit, since
    /// chunked continuation is exact.
    #[test]
    fn forked_scoring_matches_full_reprefill_bitwise() {
        let be = NativeBackend::new();
        let info = be.manifest().preset("synthetic").unwrap();
        let fpl = info.layouts.get("fp").unwrap().clone();
        let params = init_fp_params(&fpl, 5);
        let model = ModelRef::Fp { preset: "synthetic", params: &params };
        let core = model_core_of(info, &model, info.config.eval_ctx)
            .unwrap();
        let v = core.vocab;

        let items = vec![
            McItem {
                ctx: vec![1, 5, 9, 2],
                options: vec![vec![3], vec![4, 7], vec![8, 11, 6, 2]],
                correct: 1,
            },
            McItem {
                ctx: vec![2; 10],
                options: vec![vec![0, 1], vec![1, 0]],
                correct: 0,
            },
        ];
        let mut pool = KvPool::for_core(&core, 2);
        let mut sc = core.scratch();
        let mut buf = Vec::new();
        for it in &items {
            let fast =
                score_item(&core, &mut pool, &mut sc, &mut buf, it)
                    .unwrap();
            // naive reference: full forward per (ctx + option) sequence
            let mut naive_pool = KvPool::for_core(&core, 1);
            for (oi, opt) in it.options.iter().enumerate() {
                let seq: Vec<i32> =
                    it.ctx.iter().chain(opt).copied().collect();
                let l = naive_pool.lease().unwrap();
                let mut all = Vec::new();
                core.forward_logits(&mut naive_pool, &l, 0, &seq,
                                    &mut sc, &mut all)
                    .unwrap();
                naive_pool.release(l);
                let from = it.ctx.len() - 1;
                let mut want = 0f64;
                for p in from..seq.len() - 1 {
                    let row = &all[p * v..(p + 1) * v];
                    want +=
                        row[seq[p + 1] as usize] as f64 - logsumexp(row);
                }
                assert_eq!(
                    fast[oi].to_bits(),
                    want.to_bits(),
                    "item option {oi}: forked ll {} != naive ll {want}",
                    fast[oi]
                );
            }
        }
        // the fork leases were all released, no page leaked
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.n_free_pages(), pool.n_pages());
        // zero-copy contract: each multi-token option's fork COW-copied
        // at most one page (4 such forks across the two items); the
        // forks themselves moved nothing
        assert!(pool.bytes_copied() <= 4 * pool.page_bytes(),
                "prefix sharing copied more than one page per fork");
    }

    /// End-to-end accuracy sanity on every model kind the harness scores.
    #[test]
    fn eval_items_runs_for_all_model_kinds() {
        use crate::coordinator::block_ap::rtn_quantize_model;
        use crate::config::QuantScheme;
        use crate::runtime::Backend;

        let be = NativeBackend::new();
        let cfg =
            be.manifest().preset("synthetic").unwrap().config.clone();
        let fpl = be.manifest().layout("synthetic", "fp").unwrap().clone();
        let ll =
            be.manifest().layout("synthetic", "lora").unwrap().clone();
        let params = init_fp_params(&fpl, 8);
        let qm = rtn_quantize_model(
            &be, "synthetic", &params,
            QuantScheme::new(4, cfg.default_group))
            .unwrap();
        let lora = vec![0.01f32; ll.size];
        let world = World::new(cfg.vocab, 7);
        let items = gen_suite(&world, "copy", 12, 99);
        for model in [
            ModelRef::Fp { preset: "synthetic", params: &params },
            ModelRef::Quant(&qm),
            ModelRef::Lora { qm: &qm, lora: &lora },
        ] {
            let acc = eval_items(&be, &model, &items).unwrap();
            assert!((0.0..=1.0).contains(&acc), "acc {acc}");
        }
    }

    #[test]
    fn oversized_items_are_rejected() {
        let be = NativeBackend::new();
        let info = be.manifest().preset("synthetic").unwrap();
        let fpl = info.layouts.get("fp").unwrap().clone();
        let params = init_fp_params(&fpl, 5);
        let model = ModelRef::Fp { preset: "synthetic", params: &params };
        let items = vec![McItem {
            ctx: vec![1; info.config.eval_ctx],
            options: vec![vec![2]],
            correct: 0,
        }];
        assert!(eval_items(&be, &model, &items).is_err());
    }
}
