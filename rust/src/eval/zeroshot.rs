//! Multiple-choice scoring harness (lm-eval mechanics): each option is
//! scored by the total log-likelihood of its tokens given the context; the
//! model is correct when the gold option ranks first. Drives both the five
//! zero-shot suites (Table 1) and the MMLU analog (Table 4).
//!
//! Cost note: a suite scores `items x options` sequences, one
//! eval-geometry forward per batch row - on the native backend these all
//! go through the forward-only (no-tape) model core, so zero-shot eval
//! no longer materializes training tapes it immediately drops.

use anyhow::{bail, Result};

use crate::data::corpus::World;
use crate::data::tasks::{gen_mmlu, gen_suite, McItem, ZEROSHOT_SUITES};
use crate::eval::fwd::ModelRef;
use crate::runtime::Backend;
use crate::util::stats::logsumexp;

/// A sequence to score: ctx followed by option tokens.
struct Scored {
    tokens: Vec<i32>,
    /// score positions: predict tokens[p+1] at p for p in score_from..end-1
    score_from: usize,
}

/// Batched option log-likelihood scoring.
///
/// Packs one sequence per batch row (padded with 0), runs the eval-geometry
/// forward, and sums log p(option tokens). Returns per-item accuracy.
pub fn eval_items(
    rt: &dyn Backend,
    model: &ModelRef,
    items: &[McItem],
) -> Result<f64> {
    let cfg = rt.manifest().preset(model.preset())?.config.clone();
    let (bsz, ctx, v) = (cfg.eval_batch, cfg.eval_ctx, cfg.vocab);

    // flatten items into scoring jobs
    let mut jobs: Vec<Scored> = Vec::new();
    for it in items {
        for opt in &it.options {
            let mut tokens = it.ctx.clone();
            let score_from = tokens.len() - 1;
            tokens.extend_from_slice(opt);
            if tokens.len() > ctx {
                bail!("item length {} exceeds eval ctx {ctx}", tokens.len());
            }
            jobs.push(Scored { tokens, score_from });
        }
    }

    let mut scores = vec![0f64; jobs.len()];
    for (chunk_i, chunk) in jobs.chunks(bsz).enumerate() {
        let mut x = vec![0i32; bsz * ctx];
        for (row, job) in chunk.iter().enumerate() {
            x[row * ctx..row * ctx + job.tokens.len()]
                .copy_from_slice(&job.tokens);
        }
        let logits = model.logits(rt, &x)?;
        for (row, job) in chunk.iter().enumerate() {
            let mut ll = 0f64;
            for p in job.score_from..job.tokens.len() - 1 {
                let rowbase = (row * ctx + p) * v;
                let lrow = &logits[rowbase..rowbase + v];
                let y = job.tokens[p + 1] as usize;
                ll += lrow[y] as f64 - logsumexp(lrow);
            }
            scores[chunk_i * bsz + row] = ll;
        }
    }

    // rank options per item
    let mut correct = 0usize;
    let mut cursor = 0usize;
    for it in items {
        let k = it.options.len();
        let s = &scores[cursor..cursor + k];
        cursor += k;
        let mut best = 0usize;
        for (i, &x) in s.iter().enumerate() {
            if x > s[best] {
                best = i;
            }
        }
        if best == it.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// Accuracy per zero-shot suite + the average (paper Table 1 columns).
pub fn eval_zeroshot(
    rt: &dyn Backend,
    model: &ModelRef,
    world: &World,
    per_suite: usize,
    seed: u64,
) -> Result<(Vec<(String, f64)>, f64)> {
    let mut rows = Vec::new();
    let mut total = 0f64;
    for suite in ZEROSHOT_SUITES {
        let items = gen_suite(world, suite, per_suite, seed);
        let acc = eval_items(rt, model, &items)?;
        total += acc;
        rows.push((suite.to_string(), acc));
    }
    let avg = total / ZEROSHOT_SUITES.len() as f64;
    Ok((rows, avg))
}

/// MMLU-analog accuracy (few-shot).
pub fn eval_mmlu(
    rt: &dyn Backend,
    model: &ModelRef,
    world: &World,
    seed: u64,
) -> Result<f64> {
    let items = gen_mmlu(world, 4, 24, 2, seed);
    eval_items(rt, model, &items)
}
