//! Experiment drivers: one per paper table/figure (DESIGN.md §6 index).
//! Shared plumbing: a context that caches the pretrained fp model and sweep
//! results under runs/, plus a markdown table printer.

pub mod sweeps;
pub mod tables;

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::pretrain::{pretrain, PretrainOpts};
use crate::data::corpus::{domain_redpajama, World};
use crate::data::loader::LmLoader;
use crate::model::checkpoint::FpCheckpoint;
use crate::runtime::{make_backend, Backend};

/// Shared experiment context: execution backend + world + on-disk caches.
pub struct ExpCtx {
    pub rt: Box<dyn Backend>,
    pub world: World,
    pub runs_dir: PathBuf,
    /// pretraining steps per preset (tiny models learn fast)
    pub pretrain_steps: usize,
}

impl ExpCtx {
    /// `backend`: "native" | "pjrt" | "auto" (see `runtime::make_backend`).
    pub fn new(artifacts_dir: &str, runs_dir: &str, backend: &str)
               -> Result<ExpCtx> {
        let rt = make_backend(backend, artifacts_dir)?;
        std::fs::create_dir_all(runs_dir)?;
        Ok(ExpCtx {
            rt,
            world: World::new(512, 7),
            runs_dir: runs_dir.into(),
            pretrain_steps: 300,
        })
    }

    /// World sized for a given preset's vocab.
    pub fn world_for(&self, preset: &str) -> Result<World> {
        let v = self.rt.manifest().preset(preset)?.config.vocab;
        Ok(World::new(v, 7))
    }

    /// Pretrained fp params, cached at runs/{preset}-fp.eqt.
    pub fn pretrained(&self, preset: &str) -> Result<Vec<f32>> {
        let path = self.runs_dir.join(format!("{preset}-fp.eqt"));
        if path.exists() {
            let ck = FpCheckpoint::load(&path)?;
            if ck.preset == preset {
                return Ok(ck.params);
            }
        }
        let cfg = self.rt.manifest().preset(preset)?.config.clone();
        let world = self.world_for(preset)?;
        let mut loader = LmLoader::new(&world, &domain_redpajama(), 11,
                                       cfg.e2e_batch, cfg.e2e_ctx);
        let opts = PretrainOpts {
            steps: self.pretrain_steps,
            lr: 3e-3,
            seed: 5,
            log_every: 50,
        };
        let (params, report) = pretrain(self.rt.as_ref(), preset, &mut loader,
                                        &opts)?;
        crate::info!(
            "pretrained {preset}: loss {:.3} -> {:.3} in {:.1}s",
            report.losses[0],
            report.losses.last().unwrap(),
            report.seconds
        );
        FpCheckpoint { preset: preset.into(), params: params.clone(),
                       step: opts.steps }
            .save(&path)?;
        // persist the loss curve for the end-to-end driver's record
        let curve: Vec<String> =
            report.losses.iter().map(|l| format!("{l:.4}")).collect();
        std::fs::write(
            self.runs_dir.join(format!("{preset}-pretrain-loss.csv")),
            curve.join("\n"),
        )?;
        Ok(params)
    }
}

/// Render a markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for r in rows {
        out.push_str("| ");
        out.push_str(&r.join(" | "));
        out.push_str(" |\n");
    }
    out
}

pub fn fmt(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn md_table_renders() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
        assert!(t.lines().count() == 3);
    }
}
