//! The shared method x scheme sweep behind Tables 1, 2, 3 and Fig 1a:
//! quantize one pretrained model with every method, evaluate zero-shot
//! accuracy (5 suites) and wiki/c4 perplexity. Results are cached as JSON
//! under runs/ so t1/t2/t3/fig1 render from one run.

use anyhow::Result;

use crate::baselines::naive_qat::run_naive_qat;
use crate::baselines::ptq::{ptq_quantize_model, PtqMethod};
use crate::config::{QuantScheme, TrainHp, TrainableSet};
use crate::coordinator::pipeline::{efficient_qat, PhaseToggle};
use crate::data::corpus::{domain_c4, domain_redpajama, domain_wiki};
use crate::data::loader::LmLoader;
use crate::eval::fwd::ModelRef;
use crate::eval::ppl::perplexity;
use crate::eval::zeroshot::eval_zeroshot;
use crate::exp::ExpCtx;
use crate::util::json::Json;

pub const EVAL_ITEMS_PER_SUITE: usize = 40;
pub const EVAL_PPL_BATCHES: usize = 4;

#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method: String,
    pub bits: u32,
    pub group: usize,
    pub accs: Vec<(String, f64)>,
    pub acc_avg: f64,
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub seconds: f64,
}

impl MethodResult {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("bits", Json::num(self.bits as f64)),
            ("group", Json::num(self.group as f64)),
            (
                "accs",
                Json::arr(
                    self.accs
                        .iter()
                        .map(|(n, a)| {
                            Json::arr(vec![Json::str(n.clone()),
                                           Json::num(*a)])
                        })
                        .collect(),
                ),
            ),
            ("acc_avg", Json::num(self.acc_avg)),
            ("ppl_wiki", Json::num(self.ppl_wiki)),
            ("ppl_c4", Json::num(self.ppl_c4)),
            ("seconds", Json::num(self.seconds)),
        ])
    }

    fn from_json(j: &Json) -> Result<MethodResult> {
        let mut accs = Vec::new();
        for a in j.get("accs")?.as_arr()? {
            let pair = a.as_arr()?;
            accs.push((pair[0].as_str()?.to_string(), pair[1].as_f64()?));
        }
        Ok(MethodResult {
            method: j.get("method")?.as_str()?.to_string(),
            bits: j.get("bits")?.as_usize()? as u32,
            group: j.get("group")?.as_usize()?,
            accs,
            acc_avg: j.get("acc_avg")?.as_f64()?,
            ppl_wiki: j.get("ppl_wiki")?.as_f64()?,
            ppl_c4: j.get("ppl_c4")?.as_f64()?,
            seconds: j.get("seconds")?.as_f64()?,
        })
    }
}

/// Evaluate one model: (per-suite accs, avg, ppl wiki, ppl c4).
pub fn eval_model(
    ctx: &ExpCtx,
    model: &ModelRef,
) -> Result<(Vec<(String, f64)>, f64, f64, f64)> {
    let world = ctx.world_for(model.preset())?;
    let (accs, avg) =
        eval_zeroshot(ctx.rt.as_ref(), model, &world, EVAL_ITEMS_PER_SUITE, 1234)?;
    let ppl_w = perplexity(ctx.rt.as_ref(), model, &world, &domain_wiki(),
                           EVAL_PPL_BATCHES, 777)?;
    let ppl_c = perplexity(ctx.rt.as_ref(), model, &world, &domain_c4(),
                           EVAL_PPL_BATCHES, 778)?;
    Ok((accs, avg, ppl_w, ppl_c))
}

pub const SWEEP_METHODS: [&str; 7] = [
    "RTN", "GPTQ", "AWQ", "OmniQ-like", "AutoRound-like", "NaiveQAT",
    "EfficientQAT",
];

/// Quantize with one named method.
pub fn quantize_with(
    ctx: &ExpCtx,
    preset: &str,
    params: &[f32],
    sch: QuantScheme,
    method: &str,
) -> Result<crate::model::quantized::QuantizedModel> {
    let world = ctx.world_for(preset)?;
    let dom = domain_redpajama();
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let hp = TrainHp::default();
    let cal_pool = || {
        let n = (hp.block_samples + cfg.block_batch - 1) / cfg.block_batch;
        LmLoader::new(&world, &dom, hp.seed ^ 0xB10C, cfg.block_batch,
                      cfg.block_ctx)
            .sample_pool(n)
    };
    Ok(match method {
        "RTN" => crate::coordinator::block_ap::rtn_quantize_model(
            ctx.rt.as_ref(), preset, params, sch)?,
        "GPTQ" => ptq_quantize_model(ctx.rt.as_ref(), preset, params, sch,
                                     &cal_pool(), PtqMethod::Gptq, 512)?,
        "AWQ" => ptq_quantize_model(ctx.rt.as_ref(), preset, params, sch,
                                    &cal_pool(), PtqMethod::Awq, 512)?,
        "OmniQ-like" => {
            // block-wise training of (s, z) only, no E2E phase
            let mut h = hp.clone();
            h.trainable = TrainableSet::SZ;
            efficient_qat(ctx.rt.as_ref(), preset, params, sch, &h, &world, &dom,
                          PhaseToggle { block_ap: true, e2e_qp: false })?
                .0
        }
        "AutoRound-like" => {
            let mut h = hp.clone();
            h.trainable = TrainableSet::Round;
            efficient_qat(ctx.rt.as_ref(), preset, params, sch, &h, &world, &dom,
                          PhaseToggle { block_ap: true, e2e_qp: false })?
                .0
        }
        "NaiveQAT" => {
            let n = (hp.e2e_samples + cfg.e2e_batch - 1) / cfg.e2e_batch;
            let pool = LmLoader::new(&world, &dom, hp.seed ^ 0xAA7,
                                     cfg.e2e_batch, cfg.e2e_ctx)
                .sample_pool(n);
            run_naive_qat(ctx.rt.as_ref(), preset, params, sch, &pool, 1,
                          hp.e2e_lr)?
                .0
        }
        "EfficientQAT" => {
            efficient_qat(ctx.rt.as_ref(), preset, params, sch, &hp, &world, &dom,
                          PhaseToggle::default())?
                .0
        }
        _ => anyhow::bail!("unknown method {method}"),
    })
}

/// Full sweep with JSON caching. Schemes: the presets' main grid.
pub fn method_sweep(ctx: &ExpCtx, preset: &str)
                    -> Result<Vec<MethodResult>> {
    let cache = ctx.runs_dir.join(format!("sweep-{preset}.json"));
    if cache.exists() {
        let j = Json::parse(&std::fs::read_to_string(&cache)?)?;
        return j.as_arr()?.iter().map(MethodResult::from_json).collect();
    }

    let params = ctx.pretrained(preset)?;
    let mut results = Vec::new();

    // FP16 reference
    let t0 = std::time::Instant::now();
    let fp = ModelRef::Fp { preset, params: &params };
    let (accs, avg, pw, pc) = eval_model(ctx, &fp)?;
    results.push(MethodResult {
        method: "FP16".into(), bits: 16, group: 0,
        accs, acc_avg: avg, ppl_wiki: pw, ppl_c4: pc,
        seconds: t0.elapsed().as_secs_f64(),
    });

    let g = ctx.rt.manifest().preset(preset)?.config.default_group;
    let mut schemes =
        vec![QuantScheme::new(4, g), QuantScheme::new(3, g),
             QuantScheme::new(2, g)];
    // the paper's extra 2-bit finer-group row
    let groups =
        &ctx.rt.manifest().preset(preset)?.config.group_sizes;
    if let Some(&g2) = groups.iter().find(|&&x| x > g) {
        schemes.push(QuantScheme::new(2, g2));
    }

    for sch in schemes {
        for method in SWEEP_METHODS {
            // NaiveQAT only at 2-bit (the paper's Table 2 regime) and only
            // at the default group: its artifact (e2e_full_step) is lowered
            // once per preset (train.DEFAULT_GROUP_ONLY)
            if method == "NaiveQAT" && (sch.bits != 2 || sch.group != g) {
                continue;
            }
            let t0 = std::time::Instant::now();
            let qm = quantize_with(ctx, preset, &params, sch, method)?;
            let (accs, avg, pw, pc) =
                eval_model(ctx, &ModelRef::Quant(&qm))?;
            crate::info!(
                "sweep[{preset}] {method} {}: acc {avg:.3} pplw {pw:.2} \
                 ({:.1}s)",
                sch.tag(),
                t0.elapsed().as_secs_f64()
            );
            results.push(MethodResult {
                method: method.into(),
                bits: sch.bits,
                group: sch.group,
                accs,
                acc_avg: avg,
                ppl_wiki: pw,
                ppl_c4: pc,
                seconds: t0.elapsed().as_secs_f64(),
            });
        }
    }

    let j = Json::arr(results.iter().map(|r| r.to_json()).collect());
    std::fs::write(&cache, j.dump())?;
    Ok(results)
}
