//! One driver per paper table/figure. Each returns the rendered markdown
//! (also printed), so the CLI and the EXPERIMENTS.md generator share them.
//! Scale note: our testbed is a synthetic-corpus CPU reproduction; the
//! claims under test are the paper's *shape* claims (who wins, by roughly
//! how much, where crossovers fall) - see DESIGN.md §6.

use anyhow::Result;

use crate::baselines::naive_qat::run_naive_qat;
use crate::baselines::ptq::{ptq_quantize_model, PtqMethod};
use crate::baselines::qlora::{merge_lora, run_peqa, run_qlora};
use crate::config::{llama_by_name, QuantScheme, TrainHp, TrainableSet};
use crate::coordinator::block_ap::{block_train_mem_bytes,
                                   rtn_quantize_model, run_block_ap};
use crate::coordinator::e2e_qp::{instr_batches, lm_batches, run_e2e_qp};
use crate::coordinator::pipeline::{efficient_qat, PhaseToggle};
use crate::data::corpus::{domain_by_name, domain_redpajama};
use crate::data::loader::{InstrLoader, LmLoader};
use crate::eval::fwd::ModelRef;
use crate::eval::zeroshot::{eval_items, eval_mmlu};
use crate::exp::sweeps::{eval_model, method_sweep};
use crate::exp::{fmt, md_table, ExpCtx};
use crate::quant::size::report as size_report;

pub fn run(ctx: &ExpCtx, id: &str, preset: &str) -> Result<String> {
    let out = match id {
        "t1" => t1(ctx, preset)?,
        "t2" => t2(ctx, preset)?,
        "t3" => t3(ctx, preset)?,
        "t4" => t4(ctx, preset)?,
        "t5" => t5(ctx, preset)?,
        "t6" => t6(ctx, preset)?,
        "t7" => t7(ctx, preset)?,
        "t8" => t8(ctx)?,
        "t9" => t9(ctx, preset)?,
        "t11" => t11()?,
        "t12" => t12(ctx, preset)?,
        "t13" => t13(ctx, preset)?,
        "t14" => t14(ctx, preset)?,
        "fig1" => fig1(ctx, preset)?,
        "fig3" => fig3(ctx, preset)?,
        "fig4" => fig4(ctx, preset)?,
        _ => anyhow::bail!(
            "unknown experiment '{id}' (t1-t9, t11-t14, fig1, fig3, fig4; \
             t10 = `eqat bench qlinear`)"
        ),
    };
    println!("{out}");
    let path = ctx.runs_dir.join(format!("{id}-{preset}.md"));
    std::fs::write(path, &out)?;
    Ok(out)
}

/// Table 1 analog: zero-shot accuracy, methods x bits.
fn t1(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let res = method_sweep(ctx, preset)?;
    let mut rows = Vec::new();
    for r in &res {
        let mut row = vec![
            r.method.clone(),
            if r.bits == 16 { "16".into() } else { r.bits.to_string() },
            if r.group == 0 { "-".into() } else { r.group.to_string() },
        ];
        for (_, a) in &r.accs {
            row.push(fmt(100.0 * a, 1));
        }
        row.push(fmt(100.0 * r.acc_avg, 1));
        rows.push(row);
    }
    let mut headers = vec!["Method", "Bits", "Group"];
    for (n, _) in &res[0].accs {
        headers.push(Box::leak(n.clone().into_boxed_str()));
    }
    headers.push("Avg");
    Ok(format!(
        "## Table 1 analog - zero-shot accuracy ({preset}, 5 synthetic \
         suites)\n\n{}",
        md_table(&headers, &rows)
    ))
}

/// Table 2 analog: QAT method comparison (ppl + acc at 2-bit).
fn t2(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let res = method_sweep(ctx, preset)?;
    let mut rows = Vec::new();
    for r in &res {
        if !(r.bits == 2 || r.bits == 16)
            || !matches!(r.method.as_str(),
                         "FP16" | "RTN" | "NaiveQAT" | "EfficientQAT")
        {
            continue;
        }
        rows.push(vec![
            r.method.clone(),
            r.bits.to_string(),
            if r.group == 0 { "-".into() } else { r.group.to_string() },
            fmt(r.ppl_wiki, 2),
            fmt(r.ppl_c4, 2),
            fmt(100.0 * r.acc_avg, 1),
        ]);
    }
    Ok(format!(
        "## Table 2 analog - vs QAT methods ({preset}; NaiveQAT = \
         LLM-QAT-style all-param dynamic-scale e2e)\n\n{}",
        md_table(&["Method", "Bits", "Group", "Wiki PPL", "C4 PPL",
                   "Avg Acc"], &rows)
    ))
}

/// Table 3 analog: wiki/c4 perplexity, methods x bits.
fn t3(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let res = method_sweep(ctx, preset)?;
    let mut rows = Vec::new();
    for r in &res {
        rows.push(vec![
            r.method.clone(),
            if r.bits == 16 { "16".into() } else { r.bits.to_string() },
            if r.group == 0 { "-".into() } else { r.group.to_string() },
            fmt(r.ppl_wiki, 2),
            fmt(r.ppl_c4, 2),
        ]);
    }
    Ok(format!(
        "## Table 3 analog - perplexity ({preset})\n\n{}",
        md_table(&["Method", "Bits", "Group", "Wiki PPL", "C4 PPL"], &rows)
    ))
}

/// Table 4 analog: instruction tuning -> MMLU-like accuracy.
fn t4(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let hp = TrainHp::default();

    let mk_batches = |n: usize| {
        let mut il = InstrLoader::new(&world, 91, 256, cfg.e2e_batch,
                                      cfg.e2e_ctx);
        instr_batches(&mut il, n)
    };
    let n_batches = 48;

    let mut rows: Vec<Vec<String>> = Vec::new();
    // base model, no tuning
    let base = ModelRef::Fp { preset, params: &params };
    rows.push(vec!["base (no tune)".into(), "16".into(), "-".into(),
                   fmt(100.0 * eval_mmlu(ctx.rt.as_ref(), &base, &world, 555)?, 1)]);

    for bits in [4u32, 2] {
        let sch = QuantScheme::new(bits, g);
        let batches = mk_batches(n_batches);

        // PEQA: RTN + s-only e2e on instructions
        let (peqa_m, _) = run_peqa(ctx.rt.as_ref(), preset, &params, sch, &batches,
                                   &hp)?;
        rows.push(vec![
            "PEQA".into(), bits.to_string(), g.to_string(),
            fmt(100.0 * eval_mmlu(ctx.rt.as_ref(), &ModelRef::Quant(&peqa_m),
                                  &world, 555)?, 1),
        ]);

        // QLoRA (bits + fp16 LoRA) - only the 4-bit row, as in the paper
        if bits == 4 {
            let qbase = rtn_quantize_model(ctx.rt.as_ref(), preset, &params, sch)?;
            let (lora, _) = run_qlora(ctx.rt.as_ref(), &qbase, &batches, 1,
                                      2e-3, 33)?;
            rows.push(vec![
                "QLoRA".into(), format!("{bits}+16"), "-".into(),
                fmt(100.0 * eval_mmlu(
                    ctx.rt.as_ref(),
                    &ModelRef::Lora { qm: &qbase, lora: &lora },
                    &world, 555)?, 1),
            ]);
            // QLoRA w/ GPTQ: merge LoRA -> fp, re-quantize with GPTQ
            let merged = merge_lora(ctx.rt.as_ref(), &qbase, &lora)?;
            let cal = LmLoader::new(&world, &domain_redpajama(), 0xCA1,
                                    cfg.block_batch, cfg.block_ctx)
                .sample_pool(8);
            let requant = ptq_quantize_model(ctx.rt.as_ref(), preset, &merged, sch,
                                             &cal, PtqMethod::Gptq, 512)?;
            rows.push(vec![
                "QLoRA w/ GPTQ".into(), bits.to_string(), g.to_string(),
                fmt(100.0 * eval_mmlu(ctx.rt.as_ref(), &ModelRef::Quant(&requant),
                                      &world, 555)?, 1),
            ]);
        }

        // EfficientQAT: Block-AP on LM data, then E2E-QP on instructions
        let (mut eq, _) = efficient_qat(
            ctx.rt.as_ref(), preset, &params, sch, &hp, &world,
            &domain_redpajama(),
            PhaseToggle { block_ap: true, e2e_qp: false })?;
        run_e2e_qp(ctx.rt.as_ref(), &mut eq, &batches, &hp)?;
        rows.push(vec![
            "EfficientQAT".into(), bits.to_string(), g.to_string(),
            fmt(100.0 * eval_mmlu(ctx.rt.as_ref(), &ModelRef::Quant(&eq), &world,
                                  555)?, 1),
        ]);
    }
    Ok(format!(
        "## Table 4 analog - instruction tuning, MMLU-like few-shot acc \
         ({preset})\n\n{}",
        md_table(&["Method", "Bits", "Group", "MMLU-like"], &rows)
    ))
}

/// Table 5: component ablation (Block-AP x E2E-QP) at w2, default group.
fn t5(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let g = ctx.rt.manifest().preset(preset)?.config.default_group;
    let sch = QuantScheme::new(2, g);
    let hp = TrainHp::default();
    let dom = domain_redpajama();
    let combos = [(false, false), (true, false), (false, true),
                  (true, true)];
    let mut rows = Vec::new();
    for (bap, e2e) in combos {
        let (qm, _) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp,
                                    &world, &dom,
                                    PhaseToggle { block_ap: bap,
                                                  e2e_qp: e2e })?;
        let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
        rows.push(vec![
            if bap { "+" } else { "-" }.into(),
            if e2e { "+" } else { "-" }.into(),
            fmt((pw + pc) / 2.0, 2),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Table 5 - component ablation ({preset} {})\n\n{}",
        sch.tag(),
        md_table(&["Block-AP", "E2E-QP", "Avg PPL", "Avg Acc"], &rows)
    ))
}

/// Table 6: Block-AP trainable-parameter ablation (w/o E2E-QP).
fn t6(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let dom = domain_redpajama();
    let bl = ctx.rt.manifest().layout(preset, "block")?.clone();
    let qbl = ctx.rt.manifest().layout(preset,
                                     &format!("qp_block_g{g}"))?.clone();
    let sets = [TrainableSet::Clipping, TrainableSet::SZ,
                TrainableSet::Round, TrainableSet::SZRound,
                TrainableSet::SZW];
    let mut rows = Vec::new();
    for set in sets {
        let mut hp = TrainHp::default();
        hp.trainable = set;
        let (qm, _) = efficient_qat(
            ctx.rt.as_ref(), preset, &params, sch, &hp, &world, &dom,
            PhaseToggle { block_ap: true, e2e_qp: false })?;
        let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
        let (mw, ms, mz, _) = set.masks();
        let n_train = (mw as usize) * bl.size
            + ((ms as usize) + (mz as usize)) * (qbl.size / 2);
        // memory: trained params get Adam moments; round variants carry the
        // extra window buffers (the paper's "copy of rounding parameters")
        let mem = block_train_mem_bytes(&bl, &qbl, cfg.block_batch,
                                        cfg.block_ctx, cfg.dim);
        rows.push(vec![
            set.name().into(),
            format!("{:.2}M", n_train as f64 / 1e6),
            format!("{:.1}MB", mem as f64 / 1e6),
            fmt((pw + pc) / 2.0, 2),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Table 6 - Block-AP trainable parameters ({preset} {}, w/o \
         E2E-QP)\n\n{}",
        sch.tag(),
        md_table(&["Trained", "# Param", "Mem est", "Avg PPL", "Avg Acc"],
                 &rows)
    ))
}

/// Table 7: E2E-QP trainable parameters (s / z / s,z), w/ Block-AP.
fn t7(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let dom = domain_redpajama();
    // one Block-AP, three E2E variants from the same init
    let hp0 = TrainHp::default();
    let (base, _) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp0,
                                  &world, &dom,
                                  PhaseToggle { block_ap: true,
                                                e2e_qp: false })?;
    let n = (hp0.e2e_samples + cfg.e2e_batch - 1) / cfg.e2e_batch;
    let pool = LmLoader::new(&world, &dom, hp0.seed ^ 0xE2E0, cfg.e2e_batch,
                             cfg.e2e_ctx)
        .sample_pool(n);
    let batches = lm_batches(&pool);
    let variants = [("s", true, false), ("z", false, true),
                    ("s,z", true, true)];
    let mut rows = Vec::new();
    for (name, ts, tz) in variants {
        let mut qm = base.clone();
        let mut hp = hp0.clone();
        hp.train_s_e2e = ts;
        hp.train_z_e2e = tz;
        run_e2e_qp(ctx.rt.as_ref(), &mut qm, &batches, &hp)?;
        let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
        // avg bits: training z promotes it from N-bit storage to FP16
        let extra = if tz { (16.0 - sch.bits as f64) / g as f64 } else { 0.0 };
        rows.push(vec![
            name.into(),
            fmt(sch.avg_bits() + extra, 2),
            fmt((pw + pc) / 2.0, 2),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Table 7 - E2E-QP trainable parameters ({preset} {}, w/ \
         Block-AP)\n\n{}",
        sch.tag(),
        md_table(&["Trained", "Avg Bits", "Avg PPL", "Avg Acc"], &rows)
    ))
}

/// Table 8: training time & memory by model size.
fn t8(ctx: &ExpCtx) -> Result<String> {
    let mut rows = Vec::new();
    for preset in ["tiny", "small"] {
        let params = ctx.pretrained(preset)?;
        let world = ctx.world_for(preset)?;
        let g = ctx.rt.manifest().preset(preset)?.config.default_group;
        let sch = QuantScheme::new(2, g);
        let hp = TrainHp::default();
        let dom = domain_redpajama();
        let (_, report) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp,
                                        &world, &dom,
                                        PhaseToggle::default())?;
        let bap = report.block_ap.as_ref().unwrap();
        let e2e = report.e2e.as_ref().unwrap();
        let fpl = ctx.rt.manifest().layout(preset, "fp")?;
        rows.push(vec![
            preset.into(),
            format!("{:.1}M", fpl.size as f64 / 1e6),
            fmt(bap.seconds, 1),
            format!("{:.1}MB", bap.mem_bytes as f64 / 1e6),
            fmt(e2e.seconds, 1),
            format!("{:.1}MB", e2e.mem_bytes as f64 / 1e6),
            fmt(report.total_seconds, 1),
        ]);
    }
    Ok(format!(
        "## Table 8 analog - EfficientQAT training cost (w2, CPU \
         seconds / analytic memory)\n\n{}",
        md_table(&["Model", "Params", "Block-AP s", "Block-AP mem",
                   "E2E-QP s", "E2E-QP mem", "Total s"], &rows)
    ))
}

/// Table 9 analog: training time vs the naive-QAT comparator at matched
/// token budgets, plus the memory ratio (the single-GPU claim).
fn t9(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let hp = TrainHp::default();
    let dom = domain_redpajama();

    let (_, report) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp,
                                    &world, &dom, PhaseToggle::default())?;
    let eq_secs = report.total_seconds;
    let eq_mem = report.block_ap.as_ref().unwrap().mem_bytes
        .max(report.e2e.as_ref().unwrap().mem_bytes);

    let n = (hp.e2e_samples + cfg.e2e_batch - 1) / cfg.e2e_batch;
    let pool = LmLoader::new(&world, &dom, hp.seed ^ 0xAA7, cfg.e2e_batch,
                             cfg.e2e_ctx)
        .sample_pool(n);
    // match total optimization steps: block epochs add up
    let epochs = 1 + hp.block_epochs;
    let (_, nq) = run_naive_qat(ctx.rt.as_ref(), preset, &params, sch, &pool,
                                epochs, hp.e2e_lr)?;
    let rows = vec![
        vec!["EfficientQAT".into(), fmt(eq_secs, 1),
             format!("{:.1}MB", eq_mem as f64 / 1e6), "1.00x".into()],
        vec!["NaiveQAT (LLM-QAT-style)".into(), fmt(nq.seconds, 1),
             format!("{:.1}MB", nq.mem_bytes as f64 / 1e6),
             format!("{:.2}x", nq.seconds / eq_secs)],
    ];
    Ok(format!(
        "## Table 9 analog - training cost vs naive QAT ({preset} {}, \
         matched token budget)\n\n{}",
        sch.tag(),
        md_table(&["Method", "Wall s", "Train mem", "Time ratio"], &rows)
    ))
}

/// Table 11: exact size arithmetic for the real Llama-2 family.
fn t11() -> Result<String> {
    let mut rows = Vec::new();
    for name in ["llama2-7b", "llama2-13b", "llama2-70b"] {
        let shape = llama_by_name(name)?;
        rows.push(vec![shape.name.into(), "16".into(), "-".into(),
                       "16".into(),
                       fmt(crate::quant::size::fp16_size_gib(&shape), 2),
                       "-".into()]);
        for bits in [4u32, 3, 2] {
            for group in [32usize, 64, 128] {
                let r = size_report(&shape, QuantScheme::new(bits, group));
                rows.push(vec![
                    shape.name.into(),
                    bits.to_string(),
                    group.to_string(),
                    fmt(r.bits_per_param, 2),
                    fmt(r.size_gib, 2),
                    fmt(r.compression_pct, 2),
                ]);
            }
        }
    }
    Ok(format!(
        "## Table 11 - quantized model sizes (exact arithmetic, real \
         Llama-2 shapes)\n\n{}",
        md_table(&["Model", "Bits", "Group", "bits/param", "GiB",
                   "Compression %"], &rows)
    ))
}

/// Table 12: group-size ablation at 2-bit.
fn t12(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let groups = ctx.rt.manifest().preset(preset)?.config.group_sizes.clone();
    let hp = TrainHp::default();
    let dom = domain_redpajama();
    let mut rows = Vec::new();
    for g in groups {
        let sch = QuantScheme::new(2, g);
        let (qm, _) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp,
                                    &world, &dom, PhaseToggle::default())?;
        let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
        rows.push(vec![
            g.to_string(),
            fmt(sch.avg_bits(), 2),
            fmt((pw + pc) / 2.0, 2),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Table 12 - group size ablation ({preset}, 2-bit)\n\n{}",
        md_table(&["Group", "Avg Bits", "Avg PPL", "Avg Acc"], &rows)
    ))
}

/// Table 13: Block-AP calibration-dataset ablation (w/o E2E-QP).
fn t13(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let g = ctx.rt.manifest().preset(preset)?.config.default_group;
    let mut rows = Vec::new();
    for bits in [3u32, 2] {
        let sch = QuantScheme::new(bits, g);
        for dom_name in ["wiki", "c4", "redpajama"] {
            let dom = domain_by_name(dom_name)?;
            let hp = TrainHp::default();
            let (qm, _) = efficient_qat(
                ctx.rt.as_ref(), preset, &params, sch, &hp, &world, &dom,
                PhaseToggle { block_ap: true, e2e_qp: false })?;
            let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
            rows.push(vec![
                sch.tag(),
                dom_name.into(),
                fmt(pw, 2),
                fmt(pc, 2),
                fmt(100.0 * avg, 1),
            ]);
        }
    }
    Ok(format!(
        "## Table 13 - calibration dataset ablation ({preset}, Block-AP \
         only)\n\n{}",
        md_table(&["Bits", "Calib set", "Wiki PPL", "C4 PPL", "Avg Acc"],
                 &rows)
    ))
}

/// Table 14 analog - "multimodal" instruction tuning. Substitution
/// (DESIGN.md §4): vision features become discrete visual tokens encoding a
/// latent fact; compares QLoRA+Block-AP (quantize after tuning) against
/// EfficientQAT (tune the quantized model) on the VQA-like suite.
fn t14(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let hp = TrainHp::default();
    let mk_batches = |n: usize| {
        let mut il = InstrLoader::new(&world, 92, 256, cfg.e2e_batch,
                                      cfg.e2e_ctx);
        instr_batches(&mut il, n)
    };
    let eval_vqa = |m: &ModelRef| -> Result<f64> {
        let items = crate::data::tasks::gen_mmlu(&world, 4, 24, 1, 777);
        eval_items(ctx.rt.as_ref(), m, &items)
    };
    let mut rows = Vec::new();
    for bits in [4u32, 2] {
        let sch = QuantScheme::new(bits, g);
        let batches = mk_batches(32);
        // QLoRA then Block-AP requantization (paper's "QLoRA + Block-AP")
        let qbase = rtn_quantize_model(ctx.rt.as_ref(), preset, &params,
                                       QuantScheme::new(4, g))?;
        let (lora, _) = run_qlora(ctx.rt.as_ref(), &qbase, &batches, 1, 2e-3, 34)?;
        let merged = merge_lora(ctx.rt.as_ref(), &qbase, &lora)?;
        let dom = domain_redpajama();
        let (ql_bap, _) = efficient_qat(
            ctx.rt.as_ref(), preset, &merged, sch, &hp, &world, &dom,
            PhaseToggle { block_ap: true, e2e_qp: false })?;
        rows.push(vec![
            "QLoRA + Block-AP".into(), format!("4+16 -> {bits}"),
            fmt(100.0 * eval_vqa(&ModelRef::Quant(&ql_bap))?, 1),
        ]);
        // EfficientQAT end-to-end at the target bits
        let (mut eq, _) = efficient_qat(
            ctx.rt.as_ref(), preset, &params, sch, &hp, &world, &dom,
            PhaseToggle { block_ap: true, e2e_qp: false })?;
        run_e2e_qp(ctx.rt.as_ref(), &mut eq, &batches, &hp)?;
        rows.push(vec![
            "EfficientQAT".into(), format!("{bits}"),
            fmt(100.0 * eval_vqa(&ModelRef::Quant(&eq))?, 1),
        ]);
    }
    Ok(format!(
        "## Table 14 analog - multimodal-style tuning ({preset}; vision \
         features simulated as discrete visual tokens, see DESIGN.md §4)\
         \n\n{}",
        md_table(&["Method", "Bits (train -> infer)", "VQA-like Acc"],
                 &rows)
    ))
}

/// Fig 1 summaries re-rendered from cached sweep data.
fn fig1(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let res = method_sweep(ctx, preset)?;
    let mut rows = Vec::new();
    for r in &res {
        if r.bits == 2 {
            rows.push(vec![r.method.clone(),
                           format!("w2g{}", r.group),
                           fmt(100.0 * r.acc_avg, 1),
                           fmt(r.seconds, 1)]);
        }
    }
    Ok(format!(
        "## Figure 1a/1c analog - 2-bit accuracy & quantization wall-time \
         ({preset})\n\n{}",
        md_table(&["Method", "Scheme", "Avg Acc", "Quantize s"], &rows)
    ))
}

/// Fig 3: Block-AP calibration-sample sweep -> train/val gap + accuracy.
fn fig3(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let dom = domain_redpajama();
    let sweep = [16usize, 32, 64, 128, 256];
    let base_steps = 2 * 256; // epochs x samples kept ~constant
    let mut rows = Vec::new();
    for samples in sweep {
        let mut hp = TrainHp::default();
        hp.block_samples = samples;
        hp.block_epochs = (base_steps / samples).max(1);
        let n_cal = (samples + cfg.block_batch - 1) / cfg.block_batch;
        let pool = LmLoader::new(&world, &dom, hp.seed ^ 0xB10C,
                                 cfg.block_batch, cfg.block_ctx)
            .sample_pool(n_cal.max(1));
        let val = LmLoader::new(&world, &dom, hp.seed ^ 0x7A11,
                                cfg.block_batch, cfg.block_ctx)
            .sample_pool(4);
        let out = run_block_ap(ctx.rt.as_ref(), preset, &params, sch, &hp, &pool,
                               &val)?;
        let train: f64 = out.report.train_losses.iter()
            .map(|&x| x as f64).sum::<f64>()
            / out.report.train_losses.len() as f64;
        let vall: f64 = out.report.val_losses.iter()
            .map(|&x| x as f64).sum::<f64>()
            / out.report.val_losses.len() as f64;
        let (_, avg, _, _) = eval_model(ctx, &ModelRef::Quant(&out.model))?;
        rows.push(vec![
            samples.to_string(),
            hp.block_epochs.to_string(),
            format!("{train:.4}"),
            format!("{vall:.4}"),
            format!("{:.3}", vall / train.max(1e-9)),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Figure 3 analog - Block-AP sample count vs overfitting \
         ({preset} {}, steps held ~constant)\n\n{}",
        sch.tag(),
        md_table(&["Samples", "Epochs", "Train loss", "Val loss",
                   "Val/Train", "Avg Acc"], &rows)
    ))
}

/// Fig 4 (table): E2E-QP sample-count sweep.
fn fig4(ctx: &ExpCtx, preset: &str) -> Result<String> {
    let params = ctx.pretrained(preset)?;
    let world = ctx.world_for(preset)?;
    let cfg = ctx.rt.manifest().preset(preset)?.config.clone();
    let g = cfg.default_group;
    let sch = QuantScheme::new(2, g);
    let dom = domain_redpajama();
    let hp0 = TrainHp::default();
    let (base, _) = efficient_qat(ctx.rt.as_ref(), preset, &params, sch, &hp0,
                                  &world, &dom,
                                  PhaseToggle { block_ap: true,
                                                e2e_qp: false })?;
    let mut rows = Vec::new();
    for samples in [32usize, 64, 128, 256, 512] {
        let mut qm = base.clone();
        let n = (samples + cfg.e2e_batch - 1) / cfg.e2e_batch;
        let pool = LmLoader::new(&world, &dom, hp0.seed ^ 0xE2E0,
                                 cfg.e2e_batch, cfg.e2e_ctx)
            .sample_pool(n);
        let batches = lm_batches(&pool);
        run_e2e_qp(ctx.rt.as_ref(), &mut qm, &batches, &hp0)?;
        let (_, avg, pw, pc) = eval_model(ctx, &ModelRef::Quant(&qm))?;
        rows.push(vec![
            samples.to_string(),
            fmt((pw + pc) / 2.0, 2),
            fmt(100.0 * avg, 1),
        ]);
    }
    Ok(format!(
        "## Figure 4 analog - E2E-QP sample count ({preset} {})\n\n{}",
        sch.tag(),
        md_table(&["Samples", "Avg PPL", "Avg Acc"], &rows)
    ))
}
