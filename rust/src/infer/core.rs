//! The immutable half of the serving stack: [`ModelCore`] owns everything
//! a request does **not** mutate - packed (or dense) linears, norm
//! weights, the embedding/lm-head matrices, and precomputed RoPE sin/cos
//! tables - and exposes the three forward primitives every serving path
//! is built from:
//!
//! * [`ModelCore::step`] - one token through one sequence's paged KV
//!   rows (zero-alloc solo decode; the `Engine` facade's hot path);
//! * [`ModelCore::prefill`] / [`ModelCore::forward_logits`] - a batch of
//!   positions of **one** sequence through each linear as a single
//!   [`PackedLinear::matmul`] (prompt ingestion and eval forwards);
//! * [`ModelCore::decode_batch`] - the *last* token of **many** sequences
//!   through each linear as a single [`PackedLinear::matmul_rows`], each
//!   sequence attending against its own [`KvPool`](crate::infer::kv)
//!   rows (the continuous-batching scheduler's tick).
//!
//! A `ModelCore` is shared (`Arc`) between any number of sessions,
//! engines, schedulers, and threads; all mutable state lives in the
//! caller's [`Scratch`], [`KvPool`] page tables, and positions. Every
//! primitive addresses KV through a leased page table (see `infer::kv`
//! for the page / copy-on-write lifecycle): writes go through
//! `KvPool::prepare_rows` plus per-row/scatter accessors, reads stream
//! per-page segments in ascending row order. Numerics mirror
//! python/compile/model.py exactly (RMSNorm, split-half RoPE, causal
//! attention, SwiGLU).
//!
//! # Bit-exactness contract
//!
//! All three primitives produce **bit-identical** logits for the same
//! sequence at any batch size, chunking, worker count, and page size:
//! per-(token, row) accumulation order is fixed across
//! `matvec`/`matmul`/`matmul_rows` (and their dense siblings), attention
//! is the shared `attend_head_paged` in every path (its segment walk
//! visits rows in exactly the ascending order a contiguous cache would),
//! and the worker pool only partitions work. This is what makes
//! continuous batching and zero-copy prefix forking safe to ship:
//! co-batching requests or sharing prefix pages cannot change any
//! request's output (pinned by tests here, in `infer::sched`, in
//! `bench::serve_throughput`, and in the integration suite).
//!
//! Pools with a packed [`KvFormat`] (low-bit KV pages) carry the same
//! contract *within the mode*: K/V rows are quantized once at write
//! time by a scalar writer (identical stored bits under every
//! `EQAT_SIMD` setting) and attention streams the packed words through
//! the lane-order-pinned fused dequant kernels in `util::simd` - so
//! low-bit logits are bit-identical across batch size, chunking,
//! threads, page size, SIMD ISA, and cache hit vs cold, just not equal
//! to the f32 mode (the accuracy delta is tracked by the `kv_lowbit`
//! bench section).

use anyhow::{anyhow, bail, Result};

use crate::config::QuantScheme;
use crate::infer::kv::{KvFormat, KvLease, KvPool};
use crate::infer::qlinear::{dense_matmul, dense_matmul_rows, dense_matvec,
                            PackedLinear};
use crate::io::manifest::PresetInfo;
use crate::model::quantized::QuantizedModel;
use crate::quant::rtn::{minmax_init, quantize};
use crate::util::failpoint;
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::threads;

/// Below this many attention MACs (sequences * heads * positions *
/// head_dim), the per-head loop stays serial: even a pool dispatch
/// (~1-2us) would cost more than the work.
const ATT_PAR_MIN: usize = 1 << 13;

/// One transformer linear: packed low-bit (the deployment artifact) or
/// dense f32 (full-precision eval, LoRA-merged eval). Both sides share
/// the same batched/rows-parallel call surface so every forward primitive
/// is linear-kind agnostic.
pub enum Linear {
    Packed(PackedLinear),
    Dense { w: Vec<f32>, out_dim: usize, in_dim: usize },
}

impl Linear {
    fn matvec_in(&self, x: &[f32], y: &mut [f32], sx: &mut Vec<f32>) {
        match self {
            Linear::Packed(pl) => pl.matvec_in(x, y, sx),
            Linear::Dense { w, out_dim, in_dim } => {
                dense_matvec(w, *out_dim, *in_dim, x, y)
            }
        }
    }

    /// Batched matmul with caller-provided group-sum scratch (`sxs`),
    /// so repeated prefill calls reuse one allocation; the dense arm has
    /// no group sums and ignores it.
    fn matmul_in(&self, xs: &[f32], n: usize, ys: &mut [f32],
                 sxs: &mut Vec<f32>) {
        match self {
            Linear::Packed(pl) => pl.matmul_in(xs, n, ys, sxs),
            Linear::Dense { w, out_dim, in_dim } => {
                dense_matmul(w, *out_dim, *in_dim, xs, n, ys)
            }
        }
    }

    fn matmul_rows(&self, xs: &[f32], n: usize, ys: &mut [f32],
                   tmp: &mut Vec<f32>, sx: &mut Vec<f32>) {
        match self {
            Linear::Packed(pl) => pl.matmul_rows(xs, n, ys, tmp, sx),
            Linear::Dense { w, out_dim, in_dim } => {
                dense_matmul_rows(w, *out_dim, *in_dim, xs, n, ys, tmp)
            }
        }
    }
}

pub(crate) struct BlockW {
    pub(crate) attn_norm: Vec<f32>,
    pub(crate) mlp_norm: Vec<f32>,
    /// q, k, v, o, gate, up, down
    pub(crate) lins: Vec<Linear>,
}

/// Persistent intermediate buffers for one caller (engine, scheduler, or
/// eval loop). Solo decode (`ModelCore::step`) touches only the
/// fixed-size fields and allocates nothing in steady state; the `p_*`
/// prefill buffers grow to the longest chunk seen, the `b_*` batch
/// buffers to the largest decode batch, and both are then re-used - so a
/// steady-state scheduler tick is allocation-free too.
pub struct Scratch {
    vocab: usize,
    hn: Vec<f32>,       // dim
    q: Vec<f32>,        // dim
    ctx: Vec<f32>,      // dim
    attn_out: Vec<f32>, // dim
    gate: Vec<f32>,     // inter
    up: Vec<f32>,       // inter
    down: Vec<f32>,     // dim
    h: Vec<f32>,        // dim
    pub(crate) logits: Vec<f32>, // vocab
    /// per-head attention scores: n_heads rows of max_ctx
    att: Vec<f32>,
    /// shared group-sum scratch for `PackedLinear::matvec_in`
    sx: Vec<f32>,
    // batched buffers, row-major (n * width): prefill tokens or decode
    // batch rows
    p_h: Vec<f32>,
    p_hn: Vec<f32>,
    p_q: Vec<f32>,
    p_ctx: Vec<f32>,
    p_attn: Vec<f32>,
    p_gate: Vec<f32>,
    p_up: Vec<f32>,
    p_down: Vec<f32>,
    // prefill K/V staging before the per-page scatter (rows of one
    // chunk may span page boundaries)
    p_k: Vec<f32>,
    p_v: Vec<f32>,
    // decode-batch staging: per-tick K/V rows before the per-sequence
    // scatter, per-(sequence, head) score rows, per-sequence logits
    b_k: Vec<f32>,
    b_v: Vec<f32>,
    b_att: Vec<f32>,
    pub(crate) b_logits: Vec<f32>,
    // row-major scratch + per-token group sums for the *_rows kernels
    mm_tmp: Vec<f32>,
    mm_sx: Vec<f32>,
}

impl Scratch {
    pub(crate) fn new(dim: usize, inter: usize, vocab: usize,
                      n_heads: usize, max_ctx: usize) -> Scratch {
        Scratch {
            vocab,
            hn: vec![0.0; dim],
            q: vec![0.0; dim],
            ctx: vec![0.0; dim],
            attn_out: vec![0.0; dim],
            gate: vec![0.0; inter],
            up: vec![0.0; inter],
            down: vec![0.0; dim],
            h: vec![0.0; dim],
            logits: vec![0.0; vocab],
            att: vec![0.0; n_heads * max_ctx],
            sx: Vec::new(),
            p_h: Vec::new(),
            p_hn: Vec::new(),
            p_q: Vec::new(),
            p_ctx: Vec::new(),
            p_attn: Vec::new(),
            p_gate: Vec::new(),
            p_up: Vec::new(),
            p_down: Vec::new(),
            p_k: Vec::new(),
            p_v: Vec::new(),
            b_k: Vec::new(),
            b_v: Vec::new(),
            b_att: Vec::new(),
            b_logits: Vec::new(),
            mm_tmp: Vec::new(),
            mm_sx: Vec::new(),
        }
    }

    /// Logits of the last solo `step`/`prefill` call.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Logits row `i` of the last `decode_batch` call.
    pub fn batch_logits(&self, i: usize) -> &[f32] {
        &self.b_logits[i * self.vocab..(i + 1) * self.vocab]
    }
}

/// The immutable, shareable model: weights + geometry + RoPE tables.
/// See the module docs for the forward primitives and the bit-exactness
/// contract.
pub struct ModelCore {
    pub dim: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub inter: usize,
    pub vocab: usize,
    /// KV capacity per sequence (the row budget every pool built for
    /// this core pages out).
    pub max_ctx: usize,
    #[allow(dead_code)]
    pub(crate) rope_theta: f64,
    pub(crate) norm_eps: f32,
    pub(crate) embed: Vec<f32>,
    pub(crate) final_norm: Vec<f32>,
    pub(crate) head: Vec<f32>,
    pub(crate) blocks: Vec<BlockW>,
    /// precomputed RoPE tables, (max_ctx * head_dim/2) each
    pub(crate) rope_cos: Vec<f32>,
    pub(crate) rope_sin: Vec<f32>,
}

impl ModelCore {
    /// Build from the in-memory quantized model + manifest preset info
    /// (the deployment path: packed low-bit linears).
    pub fn from_quantized(qm: &QuantizedModel, info: &PresetInfo,
                          max_ctx: usize) -> Result<ModelCore> {
        let cfg = &info.config;
        let g = qm.scheme.group;
        let wql = info.layouts.get("wq")
            .ok_or_else(|| anyhow!("missing wq layout"))?;
        let qpl = info.layouts.get(&format!("qp_g{g}"))
            .ok_or_else(|| anyhow!("missing qp_g{g} layout"))?;
        let fprl = info.layouts.get("fpr")
            .ok_or_else(|| anyhow!("missing fpr layout"))?;

        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let mut lins = Vec::with_capacity(7);
            for (name, _, _) in cfg.linears() {
                let we = wql.entry(&format!("blocks.{b}.{name}"))?;
                let (out_d, in_d) = (we.shape[0], we.shape[1]);
                let w_int = wql.slice(&qm.wq, &format!("blocks.{b}.{name}"))?;
                let s = qpl.slice(&qm.qp, &format!("s.blocks.{b}.{name}"))?;
                let z = qpl.slice(&qm.qp, &format!("z.blocks.{b}.{name}"))?;
                lins.push(Linear::Packed(PackedLinear::pack(
                    w_int, out_d, in_d, s, z, qm.scheme)?));
            }
            blocks.push(BlockW {
                attn_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.attn_norm"))?
                    .to_vec(),
                mlp_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.mlp_norm"))?
                    .to_vec(),
                lins,
            });
        }
        Ok(ModelCore::assemble(
            cfg.dim,
            cfg.n_heads,
            cfg.head_dim,
            cfg.inter,
            cfg.vocab,
            max_ctx,
            cfg.rope_theta,
            cfg.norm_eps as f32,
            fprl.slice(&qm.fpr, "embed")?.to_vec(),
            fprl.slice(&qm.fpr, "final_norm")?.to_vec(),
            fprl.slice(&qm.fpr, "head")?.to_vec(),
            blocks,
        ))
    }

    /// Build a randomly-initialized core directly from shapes, no
    /// manifest or artifacts needed: weights are RTN-quantized to `scheme`
    /// and packed exactly like the artifact path. This is the harness
    /// behind the serving benches and the batching/threading tests.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        dim: usize,
        n_heads: usize,
        head_dim: usize,
        inter: usize,
        vocab: usize,
        n_layers: usize,
        scheme: QuantScheme,
        max_ctx: usize,
        seed: u64,
    ) -> Result<ModelCore> {
        if n_heads * head_dim != dim {
            bail!("n_heads {n_heads} * head_dim {head_dim} != dim {dim}");
        }
        if dim % scheme.group != 0 || inter % scheme.group != 0 {
            bail!("group {} must divide dim {dim} and inter {inter}",
                  scheme.group);
        }
        let mut rng = Rng::new(seed);
        let shapes = [
            (dim, dim),   // attn.q
            (dim, dim),   // attn.k
            (dim, dim),   // attn.v
            (dim, dim),   // attn.o
            (inter, dim), // mlp.gate
            (inter, dim), // mlp.up
            (dim, inter), // mlp.down
        ];
        let mut blocks = Vec::with_capacity(n_layers);
        let mut wbuf: Vec<f32> = Vec::new();
        for _ in 0..n_layers {
            let mut lins = Vec::with_capacity(7);
            for &(o, i) in &shapes {
                wbuf.clear();
                wbuf.resize(o * i, 0.0);
                rng.fill_normal(&mut wbuf, 0.0, 0.05);
                let gp = minmax_init(&wbuf, o, i, scheme);
                let wi = quantize(&wbuf, &gp, scheme);
                lins.push(Linear::Packed(PackedLinear::pack(
                    &wi, o, i, &gp.s, &gp.z, scheme)?));
            }
            blocks.push(BlockW {
                attn_norm: vec![1.0; dim],
                mlp_norm: vec![1.0; dim],
                lins,
            });
        }
        let mut embed = vec![0f32; vocab * dim];
        rng.fill_normal(&mut embed, 0.0, 0.02);
        let mut head = vec![0f32; vocab * dim];
        rng.fill_normal(&mut head, 0.0, 0.02);
        Ok(ModelCore::assemble(dim, n_heads, head_dim, inter, vocab,
                               max_ctx, 10000.0, 1e-5, embed,
                               vec![1.0; dim], head, blocks))
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        dim: usize,
        n_heads: usize,
        head_dim: usize,
        inter: usize,
        vocab: usize,
        max_ctx: usize,
        rope_theta: f64,
        norm_eps: f32,
        embed: Vec<f32>,
        final_norm: Vec<f32>,
        head: Vec<f32>,
        blocks: Vec<BlockW>,
    ) -> ModelCore {
        let (rope_cos, rope_sin) = rope_tables(max_ctx, head_dim,
                                               rope_theta);
        ModelCore {
            dim,
            n_heads,
            head_dim,
            inter,
            vocab,
            max_ctx,
            rope_theta,
            norm_eps,
            embed,
            final_norm,
            head,
            blocks,
            rope_cos,
            rope_sin,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// A scratch sized for this core.
    pub fn scratch(&self) -> Scratch {
        Scratch::new(self.dim, self.inter, self.vocab, self.n_heads,
                     self.max_ctx)
    }

    fn check_token(&self, tok: i32) -> Result<()> {
        if tok < 0 || tok as usize >= self.vocab {
            bail!("token {tok} out of range (vocab {})", self.vocab);
        }
        Ok(())
    }

    /// One decode step of one sequence: feed `tok` at `pos` against the
    /// lease's rows `[0, pos]`; logits land in `sc.logits`. The caller
    /// owns and advances the position. Steady-state (no page boundary
    /// crossed, no COW fault) this allocates nothing.
    pub fn step(&self, pool: &mut KvPool, lease: &KvLease, pos: usize,
                tok: i32, sc: &mut Scratch) -> Result<()> {
        self.step_impl(pool, lease, pos, tok, sc, None)
    }

    pub(crate) fn step_impl(&self, pool: &mut KvPool, lease: &KvLease,
                            pos: usize, tok: i32, sc: &mut Scratch,
                            mut trace: Option<&mut Vec<Vec<f32>>>)
                            -> Result<()> {
        // fault-injection site, before any KV/scratch mutation
        failpoint::check("fwd.step")?;
        if pos >= self.max_ctx {
            bail!("KV cache full ({} positions)", self.max_ctx);
        }
        self.check_token(tok)?;
        pool.prepare_rows(lease, pos, 1)?;
        let d = self.dim;
        let nh = self.n_heads;
        let hd = self.head_dim;
        let it = self.inter;
        let eps = self.norm_eps;
        let mc = self.max_ctx;
        let p = pos;
        let packed = pool.format().is_packed();
        let Scratch {
            hn, q, ctx, attn_out, gate, up, down, h, logits, att, sx,
            p_k, p_v, ..
        } = sc;
        if packed {
            // packed pools stage K/V in scratch (rope, then
            // quantize-on-write); grown once, then steady-state
            // zero-alloc like the f32 path
            p_k.resize(d, 0.0);
            p_v.resize(d, 0.0);
        }

        h.copy_from_slice(
            &self.embed[tok as usize * d..(tok as usize + 1) * d]);
        let scale = 1.0 / (hd as f32).sqrt();
        for (bi, blk) in self.blocks.iter().enumerate() {
            rms_norm(&h[..], &blk.attn_norm, eps, &mut hn[..]);
            blk.lins[0].matvec_in(&hn[..], &mut q[..], sx);
            if packed {
                blk.lins[1].matvec_in(&hn[..], &mut p_k[..], sx);
                rope_apply(&mut p_k[..], p, nh, hd, &self.rope_cos,
                           &self.rope_sin);
                pool.put_k_row(lease, bi, p, &p_k[..]);
                blk.lins[2].matvec_in(&hn[..], &mut p_v[..], sx);
                pool.put_v_row(lease, bi, p, &p_v[..]);
            } else {
                {
                    let krow = pool.k_row_mut(lease, bi, p);
                    blk.lins[1].matvec_in(&hn[..], krow, sx);
                    rope_apply(krow, p, nh, hd, &self.rope_cos,
                               &self.rope_sin);
                }
                blk.lins[2].matvec_in(&hn[..],
                                      pool.v_row_mut(lease, bi, p), sx);
            }
            rope_apply(&mut q[..], p, nh, hd, &self.rope_cos,
                       &self.rope_sin);
            let pool_ref: &KvPool = pool;
            let qv: &[f32] = &q[..];
            // chunk i covers the same heads of both the context output and
            // the per-head score scratch; serial for short contexts
            let hpc = if nh * (p + 1) * hd < ATT_PAR_MIN {
                nh
            } else {
                threads::chunk_len(nh)
            };
            threads::par_chunks2_mut(
                &mut ctx[..],
                hpc * hd,
                &mut att[..],
                hpc * mc,
                |ci, cxc, atc| {
                    for (j, (ch, ath)) in cxc
                        .chunks_mut(hd)
                        .zip(atc.chunks_mut(mc))
                        .enumerate()
                    {
                        let hh = ci * hpc + j;
                        attend_head_paged(&qv[hh * hd..(hh + 1) * hd],
                                          pool_ref, lease, bi, hh, hd, p,
                                          scale, ath, ch);
                    }
                },
            );
            blk.lins[3].matvec_in(&ctx[..], &mut attn_out[..], sx);
            for i in 0..d {
                h[i] += attn_out[i];
            }
            rms_norm(&h[..], &blk.mlp_norm, eps, &mut hn[..]);
            blk.lins[4].matvec_in(&hn[..], &mut gate[..], sx);
            blk.lins[5].matvec_in(&hn[..], &mut up[..], sx);
            for i in 0..it {
                let gx = gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                gate[i] = silu * up[i];
            }
            blk.lins[6].matvec_in(&gate[..], &mut down[..], sx);
            for i in 0..d {
                h[i] += down[i];
            }
            if let Some(tr) = trace.as_mut() {
                tr.push(h.to_vec());
            }
        }
        rms_norm(&h[..], &self.final_norm[..], eps, &mut hn[..]);
        dense_matvec(&self.head[..], logits.len(), d, &hn[..],
                     &mut logits[..]);
        Ok(())
    }

    /// Feed `tokens` at positions `[pos, pos+n)` of one sequence: all
    /// positions run through each block's linears as one batched matmul,
    /// the K/V rows are staged then scattered into the lease's pages,
    /// and the final per-token hidden states land in `sc.p_h`. Logits of
    /// the *last* position land in `sc.logits`. Bit-exact with a
    /// sequential `step` loop at any chunking (prefilling `[0,8)` then
    /// `[8,12)` equals prefilling `[0,12)` equals 12 steps - tested),
    /// which is what makes the scheduler's chunked admission and
    /// `eval_items`' prefix forks exact.
    pub fn prefill(&self, pool: &mut KvPool, lease: &KvLease, pos: usize,
                   tokens: &[i32], sc: &mut Scratch) -> Result<()> {
        // fault-injection site, before any KV/scratch mutation
        failpoint::check("fwd.prefill")?;
        self.forward_rows(pool, lease, pos, tokens, sc)?;
        let n = tokens.len();
        let d = self.dim;
        let Scratch { p_h, hn, logits, .. } = sc;
        rms_norm(&p_h[(n - 1) * d..n * d], &self.final_norm[..],
                 self.norm_eps, &mut hn[..]);
        dense_matvec(&self.head[..], self.vocab, d, &hn[..],
                     &mut logits[..]);
        Ok(())
    }

    /// Evaluation forward: like [`ModelCore::prefill`] but writes logits
    /// for *every* fed position (token-major, n * vocab) into `out`.
    pub fn forward_logits(&self, pool: &mut KvPool, lease: &KvLease,
                          pos: usize, tokens: &[i32], sc: &mut Scratch,
                          out: &mut Vec<f32>) -> Result<()> {
        out.resize(tokens.len() * self.vocab, 0.0);
        self.forward_logits_slice(pool, lease, pos, tokens, sc,
                                  &mut out[..])
    }

    /// [`ModelCore::forward_logits`] into a caller-provided slice (len
    /// n * vocab, fully overwritten) - lets batched eval loops write each
    /// row's logits straight into its place in a larger buffer with no
    /// per-row allocation or copy.
    pub fn forward_logits_slice(&self, pool: &mut KvPool,
                                lease: &KvLease, pos: usize,
                                tokens: &[i32], sc: &mut Scratch,
                                out: &mut [f32]) -> Result<()> {
        let n = tokens.len();
        let d = self.dim;
        let v = self.vocab;
        if out.len() != n * v {
            bail!("forward_logits: out has {} elems, want {n}x{v}",
                  out.len());
        }
        self.forward_rows(pool, lease, pos, tokens, sc)?;
        let Scratch { p_h, p_hn, .. } = sc;
        for t in 0..n {
            rms_norm(&p_h[t * d..(t + 1) * d], &self.final_norm[..],
                     self.norm_eps, &mut p_hn[t * d..(t + 1) * d]);
        }
        dense_matmul(&self.head[..], v, d, &p_hn[..n * d], n, out);
        Ok(())
    }

    /// Batched single-sequence core behind `prefill`/`forward_logits`:
    /// runs `n` positions through every block, filling the lease's rows
    /// `[pos, pos+n)` in one pass (staged K/V matmul then a per-page
    /// scatter); final per-token hidden states land in `sc.p_h`.
    fn forward_rows(&self, pool: &mut KvPool, lease: &KvLease,
                    pos: usize, tokens: &[i32], sc: &mut Scratch)
                    -> Result<()> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty prefill");
        }
        if pos + n > self.max_ctx {
            bail!(
                "prompt of {n} tokens overflows KV cache ({} used of {})",
                pos, self.max_ctx
            );
        }
        for &t in tokens {
            self.check_token(t)?;
        }
        pool.prepare_rows(lease, pos, n)?;
        let d = self.dim;
        let nh = self.n_heads;
        let hd = self.head_dim;
        let it = self.inter;
        let eps = self.norm_eps;
        let p0 = pos;
        let Scratch {
            p_h, p_hn, p_q, p_ctx, p_attn, p_gate, p_up, p_down, p_k,
            p_v, mm_sx, ..
        } = sc;
        p_h.resize(n * d, 0.0);
        p_hn.resize(n * d, 0.0);
        p_q.resize(n * d, 0.0);
        p_ctx.resize(n * d, 0.0);
        p_attn.resize(n * d, 0.0);
        p_gate.resize(n * it, 0.0);
        p_up.resize(n * it, 0.0);
        p_down.resize(n * d, 0.0);
        p_k.resize(n * d, 0.0);
        p_v.resize(n * d, 0.0);

        for (t, &tok) in tokens.iter().enumerate() {
            p_h[t * d..(t + 1) * d].copy_from_slice(
                &self.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for (bi, blk) in self.blocks.iter().enumerate() {
            for t in 0..n {
                rms_norm(&p_h[t * d..(t + 1) * d], &blk.attn_norm, eps,
                         &mut p_hn[t * d..(t + 1) * d]);
            }
            blk.lins[0].matmul_in(&p_hn[..n * d], n, &mut p_q[..n * d],
                                  mm_sx);
            blk.lins[1].matmul_in(&p_hn[..n * d], n, &mut p_k[..n * d],
                                  mm_sx);
            for t in 0..n {
                rope_apply(&mut p_k[t * d..(t + 1) * d], p0 + t, nh, hd,
                           &self.rope_cos, &self.rope_sin);
            }
            pool.scatter_k(lease, bi, p0, &p_k[..n * d]);
            blk.lins[2].matmul_in(&p_hn[..n * d], n, &mut p_v[..n * d],
                                  mm_sx);
            pool.scatter_v(lease, bi, p0, &p_v[..n * d]);
            for t in 0..n {
                rope_apply(&mut p_q[t * d..(t + 1) * d], p0 + t, nh, hd,
                           &self.rope_cos, &self.rope_sin);
            }
            let pool_ref: &KvPool = pool;
            let qv: &[f32] = &p_q[..];
            // causal attention over the batch, token-chunked across
            // threads; workers allocate their own score buffers (prefill
            // is not the zero-alloc path)
            let tpc = if n * nh * (p0 + n) * hd < ATT_PAR_MIN {
                n
            } else {
                threads::chunk_len(n)
            };
            threads::par_chunks_mut(&mut p_ctx[..n * d], tpc * d,
                                    |ci, cxc| {
                let t0 = ci * tpc;
                let mut scores = vec![0f32; p0 + n];
                for (tl, ctx_t) in cxc.chunks_mut(d).enumerate() {
                    let t = t0 + tl;
                    let last = p0 + t; // attends to cache rows 0..=last
                    for hh in 0..nh {
                        attend_head_paged(
                            &qv[t * d + hh * hd..t * d + (hh + 1) * hd],
                            pool_ref, lease, bi, hh, hd, last, scale,
                            &mut scores,
                            &mut ctx_t[hh * hd..(hh + 1) * hd],
                        );
                    }
                }
            });
            blk.lins[3].matmul_in(&p_ctx[..n * d], n,
                                  &mut p_attn[..n * d], mm_sx);
            for i in 0..n * d {
                p_h[i] += p_attn[i];
            }
            for t in 0..n {
                rms_norm(&p_h[t * d..(t + 1) * d], &blk.mlp_norm, eps,
                         &mut p_hn[t * d..(t + 1) * d]);
            }
            blk.lins[4].matmul_in(&p_hn[..n * d], n,
                                  &mut p_gate[..n * it], mm_sx);
            blk.lins[5].matmul_in(&p_hn[..n * d], n, &mut p_up[..n * it],
                                  mm_sx);
            for i in 0..n * it {
                let gx = p_gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                p_gate[i] = silu * p_up[i];
            }
            blk.lins[6].matmul_in(&p_gate[..n * it], n,
                                  &mut p_down[..n * d], mm_sx);
            for i in 0..n * d {
                p_h[i] += p_down[i];
            }
        }
        Ok(())
    }

    /// One continuous-batching decode tick: feed `toks[i]` at
    /// `batch[i] = (lease, pos)` for every live sequence, running **one
    /// rows-parallel matmul per linear across the whole batch** (the
    /// weight unpack that solo decode pays per sequence per token
    /// amortizes to ~1/batch) while each sequence attends against its own
    /// paged rows. Per-sequence logits land in `sc.b_logits`
    /// ([`Scratch::batch_logits`]); callers advance each position.
    ///
    /// Bit-exactness: row i's logits are identical at every batch size -
    /// including batch 1 - to a solo [`ModelCore::step`] of the same
    /// sequence, at any thread count (see module docs; tested).
    pub fn decode_batch(&self, pool: &mut KvPool,
                        batch: &[(&KvLease, usize)], toks: &[i32],
                        sc: &mut Scratch) -> Result<()> {
        let nb = batch.len();
        if nb != toks.len() {
            bail!("decode_batch: {} leases vs {} tokens", nb, toks.len());
        }
        if nb == 0 {
            return Ok(());
        }
        // fault-injection site: a whole-batch fault, taken before any
        // per-sequence state changes so the scheduler's per-session
        // fallback sees untouched positions
        failpoint::check("fwd.decode")?;
        for &(lease, pos) in batch {
            if pos >= self.max_ctx {
                bail!("KV cache full ({} positions)", self.max_ctx);
            }
            pool.prepare_rows(lease, pos, 1)?;
        }
        for &t in toks {
            self.check_token(t)?;
        }
        let d = self.dim;
        let nh = self.n_heads;
        let hd = self.head_dim;
        let it = self.inter;
        let eps = self.norm_eps;
        let mc = self.max_ctx;
        let Scratch {
            p_h, p_hn, p_q, p_ctx, p_attn, p_gate, p_up, p_down,
            b_k, b_v, b_att, b_logits, mm_tmp, mm_sx, ..
        } = sc;
        p_h.resize(nb * d, 0.0);
        p_hn.resize(nb * d, 0.0);
        p_q.resize(nb * d, 0.0);
        p_ctx.resize(nb * d, 0.0);
        p_attn.resize(nb * d, 0.0);
        p_gate.resize(nb * it, 0.0);
        p_up.resize(nb * it, 0.0);
        p_down.resize(nb * d, 0.0);
        b_k.resize(nb * d, 0.0);
        b_v.resize(nb * d, 0.0);
        b_att.resize(nb * nh * mc, 0.0);
        b_logits.resize(nb * self.vocab, 0.0);

        for (i, &tok) in toks.iter().enumerate() {
            p_h[i * d..(i + 1) * d].copy_from_slice(
                &self.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for (bi, blk) in self.blocks.iter().enumerate() {
            for i in 0..nb {
                rms_norm(&p_h[i * d..(i + 1) * d], &blk.attn_norm, eps,
                         &mut p_hn[i * d..(i + 1) * d]);
            }
            blk.lins[0].matmul_rows(&p_hn[..nb * d], nb, &mut p_q[..nb * d],
                                    mm_tmp, mm_sx);
            blk.lins[1].matmul_rows(&p_hn[..nb * d], nb, &mut b_k[..nb * d],
                                    mm_tmp, mm_sx);
            blk.lins[2].matmul_rows(&p_hn[..nb * d], nb, &mut b_v[..nb * d],
                                    mm_tmp, mm_sx);
            // scatter each sequence's K/V row into its own pages at its
            // own position (RoPE on K and Q at that position); packed
            // pools rope the staged row, then quantize-on-write
            let packed = pool.format().is_packed();
            for (i, &(lease, pos)) in batch.iter().enumerate() {
                if packed {
                    rope_apply(&mut b_k[i * d..(i + 1) * d], pos, nh, hd,
                               &self.rope_cos, &self.rope_sin);
                    pool.put_k_row(lease, bi, pos,
                                   &b_k[i * d..(i + 1) * d]);
                    pool.put_v_row(lease, bi, pos,
                                   &b_v[i * d..(i + 1) * d]);
                } else {
                    let krow = pool.k_row_mut(lease, bi, pos);
                    krow.copy_from_slice(&b_k[i * d..(i + 1) * d]);
                    rope_apply(krow, pos, nh, hd, &self.rope_cos,
                               &self.rope_sin);
                    pool.v_row_mut(lease, bi, pos)
                        .copy_from_slice(&b_v[i * d..(i + 1) * d]);
                }
                rope_apply(&mut p_q[i * d..(i + 1) * d], pos, nh, hd,
                           &self.rope_cos, &self.rope_sin);
            }
            // per-(sequence, head) attention against each sequence's own
            // rows; chunk granularity is one head, like solo decode
            let pool_ref: &KvPool = pool;
            let qv: &[f32] = &p_q[..];
            let total_mac: usize =
                batch.iter().map(|&(_, p)| nh * (p + 1) * hd).sum();
            let attend_one = |j: usize, ch: &mut [f32], ath: &mut [f32]| {
                let (i, hh) = (j / nh, j % nh);
                let (lease, pos) = batch[i];
                attend_head_paged(
                    &qv[i * d + hh * hd..i * d + (hh + 1) * hd],
                    pool_ref, lease, bi, hh, hd, pos, scale, ath, ch);
            };
            if total_mac < ATT_PAR_MIN {
                for (j, (ch, ath)) in p_ctx[..nb * d]
                    .chunks_mut(hd)
                    .zip(b_att[..nb * nh * mc].chunks_mut(mc))
                    .enumerate()
                {
                    attend_one(j, ch, ath);
                }
            } else {
                threads::par_chunks2_mut(
                    &mut p_ctx[..nb * d], hd,
                    &mut b_att[..nb * nh * mc], mc,
                    |j, ch, ath| attend_one(j, ch, ath),
                );
            }
            blk.lins[3].matmul_rows(&p_ctx[..nb * d], nb,
                                    &mut p_attn[..nb * d], mm_tmp, mm_sx);
            for i in 0..nb * d {
                p_h[i] += p_attn[i];
            }
            for i in 0..nb {
                rms_norm(&p_h[i * d..(i + 1) * d], &blk.mlp_norm, eps,
                         &mut p_hn[i * d..(i + 1) * d]);
            }
            blk.lins[4].matmul_rows(&p_hn[..nb * d], nb,
                                    &mut p_gate[..nb * it], mm_tmp, mm_sx);
            blk.lins[5].matmul_rows(&p_hn[..nb * d], nb,
                                    &mut p_up[..nb * it], mm_tmp, mm_sx);
            for i in 0..nb * it {
                let gx = p_gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                p_gate[i] = silu * p_up[i];
            }
            blk.lins[6].matmul_rows(&p_gate[..nb * it], nb,
                                    &mut p_down[..nb * d], mm_tmp, mm_sx);
            for i in 0..nb * d {
                p_h[i] += p_down[i];
            }
        }
        for i in 0..nb {
            rms_norm(&p_h[i * d..(i + 1) * d], &self.final_norm[..], eps,
                     &mut p_hn[i * d..(i + 1) * d]);
        }
        dense_matmul_rows(&self.head[..], self.vocab, d, &p_hn[..nb * d],
                          nb, &mut b_logits[..nb * self.vocab], mm_tmp);
        Ok(())
    }
}

/// Softmax attention for one head over a sequence's KV rows 0..=`last`,
/// read through its page table: scores go through `scores` scratch (len
/// >= last+1), the weighted value sum lands in `ch` (len head_dim).
/// Shared by the solo-decode, batched prefill, and batched-decode paths
/// so their numerics can never diverge (every cross-path bit-exactness
/// test depends on this). The page-segment walk visits rows in ascending
/// order, so every FMA happens in exactly the sequence a contiguous
/// cache would produce - paging cannot perturb a single bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_head_paged(qh: &[f32], pool: &KvPool,
                                lease: &KvLease, layer: usize, hh: usize,
                                hd: usize, last: usize, scale: f32,
                                scores: &mut [f32], ch: &mut [f32]) {
    if pool.format().is_packed() {
        attend_head_packed(qh, pool, lease, layer, hh, hd, last, scale,
                           scores, ch);
        return;
    }
    let d = pool.dim;
    let n_rows = last + 1;
    let sc = &mut scores[..n_rows];
    let mut mx = f32::NEG_INFINITY;
    let mut u0 = 0usize;
    while u0 < n_rows {
        let (kseg, rows) = pool.k_seg(lease, layer, u0, n_rows - u0);
        for r in 0..rows {
            let kh = &kseg[r * d + hh * hd..r * d + (hh + 1) * hd];
            let mut s = 0f32;
            for i in 0..hd {
                s += qh[i] * kh[i];
            }
            let s = s * scale;
            mx = mx.max(s);
            sc[u0 + r] = s;
        }
        u0 += rows;
    }
    let mut zsum = 0f32;
    for s in sc.iter_mut() {
        *s = (*s - mx).exp();
        zsum += *s;
    }
    ch.fill(0.0);
    let mut u0 = 0usize;
    while u0 < n_rows {
        let (vseg, rows) = pool.v_seg(lease, layer, u0, n_rows - u0);
        for r in 0..rows {
            let vh = &vseg[r * d + hh * hd..r * d + (hh + 1) * hd];
            let w = sc[u0 + r] / zsum;
            for i in 0..hd {
                ch[i] += w * vh[i];
            }
        }
        u0 += rows;
    }
}

/// [`attend_head_paged`] for packed [`KvFormat`] pools: the same
/// ascending segment walk, but each row's head slice stays packed and
/// streams through the fused dequant kernels. The per-row affine code
/// `x ~ q * scale + zero` turns the dequantized dot into
/// `scale * dot(q, qv) + zero * sum(qv)` (with `sum(qv)` computed once
/// per call, scalar), and the value pass into a fused
/// `ch[i] += (w * scale) * q[i] + (w * zero)` axpy - attention reads
/// 4-8x fewer bytes and never materializes an f32 row. Requires
/// `head_dim % 8 == 0` so head slices are whole packed words.
#[allow(clippy::too_many_arguments)]
fn attend_head_packed(qh: &[f32], pool: &KvPool, lease: &KvLease,
                      layer: usize, hh: usize, hd: usize, last: usize,
                      scale: f32, scores: &mut [f32], ch: &mut [f32]) {
    let fmt = pool.format();
    let vpw = fmt.vals_per_word();
    debug_assert_eq!(hd % 8, 0, "packed KV needs head_dim % 8 == 0");
    let rw = pool.dim / vpw; // packed words per row
    let hw = hd / vpw; // packed words per head slice
    let n_rows = last + 1;
    let sc = &mut scores[..n_rows];
    // sum(qv) for the zero-point term, fixed scalar order
    let mut qsum = 0f32;
    for &x in qh {
        qsum += x;
    }
    let mut mx = f32::NEG_INFINITY;
    let mut u0 = 0usize;
    while u0 < n_rows {
        let (kw, ksz, rows) = pool.k_seg_q(lease, layer, u0, n_rows - u0);
        for r in 0..rows {
            let wrow = &kw[r * rw + hh * hw..r * rw + (hh + 1) * hw];
            let dq = match fmt {
                KvFormat::Int4 => simd::kv_dot_q4(qh, wrow),
                _ => simd::kv_dot_q8(qh, wrow),
            };
            let s = (ksz[r * 2] * dq + ksz[r * 2 + 1] * qsum) * scale;
            mx = mx.max(s);
            sc[u0 + r] = s;
        }
        u0 += rows;
    }
    let mut zsum = 0f32;
    for s in sc.iter_mut() {
        *s = (*s - mx).exp();
        zsum += *s;
    }
    ch.fill(0.0);
    let mut u0 = 0usize;
    while u0 < n_rows {
        let (vw, vsz, rows) = pool.v_seg_q(lease, layer, u0, n_rows - u0);
        for r in 0..rows {
            let wrow = &vw[r * rw + hh * hw..r * rw + (hh + 1) * hw];
            let wgt = sc[u0 + r] / zsum;
            let (a, b) = (wgt * vsz[r * 2], wgt * vsz[r * 2 + 1]);
            match fmt {
                KvFormat::Int4 => simd::kv_axpy_q4(ch, a, b, wrow),
                _ => simd::kv_axpy_q8(ch, a, b, wrow),
            }
        }
        u0 += rows;
    }
}

/// RMSNorm matching model.py::rms_norm.
pub(crate) fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let mut ss = 0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / x.len() as f32 + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Precompute split-half RoPE sin/cos for every position, matching the
/// per-step powf formula bit-for-bit (same f64 math, cast once).
pub(crate) fn rope_tables(max_ctx: usize, head_dim: usize, theta: f64)
                          -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = vec![0f32; max_ctx * half];
    let mut sin = vec![0f32; max_ctx * half];
    for pos in 0..max_ctx {
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / head_dim as f64);
            let ang = pos as f64 * freq;
            sin[pos * half + i] = ang.sin() as f32;
            cos[pos * half + i] = ang.cos() as f32;
        }
    }
    (cos, sin)
}

/// Split-half RoPE matching model.py::apply_rope, reading the precomputed
/// tables instead of recomputing powf per call.
pub(crate) fn rope_apply(v: &mut [f32], pos: usize, n_heads: usize,
                         head_dim: usize, cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    let c = &cos[pos * half..(pos + 1) * half];
    let s = &sin[pos * half..(pos + 1) * half];
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..half {
            let a = v[base + i];
            let b = v[base + half + i];
            v[base + i] = a * c[i] - b * s[i];
            v[base + half + i] = b * c[i] + a * s[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::engine::Engine;
    use crate::util::threads::with_threads;
    use std::sync::Arc;

    const DIM: usize = 32;
    const NH: usize = 4;
    const HD: usize = 8;
    const INTER: usize = 64;
    const VOCAB: usize = 96;
    const LAYERS: usize = 2;
    const CTX: usize = 24;

    fn core(seed: u64) -> Arc<ModelCore> {
        Arc::new(ModelCore::synthetic(DIM, NH, HD, INTER, VOCAB, LAYERS,
                                      QuantScheme::new(2, 32), CTX, seed)
            .unwrap())
    }

    fn toks(n: usize, stride: usize) -> Vec<i32> {
        (0..n).map(|i| ((i * stride + 5) % VOCAB) as i32).collect()
    }

    /// The tentpole determinism guarantee: per-sequence logits from
    /// `decode_batch` are bit-identical to a solo `Engine` run of the
    /// same prompt, at every batch size and thread count, even with
    /// sequences at *different* positions in the batch - and with the
    /// batch's KV living in deliberately tiny (5-row) pages while the
    /// solo engines use default paging.
    #[test]
    fn decode_batch_is_bitexact_with_solo_engine() {
        let c = core(21);
        // five prompts of different lengths (staggered positions)
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| toks(4 + 2 * i, 7 + i)).collect();
        let feed = [3i32, 11, 29, 41];

        // reference: solo engines, per-step logits after each fed token
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for p in &prompts {
            let mut e = Engine::from_core(c.clone());
            e.prefill(p).unwrap();
            let mut per_step = Vec::new();
            for &t in &feed {
                per_step.push(e.step(t).unwrap());
            }
            want.push(per_step);
        }

        for &bsz in &[1usize, 2, 5] {
            for &nt in &[1usize, 4] {
                with_threads(nt, || {
                    // 5-row pages: every sequence spans several pages
                    let mut pool = KvPool::for_core_paged(
                        &c, bsz * ((CTX + 4) / 5), 5);
                    let mut sc = c.scratch();
                    let mut leases = Vec::new();
                    let mut poss = Vec::new();
                    for p in prompts.iter().take(bsz) {
                        let l = pool.lease().unwrap();
                        // chunked prefill (3-token chunks) must also be
                        // exact vs the solo engine's one-shot prefill
                        let mut pos = 0usize;
                        for ch in p.chunks(3) {
                            c.prefill(&mut pool, &l, pos, ch, &mut sc)
                                .unwrap();
                            pos += ch.len();
                        }
                        leases.push(l);
                        poss.push(pos);
                    }
                    for (si, &t) in feed.iter().enumerate() {
                        let batch: Vec<(&KvLease, usize)> = leases
                            .iter()
                            .zip(&poss)
                            .map(|(l, &p)| (l, p))
                            .collect();
                        let toks: Vec<i32> = vec![t; bsz];
                        c.decode_batch(&mut pool, &batch, &toks, &mut sc)
                            .unwrap();
                        drop(batch);
                        for i in 0..bsz {
                            poss[i] += 1;
                            let got = sc.batch_logits(i);
                            let exp = &want[i][si];
                            assert!(
                                got.iter().zip(exp).all(
                                    |(a, b)| a.to_bits() == b.to_bits()),
                                "batch {bsz} threads {nt} seq {i} \
                                 step {si}: logits diverge from solo"
                            );
                        }
                    }
                });
            }
        }
    }

    /// Satellite sweep: sessions *forked* off one prefilled parent (zero
    /// bytes copied at fork time) decode bit-identically to fresh
    /// sessions re-prefilled from scratch, at batch {1, 2, 5} x threads
    /// {1, 4}, with the prefix spanning multiple 4-row pages - and each
    /// child's first write COWs at most one page.
    #[test]
    fn forked_sessions_decode_bitexact_vs_fresh_prefill() {
        let c = core(29);
        let prefix = toks(13, 7); // 13 rows: 3 full 4-row pages + 1
        let n_steps = 3usize;
        let tok_of =
            |i: usize, s: usize| ((5 + 7 * i + 13 * s) % VOCAB) as i32;

        // reference: per child, a fresh engine re-prefills the prefix
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for i in 0..5usize {
            let mut e = Engine::from_core(c.clone());
            e.prefill(&prefix).unwrap();
            let mut per_step = Vec::new();
            for s in 0..n_steps {
                per_step.push(e.step(tok_of(i, s)).unwrap());
            }
            want.push(per_step);
        }

        let row_off = prefix.len() % 4; // surviving tail rows COW copies
        let cow_per_child = 2 * (LAYERS * row_off * DIM) as u64 * 4;
        for &bsz in &[1usize, 2, 5] {
            for &nt in &[1usize, 4] {
                with_threads(nt, || {
                    // parent needs ceil(13/4) = 4 pages; each child one
                    // fresh page (tail COW; the 3 decode rows fit in it)
                    let mut pool = KvPool::for_core_paged(&c, 4 + bsz, 4);
                    let mut sc = c.scratch();
                    let parent =
                        pool.lease_rows(prefix.len()).unwrap();
                    c.prefill(&mut pool, &parent, 0, &prefix, &mut sc)
                        .unwrap();
                    let b0 = pool.bytes_copied();
                    let children: Vec<KvLease> = (0..bsz)
                        .map(|_| {
                            pool.fork_rows(&parent, prefix.len(), n_steps)
                                .unwrap()
                        })
                        .collect();
                    assert_eq!(pool.bytes_copied(), b0,
                               "fork itself must copy zero bytes");
                    let mut poss = vec![prefix.len(); bsz];
                    for s in 0..n_steps {
                        let batch: Vec<(&KvLease, usize)> = children
                            .iter()
                            .zip(&poss)
                            .map(|(l, &p)| (l, p))
                            .collect();
                        let toks: Vec<i32> =
                            (0..bsz).map(|i| tok_of(i, s)).collect();
                        c.decode_batch(&mut pool, &batch, &toks, &mut sc)
                            .unwrap();
                        drop(batch);
                        for i in 0..bsz {
                            poss[i] += 1;
                            let got = sc.batch_logits(i);
                            let exp = &want[i][s];
                            assert!(
                                got.iter().zip(exp).all(
                                    |(a, b)| a.to_bits() == b.to_bits()),
                                "batch {bsz} threads {nt} child {i} \
                                 step {s}: forked logits diverge from \
                                 fresh re-prefill"
                            );
                        }
                    }
                    // every child COW-copied exactly the partial tail
                    // rows, once - bounded by a single page
                    let copied = pool.bytes_copied() - b0;
                    assert_eq!(copied, bsz as u64 * cow_per_child);
                    assert!(copied <= bsz as u64 * pool.page_bytes(),
                            "COW exceeded one page per fork");
                    for ch in children {
                        pool.release(ch);
                    }
                    pool.release(parent);
                    assert_eq!(pool.pages_in_use(), 0);
                });
            }
        }
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        let c = core(22);
        let prompt = toks(11, 13);
        let mut sc = c.scratch();
        // 3-row pages: chunk boundaries and page boundaries interleave
        let mut pool = KvPool::for_core_paged(&c, 2 * ((CTX + 2) / 3), 3);
        let a = pool.lease().unwrap();
        c.prefill(&mut pool, &a, 0, &prompt, &mut sc).unwrap();
        let one_shot = sc.logits().to_vec();
        let b = pool.lease().unwrap();
        let mut pos = 0usize;
        for ch in prompt.chunks(4) {
            c.prefill(&mut pool, &b, pos, ch, &mut sc).unwrap();
            pos += ch.len();
        }
        assert_eq!(one_shot, sc.logits());
        // and the cached rows themselves are identical
        for bi in 0..c.n_layers() {
            for p in 0..prompt.len() {
                assert_eq!(pool.k_row(&a, bi, p), pool.k_row(&b, bi, p));
                assert_eq!(pool.v_row(&a, bi, p), pool.v_row(&b, bi, p));
            }
        }
    }

    #[test]
    fn forked_session_continues_bitexactly() {
        let c = core(23);
        let prompt = toks(9, 11);
        let cont = toks(5, 17);
        let mut sc = c.scratch();
        let mut pool = KvPool::for_core(&c, 3);
        let l = pool.lease().unwrap();
        c.prefill(&mut pool, &l, 0, &prompt, &mut sc).unwrap();
        let mut fork_out = Vec::new();
        let f = pool.fork(&l, prompt.len()).unwrap();
        c.forward_logits(&mut pool, &f, prompt.len(), &cont, &mut sc,
                         &mut fork_out)
            .unwrap();
        let full = pool.lease().unwrap();
        let all: Vec<i32> =
            prompt.iter().chain(&cont).copied().collect();
        let mut full_out = Vec::new();
        c.forward_logits(&mut pool, &full, 0, &all, &mut sc,
                         &mut full_out)
            .unwrap();
        let tail = &full_out[prompt.len() * VOCAB..];
        assert_eq!(fork_out.len(), cont.len() * VOCAB);
        assert!(fork_out.iter().zip(tail)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn released_pages_reuse_has_no_stale_leakage() {
        let c = core(24);
        let mut sc = c.scratch();
        // cold pool reference
        let mut cold = KvPool::for_core(&c, 1);
        let l = cold.lease().unwrap();
        c.prefill(&mut cold, &l, 0, &toks(6, 7), &mut sc).unwrap();
        let want = sc.logits().to_vec();
        // warm pool: fill the whole context with junk first, release,
        // re-lease (same pages come back), score the fresh prompt
        let mut warm = KvPool::for_core(&c, 1);
        let j = warm.lease().unwrap();
        c.prefill(&mut warm, &j, 0, &toks(CTX - 1, 31), &mut sc)
            .unwrap();
        warm.release(j);
        assert_eq!(warm.pages_in_use(), 0);
        let r = warm.lease().unwrap();
        c.prefill(&mut warm, &r, 0, &toks(6, 7), &mut sc).unwrap();
        assert_eq!(want, sc.logits(), "stale KV leaked into reused pages");
    }

    #[test]
    fn pool_exhaustion_returns_none_and_release_restores() {
        let c = core(25);
        let mut pool = KvPool::for_core(&c, 2);
        assert_eq!(pool.capacity(), 2);
        let per_seq = pool.pages_per_seq();
        let a = pool.lease().unwrap();
        let b = pool.lease().unwrap();
        assert_ne!(a.id(), b.id());
        assert!(pool.lease().is_none(), "exhausted pool must not lease");
        assert_eq!(pool.n_free_pages(), 0);
        pool.release(a);
        assert_eq!(pool.n_free_pages(), per_seq);
        let c2 = pool.lease().unwrap();
        assert!(pool.lease().is_none());
        pool.release(b);
        pool.release(c2);
        assert_eq!(pool.n_free_pages(), 2 * per_seq);
    }

    #[test]
    fn fork_on_exhausted_pool_returns_none() {
        let c = core(26);
        let mut pool = KvPool::for_core(&c, 1);
        let mut sc = c.scratch();
        let l = pool.lease().unwrap();
        c.prefill(&mut pool, &l, 0, &toks(4, 3), &mut sc).unwrap();
        assert!(pool.fork(&l, 4).is_none());
    }

    #[test]
    fn decode_batch_guards_bad_input() {
        let c = core(27);
        let mut pool = KvPool::for_core(&c, 1);
        let mut sc = c.scratch();
        let l = pool.lease().unwrap();
        // lease/token count mismatch
        assert!(c
            .decode_batch(&mut pool, &[(&l, 0)], &[1, 2], &mut sc)
            .is_err());
        // out-of-range token
        assert!(c
            .decode_batch(&mut pool, &[(&l, 0)], &[VOCAB as i32], &mut sc)
            .is_err());
        // full cache
        assert!(c
            .decode_batch(&mut pool, &[(&l, CTX)], &[1], &mut sc)
            .is_err());
        // empty batch is a no-op
        assert!(c.decode_batch(&mut pool, &[], &[], &mut sc).is_ok());
    }

    #[test]
    fn dense_core_matches_itself_across_paths() {
        // a dense-linear core (the eval path for fp/LoRA-merged models)
        // must satisfy the same solo-vs-batched bit-exactness contract
        let p = core(28);
        // materialize the packed core into a dense one
        let mut blocks = Vec::new();
        for blk in &p.blocks {
            let mut lins = Vec::new();
            for lin in &blk.lins {
                let pl = match lin {
                    Linear::Packed(pl) => pl,
                    _ => unreachable!(),
                };
                let (o, i) = (pl.out_dim, pl.in_dim);
                let mut w = vec![0f32; o * i];
                let mut row = vec![0f32; i];
                for r in 0..o {
                    pl.dequant_row(r, &mut row);
                    w[r * i..(r + 1) * i].copy_from_slice(&row);
                }
                lins.push(Linear::Dense { w, out_dim: o, in_dim: i });
            }
            blocks.push(BlockW {
                attn_norm: blk.attn_norm.clone(),
                mlp_norm: blk.mlp_norm.clone(),
                lins,
            });
        }
        let dc = Arc::new(ModelCore::assemble(
            DIM, NH, HD, INTER, VOCAB, CTX, 10000.0, 1e-5,
            p.embed.clone(), p.final_norm.clone(), p.head.clone(),
            blocks));
        let prompt = toks(6, 9);
        let mut pool = KvPool::for_core(&dc, 2);
        let mut sc = dc.scratch();
        let a = pool.lease().unwrap();
        dc.prefill(&mut pool, &a, 0, &prompt, &mut sc).unwrap();
        let pre = sc.logits().to_vec();
        // solo step loop on a second lease
        let b = pool.lease().unwrap();
        for (i, &t) in prompt.iter().enumerate() {
            dc.step(&mut pool, &b, i, t, &mut sc).unwrap();
        }
        assert_eq!(pre, sc.logits());
        // batched decode vs solo step from the prefilled states
        let batch = [(&a, prompt.len()), (&b, prompt.len())];
        dc.decode_batch(&mut pool, &batch, &[7, 7], &mut sc).unwrap();
        let row0 = sc.batch_logits(0).to_vec();
        let row1 = sc.batch_logits(1).to_vec();
        assert_eq!(row0, row1);
        dc.step(&mut pool, &a, prompt.len(), 7, &mut sc).unwrap();
        assert_eq!(row0, sc.logits());
    }

    use crate::util::simd::{with_isa, Isa};

    /// Low-bit reference: solo one-shot prefill + step loop per prompt,
    /// scalar ISA, one thread, 7-row pages.
    fn lowbit_want(c: &Arc<ModelCore>, fmt: KvFormat,
                   prompts: &[Vec<i32>], feed: &[i32])
                   -> Vec<Vec<Vec<f32>>> {
        with_isa(Isa::Scalar, || {
            with_threads(1, || {
                prompts
                    .iter()
                    .map(|p| {
                        let mut pool = KvPool::for_core_paged_fmt(
                            c, (CTX + 6) / 7 + 1, 7, fmt);
                        let mut sc = c.scratch();
                        let l = pool.lease().unwrap();
                        c.prefill(&mut pool, &l, 0, p, &mut sc).unwrap();
                        let mut pos = p.len();
                        let mut per = Vec::new();
                        for &t in feed {
                            c.step(&mut pool, &l, pos, t, &mut sc)
                                .unwrap();
                            pos += 1;
                            per.push(sc.logits().to_vec());
                        }
                        per
                    })
                    .collect()
            })
        })
    }

    /// The low-bit determinism contract: packed-KV logits are
    /// bit-identical across batch size {1,2,5}, chunked-vs-one-shot
    /// prefill, threads {1,4}, page sizes {3,8}, and
    /// `EQAT_SIMD=scalar|auto` - pinned against a solo scalar reference
    /// at a third page size. (Not compared to f32: low-bit is its own
    /// numerics tier.)
    #[test]
    fn lowbit_decode_bitexact_across_batch_threads_pages_isa() {
        let c = core(31);
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| toks(4 + 2 * i, 7 + i)).collect();
        let feed = [3i32, 11, 29];
        for fmt in [KvFormat::Int4, KvFormat::Int8] {
            let want = lowbit_want(&c, fmt, &prompts, &feed);
            for &bsz in &[1usize, 2, 5] {
                for &nt in &[1usize, 4] {
                    for &pr in &[3usize, 8] {
                        for &isa in &[Isa::Scalar, crate::util::simd::detected()] {
                            with_isa(isa, || with_threads(nt, || {
                                check_lowbit_batch(&c, fmt, &prompts,
                                                   &feed, &want, bsz, pr);
                            }));
                        }
                    }
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn check_lowbit_batch(c: &Arc<ModelCore>, fmt: KvFormat,
                          prompts: &[Vec<i32>], feed: &[i32],
                          want: &[Vec<Vec<f32>>], bsz: usize, pr: usize) {
        let mut pool = KvPool::for_core_paged_fmt(
            c, bsz * ((CTX + pr - 1) / pr), pr, fmt);
        let mut sc = c.scratch();
        let mut leases = Vec::new();
        let mut poss = Vec::new();
        for p in prompts.iter().take(bsz) {
            let l = pool.lease().unwrap();
            let mut pos = 0usize;
            for ch in p.chunks(3) {
                c.prefill(&mut pool, &l, pos, ch, &mut sc).unwrap();
                pos += ch.len();
            }
            leases.push(l);
            poss.push(pos);
        }
        for (si, &t) in feed.iter().enumerate() {
            let batch: Vec<(&KvLease, usize)> =
                leases.iter().zip(&poss).map(|(l, &p)| (l, p)).collect();
            let toks: Vec<i32> = vec![t; bsz];
            c.decode_batch(&mut pool, &batch, &toks, &mut sc).unwrap();
            drop(batch);
            for i in 0..bsz {
                poss[i] += 1;
                let got = sc.batch_logits(i);
                let exp = &want[i][si];
                assert!(
                    got.iter().zip(exp)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{fmt:?} batch {bsz} pages {pr} seq {i} step {si}: \
                     low-bit logits diverge from scalar solo reference"
                );
            }
        }
    }

    /// Packed pages through fork/COW and the prefix cache: a forked
    /// child and a cache-hit admission both continue bit-identically to
    /// the parent's own decode, the child's first write COWs at most one
    /// (packed) page, and the cache hit copies zero bytes.
    #[test]
    fn lowbit_fork_cow_and_cache_hit_decode_bitexact() {
        let c = core(33);
        let prompt = toks(13, 7); // 3 full 4-row pages + 1 tail row
        let feed = [3i32, 11, 29];
        let mut pool =
            KvPool::for_core_paged_fmt(&c, 16, 4, KvFormat::Int4);
        pool.enable_prefix_cache();
        let mut sc = c.scratch();
        let parent = pool.lease().unwrap();
        c.prefill(&mut pool, &parent, 0, &prompt, &mut sc).unwrap();
        assert_eq!(pool.cache_insert(&prompt, &parent).unwrap(), 3);
        let child = pool.fork_rows(&parent, prompt.len(), feed.len())
            .unwrap();
        let b0 = pool.bytes_copied();
        // reference: the parent decodes the feed itself
        let mut want = Vec::new();
        let mut pos = prompt.len();
        for &t in &feed {
            c.step(&mut pool, &parent, pos, t, &mut sc).unwrap();
            pos += 1;
            want.push(sc.logits().to_vec());
        }
        // the fork sees the parent's quantized rows verbatim
        let mut pos = prompt.len();
        for (s, &t) in feed.iter().enumerate() {
            c.step(&mut pool, &child, pos, t, &mut sc).unwrap();
            pos += 1;
            assert!(sc.logits().iter().zip(&want[s])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "forked packed decode diverged at step {s}");
        }
        // both writers COWed at most one packed page each (1 tail row)
        let copied = pool.bytes_copied() - b0;
        assert!(copied <= 2 * pool.page_bytes(),
                "packed COW exceeded one page per writer");
        pool.release(child);
        pool.release(parent);
        // cache hit: re-admit the same prompt, resume past the match
        let (hit, matched) =
            pool.lease_rows_cached(&prompt, CTX).unwrap();
        assert_eq!(matched, 12);
        let bc = pool.bytes_copied();
        c.prefill(&mut pool, &hit, matched, &prompt[matched..], &mut sc)
            .unwrap();
        let mut pos = prompt.len();
        for (s, &t) in feed.iter().enumerate() {
            c.step(&mut pool, &hit, pos, t, &mut sc).unwrap();
            pos += 1;
            assert!(sc.logits().iter().zip(&want[s])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "cache-hit packed decode diverged at step {s}");
        }
        assert_eq!(pool.bytes_copied(), bc,
                   "cache-hit resume must copy zero bytes");
        pool.release(hit);
        pool.cache_flush();
        assert_eq!(pool.pages_in_use(), 0);
    }

    /// Teacher-forced mean NLL (nats/token) over a fixed synthetic
    /// sequence, reading KV through `pool`.
    fn tf_nll(c: &Arc<ModelCore>, pool: &mut KvPool) -> f64 {
        let seq = toks(20, 3);
        let mut sc = c.scratch();
        let l = pool.lease().unwrap();
        let mut out = Vec::new();
        c.forward_logits(pool, &l, 0, &seq, &mut sc, &mut out).unwrap();
        let mut nll = 0f64;
        for t in 0..seq.len() - 1 {
            let row = &out[t * VOCAB..(t + 1) * VOCAB];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f64 =
                row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
            let tgt = seq[t + 1] as usize;
            nll += z.ln() - (row[tgt] - mx) as f64;
        }
        pool.release(l);
        nll / (seq.len() - 1) as f64
    }

    /// The accuracy half of the low-bit contract: int8/int4 KV shifts
    /// teacher-forced ppl by a bounded relative delta vs the f32 pool
    /// (the bench's `kv_lowbit` section records the same deltas under
    /// the same gates).
    #[test]
    fn lowbit_ppl_delta_vs_fp_is_bounded() {
        let c = core(35);
        let mut fp = KvPool::for_core(&c, 1);
        let ppl_fp = tf_nll(&c, &mut fp).exp();
        assert!(ppl_fp.is_finite());
        for (fmt, gate) in
            [(KvFormat::Int8, 0.05), (KvFormat::Int4, 0.25)]
        {
            let mut qp = KvPool::for_core_fmt(&c, 1, fmt);
            let ppl_q = tf_nll(&c, &mut qp).exp();
            assert!(ppl_q.is_finite());
            let rel = (ppl_q - ppl_fp).abs() / ppl_fp;
            assert!(rel < gate,
                    "{fmt:?} KV ppl {ppl_q:.4} vs fp {ppl_fp:.4}: \
                     relative delta {rel:.4} over the {gate} gate");
        }
    }
}
