//! Pure-Rust deployment engine: autoregressive transformer forward over
//! packed low-bit weights with a KV cache. This is the "request path" a
//! downstream user ships - no Python, no XLA, just the packed .eqt model.
//!
//! Numerics mirror python/compile/model.py exactly (RMSNorm, split-half
//! RoPE, causal attention, SwiGLU). When PJRT artifacts and real xla
//! bindings are present, the integration test checks engine logits
//! against the `model_fwd_q` executable to ~1e-3; in stub builds
//! (rust/src/xla_stub.rs) that external parity check skips, and the
//! guarantees are the internal ones: kernels vs dense-dequant, batched
//! prefill vs sequential step, and thread-count determinism (all tested).
//!
//! # Hot-path design (batching + threading)
//!
//! - **Batched prefill**: [`Engine::prefill`] runs all prompt positions
//!   through each block's linears as one [`PackedLinear::matmul`] and
//!   fills the KV cache in a single pass with causal attention over the
//!   batch. The K/V matmuls write straight into the cache rows. Because
//!   `matmul` replicates `matvec`'s accumulation order, batched prefill is
//!   bit-exact with the old sequential `step()` loop - just much faster
//!   (the per-group unpack work amortizes across tokens, and the lm head
//!   runs once instead of once per prompt token).
//! - **Precomputed RoPE**: sin/cos tables for all `max_ctx` positions are
//!   built once at construction; decode no longer calls `powf` per
//!   position per head.
//! - **Zero-alloc decode**: a persistent [`Scratch`] holds every
//!   intermediate buffer (including per-head attention scores and the
//!   matvec group-sum scratch), so steady-state `step_ref` does no heap
//!   allocation.
//! - **Parallel attention**: per-head score/context work is chunked onto
//!   the persistent worker pool (`util::threads`) once the context is
//!   long enough to pay for a dispatch; prefill attention chunks across
//!   tokens.
//!
//! §Perf: batched prefill replaces, per prompt token, a full per-call
//! group-unpack pass over every linear plus an lm-head matvec with an
//! amortized share of one matmul pass - at 64 tokens on a 7B-shaped block
//! that is a large constant-factor win (target floor: >=3x vs the old
//! sequential step loop), and multi-threaded decode scales with the
//! row-chunked lm-head/linear matvecs. A decode step issues ~10 parallel
//! sections (7 linears + lm head + attention); under the old
//! spawn-per-call threading that was ~10 spawn/join cycles *per token*,
//! now it is ~10 pool dispatches (~1-2us each). Measure with
//! `eqat bench inference`; `runs/bench.json` tracks the trajectory
//! across PRs.
//!
//! [`Engine::forward_logits`] exposes the same batched pass for
//! evaluation (all-position logits), which `eval::fwd::engine_logits` and
//! `eval::ppl::perplexity_engine` build on - CPU perplexity eval with no
//! PJRT artifacts needed.

use anyhow::{anyhow, bail, Result};

use crate::config::QuantScheme;
use crate::infer::qlinear::{dense_matmul, dense_matvec, PackedLinear};
use crate::io::manifest::PresetInfo;
use crate::model::quantized::QuantizedModel;
use crate::quant::rtn::{minmax_init, quantize};
use crate::util::rng::Rng;
use crate::util::threads;

const LINS: [&str; 7] = ["attn.q", "attn.k", "attn.v", "attn.o",
                         "mlp.gate", "mlp.up", "mlp.down"];

/// Below this many attention MACs (heads * positions * head_dim), the
/// per-head loop stays serial: even a pool dispatch (~1-2us) would cost
/// more than the work. Far lower than the spawn-per-call era threshold.
const ATT_PAR_MIN: usize = 1 << 13;

struct BlockW {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    /// q, k, v, o, gate, up, down
    lins: Vec<PackedLinear>,
}

/// Persistent intermediate buffers. Decode (`step_ref`) touches only the
/// fixed-size fields and allocates nothing in steady state; the `p_*`
/// prefill buffers grow to the longest prompt seen and are then re-used.
struct Scratch {
    hn: Vec<f32>,       // dim
    q: Vec<f32>,        // dim
    ctx: Vec<f32>,      // dim
    attn_out: Vec<f32>, // dim
    gate: Vec<f32>,     // inter
    up: Vec<f32>,       // inter
    down: Vec<f32>,     // dim
    h: Vec<f32>,        // dim
    logits: Vec<f32>,   // vocab
    /// per-head attention scores: n_heads rows of max_ctx
    att: Vec<f32>,
    /// shared group-sum scratch for `PackedLinear::matvec_in`
    sx: Vec<f32>,
    // batched-prefill buffers, token-major (n * width)
    p_h: Vec<f32>,
    p_hn: Vec<f32>,
    p_q: Vec<f32>,
    p_ctx: Vec<f32>,
    p_attn: Vec<f32>,
    p_gate: Vec<f32>,
    p_up: Vec<f32>,
    p_down: Vec<f32>,
}

impl Scratch {
    fn new(dim: usize, inter: usize, vocab: usize, n_heads: usize,
           max_ctx: usize) -> Scratch {
        Scratch {
            hn: vec![0.0; dim],
            q: vec![0.0; dim],
            ctx: vec![0.0; dim],
            attn_out: vec![0.0; dim],
            gate: vec![0.0; inter],
            up: vec![0.0; inter],
            down: vec![0.0; dim],
            h: vec![0.0; dim],
            logits: vec![0.0; vocab],
            att: vec![0.0; n_heads * max_ctx],
            sx: Vec::new(),
            p_h: Vec::new(),
            p_hn: Vec::new(),
            p_q: Vec::new(),
            p_ctx: Vec::new(),
            p_attn: Vec::new(),
            p_gate: Vec::new(),
            p_up: Vec::new(),
            p_down: Vec::new(),
        }
    }
}

pub struct Engine {
    pub dim: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub inter: usize,
    pub vocab: usize,
    pub max_ctx: usize,
    #[allow(dead_code)]
    rope_theta: f64,
    norm_eps: f32,
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    head: Vec<f32>,
    blocks: Vec<BlockW>,
    /// per block: (k_cache, v_cache), each (max_ctx * dim)
    cache: Vec<(Vec<f32>, Vec<f32>)>,
    /// precomputed RoPE tables, (max_ctx * head_dim/2) each
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    scratch: Scratch,
    pub pos: usize,
}

impl Engine {
    /// Build from the in-memory quantized model + manifest preset info.
    pub fn new(qm: &QuantizedModel, info: &PresetInfo, max_ctx: usize)
               -> Result<Engine> {
        let cfg = &info.config;
        let g = qm.scheme.group;
        let wql = info.layouts.get("wq")
            .ok_or_else(|| anyhow!("missing wq layout"))?;
        let qpl = info.layouts.get(&format!("qp_g{g}"))
            .ok_or_else(|| anyhow!("missing qp_g{g} layout"))?;
        let fprl = info.layouts.get("fpr")
            .ok_or_else(|| anyhow!("missing fpr layout"))?;

        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let mut lins = Vec::with_capacity(7);
            for name in LINS {
                let we = wql.entry(&format!("blocks.{b}.{name}"))?;
                let (out_d, in_d) = (we.shape[0], we.shape[1]);
                let w_int = wql.slice(&qm.wq, &format!("blocks.{b}.{name}"))?;
                let s = qpl.slice(&qm.qp, &format!("s.blocks.{b}.{name}"))?;
                let z = qpl.slice(&qm.qp, &format!("z.blocks.{b}.{name}"))?;
                lins.push(PackedLinear::pack(w_int, out_d, in_d, s, z,
                                             qm.scheme)?);
            }
            blocks.push(BlockW {
                attn_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.attn_norm"))?
                    .to_vec(),
                mlp_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.mlp_norm"))?
                    .to_vec(),
                lins,
            });
        }
        Ok(Engine::assemble(
            cfg.dim,
            cfg.n_heads,
            cfg.head_dim,
            cfg.inter,
            cfg.vocab,
            max_ctx,
            cfg.rope_theta,
            cfg.norm_eps as f32,
            fprl.slice(&qm.fpr, "embed")?.to_vec(),
            fprl.slice(&qm.fpr, "final_norm")?.to_vec(),
            fprl.slice(&qm.fpr, "head")?.to_vec(),
            blocks,
        ))
    }

    /// Build a randomly-initialized engine directly from shapes, no
    /// manifest or artifacts needed: weights are RTN-quantized to `scheme`
    /// and packed exactly like the artifact path. This is the harness
    /// behind the inference benches and the batching/threading tests.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        dim: usize,
        n_heads: usize,
        head_dim: usize,
        inter: usize,
        vocab: usize,
        n_layers: usize,
        scheme: QuantScheme,
        max_ctx: usize,
        seed: u64,
    ) -> Result<Engine> {
        if n_heads * head_dim != dim {
            bail!("n_heads {n_heads} * head_dim {head_dim} != dim {dim}");
        }
        if dim % scheme.group != 0 || inter % scheme.group != 0 {
            bail!("group {} must divide dim {dim} and inter {inter}",
                  scheme.group);
        }
        let mut rng = Rng::new(seed);
        let shapes = [
            (dim, dim),   // attn.q
            (dim, dim),   // attn.k
            (dim, dim),   // attn.v
            (dim, dim),   // attn.o
            (inter, dim), // mlp.gate
            (inter, dim), // mlp.up
            (dim, inter), // mlp.down
        ];
        let mut blocks = Vec::with_capacity(n_layers);
        let mut wbuf: Vec<f32> = Vec::new();
        for _ in 0..n_layers {
            let mut lins = Vec::with_capacity(7);
            for &(o, i) in &shapes {
                wbuf.clear();
                wbuf.resize(o * i, 0.0);
                rng.fill_normal(&mut wbuf, 0.0, 0.05);
                let gp = minmax_init(&wbuf, o, i, scheme);
                let wi = quantize(&wbuf, &gp, scheme);
                lins.push(PackedLinear::pack(&wi, o, i, &gp.s, &gp.z,
                                             scheme)?);
            }
            blocks.push(BlockW {
                attn_norm: vec![1.0; dim],
                mlp_norm: vec![1.0; dim],
                lins,
            });
        }
        let mut embed = vec![0f32; vocab * dim];
        rng.fill_normal(&mut embed, 0.0, 0.02);
        let mut head = vec![0f32; vocab * dim];
        rng.fill_normal(&mut head, 0.0, 0.02);
        Ok(Engine::assemble(dim, n_heads, head_dim, inter, vocab, max_ctx,
                            10000.0, 1e-5, embed, vec![1.0; dim], head,
                            blocks))
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        dim: usize,
        n_heads: usize,
        head_dim: usize,
        inter: usize,
        vocab: usize,
        max_ctx: usize,
        rope_theta: f64,
        norm_eps: f32,
        embed: Vec<f32>,
        final_norm: Vec<f32>,
        head: Vec<f32>,
        blocks: Vec<BlockW>,
    ) -> Engine {
        let cache = (0..blocks.len())
            .map(|_| (vec![0f32; max_ctx * dim], vec![0f32; max_ctx * dim]))
            .collect();
        let (rope_cos, rope_sin) = rope_tables(max_ctx, head_dim, rope_theta);
        let scratch = Scratch::new(dim, inter, vocab, n_heads, max_ctx);
        Engine {
            dim,
            n_heads,
            head_dim,
            inter,
            vocab,
            max_ctx,
            rope_theta,
            norm_eps,
            embed,
            final_norm,
            head,
            blocks,
            cache,
            rope_cos,
            rope_sin,
            scratch,
            pos: 0,
        }
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// One decode step: feed `tok` at the current position, return logits.
    pub fn step(&mut self, tok: i32) -> Result<Vec<f32>> {
        self.step_impl(tok, None)?;
        Ok(self.scratch.logits.clone())
    }

    /// Like [`Engine::step`] but returns a view into the engine's scratch
    /// instead of copying: steady-state decode through this entry point
    /// performs zero heap allocation.
    pub fn step_ref(&mut self, tok: i32) -> Result<&[f32]> {
        self.step_impl(tok, None)?;
        Ok(&self.scratch.logits)
    }

    /// Debug/testing: like `step` but also returns the hidden state after
    /// each block (used to localize divergence vs the XLA forward).
    pub fn step_traced(&mut self, tok: i32)
                       -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let mut trace = Vec::with_capacity(self.blocks.len());
        self.step_impl(tok, Some(&mut trace))?;
        Ok((self.scratch.logits.clone(), trace))
    }

    fn step_impl(&mut self, tok: i32,
                 mut trace: Option<&mut Vec<Vec<f32>>>) -> Result<()> {
        if self.pos >= self.max_ctx {
            bail!("KV cache full ({} positions)", self.max_ctx);
        }
        if tok < 0 || tok as usize >= self.vocab {
            bail!("token {tok} out of range (vocab {})", self.vocab);
        }
        let Engine {
            dim,
            n_heads,
            head_dim,
            inter,
            max_ctx,
            norm_eps,
            embed,
            final_norm,
            head,
            blocks,
            cache,
            rope_cos,
            rope_sin,
            scratch,
            pos,
            ..
        } = self;
        let d = *dim;
        let nh = *n_heads;
        let hd = *head_dim;
        let it = *inter;
        let eps = *norm_eps;
        let mc = *max_ctx;
        let p = *pos;
        let Scratch {
            hn, q, ctx, attn_out, gate, up, down, h, logits, att, sx, ..
        } = scratch;

        h.copy_from_slice(
            &embed[tok as usize * d..(tok as usize + 1) * d]);
        let scale = 1.0 / (hd as f32).sqrt();
        for (bi, blk) in blocks.iter().enumerate() {
            rms_norm(&h[..], &blk.attn_norm, eps, &mut hn[..]);
            {
                let (kc, vc) = &mut cache[bi];
                blk.lins[0].matvec_in(&hn[..], &mut q[..], sx);
                blk.lins[1].matvec_in(&hn[..], &mut kc[p * d..(p + 1) * d],
                                      sx);
                blk.lins[2].matvec_in(&hn[..], &mut vc[p * d..(p + 1) * d],
                                      sx);
                rope_apply(&mut kc[p * d..(p + 1) * d], p, nh, hd, rope_cos,
                           rope_sin);
            }
            rope_apply(&mut q[..], p, nh, hd, rope_cos, rope_sin);
            let (kc, vc) = &cache[bi];
            let qv: &[f32] = &q[..];
            let kcs: &[f32] = &kc[..];
            let vcs: &[f32] = &vc[..];
            // chunk i covers the same heads of both the context output and
            // the per-head score scratch; serial for short contexts
            let hpc = if nh * (p + 1) * hd < ATT_PAR_MIN {
                nh
            } else {
                threads::chunk_len(nh)
            };
            threads::par_chunks2_mut(
                &mut ctx[..],
                hpc * hd,
                &mut att[..],
                hpc * mc,
                |ci, cxc, atc| {
                    for (j, (ch, ath)) in cxc
                        .chunks_mut(hd)
                        .zip(atc.chunks_mut(mc))
                        .enumerate()
                    {
                        let hh = ci * hpc + j;
                        attend_head(&qv[hh * hd..(hh + 1) * hd], kcs, vcs,
                                    d, hh, hd, p, scale, ath, ch);
                    }
                },
            );
            blk.lins[3].matvec_in(&ctx[..], &mut attn_out[..], sx);
            for i in 0..d {
                h[i] += attn_out[i];
            }
            rms_norm(&h[..], &blk.mlp_norm, eps, &mut hn[..]);
            blk.lins[4].matvec_in(&hn[..], &mut gate[..], sx);
            blk.lins[5].matvec_in(&hn[..], &mut up[..], sx);
            for i in 0..it {
                let gx = gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                gate[i] = silu * up[i];
            }
            blk.lins[6].matvec_in(&gate[..], &mut down[..], sx);
            for i in 0..d {
                h[i] += down[i];
            }
            if let Some(tr) = trace.as_mut() {
                tr.push(h.to_vec());
            }
        }
        *pos += 1;
        rms_norm(&h[..], &final_norm[..], eps, &mut hn[..]);
        dense_matvec(&head[..], logits.len(), d, &hn[..], &mut logits[..]);
        Ok(())
    }

    /// Debug/testing: the K-cache row for (block, pos) - post-RoPE keys.
    pub fn k_row(&self, block: usize, pos: usize) -> &[f32] {
        let d = self.dim;
        &self.cache[block].0[pos * d..(pos + 1) * d]
    }

    /// Feed a prompt; returns logits after the last token.
    ///
    /// Batched: all positions run through each block's linears as one
    /// packed matmul, the K/V matmuls write directly into the cache, and
    /// the lm head runs once (on the last position) instead of once per
    /// prompt token. Bit-exact with a sequential `step()` loop (tested),
    /// §Perf >=3x faster at 64 tokens on 7B-shaped blocks.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        self.prefill_impl(tokens)?;
        let n = tokens.len();
        let d = self.dim;
        let v = self.vocab;
        let eps = self.norm_eps;
        let Engine { final_norm, head, scratch, .. } = self;
        let Scratch { p_h, hn, logits, .. } = scratch;
        rms_norm(&p_h[(n - 1) * d..n * d], &final_norm[..], eps,
                 &mut hn[..]);
        dense_matvec(&head[..], v, d, &hn[..], &mut logits[..]);
        Ok(logits.clone())
    }

    /// Evaluation forward: logits for *every* position of `tokens`
    /// (token-major, n * vocab), via the batched prefill pass plus a dense
    /// lm-head matmul. Continues from the current `pos`; call
    /// [`Engine::reset`] first for a fresh sequence.
    pub fn forward_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let n = tokens.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        self.prefill_impl(tokens)?;
        let d = self.dim;
        let v = self.vocab;
        let eps = self.norm_eps;
        let Engine { final_norm, head, scratch, .. } = self;
        let Scratch { p_h, p_hn, .. } = scratch;
        for t in 0..n {
            rms_norm(&p_h[t * d..(t + 1) * d], &final_norm[..], eps,
                     &mut p_hn[t * d..(t + 1) * d]);
        }
        let mut out = vec![0f32; n * v];
        dense_matmul(&head[..], v, d, &p_hn[..n * d], n, &mut out);
        Ok(out)
    }

    /// Batched core: run `n` positions through every block, filling the KV
    /// cache rows [pos, pos+n) in one pass; final per-token hidden states
    /// land in `scratch.p_h` and `pos` advances by n.
    fn prefill_impl(&mut self, tokens: &[i32]) -> Result<()> {
        let n = tokens.len();
        if self.pos + n > self.max_ctx {
            bail!(
                "prompt of {n} tokens overflows KV cache ({} used of {})",
                self.pos, self.max_ctx
            );
        }
        for &t in tokens {
            if t < 0 || t as usize >= self.vocab {
                bail!("token {t} out of range (vocab {})", self.vocab);
            }
        }
        let Engine {
            dim,
            n_heads,
            head_dim,
            inter,
            norm_eps,
            embed,
            blocks,
            cache,
            rope_cos,
            rope_sin,
            scratch,
            pos,
            ..
        } = self;
        let d = *dim;
        let nh = *n_heads;
        let hd = *head_dim;
        let it = *inter;
        let eps = *norm_eps;
        let p0 = *pos;
        let Scratch {
            p_h, p_hn, p_q, p_ctx, p_attn, p_gate, p_up, p_down, ..
        } = scratch;
        p_h.resize(n * d, 0.0);
        p_hn.resize(n * d, 0.0);
        p_q.resize(n * d, 0.0);
        p_ctx.resize(n * d, 0.0);
        p_attn.resize(n * d, 0.0);
        p_gate.resize(n * it, 0.0);
        p_up.resize(n * it, 0.0);
        p_down.resize(n * d, 0.0);

        for (t, &tok) in tokens.iter().enumerate() {
            p_h[t * d..(t + 1) * d].copy_from_slice(
                &embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for (bi, blk) in blocks.iter().enumerate() {
            for t in 0..n {
                rms_norm(&p_h[t * d..(t + 1) * d], &blk.attn_norm, eps,
                         &mut p_hn[t * d..(t + 1) * d]);
            }
            blk.lins[0].matmul(&p_hn[..n * d], n, &mut p_q[..n * d]);
            {
                let (kc, vc) = &mut cache[bi];
                blk.lins[1].matmul(&p_hn[..n * d], n,
                                   &mut kc[p0 * d..(p0 + n) * d]);
                blk.lins[2].matmul(&p_hn[..n * d], n,
                                   &mut vc[p0 * d..(p0 + n) * d]);
                for t in 0..n {
                    rope_apply(&mut kc[(p0 + t) * d..(p0 + t + 1) * d],
                               p0 + t, nh, hd, rope_cos, rope_sin);
                }
            }
            for t in 0..n {
                rope_apply(&mut p_q[t * d..(t + 1) * d], p0 + t, nh, hd,
                           rope_cos, rope_sin);
            }
            let (kc, vc) = &cache[bi];
            let qv: &[f32] = &p_q[..];
            let kcs: &[f32] = &kc[..];
            let vcs: &[f32] = &vc[..];
            // causal attention over the batch, token-chunked across
            // threads; workers allocate their own score buffers (prefill
            // is not the zero-alloc path)
            let tpc = if n * nh * (p0 + n) * hd < ATT_PAR_MIN {
                n
            } else {
                threads::chunk_len(n)
            };
            threads::par_chunks_mut(&mut p_ctx[..n * d], tpc * d,
                                    |ci, cxc| {
                let t0 = ci * tpc;
                let mut scores = vec![0f32; p0 + n];
                for (tl, ctx_t) in cxc.chunks_mut(d).enumerate() {
                    let t = t0 + tl;
                    let last = p0 + t; // attends to cache rows 0..=last
                    for hh in 0..nh {
                        attend_head(
                            &qv[t * d + hh * hd..t * d + (hh + 1) * hd],
                            kcs, vcs, d, hh, hd, last, scale,
                            &mut scores,
                            &mut ctx_t[hh * hd..(hh + 1) * hd],
                        );
                    }
                }
            });
            blk.lins[3].matmul(&p_ctx[..n * d], n, &mut p_attn[..n * d]);
            for i in 0..n * d {
                p_h[i] += p_attn[i];
            }
            for t in 0..n {
                rms_norm(&p_h[t * d..(t + 1) * d], &blk.mlp_norm, eps,
                         &mut p_hn[t * d..(t + 1) * d]);
            }
            blk.lins[4].matmul(&p_hn[..n * d], n, &mut p_gate[..n * it]);
            blk.lins[5].matmul(&p_hn[..n * d], n, &mut p_up[..n * it]);
            for i in 0..n * it {
                let gx = p_gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                p_gate[i] = silu * p_up[i];
            }
            blk.lins[6].matmul(&p_gate[..n * it], n, &mut p_down[..n * d]);
            for i in 0..n * d {
                p_h[i] += p_down[i];
            }
        }
        *pos += n;
        Ok(())
    }
}

/// Softmax attention for one head over KV-cache rows 0..=`last`: scores
/// go through `scores` scratch (len >= last+1), the weighted value sum
/// lands in `ch` (len head_dim). Shared by the decode and batched-prefill
/// paths so their numerics can never diverge (the prefill==step-loop
/// bit-exactness tests depend on this).
#[allow(clippy::too_many_arguments)]
fn attend_head(qh: &[f32], kcs: &[f32], vcs: &[f32], d: usize, hh: usize,
               hd: usize, last: usize, scale: f32, scores: &mut [f32],
               ch: &mut [f32]) {
    let sc = &mut scores[..last + 1];
    let mut mx = f32::NEG_INFINITY;
    for (u, sv) in sc.iter_mut().enumerate() {
        let kh = &kcs[u * d + hh * hd..u * d + (hh + 1) * hd];
        let mut s = 0f32;
        for i in 0..hd {
            s += qh[i] * kh[i];
        }
        let s = s * scale;
        mx = mx.max(s);
        *sv = s;
    }
    let mut zsum = 0f32;
    for s in sc.iter_mut() {
        *s = (*s - mx).exp();
        zsum += *s;
    }
    ch.fill(0.0);
    for (u, &pr) in sc.iter().enumerate() {
        let vh = &vcs[u * d + hh * hd..u * d + (hh + 1) * hd];
        let w = pr / zsum;
        for i in 0..hd {
            ch[i] += w * vh[i];
        }
    }
}

/// RMSNorm matching model.py::rms_norm.
fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let mut ss = 0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / x.len() as f32 + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Precompute split-half RoPE sin/cos for every position, matching the
/// per-step powf formula bit-for-bit (same f64 math, cast once).
fn rope_tables(max_ctx: usize, head_dim: usize, theta: f64)
               -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = vec![0f32; max_ctx * half];
    let mut sin = vec![0f32; max_ctx * half];
    for pos in 0..max_ctx {
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / head_dim as f64);
            let ang = pos as f64 * freq;
            sin[pos * half + i] = ang.sin() as f32;
            cos[pos * half + i] = ang.cos() as f32;
        }
    }
    (cos, sin)
}

/// Split-half RoPE matching model.py::apply_rope, reading the precomputed
/// tables instead of recomputing powf per call.
fn rope_apply(v: &mut [f32], pos: usize, n_heads: usize, head_dim: usize,
              cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    let c = &cos[pos * half..(pos + 1) * half];
    let s = &sin[pos * half..(pos + 1) * half];
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..half {
            let a = v[base + i];
            let b = v[base + half + i];
            v[base + i] = a * c[i] - b * s[i];
            v[base + half + i] = b * c[i] + a * s[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threads::with_threads;

    const DIM: usize = 32;
    const NH: usize = 4;
    const HD: usize = 8;
    const INTER: usize = 64;
    const VOCAB: usize = 96;
    const LAYERS: usize = 2;
    const CTX: usize = 24;

    fn small(seed: u64) -> Engine {
        Engine::synthetic(DIM, NH, HD, INTER, VOCAB, LAYERS,
                          QuantScheme::new(2, 32), CTX, seed)
            .unwrap()
    }

    fn toks(n: usize) -> Vec<i32> {
        (0..n).map(|i| ((i * 13 + 5) % VOCAB) as i32).collect()
    }

    #[test]
    fn batched_prefill_matches_sequential_steps() {
        let prompt = toks(10);
        let mut a = small(11);
        let mut b = small(11);
        let la = a.prefill(&prompt).unwrap();
        let mut lb = Vec::new();
        for &t in &prompt {
            lb = b.step(t).unwrap();
        }
        assert_eq!(a.pos, b.pos);
        assert_eq!(la.len(), lb.len());
        for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
            assert!((x - y).abs() <= 1e-4,
                    "prefill logit {i}: {x} vs {y}");
        }
        // decode continues identically from the batched cache
        let na = a.step(7).unwrap();
        let nb = b.step(7).unwrap();
        for (i, (x, y)) in na.iter().zip(&nb).enumerate() {
            assert!((x - y).abs() <= 1e-4, "post logit {i}: {x} vs {y}");
        }
    }

    #[test]
    fn forward_logits_matches_step_loop_every_position() {
        let prompt = toks(8);
        let mut a = small(12);
        let mut b = small(12);
        let all = a.forward_logits(&prompt).unwrap();
        assert_eq!(all.len(), prompt.len() * VOCAB);
        for (t, &tk) in prompt.iter().enumerate() {
            let lg = b.step(tk).unwrap();
            let row = &all[t * VOCAB..(t + 1) * VOCAB];
            for (i, (x, y)) in row.iter().zip(&lg).enumerate() {
                assert!((x - y).abs() <= 1e-4,
                        "pos {t} logit {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn step_traced_returns_per_block_hiddens() {
        let mut a = small(13);
        let mut b = small(13);
        let (lg, trace) = a.step_traced(3).unwrap();
        let lg2 = b.step(3).unwrap();
        assert_eq!(lg, lg2);
        assert_eq!(trace.len(), LAYERS);
        for h in &trace {
            assert_eq!(h.len(), DIM);
        }
        // the last traced hidden is the pre-final-norm state: re-deriving
        // logits from it must reproduce the step output
        let mut hn = vec![0f32; DIM];
        rms_norm(trace.last().unwrap(), &a.final_norm, a.norm_eps, &mut hn);
        let mut logits = vec![0f32; VOCAB];
        dense_matvec(&a.head, VOCAB, DIM, &hn, &mut logits);
        assert_eq!(logits, lg);
        // consecutive blocks actually transform the state
        assert!(trace[0] != trace[1]);
    }

    #[test]
    fn decode_is_deterministic_across_thread_counts() {
        let prompt = toks(6);
        let run = |nt: usize| {
            with_threads(nt, || {
                let mut e = small(14);
                let mut out = e.prefill(&prompt).unwrap();
                for t in [1i32, 2, 3] {
                    out = e.step(t).unwrap();
                }
                out
            })
        };
        let l1 = run(1);
        for nt in [2usize, 4] {
            assert_eq!(l1, run(nt), "thread count {nt} changed logits");
        }
    }

    #[test]
    fn prefill_then_reset_reproduces() {
        let prompt = toks(5);
        let mut e = small(15);
        let a = e.prefill(&prompt).unwrap();
        e.reset();
        let b = e.prefill(&prompt).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rope_tables_match_direct_formula() {
        let (cos, sin) = rope_tables(6, HD, 10000.0);
        let half = HD / 2;
        for pos in 0..6 {
            for i in 0..half {
                let freq =
                    1.0 / 10000f64.powf(2.0 * i as f64 / HD as f64);
                let ang = pos as f64 * freq;
                assert_eq!(cos[pos * half + i], ang.cos() as f32);
                assert_eq!(sin[pos * half + i], ang.sin() as f32);
            }
        }
    }

    #[test]
    fn guards_reject_bad_input() {
        let mut e = small(16);
        assert!(e.step(-1).is_err());
        assert!(e.step(VOCAB as i32).is_err());
        assert!(e.prefill(&toks(CTX + 1)).is_err());
        assert!(Engine::synthetic(33, 4, 8, 64, 96, 1,
                                  QuantScheme::new(2, 32), 8, 1)
            .is_err());
        // cache-full error still fires
        let mut f = small(17);
        for t in 0..CTX {
            f.step((t % VOCAB) as i32).unwrap();
        }
        assert!(f.step(1).is_err());
        assert!(e.prefill(&[]).unwrap().is_empty());
    }
}
