//! Single-session facade over the multi-sequence serving core.
//!
//! The deployment stack is split into three parts (the ModelCore /
//! Session / Scheduler architecture):
//!
//! * [`ModelCore`](crate::infer::core::ModelCore) - the immutable,
//!   `Arc`-shareable half: packed (or dense) linears, norm weights,
//!   embedding/lm-head matrices, and precomputed RoPE tables, plus the
//!   three forward primitives (solo `step`, batched single-sequence
//!   `prefill`/`forward_logits`, and the multi-sequence `decode_batch`).
//!   One core serves any number of concurrent sequences; nothing in it
//!   mutates per request.
//! * [`KvPool`](crate::infer::kv::KvPool) /
//!   [`Session`](crate::infer::session::Session) - the mutable,
//!   per-request half: a position, a sampler RNG, and a *page table*
//!   leased from the paged KV pool (fixed-size refcounted pages;
//!   lease -> release -> reuse, with
//!   [`KvPool::fork`](crate::infer::kv::KvPool::fork) *sharing* the
//!   prefix pages for candidate-continuation scoring - zero bytes
//!   copied at fork time, copy-on-write bounded to one page on the
//!   first write past the fork point; see `infer::kv`).
//! * [`Scheduler`](crate::infer::sched::Scheduler) - continuous
//!   batching: every tick gathers all live sessions' last tokens and runs
//!   **one rows-parallel matmul per linear across the whole batch**
//!   (`ModelCore::decode_batch`), admits queued prompts via chunked
//!   prefill between ticks gated on free *pages* (short requests hold
//!   only the pages they touch), and retires finished sequences without
//!   stalling the batch.
//!
//! [`Engine`] is the thin single-session view kept for the CLI
//! `generate` path, the eval forwards, and every pre-existing caller: a
//! shared core + a private one-sequence page pool + one position.
//! `step`/`step_ref`/`prefill`/`forward_logits` semantics are unchanged,
//! and - because all paths share the same kernels and the same
//! page-segment attention routine - a solo `Engine` run is
//! **bit-identical** to the same sequence decoded inside any scheduler
//! batch at any thread count and page size (the determinism guarantee
//! the serving stack is tested against; see `infer::core`).
//!
//! Numerics mirror python/compile/model.py exactly (RMSNorm, split-half
//! RoPE, causal attention, SwiGLU). When PJRT artifacts and real xla
//! bindings are present, the integration test checks engine logits
//! against the `model_fwd_q` executable to ~1e-3; in stub builds the
//! guarantees are the internal ones: kernels vs dense-dequant, batched
//! prefill vs sequential step, batched decode vs solo decode, and
//! thread-count determinism (all tested).
//!
//! §Perf: batched prefill amortizes each linear's group-unpack across
//! prompt tokens (PR 1); batched decode amortizes it across *sequences*
//! (PR 4) - with N live sessions a tick pays one rows-parallel matmul
//! per linear instead of N full matvec passes, which is what makes
//! `eqat bench inference`'s serve section show multi-x aggregate
//! tokens/s over sequential per-request decode; paged KV (this
//! refactor) makes forking a T-token prefix O(1) instead of O(T), which
//! the bench's `kv_fork` section tracks. `runs/bench.json` (schema 5,
//! see docs/BENCH_SCHEMA.md) tracks the trajectory across PRs.

use std::sync::Arc;

use anyhow::Result;

use crate::config::QuantScheme;
use crate::infer::core::{ModelCore, Scratch};
use crate::infer::kv::{KvLease, KvPool};
use crate::io::manifest::PresetInfo;
use crate::model::quantized::QuantizedModel;

pub struct Engine {
    core: Arc<ModelCore>,
    pool: KvPool,
    lease: KvLease,
    scratch: Scratch,
    pos: usize,
}

impl Engine {
    /// Build from the in-memory quantized model + manifest preset info.
    pub fn new(qm: &QuantizedModel, info: &PresetInfo, max_ctx: usize)
               -> Result<Engine> {
        Ok(Engine::from_core(Arc::new(
            ModelCore::from_quantized(qm, info, max_ctx)?)))
    }

    /// Build a randomly-initialized engine directly from shapes (see
    /// [`ModelCore::synthetic`]).
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        dim: usize,
        n_heads: usize,
        head_dim: usize,
        inter: usize,
        vocab: usize,
        n_layers: usize,
        scheme: QuantScheme,
        max_ctx: usize,
        seed: u64,
    ) -> Result<Engine> {
        Ok(Engine::from_core(Arc::new(ModelCore::synthetic(
            dim, n_heads, head_dim, inter, vocab, n_layers, scheme,
            max_ctx, seed)?)))
    }

    /// Wrap a shared core as a single-session engine: a private
    /// one-sequence page pool plus a fresh position. Many engines (and
    /// schedulers) can view the same core concurrently.
    pub fn from_core(core: Arc<ModelCore>) -> Engine {
        let mut pool = KvPool::for_core(&core, 1);
        let lease = pool.lease().expect("fresh one-sequence pool");
        let scratch = core.scratch();
        Engine { core, pool, lease, scratch, pos: 0 }
    }

    /// The shared immutable model behind this engine.
    pub fn core(&self) -> &Arc<ModelCore> {
        &self.core
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Pin the position (benches rewind the KV window with this).
    pub fn set_pos(&mut self, pos: usize) {
        self.pos = pos;
    }

    pub fn max_ctx(&self) -> usize {
        self.core.max_ctx
    }

    pub fn vocab(&self) -> usize {
        self.core.vocab
    }

    pub fn n_layers(&self) -> usize {
        self.core.n_layers()
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// One decode step: feed `tok` at the current position, return logits.
    pub fn step(&mut self, tok: i32) -> Result<Vec<f32>> {
        self.step_ref(tok).map(|l| l.to_vec())
    }

    /// Like [`Engine::step`] but returns a view into the engine's scratch
    /// instead of copying: steady-state decode through this entry point
    /// performs zero heap allocation.
    pub fn step_ref(&mut self, tok: i32) -> Result<&[f32]> {
        self.core.step(&mut self.pool, &self.lease, self.pos, tok,
                       &mut self.scratch)?;
        self.pos += 1;
        Ok(self.scratch.logits())
    }

    /// Debug/testing: like `step` but also returns the hidden state after
    /// each block (used to localize divergence vs the XLA forward).
    pub fn step_traced(&mut self, tok: i32)
                       -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let mut trace = Vec::with_capacity(self.core.n_layers());
        self.core.step_impl(&mut self.pool, &self.lease, self.pos,
                            tok, &mut self.scratch, Some(&mut trace))?;
        self.pos += 1;
        Ok((self.scratch.logits().to_vec(), trace))
    }

    /// Debug/testing: the K-cache row for (block, pos) - post-RoPE keys.
    pub fn k_row(&self, block: usize, pos: usize) -> &[f32] {
        self.pool.k_row(&self.lease, block, pos)
    }

    /// Feed a prompt; returns logits after the last token.
    ///
    /// Batched: all positions run through each block's linears as one
    /// packed matmul, the K/V matmuls write directly into the slot rows,
    /// and the lm head runs once (on the last position) instead of once
    /// per prompt token. Bit-exact with a sequential `step()` loop
    /// (tested), §Perf >=3x faster at 64 tokens on 7B-shaped blocks.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        self.core.prefill(&mut self.pool, &self.lease, self.pos,
                          tokens, &mut self.scratch)?;
        self.pos += tokens.len();
        Ok(self.scratch.logits().to_vec())
    }

    /// Evaluation forward: logits for *every* position of `tokens`
    /// (token-major, n * vocab), via the batched prefill pass plus a dense
    /// lm-head matmul. Continues from the current position; call
    /// [`Engine::reset`] first for a fresh sequence.
    pub fn forward_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.forward_logits_into(tokens, &mut out)?;
        Ok(out)
    }

    /// [`Engine::forward_logits`] into a reusable buffer (the eval loops'
    /// allocation-free path).
    pub fn forward_logits_into(&mut self, tokens: &[i32],
                               out: &mut Vec<f32>) -> Result<()> {
        out.resize(tokens.len() * self.core.vocab, 0.0);
        self.forward_logits_slice(tokens, &mut out[..])
    }

    /// [`Engine::forward_logits`] into a caller-provided slice (len
    /// tokens * vocab): batched eval writes each row straight into its
    /// place in a larger buffer, no per-row allocation or copy.
    pub fn forward_logits_slice(&mut self, tokens: &[i32],
                                out: &mut [f32]) -> Result<()> {
        if tokens.is_empty() {
            return if out.is_empty() {
                Ok(())
            } else {
                Err(anyhow::anyhow!(
                    "forward_logits: out non-empty for empty tokens"))
            };
        }
        self.core.forward_logits_slice(&mut self.pool, &self.lease,
                                       self.pos, tokens,
                                       &mut self.scratch, out)?;
        self.pos += tokens.len();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::core::{rms_norm, rope_tables};
    use crate::infer::qlinear::dense_matvec;
    use crate::util::threads::with_threads;

    const DIM: usize = 32;
    const NH: usize = 4;
    const HD: usize = 8;
    const INTER: usize = 64;
    const VOCAB: usize = 96;
    const LAYERS: usize = 2;
    const CTX: usize = 24;

    fn small(seed: u64) -> Engine {
        Engine::synthetic(DIM, NH, HD, INTER, VOCAB, LAYERS,
                          QuantScheme::new(2, 32), CTX, seed)
            .unwrap()
    }

    fn toks(n: usize) -> Vec<i32> {
        (0..n).map(|i| ((i * 13 + 5) % VOCAB) as i32).collect()
    }

    #[test]
    fn batched_prefill_matches_sequential_steps() {
        let prompt = toks(10);
        let mut a = small(11);
        let mut b = small(11);
        let la = a.prefill(&prompt).unwrap();
        let mut lb = Vec::new();
        for &t in &prompt {
            lb = b.step(t).unwrap();
        }
        assert_eq!(a.pos(), b.pos());
        assert_eq!(la.len(), lb.len());
        for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
            assert!((x - y).abs() <= 1e-4,
                    "prefill logit {i}: {x} vs {y}");
        }
        // decode continues identically from the batched cache
        let na = a.step(7).unwrap();
        let nb = b.step(7).unwrap();
        for (i, (x, y)) in na.iter().zip(&nb).enumerate() {
            assert!((x - y).abs() <= 1e-4, "post logit {i}: {x} vs {y}");
        }
    }

    #[test]
    fn forward_logits_matches_step_loop_every_position() {
        let prompt = toks(8);
        let mut a = small(12);
        let mut b = small(12);
        let all = a.forward_logits(&prompt).unwrap();
        assert_eq!(all.len(), prompt.len() * VOCAB);
        for (t, &tk) in prompt.iter().enumerate() {
            let lg = b.step(tk).unwrap();
            let row = &all[t * VOCAB..(t + 1) * VOCAB];
            for (i, (x, y)) in row.iter().zip(&lg).enumerate() {
                assert!((x - y).abs() <= 1e-4,
                        "pos {t} logit {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn step_traced_returns_per_block_hiddens() {
        let mut a = small(13);
        let mut b = small(13);
        let (lg, trace) = a.step_traced(3).unwrap();
        let lg2 = b.step(3).unwrap();
        assert_eq!(lg, lg2);
        assert_eq!(trace.len(), LAYERS);
        for h in &trace {
            assert_eq!(h.len(), DIM);
        }
        // the last traced hidden is the pre-final-norm state: re-deriving
        // logits from it must reproduce the step output
        let core = a.core();
        let mut hn = vec![0f32; DIM];
        rms_norm(trace.last().unwrap(), &core.final_norm, core.norm_eps,
                 &mut hn);
        let mut logits = vec![0f32; VOCAB];
        dense_matvec(&core.head, VOCAB, DIM, &hn, &mut logits);
        assert_eq!(logits, lg);
        // consecutive blocks actually transform the state
        assert!(trace[0] != trace[1]);
    }

    #[test]
    fn decode_is_deterministic_across_thread_counts() {
        let prompt = toks(6);
        let run = |nt: usize| {
            with_threads(nt, || {
                let mut e = small(14);
                let mut out = e.prefill(&prompt).unwrap();
                for t in [1i32, 2, 3] {
                    out = e.step(t).unwrap();
                }
                out
            })
        };
        let l1 = run(1);
        for nt in [2usize, 4] {
            assert_eq!(l1, run(nt), "thread count {nt} changed logits");
        }
    }

    #[test]
    fn prefill_then_reset_reproduces() {
        let prompt = toks(5);
        let mut e = small(15);
        let a = e.prefill(&prompt).unwrap();
        e.reset();
        let b = e.prefill(&prompt).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn engines_share_one_core() {
        let core = small(18).core().clone();
        let mut a = Engine::from_core(core.clone());
        let mut b = Engine::from_core(core.clone());
        // interleaved use of two sessions over one core: each keeps its
        // own KV slot and position, outputs match a private engine
        let la = a.prefill(&toks(7)).unwrap();
        let _ = b.prefill(&toks(4)).unwrap();
        let la2 = a.step(3).unwrap();
        let mut solo = Engine::from_core(core);
        let ls = solo.prefill(&toks(7)).unwrap();
        assert_eq!(la, ls);
        assert_eq!(la2, solo.step(3).unwrap());
    }

    #[test]
    fn rope_tables_match_direct_formula() {
        let (cos, sin) = rope_tables(6, HD, 10000.0);
        let half = HD / 2;
        for pos in 0..6 {
            for i in 0..half {
                let freq =
                    1.0 / 10000f64.powf(2.0 * i as f64 / HD as f64);
                let ang = pos as f64 * freq;
                assert_eq!(cos[pos * half + i], ang.cos() as f32);
                assert_eq!(sin[pos * half + i], ang.sin() as f32);
            }
        }
    }

    #[test]
    fn guards_reject_bad_input() {
        let mut e = small(16);
        assert!(e.step(-1).is_err());
        assert!(e.step(VOCAB as i32).is_err());
        assert!(e.prefill(&toks(CTX + 1)).is_err());
        assert!(Engine::synthetic(33, 4, 8, 64, 96, 1,
                                  QuantScheme::new(2, 32), 8, 1)
            .is_err());
        // cache-full error still fires
        let mut f = small(17);
        for t in 0..CTX {
            f.step((t % VOCAB) as i32).unwrap();
        }
        assert!(f.step(1).is_err());
        assert!(e.prefill(&[]).unwrap().is_empty());
    }
}
