//! Pure-Rust deployment engine: autoregressive transformer forward over
//! packed low-bit weights with a KV cache. This is the "request path" a
//! downstream user ships - no Python, no XLA, just the packed .eqt model.
//!
//! Numerics mirror python/compile/model.py exactly (RMSNorm, split-half
//! RoPE, causal attention, SwiGLU); the integration test checks engine
//! logits against the PJRT `model_fwd_q` executable to ~1e-3.

use anyhow::{anyhow, Result};

use crate::io::manifest::PresetInfo;
use crate::infer::qlinear::{dense_matvec, PackedLinear};
use crate::model::quantized::QuantizedModel;

const LINS: [&str; 7] = ["attn.q", "attn.k", "attn.v", "attn.o",
                         "mlp.gate", "mlp.up", "mlp.down"];

struct BlockW {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    /// q, k, v, o, gate, up, down
    lins: Vec<PackedLinear>,
}

pub struct Engine {
    pub dim: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub inter: usize,
    pub vocab: usize,
    pub max_ctx: usize,
    rope_theta: f64,
    norm_eps: f32,
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    head: Vec<f32>,
    blocks: Vec<BlockW>,
    /// per block: (k_cache, v_cache), each (max_ctx * dim)
    cache: Vec<(Vec<f32>, Vec<f32>)>,
    pub pos: usize,
}

impl Engine {
    /// Build from the in-memory quantized model + manifest preset info.
    pub fn new(qm: &QuantizedModel, info: &PresetInfo, max_ctx: usize)
               -> Result<Engine> {
        let cfg = &info.config;
        let g = qm.scheme.group;
        let wql = info.layouts.get("wq")
            .ok_or_else(|| anyhow!("missing wq layout"))?;
        let qpl = info.layouts.get(&format!("qp_g{g}"))
            .ok_or_else(|| anyhow!("missing qp_g{g} layout"))?;
        let fprl = info.layouts.get("fpr")
            .ok_or_else(|| anyhow!("missing fpr layout"))?;

        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let mut lins = Vec::with_capacity(7);
            for name in LINS {
                let we = wql.entry(&format!("blocks.{b}.{name}"))?;
                let (out_d, in_d) = (we.shape[0], we.shape[1]);
                let w_int = wql.slice(&qm.wq, &format!("blocks.{b}.{name}"))?;
                let s = qpl.slice(&qm.qp, &format!("s.blocks.{b}.{name}"))?;
                let z = qpl.slice(&qm.qp, &format!("z.blocks.{b}.{name}"))?;
                lins.push(PackedLinear::pack(w_int, out_d, in_d, s, z,
                                             qm.scheme)?);
            }
            blocks.push(BlockW {
                attn_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.attn_norm"))?
                    .to_vec(),
                mlp_norm: fprl
                    .slice(&qm.fpr, &format!("blocks.{b}.mlp_norm"))?
                    .to_vec(),
                lins,
            });
        }
        let cache = (0..cfg.n_layers)
            .map(|_| {
                (vec![0f32; max_ctx * cfg.dim], vec![0f32; max_ctx * cfg.dim])
            })
            .collect();
        Ok(Engine {
            dim: cfg.dim,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim,
            inter: cfg.inter,
            vocab: cfg.vocab,
            max_ctx,
            rope_theta: cfg.rope_theta,
            norm_eps: cfg.norm_eps as f32,
            embed: fprl.slice(&qm.fpr, "embed")?.to_vec(),
            final_norm: fprl.slice(&qm.fpr, "final_norm")?.to_vec(),
            head: fprl.slice(&qm.fpr, "head")?.to_vec(),
            blocks,
            cache,
            pos: 0,
        })
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// One decode step: feed `tok` at the current position, return logits.
    pub fn step(&mut self, tok: i32) -> Result<Vec<f32>> {
        if self.pos >= self.max_ctx {
            anyhow::bail!("KV cache full ({} positions)", self.max_ctx);
        }
        let d = self.dim;
        let pos = self.pos;
        let mut h = self.embed[tok as usize * d..(tok as usize + 1) * d]
            .to_vec();
        let mut hn = vec![0f32; d];
        let mut q = vec![0f32; d];
        let mut ctx = vec![0f32; d];
        let mut attn_out = vec![0f32; d];
        let mut gate = vec![0f32; self.inter];
        let mut up = vec![0f32; self.inter];
        let mut down = vec![0f32; d];

        let (nh, hd_, theta, eps) =
            (self.n_heads, self.head_dim, self.rope_theta, self.norm_eps);
        for (bi, blk) in self.blocks.iter().enumerate() {
            rms_norm(&h, &blk.attn_norm, eps, &mut hn);
            {
                let (kc, vc) = &mut self.cache[bi];
                blk.lins[0].matvec(&hn, &mut q);
                blk.lins[1].matvec(&hn, &mut kc[pos * d..(pos + 1) * d]);
                blk.lins[2].matvec(&hn, &mut vc[pos * d..(pos + 1) * d]);
                rope(&mut kc[pos * d..(pos + 1) * d], pos, nh, hd_, theta);
            }
            rope(&mut q, pos, nh, hd_, theta);
            let (kc, vc) = &self.cache[bi];
            let hd = self.head_dim;
            let scale = 1.0 / (hd as f32).sqrt();
            for hh in 0..self.n_heads {
                let qh = &q[hh * hd..(hh + 1) * hd];
                // scores over positions 0..=pos
                let mut scores = Vec::with_capacity(pos + 1);
                let mut mx = f32::NEG_INFINITY;
                for t in 0..=pos {
                    let kh = &kc[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let mut s = 0f32;
                    for i in 0..hd {
                        s += qh[i] * kh[i];
                    }
                    let s = s * scale;
                    mx = mx.max(s);
                    scores.push(s);
                }
                let mut zsum = 0f32;
                for s in scores.iter_mut() {
                    *s = (*s - mx).exp();
                    zsum += *s;
                }
                let ch = &mut ctx[hh * hd..(hh + 1) * hd];
                ch.fill(0.0);
                for (t, &p) in scores.iter().enumerate() {
                    let vh = &vc[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let w = p / zsum;
                    for i in 0..hd {
                        ch[i] += w * vh[i];
                    }
                }
            }
            blk.lins[3].matvec(&ctx, &mut attn_out);
            for i in 0..d {
                h[i] += attn_out[i];
            }
            rms_norm(&h, &blk.mlp_norm, eps, &mut hn);
            blk.lins[4].matvec(&hn, &mut gate);
            blk.lins[5].matvec(&hn, &mut up);
            for i in 0..self.inter {
                let gx = gate[i];
                let silu = gx / (1.0 + (-gx).exp());
                gate[i] = silu * up[i];
            }
            blk.lins[6].matvec(&gate, &mut down);
            for i in 0..d {
                h[i] += down[i];
            }
        }
        self.pos += 1;
        let mut hn_final = vec![0f32; d];
        rms_norm(&h, &self.final_norm, self.norm_eps, &mut hn_final);
        let mut logits = vec![0f32; self.vocab];
        dense_matvec(&self.head, self.vocab, d, &hn_final, &mut logits);
        Ok(logits)
    }

    /// Debug/testing: like `step` but also returns the hidden state after
    /// each block (used to localize divergence vs the XLA forward).
    pub fn step_traced(&mut self, tok: i32)
                       -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let trace_pos = self.pos;
        let logits = self.step(tok)?;
        // recompute per-block h by replaying? cheaper: caller compares
        // caches; expose k/v rows instead.
        let _ = trace_pos;
        Ok((logits, Vec::new()))
    }

    /// Debug/testing: the K-cache row for (block, pos) - post-RoPE keys.
    pub fn k_row(&self, block: usize, pos: usize) -> &[f32] {
        let d = self.dim;
        &self.cache[block].0[pos * d..(pos + 1) * d]
    }

    /// Feed a prompt; returns logits after the last token.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.step(t)?;
        }
        Ok(logits)
    }
}

/// RMSNorm matching model.py::rms_norm.
fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let mut ss = 0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / x.len() as f32 + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Split-half RoPE matching model.py::apply_rope.
fn rope(v: &mut [f32], pos: usize, n_heads: usize, head_dim: usize,
        theta: f64) {
    let half = head_dim / 2;
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / head_dim as f64);
            let ang = pos as f64 * freq;
            let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
            let a = v[base + i];
            let b = v[base + half + i];
            v[base + i] = a * cos - b * sin;
            v[base + half + i] = b * cos + a * sin;
        }
    }
}
