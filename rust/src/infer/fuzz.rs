//! Randomized scheduler property-test harness: seeded generation of
//! whole serving schedules - arrivals, deadlines, priorities, cancels,
//! failpoint arms, prefill budgets, KV bit-widths, cache on/off, FIFO
//! and EDF - driven tick by tick with invariants asserted throughout.
//!
//! Every schedule is a pure function of `(seed, index)`, every schedule
//! runs **twice** (bit-identical events and completions required), and
//! each run checks:
//!
//! - **No leaked pages**: after the drain and a cache flush,
//!   `pages_in_use() == 0`.
//! - **Exactly-once retirement**: every accepted request produces
//!   exactly one `Finished` stream event and exactly one
//!   [`Completion`]; completions + rejects == arrivals.
//! - **Stream/poll agreement**: at every tick, tokens accumulated from
//!   stream events equal the [`Scheduler::stream_tokens`] poll, and at
//!   retirement they equal the completion's output exactly.
//! - **EDF admission order** (cache off): admissions within a tick are
//!   nondecreasing in the exact EDF key - starvation-aged entries
//!   first (FIFO by id), then absolute deadline, then priority class -
//!   using a mirror of the scheduler's aging counters.
//! - **Solo bit-equality**: natural finishes (`Done`/`ContextFull`)
//!   bit-equal a solo reference run (the `Engine` path for f32 KV, a
//!   1-slot scheduler for packed low-bit KV); every other finish is a
//!   strict prefix of it.
//!
//! Any violation aborts the sweep with the schedule index and seed in
//! the error, so a failure is reproducible with
//! `run_fuzz(1, failing_seed ^ index * GOLDEN)` - or by re-running the
//! sweep, since it is deterministic end to end.
//!
//! `rust/tests/sched_property.rs` runs a bounded sweep in tier-1 under
//! both `EQAT_SIMD=scalar` and `auto`; the `serve_slo` bench section
//! runs the full 200-schedule acceptance sweep.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::config::QuantScheme;
use crate::infer::core::ModelCore;
use crate::infer::engine::Engine;
use crate::infer::generate::{generate, Sampler};
use crate::infer::kv::{KvFormat, KvPool};
use crate::infer::sched::{Reject, SchedConfig, SchedPolicy, Scheduler,
                          StreamEvent, StreamEventKind};
use crate::infer::session::{FinishReason, Request};
use crate::util::clock::Clock;
use crate::util::failpoint;
use crate::util::rng::Rng;

/// Aggregate counters from a fuzz sweep. `violations` and
/// `leaked_pages` are always 0 on `Ok` - any breach bails instead -
/// and are carried so the bench payload can report them explicitly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FuzzReport {
    /// schedules generated and driven (each twice)
    pub schedules: usize,
    /// scheduler ticks driven across all runs
    pub ticks: u64,
    /// completions observed across all runs
    pub completions: usize,
    /// tokens observed through stream events
    pub streamed_tokens: usize,
    /// KV pages still held after any drain - 0 by construction
    pub leaked_pages: usize,
    /// invariant violations - 0 by construction
    pub violations: usize,
    /// cancels issued
    pub cancels: usize,
    /// deadline expiries observed (queued + live)
    pub timeouts: usize,
    /// failpoint fires observed (fault-armed schedules only)
    pub faults_fired: u64,
    /// schedules that ran under the EDF policy
    pub edf_schedules: usize,
    /// completions cross-checked against a solo reference
    pub solo_checked: usize,
}

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One planned request: everything the drive loop needs, pre-drawn so
/// the schedule cannot depend on scheduler state.
struct PlannedReq {
    arrive_tick: u64,
    prompt: Vec<i32>,
    max_new: usize,
    seed: u64,
    /// relative deadline in virtual seconds (1 tick = 1 second here)
    deadline: Option<f64>,
    priority: u8,
    /// cancel this request when the clock reaches this tick
    cancel_tick: Option<u64>,
}

/// One generated schedule: scheduler geometry + request plan.
struct Plan {
    pages: usize,
    page_rows: usize,
    kv_bits: u32,
    cache: bool,
    policy: SchedPolicy,
    starve_patience: u32,
    admit_lookahead: usize,
    prefill_chunk: usize,
    prefill_budget: usize,
    max_batch: usize,
    max_queue: usize,
    fault_seed: Option<u64>,
    reqs: Vec<PlannedReq>,
}

fn draw_plan(rng: &mut Rng, schedule_seed: u64) -> Plan {
    let n = rng.range(2, 7);
    let reqs = (0..n)
        .map(|i| {
            let arrive_tick = rng.below(21) as u64;
            let plen = rng.range(1, 11);
            let stride = rng.range(1, 12);
            let prompt: Vec<i32> = (0..plen)
                .map(|k| ((k * stride + i * 17 + 3) % 89) as i32)
                .collect();
            let max_new = rng.range(1, 7);
            let deadline = if rng.bool(0.5) {
                Some(2.0 + rng.f64() * 28.0)
            } else {
                None
            };
            let cancel_tick = if rng.bool(0.15) {
                Some(arrive_tick + rng.below(8) as u64)
            } else {
                None
            };
            PlannedReq {
                arrive_tick,
                prompt,
                max_new,
                seed: schedule_seed
                    .wrapping_add(1000 + i as u64),
                deadline,
                priority: rng.below(3) as u8,
                cancel_tick,
            }
        })
        .collect();
    Plan {
        pages: rng.range(8, 15),
        page_rows: rng.range(4, 9),
        kv_bits: [16u32, 16, 16, 8, 4][rng.below(5)],
        cache: rng.bool(0.3),
        policy: if rng.bool(0.5) {
            SchedPolicy::Edf
        } else {
            SchedPolicy::Fifo
        },
        starve_patience: [0u32, 2, 64, 1000][rng.below(4)],
        admit_lookahead: [0usize, 2, 4][rng.below(3)],
        prefill_chunk: rng.range(1, 7),
        prefill_budget: [0usize, 1, 3, 8][rng.below(4)],
        max_batch: rng.range(1, 5),
        max_queue: rng.range(2, 9),
        fault_seed: if rng.bool(0.25) {
            Some(schedule_seed ^ 0xFA22)
        } else {
            None
        },
        reqs,
    }
}

/// Everything one drive produced, for the determinism double-run
/// comparison and the end-of-run checks.
struct Outcome {
    /// submitted-plan-index -> scheduler id (None = QueueFull reject)
    ids: Vec<Option<u64>>,
    events: Vec<StreamEvent>,
    /// (id, finish, tokens), id order
    comps: Vec<(u64, FinishReason, Vec<i32>)>,
    ticks: u64,
    streamed_tokens: usize,
    timeouts: usize,
    cancels: usize,
}

/// The exact EDF ordering key `admit_edf` uses with the cache off.
/// `aged` mirrors the scheduler's starvation counter (see
/// [`run_schedule`]'s model).
fn edf_key(aged: bool, deadline: Option<f64>, priority: u8, id: u64)
           -> (u8, u64, u64) {
    if aged {
        (0, 0, id)
    } else if let Some(d) = deadline {
        (1, d.to_bits(), id)
    } else {
        (2, (u64::from(priority) << 1) | 1, id)
    }
}

#[derive(Clone, Copy, PartialEq)]
enum ReqState {
    Queued,
    Live,
    Finished,
}

fn run_schedule(core: &Arc<ModelCore>, plan: &Plan) -> Result<Outcome> {
    let fmt = KvFormat::from_bits(plan.kv_bits);
    let pool =
        KvPool::for_core_paged_fmt(core, plan.pages, plan.page_rows, fmt);
    let mut sched = Scheduler::with_clock(
        core.clone(), pool,
        SchedConfig {
            max_batch: plan.max_batch,
            prefill_chunk: plan.prefill_chunk,
            max_queue: plan.max_queue,
            admit_lookahead: plan.admit_lookahead,
            starve_patience: plan.starve_patience,
            prefix_cache: plan.cache,
            kv_bits: plan.kv_bits,
            policy: plan.policy,
            prefill_budget: plan.prefill_budget,
            stream: true,
            ..SchedConfig::default()
        },
        Clock::manual());

    let mut ids: Vec<Option<u64>> = vec![None; plan.reqs.len()];
    // per-id mirrors for the invariant checks
    let mut state: HashMap<u64, ReqState> = HashMap::new();
    let mut abs_deadline: HashMap<u64, Option<f64>> = HashMap::new();
    let mut priority: HashMap<u64, u8> = HashMap::new();
    let mut skipped: HashMap<u64, u32> = HashMap::new();
    let mut streamed: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut finish_events: HashMap<u64, usize> = HashMap::new();
    let mut events: Vec<StreamEvent> = Vec::new();
    let mut streamed_tokens = 0usize;
    let mut cancels = 0usize;
    let mut tick = 0u64;
    loop {
        let now = sched.clock().now();
        // arrivals planned for this tick, in plan order
        for (i, r) in plan.reqs.iter().enumerate() {
            if r.arrive_tick != tick {
                continue;
            }
            let mut req = Request::new(r.prompt.clone(), r.max_new,
                                       Sampler::Greedy, r.seed)
                .with_priority(r.priority);
            if let Some(d) = r.deadline {
                req = req.with_deadline(d);
            }
            match sched.submit(req) {
                Ok(id) => {
                    ids[i] = Some(id);
                    state.insert(id, ReqState::Queued);
                    // same expression the scheduler evaluates at submit
                    abs_deadline.insert(id, r.deadline.map(|d| now + d));
                    priority.insert(id, r.priority);
                    skipped.insert(id, 0);
                    streamed.insert(id, Vec::new());
                }
                Err(Reject::QueueFull { .. }) => {}
                Err(e) => bail!("arrival {i} rejected unexpectedly: {e}"),
            }
        }
        // planned cancels due at this tick (only for accepted requests)
        for (i, r) in plan.reqs.iter().enumerate() {
            if r.cancel_tick == Some(tick) {
                if let Some(id) = ids[i] {
                    if sched.cancel(id) {
                        cancels += 1;
                    }
                }
            }
        }
        // done once every arrival has been offered and the scheduler
        // has drained (a planned cancel for a request that already
        // finished would be a no-op - no need to wait for it)
        if sched.is_idle()
            && plan.reqs.iter().all(|r| r.arrive_tick <= tick)
        {
            break;
        }
        sched.tick()?;
        let tick_events = sched.take_stream_events();
        let mut admitted_keys: Vec<(u8, u64, u64)> = Vec::new();
        let mut any_admitted = false;
        for ev in &tick_events {
            match &ev.kind {
                StreamEventKind::Admitted => {
                    ensure!(state.get(&ev.id) == Some(&ReqState::Queued),
                            "req {} admitted while not queued", ev.id);
                    if plan.policy == SchedPolicy::Edf && !plan.cache {
                        let aged = skipped[&ev.id]
                            >= plan.starve_patience;
                        admitted_keys.push(edf_key(
                            aged, abs_deadline[&ev.id],
                            priority[&ev.id], ev.id));
                    }
                    state.insert(ev.id, ReqState::Live);
                    any_admitted = true;
                }
                StreamEventKind::Token(tok) => {
                    ensure!(state.get(&ev.id) == Some(&ReqState::Live),
                            "req {} emitted a token while not live",
                            ev.id);
                    streamed.get_mut(&ev.id)
                        .ok_or_else(|| anyhow::anyhow!(
                            "token for unknown id {}", ev.id))?
                        .push(*tok);
                    streamed_tokens += 1;
                }
                StreamEventKind::Finished(_) => {
                    ensure!(state.get(&ev.id).is_some()
                            && state[&ev.id] != ReqState::Finished,
                            "req {} finished twice or never existed",
                            ev.id);
                    state.insert(ev.id, ReqState::Finished);
                    *finish_events.entry(ev.id).or_insert(0) += 1;
                }
            }
        }
        // EDF invariant: admissions within a tick follow the exact key
        // order (aged first FIFO-by-id, then deadline, then priority)
        for w in admitted_keys.windows(2) {
            ensure!(w[0] <= w[1],
                    "EDF admitted out of key order: {:?} before {:?}",
                    w[0], w[1]);
        }
        // mirror the scheduler's aging rule: an admission tick ages
        // every entry still queued at the end of the pass
        if any_admitted && plan.policy == SchedPolicy::Edf {
            for (id, st) in &state {
                if *st == ReqState::Queued {
                    let c = skipped.entry(*id).or_insert(0);
                    *c = c.saturating_add(1);
                }
            }
        }
        events.extend(tick_events);
        // stream/poll agreement for every request we know about
        for (id, acc) in &streamed {
            if let Some(part) = sched.stream_tokens(*id) {
                ensure!(part == &acc[..],
                        "req {id}: poll disagrees with stream events");
            }
        }
        sched.clock().advance(1.0);
        tick += 1;
        ensure!(tick < 5_000, "schedule failed to drain in 5k ticks");
    }

    // drain checks: cache flushed, zero pages held, exactly-once
    // retirement, streamed == retired
    sched.flush_prefix_cache();
    let leaked = sched.pool().pages_in_use();
    ensure!(leaked == 0, "leaked {leaked} KV pages");
    let comps = sched.take_completed();
    let accepted: Vec<u64> = ids.iter().filter_map(|x| *x).collect();
    ensure!(comps.len() == accepted.len(),
            "{} completions for {} accepted requests",
            comps.len(), accepted.len());
    let mut timeouts = 0usize;
    for c in &comps {
        ensure!(finish_events.get(&c.id) == Some(&1),
                "req {}: {:?} Finished events (want exactly 1)",
                c.id, finish_events.get(&c.id));
        ensure!(&streamed[&c.id] == &c.tokens,
                "req {}: streamed tokens != retired output", c.id);
        if c.finish == FinishReason::TimedOut {
            timeouts += 1;
        }
    }
    Ok(Outcome {
        ids,
        events,
        comps: comps.into_iter()
            .map(|c| (c.id, c.finish, c.tokens))
            .collect(),
        ticks: tick,
        streamed_tokens,
        timeouts,
        cancels,
    })
}

/// Solo reference tokens for one planned request: the `Engine` path for
/// f32 KV (pinning scheduler == solo `generate`), a fresh 1-slot
/// fault-free FIFO scheduler for packed low-bit KV (whose contract is
/// reproducibility at fixed bits, not f32 equality).
fn solo_ref(core: &Arc<ModelCore>, r: &PlannedReq, kv_bits: u32)
            -> Result<Vec<i32>> {
    if kv_bits != 8 && kv_bits != 4 {
        let mut e = Engine::from_core(core.clone());
        return Ok(generate(&mut e, &r.prompt, r.max_new,
                           Sampler::Greedy, r.seed)?.tokens);
    }
    let fmt = KvFormat::from_bits(kv_bits);
    let pool = KvPool::for_core_fmt(core, 1, fmt);
    let mut s = Scheduler::with_clock(
        core.clone(), pool,
        SchedConfig { max_batch: 1, kv_bits, ..SchedConfig::default() },
        Clock::manual());
    s.submit(Request::new(r.prompt.clone(), r.max_new, Sampler::Greedy,
                          r.seed))?;
    let comps = s.run_all()?;
    ensure!(comps.len() == 1 && comps[0].finish.is_ok(),
            "low-bit solo reference did not finish cleanly");
    Ok(comps[0].tokens.clone())
}

/// Drive `schedules` generated schedules (each twice, bit-equality
/// required) against one small shared synthetic model. Returns the
/// aggregate counters; any invariant breach errors out with the
/// schedule index in the message.
pub fn run_fuzz(schedules: usize, seed: u64) -> Result<FuzzReport> {
    let core = Arc::new(ModelCore::synthetic(
        32, 4, 8, 64, 96, 2, QuantScheme::new(2, 32), 48, 7)?);
    let mut rep = FuzzReport::default();
    // low-bit solo references re-run the model; cache them across
    // schedules (prompts repeat under the bounded generator)
    let mut refs: HashMap<(Vec<i32>, usize, u64, u32), Vec<i32>> =
        HashMap::new();
    for i in 0..schedules {
        let schedule_seed = seed ^ (i as u64).wrapping_mul(GOLDEN);
        let mut rng = Rng::new(schedule_seed).fork("sched-fuzz");
        let plan = draw_plan(&mut rng, schedule_seed);
        let run = |p: &Plan| -> Result<(Outcome, u64)> {
            match p.fault_seed {
                Some(fs) => {
                    let sites = [("kv.draw", 0.03), ("fwd.prefill", 0.05),
                                 ("fwd.decode", 0.03), ("fwd.step", 0.03),
                                 ("cache.insert", 0.03)];
                    failpoint::arm(fs, &sites);
                    let res = run_schedule(core, p);
                    let reports = failpoint::disarm();
                    Ok((res?,
                        reports.iter().map(|r| r.fired).sum::<u64>()))
                }
                None => Ok((run_schedule(core, p)?, 0)),
            }
        };
        let (a, fired) = run(&plan)
            .with_context(|| format!(
                "schedule {i} (seed {schedule_seed:#x}) violated an \
                 invariant"))?;
        let (b, fired_b) = run(&plan)
            .with_context(|| format!(
                "schedule {i} (seed {schedule_seed:#x}) violated an \
                 invariant on the repeat run"))?;
        ensure!(a.events == b.events && a.comps == b.comps
                && fired == fired_b,
                "schedule {i} (seed {schedule_seed:#x}) is not \
                 deterministic across identical runs");
        rep.faults_fired += fired;
        // solo cross-checks (failpoints are disarmed here, so the
        // references are clean even for fault-armed schedules)
        for (pi, id) in a.ids.iter().enumerate() {
            let id = match id {
                Some(id) => *id,
                None => continue,
            };
            let r = &plan.reqs[pi];
            let key = (r.prompt.clone(), r.max_new, r.seed, plan.kv_bits);
            if !refs.contains_key(&key) {
                let want = solo_ref(core, r, plan.kv_bits)?;
                refs.insert(key.clone(), want);
            }
            let want = &refs[&key];
            let (_, finish, tokens) = a.comps.iter()
                .find(|c| c.0 == id)
                .ok_or_else(|| anyhow::anyhow!(
                    "schedule {i}: accepted req {id} has no completion"))?;
            if finish.is_ok() {
                ensure!(tokens == want,
                        "schedule {i} (seed {schedule_seed:#x}) req \
                         {id}: survivor tokens diverge from solo run");
            } else {
                ensure!(tokens.len() <= want.len()
                        && &want[..tokens.len()] == &tokens[..],
                        "schedule {i} (seed {schedule_seed:#x}) req \
                         {id}: partial output is not a prefix of the \
                         solo run");
            }
            rep.solo_checked += 1;
        }
        rep.schedules += 1;
        rep.ticks += a.ticks + b.ticks;
        rep.completions += a.comps.len() + b.comps.len();
        rep.streamed_tokens += a.streamed_tokens + b.streamed_tokens;
        rep.cancels += a.cancels;
        rep.timeouts += a.timeouts;
        if plan.policy == SchedPolicy::Edf {
            rep.edf_schedules += 1;
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small sweep exercises both policies and at least one fault or
    /// cancel arm, and passes every invariant. (The bounded tier-1
    /// sweep and the 200-schedule bench sweep run the same harness at
    /// scale.)
    #[test]
    fn fuzz_smoke_passes_and_covers_both_policies() {
        let rep = run_fuzz(24, 0xF122).unwrap();
        assert_eq!(rep.schedules, 24);
        assert_eq!(rep.violations, 0);
        assert_eq!(rep.leaked_pages, 0);
        assert!(rep.completions > 0);
        assert!(rep.streamed_tokens > 0);
        assert!(rep.edf_schedules > 0 && rep.edf_schedules < 24,
                "both policies must appear: {rep:?}");
        assert!(rep.solo_checked > 0);
    }

    /// The sweep itself is deterministic: same (n, seed) -> same
    /// aggregate report.
    #[test]
    fn fuzz_sweep_is_reproducible() {
        let a = run_fuzz(6, 0xF123).unwrap();
        let b = run_fuzz(6, 0xF123).unwrap();
        assert_eq!(a, b);
    }
}
