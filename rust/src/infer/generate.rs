//! Token generation loop over the pure-Rust engine: greedy or temperature
//! sampling, with tokens/sec accounting for the serving example.
//!
//! The decode loop samples from `Engine::step_ref`'s borrowed logits view,
//! so steady-state generation performs zero heap allocation per token
//! (prefill is batched inside `Engine::prefill`).

use anyhow::Result;

use crate::infer::engine::Engine;
use crate::util::rng::Rng;
use crate::util::stats::softmax;

#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    Greedy,
    Temperature(f32),
}

pub struct GenReport {
    pub tokens: Vec<i32>,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_tok_per_sec: f64,
}

pub fn generate(
    engine: &mut Engine,
    prompt: &[i32],
    max_new: usize,
    sampler: Sampler,
    seed: u64,
) -> Result<GenReport> {
    let mut rng = Rng::new(seed).fork("sample");
    engine.reset();

    let t0 = std::time::Instant::now();
    let logits = engine.prefill(prompt)?;
    let prefill_secs = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let mut out = Vec::with_capacity(max_new);
    let mut next = sample(&logits, sampler, &mut rng);
    for _ in 0..max_new {
        if engine.pos() >= engine.max_ctx() {
            break;
        }
        out.push(next);
        // borrowed logits view: no per-token allocation
        let lg = engine.step_ref(next)?;
        next = sample(lg, sampler, &mut rng);
    }
    let decode_secs = t1.elapsed().as_secs_f64();
    let tps = out.len() as f64 / decode_secs.max(1e-9);
    Ok(GenReport {
        tokens: out,
        prefill_secs,
        decode_secs,
        decode_tok_per_sec: tps,
    })
}

pub fn sample(logits: &[f32], sampler: Sampler, rng: &mut Rng) -> i32 {
    match sampler {
        Sampler::Greedy => {
            let mut best = 0usize;
            for (i, &l) in logits.iter().enumerate() {
                if l > logits[best] {
                    best = i;
                }
            }
            best as i32
        }
        Sampler::Temperature(t) => {
            let scaled: Vec<f32> =
                logits.iter().map(|&l| l / t.max(1e-6)).collect();
            let probs = softmax(&scaled);
            rng.weighted(&probs) as i32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_with_synthetic_engine() {
        use crate::config::QuantScheme;
        let mut e = Engine::synthetic(32, 4, 8, 64, 96, 1,
                                      QuantScheme::new(2, 32), 16, 3)
            .unwrap();
        let rep = generate(&mut e, &[1, 2, 3], 8, Sampler::Greedy, 9)
            .unwrap();
        assert_eq!(rep.tokens.len(), 8);
        assert!(rep.decode_tok_per_sec > 0.0);
        assert!(rep.prefill_secs >= 0.0);
        assert_eq!(e.pos(), 11); // 3 prompt + 8 generated
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(1);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, Sampler::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = vec![0.0, 5.0, 0.0];
        for _ in 0..20 {
            assert_eq!(
                sample(&logits, Sampler::Temperature(0.05), &mut rng), 1
            );
        }
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Rng::new(3);
        let logits = vec![0.0, 1.0, 0.5, 0.2];
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample(&logits, Sampler::Temperature(5.0), &mut rng));
        }
        assert!(seen.len() >= 3);
    }
}
