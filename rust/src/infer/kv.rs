//! Paged KV pool with refcounted pages and zero-copy prefix sharing.
//!
//! KV storage is a slab of fixed-size *pages* - [`KvPool::page_rows`]
//! positions of every layer's key and value rows - instead of one
//! contiguous `max_ctx` slot per sequence. Each live sequence leases a
//! *page table* ([`KvPool::lease`] / [`KvPool::lease_rows`]): an ordered
//! list of page ids covering its rows `[0, pos)`, grown one page at a
//! time as prefill/decode cross page boundaries. Pages are refcounted,
//! which buys the two properties the serving stack is built on:
//!
//! * **Zero-copy fork.** [`KvPool::fork`] hands a child session the
//!   parent's page table entries covering the forked prefix and bumps
//!   their refcounts - no row is copied at fork time. This is how
//!   `eval::zeroshot` scores N candidate continuations off one prefilled
//!   prompt with no prefix duplication at all.
//! * **Copy-on-write on the shared tail.** Only the *partial* last page
//!   of a forked prefix can ever be written by two sequences (pages
//!   wholly behind the fork point are never written again; pages past it
//!   are fresh). The first write to a shared page copies just the
//!   prefix rows that must survive (`< page_rows` rows per layer) into a
//!   private page - so continuing from a T-token fork costs at most one
//!   page of copying, independent of T. [`KvPool::bytes_copied`] counts
//!   every copied byte; tests and the bench's `kv_fork` section assert
//!   the bound.
//!
//! Admission is **reservation-based**: a lease declares how many rows it
//! may ever write (`lease_rows`, capped at `max_ctx`) and the pool
//! reserves that many pages up front, so a granted lease can never fail
//! to allocate mid-decode and the continuous-batching scheduler gates
//! admission on [`KvPool::can_admit`] / free *pages* rather than whole
//! slots - short requests hold only the pages they touch. Exhaustion is
//! not an error: `lease_rows`/`fork` return `None` and callers queue.
//!
//! Reuse is safe without zeroing, exactly like the old slab design:
//! attention only reads rows `[0, pos)` of the owning sequence, and every
//! row below `pos` was either written by this sequence or shared from a
//! parent that wrote it (pinned by the stale-leakage and COW-isolation
//! tests here and in `infer::core`/`infer::sched`).
//!
//! The forward primitives in [`ModelCore`](crate::infer::core::ModelCore)
//! read KV through per-page segments (`KvPool::k_seg`/`KvPool::v_seg`)
//! in ascending row order, replicating the exact FMA sequence of a
//! contiguous cache - the serving determinism contract (bit-identical
//! logits at any batch size, chunking, thread count, and now page size)
//! is unchanged.
//!
//! **Low-bit page storage** (opt-in via [`KvFormat`]): pages can
//! store rows as packed int8 or int4 instead of f32. Each
//! `dim`-element row is quantized *on write* with an asymmetric
//! per-row affine code (`x ~ q * scale + zero`, `zero = min`,
//! `scale = (max - min) / qmax`) - the same group scheme as
//! `infer::qlinear`'s weight groups, with the group being one row -
//! and attention streams the packed words through the fused
//! dequant kernels in [`crate::util::simd`]. Quantization is
//! deliberately scalar: a row is written once but read many times,
//! so a scalar-only writer keeps the stored bits identical under
//! every `EQAT_SIMD` setting while the read kernels carry the
//! lane-order contract. Packed pages flow through fork / COW / the
//! prefix cache unchanged (those layers move pages and rows, not
//! element formats); `page_bytes`/`bytes_copied` account the packed
//! sizes, which is where the 4-8x capacity multiplier shows up. The
//! default `F32` format keeps the byte-identical serving contract;
//! packed formats carry their own determinism contract (bit-identical
//! across batch size, chunking, threads, page size, SIMD ISA, cache
//! hit vs cold - just not to f32).
//!
//! **Cross-request prefix cache** (opt-in via
//! [`KvPool::enable_prefix_cache`]): a radix index
//! ([`PrefixCache`](crate::infer::prefixcache::PrefixCache)) from token
//! prefix to page-table prefix. [`KvPool::cache_insert`] records a
//! retiring sequence's full pages by refcount (no copy);
//! [`KvPool::lease_rows_cached`] serves the longest cached page-aligned
//! prefix back to a new lease the same way `fork` shares pages - and
//! right-sizes the reservation to only the rows past the match, so hits
//! admit under pressure that would queue a cold request. When a
//! reservation would not otherwise fit, the allocation paths evict
//! cache-only pages (LRU, refcount == 1) before giving up, so the cache
//! borrows idle pool capacity without ever breaking the reservation
//! invariant.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::infer::core::ModelCore;
use crate::infer::prefixcache::PrefixCache;
use crate::util::failpoint;

/// Default rows per page. Small enough that a forked tail copy is cheap,
/// large enough that attention's per-segment loop overhead vanishes.
pub const DEFAULT_PAGE_ROWS: usize = 64;

/// Page storage format: f32 rows (the default, byte-identical serving
/// contract) or packed low-bit rows quantized on write with a per-row
/// f32 scale/zero pair. See the module docs for the quantization code
/// and the two-tier determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvFormat {
    /// Full-precision rows.
    F32,
    /// Packed 8-bit rows: 4 values per u32 word.
    Int8,
    /// Packed 4-bit rows: 8 values per u32 word.
    Int4,
}

impl KvFormat {
    /// CLI mapping for `--kv-bits {4,8,16}`: 4 and 8 select the packed
    /// formats; anything else is full precision.
    pub fn from_bits(bits: u32) -> KvFormat {
        match bits {
            4 => KvFormat::Int4,
            8 => KvFormat::Int8,
            _ => KvFormat::F32,
        }
    }

    /// Stored bits per value (f32 reported as 32).
    pub fn bits(self) -> u32 {
        match self {
            KvFormat::F32 => 32,
            KvFormat::Int8 => 8,
            KvFormat::Int4 => 4,
        }
    }

    /// Is this a packed (quantized) format?
    pub fn is_packed(self) -> bool {
        !matches!(self, KvFormat::F32)
    }

    /// Packed values per u32 word.
    pub(crate) fn vals_per_word(self) -> usize {
        match self {
            KvFormat::F32 => 1,
            KvFormat::Int8 => 4,
            KvFormat::Int4 => 8,
        }
    }

    /// Largest stored level (packed formats; 0.0 for f32).
    fn qmax(self) -> f32 {
        match self {
            KvFormat::F32 => 0.0,
            KvFormat::Int8 => 255.0,
            KvFormat::Int4 => 15.0,
        }
    }
}

/// Quantize one row into packed `words` (cleared first), returning the
/// `(scale, zero)` pair. Asymmetric min/max code: `zero = min`,
/// `scale = (max - min) / qmax`, `x ~ q * scale + zero`. Non-finite
/// inputs quantize to the zero point; an all-equal (or all-non-finite)
/// row gets `scale = 1` so dequant reproduces the constant exactly.
/// Scalar on purpose - see the module docs' determinism note.
fn quant_row(row: &[f32], qmax: f32, bits: u32, vpw: usize,
             words: &mut [u32]) -> (f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in row {
        if x.is_finite() {
            mn = mn.min(x);
            mx = mx.max(x);
        }
    }
    if !mn.is_finite() || !mx.is_finite() {
        mn = 0.0;
        mx = 0.0;
    }
    let zero = mn;
    let scale = if mx > mn { (mx - mn) / qmax } else { 1.0 };
    let inv = 1.0 / scale;
    for w in words.iter_mut() {
        *w = 0;
    }
    for (i, &x) in row.iter().enumerate() {
        let xv = if x.is_finite() { x } else { zero };
        let q =
            ((xv - zero) * inv).round_ties_even().clamp(0.0, qmax) as u32;
        words[i / vpw] |= q << (bits * (i % vpw) as u32);
    }
    (scale, zero)
}

/// One live sequence's mutable pool state.
struct SeqState {
    /// page ids covering rows `[0, pages.len() * page_rows)`
    pages: Vec<u32>,
    /// pages this sequence may still draw (reserved at lease/fork time)
    reserved: usize,
}

/// A leased page table. Not `Clone`/`Copy`: exactly one live lease per
/// table, returned to the pool with [`KvPool::release`].
///
/// **Drop-safe**: a lease dropped without an explicit `release` (an
/// early-exit error path, a cancelled future, a panicking caller)
/// records its id in the owning pool's graveyard; the next
/// [`KvPool::reap`] - called by every `lease_rows`/`fork_rows` and by
/// the scheduler each tick - returns its pages and reservation to the
/// pool. No exit path can leak pages.
#[derive(Debug)]
pub struct KvLease {
    id: usize,
    graveyard: Arc<Mutex<Vec<usize>>>,
    released: bool,
}

impl KvLease {
    /// Table index (diagnostics / tests).
    pub fn id(&self) -> usize {
        self.id
    }
}

impl Drop for KvLease {
    fn drop(&mut self) {
        if !self.released {
            if let Ok(mut g) = self.graveyard.lock() {
                g.push(self.id);
            }
        }
    }
}

/// Paged, refcounted KV pool. See the module docs for the page / COW
/// lifecycle and the reservation-based admission contract.
pub struct KvPool {
    pub(crate) dim: usize,
    pub(crate) max_ctx: usize,
    n_layers: usize,
    page_rows: usize,
    /// elements per page in each of `k`/`v`: n_layers * page_rows * dim
    page_elems: usize,
    /// page storage format (F32 unless opted into low-bit)
    format: KvFormat,
    /// packed u32 words per page per slab: page_elems / vals_per_word
    /// (0 for F32)
    page_words: usize,
    /// scale/zero f32s per page per slab: n_layers * page_rows * 2
    /// (0 for F32)
    page_sz: usize,
    /// post-RoPE keys, `n_pages * page_elems` (empty for packed formats)
    k: Vec<f32>,
    /// values, `n_pages * page_elems` (empty for packed formats)
    v: Vec<f32>,
    /// packed keys, `n_pages * page_words` (empty for F32)
    kq: Vec<u32>,
    /// packed values, `n_pages * page_words` (empty for F32)
    vq: Vec<u32>,
    /// per-row key `[scale, zero]` pairs, `n_pages * page_sz`
    ksz: Vec<f32>,
    /// per-row value `[scale, zero]` pairs, `n_pages * page_sz`
    vsz: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<u32>,
    seqs: Vec<SeqState>,
    free_seqs: Vec<usize>,
    /// sum of undrawn `SeqState::reserved` across live leases
    total_reserved: usize,
    bytes_copied: u64,
    peak_pages: usize,
    /// ids of leases dropped without release, pending [`KvPool::reap`]
    graveyard: Arc<Mutex<Vec<usize>>>,
    /// cross-request prefix cache (None until
    /// [`KvPool::enable_prefix_cache`])
    cache: Option<PrefixCache>,
}

impl KvPool {
    /// Pool holding `n_slots` full sequences' worth of pages (the
    /// slab-era sizing convention: capacity for `n_slots` concurrent
    /// `max_ctx`-row sequences, default page size).
    pub fn new(n_layers: usize, dim: usize, max_ctx: usize,
               n_slots: usize) -> KvPool {
        let page_rows = DEFAULT_PAGE_ROWS.min(max_ctx.max(1));
        let per_seq = pages_for(max_ctx.max(1), page_rows);
        KvPool::with_page_rows(n_layers, dim, max_ctx, n_slots * per_seq,
                               page_rows)
    }

    /// Pool with an explicit page geometry: `n_pages` pages of
    /// `page_rows` rows each (tests and benches shrink `page_rows` to
    /// exercise multi-page prefixes at tiny contexts).
    pub fn with_page_rows(n_layers: usize, dim: usize, max_ctx: usize,
                          n_pages: usize, page_rows: usize) -> KvPool {
        KvPool::with_format(n_layers, dim, max_ctx, n_pages, page_rows,
                            KvFormat::F32)
    }

    /// Pool with an explicit page geometry *and* storage format. Packed
    /// formats require `dim % 8 == 0` (the fused dequant kernels read 8
    /// values per step and per-head slices must be word-aligned).
    pub fn with_format(n_layers: usize, dim: usize, max_ctx: usize,
                       n_pages: usize, page_rows: usize,
                       format: KvFormat) -> KvPool {
        let page_rows = page_rows.max(1);
        let page_elems = n_layers * page_rows * dim;
        let packed = format.is_packed();
        assert!(!packed || dim % 8 == 0,
                "packed KV formats need dim % 8 == 0 (got {dim})");
        let page_words =
            if packed { page_elems / format.vals_per_word() } else { 0 };
        let page_sz = if packed { n_layers * page_rows * 2 } else { 0 };
        let fp_elems = if packed { 0 } else { n_pages * page_elems };
        KvPool {
            dim,
            max_ctx,
            n_layers,
            page_rows,
            page_elems,
            format,
            page_words,
            page_sz,
            k: vec![0f32; fp_elems],
            v: vec![0f32; fp_elems],
            kq: vec![0u32; n_pages * page_words],
            vq: vec![0u32; n_pages * page_words],
            ksz: vec![0f32; n_pages * page_sz],
            vsz: vec![0f32; n_pages * page_sz],
            refcount: vec![0; n_pages],
            // pop() takes from the back; reversed so page 0 leases first
            free: (0..n_pages as u32).rev().collect(),
            seqs: Vec::new(),
            free_seqs: Vec::new(),
            total_reserved: 0,
            bytes_copied: 0,
            peak_pages: 0,
            graveyard: Arc::new(Mutex::new(Vec::new())),
            cache: None,
        }
    }

    /// Pool shaped for `core` with capacity for `n_slots` full sequences.
    pub fn for_core(core: &ModelCore, n_slots: usize) -> KvPool {
        KvPool::new(core.n_layers(), core.dim, core.max_ctx, n_slots)
    }

    /// [`KvPool::for_core`] with an explicit storage format (same page
    /// count as the f32 pool - the capacity multiplier shows up as
    /// smaller [`KvPool::page_bytes`], or equivalently more pages at
    /// fixed pool bytes; the `kv_lowbit` bench sizes it the second way).
    pub fn for_core_fmt(core: &ModelCore, n_slots: usize,
                        format: KvFormat) -> KvPool {
        let (max_ctx, pr) = (core.max_ctx, DEFAULT_PAGE_ROWS.min(
            core.max_ctx.max(1)));
        let per_seq = pages_for(max_ctx.max(1), pr);
        KvPool::with_format(core.n_layers(), core.dim, max_ctx,
                            n_slots * per_seq, pr, format)
    }

    /// Pool shaped for `core` with an explicit page geometry.
    pub fn for_core_paged(core: &ModelCore, n_pages: usize,
                          page_rows: usize) -> KvPool {
        KvPool::with_page_rows(core.n_layers(), core.dim, core.max_ctx,
                               n_pages, page_rows)
    }

    /// [`KvPool::for_core_paged`] with an explicit storage format.
    pub fn for_core_paged_fmt(core: &ModelCore, n_pages: usize,
                              page_rows: usize, format: KvFormat)
                              -> KvPool {
        KvPool::with_format(core.n_layers(), core.dim, core.max_ctx,
                            n_pages, page_rows, format)
    }

    /// The page storage format.
    pub fn format(&self) -> KvFormat {
        self.format
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Total pages in the pool.
    pub fn n_pages(&self) -> usize {
        self.refcount.len()
    }

    /// Pages a full `max_ctx`-row sequence needs.
    pub fn pages_per_seq(&self) -> usize {
        pages_for(self.max_ctx.max(1), self.page_rows)
    }

    /// Full-sequence capacity (slab-era convention): how many `max_ctx`
    /// sequences fit with no sharing.
    pub fn capacity(&self) -> usize {
        self.n_pages() / self.pages_per_seq()
    }

    /// Pages neither allocated nor promised to a live lease - what
    /// admission may spend.
    pub fn n_free_pages(&self) -> usize {
        self.free.len() - self.total_reserved
    }

    /// Pages currently backing at least one sequence.
    pub fn pages_in_use(&self) -> usize {
        self.n_pages() - self.free.len()
    }

    /// High-water mark of [`KvPool::pages_in_use`].
    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_pages
    }

    /// Total bytes ever copied by COW faults and [`KvPool::fork_copy`]
    /// (plain [`KvPool::fork`] contributes zero).
    pub fn bytes_copied(&self) -> u64 {
        self.bytes_copied
    }

    /// Bytes in one page (k + v, all layers; packed formats count the
    /// packed words plus the per-row scale/zero pairs) - the COW copy
    /// upper bound and the unit of pool-capacity accounting.
    pub fn page_bytes(&self) -> u64 {
        if self.format.is_packed() {
            2 * (self.page_words + self.page_sz) as u64 * 4
        } else {
            2 * self.page_elems as u64 * 4
        }
    }

    /// Pages a fresh `rows`-row lease must reserve.
    fn pages_needed(&self, rows: usize) -> usize {
        pages_for(rows.min(self.max_ctx).max(1), self.page_rows)
    }

    /// Would [`KvPool::lease_rows`]`(rows)` succeed right now?
    pub fn can_admit(&self, rows: usize) -> bool {
        self.pages_needed(rows) <= self.n_free_pages()
    }

    /// Lease a page table for a sequence that will write at most `rows`
    /// rows (capped at `max_ctx`). Reserves the covering pages so later
    /// allocation cannot fail; `None` when the pool cannot promise them
    /// (callers queue - nothing panics on a full pool).
    pub fn lease_rows(&mut self, rows: usize) -> Option<KvLease> {
        self.reap();
        let need = self.pages_needed(rows);
        if need > self.n_free_pages() {
            self.reclaim_for(need);
        }
        if need > self.n_free_pages() {
            return None;
        }
        let id = match self.free_seqs.pop() {
            Some(id) => id,
            None => {
                self.seqs.push(SeqState { pages: Vec::new(), reserved: 0 });
                self.seqs.len() - 1
            }
        };
        self.seqs[id].reserved = need;
        self.total_reserved += need;
        Some(self.make_lease(id))
    }

    fn make_lease(&self, id: usize) -> KvLease {
        KvLease {
            id,
            graveyard: Arc::clone(&self.graveyard),
            released: false,
        }
    }

    /// Lease with the full `max_ctx` row budget (the slab-era `lease`:
    /// engines and anything that may decode to the context limit).
    pub fn lease(&mut self) -> Option<KvLease> {
        self.lease_rows(self.max_ctx)
    }

    /// Return a table to the pool: refcounts drop, pages reaching zero
    /// go back to the free list (rows are left as-is - the next owner
    /// overwrites from its own position 0 before anything reads them),
    /// and the unspent reservation is cancelled.
    pub fn release(&mut self, mut lease: KvLease) {
        lease.released = true;
        self.release_id(lease.id);
    }

    /// [`KvPool::release`] by table id (shared with [`KvPool::reap`]).
    fn release_id(&mut self, id: usize) {
        let pages = std::mem::take(&mut self.seqs[id].pages);
        let reserved = self.seqs[id].reserved;
        self.seqs[id].reserved = 0;
        self.total_reserved -= reserved;
        for p in pages {
            let r = &mut self.refcount[p as usize];
            debug_assert!(*r > 0, "releasing an unowned page");
            *r -= 1;
            if *r == 0 {
                self.free.push(p);
            }
        }
        self.free_seqs.push(id);
    }

    /// Release every lease that was dropped without [`KvPool::release`]
    /// (see [`KvLease`]'s drop-safety contract); returns how many were
    /// reclaimed. Admission paths call this implicitly, so a leaked
    /// lease can delay reuse by at most one allocation attempt.
    pub fn reap(&mut self) -> usize {
        let dead: Vec<usize> = {
            let mut g = self.graveyard.lock().expect("graveyard poisoned");
            std::mem::take(&mut *g)
        };
        let n = dead.len();
        for id in dead {
            self.release_id(id);
        }
        n
    }

    /// Zero-copy fork for a child that will write at most `rows` more
    /// rows from `pos`: the parent's pages covering `[0, pos)` are shared
    /// by refcount (nothing is copied now; the first write to the shared
    /// partial tail page COW-copies at most one page). `None` when the
    /// child's page budget cannot be reserved.
    pub fn fork_rows(&mut self, parent: &KvLease, pos: usize,
                     rows: usize) -> Option<KvLease> {
        self.reap();
        let pr = self.page_rows;
        let pos = pos.min(self.max_ctx);
        let shared = pages_for(pos, pr);
        if shared > self.seqs[parent.id].pages.len() {
            // forking past the parent's filled rows is a caller bug, but
            // fail like every other fork failure instead of panicking
            debug_assert!(false, "fork past the parent's filled rows");
            return None;
        }
        let end = (pos + rows).min(self.max_ctx);
        // fresh draws the child may need: a COW of the tail page plus
        // every page past it, i.e. pages [pos/pr, ceil(end/pr))
        let need = if end > pos { pages_for(end, pr) - pos / pr } else { 0 };
        if need > self.n_free_pages() {
            self.reclaim_for(need);
        }
        if need > self.n_free_pages() {
            return None;
        }
        let id = match self.free_seqs.pop() {
            Some(id) => id,
            None => {
                self.seqs.push(SeqState { pages: Vec::new(), reserved: 0 });
                self.seqs.len() - 1
            }
        };
        let table: Vec<u32> =
            self.seqs[parent.id].pages[..shared].to_vec();
        for &p in &table {
            self.refcount[p as usize] += 1;
        }
        self.seqs[id].pages = table;
        self.seqs[id].reserved = need;
        self.total_reserved += need;
        Some(self.make_lease(id))
    }

    /// [`KvPool::fork_rows`] with the full remaining-context budget (the
    /// general candidate-scoring fork).
    pub fn fork(&mut self, parent: &KvLease, pos: usize)
                -> Option<KvLease> {
        self.fork_rows(parent, pos, self.max_ctx - pos.min(self.max_ctx))
    }

    /// Deep-copy fork: lease a fresh full-budget table and copy the
    /// parent's first `pos` rows into private pages. This is the slab-era
    /// fork semantics, kept as the reference point the `kv_fork` bench
    /// and the COW tests compare against.
    pub fn fork_copy(&mut self, parent: &KvLease, pos: usize)
                     -> Option<KvLease> {
        let child = self.lease()?;
        let pos = pos.min(self.max_ctx);
        if pos == 0 {
            return Some(child);
        }
        if self.prepare_rows(&child, 0, pos).is_err() {
            self.release(child);
            return None;
        }
        let pr = self.page_rows;
        for pi in 0..pages_for(pos, pr) {
            let rows = pr.min(pos - pi * pr);
            let sp = self.seqs[parent.id].pages[pi] as usize;
            let dp = self.seqs[child.id].pages[pi] as usize;
            self.copy_page_rows(sp, dp, rows);
        }
        Some(child)
    }

    /// Copy the first `rows` rows of page `sp` into page `dp` (k + v,
    /// every layer, whatever the storage format) and count the copied
    /// bytes. Shared body of [`KvPool::fork_copy`] and the COW fault in
    /// [`KvPool::prepare_rows`].
    fn copy_page_rows(&mut self, sp: usize, dp: usize, rows: usize) {
        let (pr, d) = (self.page_rows, self.dim);
        if self.format.is_packed() {
            let rw = d / self.format.vals_per_word();
            for l in 0..self.n_layers {
                let so = sp * self.page_words + l * pr * rw;
                let doff = dp * self.page_words + l * pr * rw;
                let len = rows * rw;
                self.kq.copy_within(so..so + len, doff);
                self.vq.copy_within(so..so + len, doff);
                let sso = sp * self.page_sz + l * pr * 2;
                let sdo = dp * self.page_sz + l * pr * 2;
                self.ksz.copy_within(sso..sso + rows * 2, sdo);
                self.vsz.copy_within(sso..sso + rows * 2, sdo);
            }
            self.bytes_copied +=
                2 * (self.n_layers * rows) as u64 * (rw as u64 * 4 + 8);
        } else {
            for l in 0..self.n_layers {
                let so = sp * self.page_elems + l * pr * d;
                let doff = dp * self.page_elems + l * pr * d;
                let len = rows * d;
                self.k.copy_within(so..so + len, doff);
                self.v.copy_within(so..so + len, doff);
            }
            self.bytes_copied += 2 * (self.n_layers * rows * d) as u64 * 4;
        }
    }

    /// Pages currently in `lease`'s table (diagnostics / tests).
    pub fn seq_pages(&self, lease: &KvLease) -> usize {
        self.seqs[lease.id].pages.len()
    }

    /// Turn on the cross-request prefix cache (idempotent). Off by
    /// default: with it off, `lease_rows_cached` degrades to
    /// [`KvPool::lease_rows`] and `cache_insert` is a no-op.
    pub fn enable_prefix_cache(&mut self) {
        if self.cache.is_none() {
            self.cache = Some(PrefixCache::new(self.page_rows));
        }
    }

    /// Is the prefix cache enabled?
    pub fn cache_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Pages currently held by the prefix cache.
    pub fn cached_pages(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.n_pages())
    }

    /// Cache pages evicted under reservation pressure so far.
    pub fn cache_evictions(&self) -> u64 {
        self.cache.as_ref().map_or(0, |c| c.evictions())
    }

    /// Rows in `key`'s longest cached page-aligned prefix, without
    /// leasing anything, bumping refcounts, or stamping LRU recency
    /// (see [`PrefixCache::probe`]). 0 with the cache off. The
    /// scheduler's cache-aware admission ordering classifies queued
    /// candidates with this before any lease call.
    pub fn cache_probe_rows(&self, key: &[i32]) -> usize {
        self.cache.as_ref().map_or(0, |c| c.probe(key) * self.page_rows)
    }

    /// Record `lease`'s KV for `tokens` in the prefix cache: every page
    /// wholly covered by `tokens` is referenced by the trie (refcount
    /// bump, zero bytes copied). Call on retirement, *before* releasing
    /// the lease. All-or-nothing: the `cache.insert` failpoint fires
    /// before any bookkeeping changes, so a faulted insert leaves no
    /// partial entry and the caller releases the lease normally.
    pub fn cache_insert(&mut self, tokens: &[i32], lease: &KvLease)
                        -> Result<usize> {
        if self.cache.is_none() {
            return Ok(0);
        }
        failpoint::check("cache.insert")?;
        let full = tokens.len() / self.page_rows;
        let n = full.min(self.seqs[lease.id].pages.len());
        if n == 0 {
            return Ok(0);
        }
        let mut cache = self.cache.take().expect("checked above");
        let added = cache.insert(&tokens[..n * self.page_rows],
                                 &self.seqs[lease.id].pages[..n],
                                 &mut self.refcount);
        self.cache = Some(cache);
        Ok(added)
    }

    /// [`KvPool::lease_rows`] with a prefix-cache lookup: the longest
    /// cached page-aligned prefix of `key` is shared into the new table
    /// by refcount (zero bytes, zero prefill compute for those rows) and
    /// the reservation covers only the pages *past* the match - the
    /// admission right-sizing that lets hits through pressure that
    /// queues cold requests. Returns `(lease, matched_rows)`; a cold
    /// pool or disabled cache yields `matched_rows == 0`.
    pub fn lease_rows_cached(&mut self, key: &[i32], rows: usize)
                             -> Option<(KvLease, usize)> {
        self.reap();
        let hit = match self.cache.as_mut() {
            None => Vec::new(),
            Some(c) => c.lookup(key),
        };
        // pin the hit pages before any reclaim can run, so eviction
        // pressure from our own reservation cannot free them
        for &p in &hit {
            self.refcount[p as usize] += 1;
        }
        let matched = hit.len();
        let need = self.pages_needed(rows).saturating_sub(matched);
        if need > self.n_free_pages() {
            self.reclaim_for(need);
        }
        if need > self.n_free_pages() {
            // roll back the pins; the cache still holds one ref on each
            // hit page, so none of these can reach zero
            for &p in &hit {
                self.refcount[p as usize] -= 1;
            }
            return None;
        }
        let matched_rows = matched * self.page_rows;
        let id = match self.free_seqs.pop() {
            Some(id) => id,
            None => {
                self.seqs.push(SeqState { pages: Vec::new(), reserved: 0 });
                self.seqs.len() - 1
            }
        };
        self.seqs[id].pages = hit;
        self.seqs[id].reserved = need;
        self.total_reserved += need;
        Some((self.make_lease(id), matched_rows))
    }

    /// Drop every cache reference (pages pinned by live leases survive;
    /// the rest return to the free list). Returns how many cache refs
    /// were released. Drain-time leak checks flush first, then assert
    /// `pages_in_use() == 0`.
    pub fn cache_flush(&mut self) -> usize {
        let Some(mut cache) = self.cache.take() else { return 0 };
        let n = cache.flush(&mut self.refcount, &mut self.free);
        self.cache = Some(cache);
        n
    }

    /// Evict cold cache-only pages (LRU, refcount == 1) until `need`
    /// pages are free beyond reservations or nothing is evictable.
    fn reclaim_for(&mut self, need: usize) {
        let Some(mut cache) = self.cache.take() else { return };
        while need > self.free.len() - self.total_reserved
            && cache.evict_one(&mut self.refcount, &mut self.free)
        {}
        self.cache = Some(cache);
    }

    /// Draw one fresh page for `id`, preferring its reservation and
    /// falling back to unreserved spare pages (a parent COW-ing a page it
    /// already drew once, after forking). Errors only when the pool is
    /// truly out of pages - impossible for writes within a lease's
    /// declared row budget.
    fn draw(&mut self, id: usize) -> Result<u32> {
        // fault-injection site: simulate an allocation failure before
        // any accounting changes, so an injected error leaves the
        // reservation intact and release() stays consistent
        failpoint::check("kv.draw")?;
        if self.seqs[id].reserved > 0 {
            self.seqs[id].reserved -= 1;
            self.total_reserved -= 1;
        } else {
            if self.free.len() <= self.total_reserved {
                // an unreserved spare draw may reclaim cold cache pages
                self.reclaim_for(1);
            }
            if self.free.len() <= self.total_reserved {
                bail!("KV page pool exhausted ({} pages, all reserved)",
                      self.n_pages());
            }
        }
        let p = self.free.pop().expect("free list >= reservations");
        self.refcount[p as usize] = 1;
        let in_use = self.n_pages() - self.free.len();
        if in_use > self.peak_pages {
            self.peak_pages = in_use;
        }
        Ok(p)
    }

    /// Make rows `[pos, pos + n)` privately writable: append fresh pages
    /// past the table end and COW-copy the shared prefix rows of a
    /// partial tail page. Called once per forward call before any
    /// row write; after it, `k_row_mut`/`v_row_mut`/`scatter_*` are plain
    /// indexing.
    pub(crate) fn prepare_rows(&mut self, lease: &KvLease, pos: usize,
                               n: usize) -> Result<()> {
        if n == 0 {
            return Ok(());
        }
        if pos + n > self.max_ctx {
            bail!("KV write [{pos}, {}) overflows max_ctx {}", pos + n,
                  self.max_ctx);
        }
        let pr = self.page_rows;
        let first = pos / pr;
        let last = (pos + n - 1) / pr;
        if first > self.seqs[lease.id].pages.len() {
            bail!("KV write at row {pos} leaves a page gap");
        }
        for pi in first..=last {
            if pi == self.seqs[lease.id].pages.len() {
                let p = self.draw(lease.id)?;
                self.seqs[lease.id].pages.push(p);
                continue;
            }
            let p = self.seqs[lease.id].pages[pi] as usize;
            if self.refcount[p] == 1 {
                continue;
            }
            // shared page: copy the rows below `pos` that must survive
            // (only the first written page can have any), then go private
            let np = self.draw(lease.id)? as usize;
            let row_off = pos.saturating_sub(pi * pr).min(pr);
            if row_off > 0 {
                self.copy_page_rows(p, np, row_off);
            }
            self.refcount[p] -= 1;
            debug_assert!(self.refcount[p] > 0);
            self.seqs[lease.id].pages[pi] = np as u32;
        }
        Ok(())
    }

    #[inline]
    fn row_base(&self, lease: &KvLease, layer: usize, pos: usize)
                -> usize {
        let pr = self.page_rows;
        let page = self.seqs[lease.id].pages[pos / pr] as usize;
        page * self.page_elems + layer * pr * self.dim
            + (pos % pr) * self.dim
    }

    /// [`KvPool::row_base`] for a *write*: asserts the page is privately
    /// owned (a shared-page write means a missing `prepare_rows`).
    #[inline]
    fn row_base_mut(&self, lease: &KvLease, layer: usize, pos: usize)
                    -> usize {
        debug_assert_eq!(
            self.refcount
                [self.seqs[lease.id].pages[pos / self.page_rows] as usize],
            1,
            "write to a shared page (missing prepare_rows)"
        );
        self.row_base(lease, layer, pos)
    }

    /// Packed-row bases for `pos`: (index into `kq`/`vq` in words,
    /// index into `ksz`/`vsz`). Packed-format pools only.
    #[inline]
    fn row_q_base(&self, lease: &KvLease, layer: usize, pos: usize)
                  -> (usize, usize) {
        let pr = self.page_rows;
        let page = self.seqs[lease.id].pages[pos / pr] as usize;
        let r = layer * pr + pos % pr;
        let rw = self.dim / self.format.vals_per_word();
        (page * self.page_words + r * rw, page * self.page_sz + r * 2)
    }

    /// Write one row in the pool's storage format: a plain copy for
    /// f32, quantize-on-write for packed formats. Requires a prior
    /// [`KvPool::prepare_rows`] covering `pos` (shared body of
    /// `put_k_row`/`put_v_row`).
    fn put_row(&mut self, into_k: bool, lease: &KvLease, layer: usize,
               pos: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        debug_assert_eq!(
            self.refcount
                [self.seqs[lease.id].pages[pos / self.page_rows] as usize],
            1,
            "write to a shared page (missing prepare_rows)"
        );
        if !self.format.is_packed() {
            let b = self.row_base(lease, layer, pos);
            let dst = if into_k { &mut self.k } else { &mut self.v };
            dst[b..b + row.len()].copy_from_slice(row);
            return;
        }
        let f = self.format;
        let (wb, sb) = self.row_q_base(lease, layer, pos);
        let rw = self.dim / f.vals_per_word();
        let (dst, sz) = if into_k {
            (&mut self.kq, &mut self.ksz)
        } else {
            (&mut self.vq, &mut self.vsz)
        };
        let (s, z) = quant_row(row, f.qmax(), f.bits(), f.vals_per_word(),
                               &mut dst[wb..wb + rw]);
        sz[sb] = s;
        sz[sb + 1] = z;
    }

    /// Write one key row in the pool's storage format (see
    /// [`KvPool::put_row`]'s contract).
    pub(crate) fn put_k_row(&mut self, lease: &KvLease, layer: usize,
                            pos: usize, row: &[f32]) {
        self.put_row(true, lease, layer, pos, row);
    }

    /// Write one value row in the pool's storage format.
    pub(crate) fn put_v_row(&mut self, lease: &KvLease, layer: usize,
                            pos: usize, row: &[f32]) {
        self.put_row(false, lease, layer, pos, row);
    }

    /// Dequantize one stored row back to f32 (tests and accuracy
    /// probes; the hot path reads packed segments directly). F32 pools
    /// return the stored row verbatim.
    pub fn dequant_row(&self, into_k: bool, lease: &KvLease,
                       layer: usize, pos: usize) -> Vec<f32> {
        if !self.format.is_packed() {
            let b = self.row_base(lease, layer, pos);
            let src = if into_k { &self.k } else { &self.v };
            return src[b..b + self.dim].to_vec();
        }
        let f = self.format;
        let (vpw, bits) = (f.vals_per_word(), f.bits());
        let (wb, sb) = self.row_q_base(lease, layer, pos);
        let (src, sz) = if into_k {
            (&self.kq, &self.ksz)
        } else {
            (&self.vq, &self.vsz)
        };
        let (s, z) = (sz[sb], sz[sb + 1]);
        let mask = (1u32 << bits) - 1;
        (0..self.dim)
            .map(|i| {
                let q = (src[wb + i / vpw] >> (bits * (i % vpw) as u32))
                    & mask;
                q as f32 * s + z
            })
            .collect()
    }

    /// One key row, writable. Requires a prior
    /// [`KvPool::prepare_rows`] covering `pos`. F32 pools only (packed
    /// formats write through [`KvPool::put_k_row`]).
    #[inline]
    pub(crate) fn k_row_mut(&mut self, lease: &KvLease, layer: usize,
                            pos: usize) -> &mut [f32] {
        let b = self.row_base_mut(lease, layer, pos);
        &mut self.k[b..b + self.dim]
    }

    /// One value row, writable (same contract as [`KvPool::k_row_mut`]).
    #[inline]
    pub(crate) fn v_row_mut(&mut self, lease: &KvLease, layer: usize,
                            pos: usize) -> &mut [f32] {
        let b = self.row_base_mut(lease, layer, pos);
        &mut self.v[b..b + self.dim]
    }

    /// One key row, read-only (debug/tests).
    pub fn k_row(&self, lease: &KvLease, layer: usize, pos: usize)
                 -> &[f32] {
        let b = self.row_base(lease, layer, pos);
        &self.k[b..b + self.dim]
    }

    /// One value row, read-only (debug/tests).
    pub fn v_row(&self, lease: &KvLease, layer: usize, pos: usize)
                 -> &[f32] {
        let b = self.row_base(lease, layer, pos);
        &self.v[b..b + self.dim]
    }

    /// The contiguous segment starting at `row0`: rows of the page
    /// containing `row0`, clipped to `max_rows`. Returns (segment base,
    /// rows); one body serves both the `k` and `v` slabs so the
    /// page-walk arithmetic can never diverge between them.
    #[inline]
    fn seg(&self, lease: &KvLease, layer: usize, row0: usize,
           max_rows: usize) -> (usize, usize) {
        let rows = (self.page_rows - row0 % self.page_rows).min(max_rows);
        (self.row_base(lease, layer, row0), rows)
    }

    /// The contiguous key segment starting at `row0` (rows * dim slice,
    /// rows). Attention walks segments in ascending row order, which
    /// replicates a contiguous cache's exact FMA sequence.
    #[inline]
    pub(crate) fn k_seg(&self, lease: &KvLease, layer: usize, row0: usize,
                        max_rows: usize) -> (&[f32], usize) {
        let (b, rows) = self.seg(lease, layer, row0, max_rows);
        (&self.k[b..b + rows * self.dim], rows)
    }

    /// The contiguous value segment starting at `row0` (see
    /// [`KvPool::k_seg`]).
    #[inline]
    pub(crate) fn v_seg(&self, lease: &KvLease, layer: usize, row0: usize,
                        max_rows: usize) -> (&[f32], usize) {
        let (b, rows) = self.seg(lease, layer, row0, max_rows);
        (&self.v[b..b + rows * self.dim], rows)
    }

    /// Packed-segment bases starting at `row0`: (word base, scale/zero
    /// base, rows). One body serves both slabs, like [`KvPool::seg`].
    #[inline]
    fn seg_q(&self, lease: &KvLease, layer: usize, row0: usize,
             max_rows: usize) -> (usize, usize, usize) {
        debug_assert!(self.format.is_packed());
        let pr = self.page_rows;
        let rows = (pr - row0 % pr).min(max_rows);
        let page = self.seqs[lease.id].pages[row0 / pr] as usize;
        let r = layer * pr + row0 % pr;
        let rw = self.dim / self.format.vals_per_word();
        (page * self.page_words + r * rw, page * self.page_sz + r * 2,
         rows)
    }

    /// The contiguous *packed* key segment starting at `row0`: (packed
    /// words, per-row `[scale, zero]` pairs, rows). Packed-format pools
    /// only; attention walks these exactly like [`KvPool::k_seg`].
    #[inline]
    pub(crate) fn k_seg_q(&self, lease: &KvLease, layer: usize,
                          row0: usize, max_rows: usize)
                          -> (&[u32], &[f32], usize) {
        let (wb, sb, rows) = self.seg_q(lease, layer, row0, max_rows);
        let rw = self.dim / self.format.vals_per_word();
        (&self.kq[wb..wb + rows * rw], &self.ksz[sb..sb + rows * 2], rows)
    }

    /// The contiguous *packed* value segment starting at `row0` (see
    /// [`KvPool::k_seg_q`]).
    #[inline]
    pub(crate) fn v_seg_q(&self, lease: &KvLease, layer: usize,
                          row0: usize, max_rows: usize)
                          -> (&[u32], &[f32], usize) {
        let (wb, sb, rows) = self.seg_q(lease, layer, row0, max_rows);
        let rw = self.dim / self.format.vals_per_word();
        (&self.vq[wb..wb + rows * rw], &self.vsz[sb..sb + rows * 2], rows)
    }

    /// Scatter `rows` (row-major, n * dim) into rows `[pos, pos + n)` of
    /// one slab, page by page (shared body of `scatter_k`/`scatter_v`).
    /// Requires a prior [`KvPool::prepare_rows`] covering the range.
    fn scatter(&mut self, into_k: bool, lease: &KvLease, layer: usize,
               pos: usize, rows: &[f32]) {
        let d = self.dim;
        let n = rows.len() / d;
        if self.format.is_packed() {
            // packed formats quantize row by row (scalar writer; see
            // the module docs' determinism note)
            for i in 0..n {
                self.put_row(into_k, lease, layer, pos + i,
                             &rows[i * d..(i + 1) * d]);
            }
            return;
        }
        let mut done = 0usize;
        while done < n {
            let (b, take) = self.seg(lease, layer, pos + done, n - done);
            debug_assert_eq!(
                self.refcount[self.seqs[lease.id].pages
                    [(pos + done) / self.page_rows] as usize],
                1,
                "scatter into a shared page (missing prepare_rows)"
            );
            let dst = if into_k { &mut self.k } else { &mut self.v };
            dst[b..b + take * d]
                .copy_from_slice(&rows[done * d..(done + take) * d]);
            done += take;
        }
    }

    /// Scatter into key rows (see [`KvPool::scatter`]).
    pub(crate) fn scatter_k(&mut self, lease: &KvLease, layer: usize,
                            pos: usize, rows: &[f32]) {
        self.scatter(true, lease, layer, pos, rows);
    }

    /// Scatter into value rows (see [`KvPool::scatter`]).
    pub(crate) fn scatter_v(&mut self, lease: &KvLease, layer: usize,
                            pos: usize, rows: &[f32]) {
        self.scatter(false, lease, layer, pos, rows);
    }
}

/// Pages covering `rows` rows (ceil division; 0 rows -> 0 pages).
fn pages_for(rows: usize, page_rows: usize) -> usize {
    (rows + page_rows - 1) / page_rows
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: usize = 4;
    const L: usize = 2;

    /// 1-layer-like tiny pool: L layers, D dim, tiny pages.
    fn pool(n_pages: usize, page_rows: usize, max_ctx: usize) -> KvPool {
        KvPool::with_page_rows(L, D, max_ctx, n_pages, page_rows)
    }

    fn fill_row(p: &mut KvPool, l: &KvLease, layer: usize, pos: usize,
                tag: f32) {
        for (i, x) in p.k_row_mut(l, layer, pos).iter_mut().enumerate() {
            *x = tag + i as f32;
        }
        for (i, x) in p.v_row_mut(l, layer, pos).iter_mut().enumerate() {
            *x = -(tag + i as f32);
        }
    }

    fn row_tag(p: &KvPool, l: &KvLease, layer: usize, pos: usize) -> f32 {
        p.k_row(l, layer, pos)[0]
    }

    #[test]
    fn refcount_lifecycle_child_pages_survive_parent_release() {
        let mut p = pool(6, 4, 16);
        let parent = p.lease_rows(10).unwrap();
        p.prepare_rows(&parent, 0, 10).unwrap();
        for pos in 0..10 {
            for layer in 0..L {
                fill_row(&mut p, &parent, layer, pos, (pos * 100) as f32);
            }
        }
        assert_eq!(p.seq_pages(&parent), 3);
        assert_eq!(p.pages_in_use(), 3);

        // fork shares all three covering pages, copies nothing
        let b0 = p.bytes_copied();
        let child = p.fork_rows(&parent, 10, 4).unwrap();
        assert_eq!(p.bytes_copied(), b0, "fork must copy zero bytes");
        assert_eq!(p.seq_pages(&child), 3);
        assert_eq!(p.pages_in_use(), 3, "fork must not allocate pages");

        // parent gone: shared pages must survive for the child
        p.release(parent);
        assert_eq!(p.pages_in_use(), 3);
        for pos in 0..10 {
            assert_eq!(row_tag(&p, &child, 0, pos), (pos * 100) as f32,
                       "row {pos} lost after parent release");
        }
        p.release(child);
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.n_free_pages(), 6);
    }

    #[test]
    fn cow_isolates_child_writes_from_parent_rows() {
        let mut p = pool(6, 4, 16);
        let parent = p.lease_rows(16).unwrap();
        p.prepare_rows(&parent, 0, 6).unwrap();
        for pos in 0..6 {
            for layer in 0..L {
                fill_row(&mut p, &parent, layer, pos, (pos * 10) as f32);
            }
        }
        // fork mid-page (6 % 4 = 2 rows into page 1)
        let child = p.fork_rows(&parent, 6, 4).unwrap();
        let b0 = p.bytes_copied();
        p.prepare_rows(&child, 6, 2).unwrap();
        // COW copied exactly the 2 surviving tail-page rows, k+v, L layers
        let expect = 2 * (L * 2 * D) as u64 * 4;
        assert_eq!(p.bytes_copied() - b0, expect);
        assert!(p.bytes_copied() - b0 <= p.page_bytes(),
                "COW exceeded one page");
        for pos in 6..8 {
            for layer in 0..L {
                fill_row(&mut p, &child, layer, pos, 9000.0);
            }
        }
        // child writes must not leak into the parent's page
        let parent_next = p.prepare_rows(&parent, 6, 1);
        parent_next.unwrap();
        for pos in 0..6 {
            assert_eq!(row_tag(&p, &parent, 0, pos), (pos * 10) as f32);
            assert_eq!(row_tag(&p, &child, 0, pos), (pos * 10) as f32,
                       "shared prefix diverged");
        }
        assert_eq!(row_tag(&p, &child, 0, 6), 9000.0);
        p.release(parent);
        p.release(child);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn reservation_gates_admission_by_pages() {
        let mut p = pool(4, 4, 16); // 16 rows = 4 pages per full seq
        assert_eq!(p.pages_per_seq(), 4);
        assert_eq!(p.capacity(), 1);
        assert!(p.can_admit(16));
        let a = p.lease().unwrap(); // reserves all 4 pages
        assert_eq!(p.n_free_pages(), 0);
        assert!(!p.can_admit(1));
        assert!(p.lease_rows(1).is_none(), "over-committed lease granted");
        // nothing allocated yet - reservation alone gates admission
        assert_eq!(p.pages_in_use(), 0);
        p.release(a);
        assert_eq!(p.n_free_pages(), 4);
        // short leases pack: four 3-row sequences fit where one max_ctx
        // sequence would
        let ls: Vec<KvLease> =
            (0..4).map(|_| p.lease_rows(3).unwrap()).collect();
        assert!(p.lease_rows(1).is_none());
        for l in ls {
            p.release(l);
        }
        assert_eq!(p.n_free_pages(), 4);
    }

    #[test]
    fn fork_on_exhausted_pool_returns_none() {
        let mut p = pool(3, 4, 12);
        let parent = p.lease().unwrap(); // reserves all 3 pages
        p.prepare_rows(&parent, 0, 6).unwrap();
        // a fork that could write needs a fresh page; none are spare
        assert!(p.fork_rows(&parent, 6, 4).is_none());
        // a read-only fork (zero new rows) needs none and succeeds
        let ro = p.fork_rows(&parent, 6, 0).unwrap();
        assert_eq!(p.seq_pages(&ro), 2);
        p.release(ro);
        p.release(parent);
    }

    #[test]
    fn fork_copy_duplicates_rows_and_counts_bytes() {
        let mut p = pool(8, 4, 16);
        let parent = p.lease_rows(6).unwrap();
        p.prepare_rows(&parent, 0, 6).unwrap();
        for pos in 0..6 {
            for layer in 0..L {
                fill_row(&mut p, &parent, layer, pos, (pos * 7) as f32);
            }
        }
        let b0 = p.bytes_copied();
        let child = p.fork_copy(&parent, 6).unwrap();
        assert_eq!(p.bytes_copied() - b0, 2 * (L * 6 * D) as u64 * 4);
        // private pages, identical contents
        for pos in 0..6 {
            assert_eq!(row_tag(&p, &child, 0, pos), (pos * 7) as f32);
        }
        // deep copy allocates its own pages
        assert_eq!(p.pages_in_use(), 4);
        p.release(parent);
        p.release(child);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn page_gap_and_overflow_are_rejected() {
        let mut p = pool(4, 4, 16);
        let l = p.lease().unwrap();
        assert!(p.prepare_rows(&l, 8, 1).is_err(), "gap accepted");
        assert!(p.prepare_rows(&l, 14, 4).is_err(), "overflow accepted");
        assert!(p.prepare_rows(&l, 0, 0).is_ok());
        p.release(l);
    }

    #[test]
    fn dropped_lease_is_reaped_not_leaked() {
        let mut p = pool(4, 4, 16);
        let l = p.lease_rows(8).unwrap();
        p.prepare_rows(&l, 0, 8).unwrap();
        assert_eq!(p.pages_in_use(), 2);
        drop(l); // early-exit path: no release
        // drop alone only records the leak; accounting is unchanged
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.reap(), 1);
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.n_free_pages(), 4);
        assert_eq!(p.reap(), 0, "reap must be idempotent");
    }

    #[test]
    fn admission_reaps_dropped_reservations() {
        let mut p = pool(4, 4, 16);
        // reserves the whole pool, then leaks
        drop(p.lease().unwrap());
        // a fresh full-pool lease still succeeds: lease_rows reaps first
        let l = p.lease().expect("dropped reservation blocked admission");
        p.release(l);
        assert_eq!(p.n_free_pages(), 4);
    }

    #[test]
    fn injected_draw_fault_leaves_pool_consistent() {
        use crate::util::failpoint;
        let mut p = pool(4, 4, 16);
        let l = p.lease_rows(8).unwrap();
        let err = failpoint::with(9, &[("kv.draw", 1.0)], || {
            p.prepare_rows(&l, 0, 8)
        });
        assert!(err.is_err(), "armed kv.draw must fail the write");
        // the failed write drew nothing and kept the reservation, so
        // releasing restores the pool exactly
        p.release(l);
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.n_free_pages(), 4);
        // disarmed again: the same sequence succeeds
        let l = p.lease_rows(8).unwrap();
        p.prepare_rows(&l, 0, 8).unwrap();
        p.release(l);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn lease_ids_recycle_without_stale_tables() {
        let mut p = pool(4, 2, 8);
        let a = p.lease_rows(4).unwrap();
        p.prepare_rows(&a, 0, 4).unwrap();
        assert_eq!(p.seq_pages(&a), 2);
        let aid = a.id();
        p.release(a);
        let b = p.lease_rows(2).unwrap();
        assert_eq!(b.id(), aid, "table id not recycled");
        assert_eq!(p.seq_pages(&b), 0, "stale page table leaked");
        p.release(b);
    }

    #[test]
    fn cache_hit_shares_pages_and_rightsizes_reservation() {
        let mut p = pool(6, 2, 12);
        p.enable_prefix_cache();
        let a = p.lease_rows(6).unwrap();
        p.prepare_rows(&a, 0, 6).unwrap();
        for pos in 0..6 {
            for layer in 0..L {
                fill_row(&mut p, &a, layer, pos, (pos * 10) as f32);
            }
        }
        let toks: Vec<i32> = (0..6).collect();
        assert_eq!(p.cache_insert(&toks, &a).unwrap(), 3);
        p.release(a);
        // the cache retains the retired request's pages
        assert_eq!(p.pages_in_use(), 3);
        assert_eq!(p.cached_pages(), 3);

        // a 5-token key matches 2 full pages (4 rows at page_rows 2)
        let (b, matched) = p.lease_rows_cached(&toks[..5], 8).unwrap();
        assert_eq!(matched, 4);
        assert_eq!(p.seq_pages(&b), 2);
        // right-sizing: 8 rows need 4 pages, 2 are cached, so only 2
        // fresh pages are reserved out of the 3 free
        assert_eq!(p.n_free_pages(), 1);
        // shared rows are the retired request's bytes, verbatim
        for pos in 0..4 {
            assert_eq!(row_tag(&p, &b, 0, pos), (pos * 10) as f32);
        }
        // resuming the write past the page-aligned match point never
        // touches a shared page: zero COW bytes on the hit path
        let bc = p.bytes_copied();
        p.prepare_rows(&b, 4, 4).unwrap();
        assert_eq!(p.bytes_copied(), bc, "hit path must copy zero bytes");
        p.release(b);
        assert_eq!(p.cache_flush(), 3);
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.n_free_pages(), 6);
    }

    #[test]
    fn reservation_pressure_evicts_lru_cache_pages_only() {
        let mut p = pool(4, 2, 8);
        p.enable_prefix_cache();
        // two retired 4-row prompts fill the whole pool with cache pages
        let t1 = vec![1, 2, 3, 4];
        let t2 = vec![5, 6, 7, 8];
        for t in [&t1, &t2] {
            let l = p.lease_rows(4).unwrap();
            p.prepare_rows(&l, 0, 4).unwrap();
            assert_eq!(p.cache_insert(t, &l).unwrap(), 2);
            p.release(l);
        }
        assert_eq!(p.cached_pages(), 4);
        assert_eq!(p.n_free_pages(), 0);
        // touch t1 so t2 becomes the LRU entry; a full hit needs no
        // fresh pages so it admits on a zero-free pool
        let (h, m) = p.lease_rows_cached(&t1, 4).unwrap();
        assert_eq!(m, 4);
        p.release(h);
        // a cold lease needs 2 pages: exactly t2's (LRU) pages go
        let cold = p.lease_rows(4).expect("eviction must make room");
        assert_eq!(p.cache_evictions(), 2);
        assert_eq!(p.cached_pages(), 2);
        // the evicted prefix is now a clean miss...
        p.release(cold);
        let (h2, m2) = p.lease_rows_cached(&t2, 4).unwrap();
        assert_eq!(m2, 0, "evicted prefix must miss, not serve stale KV");
        // ...while the recently-used one still hits
        let (h1, m1) = p.lease_rows_cached(&t1, 4).unwrap();
        assert_eq!(m1, 4);
        p.release(h2);
        p.release(h1);
        p.cache_flush();
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.n_free_pages(), 4);
    }

    #[test]
    fn pinned_cache_pages_survive_pressure_and_lookup_failure_rolls_back() {
        let mut p = pool(4, 2, 8);
        p.enable_prefix_cache();
        let l = p.lease_rows(4).unwrap();
        p.prepare_rows(&l, 0, 4).unwrap();
        let toks = vec![1, 2, 3, 4];
        p.cache_insert(&toks, &l).unwrap();
        p.release(l);
        // a live hit pins the cached pages (refcount 2)
        let (h, m) = p.lease_rows_cached(&toks, 6).unwrap();
        assert_eq!(m, 4);
        // 6 rows = 3 pages, 2 cached -> 1 fresh reserved; 1 page spare
        assert_eq!(p.n_free_pages(), 1);
        // a cold request needing 2 pages cannot evict the pinned pages
        // and must queue; the failed lookup rolls its pins back cleanly
        assert!(p.lease_rows_cached(&[9, 9, 9, 9], 4).is_none());
        assert_eq!(p.cache_evictions(), 0, "pinned pages were evicted");
        assert_eq!(p.cached_pages(), 2);
        // the live hit still reads valid rows and can keep writing
        p.prepare_rows(&h, 4, 2).unwrap();
        p.release(h);
        p.cache_flush();
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn faulted_cache_insert_leaves_no_partial_entry() {
        use crate::util::failpoint;
        let mut p = pool(4, 2, 8);
        p.enable_prefix_cache();
        let l = p.lease_rows(4).unwrap();
        p.prepare_rows(&l, 0, 4).unwrap();
        let toks = vec![1, 2, 3, 4];
        let err = failpoint::with(3, &[("cache.insert", 1.0)], || {
            p.cache_insert(&toks, &l)
        });
        assert!(err.is_err(), "armed cache.insert must fail");
        assert_eq!(p.cached_pages(), 0, "partial insert reached the trie");
        p.release(l);
        assert_eq!(p.pages_in_use(), 0, "faulted insert leaked pages");
        // disarmed: the same insert lands and is served back
        let l = p.lease_rows(4).unwrap();
        p.prepare_rows(&l, 0, 4).unwrap();
        assert_eq!(p.cache_insert(&toks, &l).unwrap(), 2);
        p.release(l);
        let (h, m) = p.lease_rows_cached(&toks, 4).unwrap();
        assert_eq!(m, 4);
        p.release(h);
        assert_eq!(p.cache_flush(), 2);
        assert_eq!(p.pages_in_use(), 0);
    }

    /// dim for packed-format pools (packed formats need dim % 8 == 0).
    const QD: usize = 8;

    fn qpool(n_pages: usize, page_rows: usize, max_ctx: usize,
             fmt: KvFormat) -> KvPool {
        KvPool::with_format(L, QD, max_ctx, n_pages, page_rows, fmt)
    }

    fn qrow(tag: f32) -> Vec<f32> {
        (0..QD).map(|i| tag + (i as f32) * 0.37 - 1.1).collect()
    }

    #[test]
    fn kv_format_mapping_and_page_bytes() {
        assert_eq!(KvFormat::from_bits(4), KvFormat::Int4);
        assert_eq!(KvFormat::from_bits(8), KvFormat::Int8);
        assert_eq!(KvFormat::from_bits(16), KvFormat::F32);
        assert_eq!(KvFormat::from_bits(32), KvFormat::F32);
        let fp = qpool(2, 4, 8, KvFormat::F32);
        let q8 = qpool(2, 4, 8, KvFormat::Int8);
        let q4 = qpool(2, 4, 8, KvFormat::Int4);
        // int4 pages must be small enough for the >= 3.5x capacity gate
        assert!(fp.page_bytes() as f64 / q4.page_bytes() as f64 >= 3.5,
                "int4 page {} vs fp {}", q4.page_bytes(), fp.page_bytes());
        assert!(fp.page_bytes() > q8.page_bytes());
        assert!(q8.page_bytes() > q4.page_bytes());
    }

    #[test]
    fn packed_roundtrip_error_is_bounded_by_one_step() {
        for fmt in [KvFormat::Int8, KvFormat::Int4] {
            let qmax = if fmt == KvFormat::Int4 { 15.0 } else { 255.0 };
            let mut p = qpool(4, 4, 16, fmt);
            let l = p.lease_rows(8).unwrap();
            p.prepare_rows(&l, 0, 8).unwrap();
            for pos in 0..8 {
                for layer in 0..L {
                    let r = qrow((pos * 3 + layer) as f32);
                    p.put_k_row(&l, layer, pos, &r);
                    p.put_v_row(&l, layer, pos, &r);
                }
            }
            for pos in 0..8 {
                for layer in 0..L {
                    let want = qrow((pos * 3 + layer) as f32);
                    let mn = want.iter().cloned().fold(f32::INFINITY,
                                                       f32::min);
                    let mx = want.iter().cloned().fold(f32::NEG_INFINITY,
                                                       f32::max);
                    let step = (mx - mn) / qmax;
                    for (a, b) in
                        p.dequant_row(true, &l, layer, pos).iter()
                            .zip(&want)
                    {
                        assert!((a - b).abs() <= 0.5 * step + 1e-6,
                                "{fmt:?} roundtrip err {} > step {step}",
                                (a - b).abs());
                    }
                }
            }
            // a constant row reproduces exactly (scale falls back to 1)
            let flat = vec![0.625f32; QD];
            p.put_k_row(&l, 0, 0, &flat);
            assert_eq!(p.dequant_row(true, &l, 0, 0), flat);
            p.release(l);
        }
    }

    #[test]
    fn packed_fork_is_zero_copy_and_bit_identical() {
        let mut p = qpool(6, 4, 16, KvFormat::Int4);
        let parent = p.lease_rows(8).unwrap();
        p.prepare_rows(&parent, 0, 8).unwrap();
        for pos in 0..8 {
            for layer in 0..L {
                p.put_k_row(&parent, layer, pos, &qrow(pos as f32));
                p.put_v_row(&parent, layer, pos, &qrow(-(pos as f32)));
            }
        }
        let b0 = p.bytes_copied();
        let child = p.fork_rows(&parent, 8, 4).unwrap();
        assert_eq!(p.bytes_copied(), b0, "packed fork must copy nothing");
        for pos in 0..8 {
            // shared packed rows dequantize bit-for-bit identically
            let pk = p.dequant_row(true, &parent, 0, pos);
            let ck = p.dequant_row(true, &child, 0, pos);
            assert!(pk.iter().zip(&ck)
                        .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        p.release(parent);
        p.release(child);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn packed_cow_copies_at_most_one_page_and_isolates() {
        let mut p = qpool(6, 4, 16, KvFormat::Int4);
        let parent = p.lease_rows(16).unwrap();
        p.prepare_rows(&parent, 0, 6).unwrap();
        for pos in 0..6 {
            for layer in 0..L {
                p.put_k_row(&parent, layer, pos, &qrow(pos as f32));
                p.put_v_row(&parent, layer, pos, &qrow(pos as f32));
            }
        }
        let snap: Vec<Vec<f32>> =
            (0..6).map(|pos| p.dequant_row(true, &parent, 0, pos))
                .collect();
        let child = p.fork_rows(&parent, 6, 4).unwrap();
        let b0 = p.bytes_copied();
        p.prepare_rows(&child, 6, 2).unwrap();
        // COW copied exactly the 2 surviving tail-page rows: packed
        // words + scale/zero pairs, k+v, L layers
        let rw = QD / 8;
        let expect = 2 * (L * 2) as u64 * (rw as u64 * 4 + 8);
        assert_eq!(p.bytes_copied() - b0, expect);
        assert!(p.bytes_copied() - b0 <= p.page_bytes(),
                "packed COW exceeded one page");
        for pos in 6..8 {
            for layer in 0..L {
                p.put_k_row(&child, layer, pos, &qrow(9000.0));
                p.put_v_row(&child, layer, pos, &qrow(9000.0));
            }
        }
        // the shared prefix must be untouched in both tables
        for (pos, want) in snap.iter().enumerate() {
            assert_eq!(&p.dequant_row(true, &parent, 0, pos), want);
            assert_eq!(&p.dequant_row(true, &child, 0, pos), want,
                       "shared packed prefix diverged");
        }
        p.release(parent);
        p.release(child);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn packed_pages_flow_through_the_prefix_cache() {
        let mut p = qpool(6, 2, 12, KvFormat::Int8);
        p.enable_prefix_cache();
        let a = p.lease_rows(6).unwrap();
        p.prepare_rows(&a, 0, 6).unwrap();
        for pos in 0..6 {
            for layer in 0..L {
                p.put_k_row(&a, layer, pos, &qrow(pos as f32));
                p.put_v_row(&a, layer, pos, &qrow(pos as f32));
            }
        }
        let snap: Vec<Vec<f32>> =
            (0..4).map(|pos| p.dequant_row(true, &a, 0, pos)).collect();
        let toks: Vec<i32> = (0..6).collect();
        assert_eq!(p.cache_insert(&toks, &a).unwrap(), 3);
        p.release(a);
        let bc = p.bytes_copied();
        let (b, matched) = p.lease_rows_cached(&toks[..5], 8).unwrap();
        assert_eq!(matched, 4);
        assert_eq!(p.bytes_copied(), bc, "cache hit must copy nothing");
        for (pos, want) in snap.iter().enumerate() {
            assert_eq!(&p.dequant_row(true, &b, 0, pos), want,
                       "cached packed rows must be served verbatim");
        }
        p.release(b);
        p.cache_flush();
        assert_eq!(p.pages_in_use(), 0);
    }
}
