//! Shared slab KV pool for multi-sequence serving.
//!
//! A [`KvPool`] owns a fixed number of KV *slots*; each slot holds one
//! sequence's per-layer key/value rows up to `max_ctx` positions. Sessions
//! lease a slot ([`KvPool::lease`]), fill rows as they prefill/decode, and
//! hand the slot back ([`KvPool::release`]) when the sequence retires -
//! so M concurrent sessions share a bounded `n_slots * n_layers *
//! max_ctx * dim` allocation instead of each owning a full cache, and a
//! retired sequence's memory is reused by the next admission with no
//! allocation or zeroing.
//!
//! Reuse is safe without clearing because attention only ever reads rows
//! `[0, pos)` of the leasing session, and a fresh session starts at
//! `pos = 0`, overwriting rows before they are read (pinned by the
//! lease -> release -> re-lease tests here and in `infer::sched`).
//! Exhaustion is not an error: `lease` returns `None` and the scheduler
//! keeps the request queued until a slot frees.
//!
//! [`KvPool::fork`] leases a second slot and copies the parent's first
//! `pos` rows - the mechanism behind prefix reuse in
//! `eval::zeroshot::eval_items` (score N candidate continuations off one
//! prefilled prompt state instead of re-prefilling the prompt N times).
//! True zero-copy prefix *sharing* (paged KV) is the named next step in
//! ROADMAP.md.

use crate::infer::core::ModelCore;

/// One sequence's KV storage: per layer, `max_ctx * dim` keys and values.
pub struct KvSlot {
    /// per layer, (max_ctx * dim) post-RoPE keys
    pub(crate) k: Vec<Vec<f32>>,
    /// per layer, (max_ctx * dim) values
    pub(crate) v: Vec<Vec<f32>>,
}

impl KvSlot {
    fn new(n_layers: usize, dim: usize, max_ctx: usize) -> KvSlot {
        KvSlot {
            k: (0..n_layers).map(|_| vec![0f32; max_ctx * dim]).collect(),
            v: (0..n_layers).map(|_| vec![0f32; max_ctx * dim]).collect(),
        }
    }
}

/// A leased slot. Not `Clone`/`Copy`: exactly one live lease per slot,
/// returned to the pool with [`KvPool::release`].
#[derive(Debug)]
pub struct KvLease {
    pub(crate) slot: usize,
}

impl KvLease {
    /// Slot index (diagnostics / tests).
    pub fn slot_index(&self) -> usize {
        self.slot
    }
}

/// Fixed-capacity slab of KV slots with lease/release reuse.
pub struct KvPool {
    pub(crate) dim: usize,
    pub(crate) max_ctx: usize,
    slots: Vec<KvSlot>,
    free: Vec<usize>,
}

impl KvPool {
    pub fn new(n_layers: usize, dim: usize, max_ctx: usize,
               n_slots: usize) -> KvPool {
        KvPool {
            dim,
            max_ctx,
            slots: (0..n_slots)
                .map(|_| KvSlot::new(n_layers, dim, max_ctx))
                .collect(),
            // pop() takes from the back; reversed so slot 0 leases first
            free: (0..n_slots).rev().collect(),
        }
    }

    /// Pool shaped for `core` (its layer count, dim, and max_ctx).
    pub fn for_core(core: &ModelCore, n_slots: usize) -> KvPool {
        KvPool::new(core.n_layers(), core.dim, core.max_ctx, n_slots)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Lease a free slot; `None` when the pool is exhausted (callers
    /// queue - nothing panics on a full pool).
    pub fn lease(&mut self) -> Option<KvLease> {
        self.free.pop().map(|slot| KvLease { slot })
    }

    /// Return a slot to the pool. The rows are left as-is: the next
    /// lease overwrites from position 0 before anything reads them.
    pub fn release(&mut self, lease: KvLease) {
        debug_assert!(!self.free.contains(&lease.slot), "double release");
        self.free.push(lease.slot);
    }

    /// Lease a slot and copy the parent's first `pos` rows into it, so a
    /// new session continues from the parent's prefix without recomputing
    /// it. `None` when the pool is exhausted.
    pub fn fork(&mut self, parent: &KvLease, pos: usize) -> Option<KvLease> {
        let child = self.lease()?;
        let n = pos.min(self.max_ctx) * self.dim;
        let (pi, ci) = (parent.slot, child.slot);
        debug_assert_ne!(pi, ci, "fork leased the parent's slot");
        let (src, dst): (&KvSlot, &mut KvSlot) = if pi < ci {
            let (a, b) = self.slots.split_at_mut(ci);
            (&a[pi], &mut b[0])
        } else {
            let (a, b) = self.slots.split_at_mut(pi);
            (&b[0], &mut a[ci])
        };
        for (ks, kd) in src.k.iter().zip(dst.k.iter_mut()) {
            kd[..n].copy_from_slice(&ks[..n]);
        }
        for (vs, vd) in src.v.iter().zip(dst.v.iter_mut()) {
            vd[..n].copy_from_slice(&vs[..n]);
        }
        Some(child)
    }

    /// The leased slot's storage (opaque outside the crate; the
    /// `ModelCore` forward primitives read/write it).
    pub fn slot(&self, lease: &KvLease) -> &KvSlot {
        &self.slots[lease.slot]
    }

    pub fn slot_mut(&mut self, lease: &KvLease) -> &mut KvSlot {
        &mut self.slots[lease.slot]
    }
}
