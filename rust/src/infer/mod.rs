//! Pure-Rust serving stack for packed low-bit models: immutable
//! [`core::ModelCore`] shared across requests, per-request
//! [`session::Session`] state over a slab [`kv::KvPool`], the
//! continuous-batching [`sched::Scheduler`], and the single-session
//! [`engine::Engine`] facade (see `infer::engine` docs for the
//! architecture).
pub mod core;
pub mod engine;
pub mod generate;
pub mod kv;
pub mod qlinear;
pub mod sched;
pub mod session;
