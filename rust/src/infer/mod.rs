//! Pure-Rust serving stack for packed low-bit models: immutable
//! [`core::ModelCore`] shared across requests, per-request
//! [`session::Session`] state over the paged, refcounted [`kv::KvPool`]
//! (zero-copy prefix sharing via [`kv::KvPool::fork`]), the
//! cross-request radix prefix cache [`prefixcache::PrefixCache`]
//! (retired prompts re-served by refcount, LRU-evicted under pressure),
//! the continuous-batching [`sched::Scheduler`] with pluggable
//! admission policy (FIFO or EDF), per-tick prefill budget, and
//! incremental token streaming, the deterministic [`openloop`] arrival
//! simulator that exercises its failure model (deadlines, backpressure,
//! fault injection, SLO accounting), the randomized scheduler
//! property-test harness [`fuzz`] that pins the whole stack's
//! invariants over generated schedules, and the single-session
//! [`engine::Engine`] facade (see `infer::engine` docs for the
//! architecture and docs/ARCHITECTURE.md for the full map).
pub mod core;
pub mod engine;
pub mod fuzz;
pub mod generate;
pub mod kv;
pub mod openloop;
pub mod prefixcache;
pub mod qlinear;
pub mod sched;
pub mod session;
