//! Pure-Rust deployment path: packed low-bit linears (Table 10), the
//! KV-cached engine, and the generation loop.
pub mod engine;
pub mod generate;
pub mod qlinear;
