//! Deterministic open-loop serving simulator: seeded Poisson arrivals
//! with a deadline mix are driven through the
//! [`Scheduler`](crate::infer::sched::Scheduler) on a
//! [`Clock::manual`](crate::util::clock::Clock) virtual clock, so the
//! whole run - arrival times, admission order, deadline expiries,
//! backpressure rejects, and (optionally) injected faults - is a pure
//! function of the config. Unlike the closed-loop `serve-sim` default
//! (submit everything up front, drain), the open loop keeps offering
//! work at a fixed rate whether or not the scheduler keeps up, which is
//! what exercises shedding, queue-full backpressure, and timeout paths.
//!
//! The same seed always produces the same [`OpenLoopReport`], including
//! its FNV-1a [`digest`](OpenLoopReport::digest) over every completion's
//! `(id, finish, tokens)` - the `serve_robust` bench section and the
//! tier-1 smoke both pin run-to-run digest equality.
//!
//! [`OpenLoopCfg::personas`] switches the arrival mix to shared-prefix
//! traffic (N fixed system prompts, short per-request user suffixes),
//! which together with [`OpenLoopCfg::prefix_cache`] exercises the
//! cross-request radix prefix cache end to end: hit admissions, LRU
//! eviction under pool pressure, and the faultable `cache.insert` site.
//!
//! [`OpenLoopCfg::kv_bits`] selects the KV page storage width: 4 or 8
//! run the packed low-bit pool (`infer::kv`), and the report carries
//! the effective [`OpenLoopReport::kv_bits`] and
//! [`OpenLoopReport::pool_bytes`] so the `kv_lowbit` bench can compare
//! admitted capacity and goodput at fixed pool bytes across formats.
//!
//! [`OpenLoopCfg::policy`] selects the admission policy (FIFO or EDF),
//! [`OpenLoopCfg::prefill_budget`] caps prefill work per tick, and
//! [`OpenLoopCfg::stream`] drains per-token stream events every tick,
//! cross-checking them against retired outputs. With
//! [`OpenLoopCfg::token_cost_secs`] > 0 the virtual clock charges each
//! processed token, so latency metrics respond to scheduling choices;
//! [`OpenLoopCfg::slo_first_token_secs`] /
//! [`OpenLoopCfg::slo_token_secs`] then gate
//! [`OpenLoopReport::slo_goodput`], the `serve_slo` bench's headline
//! metric. All of it stays a pure function of (seed, config), and
//! streaming never changes the digest.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::infer::core::ModelCore;
use crate::infer::generate::Sampler;
use crate::infer::sched::{Reject, SchedConfig, SchedPolicy, Scheduler,
                          StreamEventKind};
use crate::infer::session::{Completion, FinishReason, Request};
use crate::util::clock::Clock;
use crate::util::failpoint;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Everything an open-loop run depends on. Same config = same report,
/// bit for bit.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopCfg {
    /// total arrivals to offer
    pub requests: usize,
    /// mean arrival rate, requests per virtual second (Poisson)
    pub rate: f64,
    /// virtual seconds advanced per scheduler tick
    pub tick_secs: f64,
    /// prompt lengths are drawn uniformly from `1..=prompt_len`
    pub prompt_len: usize,
    /// token budgets are drawn uniformly from `1..=max_new`
    pub max_new: usize,
    /// base deadline; the mix assigns 0.5x (tight), 1x, 4x (relaxed),
    /// or none per request. <= 0 disables deadlines entirely.
    pub deadline_secs: f64,
    /// seeds the arrival process and the per-request sampler seeds
    pub seed: u64,
    /// KV slots (full-sequence equivalents) in the scheduler pool
    pub slots: usize,
    pub max_batch: usize,
    pub prefill_chunk: usize,
    /// submission-queue bound; overload beyond it rejects (backpressure)
    pub max_queue: usize,
    /// per-site failpoint probability; 0 runs with faults disarmed
    pub fault_rate: f64,
    /// shared-prefix request mix: with `personas > 0`, every request is
    /// one of `personas` fixed `prompt_len`-token system prompts plus a
    /// short (1-3 token) user suffix - the workload the cross-request
    /// prefix cache exists for. 0 = the classic independent-prompt mix
    /// (whose arrival stream is byte-identical to before this knob).
    pub personas: usize,
    /// explicit page geometry: rows per page (0 = the pool default).
    /// Shared-prefix runs shrink this so system prompts span whole
    /// pages; total capacity stays `slots` full sequences either way.
    pub page_rows: usize,
    /// enable the cross-request prefix cache
    /// ([`SchedConfig::prefix_cache`])
    pub prefix_cache: bool,
    /// KV page storage width (`--kv-bits {4,8,16}`): 4 and 8 run the
    /// packed low-bit pool, anything else f32. Low-bit runs follow the
    /// low-bit determinism contract - digests reproduce per seed across
    /// batch size, threads, and SIMD ISA, but differ from f32 digests.
    pub kv_bits: u32,
    /// admission policy ([`SchedPolicy`]): FIFO-with-lookahead (the
    /// default, byte-identical to the pre-policy simulator) or EDF
    pub policy: SchedPolicy,
    /// per-tick chunked-prefill token budget
    /// ([`SchedConfig::prefill_budget`], 0 = unlimited)
    pub prefill_budget: usize,
    /// drain per-token stream events each tick and cross-check them
    /// against retired outputs (observation-only: the digest is
    /// bit-identical with this on or off)
    pub stream: bool,
    /// virtual seconds of model work per prefilled-or-emitted token.
    /// 0 keeps the classic fixed-width tick; > 0 makes each tick
    /// advance `tick_secs + token_cost_secs * tokens_processed`, so
    /// heavy prefill ticks genuinely delay in-flight decodes and the
    /// prefill budget has a latency effect to measure. Still a pure
    /// function of (seed, config).
    pub token_cost_secs: f64,
    /// p95 first-token SLO target in virtual seconds; <= 0 disables
    /// the SLO accounting ([`OpenLoopReport::slo_goodput`] then equals
    /// [`OpenLoopReport::goodput`])
    pub slo_first_token_secs: f64,
    /// per-token (inter-token gap) SLO target in virtual seconds;
    /// <= 0 checks only the first-token target
    pub slo_token_secs: f64,
}

impl Default for OpenLoopCfg {
    fn default() -> OpenLoopCfg {
        OpenLoopCfg {
            requests: 32,
            rate: 50.0,
            tick_secs: 0.01,
            prompt_len: 8,
            max_new: 8,
            deadline_secs: 0.5,
            seed: 0,
            slots: 4,
            max_batch: 4,
            prefill_chunk: 8,
            max_queue: 16,
            fault_rate: 0.0,
            personas: 0,
            page_rows: 0,
            prefix_cache: false,
            kv_bits: 16,
            policy: SchedPolicy::Fifo,
            prefill_budget: 0,
            stream: false,
            token_cost_secs: 0.0,
            slo_first_token_secs: 0.0,
            slo_token_secs: 0.0,
        }
    }
}

/// One pre-drawn arrival (the whole schedule is materialized before the
/// drive loop, so submission order can't depend on scheduler state).
struct Arrival {
    at: f64,
    req: Request,
}

/// Outcome counters and determinism digest for one open-loop run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpenLoopReport {
    /// arrivals offered (== cfg.requests)
    pub arrivals: usize,
    /// completions observed (arrivals minus backpressure rejects)
    pub completions: usize,
    /// submissions refused at the full queue (open-loop clients drop,
    /// they don't retry)
    pub rejected: usize,
    /// requests that ran to a natural end (Done or ContextFull)
    pub goodput: usize,
    pub done: usize,
    pub context_full: usize,
    /// deadline expiries that never left the queue (no tokens)
    pub shed_queued: usize,
    /// deadline expiries mid-flight (partial tokens kept)
    pub timed_out_live: usize,
    /// per-request isolated failures (only nonzero with faults armed)
    pub failed: usize,
    /// total tokens emitted across all completions
    pub total_tokens: usize,
    /// scheduler ticks driven
    pub ticks: u64,
    /// mean submission-queue depth sampled once per tick
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// max concurrently-live sessions observed
    pub peak_live: usize,
    /// KV pages still held after the drain - always 0 (asserted)
    pub leaked_pages: usize,
    /// admissions served partly from the prefix cache (0 with it off)
    pub cache_hits: u64,
    /// admissions that found no cached prefix (cache on only)
    pub cache_misses: u64,
    /// prompt tokens whose prefill was skipped via cache hits
    pub tokens_prefill_avoided: u64,
    /// cache pages reclaimed under pool pressure during the run
    pub cache_evictions: u64,
    /// pages the cache held at drain end (flushed before the leak check)
    pub cached_pages: usize,
    /// stored bits per KV value (32 = f32; 8/4 = packed low-bit pages)
    pub kv_bits: u32,
    /// total pool capacity in bytes (page bytes x page count) - the
    /// `kv_lowbit` bench compares admitted sequences at fixed pool bytes
    pub pool_bytes: u64,
    /// virtual seconds elapsed over the whole run
    pub virtual_secs: f64,
    /// goodput that also met the latency SLO: natural finishes whose
    /// first-token latency was within
    /// [`OpenLoopCfg::slo_first_token_secs`] and whose p95 inter-token
    /// gap was within [`OpenLoopCfg::slo_token_secs`]. Equals
    /// [`OpenLoopReport::goodput`] with the targets disabled.
    pub slo_goodput: usize,
    /// p95 of first-token latency over completions that emitted tokens
    pub p95_first_token_secs: f64,
    /// p95 of inter-token gaps across all completions (the first gap,
    /// which includes queue wait, is excluded - it belongs to the
    /// first-token metric)
    pub p95_token_gap_secs: f64,
    /// tokens observed through per-tick stream events (0 with
    /// [`OpenLoopCfg::stream`] off; == [`OpenLoopReport::total_tokens`]
    /// with it on - the drive loop asserts streamed tokens reconcile
    /// with every retired output)
    pub streamed_tokens: usize,
    /// FNV-1a over every completion's (id, finish tag, tokens) plus the
    /// reject count: two runs agree on this iff they agreed on every
    /// request's full lifecycle
    pub digest: u64,
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

fn finish_tag(f: &FinishReason) -> u8 {
    match f {
        FinishReason::Done => 0,
        FinishReason::ContextFull => 1,
        FinishReason::TimedOut => 2,
        FinishReason::Cancelled => 3,
        FinishReason::Failed(_) => 4,
    }
}

/// Pre-draw the full arrival schedule from the config seed: Poisson
/// inter-arrival gaps at `cfg.rate`, uniform prompt lengths and token
/// budgets, and the deadline mix (1 tight : 3 standard : 1 relaxed : 1
/// none). Exposed crate-wide so the `serve_robust` bench can re-derive
/// the exact requests a run offered (when nothing was rejected,
/// completion id == arrival index) and cross-check survivors against
/// solo `generate` runs.
pub(crate) fn planned_requests(cfg: &OpenLoopCfg, max_ctx: usize)
                               -> Vec<Request> {
    draw_arrivals(cfg, max_ctx).into_iter().map(|a| a.req).collect()
}

fn draw_arrivals(cfg: &OpenLoopCfg, max_ctx: usize) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed).fork("open-loop");
    let rate = cfg.rate.max(1e-9);
    let mut at = 0.0f64;
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        at += -(1.0 - rng.f64()).ln() / rate;
        let (prompt, budget) = if cfg.personas > 0 {
            // shared-prefix mix: a per-persona fixed system prompt of
            // `prompt_len` tokens plus a 1-3 token user suffix
            let p = rng.below(cfg.personas);
            let slen = 1 + rng.below(3);
            let budget = 1 + rng.below(cfg.max_new.max(1));
            let mut toks: Vec<i32> = (0..cfg.prompt_len.max(1))
                .map(|k| ((k * 11 + p * 29 + 5) % 89) as i32)
                .collect();
            toks.extend(
                (0..slen).map(|k| ((k * 7 + i * 13 + 3) % 89) as i32));
            toks.truncate(max_ctx.max(1));
            (toks, budget)
        } else {
            // classic mix: independent prompts, uniform lengths. The
            // RNG draw order here must stay byte-identical to the
            // pre-personas simulator so old seeds reproduce old runs.
            let plen = 1 + rng.below(cfg.prompt_len.max(1));
            let budget = 1 + rng.below(cfg.max_new.max(1));
            let prompt: Vec<i32> = (0..plen)
                .map(|k| ((k * 7 + i * 13 + 3) % 89) as i32)
                .collect();
            (prompt, budget)
        };
        let plen = prompt.len();
        // cap the worst case at the context so nothing is NeverFits
        let budget = budget.min(max_ctx.saturating_sub(plen) + 1).max(1);
        let mut req = Request::new(
            prompt, budget, Sampler::Greedy,
            cfg.seed.wrapping_add(1000 + i as u64));
        if cfg.deadline_secs > 0.0 {
            req = match rng.below(6) {
                0 => req.with_deadline(cfg.deadline_secs * 0.5),
                1..=3 => req.with_deadline(cfg.deadline_secs),
                4 => req.with_deadline(cfg.deadline_secs * 4.0),
                _ => req, // no deadline
            };
        }
        out.push(Arrival { at, req });
    }
    out
}

fn drive(core: Arc<ModelCore>, cfg: &OpenLoopCfg)
         -> Result<(OpenLoopReport, Vec<Completion>)> {
    let arrivals = draw_arrivals(cfg, core.max_ctx);
    let fmt = crate::infer::kv::KvFormat::from_bits(cfg.kv_bits);
    let pool = if cfg.page_rows > 0 {
        // explicit geometry, same total capacity: `slots` sequences
        let pr = cfg.page_rows;
        let per_seq = (core.max_ctx.max(1) + pr - 1) / pr;
        crate::infer::kv::KvPool::for_core_paged_fmt(
            &core, cfg.slots.max(1) * per_seq, pr, fmt)
    } else {
        crate::infer::kv::KvPool::for_core_fmt(&core, cfg.slots.max(1),
                                               fmt)
    };
    let mut sched = Scheduler::with_clock(
        core, pool,
        SchedConfig {
            max_batch: cfg.max_batch,
            prefill_chunk: cfg.prefill_chunk,
            max_queue: cfg.max_queue,
            prefix_cache: cfg.prefix_cache,
            kv_bits: cfg.kv_bits,
            policy: cfg.policy,
            prefill_budget: cfg.prefill_budget,
            stream: cfg.stream,
            ..SchedConfig::default()
        },
        Clock::manual());

    let mut rejected = 0usize;
    let mut next = 0usize;
    let mut ticks = 0u64;
    let mut depth_sum = 0u64;
    let mut depth_max = 0usize;
    let mut peak_live = 0usize;
    let mut streamed: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut streamed_tokens = 0usize;
    let mut prev_work = 0u64;
    while next < arrivals.len() || !sched.is_idle() {
        let now = sched.clock().now();
        while next < arrivals.len() && arrivals[next].at <= now {
            match sched.submit(arrivals[next].req.clone()) {
                Ok(_) => {}
                Err(Reject::QueueFull { .. }) => rejected += 1,
                Err(e) => anyhow::bail!(
                    "open-loop arrival {next} rejected unexpectedly: {e}"),
            }
            next += 1;
        }
        depth_sum += sched.n_queued() as u64;
        depth_max = depth_max.max(sched.n_queued());
        sched.tick()?;
        peak_live = peak_live.max(sched.n_live());
        if cfg.stream {
            for ev in sched.take_stream_events() {
                if let StreamEventKind::Token(tok) = ev.kind {
                    streamed.entry(ev.id).or_default().push(tok);
                    streamed_tokens += 1;
                }
            }
        }
        // Fixed tick width, plus (optionally) work-proportional time:
        // each prefilled or emitted token costs `token_cost_secs`, so
        // a heavy prefill tick delays everyone - the latency effect
        // the prefill budget exists to bound.
        let mut dt = cfg.tick_secs.max(1e-9);
        if cfg.token_cost_secs > 0.0 {
            let st = sched.stats();
            let work = st.prefilled_tokens + st.emitted_tokens;
            dt += cfg.token_cost_secs * (work - prev_work) as f64;
            prev_work = work;
        }
        sched.clock().advance(dt);
        ticks += 1;
        ensure!(ticks < 1_000_000,
                "open-loop run failed to drain in 1M ticks");
    }
    let virtual_secs = sched.clock().now();
    let stats = sched.stats();
    // Release the cache's refcounts before the leak check: every page
    // still in use afterwards is a genuine lease leak.
    let cached_pages = sched.pool().cached_pages();
    let flushed = sched.flush_prefix_cache();
    ensure!(flushed == cached_pages,
            "cache flush released {flushed} pages, index held \
             {cached_pages}");
    let leaked_pages = sched.pool().pages_in_use();
    ensure!(leaked_pages == 0,
            "open-loop run leaked {leaked_pages} KV pages");

    let comps = sched.take_completed();
    ensure!(comps.len() + rejected == arrivals.len(),
            "lost requests: {} completions + {} rejects != {} arrivals",
            comps.len(), rejected, arrivals.len());

    let kv_bits = sched.pool().format().bits();
    let pool_bytes =
        sched.pool().page_bytes() * sched.pool().n_pages() as u64;
    let mut rep = OpenLoopReport {
        arrivals: arrivals.len(),
        completions: comps.len(),
        rejected,
        goodput: 0,
        done: 0,
        context_full: 0,
        shed_queued: 0,
        timed_out_live: 0,
        failed: 0,
        total_tokens: 0,
        ticks,
        queue_depth_mean: depth_sum as f64 / ticks.max(1) as f64,
        queue_depth_max: depth_max,
        peak_live,
        leaked_pages,
        cache_hits: stats.cache_hits,
        cache_misses: stats.cache_misses,
        tokens_prefill_avoided: stats.tokens_prefill_avoided,
        cache_evictions: stats.cache_evictions,
        cached_pages,
        kv_bits,
        pool_bytes,
        virtual_secs,
        slo_goodput: 0,
        p95_first_token_secs: 0.0,
        p95_token_gap_secs: 0.0,
        streamed_tokens,
        digest: 0xcbf29ce484222325,
    };
    let mut first_lats: Vec<f64> = Vec::with_capacity(comps.len());
    let mut gaps: Vec<f64> = Vec::new();
    for c in &comps {
        rep.total_tokens += c.tokens.len();
        if !c.tokens.is_empty() {
            first_lats.push(c.first_token_secs);
        }
        if c.token_gaps.len() > 1 {
            gaps.extend_from_slice(&c.token_gaps[1..]);
        }
        if cfg.stream {
            let got = streamed.get(&c.id).map_or(&[][..], |v| &v[..]);
            ensure!(got == &c.tokens[..],
                    "request {}: streamed tokens diverge from the \
                     retired output", c.id);
        }
        if c.finish.is_ok() {
            rep.goodput += 1;
            let ft_ok = cfg.slo_first_token_secs <= 0.0
                || (c.first_token_secs <= cfg.slo_first_token_secs
                    && (cfg.slo_token_secs <= 0.0
                        || c.token_gaps.len() <= 1
                        || percentile(&c.token_gaps[1..], 95.0)
                            <= cfg.slo_token_secs));
            if ft_ok {
                rep.slo_goodput += 1;
            }
        }
        match &c.finish {
            FinishReason::Done => rep.done += 1,
            FinishReason::ContextFull => rep.context_full += 1,
            FinishReason::TimedOut if c.tokens.is_empty() => {
                rep.shed_queued += 1
            }
            FinishReason::TimedOut => rep.timed_out_live += 1,
            FinishReason::Cancelled => {}
            FinishReason::Failed(_) => rep.failed += 1,
        }
        fnv1a(&mut rep.digest, &c.id.to_le_bytes());
        fnv1a(&mut rep.digest, &[finish_tag(&c.finish)]);
        for t in &c.tokens {
            fnv1a(&mut rep.digest, &t.to_le_bytes());
        }
    }
    fnv1a(&mut rep.digest, &(rejected as u64).to_le_bytes());
    rep.p95_first_token_secs = percentile(&first_lats, 95.0);
    rep.p95_token_gap_secs = percentile(&gaps, 95.0);
    Ok((rep, comps))
}

/// Run one open-loop simulation to completion. With
/// `cfg.fault_rate > 0` the forward/KV/cache failpoint sites are armed
/// for the whole drive (seeded from `cfg.seed`), so fault schedules are
/// as reproducible as the arrivals.
pub fn run_open_loop(core: Arc<ModelCore>, cfg: &OpenLoopCfg)
                     -> Result<OpenLoopReport> {
    run_open_loop_with_completions(core, cfg).map(|(rep, _)| rep)
}

/// [`run_open_loop`], also handing back the per-request
/// [`Completion`]s (id order). The `serve_robust` bench uses these to
/// assert survivors are bit-identical to solo `generate` runs.
pub fn run_open_loop_with_completions(core: Arc<ModelCore>,
                                      cfg: &OpenLoopCfg)
    -> Result<(OpenLoopReport, Vec<Completion>)> {
    if cfg.fault_rate > 0.0 {
        let p = cfg.fault_rate;
        let sites = [
            ("kv.draw", p * 0.5),
            ("fwd.prefill", p),
            ("fwd.decode", p * 0.5),
            ("fwd.step", p * 0.5),
            ("cache.insert", p * 0.5),
        ];
        failpoint::with(cfg.seed ^ 0xFA17, &sites, || drive(core, cfg))
    } else {
        drive(core, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;

    fn core(seed: u64) -> Arc<ModelCore> {
        Arc::new(ModelCore::synthetic(32, 4, 8, 64, 96, 2,
                                      QuantScheme::new(2, 32), 48, seed)
            .unwrap())
    }

    fn cfg() -> OpenLoopCfg {
        OpenLoopCfg {
            requests: 24,
            rate: 60.0,
            seed: 7,
            ..OpenLoopCfg::default()
        }
    }

    /// Same config -> bit-identical report (digest included), and the
    /// lifecycle counters reconcile with the arrival count.
    #[test]
    fn open_loop_is_deterministic_and_accounts_for_every_arrival() {
        let c = core(50);
        let a = run_open_loop(c.clone(), &cfg()).unwrap();
        let b = run_open_loop(c, &cfg()).unwrap();
        assert_eq!(a, b, "same config must reproduce bit-identically");
        assert_eq!(a.arrivals, 24);
        assert!(a.goodput > 0, "no request ran to completion");
        assert_eq!(a.leaked_pages, 0);
        assert_eq!(
            a.done + a.context_full + a.shed_queued + a.timed_out_live
                + a.failed,
            a.completions,
            "finish-reason counts must partition the completions");
        assert_eq!(a.completions + a.rejected, a.arrivals);
        assert_eq!(a.failed, 0, "faults disarmed but requests failed");
    }

    /// Different seeds produce different schedules (sanity that the
    /// digest actually discriminates).
    #[test]
    fn open_loop_digest_depends_on_seed() {
        let c = core(50);
        let a = run_open_loop(c.clone(), &cfg()).unwrap();
        let b = run_open_loop(
            c, &OpenLoopCfg { seed: 8, ..cfg() }).unwrap();
        assert_ne!(a.digest, b.digest);
    }

    /// Overload: offered rate far above capacity with a bounded queue
    /// must shed and/or reject, never lose accounting or leak pages.
    #[test]
    fn open_loop_overload_sheds_and_rejects_without_leaks() {
        let c = core(51);
        let hot = OpenLoopCfg {
            requests: 48,
            rate: 2000.0,
            max_queue: 4,
            deadline_secs: 0.2,
            seed: 9,
            ..OpenLoopCfg::default()
        };
        let r = run_open_loop(c, &hot).unwrap();
        assert!(r.rejected + r.shed_queued > 0,
                "overload produced no backpressure or shedding: {r:?}");
        assert!(r.goodput > 0);
        assert_eq!(r.completions + r.rejected, r.arrivals);
        assert_eq!(r.leaked_pages, 0);
    }

    /// Shared-prefix traffic with the cache on: deterministic, hits
    /// actually happen, prefill work is skipped, and the drain still
    /// leaks nothing after the cache flush. The same mix with the
    /// cache off reports zero hits and identical accounting closure.
    #[test]
    fn shared_prefix_mode_hits_cache_and_stays_deterministic() {
        let c = core(53);
        let sp = OpenLoopCfg {
            requests: 24,
            rate: 60.0,
            seed: 7,
            personas: 3,
            prompt_len: 10,
            max_new: 6,
            page_rows: 4,
            prefix_cache: true,
            ..OpenLoopCfg::default()
        };
        let a = run_open_loop(c.clone(), &sp).unwrap();
        let b = run_open_loop(c.clone(), &sp).unwrap();
        assert_eq!(a, b, "shared-prefix run must reproduce bit-identically");
        assert!(a.cache_hits > 0,
                "shared-prefix mix produced no cache hits: {a:?}");
        assert!(a.tokens_prefill_avoided >= a.cache_hits * 4,
                "every hit matches at least one 4-row page: {a:?}");
        assert_eq!(a.leaked_pages, 0);
        assert_eq!(a.completions + a.rejected, a.arrivals);
        assert!(a.goodput > 0);

        let off = run_open_loop(
            c, &OpenLoopCfg { prefix_cache: false, ..sp }).unwrap();
        assert_eq!(off.cache_hits, 0);
        assert_eq!(off.cache_misses, 0);
        assert_eq!(off.cached_pages, 0);
        assert_eq!(off.leaked_pages, 0);
        assert_eq!(off.completions + off.rejected, off.arrivals);
    }

    /// Low-bit KV mode: int4 runs reproduce bit-identically, the packed
    /// pool reports the smaller byte footprint, a randomized failpoint
    /// sweep leaks zero pages, and the prefix-cache + faults combination
    /// on packed pages stays deterministic and leak-free.
    #[test]
    fn open_loop_low_bit_kv_deterministic_and_leak_free_under_faults() {
        let c = core(54);
        let q = OpenLoopCfg { kv_bits: 4, ..cfg() };
        let a = run_open_loop(c.clone(), &q).unwrap();
        let b = run_open_loop(c.clone(), &q).unwrap();
        assert_eq!(a, b, "int4 run must reproduce bit-identically");
        assert_eq!(a.kv_bits, 4);
        assert_eq!(a.leaked_pages, 0);
        assert_eq!(a.completions + a.rejected, a.arrivals);
        assert!(a.goodput > 0);
        let fp = run_open_loop(c.clone(), &cfg()).unwrap();
        assert_eq!(fp.kv_bits, 32);
        assert!(a.pool_bytes * 3 < fp.pool_bytes,
                "packed pool not smaller at equal page count: {} vs {}",
                a.pool_bytes, fp.pool_bytes);

        // randomized failpoint sweep in low-bit mode: zero leaked pages
        // (drive() errors on any leak, so success == clean accounting)
        for seed in [31u64, 32, 33] {
            let f = OpenLoopCfg {
                kv_bits: 4,
                fault_rate: 0.05,
                seed,
                ..cfg()
            };
            let r = run_open_loop(c.clone(), &f).unwrap();
            assert_eq!(r.leaked_pages, 0, "seed {seed} leaked pages");
            assert_eq!(r.completions + r.rejected, r.arrivals,
                       "seed {seed} lost requests");
        }

        // shared prefixes + cache + faults over packed pages
        let sp = OpenLoopCfg {
            kv_bits: 4,
            personas: 3,
            prompt_len: 10,
            page_rows: 4,
            prefix_cache: true,
            fault_rate: 0.05,
            ..cfg()
        };
        let x = run_open_loop(c.clone(), &sp).unwrap();
        let y = run_open_loop(c, &sp).unwrap();
        assert_eq!(x, y, "faulted cached int4 run must reproduce");
        assert!(x.cache_hits > 0, "packed pages never hit the cache");
        assert_eq!(x.leaked_pages, 0);
    }

    /// Faulted runs are exactly as deterministic as clean ones, and the
    /// accounting still closes.
    #[test]
    fn open_loop_fault_runs_are_deterministic_and_leak_free() {
        let c = core(52);
        let f = OpenLoopCfg { fault_rate: 0.05, ..cfg() };
        let a = run_open_loop(c.clone(), &f).unwrap();
        let b = run_open_loop(c, &f).unwrap();
        assert_eq!(a, b, "faulted run must reproduce bit-identically");
        assert_eq!(a.leaked_pages, 0);
        assert_eq!(a.completions + a.rejected, a.arrivals);
    }

    /// EDF + prefill budget + streaming: bit-identical reproduction,
    /// closed accounting, and every emitted token observed through the
    /// stream. Streaming itself never changes the digest.
    #[test]
    fn open_loop_edf_budget_stream_is_deterministic() {
        let c = core(55);
        let e = OpenLoopCfg {
            policy: SchedPolicy::Edf,
            prefill_budget: 6,
            stream: true,
            fault_rate: 0.02,
            ..cfg()
        };
        let a = run_open_loop(c.clone(), &e).unwrap();
        let b = run_open_loop(c.clone(), &e).unwrap();
        assert_eq!(a, b, "EDF stream run must reproduce bit-identically");
        assert_eq!(a.leaked_pages, 0);
        assert_eq!(a.completions + a.rejected, a.arrivals);
        assert!(a.goodput > 0);
        assert_eq!(a.streamed_tokens, a.total_tokens,
                   "stream events must account for every emitted token");
        let quiet = run_open_loop(
            c, &OpenLoopCfg { stream: false, ..e }).unwrap();
        assert_eq!(quiet.digest, a.digest,
                   "streaming must be observation-only");
        assert_eq!(quiet.streamed_tokens, 0);
    }

    /// The work-proportional clock and SLO accounting: charging tokens
    /// makes runs take longer in virtual time, slo_goodput is bounded
    /// by goodput, collapses to goodput with the targets disabled, and
    /// an absurdly tight target zeroes it.
    #[test]
    fn open_loop_token_cost_clock_and_slo_accounting() {
        let c = core(56);
        let base = OpenLoopCfg {
            deadline_secs: 0.0, // isolate the clock from shedding
            max_queue: 32,      // ... and from backpressure rejects
            ..cfg()
        };
        let fixed = run_open_loop(c.clone(), &base).unwrap();
        let costed_cfg = OpenLoopCfg {
            token_cost_secs: 0.01,
            slo_first_token_secs: 1.0,
            slo_token_secs: 0.5,
            ..base
        };
        let costed = run_open_loop(c.clone(), &costed_cfg).unwrap();
        let again = run_open_loop(c, &costed_cfg).unwrap();
        assert_eq!(costed, again,
                   "token-cost run must reproduce bit-identically");
        assert!(costed.virtual_secs > fixed.virtual_secs,
                "charging per-token work must lengthen virtual time: \
                 {} vs {}", costed.virtual_secs, fixed.virtual_secs);
        assert!(costed.slo_goodput <= costed.goodput);
        assert_eq!(fixed.slo_goodput, fixed.goodput,
                   "disabled SLO targets must not gate goodput");
        assert!(costed.p95_first_token_secs > 0.0);
        // with deadlines off and no faults, the token stream itself is
        // identical under either clock - only latencies differ
        assert_eq!(costed.digest, fixed.digest,
                   "clock model changed request lifecycles unexpectedly");
    }
}
