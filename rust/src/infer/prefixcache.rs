//! Cross-request radix prefix cache over the paged KV pool.
//!
//! A trie keyed by `page_rows`-token chunks maps token prefixes to the KV
//! pages that hold them. Each node owns exactly one page id and holds one
//! refcount on it (like a permanent lease), so cached pages are shared with
//! live sequences by the same refcount mechanism as `KvPool::fork_rows` —
//! zero bytes copied on a hit, and a page is physically freed only when the
//! last holder (cache or lease) lets go.
//!
//! Determinism: a cached page is the KV a retired request wrote for tokens
//! `[0..page_rows*k)` at absolute positions — by the pool-wide bit-determinism
//! contract that KV is bit-identical to what a fresh prefill of the same
//! prefix would write, so serving it back cannot perturb logits. On chunk
//! collision the first insert wins; the loser's page is bit-identical anyway
//! and stays owned by its lease until release.
//!
//! Eviction is LRU over *evictable leaves*: nodes with no children whose page
//! refcount is exactly 1 (held only by the cache). Pages pinned by a live
//! lease (refcount > 1) are never victims, and inner nodes are never leaves,
//! so a cached path is always a contiguous prefix — descendants go before
//! ancestors. `KvPool` drives eviction from its allocation paths when a
//! reservation would not otherwise fit, preserving the "admitted sequences
//! never fail a KV allocation mid-decode" invariant.
//!
//! The cache itself never touches page *contents*; it only manipulates the
//! pool's `refcount` / `free` bookkeeping passed in by the caller, which keeps
//! it trivially decoupled from slab layout.

/// One cached page: `key` is the exact `page_rows`-token chunk whose KV the
/// page holds, at the trie depth's absolute positions.
#[derive(Debug)]
struct Node {
    key: Vec<i32>,
    page: u32,
    last_used: u64,
    children: Vec<Node>,
}

/// Radix index from token prefix to page-table prefix. Owned by [`super::kv::KvPool`]
/// when the prefix cache is enabled; all refcount/free-list bookkeeping is
/// passed in explicitly so the trie has no pool dependency.
#[derive(Debug)]
pub struct PrefixCache {
    page_rows: usize,
    roots: Vec<Node>,
    /// Logical LRU clock: bumped once per lookup/insert; every node touched
    /// by that operation shares the stamp.
    clock: u64,
    evictions: u64,
    n_pages: usize,
}

impl PrefixCache {
    pub(crate) fn new(page_rows: usize) -> Self {
        PrefixCache {
            page_rows: page_rows.max(1),
            roots: Vec::new(),
            clock: 0,
            evictions: 0,
            n_pages: 0,
        }
    }

    /// Pages currently held (one refcount each).
    pub(crate) fn n_pages(&self) -> usize {
        self.n_pages
    }

    /// Total pages evicted over the cache's lifetime.
    pub(crate) fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Longest cached page-aligned prefix of `key`: returns the page ids for
    /// every matched full chunk, in order. Stamps the matched path as
    /// recently used. Does NOT bump refcounts — the caller pins the returned
    /// pages before anything else can trigger eviction.
    pub(crate) fn lookup(&mut self, key: &[i32]) -> Vec<u32> {
        let stamp = self.clock;
        self.clock += 1;
        let mut out = Vec::new();
        let mut cur = &mut self.roots;
        for chunk in key.chunks_exact(self.page_rows) {
            let Some(idx) = cur.iter().position(|n| n.key == chunk) else {
                break;
            };
            let tmp = cur;
            let node = &mut tmp[idx];
            node.last_used = stamp;
            out.push(node.page);
            cur = &mut node.children;
        }
        out
    }

    /// Read-only twin of [`PrefixCache::lookup`]: how many full
    /// `page_rows` chunks of `key` have a cached page, without stamping
    /// the matched path or advancing the LRU clock. The scheduler's
    /// cache-aware admission pass probes every candidate in its
    /// lookahead window with this before deciding attempt order, so
    /// probing can never perturb eviction recency.
    pub(crate) fn probe(&self, key: &[i32]) -> usize {
        let mut matched = 0usize;
        let mut cur = &self.roots;
        for chunk in key.chunks_exact(self.page_rows) {
            let Some(node) = cur.iter().find(|n| n.key == chunk) else {
                break;
            };
            matched += 1;
            cur = &node.children;
        }
        matched
    }

    /// Insert `pages[i]` for the i-th full `page_rows` chunk of `key`,
    /// bumping `refcount` once for each *newly created* node. Chunks already
    /// present keep their existing page (first insert wins; both candidates
    /// are bit-identical by the determinism contract). Returns the number of
    /// pages newly referenced by the cache. Trailing partial chunks of `key`
    /// and excess `pages` are ignored.
    pub(crate) fn insert(&mut self, key: &[i32], pages: &[u32], refcount: &mut [u32]) -> usize {
        let stamp = self.clock;
        self.clock += 1;
        let mut added = 0;
        let mut cur = &mut self.roots;
        for (chunk, &page) in key.chunks_exact(self.page_rows).zip(pages) {
            let idx = match cur.iter().position(|n| n.key == chunk) {
                Some(i) => i,
                None => {
                    refcount[page as usize] += 1;
                    added += 1;
                    cur.push(Node {
                        key: chunk.to_vec(),
                        page,
                        last_used: stamp,
                        children: Vec::new(),
                    });
                    cur.len() - 1
                }
            };
            let tmp = cur;
            let node = &mut tmp[idx];
            node.last_used = stamp;
            cur = &mut node.children;
        }
        self.n_pages += added;
        added
    }

    /// Evict the least-recently-used evictable leaf (no children, page
    /// refcount exactly 1 — i.e. held only by the cache), dropping its
    /// refcount and returning the page to `free`. Returns `false` when
    /// nothing is evictable (every cached page is pinned by a live lease).
    pub(crate) fn evict_one(&mut self, refcount: &mut [u32], free: &mut Vec<u32>) -> bool {
        let mut best: Option<u64> = None;
        Self::min_evictable(&self.roots, refcount, &mut best);
        let Some(stamp) = best else { return false };
        let Some(page) = Self::remove_stamped(&mut self.roots, stamp, refcount) else {
            return false;
        };
        let r = &mut refcount[page as usize];
        *r -= 1;
        if *r == 0 {
            free.push(page);
        }
        self.n_pages -= 1;
        self.evictions += 1;
        true
    }

    /// Release every cached page (post-order, so children release before
    /// their parents), returning how many cache references were dropped.
    /// Pages still pinned by live leases keep refcount > 0 and are not
    /// pushed to `free`; unpinned ones are.
    pub(crate) fn flush(&mut self, refcount: &mut [u32], free: &mut Vec<u32>) -> usize {
        fn release(nodes: Vec<Node>, refcount: &mut [u32], free: &mut Vec<u32>) -> usize {
            let mut n = 0;
            for node in nodes {
                n += release(node.children, refcount, free);
                let r = &mut refcount[node.page as usize];
                *r -= 1;
                if *r == 0 {
                    free.push(node.page);
                }
                n += 1;
            }
            n
        }
        let roots = std::mem::take(&mut self.roots);
        let n = release(roots, refcount, free);
        self.n_pages = 0;
        n
    }

    fn min_evictable(nodes: &[Node], refcount: &[u32], best: &mut Option<u64>) {
        for n in nodes {
            if n.children.is_empty() {
                if refcount[n.page as usize] == 1 && best.map_or(true, |b| n.last_used < b) {
                    *best = Some(n.last_used);
                }
            } else {
                Self::min_evictable(&n.children, refcount, best);
            }
        }
    }

    fn remove_stamped(nodes: &mut Vec<Node>, stamp: u64, refcount: &[u32]) -> Option<u32> {
        for i in 0..nodes.len() {
            if nodes[i].children.is_empty() {
                if nodes[i].last_used == stamp && refcount[nodes[i].page as usize] == 1 {
                    return Some(nodes.remove(i).page);
                }
            } else if let Some(p) = Self::remove_stamped(&mut nodes[i].children, stamp, refcount) {
                return Some(p);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy pool: `n` pages, none referenced, free list in pool order
    /// (highest id popped last, matching KvPool's reversed init).
    fn toy_pool(n: usize) -> (Vec<u32>, Vec<u32>) {
        (vec![0; n], (0..n as u32).rev().collect())
    }

    /// "Lease" a page the way the pool does: pop free, refcount 1.
    fn alloc(refcount: &mut [u32], free: &mut Vec<u32>) -> u32 {
        let p = free.pop().unwrap();
        refcount[p as usize] = 1;
        p
    }

    #[test]
    fn lookup_matches_full_chunks_only() {
        let (mut rc, mut free) = toy_pool(8);
        let mut c = PrefixCache::new(2);
        let p0 = alloc(&mut rc, &mut free);
        let p1 = alloc(&mut rc, &mut free);
        assert_eq!(c.insert(&[1, 2, 3, 4], &[p0, p1], &mut rc), 2);
        assert_eq!(rc[p0 as usize], 2);
        assert_eq!(rc[p1 as usize], 2);
        assert_eq!(c.n_pages(), 2);

        assert_eq!(c.lookup(&[1, 2, 3, 4]), vec![p0, p1]);
        // longer key still matches the cached prefix
        assert_eq!(c.lookup(&[1, 2, 3, 4, 9, 9]), vec![p0, p1]);
        // divergence after the first chunk
        assert_eq!(c.lookup(&[1, 2, 9, 9]), vec![p0]);
        // partial trailing chunk is never matched
        assert_eq!(c.lookup(&[1, 2, 3]), vec![p0]);
        // no match at all
        assert!(c.lookup(&[9, 9]).is_empty());
        assert!(c.lookup(&[1]).is_empty());
    }

    #[test]
    fn first_insert_wins_on_collision() {
        let (mut rc, mut free) = toy_pool(8);
        let mut c = PrefixCache::new(2);
        let p0 = alloc(&mut rc, &mut free);
        assert_eq!(c.insert(&[1, 2], &[p0], &mut rc), 1);
        let p1 = alloc(&mut rc, &mut free);
        // same chunk again from a different page: no-op for the trie
        assert_eq!(c.insert(&[1, 2], &[p1], &mut rc), 0);
        assert_eq!(rc[p0 as usize], 2);
        assert_eq!(rc[p1 as usize], 1, "loser page must not gain a cache ref");
        assert_eq!(c.lookup(&[1, 2]), vec![p0]);
        // extending the shared prefix still adds the new tail node
        let p2 = alloc(&mut rc, &mut free);
        assert_eq!(c.insert(&[1, 2, 7, 8], &[p1, p2], &mut rc), 1);
        assert_eq!(c.lookup(&[1, 2, 7, 8]), vec![p0, p2]);
        assert_eq!(rc[p1 as usize], 1);
    }

    #[test]
    fn lru_eviction_in_stamp_order() {
        let (mut rc, mut free) = toy_pool(8);
        let mut c = PrefixCache::new(1);
        // three disjoint single-page entries inserted at increasing clock
        let mut pages = Vec::new();
        for t in 0..3 {
            let p = alloc(&mut rc, &mut free);
            c.insert(&[t], &[p], &mut rc);
            rc[p as usize] -= 1; // drop the "lease" ref: cache-only now
            pages.push(p);
        }
        // touch entry 0 so entry 1 becomes the LRU victim
        c.lookup(&[0]);
        assert!(c.evict_one(&mut rc, &mut free));
        assert_eq!(free.pop(), Some(pages[1]));
        assert!(c.evict_one(&mut rc, &mut free));
        assert_eq!(free.pop(), Some(pages[2]));
        assert!(c.evict_one(&mut rc, &mut free));
        assert_eq!(free.pop(), Some(pages[0]));
        assert!(!c.evict_one(&mut rc, &mut free), "cache drained");
        assert_eq!(c.n_pages(), 0);
        assert_eq!(c.evictions(), 3);
        assert!(rc.iter().all(|&r| r == 0), "no leaked refs");
    }

    #[test]
    fn pinned_pages_never_evicted_and_leaves_go_before_parents() {
        let (mut rc, mut free) = toy_pool(8);
        let mut c = PrefixCache::new(1);
        let p0 = alloc(&mut rc, &mut free);
        let p1 = alloc(&mut rc, &mut free);
        c.insert(&[5, 6], &[p0, p1], &mut rc);
        // keep the "lease" ref on the parent page: rc[p0]==2 (pinned),
        // drop it on the leaf: rc[p1]==1 (evictable)
        rc[p1 as usize] -= 1;
        // the leaf goes first even though the parent is older-or-equal
        assert!(c.evict_one(&mut rc, &mut free));
        assert_eq!(free.last(), Some(&p1));
        // parent is now a leaf but pinned: nothing evictable
        assert!(!c.evict_one(&mut rc, &mut free));
        assert_eq!(c.n_pages(), 1);
        // unpin, then it can go
        rc[p0 as usize] -= 1;
        assert!(c.evict_one(&mut rc, &mut free));
        assert_eq!(c.n_pages(), 0);
        assert!(rc.iter().all(|&r| r == 0));
    }

    #[test]
    fn flush_releases_everything_once() {
        let (mut rc, mut free) = toy_pool(8);
        let mut c = PrefixCache::new(2);
        let p0 = alloc(&mut rc, &mut free);
        let p1 = alloc(&mut rc, &mut free);
        let p2 = alloc(&mut rc, &mut free);
        c.insert(&[1, 2, 3, 4], &[p0, p1], &mut rc);
        c.insert(&[1, 2, 5, 6], &[p0, p2], &mut rc);
        assert_eq!(c.n_pages(), 3);
        // p1 stays pinned by its lease; p0/p2 leases released
        rc[p0 as usize] -= 1;
        rc[p2 as usize] -= 1;
        let free_before = free.len();
        assert_eq!(c.flush(&mut rc, &mut free), 3);
        assert_eq!(c.n_pages(), 0);
        assert_eq!(rc[p0 as usize], 0);
        assert_eq!(rc[p1 as usize], 1, "leased page survives flush");
        assert_eq!(rc[p2 as usize], 0);
        assert_eq!(free.len(), free_before + 2);
        assert!(c.lookup(&[1, 2]).is_empty(), "flushed trie serves nothing");
    }
}
