//! Packed low-bit linear layers for the pure-Rust deployment path - the
//! BitBLAS analog behind paper Table 10.
//!
//! Why INT2 wins on matvec: token generation is weight-memory-bandwidth
//! bound; packed 2-bit weights move 8x fewer bytes than f32 (16x fewer than
//! the f32 path's working set per value). The compute added by unpacking
//! (shift+mask+FMA) is cheap relative to the saved memory traffic - on CPU
//! exactly as on GPU.
//!
//! Storage: per output row, groups are contiguous; each group's g values
//! occupy exactly g*bits/32 u32 words (all supported schemes have
//! 32 | g*bits, so groups are word-aligned). Per group: one f32 scale, one
//! f32 zero point (dequantized from the f16/N-bit stored forms at load).

use anyhow::{bail, Result};

use crate::config::QuantScheme;

#[derive(Clone)]
pub struct PackedLinear {
    pub out_dim: usize,
    pub in_dim: usize,
    pub scheme: QuantScheme,
    /// u32 words, row-major: row r occupies words [r*wpr, (r+1)*wpr)
    pub words: Vec<u32>,
    /// (out * groups_per_row) scales
    pub scales: Vec<f32>,
    /// (out * groups_per_row) zero points
    pub zeros: Vec<f32>,
}

impl PackedLinear {
    pub fn words_per_row(&self) -> usize {
        self.in_dim * self.scheme.bits as usize / 32
    }

    pub fn groups_per_row(&self) -> usize {
        self.in_dim / self.scheme.group
    }

    /// Pack from integer-valued f32 weights (wq layout) + group params.
    pub fn pack(
        w_int: &[f32],
        out_dim: usize,
        in_dim: usize,
        scales: &[f32],
        zeros: &[f32],
        scheme: QuantScheme,
    ) -> Result<PackedLinear> {
        let bits = scheme.bits as usize;
        if in_dim * bits % 32 != 0 || scheme.group * bits % 32 != 0 {
            bail!("group {}x{}bit not word-aligned", scheme.group, bits);
        }
        if w_int.len() != out_dim * in_dim {
            bail!("w_int size mismatch");
        }
        let wpr = in_dim * bits / 32;
        let mut words = vec![0u32; out_dim * wpr];
        for r in 0..out_dim {
            let row = &w_int[r * in_dim..(r + 1) * in_dim];
            let out_row = &mut words[r * wpr..(r + 1) * wpr];
            let mut bitpos = 0usize;
            for &q in row {
                if q < 0.0 || q > scheme.qmax() || q.fract() != 0.0 {
                    bail!("bad quantized value {q}");
                }
                let v = q as u32;
                out_row[bitpos >> 5] |= v << (bitpos & 31);
                if (bitpos & 31) + bits > 32 {
                    out_row[(bitpos >> 5) + 1] |= v >> (32 - (bitpos & 31));
                }
                bitpos += bits;
            }
        }
        Ok(PackedLinear {
            out_dim,
            in_dim,
            scheme,
            words,
            scales: scales.to_vec(),
            zeros: zeros.to_vec(),
        })
    }

    /// Dequantize row r into `out` (testing / debugging).
    pub fn dequant_row(&self, r: usize, out: &mut [f32]) {
        let bits = self.scheme.bits as usize;
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpr = self.words_per_row();
        let row = &self.words[r * wpr..(r + 1) * wpr];
        let mask = (1u32 << bits) - 1;
        let mut bitpos = 0usize;
        for k in 0..self.in_dim {
            let mut v = row[bitpos >> 5] >> (bitpos & 31);
            if (bitpos & 31) + bits > 32 {
                v |= row[(bitpos >> 5) + 1] << (32 - (bitpos & 31));
            }
            let q = (v & mask) as f32;
            let gi = k / g;
            out[k] = (q - self.zeros[r * gpr + gi])
                * self.scales[r * gpr + gi];
            bitpos += bits;
        }
    }

    /// y = W_hat @ x  (matvec; x len = in_dim, y len = out_dim).
    ///
    /// Per group: y_r += s * (sum_k q_k x_k - z * sum_k x_k); the group
    /// sums of x are precomputed once per call and shared across rows.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(y.len(), self.out_dim);
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        // group sums of x (shared across all rows)
        let mut sx = vec![0f32; gpr];
        for (gi, s) in sx.iter_mut().enumerate() {
            *s = x[gi * g..(gi + 1) * g].iter().sum();
        }
        match self.scheme.bits {
            2 => self.matvec_b2(x, y, &sx),
            4 => self.matvec_b4(x, y, &sx),
            _ => self.matvec_generic(x, y, &sx),
        }
    }

    fn matvec_b2(&self, x: &[f32], y: &mut [f32], sx: &[f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 2 / 32; // words per group
        let wpr = self.words_per_row();
        for r in 0..self.out_dim {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                // §Perf: 4 independent accumulators + direct-shift nibble
                // extraction (no serial `v >>= 2` dependency chain) lets
                // the CPU pipeline the FMAs; ~1.6x over the naive loop.
                let xs = &x[gi * g..(gi + 1) * g];
                let (mut d0, mut d1, mut d2, mut d3) =
                    (0f32, 0f32, 0f32, 0f32);
                for (wi, &w) in
                    row[gi * wpg..(gi + 1) * wpg].iter().enumerate()
                {
                    let xb = &xs[wi * 16..(wi + 1) * 16];
                    d0 += (w & 3) as f32 * xb[0]
                        + ((w >> 8) & 3) as f32 * xb[4]
                        + ((w >> 16) & 3) as f32 * xb[8]
                        + ((w >> 24) & 3) as f32 * xb[12];
                    d1 += ((w >> 2) & 3) as f32 * xb[1]
                        + ((w >> 10) & 3) as f32 * xb[5]
                        + ((w >> 18) & 3) as f32 * xb[9]
                        + ((w >> 26) & 3) as f32 * xb[13];
                    d2 += ((w >> 4) & 3) as f32 * xb[2]
                        + ((w >> 12) & 3) as f32 * xb[6]
                        + ((w >> 20) & 3) as f32 * xb[10]
                        + ((w >> 28) & 3) as f32 * xb[14];
                    d3 += ((w >> 6) & 3) as f32 * xb[3]
                        + ((w >> 14) & 3) as f32 * xb[7]
                        + ((w >> 22) & 3) as f32 * xb[11]
                        + ((w >> 30) & 3) as f32 * xb[15];
                }
                let dot = (d0 + d1) + (d2 + d3);
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            y[r] = acc;
        }
    }

    fn matvec_b4(&self, x: &[f32], y: &mut [f32], sx: &[f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 4 / 32;
        let wpr = self.words_per_row();
        for r in 0..self.out_dim {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let mut dot = 0f32;
                let xs = &x[gi * g..(gi + 1) * g];
                // §Perf: direct-shift extraction, two accumulators
                let mut dot2 = 0f32;
                for (wi, &w) in
                    row[gi * wpg..(gi + 1) * wpg].iter().enumerate()
                {
                    let xb = &xs[wi * 8..(wi + 1) * 8];
                    dot += (w & 15) as f32 * xb[0]
                        + ((w >> 8) & 15) as f32 * xb[2]
                        + ((w >> 16) & 15) as f32 * xb[4]
                        + ((w >> 24) & 15) as f32 * xb[6];
                    dot2 += ((w >> 4) & 15) as f32 * xb[1]
                        + ((w >> 12) & 15) as f32 * xb[3]
                        + ((w >> 20) & 15) as f32 * xb[5]
                        + ((w >> 28) & 15) as f32 * xb[7];
                }
                dot += dot2;
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            y[r] = acc;
        }
    }

    /// Any bit width (3-bit path): u64 sliding window over the bitstream.
    fn matvec_generic(&self, x: &[f32], y: &mut [f32], sx: &[f32]) {
        let bits = self.scheme.bits as usize;
        let mask = (1u64 << bits) - 1;
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * bits / 32;
        let wpr = self.words_per_row();
        for r in 0..self.out_dim {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let gw = &row[gi * wpg..(gi + 1) * wpg];
                let xs = &x[gi * g..(gi + 1) * g];
                let mut dot = 0f32;
                let mut buf: u64 = 0;
                let mut nbits = 0usize;
                let mut wi = 0usize;
                for &xv in xs {
                    if nbits < bits {
                        buf |= (gw[wi] as u64) << nbits;
                        nbits += 32;
                        wi += 1;
                    }
                    dot += (buf & mask) as f32 * xv;
                    buf >>= bits;
                    nbits -= bits;
                }
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            y[r] = acc;
        }
    }
}

/// Dense f32 matvec baseline (the "FP16" comparator of Table 10; CPU has no
/// native f16 math - f32 moves 2x the bytes of f16, so reported speedups
/// are conservative vs the paper's).
pub fn dense_matvec(w: &[f32], out_dim: usize, in_dim: usize, x: &[f32],
                    y: &mut [f32]) {
    for r in 0..out_dim {
        let row = &w[r * in_dim..(r + 1) * in_dim];
        let mut acc = 0f32;
        for k in 0..in_dim {
            acc += row[k] * x[k];
        }
        y[r] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::{dequantize, minmax_init, quantize};
    use crate::util::rng::Rng;

    fn setup(bits: u32, group: usize, out_d: usize, in_d: usize, seed: u64)
             -> (PackedLinear, Vec<f32>) {
        let sch = QuantScheme::new(bits, group);
        let mut r = Rng::new(seed);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 0.5);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let w_hat = dequantize(&wi, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)
            .unwrap();
        (pl, w_hat)
    }

    #[test]
    fn matvec_matches_dense_dequant_all_bits() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (24, 128, 32);
            let (pl, w_hat) = setup(bits, g, out_d, in_d, 60 + bits as u64);
            let mut r = Rng::new(61);
            let mut x = vec![0f32; in_d];
            r.fill_normal(&mut x, 0.0, 1.0);
            let mut y_packed = vec![0f32; out_d];
            let mut y_dense = vec![0f32; out_d];
            pl.matvec(&x, &mut y_packed);
            dense_matvec(&w_hat, out_d, in_d, &x, &mut y_dense);
            for (a, b) in y_packed.iter().zip(&y_dense) {
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "bits={bits}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn dequant_row_roundtrip() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (8, 64, 32);
            let (pl, w_hat) = setup(bits, g, out_d, in_d, 70 + bits as u64);
            let mut row = vec![0f32; in_d];
            for r in 0..out_d {
                pl.dequant_row(r, &mut row);
                for k in 0..in_d {
                    assert!(
                        (row[k] - w_hat[r * in_d + k]).abs() < 1e-6,
                        "bits={bits} r={r} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_is_8x_smaller_at_2bit() {
        let (pl, _) = setup(2, 32, 16, 128, 80);
        let packed_bytes = pl.words.len() * 4;
        let dense_bytes = 16 * 128 * 4;
        assert_eq!(dense_bytes / packed_bytes, 16); // f32 vs 2-bit
    }

    #[test]
    fn pack_rejects_unaligned_and_bad_values() {
        let sch = QuantScheme::new(3, 8); // 24 bits per group: unaligned
        assert!(PackedLinear::pack(&[0.0; 64], 4, 16, &[1.0; 8], &[0.0; 8],
                                   sch).is_err());
        let sch2 = QuantScheme::new(2, 32);
        let mut w = vec![0f32; 32];
        w[5] = 9.0; // out of range for 2 bits
        assert!(PackedLinear::pack(&w, 1, 32, &[1.0], &[0.0], sch2).is_err());
    }
}
