//! Packed low-bit linear layers for the pure-Rust deployment path - the
//! BitBLAS analog behind paper Table 10.
//!
//! Why INT2 wins on matvec: token generation is weight-memory-bandwidth
//! bound; packed 2-bit weights move 8x fewer bytes than f32 (16x fewer than
//! the f32 path's working set per value). The compute added by unpacking
//! (shift+mask+FMA) is cheap relative to the saved memory traffic - on CPU
//! exactly as on GPU.
//!
//! Storage: per output row, groups are contiguous; each group's g values
//! occupy exactly g*bits/32 u32 words (all supported schemes have
//! 32 | g*bits, so groups are word-aligned). Per group: one f32 scale, one
//! f32 zero point (dequantized from the f16/N-bit stored forms at load).
//!
//! # Batching and threading (the serving hot path)
//!
//! Two levers turn the bandwidth win into wall-clock throughput:
//!
//! - [`PackedLinear::matmul`] applies one weight matrix to a whole batch of
//!   token activations. Each weight group is unpacked (shift+mask) once per
//!   thread and the dequantized values are re-used across every token in
//!   the batch, so the unpack cost - which `matvec` pays on every call -
//!   amortizes to ~1/n_tokens. This is what makes batched prefill >>
//!   sequential `step()` loops (see `bench::inference_throughput`).
//! - All of `matvec` / `matmul` / `dense_matvec` / `dense_matmul`
//!   parallelize across output-row (resp. token) chunks on the
//!   **persistent worker pool** in `util::threads` (`EQAT_THREADS` to
//!   override the worker count). The lm-head matvec over `vocab` rows is
//!   the single largest serial loop in decode; row-chunking it is most of
//!   the multi-thread decode speedup, and because the pool dispatches
//!   without spawning threads, every decode step pays ~zero threading
//!   latency (the old scoped-thread design spawned/joined per call).
//!
//! Determinism: each output element is produced by exactly one worker with
//! a fixed instruction order, so results are bit-identical across thread
//! counts; `matmul` replicates `matvec`'s per-group accumulation order
//! exactly (same FMA lanes), so batched and per-token paths are bit-exact
//! too. Both properties are locked in by tests below.
//!
//! §Perf: 2-bit matvec beats f32 dense single-threaded because it is
//! memory-bound and moves 16x fewer weight bytes (Table 10's mechanism).
//! The 2/4-bit unpack+FMA inner loops and the dense dot microkernel live
//! in `util::simd` as explicitly vectorized primitives (AVX2/NEON behind
//! runtime detection, `EQAT_SIMD` to override) whose vector paths are
//! **bit-identical** to their scalar references - the fixed 16/8-lane
//! word layout maps one-to-one onto SIMD lanes, so vectorizing changes
//! which instructions run, never which bits come out. Row-chunk scaling
//! extends to small layers because pool dispatch costs ~1-2us (so
//! `PAR_MIN_WORK` sits low). Current numbers: run
//! `eqat bench inference` and read the table / `runs/bench.json`
//! (`kernels` section for scalar-vs-SIMD side by side).

use anyhow::{bail, Result};

use crate::config::QuantScheme;
use crate::util::simd;
use crate::util::threads;

/// Below this many multiply-accumulates per call, a kernel stays serial.
/// With the persistent pool a parallel section costs ~1-2us of dispatch
/// (vs ~tens of us when every call spawned scoped threads), so the
/// break-even sits far lower than the old `1 << 18`.
const PAR_MIN_WORK: usize = 1 << 15;

#[derive(Clone)]
pub struct PackedLinear {
    pub out_dim: usize,
    pub in_dim: usize,
    pub scheme: QuantScheme,
    /// u32 words, row-major: row r occupies words [r*wpr, (r+1)*wpr)
    pub words: Vec<u32>,
    /// (out * groups_per_row) scales
    pub scales: Vec<f32>,
    /// (out * groups_per_row) zero points
    pub zeros: Vec<f32>,
}

impl PackedLinear {
    pub fn words_per_row(&self) -> usize {
        self.in_dim * self.scheme.bits as usize / 32
    }

    pub fn groups_per_row(&self) -> usize {
        self.in_dim / self.scheme.group
    }

    /// Pack from integer-valued f32 weights (wq layout) + group params.
    pub fn pack(
        w_int: &[f32],
        out_dim: usize,
        in_dim: usize,
        scales: &[f32],
        zeros: &[f32],
        scheme: QuantScheme,
    ) -> Result<PackedLinear> {
        let bits = scheme.bits as usize;
        if in_dim * bits % 32 != 0 || scheme.group * bits % 32 != 0 {
            bail!("group {}x{}bit not word-aligned", scheme.group, bits);
        }
        if w_int.len() != out_dim * in_dim {
            bail!("w_int size mismatch");
        }
        let wpr = in_dim * bits / 32;
        let mut words = vec![0u32; out_dim * wpr];
        for r in 0..out_dim {
            let row = &w_int[r * in_dim..(r + 1) * in_dim];
            let out_row = &mut words[r * wpr..(r + 1) * wpr];
            let mut bitpos = 0usize;
            for &q in row {
                if q < 0.0 || q > scheme.qmax() || q.fract() != 0.0 {
                    bail!("bad quantized value {q}");
                }
                let v = q as u32;
                out_row[bitpos >> 5] |= v << (bitpos & 31);
                if (bitpos & 31) + bits > 32 {
                    out_row[(bitpos >> 5) + 1] |= v >> (32 - (bitpos & 31));
                }
                bitpos += bits;
            }
        }
        Ok(PackedLinear {
            out_dim,
            in_dim,
            scheme,
            words,
            scales: scales.to_vec(),
            zeros: zeros.to_vec(),
        })
    }

    /// Dequantize row r into `out` (testing / debugging).
    pub fn dequant_row(&self, r: usize, out: &mut [f32]) {
        let bits = self.scheme.bits as usize;
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpr = self.words_per_row();
        let row = &self.words[r * wpr..(r + 1) * wpr];
        let mask = (1u32 << bits) - 1;
        let mut bitpos = 0usize;
        for k in 0..self.in_dim {
            let mut v = row[bitpos >> 5] >> (bitpos & 31);
            if (bitpos & 31) + bits > 32 {
                v |= row[(bitpos >> 5) + 1] << (32 - (bitpos & 31));
            }
            let q = (v & mask) as f32;
            let gi = k / g;
            out[k] = (q - self.zeros[r * gpr + gi])
                * self.scales[r * gpr + gi];
            bitpos += bits;
        }
    }

    /// y = W_hat @ x  (matvec; x len = in_dim, y len = out_dim).
    ///
    /// Per group: y_r += s * (sum_k q_k x_k - z * sum_k x_k); the group
    /// sums of x are precomputed once per call and shared across rows.
    /// Output rows are chunked across threads for large layers.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let mut sx = Vec::new();
        self.matvec_in(x, y, &mut sx);
    }

    /// `matvec` with a caller-provided group-sum scratch buffer, so
    /// steady-state decode does zero heap allocation (the buffer is
    /// resized once and re-used across calls/layers).
    pub fn matvec_in(&self, x: &[f32], y: &mut [f32], sx: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(y.len(), self.out_dim);
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        // group sums of x (shared across all rows)
        sx.resize(gpr, 0.0);
        for (gi, s) in sx.iter_mut().enumerate() {
            *s = x[gi * g..(gi + 1) * g].iter().sum();
        }
        let rows = if self.out_dim * self.in_dim < PAR_MIN_WORK {
            self.out_dim
        } else {
            threads::chunk_len(self.out_dim)
        };
        let sxr: &[f32] = &sx[..];
        threads::par_chunks_mut(y, rows, |ci, yc| {
            let r0 = ci * rows;
            match self.scheme.bits {
                2 => self.matvec_rows_b2(x, sxr, r0, yc),
                3 => self.matvec_rows_b3(x, sxr, r0, yc),
                4 => self.matvec_rows_b4(x, sxr, r0, yc),
                _ => self.matvec_rows_generic(x, sxr, r0, yc),
            }
        });
    }

    /// ys = xs @ W_hat^T for a whole token batch (the prefill/eval path).
    ///
    /// Layouts are token-major: `xs[t*in_dim + k]`, `ys[t*out_dim + r]`.
    /// Each weight group is unpacked once and applied to every token,
    /// amortizing the shift/mask work `matvec` pays per call; tokens are
    /// chunked across threads. Accumulation order per (token, row) matches
    /// `matvec` exactly, so results are bit-identical to per-token matvec
    /// calls (tested).
    pub fn matmul(&self, xs: &[f32], n_tokens: usize, ys: &mut [f32]) {
        let mut sxs = Vec::new();
        self.matmul_in(xs, n_tokens, ys, &mut sxs);
    }

    /// `matmul` with a caller-provided group-sum scratch buffer (the
    /// `matvec_in` analog): steady-state prefill/eval reuses one buffer
    /// across calls/layers, so the batched decode+prefill path does zero
    /// heap allocation per call.
    pub fn matmul_in(&self, xs: &[f32], n_tokens: usize, ys: &mut [f32],
                     sxs: &mut Vec<f32>) {
        debug_assert_eq!(xs.len(), n_tokens * self.in_dim);
        debug_assert_eq!(ys.len(), n_tokens * self.out_dim);
        if n_tokens == 0 {
            return;
        }
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let d = self.in_dim;
        // per-token group sums, same accumulation order as matvec's
        sxs.resize(n_tokens * gpr, 0.0);
        for t in 0..n_tokens {
            let x = &xs[t * d..(t + 1) * d];
            let st = &mut sxs[t * gpr..(t + 1) * gpr];
            for (gi, s) in st.iter_mut().enumerate() {
                *s = x[gi * g..(gi + 1) * g].iter().sum();
            }
        }
        let tpc = if n_tokens * self.out_dim * d < PAR_MIN_WORK {
            n_tokens
        } else {
            threads::chunk_len(n_tokens)
        };
        let sxr: &[f32] = &sxs[..];
        threads::par_chunks_mut(ys, tpc * self.out_dim, |ci, yc| {
            let t0 = ci * tpc;
            let nt = yc.len() / self.out_dim;
            let xc = &xs[t0 * d..(t0 + nt) * d];
            let sc = &sxr[t0 * gpr..(t0 + nt) * gpr];
            match self.scheme.bits {
                2 => self.matmul_tokens_b2(xc, nt, sc, yc),
                3 => self.matmul_tokens_b3(xc, nt, sc, yc),
                4 => self.matmul_tokens_b4(xc, nt, sc, yc),
                _ => self.matmul_tokens_generic(xc, nt, sc, yc),
            }
        });
    }

    /// Like [`PackedLinear::matmul`] but parallelized across **output
    /// rows** instead of tokens - the batched-*decode* shape (a handful
    /// of tokens, thousands of rows). Token-chunking degenerates there:
    /// with fewer tokens than workers each chunk re-unpacks every weight
    /// group, so the unpack amortization the batch exists for is lost.
    /// Here each worker owns a row range, unpacks each of its groups
    /// exactly once, and applies it to every token - total unpack work
    /// stays one pass over the matrix regardless of the worker count.
    ///
    /// Accumulation per (token, row) replicates `matvec` exactly (same
    /// group order, same FMA lanes), so results are bit-identical to
    /// per-token `matvec` calls and to `matmul` (tested). Workers write a
    /// row-major scratch (`tmp`, resized to out_dim * n_tokens) that is
    /// transposed into the token-major `ys` at the end; `tmp`/`sx` are
    /// caller-provided so steady-state batched decode allocates nothing.
    pub fn matmul_rows(&self, xs: &[f32], n_tokens: usize, ys: &mut [f32],
                       tmp: &mut Vec<f32>, sx: &mut Vec<f32>) {
        debug_assert_eq!(xs.len(), n_tokens * self.in_dim);
        debug_assert_eq!(ys.len(), n_tokens * self.out_dim);
        if n_tokens == 0 {
            return;
        }
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * self.scheme.bits as usize / 32;
        let wpr = self.words_per_row();
        let (d, od) = (self.in_dim, self.out_dim);
        // per-token group sums, same accumulation order as matvec's
        sx.resize(n_tokens * gpr, 0.0);
        for t in 0..n_tokens {
            let x = &xs[t * d..(t + 1) * d];
            let st = &mut sx[t * gpr..(t + 1) * gpr];
            for (gi, s) in st.iter_mut().enumerate() {
                *s = x[gi * g..(gi + 1) * g].iter().sum();
            }
        }
        tmp.resize(od * n_tokens, 0.0);
        let rpc = if n_tokens * od * d < PAR_MIN_WORK {
            od
        } else {
            threads::chunk_len(od)
        };
        let sxr: &[f32] = &sx[..];
        threads::par_chunks_mut(&mut tmp[..od * n_tokens], rpc * n_tokens,
                                |ci, tc| {
            let r0 = ci * rpc;
            let mut qbuf = [0f32; MAX_STACK_GROUP];
            let mut qheap: Vec<f32> = Vec::new();
            let qb: &mut [f32] = if g <= MAX_STACK_GROUP {
                &mut qbuf[..g]
            } else {
                qheap.resize(g, 0.0);
                &mut qheap[..]
            };
            for (rl, tr) in tc.chunks_mut(n_tokens).enumerate() {
                let r = r0 + rl;
                let row = &self.words[r * wpr..(r + 1) * wpr];
                tr.fill(0.0);
                for gi in 0..gpr {
                    self.unpack_group(&row[gi * wpg..(gi + 1) * wpg], qb);
                    let s = self.scales[r * gpr + gi];
                    let z = self.zeros[r * gpr + gi];
                    for (t, acc) in tr.iter_mut().enumerate() {
                        let xg =
                            &xs[t * d + gi * g..t * d + (gi + 1) * g];
                        let dot = group_dot(self.scheme.bits, qb, xg);
                        *acc += s * (dot - z * sxr[t * gpr + gi]);
                    }
                }
            }
        });
        for r in 0..od {
            for t in 0..n_tokens {
                ys[t * od + r] = tmp[r * n_tokens + t];
            }
        }
    }

    /// Unpack one group's packed words into `qb` (len = group), with the
    /// same per-word lane order as every other kernel.
    #[inline]
    fn unpack_group(&self, gw: &[u32], qb: &mut [f32]) {
        match self.scheme.bits {
            2 => simd::unpack_b2(gw, qb),
            3 => simd::unpack_b3(gw, qb),
            4 => simd::unpack_b4(gw, qb),
            _ => {
                let bits = self.scheme.bits as usize;
                let mask = (1u64 << bits) - 1;
                let mut buf: u64 = 0;
                let mut nbits = 0usize;
                let mut wi = 0usize;
                for qv in qb.iter_mut() {
                    if nbits < bits {
                        buf |= (gw[wi] as u64) << nbits;
                        nbits += 32;
                        wi += 1;
                    }
                    *qv = (buf & mask) as f32;
                    buf >>= bits;
                    nbits -= bits;
                }
            }
        }
    }

    fn matvec_rows_b2(&self, x: &[f32], sx: &[f32], r0: usize,
                      y: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 2 / 32; // words per group
        let wpr = self.words_per_row();
        // Unpack+FMA lives in `util::simd::group_dot_packed_b2`: each u32
        // word carries 16 2-bit lanes that map one-to-one onto vector
        // lanes (AVX2/NEON when detected, scalar reference otherwise),
        // with the 4-accumulator lane order shared by `matmul_tokens_b2`
        // pinned bit-identical across every ISA.
        for (j, yr) in y.iter_mut().enumerate() {
            let r = r0 + j;
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let dot = simd::group_dot_packed_b2(
                    &row[gi * wpg..(gi + 1) * wpg],
                    &x[gi * g..(gi + 1) * g],
                );
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            *yr = acc;
        }
    }

    fn matvec_rows_b4(&self, x: &[f32], sx: &[f32], r0: usize,
                      y: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 4 / 32;
        let wpr = self.words_per_row();
        // Unpack+FMA lives in `util::simd::group_dot_packed_b4`: 8 4-bit
        // lanes per word, even/odd accumulator pair matching
        // `matmul_tokens_b4`, bit-identical on every ISA.
        for (j, yr) in y.iter_mut().enumerate() {
            let r = r0 + j;
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let dot = simd::group_dot_packed_b4(
                    &row[gi * wpg..(gi + 1) * wpg],
                    &x[gi * g..(gi + 1) * g],
                );
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            *yr = acc;
        }
    }

    fn matvec_rows_b3(&self, x: &[f32], sx: &[f32], r0: usize,
                      y: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 3 / 32; // word-aligned: pack() enforces 32 | 3g
        let wpr = self.words_per_row();
        // Unpack+FMA lives in `util::simd::group_dot_packed_b3`: a u64
        // window slides over the bitstream and feeds 8 3-bit lanes per
        // 24-bit chunk, with the 8-partial reduce8 tree shared by
        // `matmul_tokens_b3` / `group_dot_b3`, bit-identical on every
        // ISA.
        for (j, yr) in y.iter_mut().enumerate() {
            let r = r0 + j;
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let dot = simd::group_dot_packed_b3(
                    &row[gi * wpg..(gi + 1) * wpg],
                    &x[gi * g..(gi + 1) * g],
                );
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            *yr = acc;
        }
    }

    /// Any bit width (non-2/3/4 fallback): u64 sliding window over the
    /// bitstream, sequential accumulation.
    fn matvec_rows_generic(&self, x: &[f32], sx: &[f32], r0: usize,
                           y: &mut [f32]) {
        let bits = self.scheme.bits as usize;
        let mask = (1u64 << bits) - 1;
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * bits / 32;
        let wpr = self.words_per_row();
        for (j, yr) in y.iter_mut().enumerate() {
            let r = r0 + j;
            let row = &self.words[r * wpr..(r + 1) * wpr];
            let mut acc = 0f32;
            for gi in 0..gpr {
                let gw = &row[gi * wpg..(gi + 1) * wpg];
                let xs = &x[gi * g..(gi + 1) * g];
                let mut dot = 0f32;
                let mut buf: u64 = 0;
                let mut nbits = 0usize;
                let mut wi = 0usize;
                for &xv in xs {
                    if nbits < bits {
                        buf |= (gw[wi] as u64) << nbits;
                        nbits += 32;
                        wi += 1;
                    }
                    dot += (buf & mask) as f32 * xv;
                    buf >>= bits;
                    nbits -= bits;
                }
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                acc += s * (dot - z * sx[gi]);
            }
            *yr = acc;
        }
    }

    /// Batched 2-bit kernel: unpack each group once into `qbuf`, then run
    /// the exact same 4-lane accumulation as `matvec_rows_b2` per token
    /// (same FP order -> bit-exact with the matvec path).
    fn matmul_tokens_b2(&self, xs: &[f32], n_tokens: usize, sxs: &[f32],
                        ys: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 2 / 32;
        let wpr = self.words_per_row();
        let (d, od) = (self.in_dim, self.out_dim);
        let mut qbuf = vec![0f32; g];
        for v in ys.iter_mut() {
            *v = 0.0;
        }
        for r in 0..od {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            for gi in 0..gpr {
                simd::unpack_b2(&row[gi * wpg..(gi + 1) * wpg],
                                &mut qbuf);
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                for t in 0..n_tokens {
                    let xg = &xs[t * d + gi * g..t * d + (gi + 1) * g];
                    let dot = simd::group_dot_b2(&qbuf, xg);
                    ys[t * od + r] += s * (dot - z * sxs[t * gpr + gi]);
                }
            }
        }
    }

    /// Batched 4-bit kernel; see `matmul_tokens_b2` for the scheme.
    fn matmul_tokens_b4(&self, xs: &[f32], n_tokens: usize, sxs: &[f32],
                        ys: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 4 / 32;
        let wpr = self.words_per_row();
        let (d, od) = (self.in_dim, self.out_dim);
        let mut qbuf = vec![0f32; g];
        for v in ys.iter_mut() {
            *v = 0.0;
        }
        for r in 0..od {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            for gi in 0..gpr {
                simd::unpack_b4(&row[gi * wpg..(gi + 1) * wpg],
                                &mut qbuf);
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                for t in 0..n_tokens {
                    let xg = &xs[t * d + gi * g..t * d + (gi + 1) * g];
                    let dot = simd::group_dot_b4(&qbuf, xg);
                    ys[t * od + r] += s * (dot - z * sxs[t * gpr + gi]);
                }
            }
        }
    }

    /// Batched 3-bit kernel: unpack each group once, then the 8-lane
    /// group dot per token (same reduce8 tree as `matvec_rows_b3`).
    fn matmul_tokens_b3(&self, xs: &[f32], n_tokens: usize, sxs: &[f32],
                        ys: &mut [f32]) {
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * 3 / 32;
        let wpr = self.words_per_row();
        let (d, od) = (self.in_dim, self.out_dim);
        let mut qbuf = vec![0f32; g];
        for v in ys.iter_mut() {
            *v = 0.0;
        }
        for r in 0..od {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            for gi in 0..gpr {
                simd::unpack_b3(&row[gi * wpg..(gi + 1) * wpg],
                                &mut qbuf);
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                for t in 0..n_tokens {
                    let xg = &xs[t * d + gi * g..t * d + (gi + 1) * g];
                    let dot = simd::group_dot_b3(&qbuf, xg);
                    ys[t * od + r] += s * (dot - z * sxs[t * gpr + gi]);
                }
            }
        }
    }

    /// Batched any-bit kernel (non-2/3/4 fallback): sliding-window unpack
    /// once per group, sequential dot per token (matches
    /// `matvec_rows_generic`).
    fn matmul_tokens_generic(&self, xs: &[f32], n_tokens: usize,
                             sxs: &[f32], ys: &mut [f32]) {
        let bits = self.scheme.bits as usize;
        let mask = (1u64 << bits) - 1;
        let g = self.scheme.group;
        let gpr = self.groups_per_row();
        let wpg = g * bits / 32;
        let wpr = self.words_per_row();
        let (d, od) = (self.in_dim, self.out_dim);
        let mut qbuf = vec![0f32; g];
        for v in ys.iter_mut() {
            *v = 0.0;
        }
        for r in 0..od {
            let row = &self.words[r * wpr..(r + 1) * wpr];
            for gi in 0..gpr {
                let gw = &row[gi * wpg..(gi + 1) * wpg];
                let mut buf: u64 = 0;
                let mut nbits = 0usize;
                let mut wi = 0usize;
                for qv in qbuf.iter_mut() {
                    if nbits < bits {
                        buf |= (gw[wi] as u64) << nbits;
                        nbits += 32;
                        wi += 1;
                    }
                    *qv = (buf & mask) as f32;
                    buf >>= bits;
                    nbits -= bits;
                }
                let s = self.scales[r * gpr + gi];
                let z = self.zeros[r * gpr + gi];
                for t in 0..n_tokens {
                    let xg = &xs[t * d + gi * g..t * d + (gi + 1) * g];
                    let mut dot = 0f32;
                    for (qv, xv) in qbuf.iter().zip(xg) {
                        dot += qv * xv;
                    }
                    ys[t * od + r] += s * (dot - z * sxs[t * gpr + gi]);
                }
            }
        }
    }
}

/// Largest group unpacked on the stack by `matmul_rows`; bigger groups
/// (none of the shipped schemes) fall back to a per-worker heap buffer.
const MAX_STACK_GROUP: usize = 256;

/// One group's dot product with the exact FMA lane order of the matvec
/// kernels: 2-bit uses 4 accumulators over 16-lane word chunks, 3-bit
/// the 8-partial reduce8 tree, 4-bit 2 accumulators over 8-lane chunks,
/// everything else a sequential loop - so any kernel built on it is
/// bit-identical to `matvec`.
#[inline]
fn group_dot(bits: u32, qb: &[f32], xg: &[f32]) -> f32 {
    match bits {
        2 => simd::group_dot_b2(qb, xg),
        3 => simd::group_dot_b3(qb, xg),
        4 => simd::group_dot_b4(qb, xg),
        _ => {
            let mut dot = 0f32;
            for (qv, xv) in qb.iter().zip(xg) {
                dot += qv * xv;
            }
            dot
        }
    }
}

/// Dense f32 matvec baseline (the "FP16" comparator of Table 10; CPU has no
/// native f16 math - f32 moves 2x the bytes of f16, so reported speedups
/// are conservative vs the paper's). Row-chunked across threads for large
/// layers, like the packed kernels. The dot runs on the `util::simd`
/// microkernel: rows are processed in register-blocked pairs sharing the
/// activation loads (`dot8_x2`), each row's bits equal to a standalone
/// [`simd::dot8`] - so pairing parity and worker-chunk boundaries never
/// change results.
pub fn dense_matvec(w: &[f32], out_dim: usize, in_dim: usize, x: &[f32],
                    y: &mut [f32]) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(y.len(), out_dim);
    let rows = if out_dim * in_dim < PAR_MIN_WORK {
        out_dim
    } else {
        threads::chunk_len(out_dim)
    };
    threads::par_chunks_mut(y, rows, |ci, yc| {
        let r0 = ci * rows;
        let mut j = 0;
        while j + 1 < yc.len() {
            let r = r0 + j;
            let (a, b) = simd::dot8_x2(
                &w[r * in_dim..(r + 1) * in_dim],
                &w[(r + 1) * in_dim..(r + 2) * in_dim],
                x,
            );
            yc[j] = a;
            yc[j + 1] = b;
            j += 2;
        }
        if j < yc.len() {
            let r = r0 + j;
            yc[j] = simd::dot8(&w[r * in_dim..(r + 1) * in_dim], x);
        }
    });
}

/// Dense f32 batched matmul (token-major, like `PackedLinear::matmul`):
/// `ys[t*out_dim + r] = W[r] . xs[t]`. Token-chunked across threads; per
/// token the accumulation order matches `dense_matvec` (bit-exact).
pub fn dense_matmul(w: &[f32], out_dim: usize, in_dim: usize, xs: &[f32],
                    n_tokens: usize, ys: &mut [f32]) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(xs.len(), n_tokens * in_dim);
    debug_assert_eq!(ys.len(), n_tokens * out_dim);
    if n_tokens == 0 {
        return;
    }
    let tpc = if n_tokens * out_dim * in_dim < PAR_MIN_WORK {
        n_tokens
    } else {
        threads::chunk_len(n_tokens)
    };
    threads::par_chunks_mut(ys, tpc * out_dim, |ci, yc| {
        let t0 = ci * tpc;
        let nt = yc.len() / out_dim;
        for tl in 0..nt {
            let x = &xs[(t0 + tl) * in_dim..(t0 + tl + 1) * in_dim];
            let yt = &mut yc[tl * out_dim..(tl + 1) * out_dim];
            let mut r = 0;
            while r + 1 < out_dim {
                let (a, b) = simd::dot8_x2(
                    &w[r * in_dim..(r + 1) * in_dim],
                    &w[(r + 1) * in_dim..(r + 2) * in_dim],
                    x,
                );
                yt[r] = a;
                yt[r + 1] = b;
                r += 2;
            }
            if r < out_dim {
                yt[r] = simd::dot8(&w[r * in_dim..(r + 1) * in_dim], x);
            }
        }
    });
}

/// Row-parallel dense batched matmul, the `matmul_rows` sibling for the
/// dense lm head in batched decode: each worker streams its row range of
/// `w` once and applies every row to all tokens (the token-outer
/// `dense_matmul` re-streams the whole matrix per token - ruinous for a
/// memory-bound head at small batch). Per (token, row) the accumulation
/// matches `dense_matvec` exactly (bit-identical, tested). `tmp` is the
/// caller-provided row-major scratch (resized to out_dim * n_tokens).
pub fn dense_matmul_rows(w: &[f32], out_dim: usize, in_dim: usize,
                         xs: &[f32], n_tokens: usize, ys: &mut [f32],
                         tmp: &mut Vec<f32>) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(xs.len(), n_tokens * in_dim);
    debug_assert_eq!(ys.len(), n_tokens * out_dim);
    if n_tokens == 0 {
        return;
    }
    tmp.resize(out_dim * n_tokens, 0.0);
    let rpc = if n_tokens * out_dim * in_dim < PAR_MIN_WORK {
        out_dim
    } else {
        threads::chunk_len(out_dim)
    };
    threads::par_chunks_mut(&mut tmp[..out_dim * n_tokens],
                            rpc * n_tokens, |ci, tc| {
        let r0 = ci * rpc;
        // row pairs share each token's activation loads (dot8_x2); a
        // lone trailing row in the chunk falls back to dot8 - per-row
        // bits are identical either way
        for (pi, pr) in tc.chunks_mut(2 * n_tokens).enumerate() {
            let r = r0 + 2 * pi;
            if pr.len() == 2 * n_tokens {
                let (tr0, tr1) = pr.split_at_mut(n_tokens);
                let row0 = &w[r * in_dim..(r + 1) * in_dim];
                let row1 = &w[(r + 1) * in_dim..(r + 2) * in_dim];
                for t in 0..n_tokens {
                    let x = &xs[t * in_dim..(t + 1) * in_dim];
                    let (a, b) = simd::dot8_x2(row0, row1, x);
                    tr0[t] = a;
                    tr1[t] = b;
                }
            } else {
                let row = &w[r * in_dim..(r + 1) * in_dim];
                for (t, yv) in pr.iter_mut().enumerate() {
                    let x = &xs[t * in_dim..(t + 1) * in_dim];
                    *yv = simd::dot8(row, x);
                }
            }
        }
    });
    for r in 0..out_dim {
        for t in 0..n_tokens {
            ys[t * out_dim + r] = tmp[r * n_tokens + t];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::{dequantize, minmax_init, quantize};
    use crate::util::rng::Rng;
    use crate::util::threads::with_threads;

    fn setup(bits: u32, group: usize, out_d: usize, in_d: usize, seed: u64)
             -> (PackedLinear, Vec<f32>) {
        let sch = QuantScheme::new(bits, group);
        let mut r = Rng::new(seed);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 0.5);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let wi = quantize(&w, &gp, sch);
        let w_hat = dequantize(&wi, &gp, sch);
        let pl = PackedLinear::pack(&wi, out_d, in_d, &gp.s, &gp.z, sch)
            .unwrap();
        (pl, w_hat)
    }

    #[test]
    fn matvec_matches_dense_dequant_all_bits() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (24, 128, 32);
            let (pl, w_hat) = setup(bits, g, out_d, in_d, 60 + bits as u64);
            let mut r = Rng::new(61);
            let mut x = vec![0f32; in_d];
            r.fill_normal(&mut x, 0.0, 1.0);
            let mut y_packed = vec![0f32; out_d];
            let mut y_dense = vec![0f32; out_d];
            pl.matvec(&x, &mut y_packed);
            dense_matvec(&w_hat, out_d, in_d, &x, &mut y_dense);
            for (a, b) in y_packed.iter().zip(&y_dense) {
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "bits={bits}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn matmul_is_bitexact_with_matvec_all_bits() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (24, 128, 32);
            let (pl, _) = setup(bits, g, out_d, in_d, 90 + bits as u64);
            let n_tok = 5;
            let mut r = Rng::new(91);
            let mut xs = vec![0f32; n_tok * in_d];
            r.fill_normal(&mut xs, 0.0, 1.0);
            let mut ys = vec![0f32; n_tok * out_d];
            pl.matmul(&xs, n_tok, &mut ys);
            let mut y = vec![0f32; out_d];
            for t in 0..n_tok {
                pl.matvec(&xs[t * in_d..(t + 1) * in_d], &mut y);
                for rr in 0..out_d {
                    assert_eq!(
                        ys[t * out_d + rr].to_bits(),
                        y[rr].to_bits(),
                        "bits={bits} t={t} r={rr}: {} vs {}",
                        ys[t * out_d + rr],
                        y[rr]
                    );
                }
            }
        }
    }

    #[test]
    fn threaded_kernels_are_deterministic() {
        // large enough to clear PAR_MIN_WORK so row/token chunking kicks in
        let (out_d, in_d) = (512, 1024);
        let (pl, w_hat) = setup(2, 128, out_d, in_d, 95);
        let n_tok = 3;
        let mut r = Rng::new(96);
        let mut xs = vec![0f32; n_tok * in_d];
        r.fill_normal(&mut xs, 0.0, 1.0);

        let run = || {
            let mut y = vec![0f32; out_d];
            pl.matvec(&xs[..in_d], &mut y);
            let mut ys = vec![0f32; n_tok * out_d];
            pl.matmul(&xs, n_tok, &mut ys);
            let mut yd = vec![0f32; out_d];
            dense_matvec(&w_hat, out_d, in_d, &xs[..in_d], &mut yd);
            let mut ysd = vec![0f32; n_tok * out_d];
            dense_matmul(&w_hat, out_d, in_d, &xs, n_tok, &mut ysd);
            (y, ys, yd, ysd)
        };
        let single = with_threads(1, run);
        for nt in [2usize, 4, 7] {
            let multi = with_threads(nt, run);
            assert!(
                single.0 == multi.0
                    && single.1 == multi.1
                    && single.2 == multi.2
                    && single.3 == multi.3,
                "thread count {nt} changed results"
            );
        }
    }

    #[test]
    fn matmul_rows_is_bitexact_with_matvec_all_bits() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (24, 128, 32);
            let (pl, _) = setup(bits, g, out_d, in_d, 190 + bits as u64);
            let mut r = Rng::new(191);
            for n_tok in [1usize, 3, 8] {
                let mut xs = vec![0f32; n_tok * in_d];
                r.fill_normal(&mut xs, 0.0, 1.0);
                let mut ys = vec![0f32; n_tok * out_d];
                let (mut tmp, mut sx) = (Vec::new(), Vec::new());
                pl.matmul_rows(&xs, n_tok, &mut ys, &mut tmp, &mut sx);
                let mut y = vec![0f32; out_d];
                for t in 0..n_tok {
                    pl.matvec(&xs[t * in_d..(t + 1) * in_d], &mut y);
                    for rr in 0..out_d {
                        assert_eq!(
                            ys[t * out_d + rr].to_bits(),
                            y[rr].to_bits(),
                            "bits={bits} n_tok={n_tok} t={t} r={rr}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_rows_is_thread_deterministic() {
        // large enough to clear PAR_MIN_WORK so row chunking kicks in
        let (out_d, in_d) = (512, 1024);
        let (pl, w_hat) = setup(2, 128, out_d, in_d, 195);
        let n_tok = 5;
        let mut r = Rng::new(196);
        let mut xs = vec![0f32; n_tok * in_d];
        r.fill_normal(&mut xs, 0.0, 1.0);
        let run = || {
            let mut ys = vec![0f32; n_tok * out_d];
            let (mut tmp, mut sx) = (Vec::new(), Vec::new());
            pl.matmul_rows(&xs, n_tok, &mut ys, &mut tmp, &mut sx);
            let mut ysd = vec![0f32; n_tok * out_d];
            dense_matmul_rows(&w_hat, out_d, in_d, &xs, n_tok, &mut ysd,
                              &mut tmp);
            (ys, ysd)
        };
        let single = with_threads(1, run);
        for nt in [2usize, 4, 7] {
            let multi = with_threads(nt, run);
            assert!(single == multi,
                    "thread count {nt} changed matmul_rows results");
        }
        // and the row-parallel path agrees bitwise with token-parallel
        let (ys_rows, _) = single;
        let mut ys_tok = vec![0f32; n_tok * out_d];
        pl.matmul(&xs, n_tok, &mut ys_tok);
        assert!(ys_rows.iter().zip(&ys_tok)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn dense_matmul_rows_is_bitexact_with_dense_matvec() {
        let (out_d, in_d, n_tok) = (16, 48, 4);
        let mut r = Rng::new(198);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 0.5);
        let mut xs = vec![0f32; n_tok * in_d];
        r.fill_normal(&mut xs, 0.0, 1.0);
        let mut ys = vec![0f32; n_tok * out_d];
        let mut tmp = Vec::new();
        dense_matmul_rows(&w, out_d, in_d, &xs, n_tok, &mut ys, &mut tmp);
        let mut y = vec![0f32; out_d];
        for t in 0..n_tok {
            dense_matvec(&w, out_d, in_d, &xs[t * in_d..(t + 1) * in_d],
                         &mut y);
            for rr in 0..out_d {
                assert_eq!(ys[t * out_d + rr].to_bits(), y[rr].to_bits());
            }
        }
    }

    #[test]
    fn dense_matmul_is_bitexact_with_dense_matvec() {
        let (out_d, in_d, n_tok) = (16, 48, 4);
        let mut r = Rng::new(97);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 0.5);
        let mut xs = vec![0f32; n_tok * in_d];
        r.fill_normal(&mut xs, 0.0, 1.0);
        let mut ys = vec![0f32; n_tok * out_d];
        dense_matmul(&w, out_d, in_d, &xs, n_tok, &mut ys);
        let mut y = vec![0f32; out_d];
        for t in 0..n_tok {
            dense_matvec(&w, out_d, in_d, &xs[t * in_d..(t + 1) * in_d],
                         &mut y);
            for rr in 0..out_d {
                assert_eq!(ys[t * out_d + rr].to_bits(), y[rr].to_bits());
            }
        }
    }

    #[test]
    fn simd_packed_kernels_match_scalar_bit_for_bit() {
        use crate::util::simd::{detected, with_isa, Isa};
        // bits x group (incl. single-word groups) x odd out_d x n_tok;
        // in_d = 5 groups so chunk boundaries land off vector widths
        let shapes: &[(u32, usize)] = &[
            (2, 16), (2, 32), (2, 64),
            (3, 32), (3, 64),
            (4, 8), (4, 16), (4, 32),
        ];
        for &(bits, g) in shapes {
            for out_d in [7usize, 24, 33] {
                let in_d = g * 5;
                let (pl, _) =
                    setup(bits, g, out_d, in_d, 700 + bits as u64);
                let mut r = Rng::new(701);
                for n_tok in [1usize, 3, 8] {
                    let mut xs = vec![0f32; n_tok * in_d];
                    r.fill_normal(&mut xs, 0.0, 1.0);
                    let run = || {
                        let mut y = vec![0f32; out_d];
                        pl.matvec(&xs[..in_d], &mut y);
                        let mut ys = vec![0f32; n_tok * out_d];
                        pl.matmul(&xs, n_tok, &mut ys);
                        let mut yr = vec![0f32; n_tok * out_d];
                        let (mut tmp, mut sx) = (Vec::new(), Vec::new());
                        pl.matmul_rows(&xs, n_tok, &mut yr, &mut tmp,
                                       &mut sx);
                        (y, ys, yr)
                    };
                    let scalar = with_isa(Isa::Scalar, run);
                    let vector = with_isa(detected(), run);
                    assert!(
                        scalar.0.iter().zip(&vector.0)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                            && scalar.1.iter().zip(&vector.1)
                                .all(|(a, b)| a.to_bits() == b.to_bits())
                            && scalar.2.iter().zip(&vector.2)
                                .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "bits={bits} g={g} out_d={out_d} n_tok={n_tok}: \
                         SIMD diverged from scalar"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_dense_kernels_match_scalar_bit_for_bit() {
        use crate::util::simd::{detected, with_isa, Isa};
        // in_dim off the 8-lane width (tail-only, tail+body, odd rows)
        let mut r = Rng::new(710);
        for in_d in [1usize, 7, 8, 9, 100] {
            for out_d in [1usize, 5, 16] {
                for n_tok in [1usize, 3] {
                    let mut w = vec![0f32; out_d * in_d];
                    r.fill_normal(&mut w, 0.0, 0.5);
                    let mut xs = vec![0f32; n_tok * in_d];
                    r.fill_normal(&mut xs, 0.0, 1.0);
                    let run = || {
                        let mut y = vec![0f32; out_d];
                        dense_matvec(&w, out_d, in_d, &xs[..in_d],
                                     &mut y);
                        let mut ys = vec![0f32; n_tok * out_d];
                        dense_matmul(&w, out_d, in_d, &xs, n_tok,
                                     &mut ys);
                        let mut yr = vec![0f32; n_tok * out_d];
                        let mut tmp = Vec::new();
                        dense_matmul_rows(&w, out_d, in_d, &xs, n_tok,
                                          &mut yr, &mut tmp);
                        (y, ys, yr)
                    };
                    let scalar = with_isa(Isa::Scalar, run);
                    let vector = with_isa(detected(), run);
                    assert_eq!(
                        scalar.0.iter().map(|v| v.to_bits())
                            .collect::<Vec<_>>(),
                        vector.0.iter().map(|v| v.to_bits())
                            .collect::<Vec<_>>(),
                        "dense_matvec in_d={in_d} out_d={out_d}"
                    );
                    assert!(
                        scalar.1.iter().zip(&vector.1)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                            && scalar.2.iter().zip(&vector.2)
                                .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "dense batched in_d={in_d} out_d={out_d} \
                         n_tok={n_tok}: SIMD diverged from scalar"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_threaded_kernels_match_scalar_bit_for_bit() {
        use crate::util::simd::{detected, with_isa, Isa};
        // big enough to clear PAR_MIN_WORK: the ISA sweep must commute
        // with row/token chunking at every thread count
        let (out_d, in_d) = (256, 1024);
        let (pl, w_hat) = setup(2, 128, out_d, in_d, 720);
        let n_tok = 3;
        let mut r = Rng::new(721);
        let mut xs = vec![0f32; n_tok * in_d];
        r.fill_normal(&mut xs, 0.0, 1.0);
        let run = |nt: usize, isa: Isa| {
            with_threads(nt, || {
                with_isa(isa, || {
                    let mut y = vec![0f32; out_d];
                    pl.matvec(&xs[..in_d], &mut y);
                    let mut ys = vec![0f32; n_tok * out_d];
                    pl.matmul(&xs, n_tok, &mut ys);
                    let mut yd = vec![0f32; out_d];
                    dense_matvec(&w_hat, out_d, in_d, &xs[..in_d],
                                 &mut yd);
                    (y, ys, yd)
                })
            })
        };
        let base = run(1, Isa::Scalar);
        for nt in [1usize, 4, 7] {
            let v = run(nt, detected());
            assert!(
                base == v,
                "nt={nt}: SIMD+threads diverged from serial scalar"
            );
        }
    }

    #[test]
    fn dequant_row_roundtrip() {
        for bits in [2u32, 3, 4] {
            let (out_d, in_d, g) = (8, 64, 32);
            let (pl, w_hat) = setup(bits, g, out_d, in_d, 70 + bits as u64);
            let mut row = vec![0f32; in_d];
            for r in 0..out_d {
                pl.dequant_row(r, &mut row);
                for k in 0..in_d {
                    assert!(
                        (row[k] - w_hat[r * in_d + k]).abs() < 1e-6,
                        "bits={bits} r={r} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_size_ratios_at_2bit() {
        // f32 weights are 16x the packed 2-bit bytes; the fp16 deployment
        // comparator (2 bytes/weight) is 8x.
        let (pl, _) = setup(2, 32, 16, 128, 80);
        let packed_bytes = pl.words.len() * 4;
        let dense_f32_bytes = 16 * 128 * 4;
        let dense_f16_bytes = 16 * 128 * 2;
        assert_eq!(dense_f32_bytes / packed_bytes, 16);
        assert_eq!(dense_f16_bytes / packed_bytes, 8);
    }

    #[test]
    fn pack_rejects_unaligned_and_bad_values() {
        let sch = QuantScheme::new(3, 8); // 24 bits per group: unaligned
        assert!(PackedLinear::pack(&[0.0; 64], 4, 16, &[1.0; 8], &[0.0; 8],
                                   sch).is_err());
        let sch2 = QuantScheme::new(2, 32);
        let mut w = vec![0f32; 32];
        w[5] = 9.0; // out of range for 2 bits
        assert!(PackedLinear::pack(&w, 1, 32, &[1.0], &[0.0], sch2).is_err());
    }
}
