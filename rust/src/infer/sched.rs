//! Continuous-batching scheduler over the shared
//! [`ModelCore`](crate::infer::core::ModelCore) + pooled-KV
//! [`Session`](crate::infer::session::Session)s, with the serving
//! lifecycle and failure model on top: bounded-queue backpressure,
//! per-request deadlines, cancellation, and per-request fault isolation.
//!
//! Each [`Scheduler::tick`]:
//!
//! 1. **reaps** any KV leases dropped on an early-exit path
//!    ([`KvPool::reap`] - the drop-safe lease contract means no path can
//!    leak pages), then **sheds** queued requests and **retires** live
//!    sessions whose deadline has expired
//!    ([`FinishReason::TimedOut`], partial output kept for live ones);
//! 2. **admits** queued requests while the batch has room *and* the
//!    paged [`KvPool`] can reserve the request's KV rows
//!    ([`KvPool::lease_rows`] with the prompt + token-budget row count,
//!    so an admitted request can never fail a KV allocation mid-flight).
//!    Admission is FIFO with a bounded lookahead
//!    ([`SchedConfig::admit_lookahead`]): a front request whose pages
//!    don't fit yet doesn't block smaller later requests, and the
//!    starvation guard ([`SchedConfig::starve_patience`]) suspends the
//!    lookahead once the front has been passed over too many ticks.
//!    With [`SchedConfig::prefix_cache`] on, admission goes through
//!    [`KvPool::lease_rows_cached`]: the longest cached page-aligned
//!    prompt prefix is leased by refcount (zero copy, zero prefill
//!    compute), chunked prefill resumes at the match point, and the
//!    reservation covers only the rows past it - so hits admit under
//!    pool pressure that queues cold requests. When the queue is
//!    contended, a cache-aware preference pass additionally attempts
//!    the window's cached candidates (classified by the read-only
//!    [`KvPool::cache_probe_rows`]) before cold ones, FIFO among
//!    themselves; jumping the front this way charges the same
//!    starvation counter, so a cold front still ages out of being
//!    skipped. Successful retirements insert their page-aligned KV
//!    prefix back into the cache. Under [`SchedPolicy::Edf`] the
//!    admission *order* changes (earliest absolute deadline first,
//!    with [`Request::priority`] as the fallback class for
//!    deadline-free requests and the same starvation guard as an
//!    escape hatch - see [`SchedPolicy`]) while every capacity rule
//!    above is unchanged;
//! 3. **prefills** admitted prompts in bounded chunks
//!    ([`SchedConfig::prefill_chunk`]), capped per tick by the shared
//!    [`SchedConfig::prefill_budget`] token quantum (0 = unlimited) so
//!    a long arriving prompt cannot monopolize a tick: decode for
//!    in-flight sessions proceeds every tick regardless of how much
//!    prompt work is pending. Under EDF the budget is spent
//!    earliest-deadline-first; a prefill error fails *only* the
//!    offending session (lease released, [`FinishReason::Failed`]
//!    completion) while the rest of the batch is untouched;
//! 4. **decodes** all prompt-complete sessions in one
//!    [`decode_batch`](crate::infer::core::ModelCore::decode_batch)
//!    step. On a batch error the scheduler falls back to per-session
//!    solo [`step`](crate::infer::core::ModelCore::step)s - bit-identical
//!    to the batched step by the determinism contract - so only sessions
//!    that individually fail are retired `Failed`;
//! 5. **retires** finished sequences immediately (lease back to the
//!    pool, a [`Completion`] with its [`FinishReason`] and latency
//!    accounting out), so a short request never waits for a long
//!    co-batched one.
//!
//! [`Scheduler::submit`] applies backpressure: beyond
//! [`SchedConfig::max_queue`] it returns the typed
//! [`Reject::QueueFull`] instead of growing without bound, and requests
//! that could never be admitted are refused up front
//! ([`Reject::NeverFits`]). [`Scheduler::cancel`] removes a request at
//! any lifecycle stage. All latency/deadline bookkeeping runs on the
//! scheduler's [`Clock`] - wall time in production,
//! [`Clock::manual`] in deadline tests and the open-loop simulator.
//!
//! Streaming: with [`SchedConfig::stream`] on, every admission, token
//! emission, and retirement is mirrored as a [`StreamEvent`] drained
//! via [`Scheduler::take_stream_events`], and
//! [`Scheduler::stream_tokens`] polls any request's
//! tokens-produced-so-far. First-token and per-token latency are
//! stamped at emission time either way (see
//! [`Completion::first_token_secs`]); streaming is observation-only
//! and cannot perturb a single scheduling or sampling decision.
//!
//! Determinism: a session's logits (and therefore its sampled tokens)
//! are bit-identical to a solo `Engine`/`generate` run of the same
//! `(prompt, seed, sampler)` at any batch size, admission order, and
//! thread count - co-batched requests cannot perturb each other, and a
//! request that fails or is cancelled mid-flight leaves with a bit-exact
//! *prefix* of its solo token stream. Pinned here, in `infer::core`, in
//! the serve benches, and in the integration suite.

use std::collections::VecDeque;
use std::fmt;
use std::sync::Arc;

use anyhow::Result;

use crate::infer::core::{ModelCore, Scratch};
use crate::infer::kv::{KvFormat, KvLease, KvPool};
use crate::infer::session::{Completion, FinishReason, Request, Session};
use crate::util::clock::Clock;

/// Admission ordering policy. Capacity rules (batch room, KV page
/// reservation, backpressure) are identical under every policy - the
/// policy only decides *which* queued request is attempted first - and
/// so is the determinism contract: a request's token stream is a pure
/// function of `(prompt, seed, sampler)` no matter which policy
/// admitted it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Arrival order with bounded lookahead past a non-fitting front
    /// ([`SchedConfig::admit_lookahead`]) and, with the prefix cache
    /// on, a cache-aware preference pass (the PR-9 behavior, and the
    /// default).
    Fifo,
    /// Earliest-deadline-first: queued requests are attempted in order
    /// of absolute deadline; deadline-free requests come after every
    /// deadline-bearing one, ordered by [`Request::priority`] class
    /// (then cached-before-cold with the prefix cache on, then
    /// submission order). The starvation guard still applies - an
    /// entry passed over on [`SchedConfig::starve_patience`] admission
    /// ticks outranks everything (FIFO among aged entries) and, like a
    /// FIFO front, pins admission until it fits - so a stream of tight
    /// deadlines cannot starve a deadline-free request indefinitely.
    Edf,
}

impl Default for SchedPolicy {
    fn default() -> SchedPolicy {
        SchedPolicy::Fifo
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Max concurrently-live sessions (also bounds the decode batch).
    pub max_batch: usize,
    /// Max prompt tokens fed per session per tick during admission.
    pub prefill_chunk: usize,
    /// Max queued (not-yet-admitted) requests; [`Scheduler::submit`]
    /// beyond this returns [`Reject::QueueFull`] (backpressure) instead
    /// of queueing unboundedly.
    pub max_queue: usize,
    /// How many queued requests may be inspected past a front request
    /// whose pages don't fit (head-of-line fix). 0 = strict FIFO.
    pub admit_lookahead: usize,
    /// Ticks the front request may be passed over before lookahead is
    /// suspended until it admits (starvation guard). 0 = the front can
    /// never be skipped.
    pub starve_patience: u32,
    /// Enable the cross-request prefix cache
    /// ([`KvPool::enable_prefix_cache`]): admission serves the longest
    /// cached page-aligned prompt prefix by refcount (zero copy, zero
    /// prefill compute, right-sized reservation) and successful
    /// retirements insert their page-aligned KV prefix back. Off by
    /// default; bit-determinism is unaffected either way (cached pages
    /// are bit-identical to freshly prefilled ones by construction).
    pub prefix_cache: bool,
    /// KV page storage width for pools built by [`Scheduler::new`]:
    /// 4 and 8 select the packed low-bit formats
    /// ([`KvFormat::from_bits`]), anything else the default f32 slabs.
    /// Packed pools follow the low-bit determinism contract (see
    /// `infer::kv`): bit-identical across batch size, chunking,
    /// threads, page size, SIMD ISA, and cache state - but not to the
    /// f32 path. Ignored by [`Scheduler::with_pool`], which takes an
    /// already-shaped pool.
    pub kv_bits: u32,
    /// Admission ordering policy (see [`SchedPolicy`]). FIFO by
    /// default; EDF changes which request is admitted first, never
    /// what any request's tokens are.
    pub policy: SchedPolicy,
    /// Per-tick cap on the *total* prompt tokens prefilled across all
    /// live sessions (0 = unlimited, the pre-budget behavior). Bounds
    /// how long one tick can stall in-flight decodes on prompt work:
    /// a newly-admitted long prompt spreads over
    /// `ceil(len / prefill_budget)` ticks while every prompt-complete
    /// session keeps emitting one token per tick. Chunk-exact prefill
    /// makes any budget value bit-identical in tokens.
    pub prefill_budget: usize,
    /// Record incremental [`StreamEvent`]s (admission, each emitted
    /// token, retirement) for [`Scheduler::take_stream_events`]. Off
    /// by default; purely observational either way.
    pub stream: bool,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch: 8,
            prefill_chunk: 16,
            max_queue: 1024,
            admit_lookahead: 4,
            starve_patience: 64,
            prefix_cache: false,
            kv_bits: 16,
            policy: SchedPolicy::Fifo,
            prefill_budget: 0,
            stream: false,
        }
    }
}

/// Typed [`Scheduler::submit`] refusal. Implements `std::error::Error`,
/// so `submit(...)?` still works in `anyhow` contexts while callers that
/// care (the open-loop driver) can match on the variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reject {
    /// Empty prompts have nothing to prefill.
    EmptyPrompt,
    /// The prompt alone exceeds the model's context.
    PromptTooLong { len: usize, max_ctx: usize },
    /// The worst-case KV footprint exceeds the whole pool - the request
    /// could never be admitted, even by an idle scheduler.
    NeverFits { pages_needed: usize, pool_pages: usize },
    /// Backpressure: the submission queue is at
    /// [`SchedConfig::max_queue`]. Retry after completions drain.
    QueueFull { limit: usize },
}

impl fmt::Display for Reject {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Reject::EmptyPrompt => write!(f, "empty prompt"),
            Reject::PromptTooLong { len, max_ctx } => {
                write!(f, "prompt of {len} tokens exceeds max_ctx \
                           {max_ctx}")
            }
            Reject::NeverFits { pages_needed, pool_pages } => {
                write!(f, "request needs {pages_needed} KV pages but the \
                           pool only has {pool_pages}")
            }
            Reject::QueueFull { limit } => {
                write!(f, "submission queue full ({limit} requests)")
            }
        }
    }
}

impl std::error::Error for Reject {}

/// Lifecycle counters, updated at every request state transition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// requests accepted into the queue
    pub submitted: u64,
    /// submissions refused (any [`Reject`] variant)
    pub rejected: u64,
    /// completions that emitted their full budget
    pub done: u64,
    /// completions truncated by the context limit
    pub context_full: u64,
    /// deadline expiries (shed from the queue or retired live)
    pub timed_out: u64,
    /// [`Scheduler::cancel`] hits (queued or live)
    pub cancelled: u64,
    /// per-request fault isolations ([`FinishReason::Failed`])
    pub failed: u64,
    /// [`Scheduler::tick`] calls
    pub ticks: u64,
    /// admissions that leased a cached prompt prefix (prefix cache on)
    pub cache_hits: u64,
    /// admissions that found no cached prefix (prefix cache on)
    pub cache_misses: u64,
    /// prompt rows served from the cache instead of being prefilled
    pub tokens_prefill_avoided: u64,
    /// cache pages reclaimed under reservation pressure
    pub cache_evictions: u64,
    /// prompt tokens actually prefilled (cache-served rows excluded);
    /// per-tick deltas are bounded by [`SchedConfig::prefill_budget`]
    pub prefilled_tokens: u64,
    /// tokens emitted across all sessions
    pub emitted_tokens: u64,
}

/// What happened to one request, as it happens. Only recorded with
/// [`SchedConfig::stream`] on; drained via
/// [`Scheduler::take_stream_events`] in exact occurrence order.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamEventKind {
    /// Left the queue: KV rows leased, prefill starts this tick.
    Admitted,
    /// One token emitted. The tokens streamed for a request are always
    /// a prefix of (and, at retirement, exactly) its
    /// [`Completion::tokens`].
    Token(i32),
    /// Retired with this [`FinishReason`]; no further events for the id.
    Finished(FinishReason),
}

/// One entry of the incremental per-request stream (see
/// [`StreamEventKind`]). `at` is the scheduler-clock timestamp, so on
/// the manual clock event times are bit-reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamEvent {
    pub id: u64,
    /// scheduler-clock time the event happened, seconds
    pub at: f64,
    pub kind: StreamEventKind,
}

/// A queued (not yet admitted) request.
struct Queued {
    id: u64,
    req: Request,
    submitted: f64,
    /// absolute deadline on the scheduler clock
    deadline: Option<f64>,
    /// admission ticks this entry has been passed over (FIFO: while at
    /// the front; EDF: while anything else was admitted) - drives the
    /// starvation guard
    skipped: u32,
}

pub struct Scheduler {
    core: Arc<ModelCore>,
    pool: KvPool,
    cfg: SchedConfig,
    clock: Clock,
    queue: VecDeque<Queued>,
    live: Vec<Session>,
    scratch: Scratch,
    done: Vec<Completion>,
    stats: SchedStats,
    events: Vec<StreamEvent>,
    next_id: u64,
}

impl Scheduler {
    /// A scheduler with `n_slots` full sequences' worth of KV pages over
    /// a shared core (at least one - an empty pool would mean no
    /// admissible request). Thanks to paging, *more* than `n_slots`
    /// short requests can be live at once: admission is gated on pages,
    /// not whole-sequence slots.
    pub fn new(core: Arc<ModelCore>, n_slots: usize, cfg: SchedConfig)
               -> Scheduler {
        let pool = KvPool::for_core_fmt(&core, n_slots.max(1),
                                        KvFormat::from_bits(cfg.kv_bits));
        Scheduler::with_pool(core, pool, cfg)
    }

    /// A scheduler over an explicitly-shaped page pool (see
    /// [`KvPool::for_core_paged`]); tests and benches size pages
    /// directly to exercise multi-page prefixes and page exhaustion.
    pub fn with_pool(core: Arc<ModelCore>, pool: KvPool,
                     cfg: SchedConfig) -> Scheduler {
        Scheduler::with_clock(core, pool, cfg, Clock::wall())
    }

    /// [`Scheduler::with_pool`] on an explicit clock - a
    /// [`Clock::manual`] makes deadlines, latency accounting, and the
    /// open-loop simulator bit-reproducible.
    pub fn with_clock(core: Arc<ModelCore>, mut pool: KvPool,
                      cfg: SchedConfig, clock: Clock) -> Scheduler {
        let scratch = core.scratch();
        if cfg.prefix_cache {
            pool.enable_prefix_cache();
        }
        Scheduler {
            core,
            pool,
            // config normalization happens once, here: every knob that
            // would divide-by-zero or livelock at 0 is clamped to 1
            cfg: SchedConfig {
                max_batch: cfg.max_batch.max(1),
                prefill_chunk: cfg.prefill_chunk.max(1),
                max_queue: cfg.max_queue.max(1),
                ..cfg
            },
            clock,
            queue: VecDeque::new(),
            live: Vec::new(),
            scratch,
            done: Vec::new(),
            stats: SchedStats::default(),
            events: Vec::new(),
            next_id: 0,
        }
    }

    /// The scheduler's page pool (occupancy reporting: `serve-sim`
    /// prints peak pages in use and COW bytes from here).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// The clock all latency/deadline bookkeeping runs on.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Lifecycle counters so far (evictions live pool-side and are
    /// merged in here).
    pub fn stats(&self) -> SchedStats {
        let mut s = self.stats;
        s.cache_evictions = self.pool.cache_evictions();
        s
    }

    /// Drop every prefix-cache page reference (see
    /// [`KvPool::cache_flush`]). Drain-time leak checks flush first,
    /// then assert `pool().pages_in_use() == 0`.
    pub fn flush_prefix_cache(&mut self) -> usize {
        self.pool.cache_flush()
    }

    /// Worst-case KV rows a request may write: prompt plus decode feeds
    /// (the final sampled token is emitted without being fed, hence
    /// `max_new - 1`), capped at the model context.
    fn rows_for(req: &Request, max_ctx: usize) -> usize {
        (req.prompt.len() + req.max_new.saturating_sub(1)).min(max_ctx)
    }

    /// On a successful retirement (Done / ContextFull), record the
    /// session's page-aligned KV prefix in the prefix cache. The key is
    /// the tokens actually fed - prompt plus decoded feeds - since KV
    /// row `i` is a pure function of tokens `[0..=i]` at absolute
    /// positions. No-op with the cache off; a faulted insert (the
    /// `cache.insert` failpoint) is all-or-nothing pool-side and simply
    /// skipped here - the lease still releases normally, nothing leaks.
    fn cache_retire(pool: &mut KvPool, s: &Session) {
        if !pool.cache_enabled() {
            return;
        }
        let fed = s.pos.saturating_sub(s.prompt.len()).min(s.out.len());
        let mut toks = Vec::with_capacity(s.prompt.len() + fed);
        toks.extend_from_slice(&s.prompt);
        toks.extend_from_slice(&s.out[..fed]);
        let _ = pool.cache_insert(&toks, &s.lease);
    }

    fn validate(&self, req: &Request) -> Result<(), Reject> {
        if req.prompt.is_empty() {
            return Err(Reject::EmptyPrompt);
        }
        if req.prompt.len() > self.core.max_ctx {
            return Err(Reject::PromptTooLong {
                len: req.prompt.len(),
                max_ctx: self.core.max_ctx,
            });
        }
        let rows = Self::rows_for(req, self.core.max_ctx).max(1);
        let pr = self.pool.page_rows();
        let need = (rows + pr - 1) / pr;
        if need > self.pool.n_pages() {
            return Err(Reject::NeverFits {
                pages_needed: need,
                pool_pages: self.pool.n_pages(),
            });
        }
        if self.queue.len() >= self.cfg.max_queue {
            return Err(Reject::QueueFull { limit: self.cfg.max_queue });
        }
        Ok(())
    }

    /// Enqueue a request; returns its id, or a typed [`Reject`] (bad
    /// request, impossible KV footprint, or queue-full backpressure).
    /// An accepted request is admitted (KV rows leased, prefill started)
    /// on a later [`Scheduler::tick`] when capacity allows. Expired
    /// queued entries are shed *before* the backpressure check, so a
    /// queue full of already-dead requests never refuses live work.
    pub fn submit(&mut self, req: Request) -> Result<u64, Reject> {
        self.shed_expired_queued();
        if let Err(r) = self.validate(&req) {
            self.stats.rejected += 1;
            return Err(r);
        }
        let now = self.clock.now();
        let deadline = req.deadline.map(|d| now + d);
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        self.queue.push_back(Queued {
            id,
            req,
            submitted: now,
            deadline,
            skipped: 0,
        });
        Ok(id)
    }

    /// Cancel a request at any lifecycle stage. Queued: it leaves the
    /// queue with an empty [`FinishReason::Cancelled`] completion.
    /// Live (prefilling or decoding): it retires now, keeping whatever
    /// tokens it already emitted, and its KV lease frees immediately.
    /// Returns `false` for ids that are unknown or already completed.
    pub fn cancel(&mut self, id: u64) -> bool {
        let now = self.clock.now();
        if let Some(qi) = self.queue.iter().position(|q| q.id == id) {
            let q = self.queue.remove(qi).expect("indexed entry");
            let comp = Self::unstarted_completion(
                &q, now, FinishReason::Cancelled);
            Self::retire(&mut self.events, &mut self.done,
                         self.cfg.stream, now, comp);
            self.stats.cancelled += 1;
            return true;
        }
        if let Some(li) = self.live.iter().position(|s| s.id == id) {
            let (lease, comp) =
                self.live.remove(li).finish(now, FinishReason::Cancelled);
            self.pool.release(lease);
            Self::retire(&mut self.events, &mut self.done,
                         self.cfg.stream, now, comp);
            self.stats.cancelled += 1;
            return true;
        }
        false
    }

    /// Record a retirement: the completion lands in `done` and, with
    /// streaming on, is mirrored as a [`StreamEventKind::Finished`]
    /// event (always the id's last event).
    fn retire(events: &mut Vec<StreamEvent>, done: &mut Vec<Completion>,
              stream: bool, now: f64, comp: Completion) {
        if stream {
            events.push(StreamEvent {
                id: comp.id,
                at: now,
                kind: StreamEventKind::Finished(comp.finish.clone()),
            });
        }
        done.push(comp);
    }

    /// Shed every queued entry whose deadline has passed
    /// ([`FinishReason::TimedOut`], no output). Runs on every tick
    /// *and* at [`Scheduler::submit`] time, so under backpressure an
    /// expired entry's queue slot frees the moment new work arrives
    /// instead of holding a [`Reject::QueueFull`] until the next tick.
    fn shed_expired_queued(&mut self) {
        let now = self.clock.now();
        let mut qi = 0usize;
        while qi < self.queue.len() {
            if self.queue[qi].deadline.map_or(false, |d| now >= d) {
                let q = self.queue.remove(qi).expect("indexed entry");
                let comp = Self::unstarted_completion(
                    &q, now, FinishReason::TimedOut);
                Self::retire(&mut self.events, &mut self.done,
                             self.cfg.stream, now, comp);
                self.stats.timed_out += 1;
            } else {
                qi += 1;
            }
        }
    }

    /// Drain the incremental stream: every [`StreamEvent`] recorded
    /// since the last drain, in exact occurrence order. Always empty
    /// unless [`SchedConfig::stream`] is on. Streaming is
    /// observation-only - it changes no admission, prefill, or
    /// sampling decision, so token streams are bit-identical with it
    /// on or off.
    pub fn take_stream_events(&mut self) -> Vec<StreamEvent> {
        std::mem::take(&mut self.events)
    }

    /// Poll the tokens produced so far for a request: `Some` of the
    /// empty slice while queued, the partial output while live, the
    /// final output once retired (until [`Scheduler::take_completed`]
    /// drains it), `None` for unknown or drained ids. Works with or
    /// without [`SchedConfig::stream`].
    pub fn stream_tokens(&self, id: u64) -> Option<&[i32]> {
        if self.queue.iter().any(|q| q.id == id) {
            return Some(&[]);
        }
        if let Some(s) = self.live.iter().find(|s| s.id == id) {
            return Some(&s.out);
        }
        self.done
            .iter()
            .find(|c| c.id == id)
            .map(|c| c.tokens.as_slice())
    }

    /// A completion for a request that never left the queue.
    fn unstarted_completion(q: &Queued, now: f64, finish: FinishReason)
                            -> Completion {
        Completion {
            id: q.id,
            prompt_len: q.req.prompt.len(),
            tokens: Vec::new(),
            finish,
            first_token_secs: 0.0,
            finish_secs: (now - q.submitted).max(0.0),
            token_gaps: Vec::new(),
        }
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.live.is_empty()
    }

    /// Completions collected so far (drained, ordered by request id).
    pub fn take_completed(&mut self) -> Vec<Completion> {
        let mut done = std::mem::take(&mut self.done);
        done.sort_by_key(|c| c.id);
        done
    }

    /// The EDF admission pass (see [`SchedPolicy::Edf`]). Every queued
    /// entry gets an ordering key snapshotted at tick start -
    /// starvation-aged entries first (FIFO among themselves), then
    /// deadline-bearing entries by absolute deadline, then
    /// deadline-free entries by priority class (cached-before-cold as
    /// a tiebreak with the prefix cache on), submission order last -
    /// and candidates are attempted in key order while the batch has
    /// room. An aged entry that cannot lease pins the pass (nothing
    /// may pass it, exactly like a FIFO front past its patience); an
    /// un-aged miss only charges the [`SchedConfig::admit_lookahead`]
    /// attempt budget. When anything was admitted, every entry still
    /// queued afterwards was passed over and ages one step - so
    /// [`SchedConfig::starve_patience`] bounds how many admission
    /// rounds any request (deadline-free included) can lose before it
    /// outranks the deadline order. Patience 0 therefore degenerates
    /// to strict submission order, mirroring FIFO's "0 = the front can
    /// never be skipped".
    #[allow(clippy::too_many_arguments)]
    fn admit_edf(core: &Arc<ModelCore>, pool: &mut KvPool,
                 cfg: &SchedConfig, queue: &mut VecDeque<Queued>,
                 live: &mut Vec<Session>, stats: &mut SchedStats,
                 events: &mut Vec<StreamEvent>, now: f64) {
        let key_of = |q: &Queued, pool: &KvPool| -> (u8, u64, u64) {
            if q.skipped >= cfg.starve_patience {
                (0, 0, q.id)
            } else if let Some(d) = q.deadline {
                // non-negative finite f64: to_bits preserves order
                (1, d.to_bits(), q.id)
            } else {
                let cold = if pool.cache_enabled()
                    && pool.cache_probe_rows(
                        &q.req.prompt[..q.req.prompt.len() - 1]) > 0
                {
                    0u64
                } else {
                    1u64
                };
                (2, (u64::from(q.req.priority) << 1) | cold, q.id)
            }
        };
        let mut order: Vec<((u8, u64, u64), u64)> =
            queue.iter().map(|q| (key_of(q, pool), q.id)).collect();
        order.sort_unstable();

        let mut any_admitted = false;
        let mut misses = 0usize;
        for &(key, id) in &order {
            if live.len() >= cfg.max_batch {
                break;
            }
            let qi = match queue.iter().position(|q| q.id == id) {
                Some(qi) => qi,
                None => continue,
            };
            let rows = Self::rows_for(&queue[qi].req, core.max_ctx);
            // the cache key stops one token short of the prompt: the
            // final prompt token is always prefilled, so the
            // first-token sample reads logits produced as in a cold run
            let key_len = queue[qi].req.prompt.len() - 1;
            let res = pool.lease_rows_cached(
                &queue[qi].req.prompt[..key_len], rows);
            match res {
                Some((lease, matched)) => {
                    if matched > 0 {
                        stats.cache_hits += 1;
                        stats.tokens_prefill_avoided += matched as u64;
                    } else if pool.cache_enabled() {
                        stats.cache_misses += 1;
                    }
                    let q = queue.remove(qi).expect("indexed entry");
                    if cfg.stream {
                        events.push(StreamEvent {
                            id: q.id,
                            at: now,
                            kind: StreamEventKind::Admitted,
                        });
                    }
                    live.push(Session::start(q.id, q.req, lease, matched,
                                             q.submitted, q.deadline));
                    any_admitted = true;
                }
                None => {
                    if key.0 == 0 {
                        // an aged entry pins the pass: nothing behind
                        // it in EDF order may admit past it
                        break;
                    }
                    misses += 1;
                    if misses > cfg.admit_lookahead {
                        break;
                    }
                }
            }
        }
        if any_admitted {
            for q in queue.iter_mut() {
                q.skipped = q.skipped.saturating_add(1);
            }
        }
    }

    /// One scheduling round: reap + deadlines + admit + chunked prefill
    /// + one batched decode step + retire (see the module docs for the
    /// phase-by-phase contract). Returns the number of tokens emitted
    /// this tick. Per-request failures are isolated into `Failed`
    /// completions; an `Err` from `tick` itself would mean a scheduler
    /// invariant broke, not a request fault.
    pub fn tick(&mut self) -> Result<usize> {
        self.stats.ticks += 1;

        // 1a. reclaim pages from leases dropped without release
        self.pool.reap();

        // 1b. deadline enforcement, queue side: shed expired queued
        //     requests (also runs at submit time, so expired entries
        //     never hold queue slots against backpressure)
        self.shed_expired_queued();

        let Scheduler {
            core, pool, cfg, clock, queue, live, scratch, done, stats,
            events, ..
        } = self;
        let now = clock.now();

        // 1c. deadline enforcement, live side: retire expired sessions
        //     with their partial output
        let mut li = 0usize;
        while li < live.len() {
            if live[li].expired(now) {
                let (lease, comp) =
                    live.remove(li).finish(now, FinishReason::TimedOut);
                pool.release(lease);
                Self::retire(events, done, cfg.stream, now, comp);
                stats.timed_out += 1;
            } else {
                li += 1;
            }
        }

        // 2. admission: queue -> live while batch room exists and the
        //    pool can reserve the request's worst-case KV rows. The
        //    policy decides only the attempt order; under EDF the
        //    whole pass is [`Scheduler::admit_edf`].
        if cfg.policy == SchedPolicy::Edf {
            Self::admit_edf(core, pool, cfg, queue, live, stats, events,
                            now);
        } else {
        // FIFO with bounded lookahead past a non-fitting front, and a
        //    starvation guard so the front ages out of being skipped.
        //
        //    2a. cache-aware preference pass: with the prefix cache on
        //    and more than one request competing, candidates in the
        //    same lookahead window whose prompts probe as cached
        //    ([`KvPool::cache_probe_rows`], read-only - no LRU stamp,
        //    no refcounts) are attempted first, FIFO among themselves.
        //    Jumping the front this way charges the same starvation
        //    counter as the plain lookahead, so `starve_patience`
        //    bounds how long a cold front can be preferred against;
        //    with the cache off or `admit_lookahead` 0, admission
        //    order is exactly the pre-existing FIFO.
        let mut skipped_front: Option<u64> = None;
        if queue.len() > 1
            && cfg.admit_lookahead > 0
            && pool.cache_enabled()
            && queue[0].skipped < cfg.starve_patience
        {
            let mut qi = 0usize;
            while live.len() < cfg.max_batch
                && qi < queue.len()
                && qi <= cfg.admit_lookahead
            {
                let key_len = queue[qi].req.prompt.len() - 1;
                if pool.cache_probe_rows(&queue[qi].req.prompt[..key_len])
                    == 0
                {
                    qi += 1;
                    continue;
                }
                let rows = Self::rows_for(&queue[qi].req, core.max_ctx);
                let res = pool.lease_rows_cached(
                    &queue[qi].req.prompt[..key_len], rows);
                match res {
                    Some((lease, matched)) => {
                        if matched > 0 {
                            stats.cache_hits += 1;
                            stats.tokens_prefill_avoided += matched as u64;
                        } else {
                            // the probed prefix was evicted by an
                            // earlier admission's reservation pressure
                            stats.cache_misses += 1;
                        }
                        if qi > 0 {
                            skipped_front =
                                skipped_front.or(Some(queue[0].id));
                        }
                        let q = queue.remove(qi).expect("indexed entry");
                        if cfg.stream {
                            events.push(StreamEvent {
                                id: q.id,
                                at: now,
                                kind: StreamEventKind::Admitted,
                            });
                        }
                        live.push(Session::start(q.id, q.req, lease,
                                                 matched, q.submitted,
                                                 q.deadline));
                        // don't advance qi: the next entry shifted here
                    }
                    None => qi += 1,
                }
            }
        }
        //    2b. the FIFO-with-lookahead pass over whatever remains.
        let mut qi = 0usize;
        while live.len() < cfg.max_batch && qi < queue.len() {
            let rows = Self::rows_for(&queue[qi].req, core.max_ctx);
            // the cache key stops one token short of the prompt: the
            // final prompt token is always prefilled, so the first-token
            // sample reads logits produced exactly as in a cold run
            let key_len = queue[qi].req.prompt.len() - 1;
            let res = pool.lease_rows_cached(
                &queue[qi].req.prompt[..key_len], rows);
            match res {
                Some((lease, matched)) => {
                    if matched > 0 {
                        stats.cache_hits += 1;
                        stats.tokens_prefill_avoided += matched as u64;
                    } else if pool.cache_enabled() {
                        stats.cache_misses += 1;
                    }
                    let q = queue.remove(qi).expect("indexed entry");
                    if cfg.stream {
                        events.push(StreamEvent {
                            id: q.id,
                            at: now,
                            kind: StreamEventKind::Admitted,
                        });
                    }
                    live.push(Session::start(q.id, q.req, lease, matched,
                                             q.submitted, q.deadline));
                    // don't advance qi: the next entry shifted here
                }
                None => {
                    if qi == 0 {
                        if cfg.admit_lookahead == 0
                            || queue[0].skipped >= cfg.starve_patience
                        {
                            break; // strict FIFO: nothing may pass
                        }
                        skipped_front = skipped_front.or(Some(queue[0].id));
                    }
                    qi += 1;
                    if qi > cfg.admit_lookahead {
                        break;
                    }
                }
            }
        }
        // the front only ages if it is still the same entry that was
        // passed over (a front jumped in 2a may itself admit in 2b)
        if let Some(fid) = skipped_front {
            if let Some(front) = queue.front_mut() {
                if front.id == fid {
                    front.skipped = front.skipped.saturating_add(1);
                }
            }
        }
        } // end FIFO admission

        // 3. chunked prefill: bounded chunks per session, the total
        //    capped by the per-tick prefill budget (0 = unlimited).
        //    Under EDF the budget is spent earliest-deadline-first
        //    (then priority class, then admission order) so a
        //    tight-deadline prompt is never starved of prefill
        //    bandwidth by an earlier-admitted relaxed one; under FIFO
        //    it is spent in admission order, exactly the pre-budget
        //    behavior. Chunk-exact prefill (the determinism contract)
        //    makes every split bit-identical in tokens.
        //    Isolation: a prefill error fails only this session - its
        //    lease is released (pages and unspent reservation back to
        //    the pool) and a Failed completion records the error.
        let mut budget = if cfg.prefill_budget == 0 {
            usize::MAX
        } else {
            cfg.prefill_budget
        };
        let pf_ids: Vec<u64> = {
            let mut idx: Vec<usize> = (0..live.len())
                .filter(|&i| !live[i].prompt_done())
                .collect();
            if cfg.policy == SchedPolicy::Edf {
                idx.sort_by_key(|&i| {
                    let s = &live[i];
                    match s.deadline {
                        // non-negative finite f64: to_bits preserves order
                        Some(d) => (0u8, d.to_bits(), s.id),
                        None => (1, u64::from(s.priority), s.id),
                    }
                });
            }
            idx.iter().map(|&i| live[i].id).collect()
        };
        for id in pf_ids {
            if budget == 0 {
                // quantum exhausted: remaining prompts resume next tick
                break;
            }
            let i = match live.iter().position(|s| s.id == id) {
                Some(i) => i,
                None => continue,
            };
            let s = &mut live[i];
            let n = cfg
                .prefill_chunk
                .min(s.prompt.len() - s.prefilled)
                .min(budget);
            let res = {
                let chunk = &s.prompt[s.prefilled..s.prefilled + n];
                core.prefill(pool, &s.lease, s.pos, chunk, scratch)
            };
            match res {
                Ok(()) => {
                    s.pos += n;
                    s.prefilled += n;
                    budget -= n;
                    stats.prefilled_tokens += n as u64;
                    if s.prompt_done() {
                        // same sampling order as solo generate: the
                        // first token comes from the prefill logits
                        s.next = {
                            let logits = scratch.logits();
                            s.sample(logits)
                        };
                    }
                }
                Err(e) => {
                    let (lease, comp) = live.remove(i).finish(
                        now, FinishReason::Failed(e.to_string()));
                    pool.release(lease);
                    Self::retire(events, done, cfg.stream, now, comp);
                    stats.failed += 1;
                }
            }
        }

        // 4. emission + retire-before-step: a session whose budget or
        //    context is exhausted leaves the batch *now*, freeing its
        //    pages for the next admission instead of stalling the batch
        let mut emitted = 0usize;
        let mut stepping: Vec<usize> = Vec::with_capacity(live.len());
        let mut i = 0usize;
        while i < live.len() {
            let s = &mut live[i];
            if !s.prompt_done() {
                i += 1;
                continue;
            }
            if s.out.len() >= s.max_new {
                Self::cache_retire(pool, &live[i]);
                let (lease, comp) =
                    live.remove(i).finish(now, FinishReason::Done);
                pool.release(lease);
                Self::retire(events, done, cfg.stream, now, comp);
                stats.done += 1;
                continue;
            }
            if s.pos >= core.max_ctx {
                // same truncation a solo generate performs
                Self::cache_retire(pool, &live[i]);
                let (lease, comp) =
                    live.remove(i).finish(now, FinishReason::ContextFull);
                pool.release(lease);
                Self::retire(events, done, cfg.stream, now, comp);
                stats.context_full += 1;
                continue;
            }
            let tok = s.next;
            s.emit(tok, now);
            emitted += 1;
            stats.emitted_tokens += 1;
            if cfg.stream {
                events.push(StreamEvent {
                    id: s.id,
                    at: now,
                    kind: StreamEventKind::Token(tok),
                });
            }
            if s.out.len() >= s.max_new {
                Self::cache_retire(pool, &live[i]);
                let (lease, comp) =
                    live.remove(i).finish(now, FinishReason::Done);
                pool.release(lease);
                Self::retire(events, done, cfg.stream, now, comp);
                stats.done += 1;
                continue;
            }
            stepping.push(i);
            i += 1;
        }

        // 5. one batched decode step across every still-live sequence.
        //    Isolation: on a batch error, re-run each sequence as a solo
        //    step - bit-identical to the batched step by the determinism
        //    contract - so only sessions that individually fail retire
        //    as Failed while the rest keep their exact token streams.
        if !stepping.is_empty() {
            let batch: Vec<(&KvLease, usize)> = stepping
                .iter()
                .map(|&i| (&live[i].lease, live[i].pos))
                .collect();
            let toks: Vec<i32> =
                stepping.iter().map(|&i| *live[i].out.last().unwrap())
                    .collect();
            let res = core.decode_batch(pool, &batch, &toks, scratch);
            drop(batch);
            match res {
                Ok(()) => {
                    for (row, &i) in stepping.iter().enumerate() {
                        let s = &mut live[i];
                        s.pos += 1;
                        s.next = {
                            let logits = scratch.batch_logits(row);
                            s.sample(logits)
                        };
                    }
                }
                Err(_) => {
                    // highest index first so removals don't shift the
                    // entries still pending
                    for (row, &i) in stepping.iter().enumerate().rev() {
                        let res = core.step(pool, &live[i].lease,
                                            live[i].pos, toks[row],
                                            scratch);
                        match res {
                            Ok(()) => {
                                let s = &mut live[i];
                                s.pos += 1;
                                s.next = {
                                    let logits = scratch.logits();
                                    s.sample(logits)
                                };
                            }
                            Err(e) => {
                                let (lease, comp) = live.remove(i).finish(
                                    now,
                                    FinishReason::Failed(e.to_string()));
                                pool.release(lease);
                                Self::retire(events, done, cfg.stream,
                                             now, comp);
                                stats.failed += 1;
                            }
                        }
                    }
                }
            }
        }
        Ok(emitted)
    }

    /// Tick until every submitted request has completed; returns the
    /// completions ordered by request id.
    pub fn run_all(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.tick()?;
        }
        Ok(self.take_completed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;
    use crate::infer::engine::Engine;
    use crate::infer::generate::{generate, Sampler};
    use crate::util::failpoint;
    use crate::util::threads::with_threads;

    const VOCAB: usize = 96;
    const CTX: usize = 48;

    fn core(seed: u64) -> Arc<ModelCore> {
        Arc::new(ModelCore::synthetic(32, 4, 8, 64, VOCAB, 2,
                                      QuantScheme::new(2, 32), CTX, seed)
            .unwrap())
    }

    fn prompt(len: usize, stride: usize) -> Vec<i32> {
        (0..len).map(|i| ((i * stride + 3) % VOCAB) as i32).collect()
    }

    fn greedy(p: Vec<i32>, max_new: usize, seed: u64) -> Request {
        Request::new(p, max_new, Sampler::Greedy, seed)
    }

    fn solo(core: &Arc<ModelCore>, req: &(Vec<i32>, usize, u64))
            -> Vec<i32> {
        let mut e = Engine::from_core(core.clone());
        generate(&mut e, &req.0, req.1, Sampler::Temperature(0.9), req.2)
            .unwrap()
            .tokens
    }

    fn solo_greedy(core: &Arc<ModelCore>, req: &(Vec<i32>, usize, u64))
                   -> Vec<i32> {
        let mut e = Engine::from_core(core.clone());
        generate(&mut e, &req.0, req.1, Sampler::Greedy, req.2)
            .unwrap()
            .tokens
    }

    /// Scheduler outputs == solo generate outputs for every request, for
    /// batch sizes {1, 2, 5} x thread counts {1, 4}, with different
    /// prompt lengths, token budgets, and sampler seeds in one batch.
    #[test]
    fn scheduler_matches_solo_generate_across_batch_and_threads() {
        let c = core(31);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(3 + 4 * i, 5 + i), 4 + 2 * i, 100 + i as u64))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo(&c, r)).collect();

        for &bsz in &[1usize, 2, 5] {
            for &nt in &[1usize, 4] {
                with_threads(nt, || {
                    let mut sched = Scheduler::new(
                        c.clone(), bsz,
                        SchedConfig {
                            max_batch: bsz,
                            prefill_chunk: 4,
                            ..SchedConfig::default()
                        });
                    for r in &reqs {
                        sched.submit(Request::new(
                            r.0.clone(), r.1,
                            Sampler::Temperature(0.9), r.2)).unwrap();
                    }
                    let comps = sched.run_all().unwrap();
                    assert_eq!(comps.len(), reqs.len());
                    for (comp, want) in comps.iter().zip(&want) {
                        assert_eq!(
                            &comp.tokens, want,
                            "batch {bsz} threads {nt} req {}: scheduler \
                             output diverged from solo generate",
                            comp.id
                        );
                        assert_eq!(comp.finish, FinishReason::Done);
                    }
                });
            }
        }
    }

    /// More requests than KV slots: exhaustion queues (never panics) and
    /// every request still completes with its solo output.
    #[test]
    fn pool_exhaustion_queues_and_retirement_readmits() {
        let c = core(32);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(2 + 3 * i, 7 + i), 3 + i, 900 + i as u64))
            .collect();
        let mut sched = Scheduler::new(c.clone(), 2, SchedConfig {
            max_batch: 8, // more than the pool's 2 slots can carry
            prefill_chunk: 8,
            ..SchedConfig::default()
        });
        for r in &reqs {
            sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
        }
        assert_eq!(sched.n_queued(), 5);
        let mut max_live = 0usize;
        while !sched.is_idle() {
            sched.tick().unwrap();
            max_live = max_live.max(sched.n_live());
        }
        assert!(max_live <= 2, "live {max_live} exceeded the 2 slots");
        let comps = sched.take_completed();
        assert_eq!(comps.len(), 5);
        for (comp, r) in comps.iter().zip(&reqs) {
            let want = solo_greedy(&c, r);
            assert_eq!(comp.tokens, want, "req {}", comp.id);
            assert_eq!(comp.prompt_len, r.0.len());
            assert_eq!(comp.token_gaps.len(), comp.tokens.len());
            assert!(comp.first_token_secs >= 0.0);
            assert!(comp.finish_secs >= comp.first_token_secs);
        }
        let st = sched.stats();
        assert_eq!(st.submitted, 5);
        assert_eq!(st.done, 5);
        assert_eq!(st.rejected + st.failed + st.timed_out + st.cancelled,
                   0);
    }

    /// Page-granular exhaustion: with 6-row pages and only 4 pages, the
    /// 2-page requests queue (at most 2 live at once), every request
    /// still completes with its solo output, and the pool never exceeds
    /// its page budget.
    #[test]
    fn page_exhaustion_queues_and_completes() {
        let c = core(36);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(7, 5 + i), 4, 700 + i as u64))
            .collect();
        // rows needed per request = 7 prompt + 4 - 1 decode feeds = 10
        // -> 2 pages of 6 rows each; 4 pages total -> <= 2 live
        let mut sched = Scheduler::with_pool(
            c.clone(),
            KvPool::for_core_paged(&c, 4, 6),
            SchedConfig {
                max_batch: 8,
                prefill_chunk: 4,
                ..SchedConfig::default()
            });
        for r in &reqs {
            sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
        }
        let mut max_live = 0usize;
        while !sched.is_idle() {
            sched.tick().unwrap();
            max_live = max_live.max(sched.n_live());
        }
        assert!(max_live <= 2, "live {max_live} exceeded the page budget");
        assert!(sched.pool().peak_pages_in_use() <= 4);
        assert_eq!(sched.pool().pages_in_use(), 0, "pages leaked");
        let comps = sched.take_completed();
        assert_eq!(comps.len(), reqs.len());
        for (comp, r) in comps.iter().zip(&reqs) {
            assert_eq!(comp.tokens, solo_greedy(&c, r), "req {}", comp.id);
        }
    }

    /// A sequence that fills its context retires instead of erroring, and
    /// matches generate()'s truncation behavior.
    #[test]
    fn context_full_retires_like_generate_truncates() {
        let c = core(33);
        let p = prompt(CTX - 3, 5);
        let mut e = Engine::from_core(c.clone());
        let want = generate(&mut e, &p, 10, Sampler::Greedy, 7)
            .unwrap()
            .tokens;
        assert!(want.len() < 10, "prompt too short to hit the ctx cap");
        let mut sched =
            Scheduler::new(c, 1, SchedConfig::default());
        sched.submit(greedy(p, 10, 7)).unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps[0].tokens, want);
        assert_eq!(comps[0].finish, FinishReason::ContextFull);
        assert_eq!(sched.stats().context_full, 1);
    }

    #[test]
    fn submit_rejects_bad_requests_with_typed_errors() {
        let c = core(34);
        let mut sched = Scheduler::new(c, 1, SchedConfig::default());
        assert_eq!(sched.submit(greedy(vec![], 1, 1)),
                   Err(Reject::EmptyPrompt));
        assert_eq!(sched.submit(greedy(vec![0; CTX + 1], 1, 1)),
                   Err(Reject::PromptTooLong { len: CTX + 1,
                                               max_ctx: CTX }));
        assert_eq!(sched.stats().rejected, 2);
        assert_eq!(sched.stats().submitted, 0);
    }

    /// A request whose worst-case KV footprint exceeds the entire pool
    /// is refused up front instead of queueing forever.
    #[test]
    fn impossible_footprint_is_rejected_not_queued_forever() {
        let c = core(37);
        // 2 pages x 4 rows = 8 rows total
        let mut sched = Scheduler::with_pool(
            c.clone(), KvPool::for_core_paged(&c, 2, 4),
            SchedConfig::default());
        let r = sched.submit(greedy(prompt(10, 3), 8, 1));
        assert!(matches!(r, Err(Reject::NeverFits { .. })), "{r:?}");
        // a fitting request on the same scheduler works
        sched.submit(greedy(prompt(3, 3), 4, 2)).unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].finish, FinishReason::Done);
    }

    /// Backpressure: the queue is bounded and submit returns QueueFull
    /// instead of growing without limit; draining reopens it.
    #[test]
    fn bounded_queue_applies_backpressure() {
        let c = core(38);
        let mut sched = Scheduler::new(c, 1, SchedConfig {
            max_batch: 1,
            max_queue: 2,
            ..SchedConfig::default()
        });
        sched.submit(greedy(prompt(3, 3), 2, 1)).unwrap();
        sched.submit(greedy(prompt(3, 4), 2, 2)).unwrap();
        assert_eq!(sched.submit(greedy(prompt(3, 5), 2, 3)),
                   Err(Reject::QueueFull { limit: 2 }));
        sched.tick().unwrap(); // admits one, queue has room again
        sched.submit(greedy(prompt(3, 5), 2, 3)).unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), 3);
        let st = sched.stats();
        assert_eq!((st.submitted, st.rejected, st.done), (3, 1, 3));
    }

    #[test]
    fn zero_budget_request_completes_empty() {
        let c = core(35);
        let mut sched = Scheduler::new(c, 1, SchedConfig::default());
        sched.submit(greedy(prompt(4, 3), 0, 1)).unwrap();
        let comps = sched.run_all().unwrap();
        assert!(comps[0].tokens.is_empty());
        assert_eq!(comps[0].finish, FinishReason::Done);
    }

    /// Cancel at every lifecycle stage: queued (empty completion),
    /// mid-prefill (empty completion, pages freed), mid-decode (partial
    /// tokens that are a bit-exact prefix of the solo run).
    #[test]
    fn cancel_covers_queued_prefilling_and_decoding() {
        let c = core(39);
        let solo_ref =
            solo_greedy(&c, &(prompt(4, 3), 8, 21));

        // queued: one slot, second request waits
        let mut sched = Scheduler::new(c.clone(), 1, SchedConfig {
            max_batch: 1,
            ..SchedConfig::default()
        });
        let a = sched.submit(greedy(prompt(4, 3), 6, 11)).unwrap();
        let b = sched.submit(greedy(prompt(4, 5), 6, 12)).unwrap();
        sched.tick().unwrap();
        assert_eq!(sched.n_queued(), 1);
        assert!(sched.cancel(b), "queued cancel must hit");
        assert!(!sched.cancel(b), "double cancel must miss");
        assert!(!sched.cancel(999), "unknown id must miss");
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), 2);
        let cb = comps.iter().find(|x| x.id == b).unwrap();
        assert_eq!(cb.finish, FinishReason::Cancelled);
        assert!(cb.tokens.is_empty());
        let ca = comps.iter().find(|x| x.id == a).unwrap();
        assert_eq!(ca.finish, FinishReason::Done);

        // mid-prefill: long prompt, tiny chunks
        let mut sched = Scheduler::new(c.clone(), 1, SchedConfig {
            prefill_chunk: 2,
            ..SchedConfig::default()
        });
        let a = sched.submit(greedy(prompt(12, 3), 6, 13)).unwrap();
        sched.tick().unwrap();
        assert_eq!(sched.n_live(), 1, "should be mid-prefill");
        assert!(sched.cancel(a));
        assert!(sched.is_idle());
        assert_eq!(sched.pool().pages_in_use(), 0, "cancel leaked pages");
        let comps = sched.take_completed();
        assert_eq!(comps[0].finish, FinishReason::Cancelled);
        assert!(comps[0].tokens.is_empty());

        // mid-decode: cancel after a few emitted tokens
        let mut sched = Scheduler::new(c.clone(), 1, SchedConfig::default());
        let a = sched.submit(greedy(prompt(4, 3), 8, 21)).unwrap();
        for _ in 0..3 {
            sched.tick().unwrap();
        }
        assert_eq!(sched.n_live(), 1);
        assert!(sched.cancel(a));
        assert_eq!(sched.pool().pages_in_use(), 0, "cancel leaked pages");
        let comps = sched.take_completed();
        assert_eq!(comps[0].finish, FinishReason::Cancelled);
        assert!(!comps[0].tokens.is_empty());
        assert!(comps[0].tokens.len() < 8);
        assert_eq!(comps[0].tokens[..],
                   solo_ref[..comps[0].tokens.len()],
                   "cancelled output must be a prefix of the solo run");
        assert_eq!(sched.stats().cancelled, 1);
    }

    /// Deadline expiry while queued: the request is shed with TimedOut
    /// and no output; co-queued work is unaffected. Runs on the manual
    /// clock, so expiry is exact and deterministic.
    #[test]
    fn deadline_expiry_in_queue_sheds_request() {
        let c = core(40);
        let pool = KvPool::for_core(&c, 1);
        let mut sched = Scheduler::with_clock(
            c.clone(), pool,
            SchedConfig { max_batch: 1, ..SchedConfig::default() },
            Clock::manual());
        let a = sched.submit(greedy(prompt(4, 3), 30, 1)).unwrap();
        let b = sched
            .submit(Request::new(prompt(3, 5), 4, Sampler::Greedy, 2)
                .with_deadline(0.5))
            .unwrap();
        sched.tick().unwrap(); // a admitted, b queued behind the slot
        assert_eq!((sched.n_live(), sched.n_queued()), (1, 1));
        sched.clock().advance(1.0); // past b's deadline
        sched.tick().unwrap();
        assert_eq!(sched.n_queued(), 0, "expired request not shed");
        let comps = sched.run_all().unwrap();
        let cb = comps.iter().find(|x| x.id == b).unwrap();
        assert_eq!(cb.finish, FinishReason::TimedOut);
        assert!(cb.tokens.is_empty());
        assert!(cb.finish_secs >= 0.5);
        let ca = comps.iter().find(|x| x.id == a).unwrap();
        assert_eq!(ca.finish, FinishReason::Done);
        assert_eq!(sched.stats().timed_out, 1);
    }

    /// Deadline expiry mid-decode: the session retires with the partial
    /// tokens it emitted - a bit-exact prefix of its solo run - and
    /// frees its pages.
    #[test]
    fn deadline_expiry_mid_decode_keeps_partial_output() {
        let c = core(41);
        let p = prompt(4, 3);
        let want = solo_greedy(&c, &(p.clone(), 10, 7));
        let pool = KvPool::for_core(&c, 1);
        let mut sched = Scheduler::with_clock(
            c.clone(), pool, SchedConfig::default(), Clock::manual());
        sched.submit(
            Request::new(p, 10, Sampler::Greedy, 7).with_deadline(5.0))
            .unwrap();
        for _ in 0..4 {
            sched.tick().unwrap();
            sched.clock().advance(1.0);
        }
        assert_eq!(sched.n_live(), 1, "should still be decoding");
        sched.clock().advance(2.0); // now 6.0 > deadline 5.0
        sched.tick().unwrap();
        assert!(sched.is_idle(), "expired session not retired");
        assert_eq!(sched.pool().pages_in_use(), 0, "expiry leaked pages");
        let comps = sched.take_completed();
        assert_eq!(comps[0].finish, FinishReason::TimedOut);
        assert!(!comps[0].tokens.is_empty());
        assert!(comps[0].tokens.len() < 10);
        assert_eq!(comps[0].tokens[..], want[..comps[0].tokens.len()],
                   "timed-out output must be a prefix of the solo run");
    }

    /// Head-of-line fix: a small later request is admitted past a front
    /// request whose pages don't fit, admission stays deterministic, and
    /// with lookahead disabled the old strict-FIFO behavior returns.
    #[test]
    fn lookahead_admits_small_request_past_blocked_front() {
        let c = core(42);
        // 6 pages x 4 rows; B and A need 4 pages each, C needs 1
        let reqs = [
            (prompt(8, 3), 9usize, 801u64), // B: admitted first
            (prompt(8, 5), 9, 802),         // A: blocked behind B
            (prompt(2, 7), 3, 803),         // C: small, fits beside B
        ];
        let mk = |lookahead: usize| {
            let mut s = Scheduler::with_pool(
                c.clone(), KvPool::for_core_paged(&c, 6, 4),
                SchedConfig {
                    max_batch: 4,
                    prefill_chunk: 8,
                    admit_lookahead: lookahead,
                    starve_patience: 64,
                    ..SchedConfig::default()
                });
            for r in &reqs {
                s.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
            }
            s
        };

        // with lookahead: C jumps the blocked A on the first tick
        let mut s = mk(4);
        s.tick().unwrap();
        assert_eq!((s.n_live(), s.n_queued()), (2, 1),
                   "lookahead should admit B and C");
        // strict FIFO: C stays behind A
        let mut s0 = mk(0);
        s0.tick().unwrap();
        assert_eq!((s0.n_live(), s0.n_queued()), (1, 2),
                   "lookahead 0 must preserve strict FIFO");

        // both orders drain to identical, solo-exact outputs: admission
        // order is invisible in the tokens (determinism contract)
        let done_a = s.run_all().unwrap();
        let done_b = s0.run_all().unwrap();
        // and lookahead admission itself is run-to-run deterministic
        let done_c = {
            let mut s = mk(4);
            s.tick().unwrap();
            s.run_all().unwrap()
        };
        assert_eq!(done_a.len(), 3);
        for ((x, y), z) in done_a.iter().zip(&done_b).zip(&done_c) {
            assert_eq!(x.tokens, y.tokens,
                       "lookahead changed tokens of req {}", x.id);
            assert_eq!(x.tokens, z.tokens,
                       "lookahead admission not deterministic");
        }
        for (comp, r) in done_a.iter().zip(&reqs) {
            assert_eq!(comp.tokens,
                       solo_greedy(&c, &(r.0.clone(), r.1, r.2)),
                       "req {}", comp.id);
        }
    }

    /// Starvation guard: once the front request has been passed over
    /// `starve_patience` ticks, lookahead is suspended - nothing may
    /// jump it anymore - and the front still completes under a
    /// continuous stream of small requests.
    #[test]
    fn starvation_guard_front_ages_out_of_being_skipped() {
        let c = core(43);
        let pool = || KvPool::for_core_paged(&c, 6, 4);
        let big = |seed| greedy(prompt(8, 3), 9, seed); // 4 pages
        let small = |seed| greedy(prompt(2, 5), 2, seed); // 1 page

        // patience 0 behaves like strict FIFO from the first tick
        let mut s = Scheduler::with_pool(c.clone(), pool(), SchedConfig {
            max_batch: 4,
            prefill_chunk: 8,
            admit_lookahead: 4,
            starve_patience: 0,
            ..SchedConfig::default()
        });
        s.submit(big(1)).unwrap();
        s.submit(big(2)).unwrap();
        s.submit(small(3)).unwrap();
        s.tick().unwrap();
        assert_eq!((s.n_live(), s.n_queued()), (1, 2),
                   "patience 0 must not let the small request jump");

        // patience 1 + continuous small traffic on the manual clock: the
        // big front request must finish before the stream drains
        let mut s = Scheduler::with_clock(c.clone(), pool(), SchedConfig {
            max_batch: 4,
            prefill_chunk: 8,
            admit_lookahead: 4,
            starve_patience: 1,
            ..SchedConfig::default()
        }, Clock::manual());
        s.submit(big(4)).unwrap(); // occupies 4 of 6 pages
        let a = s.submit(big(5)).unwrap(); // the skippable front
        let mut smalls = Vec::new();
        let mut t = 0usize;
        loop {
            if t < 20 {
                smalls.push(s.submit(small(100 + t as u64)).unwrap());
            }
            s.tick().unwrap();
            s.clock().advance(1.0);
            t += 1;
            if s.is_idle() {
                break;
            }
            assert!(t < 1000, "starved: scheduler failed to drain");
        }
        let comps = s.take_completed();
        let fa = comps.iter().find(|x| x.id == a).unwrap().finish_secs;
        let last_small = smalls
            .iter()
            .map(|id| {
                comps.iter().find(|x| x.id == *id).unwrap().finish_secs
            })
            .fold(0.0f64, f64::max);
        assert!(fa < last_small,
                "guard failed: big request ({fa}s) outlived every small \
                 request (last at {last_small}s)");
        for comp in &comps {
            assert_eq!(comp.finish, FinishReason::Done, "req {}",
                       comp.id);
        }
    }

    /// Satellite regression: a failing forward call must not abandon
    /// every live lease anymore. With prefill failing for everything,
    /// all sessions retire Failed and the pool accounting is exact.
    #[test]
    fn failed_tick_releases_failed_sessions_pages() {
        let c = core(45);
        let mut sched = Scheduler::new(c.clone(), 2, SchedConfig {
            max_batch: 2,
            prefill_chunk: 4,
            ..SchedConfig::default()
        });
        sched.submit(greedy(prompt(6, 3), 4, 1)).unwrap();
        sched.submit(greedy(prompt(6, 5), 4, 2)).unwrap();
        failpoint::with(1, &[("fwd.prefill", 1.0)], || {
            sched.tick().unwrap();
        });
        assert_eq!(sched.n_live(), 0, "failed sessions must retire");
        assert_eq!(sched.pool().pages_in_use(), 0,
                   "failed tick leaked pages");
        let comps = sched.take_completed();
        assert_eq!(comps.len(), 2);
        for comp in &comps {
            assert!(matches!(comp.finish, FinishReason::Failed(_)),
                    "req {}: {:?}", comp.id, comp.finish);
            assert!(comp.tokens.is_empty());
        }
        assert_eq!(sched.stats().failed, 2);
        // the scheduler stays serviceable after the fault
        sched.submit(greedy(prompt(4, 3), 3, 3)).unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].finish, FinishReason::Done);
        assert_eq!(sched.pool().pages_in_use(), 0);
    }

    /// Isolation: a prefill fault fails only the offending session; the
    /// co-batched request keeps decoding bit-identically.
    #[test]
    fn failed_prefill_isolates_offending_session() {
        let c = core(46);
        let fast = (prompt(3, 3), 6usize, 51u64); // prefills in 1 chunk
        let slow = (prompt(12, 5), 4usize, 52u64); // needs 3 chunks
        let want_fast = solo_greedy(&c, &fast);
        let mut sched = Scheduler::new(c.clone(), 2, SchedConfig {
            max_batch: 2,
            prefill_chunk: 4,
            ..SchedConfig::default()
        });
        let fid = sched.submit(greedy(fast.0.clone(), fast.1, fast.2))
            .unwrap();
        let sid = sched.submit(greedy(slow.0.clone(), slow.1, slow.2))
            .unwrap();
        sched.tick().unwrap(); // both admitted; fast emits, slow prefills
        assert_eq!(sched.n_live(), 2);
        // next tick: only `slow` still prefills, so a p=1.0 prefill
        // fault hits exactly that session
        failpoint::with(2, &[("fwd.prefill", 1.0)], || {
            sched.tick().unwrap();
        });
        assert_eq!(sched.n_live(), 1, "only the faulted session leaves");
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), 2);
        let cf = comps.iter().find(|x| x.id == fid).unwrap();
        assert_eq!(cf.finish, FinishReason::Done);
        assert_eq!(cf.tokens, want_fast,
                   "survivor diverged from its solo run");
        let cs = comps.iter().find(|x| x.id == sid).unwrap();
        assert!(matches!(cs.finish, FinishReason::Failed(_)));
        assert!(cs.tokens.is_empty());
        assert_eq!(sched.pool().pages_in_use(), 0);
    }

    /// Isolation: a whole-batch decode fault falls back to per-session
    /// solo steps; with no per-session fault everyone survives with
    /// outputs bit-identical to solo runs.
    #[test]
    fn whole_batch_decode_fault_survived_bit_identically() {
        let c = core(47);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..3)
            .map(|i| (prompt(3 + i, 4 + i), 5, 600 + i as u64))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo_greedy(&c, r)).collect();
        let mut sched = Scheduler::new(c.clone(), 3, SchedConfig {
            max_batch: 3,
            ..SchedConfig::default()
        });
        for r in &reqs {
            sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
        }
        // every decode_batch call fails; every solo fallback step works
        let comps = failpoint::with(3, &[("fwd.decode", 1.0)], || {
            sched.run_all().unwrap()
        });
        assert_eq!(comps.len(), reqs.len());
        for (comp, want) in comps.iter().zip(&want) {
            assert_eq!(comp.finish, FinishReason::Done, "req {}", comp.id);
            assert_eq!(&comp.tokens, want,
                       "solo-fallback output diverged (req {})", comp.id);
        }
        assert_eq!(sched.stats().failed, 0);
        assert_eq!(sched.pool().pages_in_use(), 0);
    }

    /// Acceptance sweep: randomized fault schedules across seeds and all
    /// four sites. Every run drains, leaks zero pages, and every
    /// completion is either a bit-exact solo match (Done/ContextFull) or
    /// a Failed request whose partial tokens are a bit-exact prefix.
    #[test]
    fn randomized_fault_sweep_no_leaks_survivors_bit_identical() {
        let c = core(44);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..6)
            .map(|i| (prompt(2 + 3 * i, 5 + i), 3 + i, 500 + i as u64))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo_greedy(&c, r)).collect();
        let mut total_fired = 0u64;
        for seed in [11u64, 12, 13, 14] {
            let mut sched = Scheduler::with_pool(
                c.clone(), KvPool::for_core_paged(&c, 8, 6),
                SchedConfig {
                    max_batch: 4,
                    prefill_chunk: 4,
                    ..SchedConfig::default()
                });
            for r in &reqs {
                sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
            }
            failpoint::arm(seed, &[
                ("kv.draw", 0.05),
                ("fwd.prefill", 0.10),
                ("fwd.decode", 0.10),
                ("fwd.step", 0.10),
            ]);
            let mut ticks = 0usize;
            while !sched.is_idle() {
                sched.tick().unwrap();
                ticks += 1;
                assert!(ticks < 10_000,
                        "seed {seed}: fault run failed to drain");
            }
            total_fired +=
                failpoint::disarm().iter().map(|r| r.fired).sum::<u64>();
            let comps = sched.take_completed();
            assert_eq!(comps.len(), reqs.len(),
                       "seed {seed}: lost requests");
            assert_eq!(sched.pool().pages_in_use(), 0,
                       "seed {seed}: leaked pages");
            for (comp, want) in comps.iter().zip(&want) {
                match &comp.finish {
                    FinishReason::Done | FinishReason::ContextFull => {
                        assert_eq!(&comp.tokens, want,
                                   "seed {seed} req {}: survivor \
                                    diverged from solo", comp.id);
                    }
                    FinishReason::Failed(_) => {
                        assert_eq!(comp.tokens[..],
                                   want[..comp.tokens.len()],
                                   "seed {seed} req {}: failed request's \
                                    partial output is not a solo prefix",
                                   comp.id);
                    }
                    other => panic!(
                        "seed {seed} req {}: unexpected finish {other:?}",
                        comp.id),
                }
            }
        }
        // per-seed fire counts vary with the schedule; across the whole
        // sweep at these probabilities faults must have been injected
        assert!(total_fired > 0,
                "sweep injected no faults - sites unreachable?");
    }

    /// A shared-prefix request mix: one system prompt, distinct user
    /// suffixes and seeds per request.
    fn shared_prefix_reqs(n: usize, sys_len: usize)
                          -> Vec<(Vec<i32>, usize, u64)> {
        let sys = prompt(sys_len, 3);
        (0..n)
            .map(|i| {
                let mut p = sys.clone();
                p.push(((7 * i + 11) % VOCAB) as i32);
                p.push(((5 * i + 2) % VOCAB) as i32);
                (p, 4 + i, 200 + i as u64)
            })
            .collect()
    }

    /// Tentpole determinism sweep: with the prefix cache on, every
    /// completion is bit-identical to its solo (cold, uncached) run at
    /// batch {1, 2, 5} x threads {1, 4} x page sizes {4, 6} - and a
    /// fully-warm second wave (every admission a cache hit) reproduces
    /// the exact same tokens again. Leak check via flush.
    #[test]
    fn cache_hits_bit_identical_to_cold_runs_across_batch_and_threads() {
        let c = core(50);
        let reqs = shared_prefix_reqs(5, 8);
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo(&c, r)).collect();
        for &page_rows in &[4usize, 6] {
            for &bsz in &[1usize, 2, 5] {
                for &nt in &[1usize, 4] {
                    with_threads(nt, || {
                        let mut sched = Scheduler::with_pool(
                            c.clone(),
                            KvPool::for_core_paged(&c, 40, page_rows),
                            SchedConfig {
                                max_batch: bsz,
                                prefill_chunk: 4,
                                prefix_cache: true,
                                ..SchedConfig::default()
                            });
                        for wave in 0..2 {
                            let h0 = sched.stats().cache_hits;
                            for r in &reqs {
                                sched.submit(Request::new(
                                    r.0.clone(), r.1,
                                    Sampler::Temperature(0.9), r.2))
                                    .unwrap();
                            }
                            let comps = sched.run_all().unwrap();
                            assert_eq!(comps.len(), reqs.len());
                            for (comp, want) in comps.iter().zip(&want) {
                                assert_eq!(
                                    &comp.tokens, want,
                                    "pr {page_rows} batch {bsz} threads \
                                     {nt} wave {wave}: cached output \
                                     diverged from solo");
                            }
                            if wave == 1 {
                                // warm cache: every admission must hit
                                assert_eq!(
                                    sched.stats().cache_hits - h0,
                                    reqs.len() as u64,
                                    "warm wave had cold admissions");
                            }
                        }
                        let st = sched.stats();
                        assert!(st.tokens_prefill_avoided > 0);
                        assert!(sched.flush_prefix_cache() > 0);
                        assert_eq!(sched.pool().pages_in_use(), 0,
                                   "cache flush left pages behind");
                    });
                }
            }
        }
    }

    /// Satellite: admission right-sizing. Under pool pressure a cache
    /// hit (needing only the rows past its match) admits while an
    /// equally-sized cold request queues; eviction reclaims only the
    /// unpinned cache page.
    #[test]
    fn cache_hit_admits_under_pressure_that_queues_cold_request() {
        let c = core(53);
        let sys = prompt(8, 3); // two 4-row pages of shared prefix
        let user = |t: i32| {
            let mut p = sys.clone();
            p.push(t);
            p
        };
        let cold: Vec<i32> = prompt(9, 7); // different persona, same size
        let mut sched = Scheduler::with_pool(
            c.clone(), KvPool::for_core_paged(&c, 9, 4),
            SchedConfig {
                max_batch: 4,
                prefill_chunk: 8,
                prefix_cache: true,
                ..SchedConfig::default()
            });
        // warm: one request retires and caches 3 pages (12 fed rows)
        sched.submit(greedy(user(40), 4, 901)).unwrap();
        sched.run_all().unwrap();
        assert_eq!(sched.pool().cached_pages(), 3);
        assert_eq!(sched.pool().pages_in_use(), 3);
        // M: same persona, long budget -> 9+19 rows = 7 pages, 2 cached
        // -> reserves 5 of the 6 free pages and stays live
        let m = sched.submit(greedy(user(41), 20, 902)).unwrap();
        // D: cold, needs 3 pages -> only the 1 unpinned cache page can
        // be evicted, still short -> queues
        let d = sched.submit(greedy(cold.clone(), 4, 903)).unwrap();
        // C: same persona, same worst case as D, but its 2-page hit
        // means 1 fresh page -> admits past the blocked D
        let cc = sched.submit(greedy(user(42), 4, 904)).unwrap();
        sched.tick().unwrap();
        assert_eq!((sched.n_live(), sched.n_queued()), (2, 1),
                   "hit did not right-size past the queued cold request");
        assert!(sched.cancel(d), "the cold request should still be queued");
        let st = sched.stats();
        assert_eq!(st.cache_hits, 2, "M and C must both hit");
        assert!(st.tokens_prefill_avoided >= 16, "8 rows per hit");
        assert_eq!(st.cache_evictions, 1,
                   "exactly the unpinned cache page is reclaimed");
        // drain everything (resubmit the cold request) and verify every
        // output, hit or cold, against its solo run
        let d2 = sched.submit(greedy(cold.clone(), 4, 903)).unwrap();
        let comps = sched.run_all().unwrap();
        for (id, r) in [(m, (user(41), 20usize, 902u64)),
                        (cc, (user(42), 4, 904)),
                        (d2, (cold, 4, 903))] {
            let comp = comps.iter().find(|x| x.id == id).unwrap();
            assert_eq!(comp.tokens, solo_greedy(&c, &r), "req {id}");
        }
        sched.flush_prefix_cache();
        assert_eq!(sched.pool().pages_in_use(), 0);
    }

    /// Satellite: eviction churn. Many distinct prompts through a pool
    /// the cache keeps saturating - victims are reclaimed, nothing
    /// leaks, no stale KV is ever served (every output solo-exact), and
    /// a post-eviction resubmit of an evicted prefix re-prefills
    /// bit-identically.
    #[test]
    fn eviction_churn_leaks_nothing_and_serves_no_stale_kv() {
        let c = core(51);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..12)
            .map(|i| (prompt(6 + (i % 4), 3 + i), 3, 300 + i as u64))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo_greedy(&c, r)).collect();
        let mut sched = Scheduler::with_pool(
            c.clone(), KvPool::for_core_paged(&c, 6, 4),
            SchedConfig {
                max_batch: 2,
                prefill_chunk: 4,
                prefix_cache: true,
                ..SchedConfig::default()
            });
        for r in &reqs {
            sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
        }
        let comps = sched.run_all().unwrap();
        assert_eq!(comps.len(), reqs.len());
        for (comp, want) in comps.iter().zip(&want) {
            assert_eq!(&comp.tokens, want,
                       "req {}: stale KV served under churn", comp.id);
        }
        assert!(sched.stats().cache_evictions > 0,
                "churn never evicted - pool too large for the test");
        // the first prompt's pages were evicted long ago: resubmitting
        // it is a clean miss that re-prefills to the same tokens
        sched.submit(greedy(reqs[0].0.clone(), reqs[0].1, reqs[0].2))
            .unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps[0].tokens, want[0],
                   "post-eviction resubmit diverged");
        sched.flush_prefix_cache();
        assert_eq!(sched.pool().pages_in_use(), 0, "churn leaked pages");
        assert_eq!(sched.pool().n_free_pages(), 6);
    }

    /// Satellite: randomized multi-seed fault sweep over `cache.insert`
    /// (plus kv.draw pressure). A faulted insert must never leak a page
    /// or leave a partial prefix behind - pinned by every completion
    /// (first wave and warm second wave) staying solo-exact and the
    /// flushed pool draining to zero.
    #[test]
    fn cache_insert_fault_sweep_no_leaks_no_partial_prefixes() {
        let c = core(52);
        let reqs = shared_prefix_reqs(6, 8);
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo_greedy(&c, r)).collect();
        let mut insert_fired = 0u64;
        for seed in [21u64, 22, 23, 24, 25] {
            let mut sched = Scheduler::with_pool(
                c.clone(), KvPool::for_core_paged(&c, 12, 4),
                SchedConfig {
                    max_batch: 2,
                    prefill_chunk: 4,
                    prefix_cache: true,
                    ..SchedConfig::default()
                });
            failpoint::arm(seed, &[
                ("cache.insert", 0.5),
                ("kv.draw", 0.03),
            ]);
            for wave in 0..2 {
                for r in &reqs {
                    sched.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
                }
                let mut ticks = 0usize;
                while !sched.is_idle() {
                    sched.tick().unwrap();
                    ticks += 1;
                    assert!(ticks < 10_000,
                            "seed {seed}: fault run failed to drain");
                }
                let comps = sched.take_completed();
                assert_eq!(comps.len(), reqs.len(),
                           "seed {seed} wave {wave}: lost requests");
                for (comp, want) in comps.iter().zip(&want) {
                    match &comp.finish {
                        FinishReason::Done => {
                            assert_eq!(&comp.tokens, want,
                                       "seed {seed} wave {wave} req {}: \
                                        partial/stale cached prefix \
                                        served", comp.id);
                        }
                        FinishReason::Failed(_) => {
                            assert_eq!(comp.tokens[..],
                                       want[..comp.tokens.len()],
                                       "seed {seed} req {}: not a solo \
                                        prefix", comp.id);
                        }
                        other => panic!("seed {seed} req {}: {other:?}",
                                        comp.id),
                    }
                }
            }
            insert_fired += failpoint::disarm()
                .iter()
                .filter(|r| r.site == "cache.insert")
                .map(|r| r.fired)
                .sum::<u64>();
            sched.flush_prefix_cache();
            assert_eq!(sched.pool().pages_in_use(), 0,
                       "seed {seed}: faulted inserts leaked pages");
        }
        assert!(insert_fired > 0,
                "sweep never fired cache.insert - site unreachable?");
    }

    /// Satellite: cache-aware admission ordering. Under contention a
    /// cached candidate is attempted before a cold front even when the
    /// front *could* have admitted (pure preference, not capacity),
    /// strict FIFO returns with the lookahead off, the reordering is
    /// run-to-run deterministic, and every output stays solo-exact
    /// (admission order is invisible in tokens).
    #[test]
    fn cache_aware_admission_prefers_hits_and_is_deterministic() {
        let c = core(54);
        let sys = prompt(8, 3);
        let user = |t: i32| {
            let mut p = sys.clone();
            p.push(t);
            p
        };
        let cold: Vec<i32> = prompt(9, 7);
        let run = |lookahead: usize| {
            let mut s = Scheduler::with_pool(
                c.clone(), KvPool::for_core_paged(&c, 16, 4),
                SchedConfig {
                    max_batch: 1,
                    prefill_chunk: 8,
                    admit_lookahead: lookahead,
                    prefix_cache: true,
                    ..SchedConfig::default()
                });
            // warm: one retirement caches the shared-prefix pages
            s.submit(greedy(user(40), 4, 901)).unwrap();
            s.run_all().unwrap();
            // contended wave: a cold front, a cached candidate behind
            let d = s.submit(greedy(cold.clone(), 4, 902)).unwrap();
            let h = s.submit(greedy(user(41), 4, 903)).unwrap();
            s.tick().unwrap();
            let shape = (s.n_live(), s.n_queued());
            let hits = s.stats().cache_hits;
            let comps = s.run_all().unwrap();
            s.flush_prefix_cache();
            assert_eq!(s.pool().pages_in_use(), 0, "leaked pages");
            (d, h, shape, hits, comps)
        };

        let (d, h, shape1, hits1, comps1) = run(4);
        assert_eq!(shape1, (1, 1), "first tick should admit exactly one");
        assert_eq!(hits1, 1,
                   "the cached candidate should jump the cold front");
        let (_, _, shape0, hits0, _) = run(0);
        assert_eq!(shape0, (1, 1));
        assert_eq!(hits0, 0,
                   "lookahead 0 must not reorder for the cache");

        let (_, _, shape2, hits2, comps2) = run(4);
        assert_eq!((shape1, hits1), (shape2, hits2),
                   "cache-aware admission shape not reproducible");
        assert_eq!(comps1.len(), comps2.len());
        for (x, y) in comps1.iter().zip(&comps2) {
            assert_eq!((x.id, &x.tokens), (y.id, &y.tokens),
                       "cache-aware admission is not deterministic");
        }
        for (id, r) in [(d, (cold.clone(), 4usize, 902u64)),
                        (h, (user(41), 4, 903))] {
            let comp = comps1.iter().find(|x| x.id == id).unwrap();
            assert_eq!(comp.tokens, solo_greedy(&c, &r), "req {id}");
        }
    }

    /// Satellite: cache preference vs the starvation guard. With
    /// patience 2, exactly two cached candidates jump the cold front
    /// before it ages out and admits; with patience 0 the preference
    /// pass never runs and the front goes strictly first.
    #[test]
    fn cache_preference_respects_starvation_guard() {
        let c = core(56);
        let sys = prompt(8, 3);
        let user = |t: i32| {
            let mut p = sys.clone();
            p.push(t);
            p
        };
        let cold: Vec<i32> = prompt(9, 7);
        let run = |patience: u32| {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core_paged(&c, 24, 4),
                SchedConfig {
                    max_batch: 1,
                    prefill_chunk: 8,
                    admit_lookahead: 4,
                    starve_patience: patience,
                    prefix_cache: true,
                    ..SchedConfig::default()
                }, Clock::manual());
            s.submit(greedy(user(30), 3, 910)).unwrap();
            s.run_all().unwrap(); // warm the shared prefix
            let cold_id = s.submit(greedy(cold.clone(), 3, 911)).unwrap();
            let hits: Vec<u64> = (0..6)
                .map(|i| {
                    s.submit(greedy(user(31 + i), 3, 920 + i as u64))
                        .unwrap()
                })
                .collect();
            let mut t = 0usize;
            while !s.is_idle() {
                s.tick().unwrap();
                s.clock().advance(1.0);
                t += 1;
                assert!(t < 1000, "patience {patience}: failed to drain");
            }
            (cold_id, hits, s.take_completed())
        };

        let (cold_id, hits, comps) = run(2);
        let fin = |comps: &[Completion], id: u64| {
            comps.iter().find(|x| x.id == id).unwrap().finish_secs
        };
        let cf = fin(&comps, cold_id);
        let jumped =
            hits.iter().filter(|&&h| fin(&comps, h) < cf).count();
        assert_eq!(jumped, 2,
                   "patience 2 must let exactly two cached candidates \
                    jump before the front ages out (got {jumped})");
        for comp in &comps {
            assert_eq!(comp.finish, FinishReason::Done, "req {}",
                       comp.id);
        }

        let (cold_id, hits, comps) = run(0);
        let cf = fin(&comps, cold_id);
        assert!(hits.iter().all(|&h| fin(&comps, h) > cf),
                "patience 0 let a cached candidate jump the cold front");
    }

    /// `SchedConfig::kv_bits` threads the packed formats into the
    /// scheduler's own pool: int4 outputs are bit-identical across
    /// batch size (the low-bit determinism contract), and the default
    /// config stays on the f32 path.
    #[test]
    fn low_bit_kv_scheduler_is_deterministic_across_batch_size() {
        let c = core(57);
        assert_eq!(Scheduler::new(c.clone(), 1, SchedConfig::default())
                       .pool()
                       .format(),
                   KvFormat::F32);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(3 + 4 * i, 5 + i), 4 + i, 130 + i as u64))
            .collect();
        let run = |bsz: usize| {
            let mut s = Scheduler::new(c.clone(), 8, SchedConfig {
                max_batch: bsz,
                prefill_chunk: 4,
                kv_bits: 4,
                ..SchedConfig::default()
            });
            assert_eq!(s.pool().format(), KvFormat::Int4);
            for r in &reqs {
                s.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
            }
            s.run_all().unwrap()
        };
        let want = run(1);
        assert_eq!(want.len(), reqs.len());
        for &bsz in &[2usize, 5] {
            let got = run(bsz);
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.tokens, y.tokens,
                           "int4 KV diverged across batch size {bsz} \
                            (req {})", x.id);
                assert_eq!(x.finish, FinishReason::Done);
            }
        }
    }

    /// Tentpole: EDF admission order. With one slot serializing
    /// admissions, deadline-bearing requests admit by absolute
    /// deadline regardless of submission order, and deadline-free
    /// requests follow, ordered by priority class.
    #[test]
    fn edf_admits_by_deadline_with_priority_fallback() {
        let c = core(60);
        let mut s = Scheduler::with_clock(
            c.clone(), KvPool::for_core(&c, 1),
            SchedConfig {
                max_batch: 1,
                policy: SchedPolicy::Edf,
                stream: true,
                ..SchedConfig::default()
            }, Clock::manual());
        let a = s.submit(greedy(prompt(3, 3), 2, 1)
            .with_deadline(50.0)).unwrap();
        let b = s.submit(greedy(prompt(3, 4), 2, 2)
            .with_priority(2)).unwrap();
        let d = s.submit(greedy(prompt(3, 5), 2, 3)
            .with_deadline(10.0)).unwrap();
        let e = s.submit(greedy(prompt(3, 6), 2, 4)
            .with_priority(0)).unwrap();
        let f = s.submit(greedy(prompt(3, 7), 2, 5)
            .with_deadline(30.0)).unwrap();
        let mut t = 0usize;
        while !s.is_idle() {
            s.tick().unwrap();
            s.clock().advance(0.1);
            t += 1;
            assert!(t < 1000, "failed to drain");
        }
        let admitted: Vec<u64> = s
            .take_stream_events()
            .iter()
            .filter_map(|ev| match ev.kind {
                StreamEventKind::Admitted => Some(ev.id),
                _ => None,
            })
            .collect();
        assert_eq!(admitted, vec![d, f, a, e, b],
                   "EDF admission order wrong: deadlines 10 < 30 < 50 \
                    must go first, then priority 0 before priority 2");
        let comps = s.take_completed();
        assert_eq!(comps.len(), 5);
        for comp in &comps {
            assert_eq!(comp.finish, FinishReason::Done, "req {}",
                       comp.id);
        }
    }

    /// Satellite: for a fixed workload, EDF strictly beats FIFO on
    /// missed-deadline count. FIFO runs the long deadline-free job
    /// first and the tight-deadline job expires in queue; EDF runs the
    /// tight job first (it finishes well inside its deadline), the
    /// deadline-free job finishes later but misses nothing - and
    /// policy changes scheduling only, never tokens.
    #[test]
    fn edf_beats_fifo_on_missed_deadline_count() {
        let c = core(61);
        let tight = (prompt(3, 5), 3usize, 2u64);
        let run = |policy: SchedPolicy| {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core(&c, 1),
                SchedConfig {
                    max_batch: 1,
                    policy,
                    ..SchedConfig::default()
                }, Clock::manual());
            s.submit(greedy(prompt(4, 3), 20, 1)).unwrap();
            let b = s.submit(greedy(tight.0.clone(), tight.1, tight.2)
                .with_deadline(8.0)).unwrap();
            let mut t = 0usize;
            while !s.is_idle() {
                s.tick().unwrap();
                s.clock().advance(1.0);
                t += 1;
                assert!(t < 1000, "failed to drain");
            }
            (b, s.take_completed(), s.stats())
        };
        let (fb, fifo_comps, fifo_st) = run(SchedPolicy::Fifo);
        assert_eq!(fifo_st.timed_out, 1,
                   "FIFO should miss the tight deadline");
        assert_eq!(fifo_comps.iter().find(|x| x.id == fb).unwrap().finish,
                   FinishReason::TimedOut);
        let (eb, edf_comps, edf_st) = run(SchedPolicy::Edf);
        assert_eq!(edf_st.timed_out, 0, "EDF should miss nothing");
        assert!(edf_st.timed_out < fifo_st.timed_out);
        for comp in &edf_comps {
            assert_eq!(comp.finish, FinishReason::Done, "req {}",
                       comp.id);
        }
        let ebc = edf_comps.iter().find(|x| x.id == eb).unwrap();
        assert_eq!(ebc.tokens, solo_greedy(&c, &tight),
                   "EDF changed the tight request's tokens");
    }

    /// Satellite: the EDF starvation guard. A continuous stream of
    /// deadline-bearing requests always outranks a deadline-free one,
    /// but with `starve_patience` 3 the deadline-free request ages out
    /// of being passed over and admits within a bounded number of
    /// ticks; with an effectively-unbounded patience it starves for
    /// the whole horizon.
    #[test]
    fn edf_starvation_guard_protects_deadline_free_request() {
        let c = core(62);
        let run = |patience: u32| -> Option<usize> {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core(&c, 1),
                SchedConfig {
                    max_batch: 1,
                    policy: SchedPolicy::Edf,
                    starve_patience: patience,
                    stream: true,
                    ..SchedConfig::default()
                }, Clock::manual());
            let a = s.submit(greedy(prompt(3, 3), 2, 1)).unwrap();
            let mut seed = 10u64;
            let mut admitted_at: Option<usize> = None;
            for t in 0..200usize {
                // keep the tight-deadline pressure up: the queue never
                // runs dry of deadline-bearing competitors
                if s.n_queued() < 3 {
                    s.submit(greedy(prompt(3, 5), 2, seed)
                        .with_deadline(500.0)).unwrap();
                    seed += 1;
                }
                s.tick().unwrap();
                for ev in s.take_stream_events() {
                    if ev.id == a
                        && matches!(ev.kind, StreamEventKind::Admitted)
                    {
                        admitted_at = admitted_at.or(Some(t));
                    }
                }
                s.clock().advance(0.5);
                if admitted_at.is_some() {
                    break;
                }
            }
            admitted_at
        };
        let when = run(3)
            .expect("guard failed: deadline-free request starved");
        assert!(when <= 20,
                "patience 3 should admit the deadline-free request \
                 within a handful of admission rounds (tick {when})");
        assert!(run(100_000).is_none(),
                "without the guard the deadline stream must starve the \
                 deadline-free request - the patience-3 run above \
                 proved nothing");
    }

    /// Satellite regression: expired queued entries are shed at
    /// *submit* time too, so their queue slots free immediately under
    /// backpressure instead of holding QueueFull until the next tick.
    #[test]
    fn expired_queue_entries_shed_at_submit_frees_backpressure_slots() {
        let c = core(63);
        let mut s = Scheduler::with_clock(
            c.clone(), KvPool::for_core(&c, 1),
            SchedConfig {
                max_batch: 1,
                max_queue: 2,
                ..SchedConfig::default()
            }, Clock::manual());
        // occupy the only slot with a long-running request
        let a = s.submit(greedy(prompt(4, 3), 30, 1)).unwrap();
        s.tick().unwrap();
        s.clock().advance(1.0);
        // fill the queue with short-deadline requests
        let b = s.submit(greedy(prompt(3, 5), 2, 2)
            .with_deadline(0.5)).unwrap();
        let d = s.submit(greedy(prompt(3, 6), 2, 3)
            .with_deadline(0.5)).unwrap();
        assert_eq!(s.submit(greedy(prompt(3, 7), 2, 4)),
                   Err(Reject::QueueFull { limit: 2 }));
        // let them expire with NO tick in between: submission alone
        // must shed them and reuse their slots
        s.clock().advance(1.0);
        let e = s.submit(greedy(prompt(3, 7), 2, 5)).unwrap();
        assert_eq!(s.n_queued(), 1,
                   "expired entries still hold queue slots at submit");
        let comps = s.run_all().unwrap();
        for id in [b, d] {
            let comp = comps.iter().find(|x| x.id == id).unwrap();
            assert_eq!(comp.finish, FinishReason::TimedOut, "req {id}");
            assert!(comp.tokens.is_empty());
        }
        for id in [a, e] {
            assert_eq!(comps.iter().find(|x| x.id == id).unwrap().finish,
                       FinishReason::Done, "req {id}");
        }
        let st = s.stats();
        assert_eq!((st.timed_out, st.rejected), (2, 1));
    }

    /// Tentpole: incremental streaming. Tokens drain tick by tick via
    /// events, agree with the `stream_tokens` poll at every tick, sum
    /// to exactly the retired output, every request gets exactly one
    /// Finished event, first-token latency is stamped at emission (not
    /// retirement) - and turning streaming off changes no tokens.
    #[test]
    fn streaming_exposes_tokens_incrementally_and_matches_retirement() {
        use std::collections::HashMap;
        let c = core(64);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..3)
            .map(|i| (prompt(3 + 2 * i, 4 + i), 4 + i, 70 + i as u64))
            .collect();
        let run = |stream: bool| {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core(&c, 2),
                SchedConfig {
                    max_batch: 2,
                    prefill_chunk: 2,
                    stream,
                    ..SchedConfig::default()
                }, Clock::manual());
            let ids: Vec<u64> = reqs
                .iter()
                .map(|r| s.submit(greedy(r.0.clone(), r.1, r.2)).unwrap())
                .collect();
            let mut streamed: HashMap<u64, Vec<i32>> =
                ids.iter().map(|&id| (id, Vec::new())).collect();
            let mut finished: Vec<u64> = Vec::new();
            let mut t = 0usize;
            while !s.is_idle() {
                s.tick().unwrap();
                s.clock().advance(0.25);
                for ev in s.take_stream_events() {
                    match ev.kind {
                        StreamEventKind::Token(tok) => {
                            streamed.get_mut(&ev.id).unwrap().push(tok)
                        }
                        StreamEventKind::Finished(_) => {
                            finished.push(ev.id)
                        }
                        StreamEventKind::Admitted => {}
                    }
                }
                if stream {
                    // the poll surface agrees with the event stream at
                    // every single tick
                    for &id in &ids {
                        if let Some(part) = s.stream_tokens(id) {
                            assert_eq!(part, &streamed[&id][..],
                                       "tick {t}: poll/event mismatch \
                                        for req {id}");
                        }
                    }
                }
                t += 1;
                assert!(t < 1000, "failed to drain");
            }
            (ids, streamed, finished, s.take_completed())
        };
        let (ids, streamed, mut finished, comps) = run(true);
        assert_eq!(comps.len(), reqs.len());
        for comp in &comps {
            assert_eq!(&streamed[&comp.id], &comp.tokens,
                       "req {}: streamed tokens != retired output",
                       comp.id);
            assert!(comp.first_token_secs < comp.finish_secs,
                    "req {}: first-token latency was not stamped at \
                     emission time", comp.id);
        }
        finished.sort_unstable();
        assert_eq!(finished, ids,
                   "every request must get exactly one Finished event");
        let (_, _, finished_off, comps_off) = run(false);
        assert!(finished_off.is_empty(),
                "stream off must record no events");
        for (x, y) in comps.iter().zip(&comps_off) {
            assert_eq!((x.id, &x.tokens), (y.id, &y.tokens),
                       "streaming perturbed the token stream");
        }
    }

    /// Tentpole: the per-tick prefill budget. Prefilled-token deltas
    /// per tick never exceed the budget, the short request retires
    /// first (long prompts can't monopolize ticks), the total prefill
    /// work is the same for every budget, and - chunk-exactness -
    /// every budget yields bit-identical, solo-exact tokens.
    #[test]
    fn prefill_budget_bounds_per_tick_prefill_and_keeps_bit_identity() {
        let c = core(65);
        let reqs: Vec<(Vec<i32>, usize, u64)> = vec![
            (prompt(2, 3), 6, 80),
            (prompt(24, 5), 4, 81),
            (prompt(17, 7), 4, 82),
        ];
        let total_prompt: u64 =
            reqs.iter().map(|r| r.0.len() as u64).sum();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo_greedy(&c, r)).collect();
        let run = |budget: usize| {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core(&c, 3),
                SchedConfig {
                    max_batch: 3,
                    prefill_chunk: 8,
                    prefill_budget: budget,
                    ..SchedConfig::default()
                }, Clock::manual());
            for r in &reqs {
                s.submit(greedy(r.0.clone(), r.1, r.2)).unwrap();
            }
            let mut prev = 0u64;
            let mut t = 0usize;
            while !s.is_idle() {
                s.tick().unwrap();
                s.clock().advance(1.0);
                let pf = s.stats().prefilled_tokens;
                if budget > 0 {
                    assert!(pf - prev <= budget as u64,
                            "budget {budget}: one tick prefilled {} \
                             tokens", pf - prev);
                }
                prev = pf;
                t += 1;
                assert!(t < 1000, "budget {budget}: failed to drain");
            }
            (s.take_completed(), s.stats())
        };
        for budget in [0usize, 1, 3, 8, 64] {
            let (mut comps, st) = run(budget);
            comps.sort_by_key(|x| x.id); // id order == submission order
            assert_eq!(comps.len(), reqs.len());
            for (comp, want) in comps.iter().zip(&want) {
                assert_eq!(&comp.tokens, want,
                           "budget {budget} req {}: prefill split \
                            changed tokens (chunk-exactness broken)",
                           comp.id);
                assert_eq!(comp.finish, FinishReason::Done);
            }
            assert_eq!(st.prefilled_tokens, total_prompt,
                       "budget {budget}: prefill work went missing");
            assert!(comps[0].finish_secs <= comps[1].finish_secs,
                    "budget {budget}: the short request was stalled \
                     behind a long prompt");
        }
    }

    /// EDF + budget + streaming + prefix cache together are run-to-run
    /// reproducible on the manual clock: identical event streams and
    /// identical completions, with zero leaked pages.
    #[test]
    fn edf_budget_stream_run_is_reproducible() {
        let c = core(66);
        let run = || {
            let mut s = Scheduler::with_clock(
                c.clone(), KvPool::for_core_paged(&c, 10, 6),
                SchedConfig {
                    max_batch: 2,
                    prefill_chunk: 4,
                    policy: SchedPolicy::Edf,
                    prefill_budget: 6,
                    stream: true,
                    prefix_cache: true,
                    ..SchedConfig::default()
                }, Clock::manual());
            for i in 0..6u64 {
                let mut r = greedy(prompt(3 + 2 * i as usize,
                                          3 + i as usize), 3, 40 + i);
                if i % 2 == 0 {
                    r = r.with_deadline(4.0 + i as f64);
                }
                if i == 3 {
                    r = r.with_priority(0);
                }
                s.submit(r).unwrap();
            }
            let mut events = Vec::new();
            let mut t = 0usize;
            while !s.is_idle() {
                s.tick().unwrap();
                events.extend(s.take_stream_events());
                s.clock().advance(0.5);
                t += 1;
                assert!(t < 1000, "failed to drain");
            }
            s.flush_prefix_cache();
            assert_eq!(s.pool().pages_in_use(), 0, "leaked pages");
            (events, s.take_completed())
        };
        let (e1, c1) = run();
        let (e2, c2) = run();
        assert!(!e1.is_empty());
        assert_eq!(e1, e2, "stream events not reproducible");
        assert_eq!(c1.len(), c2.len());
        for (x, y) in c1.iter().zip(&c2) {
            assert_eq!((x.id, &x.finish, &x.tokens),
                       (y.id, &y.finish, &y.tokens),
                       "EDF + budget + stream run not reproducible");
        }
    }
}
