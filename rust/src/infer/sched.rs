//! Continuous-batching scheduler over the shared
//! [`ModelCore`](crate::infer::core::ModelCore) + pooled-KV
//! [`Session`](crate::infer::session::Session)s.
//!
//! Each [`Scheduler::tick`]:
//!
//! 1. **admits** queued requests while the batch has room *and* the
//!    paged [`KvPool`] can reserve the request's KV rows
//!    ([`KvPool::lease_rows`] with the prompt + token-budget row count,
//!    so short requests hold only the pages they touch and page
//!    exhaustion queues - it never panics, and an admitted request can
//!    never fail a KV allocation mid-flight);
//! 2. **prefills** admitted prompts in bounded chunks
//!    ([`SchedConfig::prefill_chunk`]) between decode steps, so a long
//!    prompt cannot stall the live batch for more than one chunk;
//! 3. **decodes** all prompt-complete sessions in one
//!    [`decode_batch`](crate::infer::core::ModelCore::decode_batch) step
//!    - one rows-parallel matmul per linear across the whole batch -
//!    then samples each session's next token;
//! 4. **retires** finished sequences immediately (lease back to the
//!    pool, a [`Completion`] with latency accounting out), so a short
//!    request never waits for a long co-batched one.
//!
//! Determinism: a session's logits (and therefore its sampled tokens)
//! are bit-identical to a solo `Engine`/`generate` run of the same
//! `(prompt, seed, sampler)` at any batch size, admission order, and
//! thread count - co-batched requests cannot perturb each other. Pinned
//! here, in `infer::core`, in the serve bench, and in the integration
//! suite.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::infer::core::{ModelCore, Scratch};
use crate::infer::kv::{KvLease, KvPool};
use crate::infer::session::{Completion, Request, Session};

#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Max concurrently-live sessions (also bounds the decode batch).
    pub max_batch: usize,
    /// Max prompt tokens fed per session per tick during admission.
    pub prefill_chunk: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig { max_batch: 8, prefill_chunk: 16 }
    }
}

pub struct Scheduler {
    core: Arc<ModelCore>,
    pool: KvPool,
    cfg: SchedConfig,
    queue: VecDeque<(u64, Request, Instant)>,
    live: Vec<Session>,
    scratch: Scratch,
    done: Vec<Completion>,
    next_id: u64,
}

impl Scheduler {
    /// A scheduler with `n_slots` full sequences' worth of KV pages over
    /// a shared core (at least one - an empty pool would mean no
    /// admissible request). Thanks to paging, *more* than `n_slots`
    /// short requests can be live at once: admission is gated on pages,
    /// not whole-sequence slots.
    pub fn new(core: Arc<ModelCore>, n_slots: usize, cfg: SchedConfig)
               -> Scheduler {
        let pool = KvPool::for_core(&core, n_slots.max(1));
        Scheduler::with_pool(core, pool, cfg)
    }

    /// A scheduler over an explicitly-shaped page pool (see
    /// [`KvPool::for_core_paged`]); tests and benches size pages
    /// directly to exercise multi-page prefixes and page exhaustion.
    pub fn with_pool(core: Arc<ModelCore>, pool: KvPool,
                     cfg: SchedConfig) -> Scheduler {
        let scratch = core.scratch();
        Scheduler {
            core,
            pool,
            cfg: SchedConfig { max_batch: cfg.max_batch.max(1), ..cfg },
            queue: VecDeque::new(),
            live: Vec::new(),
            scratch,
            done: Vec::new(),
            next_id: 0,
        }
    }

    /// The scheduler's page pool (occupancy reporting: `serve-sim`
    /// prints peak pages in use and COW bytes from here).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Enqueue a request; returns its id. The request is admitted (KV
    /// slot leased, prefill started) on a later [`Scheduler::tick`] when
    /// capacity allows.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt.len() > self.core.max_ctx {
            bail!("prompt of {} tokens exceeds max_ctx {}",
                  req.prompt.len(), self.core.max_ctx);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, req, Instant::now()));
        Ok(id)
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.live.is_empty()
    }

    /// Completions collected so far (drained, ordered by request id).
    pub fn take_completed(&mut self) -> Vec<Completion> {
        let mut done = std::mem::take(&mut self.done);
        done.sort_by_key(|c| c.id);
        done
    }

    /// One scheduling round: admit + chunked prefill + one batched decode
    /// step + retire. Returns the number of tokens emitted this tick.
    pub fn tick(&mut self) -> Result<usize> {
        let Scheduler { core, pool, cfg, queue, live, scratch, done, .. } =
            self;

        // 1. admission: queue -> live while batch room exists and the
        //    pool can reserve the request's worst-case KV rows (prompt
        //    plus decode feeds; the final sampled token is emitted
        //    without being fed, hence max_new - 1)
        while live.len() < cfg.max_batch && !queue.is_empty() {
            let rows = {
                let (_, req, _) = queue.front().unwrap();
                (req.prompt.len() + req.max_new.saturating_sub(1))
                    .min(core.max_ctx)
            };
            match pool.lease_rows(rows) {
                None => break, // page-exhausted: requests stay queued
                Some(lease) => {
                    let (id, req, submitted) = queue.pop_front().unwrap();
                    live.push(Session::start(id, req, lease, submitted));
                }
            }
        }

        // 2. chunked prefill: one bounded chunk per admitted session
        for s in live.iter_mut().filter(|s| !s.prompt_done()) {
            let n =
                cfg.prefill_chunk.max(1).min(s.prompt.len() - s.prefilled);
            let chunk = &s.prompt[s.prefilled..s.prefilled + n];
            core.prefill(pool, &s.lease, s.pos, chunk, scratch)?;
            s.pos += n;
            s.prefilled += n;
            if s.prompt_done() {
                // same sampling order as solo generate: first token comes
                // from the prefill logits
                s.next = {
                    let logits = scratch.logits();
                    s.sample(logits)
                };
            }
        }

        // 3. emission + retire-before-step: a session whose budget or
        //    context is exhausted leaves the batch *now*, freeing its
        //    slot for the next admission instead of stalling the batch
        let now = Instant::now();
        let mut emitted = 0usize;
        let mut stepping: Vec<usize> = Vec::with_capacity(live.len());
        let mut i = 0usize;
        while i < live.len() {
            let s = &mut live[i];
            if !s.prompt_done() {
                i += 1;
                continue;
            }
            if s.pos >= core.max_ctx || s.out.len() >= s.max_new {
                let (lease, comp) = live.remove(i).finish(now);
                pool.release(lease);
                done.push(comp);
                continue;
            }
            let tok = s.next;
            s.emit(tok, now);
            emitted += 1;
            if s.out.len() >= s.max_new {
                let (lease, comp) = live.remove(i).finish(now);
                pool.release(lease);
                done.push(comp);
                continue;
            }
            stepping.push(i);
            i += 1;
        }

        // 4. one batched decode step across every still-live sequence
        if !stepping.is_empty() {
            let batch: Vec<(&KvLease, usize)> = stepping
                .iter()
                .map(|&i| (&live[i].lease, live[i].pos))
                .collect();
            let toks: Vec<i32> =
                stepping.iter().map(|&i| *live[i].out.last().unwrap())
                    .collect();
            core.decode_batch(pool, &batch, &toks, scratch)?;
            drop(batch);
            for (row, &i) in stepping.iter().enumerate() {
                let s = &mut live[i];
                s.pos += 1;
                s.next = {
                    let logits = scratch.batch_logits(row);
                    s.sample(logits)
                };
            }
        }
        Ok(emitted)
    }

    /// Tick until every submitted request has completed; returns the
    /// completions ordered by request id.
    pub fn run_all(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.tick()?;
        }
        Ok(self.take_completed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;
    use crate::infer::engine::Engine;
    use crate::infer::generate::{generate, Sampler};
    use crate::util::threads::with_threads;

    const VOCAB: usize = 96;
    const CTX: usize = 48;

    fn core(seed: u64) -> Arc<ModelCore> {
        Arc::new(ModelCore::synthetic(32, 4, 8, 64, VOCAB, 2,
                                      QuantScheme::new(2, 32), CTX, seed)
            .unwrap())
    }

    fn prompt(len: usize, stride: usize) -> Vec<i32> {
        (0..len).map(|i| ((i * stride + 3) % VOCAB) as i32).collect()
    }

    fn solo(core: &Arc<ModelCore>, req: &(Vec<i32>, usize, u64))
            -> Vec<i32> {
        let mut e = Engine::from_core(core.clone());
        generate(&mut e, &req.0, req.1, Sampler::Temperature(0.9), req.2)
            .unwrap()
            .tokens
    }

    /// Scheduler outputs == solo generate outputs for every request, for
    /// batch sizes {1, 2, 5} x thread counts {1, 4}, with different
    /// prompt lengths, token budgets, and sampler seeds in one batch.
    #[test]
    fn scheduler_matches_solo_generate_across_batch_and_threads() {
        let c = core(31);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(3 + 4 * i, 5 + i), 4 + 2 * i, 100 + i as u64))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo(&c, r)).collect();

        for &bsz in &[1usize, 2, 5] {
            for &nt in &[1usize, 4] {
                with_threads(nt, || {
                    let mut sched = Scheduler::new(
                        c.clone(), bsz,
                        SchedConfig { max_batch: bsz, prefill_chunk: 4 });
                    for r in &reqs {
                        sched.submit(Request {
                            prompt: r.0.clone(),
                            max_new: r.1,
                            sampler: Sampler::Temperature(0.9),
                            seed: r.2,
                        }).unwrap();
                    }
                    let comps = sched.run_all().unwrap();
                    assert_eq!(comps.len(), reqs.len());
                    for (comp, want) in comps.iter().zip(&want) {
                        assert_eq!(
                            &comp.tokens, want,
                            "batch {bsz} threads {nt} req {}: scheduler \
                             output diverged from solo generate",
                            comp.id
                        );
                    }
                });
            }
        }
    }

    /// More requests than KV slots: exhaustion queues (never panics) and
    /// every request still completes with its solo output.
    #[test]
    fn pool_exhaustion_queues_and_retirement_readmits() {
        let c = core(32);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(2 + 3 * i, 7 + i), 3 + i, 900 + i as u64))
            .collect();
        let mut sched = Scheduler::new(c.clone(), 2, SchedConfig {
            max_batch: 8, // clamped to the 2 slots
            prefill_chunk: 8,
        });
        for r in &reqs {
            sched.submit(Request {
                prompt: r.0.clone(),
                max_new: r.1,
                sampler: Sampler::Greedy,
                seed: r.2,
            }).unwrap();
        }
        assert_eq!(sched.n_queued(), 5);
        let mut max_live = 0usize;
        while !sched.is_idle() {
            sched.tick().unwrap();
            max_live = max_live.max(sched.n_live());
        }
        assert!(max_live <= 2, "live {max_live} exceeded the 2 slots");
        let comps = sched.take_completed();
        assert_eq!(comps.len(), 5);
        for (comp, r) in comps.iter().zip(&reqs) {
            let mut e = Engine::from_core(c.clone());
            let want =
                generate(&mut e, &r.0, r.1, Sampler::Greedy, r.2)
                    .unwrap()
                    .tokens;
            assert_eq!(comp.tokens, want, "req {}", comp.id);
            assert_eq!(comp.prompt_len, r.0.len());
            assert_eq!(comp.token_gaps.len(), comp.tokens.len());
            assert!(comp.first_token_secs >= 0.0);
            assert!(comp.finish_secs >= comp.first_token_secs);
        }
    }

    /// Page-granular exhaustion: with 6-row pages and only 4 pages, the
    /// 2-page requests queue (at most 2 live at once), every request
    /// still completes with its solo output, and the pool never exceeds
    /// its page budget.
    #[test]
    fn page_exhaustion_queues_and_completes() {
        let c = core(36);
        let reqs: Vec<(Vec<i32>, usize, u64)> = (0..5)
            .map(|i| (prompt(7, 5 + i), 4, 700 + i as u64))
            .collect();
        // rows needed per request = 7 prompt + 4 - 1 decode feeds = 10
        // -> 2 pages of 6 rows each; 4 pages total -> <= 2 live
        let mut sched = Scheduler::with_pool(
            c.clone(),
            KvPool::for_core_paged(&c, 4, 6),
            SchedConfig { max_batch: 8, prefill_chunk: 4 });
        for r in &reqs {
            sched.submit(Request {
                prompt: r.0.clone(),
                max_new: r.1,
                sampler: Sampler::Greedy,
                seed: r.2,
            }).unwrap();
        }
        let mut max_live = 0usize;
        while !sched.is_idle() {
            sched.tick().unwrap();
            max_live = max_live.max(sched.n_live());
        }
        assert!(max_live <= 2, "live {max_live} exceeded the page budget");
        assert!(sched.pool().peak_pages_in_use() <= 4);
        assert_eq!(sched.pool().pages_in_use(), 0, "pages leaked");
        let comps = sched.take_completed();
        assert_eq!(comps.len(), reqs.len());
        for (comp, r) in comps.iter().zip(&reqs) {
            assert_eq!(comp.tokens, solo_greedy(&c, r), "req {}", comp.id);
        }
    }

    fn solo_greedy(core: &Arc<ModelCore>, req: &(Vec<i32>, usize, u64))
                   -> Vec<i32> {
        let mut e = Engine::from_core(core.clone());
        generate(&mut e, &req.0, req.1, Sampler::Greedy, req.2)
            .unwrap()
            .tokens
    }

    /// A sequence that fills its context retires instead of erroring, and
    /// matches generate()'s truncation behavior.
    #[test]
    fn context_full_retires_like_generate_truncates() {
        let c = core(33);
        let p = prompt(CTX - 3, 5);
        let mut e = Engine::from_core(c.clone());
        let want = generate(&mut e, &p, 10, Sampler::Greedy, 7)
            .unwrap()
            .tokens;
        assert!(want.len() < 10, "prompt too short to hit the ctx cap");
        let mut sched =
            Scheduler::new(c, 1, SchedConfig::default());
        sched.submit(Request {
            prompt: p,
            max_new: 10,
            sampler: Sampler::Greedy,
            seed: 7,
        }).unwrap();
        let comps = sched.run_all().unwrap();
        assert_eq!(comps[0].tokens, want);
    }

    #[test]
    fn submit_rejects_bad_requests() {
        let c = core(34);
        let mut sched = Scheduler::new(c, 1, SchedConfig::default());
        assert!(sched.submit(Request {
            prompt: vec![],
            max_new: 1,
            sampler: Sampler::Greedy,
            seed: 1,
        }).is_err());
        assert!(sched.submit(Request {
            prompt: vec![0; CTX + 1],
            max_new: 1,
            sampler: Sampler::Greedy,
            seed: 1,
        }).is_err());
    }

    #[test]
    fn zero_budget_request_completes_empty() {
        let c = core(35);
        let mut sched = Scheduler::new(c, 1, SchedConfig::default());
        sched.submit(Request {
            prompt: prompt(4, 3),
            max_new: 0,
            sampler: Sampler::Greedy,
            seed: 1,
        }).unwrap();
        let comps = sched.run_all().unwrap();
        assert!(comps[0].tokens.is_empty());
    }
}
