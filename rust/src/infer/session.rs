//! Per-request mutable state for the serving core: a [`Session`] owns
//! exactly what one in-flight sequence needs - its position, its sampler
//! RNG, its page-table lease from the shared paged
//! [`KvPool`](crate::infer::kv::KvPool) (reserved for the request's
//! worst-case row count at admission, so decode can never fail a KV
//! allocation), and its generation bookkeeping (prompt progress, emitted
//! tokens, latency timestamps, an optional absolute deadline).
//! Everything immutable lives in the shared
//! [`ModelCore`](crate::infer::core::ModelCore).
//!
//! Timestamps are `f64` seconds on the scheduler's
//! [`Clock`](crate::util::clock::Clock), so the same bookkeeping runs on
//! wall time in production and on the deterministic manual clock in
//! deadline tests and the open-loop simulator.
//!
//! The RNG is forked exactly like `infer::generate::generate` forks it
//! (`Rng::new(seed).fork("sample")`), and tokens are sampled in the same
//! order, so a session scheduled inside any batch emits the same token
//! stream as a solo `generate` call with the same `(prompt, seed,
//! sampler)` - the scheduler-vs-solo equivalence tests pin this.

use crate::infer::generate::{sample, Sampler};
use crate::infer::kv::KvLease;
use crate::util::rng::Rng;

/// One queued or in-flight generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampler: Sampler,
    pub seed: u64,
    /// Optional completion budget in seconds, measured from submission
    /// on the scheduler's clock. Expired in queue: the request is shed
    /// with [`FinishReason::TimedOut`] and no output. Expired live: it
    /// retires with its partial output. `None` = no deadline.
    pub deadline: Option<f64>,
    /// Priority class, lower = sooner. Only consulted by the EDF policy
    /// (`SchedPolicy::Edf`) as the ordering fallback for deadline-free
    /// requests: any deadline outranks any priority class, and FIFO
    /// ignores this field entirely. Convention: 0 = interactive,
    /// 1 = normal (the default), 2+ = batch.
    pub priority: u8,
}

impl Request {
    /// A request with no deadline (add one with
    /// [`Request::with_deadline`]) and the default priority class 1
    /// (change it with [`Request::with_priority`]).
    pub fn new(prompt: Vec<i32>, max_new: usize, sampler: Sampler,
               seed: u64) -> Request {
        Request {
            prompt,
            max_new,
            sampler,
            seed,
            deadline: None,
            priority: 1,
        }
    }

    /// Set a completion deadline, in seconds from submission.
    pub fn with_deadline(mut self, secs: f64) -> Request {
        self.deadline = Some(secs);
        self
    }

    /// Set the EDF fallback priority class (lower = sooner; see
    /// [`Request::priority`]).
    pub fn with_priority(mut self, class: u8) -> Request {
        self.priority = class;
        self
    }
}

/// Why a request left the scheduler. The first two are success shapes
/// ([`FinishReason::is_ok`]); the rest carry whatever partial output was
/// produced before the exit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted its full `max_new` token budget.
    Done,
    /// Hit the model's context limit first - same truncation a solo
    /// `generate` performs.
    ContextFull,
    /// Deadline expired, in queue (no output) or mid-flight (partial
    /// output kept).
    TimedOut,
    /// Cancelled via `Scheduler::cancel`; partial output kept.
    Cancelled,
    /// An isolated per-request failure (forward / KV error, with the
    /// error text); co-batched requests are unaffected.
    Failed(String),
}

impl FinishReason {
    /// Did the request run to a natural end (budget or context)?
    pub fn is_ok(&self) -> bool {
        matches!(self, FinishReason::Done | FinishReason::ContextFull)
    }
}

/// A finished request with its output, exit reason, and latency
/// accounting (seconds on the scheduler's clock).
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// how the request exited (see [`FinishReason`])
    pub finish: FinishReason,
    /// submit -> first emitted token (includes queue wait), seconds
    pub first_token_secs: f64,
    /// submit -> retirement, seconds
    pub finish_secs: f64,
    /// per-token emission gaps (first gap measured from submission)
    pub token_gaps: Vec<f64>,
}

/// A live sequence: mutable state only. Created by the scheduler when a
/// request is admitted (a KV slot could be leased), destroyed into a
/// [`Completion`] when it retires (the lease goes back to the pool).
pub struct Session {
    pub id: u64,
    pub(crate) lease: KvLease,
    /// next KV row to write == number of positions fed so far
    pub pos: usize,
    pub(crate) prompt: Vec<i32>,
    /// prompt tokens fed so far (chunked prefill cursor)
    pub(crate) prefilled: usize,
    /// sampled-but-not-yet-emitted token (valid once the prompt is done)
    pub(crate) next: i32,
    pub(crate) rng: Rng,
    pub(crate) sampler: Sampler,
    pub(crate) max_new: usize,
    pub out: Vec<i32>,
    pub(crate) submitted: f64,
    /// absolute clock deadline (submission time + request deadline)
    pub(crate) deadline: Option<f64>,
    /// EDF fallback class carried over from [`Request::priority`]
    pub(crate) priority: u8,
    pub(crate) first_token_secs: Option<f64>,
    pub(crate) last_event: f64,
    pub(crate) token_gaps: Vec<f64>,
}

impl Session {
    /// `cached_rows` is the prefix-cache match: that many leading prompt
    /// rows already sit in the lease's pages (shared by refcount), so the
    /// prefill cursor and KV position both start past them. Always
    /// strictly less than the prompt length - the final prompt chunk is
    /// prefilled by every path, so the first-token sample reads logits
    /// produced identically to a cold run.
    pub(crate) fn start(id: u64, req: Request, lease: KvLease,
                        cached_rows: usize, submitted: f64,
                        deadline: Option<f64>) -> Session {
        debug_assert!(cached_rows < req.prompt.len().max(1));
        Session {
            id,
            lease,
            pos: cached_rows,
            max_new: req.max_new,
            out: Vec::with_capacity(req.max_new),
            rng: Rng::new(req.seed).fork("sample"),
            sampler: req.sampler,
            priority: req.priority,
            prompt: req.prompt,
            prefilled: cached_rows,
            next: 0,
            submitted,
            deadline,
            first_token_secs: None,
            last_event: submitted,
            token_gaps: Vec::with_capacity(req.max_new),
        }
    }

    pub(crate) fn prompt_done(&self) -> bool {
        self.prefilled == self.prompt.len()
    }

    /// Has this session's absolute deadline passed at `now`?
    pub(crate) fn expired(&self, now: f64) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }

    /// Sample from `logits` with this session's RNG (same call order as
    /// solo `generate`).
    pub(crate) fn sample(&mut self, logits: &[f32]) -> i32 {
        sample(logits, self.sampler, &mut self.rng)
    }

    /// Record one emitted token's latency.
    pub(crate) fn emit(&mut self, tok: i32, now: f64) {
        let gap = (now - self.last_event).max(0.0);
        self.last_event = now;
        if self.first_token_secs.is_none() {
            self.first_token_secs = Some((now - self.submitted).max(0.0));
        }
        self.token_gaps.push(gap);
        self.out.push(tok);
    }

    pub(crate) fn finish(self, now: f64, finish: FinishReason)
                         -> (KvLease, Completion) {
        let first = self.first_token_secs.unwrap_or(0.0);
        (
            self.lease,
            Completion {
                id: self.id,
                prompt_len: self.prompt.len(),
                tokens: self.out,
                finish,
                first_token_secs: first,
                finish_secs: (now - self.submitted).max(0.0),
                token_gaps: self.token_gaps,
            },
        )
    }
}
