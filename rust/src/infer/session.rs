//! Per-request mutable state for the serving core: a [`Session`] owns
//! exactly what one in-flight sequence needs - its position, its sampler
//! RNG, its page-table lease from the shared paged
//! [`KvPool`](crate::infer::kv::KvPool) (reserved for the request's
//! worst-case row count at admission, so decode can never fail a KV
//! allocation), and its generation bookkeeping (prompt progress, emitted
//! tokens, latency timestamps). Everything immutable lives in the shared
//! [`ModelCore`](crate::infer::core::ModelCore).
//!
//! The RNG is forked exactly like `infer::generate::generate` forks it
//! (`Rng::new(seed).fork("sample")`), and tokens are sampled in the same
//! order, so a session scheduled inside any batch emits the same token
//! stream as a solo `generate` call with the same `(prompt, seed,
//! sampler)` - the scheduler-vs-solo equivalence tests pin this.

use std::time::Instant;

use crate::infer::generate::{sample, Sampler};
use crate::infer::kv::KvLease;
use crate::util::rng::Rng;

/// One queued or in-flight generation request.
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampler: Sampler,
    pub seed: u64,
}

/// A finished request with its output and latency accounting.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// submit -> first emitted token (includes queue wait), seconds
    pub first_token_secs: f64,
    /// submit -> retirement, seconds
    pub finish_secs: f64,
    /// per-token emission gaps (first gap measured from submission)
    pub token_gaps: Vec<f64>,
}

/// A live sequence: mutable state only. Created by the scheduler when a
/// request is admitted (a KV slot could be leased), destroyed into a
/// [`Completion`] when it retires (the lease goes back to the pool).
pub struct Session {
    pub id: u64,
    pub(crate) lease: KvLease,
    /// next KV row to write == number of positions fed so far
    pub pos: usize,
    pub(crate) prompt: Vec<i32>,
    /// prompt tokens fed so far (chunked prefill cursor)
    pub(crate) prefilled: usize,
    /// sampled-but-not-yet-emitted token (valid once the prompt is done)
    pub(crate) next: i32,
    pub(crate) rng: Rng,
    pub(crate) sampler: Sampler,
    pub(crate) max_new: usize,
    pub out: Vec<i32>,
    pub(crate) submitted: Instant,
    pub(crate) first_token_secs: Option<f64>,
    pub(crate) last_event: Instant,
    pub(crate) token_gaps: Vec<f64>,
}

impl Session {
    pub(crate) fn start(id: u64, req: Request, lease: KvLease,
                        submitted: Instant) -> Session {
        Session {
            id,
            lease,
            pos: 0,
            max_new: req.max_new,
            out: Vec::with_capacity(req.max_new),
            rng: Rng::new(req.seed).fork("sample"),
            sampler: req.sampler,
            prompt: req.prompt,
            prefilled: 0,
            next: 0,
            submitted,
            first_token_secs: None,
            last_event: submitted,
            token_gaps: Vec::with_capacity(req.max_new),
        }
    }

    pub(crate) fn prompt_done(&self) -> bool {
        self.prefilled == self.prompt.len()
    }

    /// Sample from `logits` with this session's RNG (same call order as
    /// solo `generate`).
    pub(crate) fn sample(&mut self, logits: &[f32]) -> i32 {
        sample(logits, self.sampler, &mut self.rng)
    }

    /// Record one emitted token's latency.
    pub(crate) fn emit(&mut self, tok: i32, now: Instant) {
        let gap = now.duration_since(self.last_event).as_secs_f64();
        self.last_event = now;
        if self.first_token_secs.is_none() {
            self.first_token_secs =
                Some(now.duration_since(self.submitted).as_secs_f64());
        }
        self.token_gaps.push(gap);
        self.out.push(tok);
    }

    pub(crate) fn finish(self, now: Instant) -> (KvLease, Completion) {
        let first = self.first_token_secs.unwrap_or(0.0);
        (
            self.lease,
            Completion {
                id: self.id,
                prompt_len: self.prompt.len(),
                tokens: self.out,
                first_token_secs: first,
                finish_secs:
                    now.duration_since(self.submitted).as_secs_f64(),
                token_gaps: self.token_gaps,
            },
        )
    }
}
