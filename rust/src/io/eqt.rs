//! `.eqt` checkpoint container (safetensors-style): JSON header + raw bytes.
//!
//! Layout on disk:
//!   [0..8)   magic  b"EQAT\x00\x01\x00\x00"  (version 1)
//!   [8..16)  u64 LE header length H
//!   [16..16+H)  JSON: {"tensors": {name: {dtype, shape, offset, nbytes}},
//!                      "meta": {string: string}}
//!   [16+H..) raw little-endian data, offsets relative to data start
//!
//! Stores fp checkpoints (f32), packed quantized models (u32 bitstreams,
//! f16-as-u16 scales) and optimizer state. Round-trips bit-exactly (tested).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

const MAGIC: [u8; 8] = *b"EQAT\x00\x01\x00\x00";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EqtDtype {
    F32,
    I32,
    U32,
    U16,
}

impl EqtDtype {
    pub fn name(self) -> &'static str {
        match self {
            EqtDtype::F32 => "f32",
            EqtDtype::I32 => "i32",
            EqtDtype::U32 => "u32",
            EqtDtype::U16 => "u16",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => EqtDtype::F32,
            "i32" => EqtDtype::I32,
            "u32" => EqtDtype::U32,
            "u16" => EqtDtype::U16,
            _ => bail!("unknown eqt dtype {s}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            EqtDtype::U16 => 2,
            _ => 4,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EqtTensor {
    pub dtype: EqtDtype,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl EqtTensor {
    pub fn f32(shape: &[usize], data: &[f32]) -> EqtTensor {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        EqtTensor { dtype: EqtDtype::F32, shape: shape.to_vec(), bytes }
    }

    pub fn u32(shape: &[usize], data: &[u32]) -> EqtTensor {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        EqtTensor { dtype: EqtDtype::U32, shape: shape.to_vec(), bytes }
    }

    pub fn u16(shape: &[usize], data: &[u16]) -> EqtTensor {
        let mut bytes = Vec::with_capacity(data.len() * 2);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        EqtTensor { dtype: EqtDtype::U16, shape: shape.to_vec(), bytes }
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != EqtDtype::F32 {
            bail!("tensor is {}, wanted f32", self.dtype.name());
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_u32(&self) -> Result<Vec<u32>> {
        if self.dtype != EqtDtype::U32 {
            bail!("tensor is {}, wanted u32", self.dtype.name());
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_u16(&self) -> Result<Vec<u16>> {
        if self.dtype != EqtDtype::U16 {
            bail!("tensor is {}, wanted u16", self.dtype.name());
        }
        Ok(self
            .bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }
}

/// In-memory checkpoint: ordered tensors + string metadata.
#[derive(Debug, Default)]
pub struct Eqt {
    pub tensors: BTreeMap<String, EqtTensor>,
    pub meta: BTreeMap<String, String>,
}

impl Eqt {
    pub fn new() -> Eqt {
        Eqt::default()
    }

    pub fn insert_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        self.tensors.insert(name.into(), EqtTensor::f32(shape, data));
    }

    pub fn get(&self, name: &str) -> Result<&EqtTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint has no tensor '{name}'"))
    }

    pub fn f32_vec(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.to_f32()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut header = BTreeMap::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            header.insert(
                name.clone(),
                Json::obj(vec![
                    ("dtype", Json::str(t.dtype.name())),
                    (
                        "shape",
                        Json::arr(
                            t.shape.iter().map(|&d| Json::num(d as f64))
                                .collect(),
                        ),
                    ),
                    ("offset", Json::num(offset as f64)),
                    ("nbytes", Json::num(t.bytes.len() as f64)),
                ]),
            );
            offset += t.bytes.len();
        }
        let meta = Json::Obj(
            self.meta
                .iter()
                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                .collect(),
        );
        let head = Json::obj(vec![
            ("tensors", Json::Obj(header)),
            ("meta", meta),
        ])
        .dump();

        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref()).with_context(|| {
                format!("create {}", path.as_ref().display())
            })?,
        );
        f.write_all(&MAGIC)?;
        f.write_all(&(head.len() as u64).to_le_bytes())?;
        f.write_all(head.as_bytes())?;
        for t in self.tensors.values() {
            f.write_all(&t.bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Eqt> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref()).with_context(|| {
                format!("open {}", path.as_ref().display())
            })?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if magic != MAGIC {
            bail!("{} is not an .eqt file", path.as_ref().display());
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut head = vec![0u8; hlen];
        f.read_exact(&mut head)?;
        let j = Json::parse(std::str::from_utf8(&head)?)?;

        let mut data = Vec::new();
        f.read_to_end(&mut data)?;

        let mut out = Eqt::new();
        for (name, tj) in j.get("tensors")?.as_obj()? {
            let off = tj.get("offset")?.as_usize()?;
            let nbytes = tj.get("nbytes")?.as_usize()?;
            if off + nbytes > data.len() {
                bail!("tensor '{name}' out of bounds");
            }
            out.tensors.insert(
                name.clone(),
                EqtTensor {
                    dtype: EqtDtype::parse(tj.get("dtype")?.as_str()?)?,
                    shape: tj.get("shape")?.usize_list()?,
                    bytes: data[off..off + nbytes].to_vec(),
                },
            );
        }
        for (k, v) in j.get("meta")?.as_obj()? {
            out.meta.insert(k.clone(), v.as_str()?.to_string());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("eqt_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip_bit_exact() {
        let mut r = Rng::new(1);
        let mut ck = Eqt::new();
        let mut data = vec![0.0f32; 1000];
        r.fill_normal(&mut data, 0.0, 1.0);
        ck.insert_f32("params", &[10, 100], &data);
        ck.tensors.insert(
            "packed".into(),
            EqtTensor::u32(&[3], &[0xdeadbeef, 0, u32::MAX]),
        );
        ck.tensors.insert(
            "scales".into(),
            EqtTensor::u16(&[2, 2], &[1, 2, 3, 0xffff]),
        );
        ck.meta.insert("preset".into(), "tiny".into());
        ck.meta.insert("bits".into(), "2".into());

        let p = tmp("roundtrip");
        ck.save(&p).unwrap();
        let back = Eqt::load(&p).unwrap();
        std::fs::remove_file(&p).ok();

        assert_eq!(back.f32_vec("params").unwrap(), data);
        assert_eq!(
            back.get("packed").unwrap().to_u32().unwrap(),
            vec![0xdeadbeef, 0, u32::MAX]
        );
        assert_eq!(
            back.get("scales").unwrap().to_u16().unwrap(),
            vec![1, 2, 3, 0xffff]
        );
        assert_eq!(back.get("params").unwrap().shape, vec![10, 100]);
        assert_eq!(back.meta["preset"], "tiny");
    }

    #[test]
    fn rejects_wrong_magic() {
        let p = tmp("badmagic");
        std::fs::write(&p, b"NOTEQAT!plusmore").unwrap();
        assert!(Eqt::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let ck = Eqt::new();
        assert!(ck.get("nope").is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = EqtTensor::u32(&[1], &[5]);
        assert!(t.to_f32().is_err());
        assert!(t.to_u16().is_err());
        assert!(t.to_u32().is_ok());
    }
}
