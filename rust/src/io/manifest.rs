//! artifacts/manifest.json model: presets, flat-buffer layouts, artifact
//! argument specs. This file is the single source of truth for all shapes -
//! produced by python/compile/aot.py, consumed everywhere in the coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One tensor inside a flat f32 buffer.
#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered (name -> offset/shape) map over one flat f32 vector. Mirrors
/// python/compile/model.py::Layout.
#[derive(Clone, Debug)]
pub struct Layout {
    pub entries: Vec<LayoutEntry>,
    pub size: usize,
    index: BTreeMap<String, usize>,
}

impl Layout {
    pub fn new(entries: Vec<LayoutEntry>) -> Layout {
        let size = entries
            .last()
            .map(|e| e.offset + e.numel())
            .unwrap_or(0);
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        Layout { entries, size, index }
    }

    pub fn entry(&self, name: &str) -> Result<&LayoutEntry> {
        self.index
            .get(name)
            .map(|&i| &self.entries[i])
            .ok_or_else(|| anyhow!("layout has no entry '{name}'"))
    }

    pub fn slice<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self.entry(name)?;
        Ok(&flat[e.offset..e.offset + e.numel()])
    }

    pub fn slice_mut<'a>(
        &self,
        flat: &'a mut [f32],
        name: &str,
    ) -> Result<&'a mut [f32]> {
        let e = self.entry(name)?;
        Ok(&mut flat[e.offset..e.offset + e.numel()])
    }

    /// Verify entries partition [0, size) exactly (tested invariant).
    pub fn validate(&self) -> Result<()> {
        let mut pos = 0usize;
        for e in &self.entries {
            if e.offset != pos {
                bail!("layout gap/overlap before '{}'", e.name);
            }
            pos += e.numel();
        }
        if pos != self.size {
            bail!("layout size {} != covered {}", self.size, pos);
        }
        Ok(())
    }

    fn from_json(j: &Json) -> Result<Layout> {
        let mut entries = Vec::new();
        for e in j.as_arr()? {
            entries.push(LayoutEntry {
                name: e.get("name")?.as_str()?.to_string(),
                offset: e.get("offset")?.as_usize()?,
                shape: e.get("shape")?.usize_list()?,
            });
        }
        Ok(Layout::new(entries))
    }
}

/// Model/batch geometry of one preset (mirrors python configs.Preset).
#[derive(Clone, Debug)]
pub struct PresetCfg {
    pub name: String,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub inter: usize,
    pub vocab: usize,
    pub block_batch: usize,
    pub block_ctx: usize,
    pub e2e_batch: usize,
    pub e2e_ctx: usize,
    pub eval_batch: usize,
    pub eval_ctx: usize,
    pub default_group: usize,
    pub group_sizes: Vec<usize>,
    pub lora_rank: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

impl PresetCfg {
    fn from_json(j: &Json) -> Result<PresetCfg> {
        Ok(PresetCfg {
            name: j.get("name")?.as_str()?.to_string(),
            dim: j.get("dim")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            inter: j.get("inter")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            block_batch: j.get("block_batch")?.as_usize()?,
            block_ctx: j.get("block_ctx")?.as_usize()?,
            e2e_batch: j.get("e2e_batch")?.as_usize()?,
            e2e_ctx: j.get("e2e_ctx")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            eval_ctx: j.get("eval_ctx")?.as_usize()?,
            default_group: j.get("default_group")?.as_usize()?,
            group_sizes: j.get("group_sizes")?.usize_list()?,
            lora_rank: j.get("lora_rank")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
        })
    }

    /// The 7 quantized linears of one block: (name, out, in).
    pub fn linears(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("attn.q", self.dim, self.dim),
            ("attn.k", self.dim, self.dim),
            ("attn.v", self.dim, self.dim),
            ("attn.o", self.dim, self.dim),
            ("mlp.gate", self.inter, self.dim),
            ("mlp.up", self.inter, self.dim),
            ("mlp.down", self.dim, self.inter),
        ]
    }
}

/// One lowered artifact (HLO text file + typed arg spec).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub preset: String,
    pub entry: String,
    pub group: Option<usize>,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Parsed manifest: presets (config + layouts) and artifact registry.
#[derive(Debug)]
pub struct Manifest {
    pub presets: BTreeMap<String, PresetInfo>,
    pub artifacts: Vec<ArtifactSpec>,
    pub root: std::path::PathBuf,
}

#[derive(Debug)]
pub struct PresetInfo {
    pub config: PresetCfg,
    pub layouts: BTreeMap<String, Layout>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} - run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, root)
    }

    pub fn parse(text: &str, root: std::path::PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut presets = BTreeMap::new();
        for (name, pj) in j.get("presets")?.as_obj()? {
            let config = PresetCfg::from_json(pj.get("config")?)?;
            let mut layouts = BTreeMap::new();
            for (lname, lj) in pj.get("layouts")?.as_obj()? {
                let lay = Layout::from_json(lj)?;
                lay.validate()
                    .with_context(|| format!("layout {name}/{lname}"))?;
                layouts.insert(lname.clone(), lay);
            }
            presets.insert(name.clone(), PresetInfo { config, layouts });
        }
        let mut artifacts = Vec::new();
        for aj in j.get("artifacts")?.as_arr()? {
            let mut args = Vec::new();
            for arg in aj.get("args")?.as_arr()? {
                let dt = match arg.get("dtype")?.as_str()? {
                    "f32" => Dtype::F32,
                    "s32" => Dtype::I32,
                    other => bail!("unknown dtype {other}"),
                };
                args.push(ArgSpec {
                    name: arg.get("name")?.as_str()?.to_string(),
                    shape: arg.get("shape")?.usize_list()?,
                    dtype: dt,
                });
            }
            let outputs = aj
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| o.as_str().map(String::from))
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                preset: aj.get("preset")?.as_str()?.to_string(),
                entry: aj.get("entry")?.as_str()?.to_string(),
                group: aj
                    .opt("group")
                    .map(|g| g.as_usize())
                    .transpose()?,
                file: aj.get("file")?.as_str()?.to_string(),
                args,
                outputs,
            });
        }
        Ok(Manifest { presets, artifacts, root })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no preset '{name}'"))
    }

    pub fn layout(&self, preset: &str, layout: &str) -> Result<&Layout> {
        self.preset(preset)?
            .layouts
            .get(layout)
            .ok_or_else(|| anyhow!("preset {preset} has no layout '{layout}'"))
    }

    pub fn artifact(&self, preset: &str, entry: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.preset == preset && a.entry == entry)
            .ok_or_else(|| {
                anyhow!("no artifact '{entry}' for preset '{preset}'")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "presets": {
        "t": {
          "config": {"name":"t","dim":8,"n_layers":1,"n_heads":2,
            "head_dim":4,"inter":16,"vocab":32,"block_batch":1,"block_ctx":4,
            "e2e_batch":1,"e2e_ctx":4,"eval_batch":1,"eval_ctx":4,
            "default_group":4,"group_sizes":[4],"lora_rank":2,
            "rope_theta":10000.0,"norm_eps":1e-5},
          "layouts": {
            "fp": [
              {"name":"a","offset":0,"shape":[2,3]},
              {"name":"b","offset":6,"shape":[4]}
            ]
          }
        }
      },
      "artifacts": [
        {"preset":"t","entry":"fwd","group":4,"file":"t/fwd.hlo.txt",
         "args":[{"name":"x","shape":[1,4],"dtype":"s32"}],
         "outputs":["logits"]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, "/tmp".into()).unwrap();
        let p = m.preset("t").unwrap();
        assert_eq!(p.config.dim, 8);
        assert_eq!(p.config.linears().len(), 7);
        let lay = m.layout("t", "fp").unwrap();
        assert_eq!(lay.size, 10);
        let a = m.artifact("t", "fwd").unwrap();
        assert_eq!(a.args[0].dtype, Dtype::I32);
        assert_eq!(a.group, Some(4));
    }

    #[test]
    fn layout_slice_and_validate() {
        let lay = Layout::new(vec![
            LayoutEntry { name: "a".into(), offset: 0, shape: vec![2, 2] },
            LayoutEntry { name: "b".into(), offset: 4, shape: vec![3] },
        ]);
        lay.validate().unwrap();
        let flat: Vec<f32> = (0..7).map(|x| x as f32).collect();
        assert_eq!(lay.slice(&flat, "b").unwrap(), &[4.0, 5.0, 6.0]);
        assert!(lay.slice(&flat, "nope").is_err());
    }

    #[test]
    fn layout_gap_detected() {
        let lay = Layout::new(vec![
            LayoutEntry { name: "a".into(), offset: 0, shape: vec![2] },
            LayoutEntry { name: "b".into(), offset: 3, shape: vec![1] },
        ]);
        assert!(lay.validate().is_err());
    }

    #[test]
    fn missing_lookups_error() {
        let m = Manifest::parse(SAMPLE, "/tmp".into()).unwrap();
        assert!(m.preset("x").is_err());
        assert!(m.artifact("t", "nope").is_err());
        assert!(m.layout("t", "nope").is_err());
    }
}
