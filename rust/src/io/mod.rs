//! On-disk formats: .eqt checkpoint container and the artifact manifest.
pub mod eqt;
pub mod manifest;
