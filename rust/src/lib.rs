//! EfficientQAT reproduction: Rust coordinator over AOT-compiled JAX/Pallas
//! artifacts (see DESIGN.md for the three-layer architecture).
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod infer;
pub mod io;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod xla_stub;
