//! `eqat` - the EfficientQAT coordinator CLI (leader entrypoint).
//! See `eqat help` / rust/src/cli.rs for the command surface.

use anyhow::{bail, Result};

use efficientqat::cli::{parse, Cli, USAGE};
use efficientqat::config::{QuantScheme, TrainHp, TrainableSet};
use efficientqat::coordinator::pipeline::{efficient_qat, PhaseToggle};
use efficientqat::coordinator::pretrain::{pretrain, PretrainOpts};
use efficientqat::data::corpus::domain_redpajama;
use efficientqat::data::loader::LmLoader;
use efficientqat::eval::fwd::ModelRef;
use efficientqat::exp::{tables, ExpCtx};
use efficientqat::infer::engine::Engine;
use efficientqat::infer::generate::{generate, Sampler};
use efficientqat::model::checkpoint::FpCheckpoint;
use efficientqat::model::quantized::QuantizedModel;
use efficientqat::util::logging;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx(cli: &Cli) -> Result<ExpCtx> {
    ExpCtx::new(&cli.flag_or("artifacts", "artifacts"),
                &cli.flag_or("runs", "runs"),
                &cli.flag_or("backend", "auto"))
}

fn run(args: &[String]) -> Result<()> {
    if args.is_empty() || args[0] == "help" || args[0] == "--help" {
        println!("{USAGE}");
        return Ok(());
    }
    let cli = parse(args)?;
    let preset = cli.flag_or("preset", "tiny");

    match cli.cmd.as_str() {
        "pretrain" => {
            let c = ctx(&cli)?;
            let cfg = c.rt.manifest().preset(&preset)?.config.clone();
            let world = c.world_for(&preset)?;
            let mut loader = LmLoader::new(&world, &domain_redpajama(), 11,
                                           cfg.e2e_batch, cfg.e2e_ctx);
            let opts = PretrainOpts {
                steps: cli.flag_usize("steps", 300)?,
                lr: cli.flag_f64("lr", 3e-3)?,
                seed: cli.flag_usize("seed", 5)? as u64,
                log_every: 20,
            };
            let (params, report) = pretrain(c.rt.as_ref(), &preset, &mut loader,
                                            &opts)?;
            let out = cli.flag_or("out", &format!("runs/{preset}-fp.eqt"));
            FpCheckpoint { preset: preset.clone(), params,
                           step: opts.steps }
                .save(&out)?;
            println!("saved {out}; final loss {:.4} ({:.1}s)",
                     report.losses.last().unwrap(), report.seconds);
        }
        "train" => {
            // Full pipeline on any backend (native by default via `auto`
            // when no artifacts exist): pretrain (cached) -> Block-AP ->
            // E2E-QP -> perplexity vs the RTN baseline.
            let mut c = ctx(&cli)?;
            c.pretrain_steps = cli.flag_usize("pretrain-steps", 120)?;
            let cfg = c.rt.manifest().preset(&preset)?.config.clone();
            let params = c.pretrained(&preset)?;
            let bits = cli.flag_usize("bits", 2)? as u32;
            let group = cli.flag_usize("group", cfg.default_group)?;
            let sch = QuantScheme::new(bits, group);
            let mut hp = TrainHp::default();
            hp.block_samples = cli.flag_usize("block-samples", 32)?;
            hp.block_epochs = cli.flag_usize("block-epochs",
                                             hp.block_epochs)?;
            hp.e2e_samples = cli.flag_usize("e2e-samples", 32)?;
            if let Some(t) = cli.flag("trainable") {
                hp.trainable = TrainableSet::parse(t)?;
            }
            let world = c.world_for(&preset)?;
            let dom = domain_redpajama();
            let (mut qm, report) = efficient_qat(
                c.rt.as_ref(), &preset, &params, sch, &hp, &world, &dom,
                PhaseToggle::default())?;
            qm.round_scales_f16();
            if let Some(bap) = &report.block_ap {
                let mut drops = 0usize;
                for (b, curve) in bap.loss_curves.iter().enumerate() {
                    let first = curve.first().copied().unwrap_or(0.0);
                    let last = curve.last().copied().unwrap_or(0.0);
                    anyhow::ensure!(
                        curve.iter().all(|l| l.is_finite()),
                        "block {b}: non-finite loss curve"
                    );
                    if last < first {
                        drops += 1;
                    }
                    println!("block {b}: recon loss {first:.5} -> \
                              {last:.5}");
                }
                println!("block-AP: {drops}/{} blocks improved \
                          ({:.1}s)", bap.loss_curves.len(), bap.seconds);
            }
            if let Some(e2e) = &report.e2e {
                println!(
                    "e2e-qp: loss {:.4} -> {:.4} ({:.1}s)",
                    e2e.losses.first().copied().unwrap_or(f32::NAN),
                    e2e.losses.last().copied().unwrap_or(f32::NAN),
                    e2e.seconds
                );
            }
            // perplexity vs the RTN baseline on the same held-out stream
            let rtn = efficientqat::coordinator::block_ap::
                rtn_quantize_model(c.rt.as_ref(), &preset, &params, sch)?;
            let n_ppl = cli.flag_usize("ppl-batches", 4)?;
            let ppl_rtn = efficientqat::eval::ppl::perplexity(
                c.rt.as_ref(), &ModelRef::Quant(&rtn), &world, &dom,
                n_ppl, 991)?;
            let ppl_eqat = efficientqat::eval::ppl::perplexity(
                c.rt.as_ref(), &ModelRef::Quant(&qm), &world, &dom,
                n_ppl, 991)?;
            let out = cli.flag_or(
                "out", &format!("runs/{preset}-{}.eqt", sch.tag()));
            qm.save(&out)?;
            println!(
                "{} ppl: EfficientQAT {ppl_eqat:.2} vs RTN {ppl_rtn:.2} \
                 ({}) -> saved {out}",
                sch.tag(),
                if ppl_eqat < ppl_rtn { "BEATS RTN" } else {
                    "does NOT beat RTN" },
            );
            anyhow::ensure!(
                ppl_eqat.is_finite() && ppl_rtn.is_finite(),
                "non-finite perplexity"
            );
            // opt-in hard gate (the integration test asserts this at a
            // better-powered operating point; tiny smoke budgets can be
            // noisy, so the CLI only fails when explicitly asked to)
            if cli.flag_bool("require-beat-rtn") {
                anyhow::ensure!(
                    ppl_eqat < ppl_rtn,
                    "EfficientQAT ppl {ppl_eqat:.2} did not beat RTN \
                     {ppl_rtn:.2}"
                );
            }
        }
        "quantize" => {
            let c = ctx(&cli)?;
            let params = c.pretrained(&preset)?;
            let cfg = c.rt.manifest().preset(&preset)?.config.clone();
            let bits = cli.flag_usize("bits", 2)? as u32;
            let group = cli.flag_usize("group", cfg.default_group)?;
            let sch = QuantScheme::new(bits, group);
            let mut hp = TrainHp::default();
            if let Some(t) = cli.flag("trainable") {
                hp.trainable = TrainableSet::parse(t)?;
            }
            let world = c.world_for(&preset)?;
            let phases = PhaseToggle {
                block_ap: !cli.flag_bool("no-block-ap"),
                e2e_qp: !cli.flag_bool("no-e2e"),
            };
            let (mut qm, report) = efficient_qat(
                c.rt.as_ref(), &preset, &params, sch, &hp, &world,
                &domain_redpajama(), phases)?;
            qm.round_scales_f16();
            let out = cli.flag_or(
                "out", &format!("runs/{preset}-{}.eqt", sch.tag()));
            qm.save(&out)?;
            println!(
                "saved {out} ({:.2} MB packed) in {:.1}s",
                qm.packed_bytes() as f64 / 1e6,
                report.total_seconds
            );
        }
        "eval" => {
            let mut c = ctx(&cli)?;
            c.pretrain_steps =
                cli.flag_usize("pretrain-steps", c.pretrain_steps)?;
            if cli.flag_bool("ppl-only") {
                // bounded forward-only smoke (tier-1): wiki perplexity
                // through the backend's no-tape eval entries, nothing else
                let n = cli.flag_usize("ppl-batches", 2)?;
                let dom = efficientqat::data::corpus::domain_wiki();
                // the world must match the evaluated model's vocab, so a
                // loaded model sizes it from its own preset (like the
                // full eval path), not from --preset
                let ppl = match cli.flag("model") {
                    Some(path) => {
                        let qm = QuantizedModel::load(path)?;
                        let world = c.world_for(&qm.preset)?;
                        efficientqat::eval::ppl::perplexity(
                            c.rt.as_ref(), &ModelRef::Quant(&qm), &world,
                            &dom, n, 991)?
                    }
                    None => {
                        let world = c.world_for(&preset)?;
                        let params = c.pretrained(&preset)?;
                        efficientqat::eval::ppl::perplexity(
                            c.rt.as_ref(),
                            &ModelRef::Fp { preset: &preset,
                                            params: &params },
                            &world, &dom, n, 991)?
                    }
                };
                anyhow::ensure!(ppl.is_finite() && ppl > 1.0,
                                "bad forward-only perplexity {ppl}");
                println!("{preset} wiki ppl (forward-only, {n} \
                          batches): {ppl:.2}");
                return Ok(());
            }
            let (accs, avg, pw, pc) = match cli.flag("model") {
                Some(path) => {
                    let qm = QuantizedModel::load(path)?;
                    efficientqat::exp::sweeps::eval_model(
                        &c, &ModelRef::Quant(&qm))?
                }
                None => {
                    let params = c.pretrained(&preset)?;
                    efficientqat::exp::sweeps::eval_model(
                        &c, &ModelRef::Fp { preset: &preset,
                                            params: &params })?
                }
            };
            for (n, a) in &accs {
                println!("{n:>12}: {:.1}%", 100.0 * a);
            }
            println!("{:>12}: {:.1}%", "average", 100.0 * avg);
            println!("{:>12}: {pw:.2}", "wiki ppl");
            println!("{:>12}: {pc:.2}", "c4 ppl");
        }
        "generate" => {
            let c = ctx(&cli)?;
            let path = cli
                .flag("model")
                .ok_or_else(|| anyhow::anyhow!("--model FILE required"))?;
            let qm = QuantizedModel::load(path)?;
            let info = c.rt.manifest().preset(&qm.preset)?;
            let cfg = &info.config;
            let mut eng = Engine::new(&qm, info, cfg.eval_ctx)?;
            let world = c.world_for(&qm.preset)?;
            let prompt: Vec<i32> =
                vec![0, world.topic_tokens(0)[0], world.topic_tokens(0)[1]];
            let n = cli.flag_usize("tokens", 48)?;
            let temp = cli.flag_f64("temp", 0.8)? as f32;
            let rep = generate(&mut eng, &prompt, n,
                               Sampler::Temperature(temp), 7)?;
            println!("prompt {prompt:?} -> {:?}", rep.tokens);
            println!(
                "prefill {:.1}ms, decode {:.1} tok/s",
                rep.prefill_secs * 1e3,
                rep.decode_tok_per_sec
            );
        }
        "serve-sim" => {
            // Multi-request serving demo: a synthetic request stream
            // through the continuous-batching scheduler (shared
            // ModelCore, pooled KV slots, chunked prefill admission),
            // reporting aggregate throughput + latency percentiles.
            // --open-loop switches to the deterministic Poisson-arrival
            // simulator on the virtual clock (deadlines, backpressure,
            // optional fault injection) and reports goodput/shed/fail
            // counters plus the run digest. --shared-prefix switches the
            // request mix to N personas x M users (fixed system prompts,
            // short user suffixes) and turns on the cross-request prefix
            // cache (--no-cache runs the same mix cold).
            use efficientqat::infer::core::ModelCore;
            use efficientqat::infer::kv::{KvFormat, KvPool};
            use efficientqat::infer::openloop::{run_open_loop,
                                                OpenLoopCfg};
            use efficientqat::infer::sched::{SchedConfig, SchedPolicy,
                                             Scheduler, StreamEventKind};
            use efficientqat::infer::session::Request;
            use efficientqat::util::clock::Clock;
            use efficientqat::util::rng::Rng;
            use efficientqat::util::stats::percentile;
            use std::sync::Arc;

            let requests = cli.flag_usize("requests", 16)?;
            let slots = cli.flag_usize("slots", 4)?;
            let tokens = cli.flag_usize("tokens", 16)?;
            let plen = cli.flag_usize("prompt-len", 12)?.max(1);
            let chunk = cli.flag_usize("prefill-chunk", 8)?.max(1);
            let seed = cli.flag_usize("seed", 17)? as u64;
            let max_ctx = plen + tokens + 4;
            let shared = cli.flag_bool("shared-prefix");
            let personas = if shared {
                cli.flag_usize("personas", 4)?.max(1)
            } else {
                0
            };
            // shared prefixes only pay off when a system prompt spans
            // whole pages, so --shared-prefix defaults to 4-row pages
            let page_rows =
                cli.flag_usize("page-rows", if shared { 4 } else { 0 })?;
            let use_cache = shared && !cli.flag_bool("no-cache");
            // KV page storage: 16 = f32 (default), 8/4 = packed low-bit
            let kv_bits = cli.flag_usize("kv-bits", 16)? as u32;
            anyhow::ensure!(matches!(kv_bits, 4 | 8 | 16),
                            "--kv-bits wants 4, 8, or 16 (got {kv_bits})");
            // Admission policy: fifo (arrival order, default) or edf
            // (earliest absolute deadline first; deadline-free requests
            // fall back to priority classes behind deadline holders)
            let policy_name = cli.flag_or("policy", "fifo");
            let policy = match policy_name.as_str() {
                "fifo" => SchedPolicy::Fifo,
                "edf" => SchedPolicy::Edf,
                other => anyhow::bail!(
                    "--policy wants fifo or edf (got {other})"),
            };
            let prefill_budget = cli.flag_usize("prefill-budget", 0)?;
            let stream = cli.flag_bool("stream");

            let core = match cli.flag("model") {
                Some(path) => {
                    let c = ctx(&cli)?;
                    let qm = QuantizedModel::load(path)?;
                    let info = c.rt.manifest().preset(&qm.preset)?;
                    Arc::new(ModelCore::from_quantized(&qm, info,
                                                       max_ctx)?)
                }
                None => Arc::new(ModelCore::synthetic(
                    64, 4, 16, 128, 256, 2, QuantScheme::new(2, 32),
                    max_ctx, seed)?),
            };
            if cli.flag_bool("open-loop") {
                let cfg = OpenLoopCfg {
                    requests,
                    rate: cli.flag_f64("rate", 200.0)?,
                    tick_secs:
                        cli.flag_f64("tick-ms", 5.0)?.max(0.001) / 1e3,
                    prompt_len: plen,
                    max_new: tokens.max(1),
                    deadline_secs:
                        cli.flag_f64("deadline-ms", 500.0)? / 1e3,
                    seed,
                    slots,
                    max_batch: slots,
                    prefill_chunk: chunk,
                    max_queue: cli.flag_usize("max-queue", 64)?.max(1),
                    fault_rate: cli.flag_f64("fail-rate", 0.0)?,
                    personas,
                    page_rows,
                    prefix_cache: use_cache,
                    kv_bits,
                    policy,
                    prefill_budget,
                    stream,
                    token_cost_secs:
                        cli.flag_f64("token-cost-ms", 0.0)? / 1e3,
                    slo_first_token_secs:
                        cli.flag_f64("slo-ft-ms", 0.0)? / 1e3,
                    slo_token_secs:
                        cli.flag_f64("slo-tok-ms", 0.0)? / 1e3,
                };
                let r = run_open_loop(core, &cfg)?;
                println!(
                    "serve-sim --open-loop: {} arrivals at {:.0} req/s \
                     (virtual), seed {seed}, kv {}-bit ({} pool B)",
                    r.arrivals, cfg.rate, r.kv_bits, r.pool_bytes
                );
                println!(
                    "  goodput {} (done {}, ctx-full {})  shed {}  \
                     timed-out {}  failed {}  rejected {}",
                    r.goodput, r.done, r.context_full, r.shed_queued,
                    r.timed_out_live, r.failed, r.rejected
                );
                println!(
                    "  {} tokens over {} ticks ({:.2} virtual s); queue \
                     depth mean {:.2} max {}; peak {} live",
                    r.total_tokens, r.ticks, r.virtual_secs,
                    r.queue_depth_mean, r.queue_depth_max, r.peak_live
                );
                println!("  pages leaked {}  digest {:016x}",
                         r.leaked_pages, r.digest);
                println!(
                    "  policy {policy_name}  prefill-budget {}  streamed \
                     {} tok",
                    cfg.prefill_budget, r.streamed_tokens
                );
                if cfg.slo_first_token_secs > 0.0
                    || cfg.slo_token_secs > 0.0
                {
                    println!(
                        "  SLO goodput {}  p95 first-token {:.2}ms  p95 \
                         gap {:.2}ms",
                        r.slo_goodput,
                        r.p95_first_token_secs * 1e3,
                        r.p95_token_gap_secs * 1e3
                    );
                }
                if use_cache {
                    println!(
                        "  prefix cache     hits {}  misses {}  avoided \
                         {} tok  evictions {}",
                        r.cache_hits, r.cache_misses,
                        r.tokens_prefill_avoided, r.cache_evictions
                    );
                    anyhow::ensure!(
                        r.cache_hits > 0,
                        "shared-prefix run produced no cache hits");
                }
                anyhow::ensure!(r.goodput > 0,
                                "open-loop run produced no goodput");
                return Ok(());
            }
            let fmt = KvFormat::from_bits(kv_bits);
            let pool = if page_rows > 0 {
                let per_seq = (max_ctx + page_rows - 1) / page_rows;
                KvPool::for_core_paged_fmt(&core, slots.max(1) * per_seq,
                                           page_rows, fmt)
            } else {
                KvPool::for_core_fmt(&core, slots.max(1), fmt)
            };
            let mut sched = Scheduler::with_clock(
                core.clone(), pool,
                SchedConfig {
                    max_batch: slots,
                    prefill_chunk: chunk,
                    prefix_cache: use_cache,
                    kv_bits,
                    policy,
                    prefill_budget,
                    stream,
                    ..SchedConfig::default()
                },
                Clock::wall());
            // synthetic stream: varied prompt lengths/contents/budgets
            // (--shared-prefix: a fixed per-persona system prompt plus a
            // short random user suffix instead)
            let mut rng = Rng::new(seed).fork("serve-sim");
            for i in 0..requests {
                let prompt: Vec<i32> = if shared {
                    let p = rng.below(personas);
                    let slen = 1 + rng.below(3);
                    let mut toks: Vec<i32> = (0..plen)
                        .map(|k| ((k * 11 + p * 29 + 5) % 89) as i32)
                        .collect();
                    toks.extend(
                        (0..slen).map(|_| rng.below(core.vocab) as i32));
                    toks.truncate(max_ctx);
                    toks
                } else {
                    let n = 1 + rng.below(plen);
                    (0..n).map(|_| rng.below(core.vocab) as i32).collect()
                };
                sched.submit(Request::new(
                    prompt,
                    1 + rng.below(tokens.max(1)),
                    Sampler::Temperature(0.8),
                    seed + 1000 + i as u64,
                ))?;
            }
            let t0 = std::time::Instant::now();
            let mut ticks = 0usize;
            let mut max_live = 0usize;
            let mut streamed = 0usize;
            while !sched.is_idle() {
                sched.tick()?;
                ticks += 1;
                max_live = max_live.max(sched.n_live());
                if stream {
                    for ev in sched.take_stream_events() {
                        if matches!(ev.kind, StreamEventKind::Token(_)) {
                            streamed += 1;
                        }
                    }
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            let comps = sched.take_completed();
            let total: usize = comps.iter().map(|c| c.tokens.len()).sum();
            let gaps: Vec<f64> = comps
                .iter()
                .flat_map(|c| c.token_gaps.iter().map(|g| g * 1e3))
                .collect();
            let firsts: Vec<f64> = comps
                .iter()
                .map(|c| c.first_token_secs * 1e3)
                .collect();
            let finishes: Vec<f64> =
                comps.iter().map(|c| c.finish_secs * 1e3).collect();
            anyhow::ensure!(comps.len() == requests,
                            "serve-sim lost requests");
            anyhow::ensure!(total > 0, "serve-sim emitted no tokens");
            println!(
                "serve-sim: {requests} requests over {slots} KV slot(s), \
                 {ticks} ticks, max {max_live} live ({policy_name}, \
                 prefill budget {prefill_budget})"
            );
            if stream {
                anyhow::ensure!(
                    streamed == total,
                    "streamed {streamed} tokens but retired {total}");
                println!("  streamed         {streamed} tokens \
                          incrementally (matches retired output)");
            }
            println!(
                "  {total} tokens in {:.1}ms -> {:.0} tok/s aggregate",
                secs * 1e3,
                total as f64 / secs.max(1e-9)
            );
            println!(
                "  token latency    p50 {:.2}ms  p95 {:.2}ms",
                percentile(&gaps, 50.0), percentile(&gaps, 95.0)
            );
            println!(
                "  first token      p50 {:.2}ms  p95 {:.2}ms",
                percentile(&firsts, 50.0), percentile(&firsts, 95.0)
            );
            println!(
                "  request finish   p50 {:.2}ms  p95 {:.2}ms",
                percentile(&finishes, 50.0), percentile(&finishes, 95.0)
            );
            let pool = sched.pool();
            println!(
                "  page pool        {} pages x {} rows ({}-bit KV); peak \
                 {} in use ({:.0}%), {} B COW-copied",
                pool.n_pages(),
                pool.page_rows(),
                pool.format().bits(),
                pool.peak_pages_in_use(),
                100.0 * pool.peak_pages_in_use() as f64
                    / pool.n_pages().max(1) as f64,
                pool.bytes_copied()
            );
            if use_cache {
                let st = sched.stats();
                println!(
                    "  prefix cache     hits {}  misses {}  avoided {} \
                     tok  evictions {}  ({} pages cached)",
                    st.cache_hits, st.cache_misses,
                    st.tokens_prefill_avoided, st.cache_evictions,
                    sched.pool().cached_pages()
                );
                anyhow::ensure!(
                    st.cache_hits > 0,
                    "shared-prefix run produced no cache hits");
                sched.flush_prefix_cache();
            }
            anyhow::ensure!(sched.pool().pages_in_use() == 0,
                            "serve-sim leaked KV pages");
        }
        "size" => {
            let name = cli.flag_or("model", "llama2-7b");
            let shape = efficientqat::config::llama_by_name(&name)?;
            println!(
                "{} fp16: {:.2} GiB",
                shape.name,
                efficientqat::quant::size::fp16_size_gib(&shape)
            );
            for bits in [4u32, 3, 2] {
                for group in [32usize, 64, 128] {
                    let r = efficientqat::quant::size::report(
                        &shape, QuantScheme::new(bits, group));
                    println!(
                        "  w{bits}g{group}: {:.2} bits/param, {:.2} GiB, \
                         {:.2}% compression",
                        r.bits_per_param, r.size_gib, r.compression_pct
                    );
                }
            }
        }
        "exp" => {
            let id = cli
                .pos
                .first()
                .ok_or_else(|| anyhow::anyhow!("exp wants an id (t1...)"))?;
            let c = ctx(&cli)?;
            tables::run(&c, id, &preset)?;
        }
        "bench" => {
            let which = cli.pos.first().map(String::as_str).unwrap_or("");
            match which {
                "qlinear" => {
                    let (md, rows) = efficientqat::bench::qlinear_speed_table(
                        cli.flag_bool("fast"))?;
                    println!("{md}");
                    std::fs::create_dir_all("runs")?;
                    std::fs::write("runs/t10-qlinear.md", &md)?;
                    efficientqat::bench::write_bench_json(
                        "runs/t10-qlinear.json", &rows)?;
                }
                "inference" => {
                    let (md, payload) =
                        efficientqat::bench::inference_throughput(
                            cli.flag_bool("fast"))?;
                    println!("{md}");
                    std::fs::create_dir_all("runs")?;
                    std::fs::write("runs/inference.md", &md)?;
                    efficientqat::bench::write_bench_json(
                        "runs/bench.json", &payload)?;
                    println!("wrote runs/bench.json");
                }
                "check" => {
                    let path = cli.flag_or("path", "runs/bench.json");
                    efficientqat::bench::check_bench_json(&path)?;
                    println!("{path} OK");
                }
                "train-time" => {
                    let c = ctx(&cli)?;
                    tables::run(&c, "t8", &preset)?;
                    tables::run(&c, "t9", &preset)?;
                }
                _ => bail!(
                    "bench wants: qlinear | inference | check | train-time"),
            }
        }
        other => bail!("unknown command '{other}'; try `eqat help`"),
    }
    Ok(())
}
