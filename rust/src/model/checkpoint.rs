//! Full-precision checkpoints: flat params (+ optional Adam state) in .eqt.

use std::path::Path;

use anyhow::{bail, Result};

use crate::io::eqt::Eqt;

pub struct FpCheckpoint {
    pub preset: String,
    pub params: Vec<f32>,
    pub step: usize,
}

impl FpCheckpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut ck = Eqt::new();
        ck.insert_f32("params", &[self.params.len()], &self.params);
        ck.meta.insert("kind".into(), "fp".into());
        ck.meta.insert("preset".into(), self.preset.clone());
        ck.meta.insert("step".into(), self.step.to_string());
        ck.save(path)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<FpCheckpoint> {
        let ck = Eqt::load(path)?;
        if ck.meta.get("kind").map(String::as_str) != Some("fp") {
            bail!("not an fp checkpoint");
        }
        Ok(FpCheckpoint {
            preset: ck.meta.get("preset").cloned().unwrap_or_default(),
            params: ck.f32_vec("params")?,
            step: ck
                .meta
                .get("step")
                .and_then(|s| s.parse().ok())
                .unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = FpCheckpoint {
            preset: "tiny".into(),
            params: vec![1.0, -2.5, 3.25],
            step: 500,
        };
        let mut p = std::env::temp_dir();
        p.push(format!("fp_ck_{}.eqt", std::process::id()));
        ck.save(&p).unwrap();
        let back = FpCheckpoint::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back.params, ck.params);
        assert_eq!(back.preset, "tiny");
        assert_eq!(back.step, 500);
    }
}
