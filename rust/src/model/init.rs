//! Parameter initialization for the full-precision model (the substrate we
//! pretrain before quantizing). Norm weights start at 1.0; linear weights
//! use Xavier-normal; embeddings/head use std 0.02 (GPT convention).

use crate::io::manifest::Layout;
use crate::util::rng::Rng;

pub fn init_fp_params(layout: &Layout, seed: u64) -> Vec<f32> {
    let mut flat = vec![0f32; layout.size];
    let mut rng = Rng::new(seed).fork("init");
    for e in &layout.entries {
        let buf = &mut flat[e.offset..e.offset + e.numel()];
        if e.name.ends_with("norm") {
            buf.fill(1.0);
        } else if e.name == "embed" || e.name == "head" {
            rng.fill_normal(buf, 0.0, 0.02);
        } else {
            // linear (out, in): Xavier normal
            let (o, i) = (e.shape[0], e.shape[1]);
            let std = (2.0 / (o + i) as f32).sqrt();
            rng.fill_normal(buf, 0.0, std);
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::{Layout, LayoutEntry};

    fn layout() -> Layout {
        Layout::new(vec![
            LayoutEntry { name: "embed".into(), offset: 0,
                          shape: vec![32, 8] },
            LayoutEntry { name: "blocks.0.attn_norm".into(), offset: 256,
                          shape: vec![8] },
            LayoutEntry { name: "blocks.0.attn.q".into(), offset: 264,
                          shape: vec![8, 8] },
        ])
    }

    #[test]
    fn norms_are_one_weights_random() {
        let l = layout();
        let p = init_fp_params(&l, 3);
        let norm = l.slice(&p, "blocks.0.attn_norm").unwrap();
        assert!(norm.iter().all(|&x| x == 1.0));
        let q = l.slice(&p, "blocks.0.attn.q").unwrap();
        assert!(q.iter().any(|&x| x != 0.0));
        // Xavier scale sanity
        let var: f32 =
            q.iter().map(|x| x * x).sum::<f32>() / q.len() as f32;
        assert!(var < 0.5, "var={var}");
    }

    #[test]
    fn deterministic_by_seed() {
        let l = layout();
        assert_eq!(init_fp_params(&l, 3), init_fp_params(&l, 3));
        assert_ne!(init_fp_params(&l, 3), init_fp_params(&l, 4));
    }
}
