//! Model-side host logic: init, checkpoints, the quantized representation.
pub mod checkpoint;
pub mod init;
pub mod quantized;
