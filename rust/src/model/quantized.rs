//! Quantized model representation + packed on-disk format.
//!
//! In memory the model keeps the runtime-friendly flat f32 buffers (integer
//! weights as f32 values, qp = [s||z], fp rest) that feed model_fwd_q /
//! e2e_qp_step directly. On disk it packs to the paper's storage scheme:
//! N-bit weight ints (bitstream), FP16 step sizes, N-bit zero points -
//! so file size matches the Table 11 arithmetic, and f16 rounding of s is
//! applied exactly once (load == what deployment would see).

use std::path::Path;

use anyhow::{bail, Result};

use crate::config::QuantScheme;
use crate::io::eqt::{Eqt, EqtTensor};
use crate::quant::pack::{pack_bits, packed_len, unpack_bits_f32};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

#[derive(Clone)]
pub struct QuantizedModel {
    pub preset: String,
    pub scheme: QuantScheme,
    /// integer weights, values in [0, qmax], wq layout order
    pub wq: Vec<f32>,
    /// [s_all || z_all], qp_g{group} layout order
    pub qp: Vec<f32>,
    /// fp remainder (embed, norms, head), fpr layout order
    pub fpr: Vec<f32>,
}

impl QuantizedModel {
    /// z half of qp (second half by construction).
    pub fn z_slice(&self) -> &[f32] {
        &self.qp[self.qp.len() / 2..]
    }

    pub fn s_slice(&self) -> &[f32] {
        &self.qp[..self.qp.len() / 2]
    }

    /// Logical packed size in bytes (weights + s (f16) + z (N-bit) + fp32
    /// remainder as fp16): mirrors quant::size accounting for our presets.
    pub fn packed_bytes(&self) -> usize {
        let n = self.wq.len();
        let half = self.qp.len() / 2;
        let wq_bytes = packed_len(n, self.scheme.bits) * 4;
        let s_bytes = half * 2;
        let z_bytes = packed_len(half, self.scheme.bits) * 4;
        let fpr_bytes = self.fpr.len() * 2;
        wq_bytes + s_bytes + z_bytes + fpr_bytes
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let half = self.qp.len() / 2;
        let bits = self.scheme.bits;
        let to_u8 = |v: &[f32]| -> Result<Vec<u8>> {
            v.iter()
                .map(|&x| {
                    if x < 0.0 || x > self.scheme.qmax() || x.fract() != 0.0 {
                        bail!("non-integer quantized value {x}");
                    }
                    Ok(x as u8)
                })
                .collect()
        };
        let wq_packed = pack_bits(&to_u8(&self.wq)?, bits)?;
        let z_packed = pack_bits(&to_u8(&self.qp[half..])?, bits)?;
        let s_f16: Vec<u16> =
            self.qp[..half].iter().map(|&s| f32_to_f16_bits(s)).collect();

        let mut ck = Eqt::new();
        ck.tensors.insert(
            "wq_packed".into(),
            EqtTensor::u32(&[wq_packed.len()], &wq_packed),
        );
        ck.tensors
            .insert("s_f16".into(), EqtTensor::u16(&[half], &s_f16));
        ck.tensors.insert(
            "z_packed".into(),
            EqtTensor::u32(&[z_packed.len()], &z_packed),
        );
        ck.insert_f32("fpr", &[self.fpr.len()], &self.fpr);
        ck.meta.insert("kind".into(), "quantized".into());
        ck.meta.insert("preset".into(), self.preset.clone());
        ck.meta.insert("bits".into(), bits.to_string());
        ck.meta.insert("group".into(), self.scheme.group.to_string());
        ck.meta.insert("n_weights".into(), self.wq.len().to_string());
        ck.save(path)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<QuantizedModel> {
        let ck = Eqt::load(path)?;
        if ck.meta.get("kind").map(String::as_str) != Some("quantized") {
            bail!("not a quantized-model checkpoint");
        }
        let bits: u32 = ck.meta["bits"].parse()?;
        let group: usize = ck.meta["group"].parse()?;
        let n: usize = ck.meta["n_weights"].parse()?;
        let scheme = QuantScheme::new(bits, group);

        let wq_packed = ck.get("wq_packed")?.to_u32()?;
        let mut wq = vec![0f32; n];
        unpack_bits_f32(&wq_packed, bits, &mut wq);

        let s_f16 = ck.get("s_f16")?.to_u16()?;
        let half = s_f16.len();
        let z_packed = ck.get("z_packed")?.to_u32()?;
        let mut qp = vec![0f32; half * 2];
        for (i, &h) in s_f16.iter().enumerate() {
            qp[i] = f16_bits_to_f32(h);
        }
        unpack_bits_f32(&z_packed, bits, &mut qp[half..]);

        Ok(QuantizedModel {
            preset: ck.meta["preset"].clone(),
            scheme,
            wq,
            qp,
            fpr: ck.f32_vec("fpr")?,
        })
    }

    /// Round step sizes through f16 in place (storage precision), so
    /// in-memory eval matches a save/load cycle.
    pub fn round_scales_f16(&mut self) {
        let half = self.qp.len() / 2;
        for s in self.qp[..half].iter_mut() {
            *s = crate::util::f16::round_f16(*s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_model() -> QuantizedModel {
        let mut r = Rng::new(41);
        let sch = QuantScheme::new(2, 8);
        let n = 1024;
        let half = n / 8;
        let wq: Vec<f32> = (0..n).map(|_| r.below(4) as f32).collect();
        let mut qp = vec![0f32; half * 2];
        for i in 0..half {
            qp[i] = crate::util::f16::round_f16(r.normal_f32(0.05, 0.01).abs());
            qp[half + i] = r.below(4) as f32;
        }
        let mut fpr = vec![0f32; 300];
        r.fill_normal(&mut fpr, 0.0, 0.5);
        QuantizedModel { preset: "tiny".into(), scheme: sch, wq, qp, fpr }
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let m = sample_model();
        let mut p = std::env::temp_dir();
        p.push(format!("qm_{}.eqt", std::process::id()));
        m.save(&p).unwrap();
        let back = QuantizedModel::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back.wq, m.wq);
        assert_eq!(back.qp, m.qp); // s pre-rounded to f16 -> exact
        assert_eq!(back.fpr, m.fpr);
        assert_eq!(back.scheme, m.scheme);
    }

    #[test]
    fn packed_bytes_close_to_avg_bits_formula() {
        let m = sample_model();
        // weights dominate: n * avg_bits / 8 plus fp16 remainder
        let want = m.wq.len() as f64 * m.scheme.avg_bits() / 8.0
            + m.fpr.len() as f64 * 2.0;
        let got = m.packed_bytes() as f64;
        assert!((got - want).abs() / want < 0.05, "got {got} want {want}");
    }

    #[test]
    fn save_rejects_non_integer_weights() {
        let mut m = sample_model();
        m.wq[0] = 1.5;
        let mut p = std::env::temp_dir();
        p.push(format!("qm_bad_{}.eqt", std::process::id()));
        assert!(m.save(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn halves_accessors() {
        let m = sample_model();
        assert_eq!(m.s_slice().len(), m.z_slice().len());
        assert!(m.z_slice().iter().all(|&z| z.fract() == 0.0));
    }
}
