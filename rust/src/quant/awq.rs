//! AWQ-style activation-aware quantization (Lin et al. 2023), adapted.
//!
//! Real AWQ folds per-channel weight scales into the preceding elementwise
//! op; our fixed quantized-model format has no such folding slot for every
//! linear, so we implement the *activation-aware clip search* component:
//! per group, search a clip ratio r in {1.0, 0.95, .., 0.5} shrinking the
//! quantization range, and keep the r minimizing the activation-weighted
//! weight reconstruction error  sum_k E[x_k^2] (w_k - w_hat_k)^2.
//! This preserves AWQ's key insight - salient weight channels (large |x|)
//! deserve finer resolution - within the standard uniform format.

use crate::config::QuantScheme;
use crate::quant::rtn::GroupParams;

/// Result: quantized ints + clip-searched group params.
pub struct AwqResult {
    pub w_int: Vec<f32>,
    pub gp: GroupParams,
}

/// `x2_mean[k]` = mean of x_k^2 over calibration tokens (length = in_dim).
pub fn awq_quantize(
    w: &[f32],
    out_dim: usize,
    in_dim: usize,
    x2_mean: &[f32],
    sch: QuantScheme,
) -> AwqResult {
    assert_eq!(w.len(), out_dim * in_dim);
    assert_eq!(x2_mean.len(), in_dim);
    let g = sch.group;
    let gpr = in_dim / g;
    let qmax = sch.qmax();
    let ratios = [1.0f32, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5];

    let mut s_out = vec![0f32; out_dim * gpr];
    let mut z_out = vec![0f32; out_dim * gpr];
    let mut w_int = vec![0f32; w.len()];

    for r in 0..out_dim {
        for gi in 0..gpr {
            let base = r * in_dim + gi * g;
            let chunk = &w[base..base + g];
            let xw = &x2_mean[gi * g..(gi + 1) * g];
            let mut mn = 0f32;
            let mut mx = 0f32;
            for &v in chunk {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let mut best = (f64::INFINITY, 1e-8f32, 0f32);
            for &ratio in &ratios {
                let cmn = mn * ratio;
                let cmx = mx * ratio;
                let s = ((cmx - cmn) / qmax).max(1e-8);
                let z = (-cmn / s).round_ties_even().clamp(0.0, qmax);
                let mut err = 0f64;
                for k in 0..g {
                    let q = (chunk[k] / s).round_ties_even() + z;
                    let q = q.clamp(0.0, qmax);
                    let wh = (q - z) * s;
                    let d = (wh - chunk[k]) as f64;
                    err += xw[k] as f64 * d * d;
                }
                if err < best.0 {
                    best = (err, s, z);
                }
            }
            let (_, s, z) = best;
            s_out[r * gpr + gi] = s;
            z_out[r * gpr + gi] = z;
            for k in 0..g {
                let q = (chunk[k] / s).round_ties_even() + z;
                w_int[base + k] = q.clamp(0.0, qmax);
            }
        }
    }
    AwqResult {
        w_int,
        gp: GroupParams { s: s_out, z: z_out, rows: out_dim,
                          groups_per_row: gpr },
    }
}

/// Column-wise mean of squares of activations X (n, in).
pub fn x2_mean(x: &[f32], in_dim: usize) -> Vec<f32> {
    let n = x.len() / in_dim;
    let mut out = vec![0f32; in_dim];
    for s in 0..n {
        for k in 0..in_dim {
            let v = x[s * in_dim + k];
            out[k] += v * v;
        }
    }
    for o in out.iter_mut() {
        *o /= n.max(1) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::recon_error;
    use crate::quant::rtn::{dequantize, fake_quant, minmax_init};
    use crate::util::rng::Rng;

    #[test]
    fn awq_not_worse_than_rtn_weighted_error() {
        let (out_d, in_d) = (8, 32);
        let sch = QuantScheme::new(2, 8);
        let mut r = Rng::new(21);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 1.0);
        // a couple of outlier weights that plain minmax wastes range on
        for i in 0..out_d {
            w[i * in_d + 3] *= 6.0;
        }
        // salient channels: first half has much larger activations
        let mut x2 = vec![0.05f32; in_d];
        for k in 0..in_d / 2 {
            x2[k] = 4.0;
        }
        let res = awq_quantize(&w, out_d, in_d, &x2, sch);
        let w_awq = dequantize(&res.w_int, &res.gp, sch);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let w_rtn = fake_quant(&w, &gp, sch);
        let werr = |wh: &[f32]| {
            let mut e = 0f64;
            for o in 0..out_d {
                for k in 0..in_d {
                    let d = (wh[o * in_d + k] - w[o * in_d + k]) as f64;
                    e += x2[k] as f64 * d * d;
                }
            }
            e
        };
        assert!(werr(&w_awq) <= werr(&w_rtn) + 1e-9,
                "awq {} rtn {}", werr(&w_awq), werr(&w_rtn));
        assert!(werr(&w_awq) < werr(&w_rtn) * 0.98, "clip search inert");
    }

    #[test]
    fn awq_improves_layer_output_error_with_outliers() {
        let (out_d, in_d, n) = (8, 32, 64);
        let sch = QuantScheme::new(2, 16);
        let mut r = Rng::new(22);
        let mut w = vec![0f32; out_d * in_d];
        let mut x = vec![0f32; n * in_d];
        r.fill_normal(&mut w, 0.0, 1.0);
        r.fill_normal(&mut x, 0.0, 1.0);
        for i in 0..out_d {
            w[i * in_d + 7] *= 8.0; // range-wasting outlier per row
        }
        let x2 = x2_mean(&x, in_d);
        let res = awq_quantize(&w, out_d, in_d, &x2, sch);
        let w_awq = dequantize(&res.w_int, &res.gp, sch);
        let gp = minmax_init(&w, out_d, in_d, sch);
        let w_rtn = fake_quant(&w, &gp, sch);
        let e_awq = recon_error(&w_awq, &w, out_d, in_d, &x);
        let e_rtn = recon_error(&w_rtn, &w, out_d, in_d, &x);
        assert!(e_awq < e_rtn, "awq {e_awq} vs rtn {e_rtn}");
    }

    #[test]
    fn ratio_one_reduces_to_rtn() {
        // with uniform activation weights and no outliers, clip 1.0 often
        // wins; check ints stay valid either way
        let (out_d, in_d) = (4, 16);
        let sch = QuantScheme::new(4, 8);
        let mut r = Rng::new(23);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 1.0);
        let x2 = vec![1.0f32; in_d];
        let res = awq_quantize(&w, out_d, in_d, &x2, sch);
        for &q in &res.w_int {
            assert!((0.0..=sch.qmax()).contains(&q));
            assert_eq!(q, q.round_ties_even());
        }
    }

    #[test]
    fn x2_mean_computes_columnwise() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2 samples x 2 channels
        let m = x2_mean(&x, 2);
        assert_eq!(m, vec![5.0, 10.0]);
    }
}
