//! GPTQ baseline (Frantar et al. 2022), reimplemented from scratch.
//!
//! Per linear layer: given calibration inputs X (n, in) and weights
//! W (out, in), quantize columns sequentially in natural order and update
//! the remaining columns with the inverse-Hessian correction
//!     err_i = (w_i - q_i) / [H^-1]_ii ,  w_j -= err_i * [H^-1]_ij  (j > i)
//! with H = 2 X^T X + damping. Group parameters are the "static groups"
//! variant (computed from the original W) so the group grid matches the
//! RTN/EfficientQAT formats bit-for-bit.
//!
//! Dense f64 Cholesky; layer dims here are <= a few hundred (tiny presets),
//! so O(in^3) is fine.

use anyhow::{bail, Result};

use crate::config::QuantScheme;
use crate::quant::rtn::{minmax_init, GroupParams};

/// Dense symmetric positive-definite solve helpers (f64, row-major n x n).
pub(crate) struct Spd {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Spd {
    /// In-place Cholesky: A = L L^T, L stored in the lower triangle.
    pub fn cholesky(mut self) -> Result<Spd> {
        let n = self.n;
        for j in 0..n {
            let mut d = self.a[j * n + j];
            for k in 0..j {
                let l = self.a[j * n + k];
                d -= l * l;
            }
            if d <= 0.0 {
                bail!("matrix not positive definite at pivot {j} ({d})");
            }
            let d = d.sqrt();
            self.a[j * n + j] = d;
            for i in (j + 1)..n {
                let mut s = self.a[i * n + j];
                for k in 0..j {
                    s -= self.a[i * n + k] * self.a[j * n + k];
                }
                self.a[i * n + j] = s / d;
            }
        }
        // zero the upper triangle for cleanliness
        for i in 0..n {
            for j in (i + 1)..n {
                self.a[i * n + j] = 0.0;
            }
        }
        Ok(self)
    }

    /// Full inverse from the Cholesky factor (A^-1 = L^-T L^-1).
    pub fn inverse_from_chol(l: &Spd) -> Vec<f64> {
        let n = l.n;
        // invert L (lower triangular) by forward substitution per column
        let mut linv = vec![0f64; n * n];
        for j in 0..n {
            linv[j * n + j] = 1.0 / l.a[j * n + j];
            for i in (j + 1)..n {
                let mut s = 0.0;
                for k in j..i {
                    s += l.a[i * n + k] * linv[k * n + j];
                }
                linv[i * n + j] = -s / l.a[i * n + i];
            }
        }
        // A^-1 = L^-T L^-1
        let mut inv = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                // sum over k >= max(i,j): linv[k,i] * linv[k,j]
                for k in i.max(j)..n {
                    s += linv[k * n + i] * linv[k * n + j];
                }
                inv[i * n + j] = s;
            }
        }
        inv
    }
}

/// GPTQ result: quantized ints + the (static) group params used.
pub struct GptqResult {
    pub w_int: Vec<f32>,
    pub gp: GroupParams,
}

/// Quantize one layer. `w`: (out, in) row-major; `x`: (n, in) calibration
/// inputs (rows are token activations).
pub fn gptq_quantize(
    w: &[f32],
    out_dim: usize,
    in_dim: usize,
    x: &[f32],
    sch: QuantScheme,
) -> Result<GptqResult> {
    if w.len() != out_dim * in_dim {
        bail!("w size mismatch");
    }
    if x.len() % in_dim != 0 {
        bail!("x size not divisible by in_dim");
    }
    let n_samples = x.len() / in_dim;
    let qmax = sch.qmax();
    let g = sch.group;
    let gpr = in_dim / g;

    // H = 2 X^T X + mean-diag damping (GPTQ's 1% default)
    let mut h = vec![0f64; in_dim * in_dim];
    for s in 0..n_samples {
        let row = &x[s * in_dim..(s + 1) * in_dim];
        for i in 0..in_dim {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h[i * in_dim..(i + 1) * in_dim];
            for (j, &xj) in row.iter().enumerate() {
                hrow[j] += 2.0 * xi * xj as f64;
            }
        }
    }
    let mean_diag: f64 =
        (0..in_dim).map(|i| h[i * in_dim + i]).sum::<f64>() / in_dim as f64;
    let damp = (0.01 * mean_diag).max(1e-8);
    for i in 0..in_dim {
        h[i * in_dim + i] += damp;
    }

    let chol = Spd { n: in_dim, a: h }.cholesky()?;
    let hinv = Spd::inverse_from_chol(&chol);

    // static group params from the ORIGINAL weights
    let gp = minmax_init(w, out_dim, in_dim, sch);

    // per-row sequential quantization with error feedback
    let mut w_work: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut w_int = vec![0f32; w.len()];
    for r in 0..out_dim {
        let wrow = &mut w_work[r * in_dim..(r + 1) * in_dim];
        for i in 0..in_dim {
            let s = gp.s[r * gpr + i / g] as f64;
            let z = gp.z[r * gpr + i / g] as f64;
            let q = ((wrow[i] / s + z).round_ties_even())
                .clamp(0.0, qmax as f64);
            w_int[r * in_dim + i] = q as f32;
            let wq = (q - z) * s;
            let d = hinv[i * in_dim + i];
            let err = (wrow[i] - wq) / d;
            for j in (i + 1)..in_dim {
                wrow[j] -= err * hinv[i * in_dim + j];
            }
        }
    }
    Ok(GptqResult { w_int, gp })
}

/// Layer-output reconstruction error ||X W^T - X W_hat^T||_F^2 / n.
pub fn recon_error(
    w_hat: &[f32],
    w: &[f32],
    out_dim: usize,
    in_dim: usize,
    x: &[f32],
) -> f64 {
    let n = x.len() / in_dim;
    let mut err = 0f64;
    for s in 0..n {
        let xr = &x[s * in_dim..(s + 1) * in_dim];
        for o in 0..out_dim {
            let wr = &w[o * in_dim..(o + 1) * in_dim];
            let wh = &w_hat[o * in_dim..(o + 1) * in_dim];
            let mut d = 0f64;
            for k in 0..in_dim {
                d += (wr[k] as f64 - wh[k] as f64) * xr[k] as f64;
            }
            err += d * d;
        }
    }
    err / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::{dequantize, fake_quant};
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_inverse_correct() {
        // A = M M^T + I is SPD; check A * A^-1 = I
        let n = 6;
        let mut r = Rng::new(8);
        let m: Vec<f64> = (0..n * n).map(|_| r.normal()).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let chol = Spd { n, a: a.clone() }.cholesky().unwrap();
        let inv = Spd::inverse_from_chol(&chol);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j})={s}");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(Spd { n: 2, a }.cholesky().is_err());
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        // GPTQ's advantage comes from input correlation; build X with
        // strong cross-channel structure, gaussian W.
        let (out_d, in_d, n) = (16, 32, 256);
        let sch = QuantScheme::new(2, 16);
        let mut r = Rng::new(55);
        let mut w = vec![0f32; out_d * in_d];
        r.fill_normal(&mut w, 0.0, 1.0);
        let mut x = vec![0f32; n * in_d];
        for s in 0..n {
            let base = r.normal() as f32;
            for k in 0..in_d {
                x[s * in_d + k] =
                    base * (1.0 + 0.1 * k as f32) + 0.3 * r.normal() as f32;
            }
        }
        let res = gptq_quantize(&w, out_d, in_d, &x, sch).unwrap();
        let w_gptq = dequantize(&res.w_int, &res.gp, sch);
        let gp_rtn = minmax_init(&w, out_d, in_d, sch);
        let w_rtn = fake_quant(&w, &gp_rtn, sch);
        let e_gptq = recon_error(&w_gptq, &w, out_d, in_d, &x);
        let e_rtn = recon_error(&w_rtn, &w, out_d, in_d, &x);
        assert!(
            e_gptq < e_rtn * 0.9,
            "gptq {e_gptq:.4} not better than rtn {e_rtn:.4}"
        );
    }

    #[test]
    fn gptq_ints_in_range() {
        let (out_d, in_d, n) = (4, 16, 32);
        let sch = QuantScheme::new(3, 8);
        let mut r = Rng::new(9);
        let mut w = vec![0f32; out_d * in_d];
        let mut x = vec![0f32; n * in_d];
        r.fill_normal(&mut w, 0.0, 0.5);
        r.fill_normal(&mut x, 0.0, 1.0);
        let res = gptq_quantize(&w, out_d, in_d, &x, sch).unwrap();
        for &q in &res.w_int {
            assert_eq!(q, q.round_ties_even());
            assert!((0.0..=sch.qmax()).contains(&q));
        }
    }

    #[test]
    fn first_column_matches_rtn() {
        // before any error feedback, column 0 quantizes exactly like RTN
        let (out_d, in_d, n) = (3, 8, 16);
        let sch = QuantScheme::new(2, 8);
        let mut r = Rng::new(10);
        let mut w = vec![0f32; out_d * in_d];
        let mut x = vec![0f32; n * in_d];
        r.fill_normal(&mut w, 0.0, 1.0);
        r.fill_normal(&mut x, 0.0, 1.0);
        let res = gptq_quantize(&w, out_d, in_d, &x, sch).unwrap();
        let gp = minmax_init(&w, out_d, in_d, sch);
        let rtn_q = crate::quant::rtn::quantize(&w, &gp, sch);
        for row in 0..out_d {
            assert_eq!(res.w_int[row * in_d], rtn_q[row * in_d]);
        }
    }
}
