//! Quantization: packing, RTN (paper Eqs. 1-2), PTQ baselines, size math.
pub mod awq;
pub mod gptq;
pub mod pack;
pub mod rtn;
pub mod size;
