//! N-bit bitstream packing: quantized integer weights -> dense u32 words.
//!
//! Contiguous little-endian bitstream (value i occupies bits
//! [i*N, (i+1)*N) of the stream; bit j of the stream is bit j%32 of word
//! j/32). Works for any N in 1..=8 - covers the paper's 2/3/4-bit models,
//! including the awkward 3-bit case without padding waste.

use anyhow::{bail, Result};

/// Words needed for `n` values at `bits` each.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize + 31) / 32
}

/// Pack integer values (each < 2^bits) into a bitstream.
pub fn pack_bits(values: &[u8], bits: u32) -> Result<Vec<u32>> {
    if bits == 0 || bits > 8 {
        bail!("bits must be in 1..=8, got {bits}");
    }
    let limit = 1u16 << bits;
    let mut out = vec![0u32; packed_len(values.len(), bits)];
    let mut bitpos = 0usize;
    for &v in values {
        if (v as u16) >= limit {
            bail!("value {v} out of range for {bits} bits");
        }
        let word = bitpos >> 5;
        let off = bitpos & 31;
        out[word] |= (v as u32) << off;
        let spill = off + bits as usize;
        if spill > 32 {
            out[word + 1] |= (v as u32) >> (32 - off);
        }
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Unpack `n` values of `bits` each from a bitstream.
pub fn unpack_bits(words: &[u32], bits: u32, n: usize) -> Result<Vec<u8>> {
    if bits == 0 || bits > 8 {
        bail!("bits must be in 1..=8, got {bits}");
    }
    if words.len() < packed_len(n, bits) {
        bail!("bitstream too short: {} words for {} values", words.len(), n);
    }
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let word = bitpos >> 5;
        let off = bitpos & 31;
        let mut v = words[word] >> off;
        let spill = off + bits as usize;
        if spill > 32 {
            v |= words[word + 1] << (32 - off);
        }
        out.push((v & mask) as u8);
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Unpack directly into an f32 slice (hot path for dequantization).
#[inline]
pub fn unpack_bits_f32(words: &[u32], bits: u32, out: &mut [f32]) {
    let mask = (1u32 << bits) - 1;
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let word = bitpos >> 5;
        let off = bitpos & 31;
        let mut v = words[word] >> off;
        if off + bits as usize > 32 {
            v |= words[word + 1] << (32 - off);
        }
        *o = (v & mask) as f32;
        bitpos += bits as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_bit_widths_property() {
        let mut r = Rng::new(31);
        for _case in 0..200 {
            let bits = 1 + r.below(8) as u32;
            let n = r.range(1, 300);
            let vals: Vec<u8> =
                (0..n).map(|_| r.below(1 << bits) as u8).collect();
            let packed = pack_bits(&vals, bits).unwrap();
            assert_eq!(packed.len(), packed_len(n, bits));
            let back = unpack_bits(&packed, bits, n).unwrap();
            assert_eq!(back, vals, "bits={bits} n={n}");
        }
    }

    #[test]
    fn three_bit_crosses_word_boundaries() {
        // 3 bits * 11 values = 33 bits -> value 10 straddles words 0/1
        let vals: Vec<u8> = (0..11).map(|i| (i % 8) as u8).collect();
        let packed = pack_bits(&vals, 3).unwrap();
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_bits(&packed, 3, 11).unwrap(), vals);
    }

    #[test]
    fn density_is_exact() {
        // 2-bit: 16 values/word; 4-bit: 8/word
        assert_eq!(packed_len(16, 2), 1);
        assert_eq!(packed_len(17, 2), 2);
        assert_eq!(packed_len(8, 4), 1);
        assert_eq!(packed_len(32, 3), 3); // 96 bits exactly
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(pack_bits(&[4], 2).is_err());
        assert!(pack_bits(&[8], 3).is_err());
        assert!(pack_bits(&[1], 0).is_err());
        assert!(pack_bits(&[1], 9).is_err());
    }

    #[test]
    fn unpack_f32_matches_u8() {
        let mut r = Rng::new(32);
        let vals: Vec<u8> = (0..100).map(|_| r.below(8) as u8).collect();
        let packed = pack_bits(&vals, 3).unwrap();
        let mut f = vec![0f32; 100];
        unpack_bits_f32(&packed, 3, &mut f);
        for (a, b) in f.iter().zip(&vals) {
            assert_eq!(*a, *b as f32);
        }
    }

    #[test]
    fn short_stream_rejected() {
        let packed = pack_bits(&[1, 2, 3], 4).unwrap();
        assert!(unpack_bits(&packed, 4, 9).is_err());
    }
}
