//! Round-to-nearest quantization (paper Eqs. 1-2) on host tensors.
//!
//! Bit-parity with the python oracle (kernels/ref.py) is REQUIRED: the
//! Block-AP -> E2E-QP handoff quantizes trained weights here in Rust, and
//! the resulting integers must match what the fake-quant training graph saw.
//! jnp.round rounds half-to-even, so we use f32::round_ties_even.

use crate::config::QuantScheme;

/// Group-wise quantization parameters of one (out x in) weight matrix.
#[derive(Clone, Debug)]
pub struct GroupParams {
    /// step sizes, (out * in/g) row-major
    pub s: Vec<f32>,
    /// zero points (integer-valued f32), same shape
    pub z: Vec<f32>,
    pub rows: usize,
    pub groups_per_row: usize,
}

/// Min/max init of (s, z): s = (max-min)/qmax, z = clamp(round(-min/s)).
/// min clamped <= 0 and max >= 0 so zero stays representable
/// (matches ref.minmax_init_ref).
pub fn minmax_init(w: &[f32], rows: usize, cols: usize, sch: QuantScheme)
                   -> GroupParams {
    let g = sch.group;
    assert_eq!(cols % g, 0, "group {g} must divide cols {cols}");
    let gpr = cols / g;
    let qmax = sch.qmax();
    let mut s = Vec::with_capacity(rows * gpr);
    let mut z = Vec::with_capacity(rows * gpr);
    for r in 0..rows {
        for gi in 0..gpr {
            let chunk = &w[r * cols + gi * g..r * cols + (gi + 1) * g];
            let mut mn = 0f32;
            let mut mx = 0f32;
            for &x in chunk {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            let step = ((mx - mn) / qmax).max(1e-8);
            s.push(step);
            z.push((-mn / step).round_ties_even().clamp(0.0, qmax));
        }
    }
    GroupParams { s, z, rows, groups_per_row: gpr }
}

/// Eq. (1): W_int = clamp(round(W/s) + z, 0, qmax), integer-valued f32.
pub fn quantize(w: &[f32], gp: &GroupParams, sch: QuantScheme) -> Vec<f32> {
    let qmax = sch.qmax();
    let g = sch.group;
    let cols = gp.groups_per_row * g;
    let mut out = vec![0f32; w.len()];
    for r in 0..gp.rows {
        for gi in 0..gp.groups_per_row {
            let s = gp.s[r * gp.groups_per_row + gi];
            let z = gp.z[r * gp.groups_per_row + gi];
            let base = r * cols + gi * g;
            for k in 0..g {
                let q = (w[base + k] / s).round_ties_even() + z;
                out[base + k] = q.clamp(0.0, qmax);
            }
        }
    }
    out
}

/// Eq. (2): W_hat = (W_int - z) * s.
pub fn dequantize(w_int: &[f32], gp: &GroupParams, sch: QuantScheme)
                  -> Vec<f32> {
    let g = sch.group;
    let cols = gp.groups_per_row * g;
    let mut out = vec![0f32; w_int.len()];
    for r in 0..gp.rows {
        for gi in 0..gp.groups_per_row {
            let s = gp.s[r * gp.groups_per_row + gi];
            let z = gp.z[r * gp.groups_per_row + gi];
            let base = r * cols + gi * g;
            for k in 0..g {
                out[base + k] = (w_int[base + k] - z) * s;
            }
        }
    }
    out
}

/// quantize + dequantize in one pass (RTN baseline reconstruction).
pub fn fake_quant(w: &[f32], gp: &GroupParams, sch: QuantScheme) -> Vec<f32> {
    dequantize(&quantize(w, gp, sch), gp, sch)
}

/// Round a trained (continuous) zero-point vector onto the integer grid -
/// the storage step after Block-AP (z is stored low-bit, paper §3.2).
pub fn round_zeros(gp: &mut GroupParams, sch: QuantScheme) {
    let qmax = sch.qmax();
    for z in gp.z.iter_mut() {
        *z = z.round_ties_even().clamp(0.0, qmax);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sch2() -> QuantScheme {
        QuantScheme::new(2, 8)
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut r = Rng::new(2);
        for bits in [2u32, 3, 4] {
            let sch = QuantScheme::new(bits, 16);
            let (rows, cols) = (8, 64);
            let mut w = vec![0f32; rows * cols];
            r.fill_normal(&mut w, 0.0, 1.0);
            let gp = minmax_init(&w, rows, cols, sch);
            let wh = fake_quant(&w, &gp, sch);
            for row in 0..rows {
                for c in 0..cols {
                    let s = gp.s[row * gp.groups_per_row + c / 16];
                    let err = (wh[row * cols + c] - w[row * cols + c]).abs();
                    assert!(err <= 0.5 * s + 1e-5, "err={err} s={s}");
                }
            }
        }
    }

    #[test]
    fn quantize_values_integer_in_range() {
        let mut r = Rng::new(3);
        let sch = QuantScheme::new(3, 8);
        let mut w = vec![0f32; 4 * 32];
        r.fill_normal(&mut w, 0.5, 2.0);
        let gp = minmax_init(&w, 4, 32, sch);
        for q in quantize(&w, &gp, sch) {
            assert_eq!(q, q.round_ties_even());
            assert!(q >= 0.0 && q <= sch.qmax());
        }
    }

    #[test]
    fn zero_is_representable() {
        // all-positive group: min clamps to 0 so w=0 -> exactly 0
        let w = vec![1.0f32, 2.0, 3.0, 0.0, 5.0, 6.0, 7.0, 8.0];
        let gp = minmax_init(&w, 1, 8, sch2());
        let wh = fake_quant(&w, &gp, sch2());
        assert_eq!(wh[3], 0.0);
    }

    #[test]
    fn constant_group_degenerates_gracefully() {
        let w = vec![0.0f32; 8];
        let gp = minmax_init(&w, 1, 8, sch2());
        assert!(gp.s[0] > 0.0);
        let wh = fake_quant(&w, &gp, sch2());
        assert!(wh.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn ties_round_to_even_like_jnp() {
        // w/s = 0.5 and 1.5 with s=1, z=0: jnp.round gives 0 and 2
        let gp = GroupParams {
            s: vec![1.0],
            z: vec![0.0],
            rows: 1,
            groups_per_row: 1,
        };
        let q = quantize(&[0.5, 1.5, 2.5, 3.5], &gp, QuantScheme::new(4, 4));
        assert_eq!(q, vec![0.0, 2.0, 2.0, 4.0]);
    }

    #[test]
    fn round_zeros_lands_on_grid() {
        let mut gp = GroupParams {
            s: vec![1.0, 1.0],
            z: vec![1.4, 3.9],
            rows: 1,
            groups_per_row: 2,
        };
        round_zeros(&mut gp, sch2());
        assert_eq!(gp.z, vec![1.0, 3.0]); // 3.9 -> 4 -> clamped qmax=3
    }
}
