//! Quantized-model size calculator - exact reproduction of paper Table 11
//! (Appendix E): avg bits/param = N + (N+16)/g over linear layers; norms,
//! embeddings and the head stay FP16.

use crate::config::{LlamaShape, QuantScheme};

#[derive(Clone, Debug)]
pub struct SizeReport {
    pub model: String,
    pub bits: u32,
    pub group: usize,
    pub bits_per_param: f64,
    pub size_gib: f64,
    pub compression_pct: f64,
    pub fp16_gib: f64,
}

/// FP16 model size in GiB.
pub fn fp16_size_gib(shape: &LlamaShape) -> f64 {
    shape.total_params() as f64 * 2.0 / (1u64 << 30) as f64
}

/// Effective storage bits per value when packing into u32 words the way
/// deployment kernels do: floor(32/N) values per word. 2- and 4-bit divide
/// 32 evenly; 3-bit stores 10 values/word = 3.2 effective bits. The paper's
/// Table 11 *size* column uses this practical packing while its bits/param
/// column uses the ideal N + (N+16)/g - we reproduce both conventions.
/// (Our own .eqt container uses a dense bitstream - quant/pack.rs - which
/// is strictly smaller for 3-bit.)
pub fn storage_bits(bits: u32) -> f64 {
    32.0 / (32 / bits) as f64
}

/// Size of the quantized model (paper's scheme: per group one FP16 scale +
/// one N-bit zero point, u32-padded packing).
pub fn quantized_size_gib(shape: &LlamaShape, sch: QuantScheme) -> f64 {
    let lp = shape.linear_params() as f64;
    let sb = storage_bits(sch.bits);
    let avg_storage = sb + (sb + 16.0) / sch.group as f64;
    let quant_bits = lp * avg_storage;
    let fp_bits = shape.fp_params() as f64 * 16.0;
    (quant_bits + fp_bits) / 8.0 / (1u64 << 30) as f64
}

pub fn report(shape: &LlamaShape, sch: QuantScheme) -> SizeReport {
    let fp16 = fp16_size_gib(shape);
    let q = quantized_size_gib(shape, sch);
    SizeReport {
        model: shape.name.to_string(),
        bits: sch.bits,
        group: sch.group,
        bits_per_param: sch.avg_bits(),
        size_gib: q,
        compression_pct: (1.0 - q / fp16) * 100.0,
        fp16_gib: fp16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{llama2_13b, llama2_70b, llama2_7b};

    /// Paper Table 11 rows, (model, bits, group, size GiB, compression %).
    /// Tolerances: 1.5% on size (paper rounds; head-tying conventions vary).
    #[test]
    fn matches_paper_table11() {
        let rows: Vec<(LlamaShape, u32, usize, f64, f64)> = vec![
            (llama2_7b(), 4, 128, 3.62, 71.14),
            (llama2_7b(), 3, 128, 3.01, 75.98),
            (llama2_7b(), 2, 64, 2.21, 82.40),
            (llama2_7b(), 2, 128, 2.10, 83.25),
            (llama2_13b(), 4, 128, 6.75, 72.16),
            (llama2_13b(), 2, 64, 3.98, 83.58),
            (llama2_70b(), 4, 128, 34.10, 73.46),
            (llama2_70b(), 2, 64, 19.16, 85.09),
            (llama2_70b(), 2, 128, 18.04, 85.96),
        ];
        for (shape, bits, group, want_gib, want_pct) in rows {
            let r = report(&shape, QuantScheme::new(bits, group));
            let rel = (r.size_gib - want_gib).abs() / want_gib;
            assert!(
                rel < 0.015,
                "{} w{}g{}: got {:.2} GiB want {want_gib}",
                shape.name, bits, group, r.size_gib
            );
            assert!(
                (r.compression_pct - want_pct).abs() < 1.0,
                "{} w{}g{}: got {:.2}% want {want_pct}%",
                shape.name, bits, group, r.compression_pct
            );
        }
    }

    #[test]
    fn fp16_sizes_match_paper() {
        assert!((fp16_size_gib(&llama2_7b()) - 12.55).abs() < 0.1);
        assert!((fp16_size_gib(&llama2_13b()) - 24.24).abs() < 0.2);
        assert!((fp16_size_gib(&llama2_70b()) - 128.48).abs() < 0.7);
    }

    #[test]
    fn smaller_groups_cost_more_bits() {
        let s = llama2_7b();
        let g32 = quantized_size_gib(&s, QuantScheme::new(2, 32));
        let g64 = quantized_size_gib(&s, QuantScheme::new(2, 64));
        let g128 = quantized_size_gib(&s, QuantScheme::new(2, 128));
        assert!(g32 > g64 && g64 > g128);
    }
}
