//! Execution-backend layer (L3 <-> L2 bridge): the trait surface the
//! coordinator, eval, and experiment layers program against, plus the two
//! interchangeable implementations:
//!
//!   * [`native`] - a pure-Rust CPU implementation of every lowered
//!     executable (block/model forwards, the Block-AP fake-quant train step
//!     with STE gradients, the E2E-QP step-size train step, pretraining,
//!     and the baseline steps). Always available; no artifacts needed.
//!   * [`pjrt`] - the original AOT-artifact path: loads HLO-text files
//!     produced by python/compile/aot.py, compiles them once on the PJRT
//!     CPU client, and executes them with typed host buffers. Requires
//!     `make artifacts` plus real xla-rs bindings (the in-tree
//!     `rust/src/xla_stub.rs` stub makes it fail cleanly at runtime when
//!     they are absent).
//!
//! The contract is manifest-driven: a [`Backend`] exposes a
//! [`Manifest`](crate::io::manifest::Manifest) (presets, flat-buffer
//! layouts, artifact arg specs) and resolves `(preset, entry)` names to
//! [`Executor`]s whose [`Executor::run`] is spec-checked against the
//! declared argument shapes/dtypes. Callers never know which backend they
//! are on - `run_block_ap`, `run_e2e_qp`, `perplexity`, the sweep drivers
//! and the CLI all take `&dyn Backend`.
//!
//! Re-pointing at real xla-rs later: swap the `use crate::xla_stub as xla;`
//! import in [`pjrt`] for the real bindings; no caller changes. Backend
//! selection is wired through the CLI (`--backend native|pjrt|auto`, see
//! [`make_backend`]); `auto` prefers PJRT when artifacts exist and falls
//! back to the native backend otherwise.

pub mod native;
pub mod pjrt;

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::io::manifest::{ArtifactSpec, Dtype, Manifest};

pub use pjrt::PjrtRuntime;

/// A host-side argument for an executable.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

impl<'a> Arg<'a> {
    pub(crate) fn check(
        &self,
        spec: &crate::io::manifest::ArgSpec,
    ) -> Result<()> {
        let want: usize = spec.shape.iter().product();
        match self {
            Arg::F32(v) => {
                if spec.dtype != Dtype::F32 {
                    bail!("arg '{}': dtype mismatch (want f32)", spec.name);
                }
                if v.len() != want {
                    bail!(
                        "arg '{}': {} elems, spec {:?} wants {}",
                        spec.name, v.len(), spec.shape, want
                    );
                }
            }
            Arg::I32(v) => {
                if spec.dtype != Dtype::I32 {
                    bail!("arg '{}': dtype mismatch (want i32)", spec.name);
                }
                if v.len() != want {
                    bail!(
                        "arg '{}': {} elems, spec {:?} wants {}",
                        spec.name, v.len(), spec.shape, want
                    );
                }
            }
            Arg::Scalar(_) => {
                if want != 1 {
                    bail!("arg '{}': scalar passed, spec {:?}", spec.name,
                          spec.shape);
                }
            }
        }
        Ok(())
    }
}

/// One output buffer copied back to the host.
#[derive(Debug, Clone)]
pub struct OutBuf {
    pub name: String,
    pub data: Vec<f32>,
}

/// Check arg count and each arg against an artifact spec (shared by all
/// backends so the call surface rejects the same mistakes everywhere).
pub fn check_args(spec: &ArtifactSpec, args: &[Arg]) -> Result<()> {
    if args.len() != spec.args.len() {
        bail!(
            "{}: got {} args, spec wants {} ({:?})",
            spec.entry,
            args.len(),
            spec.args.len(),
            spec.args.iter().map(|a| &a.name).collect::<Vec<_>>()
        );
    }
    for (arg, aspec) in args.iter().zip(&spec.args) {
        arg.check(aspec)
            .with_context(|| format!("entry {}", spec.entry))?;
    }
    Ok(())
}

/// One compiled/lowered executable: the `Runtime::run`-style spec-checked
/// call surface every training and eval loop drives.
pub trait Executor {
    /// The artifact spec this executable was resolved from.
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with spec-checked args; returns outputs in manifest order.
    fn run(&self, args: &[Arg]) -> Result<Vec<OutBuf>>;

    /// Execute with spec-checked args, writing outputs (manifest order)
    /// into caller-held buffers whose allocations persist across calls.
    /// The native backend overrides this to compute results **in place**
    /// - a steady-state train/eval loop that hands the same `outs` back
    /// every step allocates no fresh output Vec per step (ROADMAP's
    /// "persistent output buffers" lever). Default: `run` + move.
    fn run_into(&self, args: &[Arg], outs: &mut Vec<Vec<f32>>)
                -> Result<()> {
        let bufs = self.run(args)?;
        outs.clear();
        outs.extend(bufs.into_iter().map(|b| b.data));
        Ok(())
    }

    /// Convenience: run and return the single output.
    fn run1(&self, args: &[Arg]) -> Result<Vec<f32>> {
        let mut outs = self.run(args)?;
        if outs.len() != 1 {
            bail!("{}: expected 1 output, got {}", self.spec().entry,
                  outs.len());
        }
        Ok(outs.pop().unwrap().data)
    }
}

/// An execution backend: a manifest (presets, layouts, specs) plus a
/// resolver from `(preset, entry)` names to executables.
pub trait Backend {
    /// Shape/layout source of truth for everything this backend runs.
    fn manifest(&self) -> &Manifest;

    /// Resolve (and lazily compile/cache) an executable.
    fn exec(&self, preset: &str, entry: &str) -> Result<Rc<dyn Executor>>;

    /// Entry name with group suffix, e.g. ("block_ap_step", 64) ->
    /// "block_ap_step_g64".
    fn exec_g(
        &self,
        preset: &str,
        entry: &str,
        group: usize,
    ) -> Result<Rc<dyn Executor>> {
        self.exec(preset, &format!("{entry}_g{group}"))
    }

    /// Human-readable platform tag ("cpu" for PJRT-CPU, "native-cpu").
    fn platform(&self) -> String;
}

/// Build a backend from a CLI-style choice string:
///   * `"native"` - the pure-Rust backend (built-in presets, no artifacts)
///   * `"pjrt"`   - the AOT-artifact PJRT runtime (errors without
///     artifacts/real xla bindings)
///   * `"auto"`   - PJRT when `artifacts_dir/manifest.json` exists and the
///     client comes up, native otherwise (the default)
pub fn make_backend(choice: &str, artifacts_dir: &str)
                    -> Result<Box<dyn Backend>> {
    match choice {
        "native" => Ok(Box::new(native::NativeBackend::new())),
        "pjrt" => Ok(Box::new(PjrtRuntime::new(artifacts_dir)?)),
        "auto" | "" => {
            let has_manifest = std::path::Path::new(artifacts_dir)
                .join("manifest.json")
                .exists();
            if has_manifest {
                match PjrtRuntime::new(artifacts_dir) {
                    Ok(rt) => return Ok(Box::new(rt)),
                    Err(e) => {
                        crate::info!(
                            "pjrt backend unavailable ({e:#}); \
                             falling back to native"
                        );
                    }
                }
            }
            Ok(Box::new(native::NativeBackend::new()))
        }
        other => bail!(
            "unknown backend '{other}' (native | pjrt | auto)"),
    }
}
