//! PJRT runtime (L3 <-> L2 bridge): loads AOT HLO-text artifacts produced by
//! python/compile/aot.py, compiles them once on the PJRT CPU client, and
//! executes them with typed, spec-checked host buffers.
//!
//! Python never runs here - the HLO text files are the entire interface.
//! Pattern adapted from /opt/xla-example/load_hlo/.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::io::manifest::{ArtifactSpec, Dtype, Manifest};
use crate::xla_stub as xla;

/// A host-side argument for an executable.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

impl<'a> Arg<'a> {
    fn check(&self, spec: &crate::io::manifest::ArgSpec) -> Result<()> {
        let want: usize = spec.shape.iter().product();
        match self {
            Arg::F32(v) => {
                if spec.dtype != Dtype::F32 {
                    bail!("arg '{}': dtype mismatch (want f32)", spec.name);
                }
                if v.len() != want {
                    bail!(
                        "arg '{}': {} elems, spec {:?} wants {}",
                        spec.name, v.len(), spec.shape, want
                    );
                }
            }
            Arg::I32(v) => {
                if spec.dtype != Dtype::I32 {
                    bail!("arg '{}': dtype mismatch (want i32)", spec.name);
                }
                if v.len() != want {
                    bail!(
                        "arg '{}': {} elems, spec {:?} wants {}",
                        spec.name, v.len(), spec.shape, want
                    );
                }
            }
            Arg::Scalar(_) => {
                if want != 1 {
                    bail!("arg '{}': scalar passed, spec {:?}", spec.name,
                          spec.shape);
                }
            }
        }
        Ok(())
    }

    /// Host -> device transfer as an OWNED PjRtBuffer.
    ///
    /// We deliberately avoid `PjRtLoadedExecutable::execute(&[Literal])`:
    /// its C shim (`xla_rs.cc::execute`) `release()`s every input device
    /// buffer without ever deleting it - ~100 MB leaked per train step on
    /// the `small` preset (found via OOM at 36 GB RSS; see EXPERIMENTS.md
    /// §Perf). `execute_b` borrows caller-owned buffers instead, and Rust
    /// frees them on Drop.
    fn to_buffer(&self, client: &xla::PjRtClient, shape: &[usize])
                 -> Result<xla::PjRtBuffer> {
        let buf = match self {
            Arg::F32(v) => {
                client.buffer_from_host_buffer::<f32>(v, shape, None)?
            }
            Arg::I32(v) => {
                client.buffer_from_host_buffer::<i32>(v, shape, None)?
            }
            Arg::Scalar(x) => client
                .buffer_from_host_buffer::<f32>(&[*x], shape, None)?,
        };
        Ok(buf)
    }
}

/// One output buffer copied back to the host.
#[derive(Debug, Clone)]
pub struct OutBuf {
    pub name: String,
    pub data: Vec<f32>,
}

/// A compiled artifact with its argument spec.
pub struct Exec {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Exec {
    /// Execute with spec-checked args; returns outputs in manifest order.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<OutBuf>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "{}: got {} args, spec wants {} ({:?})",
                self.spec.entry,
                args.len(),
                self.spec.args.len(),
                self.spec.args.iter().map(|a| &a.name).collect::<Vec<_>>()
            );
        }
        let mut bufs = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.spec.args) {
            arg.check(spec)
                .with_context(|| format!("entry {}", self.spec.entry))?;
            bufs.push(arg.to_buffer(&self.client, &spec.shape)?);
        }
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, spec wants {}",
                self.spec.entry,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, name) in parts.into_iter().zip(&self.spec.outputs) {
            let n = lit.element_count();
            let mut data = vec![0f32; n];
            lit.copy_raw_to(&mut data)?;
            out.push(OutBuf { name: name.clone(), data });
        }
        Ok(out)
    }

    /// Convenience: run and return the single output.
    pub fn run1(&self, args: &[Arg]) -> Result<Vec<f32>> {
        let mut outs = self.run(args)?;
        if outs.len() != 1 {
            bail!("{}: expected 1 output, got {}", self.spec.entry,
                  outs.len());
        }
        Ok(outs.pop().unwrap().data)
    }
}

/// Manifest-driven executable registry. Compiles lazily and caches.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::cell::RefCell<BTreeMap<String, std::rc::Rc<Exec>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: std::cell::RefCell::new(BTreeMap::new()),
        })
    }

    /// Load + compile (or fetch from cache) an artifact.
    pub fn exec(&self, preset: &str, entry: &str) -> Result<std::rc::Rc<Exec>> {
        let key = format!("{preset}/{entry}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(preset, entry)?.clone();
        let path = self.manifest.root.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {key}: {e}"))?;
        crate::debug!("compiled {key} in {:.2}s", t0.elapsed().as_secs_f64());
        let exec = std::rc::Rc::new(Exec {
            spec,
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(key, exec.clone());
        Ok(exec)
    }

    /// Entry name with group suffix, e.g. ("block_ap_step", 64) ->
    /// "block_ap_step_g64".
    pub fn exec_g(
        &self,
        preset: &str,
        entry: &str,
        group: usize,
    ) -> Result<std::rc::Rc<Exec>> {
        self.exec(preset, &format!("{entry}_g{group}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
